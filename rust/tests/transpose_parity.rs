//! Bit-parity pins for the transpose module (ISSUE 8 tentpole).
//!
//! The executor's in-place / parallel transpose paths must be *bit
//! identical* to the copy-based reference — these are pure element moves,
//! so any deviation is an indexing bug, not a rounding difference. All
//! assertions here are exact (`assert_eq!` on the raw values).

use so3ft::pool::WorkerPool;
use so3ft::transpose::{
    gather_permuted, transpose_in_place, transpose_into, transpose_into_parallel,
    transpose_square_in_place,
};
use so3ft::Complex64;

fn pseudo(i: usize) -> Complex64 {
    // Deterministic, irregular values; exact equality is meaningful.
    let x = ((i as u64).wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407) >> 11)
        as f64
        / (1u64 << 53) as f64;
    Complex64::new(x, 1.0 - 2.0 * x)
}

fn matrix(rows: usize, cols: usize) -> Vec<Complex64> {
    (0..rows * cols).map(pseudo).collect()
}

fn naive(src: &[Complex64], rows: usize, cols: usize) -> Vec<Complex64> {
    let mut out = vec![Complex64::zero(); rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

/// Square, rectangular, and odd-tail shapes exercised everywhere below.
const SHAPES: &[(usize, usize)] = &[
    (1, 1),
    (2, 2),
    (8, 8),
    (32, 32),
    (33, 33),
    (64, 64),
    (65, 65),
    (5, 3),
    (3, 5),
    (7, 4),
    (16, 48),
    (48, 16),
    (33, 17),
    (17, 33),
    (1, 19),
    (19, 1),
    (63, 65),
];

#[test]
fn copy_based_transpose_is_bit_exact() {
    for &(rows, cols) in SHAPES {
        let src = matrix(rows, cols);
        let mut dst = vec![Complex64::zero(); rows * cols];
        transpose_into(&mut dst, &src, rows, cols);
        assert_eq!(dst, naive(&src, rows, cols), "shape {rows}x{cols}");
    }
}

#[test]
fn in_place_matches_copy_based_bitwise() {
    for &(rows, cols) in SHAPES {
        let src = matrix(rows, cols);
        let mut copy = vec![Complex64::zero(); rows * cols];
        transpose_into(&mut copy, &src, rows, cols);
        let mut inplace = src.clone();
        transpose_in_place(&mut inplace, rows, cols);
        assert_eq!(inplace, copy, "shape {rows}x{cols}");
    }
}

#[test]
fn square_in_place_matches_copy_based_bitwise() {
    for &n in &[1usize, 2, 16, 31, 32, 33, 64, 65, 127, 128] {
        let src = matrix(n, n);
        let mut copy = vec![Complex64::zero(); n * n];
        transpose_into(&mut copy, &src, n, n);
        let mut inplace = src.clone();
        transpose_square_in_place(&mut inplace, n);
        assert_eq!(inplace, copy, "n={n}");
    }
}

#[test]
fn double_in_place_restores_the_original_bitwise() {
    for &(rows, cols) in SHAPES {
        let src = matrix(rows, cols);
        let mut a = src.clone();
        transpose_in_place(&mut a, rows, cols);
        transpose_in_place(&mut a, cols, rows);
        assert_eq!(a, src, "shape {rows}x{cols}");
    }
}

#[test]
fn parallel_matches_sequential_bitwise_on_shared_pool() {
    // One shared pool for every shape/thread combination, as the executor
    // would use it; includes shapes above and below PARALLEL_THRESHOLD.
    let pool = WorkerPool::new(4).expect("pool");
    for &(rows, cols) in &[(64usize, 64usize), (128, 512), (512, 128), (300, 300), (511, 513)] {
        let src = matrix(rows, cols);
        let mut seq = vec![Complex64::zero(); rows * cols];
        transpose_into(&mut seq, &src, rows, cols);
        for threads in [1usize, 2, 3, 4] {
            let mut par = vec![Complex64::zero(); rows * cols];
            transpose_into_parallel(&mut par, &src, rows, cols, &pool, threads);
            assert_eq!(par, seq, "shape {rows}x{cols} threads {threads}");
        }
    }
}

#[test]
fn gather_permuted_matches_reference_bitwise() {
    let (rows, cols) = (31, 40);
    let src_stride = 37;
    let src = matrix(cols, src_stride);
    let perm: Vec<usize> = (0..rows).map(|r| (r * 11 + 5) % src_stride).collect();
    let mut want = vec![Complex64::zero(); rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            want[r * cols + c] = src[c * src_stride + perm[r]];
        }
    }
    let mut got = vec![Complex64::zero(); rows * cols];
    gather_permuted(&mut got, cols, &src, src_stride, &perm, rows, cols);
    assert_eq!(got, want);
}

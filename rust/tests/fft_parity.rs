//! Parity suite for the overhauled FFT engine (ISSUE 2).
//!
//! Pins four guarantees:
//! 1. the split-radix (radix-4) and real-input kernels agree with the
//!    naive `fft::dft` oracle for n ∈ {2 … 1024}, both signs;
//! 2. the copy-free panel column pass agrees with the gather/scatter
//!    sweep (same plan, same butterflies, different memory walk);
//! 3. the split-radix engine and the radix-2 baseline engine agree to
//!    ≤ 1e-12 on the full forward+inverse round-trip at b ∈ {8, 16, 32}
//!    (b = 64 behind `--ignored`, see docs/PERF.md);
//! 4. the real-input analysis path matches the complex path on real
//!    bandlimited grids at b ∈ {8, 16, 32} and round-trips through
//!    synthesis, with complex data rejected as a typed error.

use so3ft::error::Error;
use so3ft::fft::dft::{dft, dft2};
use so3ft::fft::fft2::{ColumnPass, Fft2};
use so3ft::fft::real::{RealFft2, RealFftPlan};
use so3ft::fft::split_radix::Radix4Plan;
use so3ft::fft::{Complex64, FftAlgo, FftEngine, FftPlan, Sign};
use so3ft::prng::Xoshiro256;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;
use std::sync::Arc;

fn random_signal(n: usize, seed: u64) -> Vec<Complex64> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
        .collect()
}

fn random_real(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n).map(|_| rng.next_signed()).collect()
}

// ---------------------------------------------------------------------
// 1. 1-D kernels vs the naive DFT oracle, n ∈ {2 … 1024}, both signs
// ---------------------------------------------------------------------

#[test]
fn split_radix_matches_dft_oracle_2_to_1024() {
    for log in 1..=10 {
        let n = 1usize << log;
        let plan = Radix4Plan::new(n);
        for sign in [Sign::Negative, Sign::Positive] {
            let x = random_signal(n, 1000 + log as u64);
            let want = dft(&x, sign);
            let mut got = x;
            plan.process(&mut got, sign);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!(
                    (*a - *b).abs() < 1e-9 * n as f64,
                    "split-radix n={n} sign={sign:?}"
                );
            }
        }
    }
}

#[test]
fn split_radix_agrees_with_radix2_kernel() {
    for log in 1..=10 {
        let n = 1usize << log;
        let r4 = FftPlan::with_algo(n, FftAlgo::SplitRadix);
        let r2 = FftPlan::with_algo(n, FftAlgo::Radix2);
        for sign in [Sign::Negative, Sign::Positive] {
            let x = random_signal(n, 2000 + log as u64);
            let mut a = x.clone();
            let mut b = x;
            r4.process(&mut a, sign);
            r2.process(&mut b, sign);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((*u - *v).abs() < 1e-10 * n as f64, "n={n} sign={sign:?}");
            }
        }
    }
}

#[test]
fn real_input_matches_dft_oracle_2_to_1024() {
    // Powers of two plus assorted even sizes (odd half-lengths exercise
    // the Bluestein inner path of the packed transform).
    for &n in &[2usize, 4, 6, 8, 10, 12, 16, 20, 32, 64, 96, 128, 256, 512, 1024] {
        let plan = RealFftPlan::new(n);
        let x = random_real(n, 3000 + n as u64);
        let xc: Vec<Complex64> = x.iter().map(|&r| Complex64::new(r, 0.0)).collect();
        let mut scratch = vec![Complex64::zero(); plan.scratch_len()];
        for sign in [Sign::Negative, Sign::Positive] {
            let want = dft(&xc, sign);
            let mut got = vec![Complex64::zero(); n];
            plan.forward(&x, &mut got, &mut scratch, sign);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!(
                    (*a - *b).abs() < 1e-9 * n as f64,
                    "real n={n} sign={sign:?}"
                );
            }
        }
    }
}

#[test]
fn real_input_forward_inverse_is_identity_times_n() {
    for &n in &[4usize, 8, 30, 64, 1024] {
        let plan = RealFftPlan::new(n);
        let x = random_real(n, 71);
        let mut spec = vec![Complex64::zero(); n];
        let mut back = vec![0.0f64; n];
        let mut scratch = vec![Complex64::zero(); plan.scratch_len()];
        plan.forward(&x, &mut spec, &mut scratch, Sign::Negative);
        plan.inverse(&spec, &mut back, &mut scratch, Sign::Positive);
        for (a, b) in x.iter().zip(back.iter()) {
            assert!((a * n as f64 - b).abs() < 1e-9 * n as f64, "n={n}");
        }
    }
}

// ---------------------------------------------------------------------
// 2. Panel pass vs gather/scatter pass
// ---------------------------------------------------------------------

#[test]
fn fft2_panel_matches_gather_scatter() {
    for &n in &[2usize, 4, 8, 16, 32, 64] {
        let plan = Arc::new(FftPlan::with_algo(n, FftAlgo::SplitRadix));
        let panel = Fft2::with_column_pass(n, plan.clone(), ColumnPass::Panel);
        let gather = Fft2::with_column_pass(n, plan, ColumnPass::GatherScatter);
        assert_eq!(panel.scratch_len(), 0);
        for sign in [Sign::Negative, Sign::Positive] {
            let x = random_signal(n * n, 4000 + n as u64);
            let mut a = x.clone();
            let mut b = x;
            let mut sa = vec![];
            let mut sb = vec![Complex64::zero(); gather.scratch_len()];
            panel.process(&mut a, &mut sa, sign);
            gather.process(&mut b, &mut sb, sign);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!(
                    (*u - *v).abs() < 1e-12 * (n * n) as f64,
                    "n={n} sign={sign:?}"
                );
            }
        }
    }
}

#[test]
fn fft2_panel_matches_2d_oracle() {
    for &n in &[4usize, 8, 16] {
        let fft2 = Fft2::with_size(n);
        assert_eq!(fft2.column_pass(), ColumnPass::Panel);
        for sign in [Sign::Negative, Sign::Positive] {
            let x = random_signal(n * n, 5000 + n as u64);
            let want = dft2(&x, n, n, sign);
            let mut got = x;
            let mut scratch = vec![];
            fft2.process(&mut got, &mut scratch, sign);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((*a - *b).abs() < 1e-8 * (n * n) as f64, "n={n} sign={sign:?}");
            }
        }
    }
}

#[test]
fn real_fft2_matches_complex_fft2_on_real_slices() {
    for &n in &[2usize, 4, 8, 16, 32, 64] {
        let plan = Arc::new(FftPlan::new(n));
        let complex_fft2 = Fft2::new(n, plan.clone());
        let real_fft2 = RealFft2::new(n, plan);
        let x = random_real(n * n, 6000 + n as u64);
        let xc: Vec<Complex64> = x.iter().map(|&r| Complex64::new(r, 0.0)).collect();
        for sign in [Sign::Negative, Sign::Positive] {
            let mut a = xc.clone();
            let mut b = xc.clone();
            let mut sa = vec![Complex64::zero(); complex_fft2.scratch_len()];
            let mut sb = vec![Complex64::zero(); real_fft2.scratch_len()];
            complex_fft2.process(&mut a, &mut sa, sign);
            real_fft2.forward(&mut b, &mut sb, sign);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!(
                    (*u - *v).abs() < 1e-11 * (n * n) as f64,
                    "n={n} sign={sign:?}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// 3. Engine agreement on the full forward+inverse round-trip
// ---------------------------------------------------------------------

fn engines_roundtrip(b: usize, storage_on_the_fly: bool) {
    let build = |engine: FftEngine| {
        let mut builder = So3Plan::builder(b).fft_engine(engine);
        if storage_on_the_fly {
            builder = builder.storage(so3ft::dwt::tables::WignerStorage::OnTheFly);
        }
        builder.build().unwrap()
    };
    let split = build(FftEngine::SplitRadix);
    let baseline = build(FftEngine::Radix2Baseline);
    let coeffs = So3Coeffs::random(b, 42 + b as u64);
    let g_split = split.inverse(&coeffs).unwrap();
    let g_base = baseline.inverse(&coeffs).unwrap();
    assert!(
        g_split.max_abs_error(&g_base) < 1e-12,
        "b={b}: inverse grids diverge"
    );
    let c_split = split.forward(&g_split).unwrap();
    let c_base = baseline.forward(&g_base).unwrap();
    assert!(
        c_split.max_abs_error(&c_base) < 1e-12,
        "b={b}: roundtrip coefficients diverge"
    );
    // And both engines actually round-trip.
    assert!(coeffs.max_abs_error(&c_split) < 1e-10, "b={b}: split engine");
    assert!(coeffs.max_abs_error(&c_base) < 1e-10, "b={b}: baseline engine");
}

#[test]
fn engines_agree_roundtrip_small() {
    for b in [8usize, 16, 32] {
        engines_roundtrip(b, false);
    }
}

/// The b = 64 acceptance point — heavier, so opt-in:
/// `cargo test --release -- --ignored engines_agree_roundtrip_large`.
#[test]
#[ignore = "b=64 roundtrip is slow in debug; run with --release -- --ignored"]
fn engines_agree_roundtrip_large() {
    engines_roundtrip(64, true);
}

// ---------------------------------------------------------------------
// 4. Real-input plan mode
// ---------------------------------------------------------------------

/// The real part of a bandlimited function is bandlimited, so
/// `inverse(random coeffs).re` is a real grid the sampling theorem holds
/// for — the forward transform is exact on it and synthesis restores it.
fn real_bandlimited_grid(plan: &So3Plan, b: usize, seed: u64) -> So3Grid {
    let coeffs = So3Coeffs::random(b, seed);
    let g = plan.inverse(&coeffs).unwrap();
    So3Grid::from_vec(
        b,
        g.as_slice()
            .iter()
            .map(|z| Complex64::new(z.re, 0.0))
            .collect(),
    )
    .unwrap()
}

#[test]
fn real_input_plan_matches_complex_plan() {
    for b in [8usize, 16, 32] {
        let complex_plan = So3Plan::new(b).unwrap();
        let real_plan = So3Plan::builder(b).real_input().build().unwrap();
        let grid = real_bandlimited_grid(&complex_plan, b, 7 + b as u64);
        let want = complex_plan.forward(&grid).unwrap();
        let got = real_plan.forward(&grid).unwrap();
        assert!(
            want.max_abs_error(&got) < 1e-12,
            "b={b}: real-input analysis diverges from complex"
        );
    }
}

#[test]
fn real_input_forward_inverse_roundtrip() {
    for b in [8usize, 16, 32] {
        let real_plan = So3Plan::builder(b).real_input().build().unwrap();
        let grid = real_bandlimited_grid(&real_plan, b, 90 + b as u64);
        let coeffs = real_plan.forward(&grid).unwrap();
        let back = real_plan.inverse(&coeffs).unwrap();
        let err = grid.max_abs_error(&back);
        assert!(err < 1e-11, "b={b}: real roundtrip error {err}");
    }
}

#[test]
fn real_input_rejects_complex_data_typed() {
    let b = 8;
    let real_plan = So3Plan::builder(b).real_input().build().unwrap();
    let coeffs = So3Coeffs::random(b, 3);
    let complex_grid = real_plan.inverse(&coeffs).unwrap();
    match real_plan.forward(&complex_grid) {
        Err(Error::RealInputRequired { .. }) => {}
        other => panic!("expected RealInputRequired, got {other:?}"),
    }
    // Workspaceful entry point takes the same validation path.
    let mut ws = real_plan.make_workspace();
    let mut out = So3Coeffs::zeros(b);
    assert!(matches!(
        real_plan.forward_into(&complex_grid, &mut out, &mut ws),
        Err(Error::RealInputRequired { .. })
    ));
}

#[test]
fn real_input_works_with_baseline_engine_too() {
    let b = 8;
    let plan = So3Plan::builder(b)
        .real_input()
        .fft_engine(FftEngine::Radix2Baseline)
        .build()
        .unwrap();
    let reference = So3Plan::new(b).unwrap();
    let grid = real_bandlimited_grid(&reference, b, 55);
    let want = reference.forward(&grid).unwrap();
    let got = plan.forward(&grid).unwrap();
    assert!(want.max_abs_error(&got) < 1e-12);
}

//! Integration: the `wisdom/` measured auto-tuning planner.
//!
//! Pins the subsystem's acceptance contract end to end:
//!
//! * a `Measure` build is **bit-identical** to an `Estimate` build
//!   configured with the same winning knobs (wisdom selects among
//!   parity-tested engines; it never changes what they compute);
//! * wisdom round-trips through the on-disk `SO3WIS1` store across
//!   store reopens (measure once — ever);
//! * a wrong-version or corrupt store file degrades to Estimate
//!   behavior with a typed warning, never an error;
//! * a store written on a *different machine* (foreign fingerprint) is
//!   re-measured, not served as a stale hit;
//! * `So3Service`'s single-flight plan registry runs ONE measurement
//!   pass under concurrent cold misses on one key.

use std::path::PathBuf;
use std::sync::Arc;

use so3ft::service::{PlanOptions, So3Service};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;
use so3ft::wisdom::{PlanRigor, WisdomSource, WisdomStore, WisdomWarning};

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "so3ft-wisdom-it-{tag}-{}.so3wis",
        std::process::id()
    ))
}

/// Acceptance: wisdom only *selects* a configuration — a Measure-built
/// plan and an Estimate plan hand-configured with the measured winner
/// produce bit-identical transforms in both directions.
#[test]
fn measure_is_bit_identical_to_estimate_with_winning_knobs() {
    let b = 8;
    let store = WisdomStore::in_memory();
    let measured = So3Plan::builder(b)
        .threads(1)
        .rigor(PlanRigor::Measure)
        .wisdom_store(Arc::clone(&store))
        .wisdom_time_budget_ms(60)
        .build()
        .unwrap();
    let outcome = measured.wisdom().expect("Measure build reports wisdom");
    assert_eq!(outcome.source, WisdomSource::Measured);
    let choice = outcome.choice.clone().expect("measured build has a choice");
    assert_eq!(store.stats().measurements, 1);

    let estimate = So3Plan::builder(b)
        .threads(1)
        .schedule(choice.schedule)
        .strategy(choice.strategy)
        .algorithm(choice.algorithm)
        .fft_engine(choice.fft_engine)
        .build()
        .unwrap();
    // Estimate never attaches a wisdom outcome.
    assert!(estimate.wisdom().is_none());

    for seed in [3u64, 17] {
        let coeffs = So3Coeffs::random(b, seed);
        let g_m = measured.inverse(&coeffs).unwrap();
        let g_e = estimate.inverse(&coeffs).unwrap();
        assert_eq!(g_m.as_slice(), g_e.as_slice(), "inverse, seed {seed}");
        let c_m = measured.forward(&g_m).unwrap();
        let c_e = estimate.forward(&g_e).unwrap();
        assert_eq!(c_m.as_slice(), c_e.as_slice(), "forward, seed {seed}");
    }
}

/// Acceptance: the winner persists across store reopens — the second
/// process-lifetime (simulated by reopening the file) serves a cache
/// hit with the same knobs and runs zero measurement passes.
#[test]
fn on_disk_wisdom_round_trips_across_plan_builds() {
    let b = 8;
    let path = temp_path("roundtrip");
    let _ = std::fs::remove_file(&path);

    let store = WisdomStore::open(&path);
    let first = So3Plan::builder(b)
        .threads(1)
        .rigor(PlanRigor::Measure)
        .wisdom_store(Arc::clone(&store))
        .wisdom_time_budget_ms(60)
        .build()
        .unwrap();
    let first_choice = first.wisdom().unwrap().choice.clone().unwrap();
    assert_eq!(first.wisdom().unwrap().source, WisdomSource::Measured);
    assert!(path.is_file(), "measurement persisted to {path:?}");
    drop(store);

    let reopened = WisdomStore::open(&path);
    let second = So3Plan::builder(b)
        .threads(1)
        .rigor(PlanRigor::Measure)
        .wisdom_store(Arc::clone(&reopened))
        .wisdom_time_budget_ms(60)
        .build()
        .unwrap();
    let outcome = second.wisdom().unwrap();
    assert_eq!(outcome.source, WisdomSource::CacheHit);
    assert_eq!(reopened.stats().measurements, 0, "no re-measurement");
    let hit = outcome.choice.clone().unwrap();
    // Same knobs (seconds go through {:.6e} text, so compare choices
    // only on the axes wisdom applies).
    assert_eq!(hit.schedule, first_choice.schedule);
    assert_eq!(hit.strategy, first_choice.strategy);
    assert_eq!(hit.algorithm, first_choice.algorithm);
    assert_eq!(hit.fft_engine, first_choice.fft_engine);

    let _ = std::fs::remove_file(&path);
}

/// Acceptance: degraded stores are warnings, not errors. A
/// wrong-version file reports `VersionMismatch`, a garbage file
/// `CorruptStore`; both keep the Estimate defaults, run no measurement,
/// and still build a working (bit-identical-to-Estimate) plan.
#[test]
fn degraded_store_falls_back_to_estimate() {
    let b = 8;
    let cases: [(&str, &str); 2] = [
        ("version", "SO3WIS9\nfingerprint 0000000000000000\n"),
        ("corrupt", "not a wisdom file at all\x00\n"),
    ];
    let baseline = So3Plan::builder(b).threads(1).build().unwrap();
    let coeffs = So3Coeffs::random(b, 5);
    let g_base = baseline.inverse(&coeffs).unwrap();

    for (tag, contents) in cases {
        let path = temp_path(tag);
        std::fs::write(&path, contents).unwrap();
        let store = WisdomStore::open(&path);
        let plan = So3Plan::builder(b)
            .threads(1)
            .rigor(PlanRigor::Measure)
            .wisdom_store(Arc::clone(&store))
            .wisdom_time_budget_ms(60)
            .build()
            .unwrap();
        let outcome = plan.wisdom().unwrap();
        match (tag, &outcome.source) {
            ("version", WisdomSource::Fallback(WisdomWarning::VersionMismatch { found, .. })) => {
                assert_eq!(found, "SO3WIS9")
            }
            ("corrupt", WisdomSource::Fallback(WisdomWarning::CorruptStore { .. })) => {}
            other => panic!("{tag}: unexpected wisdom source {other:?}"),
        }
        assert!(outcome.choice.is_none(), "{tag}: fallback applies no knobs");
        assert_eq!(store.stats().measurements, 0, "{tag}: no search on fallback");
        // Estimate defaults kept — the plan computes exactly what an
        // Estimate plan computes.
        assert_eq!(plan.config().schedule, baseline.config().schedule);
        assert_eq!(plan.config().algorithm, baseline.config().algorithm);
        assert_eq!(plan.config().fft_engine, baseline.config().fft_engine);
        assert_eq!(plan.config().strategy, baseline.config().strategy);
        let g = plan.inverse(&coeffs).unwrap();
        assert_eq!(g.as_slice(), g_base.as_slice(), "{tag}: bit-identical");
        // The degraded file is left untouched for diagnosis, never
        // rewritten.
        assert_eq!(std::fs::read(&path).unwrap(), contents.as_bytes(), "{tag}");
        let _ = std::fs::remove_file(&path);
    }
}

/// Acceptance: entries recorded on a *different machine* must not be
/// served — a valid SO3WIS1 file with a foreign fingerprint is a clean
/// miss (re-measure), not a stale hit and not a warning.
#[test]
fn foreign_fingerprint_re_measures_instead_of_stale_hit() {
    let b = 8;
    let path = temp_path("foreign");
    // A well-formed store written by fingerprint 0 (never the real
    // digest) carrying deliberately non-default knobs for our exact key.
    let contents = "SO3WIS1\n\
                    fingerprint 0000000000000000\n\
                    entry b=8 dir=inv threads=1 schedule=static strategy=sigma \
                    algorithm=matvec fft=radix2-baseline seconds=1.000000e-3\n\
                    entry b=8 dir=fwd threads=1 schedule=static strategy=sigma \
                    algorithm=matvec fft=radix2-baseline seconds=1.000000e-3\n";
    std::fs::write(&path, contents).unwrap();

    let store = WisdomStore::open(&path);
    let plan = So3Plan::builder(b)
        .threads(1)
        .rigor(PlanRigor::Measure)
        .wisdom_store(Arc::clone(&store))
        .wisdom_time_budget_ms(60)
        .build()
        .unwrap();
    let outcome = plan.wisdom().unwrap();
    assert_eq!(
        outcome.source,
        WisdomSource::Measured,
        "foreign entries must trigger a fresh measurement"
    );
    let stats = store.stats();
    assert_eq!(stats.measurements, 1);
    assert_eq!(stats.hits, 0, "never a stale hit off a foreign file");
    let _ = std::fs::remove_file(&path);
}

/// Acceptance: `So3Service`'s single-flight registry doubles as
/// measurement deduplication — four concurrent cold misses on one plan
/// key run exactly ONE measurement pass and share one plan `Arc`.
#[test]
fn service_single_flight_runs_one_measurement_pass() {
    let b = 8;
    let store = WisdomStore::in_memory();
    let service = So3Service::builder()
        .threads(2)
        .plan_rigor(PlanRigor::Measure)
        .wisdom_store(Arc::clone(&store))
        .build()
        .unwrap();
    let service = Arc::new(service);

    let plans: Vec<Arc<So3Plan>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let service = Arc::clone(&service);
                scope.spawn(move || service.plan(b, PlanOptions::default()).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for plan in &plans[1..] {
        assert!(Arc::ptr_eq(&plans[0], plan), "one shared plan per key");
    }
    assert_eq!(
        store.stats().measurements,
        1,
        "single-flight must deduplicate the measurement pass"
    );
    assert_eq!(plans[0].wisdom().unwrap().source, WisdomSource::Measured);
}

//! Integration: the PJRT-offloaded DWT backend must agree with the native
//! rust path to near machine precision, end to end through the full
//! transforms.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the
//! artifact directory is absent so plain `cargo test` stays green in a
//! fresh checkout.

use std::sync::Arc;

use so3ft::runtime::{ArtifactRegistry, XlaDwt};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn artifacts_for(b: usize) -> Option<Arc<XlaDwt>> {
    if cfg!(not(feature = "xla")) {
        eprintln!("skipping xla test: built without the `xla` feature");
        return None;
    }
    let reg = ArtifactRegistry::default_location();
    if !reg.available().contains(&b) {
        eprintln!(
            "skipping xla test: no artifacts for b={b} in {:?} (run `make artifacts`)",
            reg.dir()
        );
        return None;
    }
    Some(Arc::new(XlaDwt::load(reg.dir(), b).expect("artifact load")))
}

#[test]
fn xla_forward_matches_native() {
    for b in [4usize, 8] {
        let Some(xla) = artifacts_for(b) else { return };
        let native = So3Plan::new(b).unwrap();
        let offloaded = So3Plan::builder(b).offload(xla).build().unwrap();
        let coeffs = So3Coeffs::random(b, 77);
        let grid = native.inverse(&coeffs).unwrap();
        let c_native = native.forward(&grid).unwrap();
        let c_xla = offloaded.forward(&grid).unwrap();
        let err = c_native.max_abs_error(&c_xla);
        assert!(err < 1e-12, "b={b}: native vs xla forward differ by {err}");
    }
}

#[test]
fn xla_inverse_matches_native() {
    for b in [4usize, 8] {
        let Some(xla) = artifacts_for(b) else { return };
        let native = So3Plan::new(b).unwrap();
        let offloaded = So3Plan::builder(b).offload(xla).build().unwrap();
        let coeffs = So3Coeffs::random(b, 78);
        let g_native = native.inverse(&coeffs).unwrap();
        let g_xla = offloaded.inverse(&coeffs).unwrap();
        let err = g_native.max_abs_error(&g_xla);
        assert!(err < 1e-12, "b={b}: native vs xla inverse differ by {err}");
    }
}

#[test]
fn xla_roundtrip_accuracy() {
    let b = 8;
    let Some(xla) = artifacts_for(b) else { return };
    let fft = So3Plan::builder(b).offload(xla).build().unwrap();
    let coeffs = So3Coeffs::random(b, 79);
    let grid = fft.inverse(&coeffs).unwrap();
    let back = fft.forward(&grid).unwrap();
    let err = coeffs.max_abs_error(&back);
    assert!(err < 1e-11, "xla roundtrip error {err}");
}

#[test]
fn xla_backend_parallel_consistency() {
    // The offload serializes internally; results must still match the
    // sequential run bit-for-bit under a multi-threaded coordinator.
    let b = 4;
    let Some(xla) = artifacts_for(b) else { return };
    let coeffs = So3Coeffs::random(b, 80);
    let seq = So3Plan::builder(b).offload(xla.clone()).build().unwrap();
    let par = So3Plan::builder(b).threads(3).offload(xla).build().unwrap();
    let g_seq = seq.inverse(&coeffs).unwrap();
    let g_par = par.inverse(&coeffs).unwrap();
    assert_eq!(g_seq.as_slice(), g_par.as_slice());
}

#[test]
fn registry_reports_built_bandwidths() {
    let reg = ArtifactRegistry::default_location();
    let avail = reg.available();
    if avail.is_empty() {
        eprintln!("skipping: no artifacts built");
        return;
    }
    // Makefile default set.
    for b in [4usize, 8, 16, 32] {
        assert!(avail.contains(&b), "expected artifact for b={b}, have {avail:?}");
    }
}

//! Folded-vs-baseline DWT parity suite (ISSUE 4 acceptance): the
//! β-parity-folded engine must agree with the `matvec` baseline to
//! ≤ 1e-12 in both directions, both precisions, and both Wigner sources
//! at b ∈ {8, 16, 32}; plus the half-table disk format round-trip, the
//! table-size halving, and a full-transform round-trip under
//! `matvec-folded`.

use so3ft::coordinator::PartitionStrategy;
use so3ft::dwt::tables::{WignerStorage, WignerTables};
use so3ft::dwt::{DwtAlgorithm, Precision};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::GridAngles;
use so3ft::transform::So3Plan;

fn plan(
    b: usize,
    algorithm: DwtAlgorithm,
    storage: WignerStorage,
    precision: Precision,
) -> So3Plan {
    So3Plan::builder(b)
        .algorithm(algorithm)
        .storage(storage)
        .precision(precision)
        .build()
        .unwrap()
}

/// The headline acceptance matrix: forward + inverse × double/extended
/// × tables/on-the-fly at b ∈ {8, 16, 32}.
#[test]
fn folded_matches_matvec_both_directions_precisions_and_sources() {
    for b in [8usize, 16, 32] {
        let coeffs = So3Coeffs::random(b, 0xD417 + b as u64);
        for storage in [WignerStorage::Precomputed, WignerStorage::OnTheFly] {
            for precision in [Precision::Double, Precision::Extended] {
                let base = plan(b, DwtAlgorithm::MatVec, storage, precision);
                let fold = plan(b, DwtAlgorithm::MatVecFolded, storage, precision);
                let g_base = base.inverse(&coeffs).unwrap();
                let g_fold = fold.inverse(&coeffs).unwrap();
                let inv_err = g_base.max_abs_error(&g_fold);
                assert!(
                    inv_err < 1e-12,
                    "inverse b={b} {storage:?} {precision:?}: {inv_err:.3e}"
                );
                let c_base = base.forward(&g_base).unwrap();
                let c_fold = fold.forward(&g_fold).unwrap();
                let fwd_err = c_base.max_abs_error(&c_fold);
                assert!(
                    fwd_err < 1e-12,
                    "forward b={b} {storage:?} {precision:?}: {fwd_err:.3e}"
                );
            }
        }
    }
}

/// The folded engine is the default for canonical partitions; its full
/// transform round-trips at baseline accuracy.
#[test]
fn matvec_folded_is_default_and_roundtrips() {
    for b in [4usize, 8, 16] {
        let p = So3Plan::new(b).unwrap();
        assert_eq!(p.config().algorithm, DwtAlgorithm::MatVecFolded);
        let coeffs = So3Coeffs::random(b, 31 + b as u64);
        let grid = p.inverse(&coeffs).unwrap();
        let back = p.forward(&grid).unwrap();
        let err = coeffs.max_abs_error(&back);
        assert!(err < 1e-11, "b={b}: roundtrip error {err:.3e}");
    }
}

/// Folded also serves the no-symmetry ablation (singleton clusters with
/// non-canonical order pairs go through the source-fed folded kernels).
#[test]
fn folded_agrees_under_no_symmetry_partitioning() {
    let b = 8;
    let coeffs = So3Coeffs::random(b, 99);
    let mk = |algorithm| {
        let p = So3Plan::builder(b)
            .algorithm(algorithm)
            .strategy(PartitionStrategy::NoSymmetry)
            .storage(WignerStorage::OnTheFly)
            .build()
            .unwrap();
        let g = p.inverse(&coeffs).unwrap();
        let c = p.forward(&g).unwrap();
        (g, c)
    };
    let (g_base, c_base) = mk(DwtAlgorithm::MatVec);
    let (g_fold, c_fold) = mk(DwtAlgorithm::MatVecFolded);
    assert!(g_base.max_abs_error(&g_fold) < 1e-12);
    assert!(c_base.max_abs_error(&c_fold) < 1e-12);
}

/// Parallel folded execution is bit-identical to sequential folded
/// execution (same kernels, cluster-exclusive writes).
#[test]
fn folded_parallel_matches_sequential_bitwise() {
    let b = 8;
    let coeffs = So3Coeffs::random(b, 7);
    let seq = So3Plan::builder(b).build().unwrap();
    let par = So3Plan::builder(b).threads(3).build().unwrap();
    let g_seq = seq.inverse(&coeffs).unwrap();
    let g_par = par.inverse(&coeffs).unwrap();
    assert_eq!(g_seq.as_slice(), g_par.as_slice());
    let c_seq = seq.forward(&g_seq).unwrap();
    let c_par = par.forward(&g_par).unwrap();
    assert_eq!(c_seq.as_slice(), c_par.as_slice());
}

/// The folded tables report ~half the bytes of the pre-fold full-row
/// layout for the same bandwidth (the acceptance criterion), and the
/// v2 disk format round-trips.
#[test]
fn half_tables_bytes_and_disk_roundtrip() {
    for b in [8usize, 16, 32] {
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        // Pre-fold layout: (B − l0) rows × 2B f64 per base pair.
        let full_bytes: usize = (0..b)
            .flat_map(|m| (0..=m).map(move |_| (b - m) * 2 * b * 8))
            .sum();
        // Exact ratios (the guard rows add O(B³) on top of the halved
        // O(B⁴)): 0.621 at b = 8, 0.574 at 16, 0.542 at 32 → ½
        // asymptotically.
        let ratio = tables.bytes() as f64 / full_bytes as f64;
        assert!(
            (0.45..=0.63).contains(&ratio),
            "b={b}: folded/full bytes = {ratio:.3}"
        );
    }
    let b = 16;
    let angles = GridAngles::new(b).unwrap();
    let tables = WignerTables::build(b, &angles.betas);
    // Round-trip through the canonical cache layout (an explicit dir —
    // never the process-global cache, which other tests may share).
    let dir = std::env::temp_dir().join(format!(
        "so3ft-dwt-parity-cache-{}",
        std::process::id()
    ));
    tables.save_cached_in(&dir).unwrap();
    assert!(WignerTables::cache_path_in(&dir, b).is_file());
    let loaded = WignerTables::load_cached_in(&dir, b).unwrap();
    assert_eq!(loaded.bandwidth(), b);
    assert_eq!(loaded.bytes(), tables.bytes());
    // Loaded tables serve rows identical to the freshly built ones.
    let mut a = vec![0.0; 2 * b];
    let mut c = vec![0.0; 2 * b];
    for (m, mp, l) in [(0i64, 0i64, 5usize), (7, 0, 9), (9, 4, 12), (15, 15, 15)] {
        let x = tables.row_into(m, mp, l, &mut a).to_vec();
        let y = loaded.row_into(m, mp, l, &mut c).to_vec();
        assert_eq!(x, y);
    }
    // Wrong bandwidth at the same path is a typed error, and a missing
    // cache entry is an error, not a silent rebuild.
    assert!(
        WignerTables::load(WignerTables::cache_path_in(&dir, b), b + 1).is_err()
    );
    assert!(WignerTables::load_cached_in(&dir, 2 * b).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Extended precision under the folded engine stays at least as accurate
/// as double precision on a full round-trip.
#[test]
fn folded_extended_no_worse_than_double() {
    let b = 16;
    let coeffs = So3Coeffs::random(b, 55);
    let run = |precision| {
        let p = plan(
            b,
            DwtAlgorithm::MatVecFolded,
            WignerStorage::OnTheFly,
            precision,
        );
        let grid = p.inverse(&coeffs).unwrap();
        let back = p.forward(&grid).unwrap();
        coeffs.max_abs_error(&back)
    };
    let double = run(Precision::Double);
    let extended = run(Precision::Extended);
    assert!(
        extended <= double * 1.5,
        "extended {extended:.3e} vs double {double:.3e}"
    );
    // Folded + extended never builds folded tables (reconstructed O
    // halves would defeat double-double accumulation): even when
    // Precomputed is requested, rows stream exactly from the recurrence.
    let p = plan(
        b,
        DwtAlgorithm::MatVecFolded,
        WignerStorage::Precomputed,
        Precision::Extended,
    );
    assert_eq!(p.table_bytes(), 0);
    let base = plan(b, DwtAlgorithm::MatVec, WignerStorage::Precomputed, Precision::Extended);
    assert!(base.table_bytes() > 0);
    let coeffs2 = So3Coeffs::random(b, 56);
    let g = p.inverse(&coeffs2).unwrap();
    let g_base = base.inverse(&coeffs2).unwrap();
    assert!(g.max_abs_error(&g_base) < 1e-12);
}

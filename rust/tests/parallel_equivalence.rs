//! Integration: the parallel coordinator must be *bit-identical* to the
//! sequential algorithm for every thread count, schedule and strategy —
//! the work packages write disjoint outputs with no reductions, so even
//! floating point must agree exactly.

use so3ft::coordinator::PartitionStrategy;
use so3ft::pool::Schedule;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::testkit::Prop;
use so3ft::transform::So3Plan;

#[test]
fn bit_identical_across_thread_counts() {
    let b = 10;
    let coeffs = So3Coeffs::random(b, 1);
    let reference = {
        let fft = So3Plan::builder(b).allow_any_bandwidth().threads(1).build().unwrap();
        let g = fft.inverse(&coeffs).unwrap();
        let c = fft.forward(&g).unwrap();
        (g, c)
    };
    for threads in [2usize, 3, 5, 8, 16] {
        let fft = So3Plan::builder(b).allow_any_bandwidth().threads(threads).build().unwrap();
        let g = fft.inverse(&coeffs).unwrap();
        let c = fft.forward(&g).unwrap();
        assert_eq!(reference.0.as_slice(), g.as_slice(), "{threads} threads: grid");
        assert_eq!(reference.1.as_slice(), c.as_slice(), "{threads} threads: coeffs");
    }
}

#[test]
fn bit_identical_across_schedules_and_strategies() {
    let b = 8;
    let coeffs = So3Coeffs::random(b, 2);
    // NoSymmetry has different cluster bases (different summation order),
    // so only the clustered strategies are bit-identical to each other;
    // still verify all produce near-identical values.
    let reference = {
        let fft = So3Plan::builder(b).allow_any_bandwidth().threads(3).build().unwrap();
        fft.inverse(&coeffs).unwrap()
    };
    for schedule in [
        Schedule::Dynamic { chunk: 1 },
        Schedule::Dynamic { chunk: 7 },
        Schedule::Static,
        Schedule::StaticInterleaved,
        Schedule::Guided { min_chunk: 2 },
    ] {
        for strategy in [
            PartitionStrategy::GeometricClustered,
            PartitionStrategy::SigmaClustered,
        ] {
            let fft = So3Plan::builder(b)
                .allow_any_bandwidth()
                .threads(4)
                .schedule(schedule)
                .strategy(strategy)
                .build()
                .unwrap();
            let g = fft.inverse(&coeffs).unwrap();
            assert_eq!(
                reference.as_slice(),
                g.as_slice(),
                "{schedule:?}/{strategy:?}"
            );
        }
    }
}

#[test]
fn property_random_configs_agree() {
    Prop::new("parallel == sequential for random configs")
        .cases(12)
        .run(|g| {
            let b = g.usize_in(2, 9);
            let threads = g.usize_in(2, 6);
            let seed = g.u64();
            let schedule = *g.choose(&[
                Schedule::Dynamic { chunk: 1 },
                Schedule::Static,
                Schedule::Guided { min_chunk: 1 },
            ]);
            let coeffs = So3Coeffs::random(b, seed);
            let seq = So3Plan::builder(b).allow_any_bandwidth().threads(1).build().unwrap();
            let par = So3Plan::builder(b)
                .allow_any_bandwidth()
                .threads(threads)
                .schedule(schedule)
                .build()
                .unwrap();
            let gs = seq.inverse(&coeffs).unwrap();
            let gp = par.inverse(&coeffs).unwrap();
            Prop::assert_true(gs.as_slice() == gp.as_slice(), "inverse mismatch")?;
            let cs = seq.forward(&gs).unwrap();
            let cp = par.forward(&gp).unwrap();
            Prop::assert_true(cs.as_slice() == cp.as_slice(), "forward mismatch")
        });
}

#[test]
fn worker_stats_account_for_all_packages() {
    let b = 12;
    let fft = So3Plan::builder(b).allow_any_bandwidth().threads(4).build().unwrap();
    let coeffs = So3Coeffs::random(b, 4);
    let (_, stats) = fft.inverse_with_stats(&coeffs).unwrap();
    let region = stats.dwt_region.expect("region stats");
    let total: usize = region.workers.iter().map(|w| w.packages).sum();
    assert_eq!(total, fft.executor().plan().clusters.len());
    assert_eq!(region.items, total);
}

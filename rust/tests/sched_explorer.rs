//! Schedule-explorer regression tests (`--features sched-test`) for the
//! four named interleavings the concurrency soundness pass pins:
//!
//! 1. single-flight cold-miss convergence,
//! 2. High-priority leader drained first,
//! 3. idle-exception admission,
//! 4. dispatcher-panic watchdog with zero lost jobs.
//!
//! Each test sweeps a band of seeds and then replays one pinned seed;
//! a failing schedule panics with `seed=0x...` plus the full printed
//! interleaving, replayable with `Explorer::replay(seed, ..)`.
#![cfg(feature = "sched-test")]

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use so3ft::error::OverloadCause;
use so3ft::faults::{self, FaultAction, ScopedFault};
use so3ft::schedtest::Explorer;
use so3ft::service::{JobPriority, JobSpec, PlanOptions, TryWait};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::{Error, So3Service};

/// The schedule controller is process-global, so explorer tests must
/// not overlap (cargo's default test harness is multi-threaded).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    // A poisoned lock just means another explorer test failed; keep the
    // rest of the suite meaningful.
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn explorer() -> Explorer {
    Explorer {
        grace: Duration::from_millis(2),
        max_steps: 2_000,
    }
}

// ---------------------------------------------------------------------
// 1. Single-flight cold-miss convergence
// ---------------------------------------------------------------------

/// N concurrent cold lookups of one plan key share a single build and
/// receive the **same** `Arc`, under every explored interleaving of the
/// claim/wait/publish protocol.
fn single_flight_scenario(lookups: usize) -> Result<(), String> {
    let service = So3Service::builder().threads(1).build().unwrap();
    let svc = &service;
    let plans: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..lookups)
            .map(|i| {
                std::thread::Builder::new()
                    .name(format!("lookup-{i}"))
                    .spawn_scoped(s, move || svc.plan(4, PlanOptions::default()))
                    .unwrap()
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("lookup threads do not panic"))
            .collect()
    });
    let mut first = None;
    for plan in &plans {
        let plan = plan.as_ref().map_err(|e| format!("cold lookup failed: {e}"))?;
        match &first {
            None => first = Some(Arc::clone(plan)),
            Some(p) if Arc::ptr_eq(p, plan) => {}
            Some(_) => return Err("lookups returned different Arcs".into()),
        }
    }
    let stats = service.stats().registry;
    if stats.misses != 1 {
        return Err(format!(
            "single-flight broke: {} builds for one cold key",
            stats.misses
        ));
    }
    Ok(())
}

#[test]
fn single_flight_cold_miss_convergence() {
    let _guard = serial();
    explorer().sweep(0..12, || single_flight_scenario(4));
    // Pinned-seed replay: the schedule for a given seed is stable.
    explorer().replay(0x5103_F117, || single_flight_scenario(4));
}

/// Bounded DFS over the first scripted choices of the same protocol:
/// systematic enumeration, not just random sweeps.
#[test]
fn single_flight_survives_bounded_dfs() {
    let _guard = serial();
    let explored = explorer().dfs(2, 1, || single_flight_scenario(2));
    assert!(explored >= 1, "DFS explores at least the root schedule");
}

// ---------------------------------------------------------------------
// 2. High-priority leader drained first
// ---------------------------------------------------------------------

/// A High job submitted behind a wall of Low jobs leads the next batch:
/// when its handle resolves, the Low wall must not have fully executed
/// ahead of it. A held dispatcher fault keeps every job queued until
/// submission is complete, so the leader choice itself is what's under
/// test.
fn priority_leader_scenario() -> Result<(), String> {
    let service = So3Service::builder().threads(1).build().unwrap();
    // Hold the dispatcher (lock released, nothing dequeued) until the
    // full Low wall plus the late High job are all queued.
    let _stall = ScopedFault::new(
        faults::DISPATCHER,
        FaultAction::Sleep(Duration::from_millis(100)),
        Some(1),
    );
    let lows: Vec<_> = (0..3u64)
        .map(|i| {
            service
                .submit(
                    JobSpec::inverse(8).priority(JobPriority::Low),
                    So3Coeffs::random(8, i),
                )
                .unwrap()
        })
        .collect();
    let high = service
        .submit(
            JobSpec::inverse(4).priority(JobPriority::High),
            So3Coeffs::random(4, 9),
        )
        .unwrap();
    high.wait().map_err(|e| format!("High job failed: {e}"))?;
    // The moment High resolves, the Low batch (cold b=8 plan + 3-job
    // execution) cannot have fully drained if High truly led.
    let mut pending = 0usize;
    for low in lows {
        match low.try_wait() {
            TryWait::Pending(h) => {
                pending += 1;
                h.wait().map_err(|e| format!("Low job failed: {e}"))?;
            }
            TryWait::Ready(r) => {
                r.map_err(|e| format!("Low job failed: {e}"))?;
            }
        }
    }
    if pending == 0 {
        return Err("every Low job completed before the High leader".into());
    }
    Ok(())
}

#[test]
fn high_priority_leader_drained_first() {
    let _guard = serial();
    explorer().sweep(0..6, priority_leader_scenario);
    explorer().replay(0x1EAD_E12D, priority_leader_scenario);
}

// ---------------------------------------------------------------------
// 3. Idle-exception admission
// ---------------------------------------------------------------------

/// With `max_inflight_bytes` below a single job's cost, the oversized
/// job is admitted **only** when nothing is in flight: the first submit
/// (idle) is admitted, a second while the first is still charged is
/// rejected with `Overloaded { cause: InflightBytes }`, and once the
/// first resolves the exception admits again.
fn idle_exception_scenario() -> Result<(), String> {
    let service = So3Service::builder()
        .threads(1)
        .max_inflight_bytes(1)
        .build()
        .unwrap();
    // Keep job A charged (queued, undispatched) across B's admission.
    let _stall = ScopedFault::new(
        faults::DISPATCHER,
        FaultAction::Sleep(Duration::from_millis(100)),
        Some(1),
    );
    let a = service
        .submit(JobSpec::inverse(4), So3Coeffs::random(4, 0))
        .map_err(|e| format!("idle exception must admit the oversized job: {e}"))?;
    match service.submit(JobSpec::inverse(4), So3Coeffs::random(4, 1)) {
        Err(Error::Overloaded {
            cause: OverloadCause::InflightBytes,
            ..
        }) => {}
        Err(e) => return Err(format!("wrong rejection for busy service: {e}")),
        Ok(_) => return Err("oversized job admitted while another was in flight".into()),
    }
    a.wait().map_err(|e| format!("job A failed: {e}"))?;
    // Idle again: the exception re-admits.
    let c = service
        .submit(JobSpec::inverse(4), So3Coeffs::random(4, 2))
        .map_err(|e| format!("idle service must re-admit: {e}"))?;
    c.wait().map_err(|e| format!("job C failed: {e}"))?;
    Ok(())
}

#[test]
fn idle_exception_admission() {
    let _guard = serial();
    explorer().sweep(0..6, idle_exception_scenario);
    explorer().replay(0x1D1E_CA5E, idle_exception_scenario);
}

// ---------------------------------------------------------------------
// 4. Dispatcher-panic watchdog with zero lost jobs
// ---------------------------------------------------------------------

/// An injected dispatcher panic fires the watchdog restart; the loop
/// resumes over the intact queue and **every** submitted handle still
/// resolves successfully, under every explored interleaving of submit,
/// panic, restart, and drain.
fn watchdog_scenario() -> Result<(), String> {
    let service = So3Service::builder().threads(1).build().unwrap();
    let _fault = ScopedFault::new(
        faults::DISPATCHER,
        FaultAction::Panic("sched-test: dispatcher bug".into()),
        Some(1),
    );
    let handles: Vec<_> = (0..2u64)
        .map(|i| {
            service
                .submit(JobSpec::inverse(4), So3Coeffs::random(4, i))
                .unwrap()
        })
        .collect();
    for h in handles {
        h.wait()
            .map_err(|e| format!("job lost across the watchdog restart: {e}"))?;
    }
    let metrics = service.metrics();
    if metrics.dispatcher_restarts != 1 {
        return Err(format!(
            "expected exactly one watchdog restart, saw {}",
            metrics.dispatcher_restarts
        ));
    }
    if metrics.jobs_completed != metrics.jobs_submitted {
        return Err(format!(
            "lost jobs: submitted {} completed {}",
            metrics.jobs_submitted, metrics.jobs_completed
        ));
    }
    Ok(())
}

#[test]
fn dispatcher_panic_watchdog_loses_no_jobs() {
    let _guard = serial();
    explorer().sweep(0..6, watchdog_scenario);
    explorer().replay(0xD0C_70FF, watchdog_scenario);
}

//! Integration: the application layer end to end — spherical transforms
//! feeding rotational matching through the full SO(3) machinery.

use so3ft::apps::matching::{correlation_direct, match_rotation};
use so3ft::apps::sphere::{analysis, synthesis, SphCoeffs};
use so3ft::so3::rotation::{EulerZyz, Rotation};
use so3ft::so3::sampling::GridAngles;
use so3ft::testkit::Prop;
use so3ft::transform::So3Plan;

#[test]
fn matching_recovers_random_grid_rotations() {
    let b = 8;
    let fft = So3Plan::builder(b).allow_any_bandwidth().threads(2).build().unwrap();
    let angles = GridAngles::new(b).unwrap();
    let f = SphCoeffs::random(b, 3);
    Prop::new("matching recovers planted grid rotations")
        .cases(6)
        .run(|g| {
            let idx = (
                g.usize_in(0, 2 * b - 1),
                g.usize_in(0, 2 * b - 1),
                g.usize_in(0, 2 * b - 1),
            );
            let planted = angles.euler(idx.0, idx.1, idx.2);
            let rotated = f.rotate(planted);
            let result = match_rotation(&fft, &f, &rotated).unwrap();
            let dist = Rotation::from_euler(planted)
                .angular_distance(&Rotation::from_euler(result.euler));
            Prop::assert_true(
                dist <= 1.5 * std::f64::consts::PI / b as f64,
                &format!("distance {dist} at planted index {idx:?}"),
            )
        });
}

#[test]
fn matching_robust_to_moderate_noise() {
    let b = 8;
    let fft = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
    let angles = GridAngles::new(b).unwrap();
    let f = SphCoeffs::random(b, 11);
    let planted = angles.euler(5, 7, 2);
    let mut g = f.rotate(planted);
    let mut rng = so3ft::prng::Xoshiro256::seed_from_u64(1);
    for l in 0..b {
        let li = l as i64;
        for m in -li..=li {
            *g.at_mut(l, m) += so3ft::Complex64::new(rng.next_signed(), rng.next_signed())
                .scale(0.02);
        }
    }
    let result = match_rotation(&fft, &f, &g).unwrap();
    let dist =
        Rotation::from_euler(planted).angular_distance(&Rotation::from_euler(result.euler));
    assert!(
        dist <= 1.5 * std::f64::consts::PI / b as f64,
        "noisy matching distance {dist}"
    );
}

#[test]
fn correlation_peak_value_is_cauchy_schwarz_bounded() {
    let b = 6;
    let fft = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
    let f = SphCoeffs::random(b, 1);
    let g = SphCoeffs::random(b, 2);
    let result = match_rotation(&fft, &f, &g).unwrap();
    // |C(R)| ≤ ‖f‖·‖g‖ with the same N_l inner product.
    let norm = |c: &SphCoeffs| -> f64 {
        let mut acc = 0.0;
        for l in 0..b {
            let li = l as i64;
            let nl = 4.0 * std::f64::consts::PI / (2 * l + 1) as f64;
            for m in -li..=li {
                acc += nl * c.at(l, m).norm_sqr();
            }
        }
        acc.sqrt()
    };
    assert!(result.peak <= norm(&f) * norm(&g) * (1.0 + 1e-9));
}

#[test]
fn sphere_transforms_compose_with_so3_rotation_group() {
    // Rotating twice = rotating by the composition (representation
    // property through the whole stack).
    let b = 6;
    let f = SphCoeffs::random(b, 9);
    let e1 = EulerZyz::new(0.9, 0.7, 1.3);
    let e2 = EulerZyz::new(2.1, 1.9, 0.4);
    let sequential = f.rotate(e1).rotate(e2);
    let composed_rot = Rotation::from_euler(e2) * Rotation::from_euler(e1);
    let composed = f.rotate(composed_rot.to_euler());
    let err = sequential.max_abs_error(&composed);
    assert!(err < 1e-9, "representation property violated: {err}");
}

#[test]
fn correlation_direct_agrees_with_inner_product_definition() {
    // C(R) at R=identity-ish equals Σ N_l f conj(g).
    let b = 5;
    let f = SphCoeffs::random(b, 21);
    let g = SphCoeffs::random(b, 22);
    let c = correlation_direct(&f, &g, EulerZyz::new(0.0, 1e-13, 0.0));
    let mut want = 0.0;
    for l in 0..b {
        let li = l as i64;
        let nl = 4.0 * std::f64::consts::PI / (2 * l + 1) as f64;
        for m in -li..=li {
            want += nl * (f.at(l, m) * g.at(l, m).conj()).re;
        }
    }
    assert!((c - want).abs() < 1e-8 * (1.0 + want.abs()));
}

#[test]
fn band_limited_grid_roundtrips_through_sphere_transforms() {
    let b = 8;
    let coeffs = SphCoeffs::random(b, 5);
    let grid = synthesis(&coeffs).unwrap();
    let back = analysis(&grid).unwrap();
    assert!(coeffs.max_abs_error(&back) < 1e-11);
}

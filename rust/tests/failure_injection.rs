//! Integration: failure handling — malformed inputs, invalid configs,
//! missing artifacts, poisoned values. The library must fail loudly and
//! cleanly, never silently corrupt.

use so3ft::config::{ParsedConfig, RunConfig};
use so3ft::dwt::{DwtAlgorithm, Precision};
use so3ft::coordinator::PartitionStrategy;
use so3ft::runtime::XlaDwt;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;
use so3ft::{Complex64, Error};

#[test]
fn bandwidth_zero_rejected_everywhere() {
    assert!(So3Plan::new(0).is_err());
    assert!(So3Grid::zeros(0).is_err());
    assert!(so3ft::so3::sampling::GridAngles::new(0).is_err());
}

#[test]
fn mismatched_shapes_rejected() {
    let fft = So3Plan::new(4).unwrap();
    assert!(fft.forward(&So3Grid::zeros(8).unwrap()).is_err());
    assert!(fft.inverse(&So3Coeffs::random(8, 1)).is_err());
    // from_vec with wrong length
    assert!(So3Grid::from_vec(4, vec![Complex64::zero(); 3]).is_err());
    assert!(So3Coeffs::from_vec(4, vec![Complex64::zero(); 3]).is_err());
}

/// Length-mismatch errors must say what was expected AND what arrived —
/// "wrong length" alone is undebuggable from a service log.
#[test]
fn from_vec_errors_report_expected_vs_got() {
    let err = So3Grid::from_vec(4, vec![Complex64::zero(); 3]).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("512") && msg.contains("3"),
        "grid error must carry expected (8^3 = 512) and got (3): {msg}"
    );
    let err = So3Coeffs::from_vec(4, vec![Complex64::zero(); 7]).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("84") && msg.contains("7"),
        "coeff error must carry expected (B(4B²−1)/3 = 84) and got (7): {msg}"
    );
}

#[test]
fn invalid_config_combinations_rejected() {
    assert!(matches!(
        So3Plan::builder(4)
            .algorithm(DwtAlgorithm::Clenshaw)
            .precision(Precision::Extended)
            .build(),
        Err(Error::Config(_))
    ));
    assert!(matches!(
        So3Plan::builder(4)
            .algorithm(DwtAlgorithm::Clenshaw)
            .strategy(PartitionStrategy::NoSymmetry)
            .build(),
        Err(Error::Config(_))
    ));
    assert!(matches!(
        So3Plan::builder(4).threads(0).build(),
        Err(Error::InvalidThreads(0))
    ));
}

#[test]
fn missing_artifacts_clean_error() {
    match XlaDwt::load("/definitely/not/a/path", 8) {
        Err(Error::MissingArtifact { b: 8, .. }) => {}
        other => panic!("expected MissingArtifact, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn malformed_artifact_file_is_runtime_error_not_panic() {
    let dir = std::env::temp_dir().join(format!("so3ft-badart-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("dwt_fwd_b4.hlo.txt"), "this is not HLO").unwrap();
    std::fs::write(dir.join("dwt_inv_b4.hlo.txt"), "this is not HLO").unwrap();
    match XlaDwt::load(&dir, 4) {
        Err(Error::Runtime(_)) => {}
        Err(e) => panic!("expected Runtime error, got {e}"),
        Ok(_) => panic!("malformed HLO must not load"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_file_errors_are_descriptive() {
    let bad = ParsedConfig::parse("[transform]\nschedule = \"warp9\"\n").unwrap();
    let err = RunConfig::from_parsed(&bad).unwrap_err();
    assert!(err.to_string().contains("schedule"), "got: {err}");

    let bad_syntax = ParsedConfig::parse("what even is this");
    assert!(bad_syntax.is_err());
}

#[test]
fn nan_input_propagates_not_hangs() {
    // NaN samples must flow through to NaN coefficients (IEEE semantics),
    // not crash or hang the pool.
    let b = 4;
    let fft = So3Plan::builder(b).threads(2).build().unwrap();
    let mut grid = So3Grid::zeros(b).unwrap();
    grid.set(0, 0, 0, Complex64::new(f64::NAN, 0.0));
    let coeffs = fft.forward(&grid).unwrap();
    let nan_count = coeffs.as_slice().iter().filter(|c| c.re.is_nan()).count();
    assert!(nan_count > 0, "NaN must propagate into the spectrum");
}

#[test]
fn cli_rejects_bad_invocations() {
    // Exercise the CLI parser's failure paths through the public entry.
    let code = so3ft::cli::run(vec!["so3ft".into(), "frobnicate".into()]);
    assert_eq!(code, 1);
    let code = so3ft::cli::run(vec!["so3ft".into()]);
    assert_eq!(code, 2);
    let code = so3ft::cli::run(vec!["so3ft".into(), "info".into(), "--bogus".into()]);
    assert_eq!(code, 2);
}

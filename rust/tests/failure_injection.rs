//! Integration: failure handling — malformed inputs, invalid configs,
//! missing artifacts, poisoned values, and the chaos suite driving the
//! deterministic fault-injection sites in [`so3ft::faults`] against a
//! live [`So3Service`]. The library must fail loudly, cleanly, and
//! *typed* — never hang a handle, never silently corrupt.

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use so3ft::config::{ParsedConfig, RunConfig};
use so3ft::coordinator::PartitionStrategy;
use so3ft::dwt::{DwtAlgorithm, Precision};
use so3ft::error::OverloadCause;
use so3ft::faults::{self, FaultAction, ScopedFault};
use so3ft::runtime::XlaDwt;
use so3ft::service::{JobSpec, PlanOptions, So3Service};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;
use so3ft::wisdom::{PlanRigor, WisdomSource, WisdomStore};
use so3ft::{Complex64, Error};

/// The fault registry is process-global. Every test that arms a real
/// site — or that builds plans / runs pool regions a concurrently armed
/// fault could hit — serializes on this lock. Test binaries in other
/// files run as separate processes and cannot interfere.
static CHAOS: Mutex<()> = Mutex::new(());

fn chaos_lock() -> MutexGuard<'static, ()> {
    // A failed chaos test poisons the lock; recovering keeps the rest
    // of the suite meaningful instead of cascading the failure.
    CHAOS.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn bandwidth_zero_rejected_everywhere() {
    assert!(So3Plan::new(0).is_err());
    assert!(So3Grid::zeros(0).is_err());
    assert!(so3ft::so3::sampling::GridAngles::new(0).is_err());
}

#[test]
fn mismatched_shapes_rejected() {
    let _guard = chaos_lock();
    let fft = So3Plan::new(4).unwrap();
    assert!(fft.forward(&So3Grid::zeros(8).unwrap()).is_err());
    assert!(fft.inverse(&So3Coeffs::random(8, 1)).is_err());
    // from_vec with wrong length
    assert!(So3Grid::from_vec(4, vec![Complex64::zero(); 3]).is_err());
    assert!(So3Coeffs::from_vec(4, vec![Complex64::zero(); 3]).is_err());
}

/// Length-mismatch errors must say what was expected AND what arrived —
/// "wrong length" alone is undebuggable from a service log.
#[test]
fn from_vec_errors_report_expected_vs_got() {
    let err = So3Grid::from_vec(4, vec![Complex64::zero(); 3]).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("512") && msg.contains("3"),
        "grid error must carry expected (8^3 = 512) and got (3): {msg}"
    );
    let err = So3Coeffs::from_vec(4, vec![Complex64::zero(); 7]).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("84") && msg.contains("7"),
        "coeff error must carry expected (B(4B²−1)/3 = 84) and got (7): {msg}"
    );
}

#[test]
fn invalid_config_combinations_rejected() {
    let _guard = chaos_lock();
    assert!(matches!(
        So3Plan::builder(4)
            .algorithm(DwtAlgorithm::Clenshaw)
            .precision(Precision::Extended)
            .build(),
        Err(Error::Config(_))
    ));
    assert!(matches!(
        So3Plan::builder(4)
            .algorithm(DwtAlgorithm::Clenshaw)
            .strategy(PartitionStrategy::NoSymmetry)
            .build(),
        Err(Error::Config(_))
    ));
    assert!(matches!(
        So3Plan::builder(4).threads(0).build(),
        Err(Error::InvalidThreads(0))
    ));
}

#[test]
fn missing_artifacts_clean_error() {
    match XlaDwt::load("/definitely/not/a/path", 8) {
        Err(Error::MissingArtifact { b: 8, .. }) => {}
        other => panic!("expected MissingArtifact, got {:?}", other.map(|_| ())),
    }
}

#[test]
fn malformed_artifact_file_is_runtime_error_not_panic() {
    let dir = std::env::temp_dir().join(format!("so3ft-badart-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("dwt_fwd_b4.hlo.txt"), "this is not HLO").unwrap();
    std::fs::write(dir.join("dwt_inv_b4.hlo.txt"), "this is not HLO").unwrap();
    match XlaDwt::load(&dir, 4) {
        Err(Error::Runtime(_)) => {}
        Err(e) => panic!("expected Runtime error, got {e}"),
        Ok(_) => panic!("malformed HLO must not load"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_file_errors_are_descriptive() {
    let bad = ParsedConfig::parse("[transform]\nschedule = \"warp9\"\n").unwrap();
    let err = RunConfig::from_parsed(&bad).unwrap_err();
    assert!(err.to_string().contains("schedule"), "got: {err}");

    let bad_syntax = ParsedConfig::parse("what even is this");
    assert!(bad_syntax.is_err());
}

#[test]
fn nan_input_propagates_not_hangs() {
    let _guard = chaos_lock();
    // NaN samples must flow through to NaN coefficients (IEEE semantics),
    // not crash or hang the pool.
    let b = 4;
    let fft = So3Plan::builder(b).threads(2).build().unwrap();
    let mut grid = So3Grid::zeros(b).unwrap();
    grid.set(0, 0, 0, Complex64::new(f64::NAN, 0.0));
    let coeffs = fft.forward(&grid).unwrap();
    let nan_count = coeffs.as_slice().iter().filter(|c| c.re.is_nan()).count();
    assert!(nan_count > 0, "NaN must propagate into the spectrum");
}

#[test]
fn cli_rejects_bad_invocations() {
    // Exercise the CLI parser's failure paths through the public entry.
    let code = so3ft::cli::run(vec!["so3ft".into(), "frobnicate".into()]);
    assert_eq!(code, 1);
    let code = so3ft::cli::run(vec!["so3ft".into()]);
    assert_eq!(code, 2);
    let code = so3ft::cli::run(vec!["so3ft".into(), "info".into(), "--bogus".into()]);
    assert_eq!(code, 2);
}

// ----------------------------------------------------------------------
// Chaos suite: overload, deadlines, and injected faults against the
// real sites in `so3ft::faults`, driven through a live `So3Service`.
// Invariant under test everywhere: every admitted handle resolves with
// a result or a *typed* error — no hang, no lost handle, no panic
// escaping the service.
// ----------------------------------------------------------------------

/// Saturation sheds load as typed `Overloaded { QueueDepth }` with an
/// actionable retry hint — and every *admitted* job still resolves.
#[test]
fn saturation_sheds_load_with_typed_queue_rejections() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .max_batch(1)
        .max_queue(2)
        .build()
        .unwrap();
    // Hold the dispatcher inside the first batch so the queue backs up.
    let _fault = ScopedFault::new(
        faults::BATCH_RUNNER,
        FaultAction::Sleep(Duration::from_millis(300)),
        Some(1),
    );
    let mut admitted = Vec::new();
    let mut rejections = 0u32;
    for i in 0..8u64 {
        match service.submit(JobSpec::inverse(4), So3Coeffs::random(4, i)) {
            Ok(h) => admitted.push(h),
            Err(Error::Overloaded { cause, retry_after_hint }) => {
                assert_eq!(cause, OverloadCause::QueueDepth);
                assert!(retry_after_hint > Duration::ZERO, "hint must be actionable");
                rejections += 1;
            }
            Err(e) => panic!("saturation must stay typed, got {e}"),
        }
    }
    assert!(rejections >= 1, "8 submissions into a 2-deep queue must shed");
    assert!(service.metrics().rejected.queue_depth >= 1);
    for h in admitted {
        h.wait().expect("admitted jobs resolve successfully");
    }
}

/// `max_inflight_bytes` bounds *concurrent* work: a busy service
/// rejects on bytes, but an idle one admits even an over-cap job — the
/// cap must never wedge a lone caller.
#[test]
fn inflight_bytes_cap_rejects_busy_but_never_wedges_idle() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .max_batch(1)
        .max_inflight_bytes(1)
        .build()
        .unwrap();
    let first = {
        let _fault = ScopedFault::new(
            faults::BATCH_RUNNER,
            FaultAction::Sleep(Duration::from_millis(250)),
            Some(1),
        );
        let first = service
            .submit(JobSpec::inverse(4), So3Coeffs::random(4, 0))
            .unwrap();
        match service.submit(JobSpec::inverse(4), So3Coeffs::random(4, 1)) {
            Err(Error::Overloaded { cause, .. }) => {
                assert_eq!(cause, OverloadCause::InflightBytes);
            }
            other => panic!("expected a bytes rejection, got {:?}", other.map(|_| ())),
        }
        first
    };
    first.wait().unwrap();
    assert_eq!(service.metrics().rejected.inflight_bytes, 1);
    // Idle again: the over-cap job is admitted.
    let out = service.inverse(So3Coeffs::random(4, 2)).unwrap();
    assert_eq!(out.bandwidth(), 4);
}

/// A tenant at its quota is rejected typed; other tenants and untagged
/// jobs are unaffected.
#[test]
fn tenant_quota_rejects_only_the_noisy_tenant() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .max_batch(1)
        .tenant_quota(1)
        .build()
        .unwrap();
    let _fault = ScopedFault::new(
        faults::BATCH_RUNNER,
        FaultAction::Sleep(Duration::from_millis(250)),
        Some(1),
    );
    let noisy = service
        .submit(JobSpec::inverse(4).tenant(7), So3Coeffs::random(4, 0))
        .unwrap();
    match service.submit(JobSpec::inverse(4).tenant(7), So3Coeffs::random(4, 1)) {
        Err(Error::Overloaded { cause, .. }) => {
            assert_eq!(cause, OverloadCause::TenantQuota);
        }
        other => panic!("expected a quota rejection, got {:?}", other.map(|_| ())),
    }
    let other_tenant = service
        .submit(JobSpec::inverse(4).tenant(8), So3Coeffs::random(4, 2))
        .unwrap();
    let untagged = service
        .submit(JobSpec::inverse(4), So3Coeffs::random(4, 3))
        .unwrap();
    for h in [noisy, other_tenant, untagged] {
        h.wait().expect("jobs within quota resolve");
    }
    assert_eq!(service.metrics().rejected.tenant_quota, 1);
}

/// A job whose deadline expires while queued resolves typed and never
/// executes; the job blocking it is unaffected.
#[test]
fn expired_deadline_resolves_typed_without_executing() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .max_batch(1)
        .build()
        .unwrap();
    let _fault = ScopedFault::new(
        faults::BATCH_RUNNER,
        FaultAction::Sleep(Duration::from_millis(300)),
        Some(1),
    );
    let blocker = service
        .submit(JobSpec::inverse(4), So3Coeffs::random(4, 0))
        .unwrap();
    let doomed = service
        .submit(
            JobSpec::inverse(4).deadline(Duration::from_millis(30)),
            So3Coeffs::random(4, 1),
        )
        .unwrap();
    match doomed.wait() {
        Err(Error::DeadlineExceeded { deadline }) => {
            assert_eq!(deadline, Duration::from_millis(30));
        }
        other => panic!("expected DeadlineExceeded, got {:?}", other.map(|_| ())),
    }
    blocker.wait().expect("the blocking job is unaffected");
    assert_eq!(service.metrics().deadline_expired, 1);
}

/// Cancellation before dispatch resolves `Cancelled` without executing;
/// cancelling an already-resolved job is a no-op that returns `false`.
#[test]
fn cancel_before_dispatch_resolves_typed() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .max_batch(1)
        .build()
        .unwrap();
    {
        let _fault = ScopedFault::new(
            faults::BATCH_RUNNER,
            FaultAction::Sleep(Duration::from_millis(250)),
            Some(1),
        );
        let blocker = service
            .submit(JobSpec::inverse(4), So3Coeffs::random(4, 0))
            .unwrap();
        let victim = service
            .submit(JobSpec::inverse(4), So3Coeffs::random(4, 1))
            .unwrap();
        assert!(victim.cancel(), "an undispatched job accepts cancellation");
        assert!(matches!(victim.wait(), Err(Error::Cancelled)));
        blocker.wait().expect("the blocking job is unaffected");
    }
    assert_eq!(service.metrics().cancelled, 1);
    // Cancel after completion: recorded as a no-op, result unharmed.
    let done = service
        .submit(JobSpec::inverse(4), So3Coeffs::random(4, 2))
        .unwrap();
    while !done.is_done() {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(!done.cancel(), "cancel after completion is a no-op");
    done.wait().expect("a completed job still yields its result");
}

/// An injected plan-build failure surfaces typed to the caller, is
/// cached with backoff (served as `PlanBuildFailed` without a rebuild),
/// and clears on the first successful rebuild after the window.
#[test]
fn injected_plan_build_failure_is_typed_cached_and_recoverable() {
    let _guard = chaos_lock();
    let service = So3Service::builder().threads(1).build().unwrap();
    let backoff = Duration::from_millis(500);
    service.registry().set_build_backoff(backoff, backoff);
    {
        let _fault = ScopedFault::new(
            faults::PLAN_BUILD,
            FaultAction::Err("chaos: table oom".into()),
            Some(1),
        );
        match service.plan(4, PlanOptions::default()) {
            Err(Error::FaultInjected { site, .. }) => assert_eq!(site, faults::PLAN_BUILD),
            other => panic!("expected FaultInjected, got {:?}", other.map(|_| ())),
        }
    }
    // Within the backoff window the failure is served from cache, typed
    // — the fault is already disarmed, so an (incorrect) rebuild here
    // would succeed and break the assertion.
    match service.plan(4, PlanOptions::default()) {
        Err(Error::PlanBuildFailed { attempts, retry_in, .. }) => {
            assert_eq!(attempts, 1);
            assert!(retry_in <= backoff);
        }
        other => panic!("expected PlanBuildFailed, got {:?}", other.map(|_| ())),
    }
    let stats = service.registry().stats();
    assert_eq!(stats.build_failures, 1);
    assert_eq!(stats.failed_keys, 1);
    assert_eq!(stats.plans, 0, "failed keys cache no plan");
    // Past the backoff the rebuild succeeds and clears the failure.
    std::thread::sleep(backoff + Duration::from_millis(50));
    assert!(service.plan(4, PlanOptions::default()).is_ok());
    let stats = service.registry().stats();
    assert_eq!(stats.plans, 1);
    assert_eq!(stats.failed_keys, 0, "success clears the cached failure");
}

/// One injected batch fault fails exactly one job with the typed
/// `FaultInjected` error; its batch neighbors complete bit-identical to
/// an unfaulted run through the same serving path.
#[test]
fn injected_batch_fault_is_isolated_and_neighbors_stay_bit_identical() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .batch_window(Duration::from_millis(50))
        .max_batch(8)
        .build()
        .unwrap();
    let input = So3Coeffs::random(4, 42);
    // Unfaulted reference through the same serving path.
    let reference = service.inverse(input.clone()).unwrap();
    // Fire 1 fails the whole-batch fast path (forcing per-job
    // isolation); fire 2 fails the first rerun job. However the
    // dispatcher splits these jobs into batches, exactly one faults.
    let _fault = ScopedFault::new(
        faults::BATCH_RUNNER,
        FaultAction::Err("chaos: kernel fault".into()),
        Some(2),
    );
    let handles: Vec<_> = (0..3)
        .map(|_| service.submit(JobSpec::inverse(4), input.clone()).unwrap())
        .collect();
    let mut faulted = 0;
    let mut survivors = Vec::new();
    for handle in handles {
        match handle.wait() {
            Err(Error::FaultInjected { site, .. }) => {
                assert_eq!(site, faults::BATCH_RUNNER);
                faulted += 1;
            }
            Ok(out) => survivors.push(out),
            Err(e) => panic!("unexpected error from a batch neighbor: {e}"),
        }
    }
    assert_eq!(faulted, 1, "exactly the faulted job fails, typed");
    assert_eq!(survivors.len(), 2, "batch neighbors must complete");
    for out in survivors {
        let grid = out.into_grid().expect("inverse jobs yield grids");
        assert_eq!(
            grid.as_slice(),
            reference.as_slice(),
            "neighbors of a faulted job stay bit-identical"
        );
    }
}

/// A panic inside a pool worker body is contained: the job resolves
/// with a typed error, the pool and dispatcher survive, and the next
/// job completes normally on the same workers.
#[test]
fn injected_worker_panic_is_contained_and_the_service_recovers() {
    let _guard = chaos_lock();
    let service = So3Service::builder().threads(2).build().unwrap();
    // Warm the plan so the fault hits job execution, not the build.
    let warm = service.inverse(So3Coeffs::random(8, 1)).unwrap();
    service.recycle_grid(warm);
    {
        let _fault = ScopedFault::new(
            faults::WORKER_BODY,
            FaultAction::Panic("chaos: worker bug".into()),
            None,
        );
        let handle = service
            .submit(JobSpec::inverse(8), So3Coeffs::random(8, 2))
            .unwrap();
        match handle.wait() {
            Err(Error::Service(msg)) => {
                assert!(msg.contains("panicked"), "typed panic wrap, got: {msg}");
            }
            other => panic!("expected a contained panic, got {:?}", other.map(|_| ())),
        }
    }
    // Disarmed: the same pool serves the next job.
    let out = service.inverse(So3Coeffs::random(8, 3)).unwrap();
    assert_eq!(out.bandwidth(), 8);
}

/// An injected dispatcher panic trips the watchdog: the loop restarts
/// over the intact queue, every queued job completes, and the restart
/// is visible in the metrics snapshot.
#[test]
fn dispatcher_panic_restarts_watchdog_without_losing_jobs() {
    let _guard = chaos_lock();
    let service = So3Service::builder().threads(1).build().unwrap();
    let _fault = ScopedFault::new(
        faults::DISPATCHER,
        FaultAction::Panic("chaos: dispatcher bug".into()),
        Some(1),
    );
    let handles: Vec<_> = (0..2u64)
        .map(|i| {
            service
                .submit(JobSpec::inverse(4), So3Coeffs::random(4, i))
                .unwrap()
        })
        .collect();
    for h in handles {
        h.wait().expect("jobs survive a dispatcher restart");
    }
    assert_eq!(service.metrics().dispatcher_restarts, 1);
}

/// Drain-with-deadline shutdown: the in-flight job finishes, queued
/// jobs abort typed at the deadline, and every handle resolves.
#[test]
fn shutdown_deadline_aborts_queued_jobs_typed() {
    let _guard = chaos_lock();
    let service = So3Service::builder()
        .threads(1)
        .max_batch(1)
        .build()
        .unwrap();
    let _fault = ScopedFault::new(
        faults::BATCH_RUNNER,
        FaultAction::Sleep(Duration::from_millis(300)),
        Some(1),
    );
    let running = service
        .submit(JobSpec::inverse(4), So3Coeffs::random(4, 0))
        .unwrap();
    // Give the dispatcher time to take the first job into its batch.
    std::thread::sleep(Duration::from_millis(50));
    let queued: Vec<_> = (1..3u64)
        .map(|i| {
            service
                .submit(JobSpec::inverse(4), So3Coeffs::random(4, i))
                .unwrap()
        })
        .collect();
    let report = service.shutdown(Duration::from_millis(50));
    assert_eq!(report.aborted, 2, "still-queued jobs abort at the deadline");
    assert_eq!(report.drained, 1, "the in-flight job drains");
    running.wait().expect("the dispatched job finishes normally");
    for h in queued {
        assert!(matches!(h.wait(), Err(Error::ShutdownDrain)));
    }
}

/// An injected Wigner-table load failure is a typed constructor error —
/// never a panic — and the next build succeeds once disarmed.
#[test]
fn injected_table_load_failure_is_a_typed_constructor_error() {
    let _guard = chaos_lock();
    {
        let _fault = ScopedFault::new(
            faults::WIGNER_LOAD,
            FaultAction::Err("chaos: table io".into()),
            Some(1),
        );
        match So3Plan::new(4) {
            Err(Error::FaultInjected { site, .. }) => assert_eq!(site, faults::WIGNER_LOAD),
            other => panic!("expected FaultInjected, got {:?}", other.map(|_| ())),
        }
    }
    assert!(So3Plan::new(4).is_ok(), "disarmed: the same build succeeds");
}

/// An injected wisdom-store failure degrades exactly like a real
/// unreadable store: the `Measure` build falls back to Estimate
/// defaults with a typed warning, and the plan still transforms.
#[test]
fn injected_wisdom_store_failure_degrades_to_estimate_fallback() {
    let _guard = chaos_lock();
    let store = WisdomStore::in_memory();
    let _fault = ScopedFault::new(
        faults::WISDOM_STORE,
        FaultAction::Err("chaos: store io".into()),
        Some(1),
    );
    let plan = So3Plan::builder(4)
        .rigor(PlanRigor::Measure)
        .wisdom_store(store)
        .build()
        .unwrap();
    let outcome = plan.wisdom().expect("Measure builds record an outcome");
    assert!(
        matches!(outcome.source, WisdomSource::Fallback(_)),
        "an unreadable store must fall back, got {:?}",
        outcome.source
    );
    // The degraded plan still transforms.
    let grid = plan.inverse(&So3Coeffs::random(4, 5)).unwrap();
    assert_eq!(grid.bandwidth(), 4);
}

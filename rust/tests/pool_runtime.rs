//! Integration: the persistent worker-pool runtime under concurrent
//! callers — bit-identical results vs the sequential path, worker
//! threads stable across transforms (no OS-thread spawning after pool
//! construction), shared pools across plans and bandwidths, and the
//! sequential fast path's RegionStats shape.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use so3ft::pool::{parallel_for, sequential_region, Schedule, WorkerPool};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::testkit::Prop;
use so3ft::transform::So3Plan;

const ALL_SCHEDULES: [Schedule; 5] = [
    Schedule::Dynamic { chunk: 1 },
    Schedule::Dynamic { chunk: 8 },
    Schedule::Static,
    Schedule::StaticInterleaved,
    Schedule::Guided { min_chunk: 1 },
];

/// Many `forward_into`/`inverse_into` calls from multiple caller threads
/// against one shared pool: every result must be bit-identical to the
/// sequential path (disjoint writes, no reductions — so even floating
/// point agrees exactly).
#[test]
fn concurrent_callers_on_one_shared_pool_are_bit_identical() {
    let b = 8;
    let pool = Arc::new(WorkerPool::new(3).unwrap());
    let builder = So3Plan::builder(b).pool(Arc::clone(&pool));
    let plan = Arc::new(builder.build().unwrap());
    let seq = So3Plan::builder(b).build().unwrap();

    let inputs: Vec<So3Coeffs> = (0..4).map(|i| So3Coeffs::random(b, 100 + i)).collect();
    let ref_grids: Vec<So3Grid> = inputs.iter().map(|c| seq.inverse(c).unwrap()).collect();
    let ref_specs: Vec<So3Coeffs> = ref_grids.iter().map(|g| seq.forward(g).unwrap()).collect();

    std::thread::scope(|scope| {
        for caller in 0..4usize {
            let plan = Arc::clone(&plan);
            let inputs = &inputs;
            let ref_grids = &ref_grids;
            let ref_specs = &ref_specs;
            scope.spawn(move || {
                let mut ws = plan.make_workspace();
                let mut grid = So3Grid::zeros(b).unwrap();
                let mut spec = So3Coeffs::zeros(b);
                for round in 0..6usize {
                    let k = (caller + round) % inputs.len();
                    plan.inverse_into(&inputs[k], &mut grid, &mut ws).unwrap();
                    assert_eq!(
                        grid.as_slice(),
                        ref_grids[k].as_slice(),
                        "inverse: caller {caller} round {round}"
                    );
                    plan.forward_into(&grid, &mut spec, &mut ws).unwrap();
                    assert_eq!(
                        spec.as_slice(),
                        ref_specs[k].as_slice(),
                        "forward: caller {caller} round {round}"
                    );
                }
            });
        }
    });
}

/// No parallel region spawns OS threads after pool construction: the
/// exact worker-thread-id set observed before two consecutive
/// `forward_into` calls is observed again after them.
#[test]
fn worker_thread_ids_stable_across_consecutive_transform_calls() {
    let b = 8;
    let pool = Arc::new(WorkerPool::new(2).unwrap());
    let builder = So3Plan::builder(b).pool(Arc::clone(&pool));
    let plan = builder.build().unwrap();

    // Static over n == pool size: every worker executes exactly one
    // package, so the observed id set is deterministic and complete.
    let observe = |pool: &WorkerPool| -> HashSet<std::thread::ThreadId> {
        let seen = Mutex::new(HashSet::new());
        pool.run(2, Schedule::Static, |_| {
            seen.lock().unwrap().insert(std::thread::current().id());
        });
        seen.into_inner().unwrap()
    };

    let expected: HashSet<_> = pool.thread_ids().into_iter().collect();
    assert_eq!(expected.len(), 2);
    let before = observe(&pool);
    assert_eq!(before, expected);

    let coeffs = So3Coeffs::random(b, 5);
    let mut ws = plan.make_workspace();
    let mut grid = So3Grid::zeros(b).unwrap();
    let mut spec = So3Coeffs::zeros(b);
    plan.inverse_into(&coeffs, &mut grid, &mut ws).unwrap();
    plan.forward_into(&grid, &mut spec, &mut ws).unwrap();
    plan.forward_into(&grid, &mut spec, &mut ws).unwrap();

    let after = observe(&pool);
    assert_eq!(before, after, "transforms must reuse the persistent workers");
    assert_eq!(
        pool.thread_ids().into_iter().collect::<HashSet<_>>(),
        expected,
        "the pool never respawns its threads"
    );
    assert!(
        !before.contains(&std::thread::current().id()),
        "pooled regions do not execute packages on the caller"
    );
}

/// Plans of different bandwidths interleaving on one pool: the
/// per-worker thread-local scratch is rebuilt per bandwidth without
/// corrupting either plan's results.
#[test]
fn mixed_bandwidth_plans_share_one_pool() {
    let pool = Arc::new(WorkerPool::new(2).unwrap());
    let builder4 = So3Plan::builder(4).pool(Arc::clone(&pool));
    let plan4 = builder4.build().unwrap();
    let builder8 = So3Plan::builder(8).pool(Arc::clone(&pool));
    let plan8 = builder8.build().unwrap();
    let seq4 = So3Plan::builder(4).build().unwrap();
    let seq8 = So3Plan::builder(8).build().unwrap();
    let c4 = So3Coeffs::random(4, 9);
    let c8 = So3Coeffs::random(8, 10);
    let want4 = seq4.inverse(&c4).unwrap();
    let want8 = seq8.inverse(&c8).unwrap();
    for round in 0..3 {
        let g4 = plan4.inverse(&c4).unwrap();
        assert_eq!(g4.as_slice(), want4.as_slice(), "b=4 round {round}");
        let g8 = plan8.inverse(&c8).unwrap();
        assert_eq!(g8.as_slice(), want8.as_slice(), "b=8 round {round}");
    }
}

/// Randomized configs through a shared pool (testkit property harness):
/// parallel == sequential, bit for bit, under every schedule.
#[test]
fn property_shared_pool_matches_sequential() {
    let pool = Arc::new(WorkerPool::new(3).unwrap());
    Prop::new("shared pool == sequential").cases(8).run(|g| {
        let b = g.usize_in(2, 8);
        let seed = g.u64();
        let schedule = *g.choose(&ALL_SCHEDULES);
        let coeffs = So3Coeffs::random(b, seed);
        let par = So3Plan::builder(b)
            .allow_any_bandwidth()
            .pool(Arc::clone(&pool))
            .schedule(schedule)
            .build()
            .unwrap();
        let seq = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
        let gp = par.inverse(&coeffs).unwrap();
        let gs = seq.inverse(&coeffs).unwrap();
        Prop::assert_true(gp.as_slice() == gs.as_slice(), "inverse mismatch")?;
        let cp = par.forward(&gp).unwrap();
        let cs = seq.forward(&gs).unwrap();
        Prop::assert_true(cp.as_slice() == cs.as_slice(), "forward mismatch")
    });
}

/// Regression (ISSUE 3 satellite): the single-thread fast path records
/// the same RegionStats shape as the policy accounting — one worker,
/// `packages == n` — under every `Schedule`, in all three entry points
/// (legacy scoped spawn, persistent pool, explicit sequential helper).
#[test]
fn single_thread_fast_path_region_stats_shape() {
    let pool = WorkerPool::new(1).unwrap();
    for &schedule in &ALL_SCHEDULES {
        for &n in &[0usize, 1, 5, 64] {
            let from_for = parallel_for(1, n, schedule, |_| {});
            let from_pool = pool.run(n, schedule, |_| {});
            let from_seq = sequential_region(n, |_| {});
            for (label, s) in [
                ("parallel_for", &from_for),
                ("WorkerPool::run", &from_pool),
                ("sequential_region", &from_seq),
            ] {
                assert_eq!(s.workers.len(), 1, "{label} ({schedule:?}, n={n})");
                assert_eq!(s.workers[0].packages, n, "{label} ({schedule:?}, n={n})");
                assert_eq!(s.items, n, "{label} ({schedule:?}, n={n})");
                assert_eq!(
                    s.workers.iter().map(|w| w.packages).sum::<usize>(),
                    n,
                    "{label}: total package accounting ({schedule:?}, n={n})"
                );
            }
        }
    }
}

/// The DWT region's stats flow through unchanged on the pooled runtime:
/// package totals still account for every cluster.
#[test]
fn region_stats_account_for_all_clusters_on_shared_pool() {
    let b = 8;
    let pool = Arc::new(WorkerPool::new(3).unwrap());
    let plan = So3Plan::builder(b).pool(pool).build().unwrap();
    let coeffs = So3Coeffs::random(b, 4);
    let (_, stats) = plan.inverse_with_stats(&coeffs).unwrap();
    let region = stats.dwt_region.expect("region stats");
    let total: usize = region.workers.iter().map(|w| w.packages).sum();
    assert_eq!(total, plan.executor().plan().clusters.len());
    assert_eq!(region.items, total);
    assert_eq!(region.workers.len(), 3);
}

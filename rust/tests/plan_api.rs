//! Integration: the planner/session API — allocation-free `*_into`
//! execution, batch pipelining, workspace validation, builder
//! validation, and (the one kept parity test) the deprecated
//! `So3Fft` facade against `So3Plan`.

use so3ft::coordinator::Workspace;
use so3ft::pool::PoolSpec;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::{BackendKind, So3Plan, Transform};
use so3ft::Error;

/// Acceptance: `forward_batch` over N = 8 signals matches N sequential
/// `forward` calls bit for bit at b = 16 (and the same for the inverse).
#[test]
fn batch_matches_sequential_calls_bit_for_bit_b16() {
    let b = 16;
    let n_signals = 8;
    let plan = So3Plan::builder(b).threads(2).build().unwrap();
    let specs: Vec<So3Coeffs> = (0..n_signals)
        .map(|i| So3Coeffs::random(b, 1000 + i as u64))
        .collect();

    let grids_batch = plan.inverse_batch(&specs).unwrap();
    let grids_loop: Vec<So3Grid> = specs.iter().map(|c| plan.inverse(c).unwrap()).collect();
    assert_eq!(grids_batch.len(), n_signals);
    for (a, c) in grids_batch.iter().zip(&grids_loop) {
        assert_eq!(a.as_slice(), c.as_slice(), "inverse batch vs loop");
    }

    let specs_batch = plan.forward_batch(&grids_batch).unwrap();
    let specs_loop: Vec<So3Coeffs> =
        grids_loop.iter().map(|g| plan.forward(g).unwrap()).collect();
    for (a, c) in specs_batch.iter().zip(&specs_loop) {
        assert_eq!(a.as_slice(), c.as_slice(), "forward batch vs loop");
    }
}

#[test]
fn into_variants_equal_allocating_variants() {
    let b = 8;
    for threads in [1usize, 3] {
        let plan = So3Plan::builder(b).threads(threads).build().unwrap();
        let coeffs = So3Coeffs::random(b, 7);
        let mut ws = plan.make_workspace();

        let grid_alloc = plan.inverse(&coeffs).unwrap();
        let mut grid_into = So3Grid::zeros(b).unwrap();
        plan.inverse_into(&coeffs, &mut grid_into, &mut ws).unwrap();
        assert_eq!(grid_alloc.as_slice(), grid_into.as_slice());

        let back_alloc = plan.forward(&grid_alloc).unwrap();
        let mut back_into = So3Coeffs::zeros(b);
        plan.forward_into(&grid_into, &mut back_into, &mut ws).unwrap();
        assert_eq!(back_alloc.as_slice(), back_into.as_slice());
    }
}

/// Acceptance: after plan construction, `*_into` performs zero heap
/// (re)allocation of grid/coefficient storage — asserted through pointer
/// stability of every caller-owned buffer across repeated reuse, and
/// through outputs landing in place.
#[test]
fn execute_into_reuses_storage_without_reallocation() {
    let b = 8;
    let plan = So3Plan::builder(b).threads(2).build().unwrap();
    let mut ws = plan.make_workspace();
    let mut grid = So3Grid::zeros(b).unwrap();
    let mut back = So3Coeffs::zeros(b);

    let ws_ptr = ws.work_ptr();
    let grid_ptr = grid.as_slice().as_ptr();
    let back_ptr = back.as_slice().as_ptr();

    for seed in 0..5u64 {
        let coeffs = So3Coeffs::random(b, seed);
        plan.inverse_into(&coeffs, &mut grid, &mut ws).unwrap();
        plan.forward_into(&grid, &mut back, &mut ws).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-11, "seed {seed}");
        // The buffers were written in place, never swapped or regrown.
        assert_eq!(ws.work_ptr(), ws_ptr, "workspace reallocated");
        assert_eq!(grid.as_slice().as_ptr(), grid_ptr, "grid reallocated");
        assert_eq!(back.as_slice().as_ptr(), back_ptr, "coeffs reallocated");
    }
}

/// Mixing workspaces (or outputs) across bandwidths is a typed error —
/// never a panic, never silent corruption.
#[test]
fn mixed_bandwidth_workspace_is_typed_error() {
    let plan8 = So3Plan::new(8).unwrap();
    let plan16 = So3Plan::new(16).unwrap();
    let coeffs8 = So3Coeffs::random(8, 1);
    let grid8 = plan8.inverse(&coeffs8).unwrap();

    let mut ws16 = plan16.make_workspace();
    let mut out8 = So3Coeffs::zeros(8);
    match plan8.forward_into(&grid8, &mut out8, &mut ws16) {
        Err(Error::BandwidthMismatch {
            expected: 8,
            got: 16,
            context,
        }) => assert!(context.contains("workspace"), "context: {context}"),
        other => panic!("expected BandwidthMismatch, got {:?}", other.map(|_| ())),
    }
    let mut grid_out8 = So3Grid::zeros(8).unwrap();
    assert!(plan8
        .inverse_into(&coeffs8, &mut grid_out8, &mut ws16)
        .is_err());

    // Workspace::new validates too.
    assert!(Workspace::new(0).is_err());

    // A correct workspace still works after the failed calls.
    let mut ws8 = plan8.make_workspace();
    plan8.forward_into(&grid8, &mut out8, &mut ws8).unwrap();
    let reference = plan8.forward(&grid8).unwrap();
    assert_eq!(out8.as_slice(), reference.as_slice());
}

/// The deprecated facade must stay bit-for-bit interchangeable with the
/// plan it wraps, across directions and thread counts — the single
/// facade parity test kept for the deprecation period.
#[test]
#[allow(deprecated)]
fn facade_parity_with_plan() {
    use so3ft::transform::So3Fft;
    let b = 8;
    for threads in [1usize, 4] {
        let facade = So3Fft::builder(b).threads(threads).build().unwrap();
        let plan = So3Plan::builder(b).threads(threads).build().unwrap();
        let coeffs = So3Coeffs::random(b, 21);
        let g_f = facade.inverse(&coeffs).unwrap();
        let g_p = plan.inverse(&coeffs).unwrap();
        assert_eq!(g_f.as_slice(), g_p.as_slice(), "{threads} threads inverse");
        let c_f = facade.forward(&g_f).unwrap();
        let c_p = plan.forward(&g_p).unwrap();
        assert_eq!(c_f.as_slice(), c_p.as_slice(), "{threads} threads forward");
    }
    // The facade exposes the plan it wraps.
    let facade = So3Fft::builder(b).threads(2).build().unwrap();
    assert_eq!(facade.plan().bandwidth(), b);
    assert_eq!(facade.plan().backend(), BackendKind::CpuParallel);
}

#[test]
fn builder_validation_bug_sweep() {
    // threads == 0: typed error, not a panic.
    assert!(matches!(
        So3Plan::builder(8).threads(0).build(),
        Err(Error::InvalidThreads(0))
    ));
    // Non-power-of-two bandwidth: typed rejection on the strict planner.
    for b in [3usize, 6, 12, 100] {
        assert!(matches!(
            So3Plan::builder(b).build(),
            Err(Error::NonPowerOfTwoBandwidth(_))
        ));
    }
    // Zero bandwidth: typed error everywhere.
    assert!(matches!(
        So3Plan::builder(0).build(),
        Err(Error::InvalidBandwidth(0))
    ));
    // The explicit escape hatch still serves non-powers of two through
    // the Bluestein path.
    assert!(So3Plan::builder(6).allow_any_bandwidth().build().is_ok());
}

/// Backends are interchangeable behind `dyn Transform`.
#[test]
fn backends_interchangeable_behind_dyn_transform() {
    let b = 4;
    let coeffs = So3Coeffs::random(b, 3);
    let seq = So3Plan::builder(b).threads(1).build().unwrap();
    let par = So3Plan::builder(b).threads(3).build().unwrap();
    assert_eq!(seq.backend(), BackendKind::CpuSequential);
    assert_eq!(par.backend(), BackendKind::CpuParallel);

    let backends: Vec<Box<dyn Transform>> = vec![
        Box::new(seq),
        Box::new(par),
        Box::new(
            So3Plan::builder(b)
                .threads(2)
                .pool_spec(PoolSpec::Global)
                .build()
                .unwrap(),
        ),
    ];
    let reference = backends[0].inverse(&coeffs).unwrap();
    for (i, t) in backends.iter().enumerate() {
        assert_eq!(t.bandwidth(), b);
        let mut ws = t.make_workspace();
        let mut grid = So3Grid::zeros(b).unwrap();
        t.inverse_into(&coeffs, &mut grid, &mut ws).unwrap();
        assert_eq!(grid.as_slice(), reference.as_slice(), "backend {i}");
    }
}

/// Allocation-free batch entry points validate output counts.
#[test]
fn batch_into_shape_validation() {
    let b = 4;
    let plan = So3Plan::new(b).unwrap();
    let mut ws = plan.make_workspace();
    let specs: Vec<So3Coeffs> = (0..3).map(|i| So3Coeffs::random(b, i)).collect();
    let mut grids: Vec<So3Grid> = (0..3).map(|_| So3Grid::zeros(b).unwrap()).collect();
    plan.inverse_batch_into(&specs, &mut grids, &mut ws).unwrap();
    for (c, g) in specs.iter().zip(&grids) {
        assert_eq!(plan.inverse(c).unwrap().as_slice(), g.as_slice());
    }
    let mut outs: Vec<So3Coeffs> = (0..2).map(|_| So3Coeffs::zeros(b)).collect();
    assert!(plan
        .forward_batch_into(&grids, &mut outs, &mut ws)
        .is_err());
}

/// The typed [`MemoryBudget`] sweep across the planner API: Auto keeps
/// small bandwidths fully materialized, a table-squeezing cap switches
/// the same plan to streamed (partial) Wigner tables while staying
/// numerically interchangeable, and an infeasible cap is a typed
/// [`Error::BudgetExceeded`] — never a panic or a silent OOM.
#[test]
fn memory_budget_sweep_across_plan_api() {
    use so3ft::coordinator::workspace_bytes;
    use so3ft::dwt::tables::{WignerStorage, WignerTables};
    use so3ft::MemoryBudget;

    // Auto at a small bandwidth: full tables, nothing streamed, and the
    // report's arithmetic is self-consistent.
    let plan = So3Plan::builder(8)
        .storage(WignerStorage::Precomputed)
        .memory_budget(MemoryBudget::Auto)
        .build()
        .unwrap();
    let report = plan.memory_report();
    assert_eq!(report.budget, MemoryBudget::Auto);
    assert!(!report.streamed, "b=8 must fit the Auto table cap");
    assert_eq!(report.table_bytes, report.table_bytes_full);
    assert_eq!(report.workspace_bytes, workspace_bytes(8));
    assert_eq!(
        report.total_bytes(),
        report.table_bytes + report.workspace_bytes
    );

    // A cap that admits only half the b=16 tables: the plan streams the
    // evicted degrees, reports it, stays under budget — and remains
    // numerically interchangeable with the unlimited plan.
    let b = 16;
    let cap = workspace_bytes(b) + WignerTables::full_bytes(b) / 2;
    let squeezed = So3Plan::builder(b)
        .storage(WignerStorage::Precomputed)
        .memory_budget(MemoryBudget::Bytes(cap))
        .build()
        .unwrap();
    let sq_report = squeezed.memory_report();
    assert!(sq_report.streamed, "half the table bytes must stream");
    assert!(sq_report.table_bytes < sq_report.table_bytes_full);
    assert!(sq_report.total_bytes() <= cap, "plan exceeds its own budget");

    let unlimited = So3Plan::builder(b)
        .storage(WignerStorage::Precomputed)
        .memory_budget(MemoryBudget::Unlimited)
        .build()
        .unwrap();
    assert!(!unlimited.memory_report().streamed);

    let coeffs = So3Coeffs::random(b, 99);
    let grid_sq = squeezed.inverse(&coeffs).unwrap();
    let grid_un = unlimited.inverse(&coeffs).unwrap();
    let mut dev = 0.0f64;
    for (a, c) in grid_sq.as_slice().iter().zip(grid_un.as_slice()) {
        dev = dev.max((*a - *c).abs());
    }
    assert!(dev < 1e-11, "streamed vs materialized diverged: {dev:.3e}");
    let back = squeezed.forward(&grid_sq).unwrap();
    assert!(coeffs.max_abs_error(&back) < 1e-10, "streamed roundtrip");

    // A budget below the irreducible workspace is a typed error at build
    // time, naming both sides of the inequality.
    match So3Plan::builder(b)
        .memory_budget(MemoryBudget::Bytes(1024))
        .build()
    {
        Err(Error::BudgetExceeded {
            required, budget, ..
        }) => {
            assert_eq!(budget, 1024);
            assert!(required >= workspace_bytes(b));
        }
        other => panic!("expected BudgetExceeded, got {:?}", other.map(|_| ())),
    }
}

//! Integration: full-transform roundtrip accuracy across bandwidths and
//! configurations (the paper's Table 1 protocol at test scale), plus the
//! end-to-end agreement with the direct O(B⁶) definition.

use so3ft::coordinator::PartitionStrategy;
use so3ft::dwt::tables::WignerStorage;
use so3ft::dwt::{DwtAlgorithm, Precision};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::{direct, So3Plan};

#[test]
fn roundtrip_error_scales_like_paper() {
    // Table 1: error grows mildly with B; all well under 1e-12 at these
    // scales in double precision.
    let mut last = 0.0;
    for b in [4usize, 8, 16] {
        let fft = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
        let mut worst: f64 = 0.0;
        for run in 0..3 {
            let coeffs = So3Coeffs::random(b, 100 + run);
            let grid = fft.inverse(&coeffs).unwrap();
            let back = fft.forward(&grid).unwrap();
            worst = worst.max(coeffs.max_abs_error(&back));
        }
        assert!(worst < 1e-12, "b={b}: worst abs error {worst}");
        assert!(
            worst > last * 0.2,
            "error should not shrink wildly with B (sanity)"
        );
        last = worst;
    }
}

#[test]
fn all_configurations_roundtrip_b12() {
    let b = 12;
    let coeffs = So3Coeffs::random(b, 5);
    for strategy in [
        PartitionStrategy::GeometricClustered,
        PartitionStrategy::SigmaClustered,
        PartitionStrategy::NoSymmetry,
    ] {
        for algorithm in [
            DwtAlgorithm::MatVec,
            DwtAlgorithm::MatVecFolded,
            DwtAlgorithm::Clenshaw,
        ] {
            for storage in [WignerStorage::Precomputed, WignerStorage::OnTheFly] {
                for precision in [Precision::Double, Precision::Extended] {
                    // Skip invalid combinations (rejected by the builder).
                    let builder = So3Plan::builder(b)
                        .allow_any_bandwidth()
                        .strategy(strategy)
                        .algorithm(algorithm)
                        .storage(storage)
                        .precision(precision)
                        .threads(2);
                    let fft = match builder.build() {
                        Ok(f) => f,
                        Err(_) => continue,
                    };
                    let grid = fft.inverse(&coeffs).unwrap();
                    let back = fft.forward(&grid).unwrap();
                    let err = coeffs.max_abs_error(&back);
                    assert!(
                        err < 1e-11,
                        "{strategy:?}/{algorithm:?}/{storage:?}/{precision:?}: {err}"
                    );
                }
            }
        }
    }
}

#[test]
fn extended_precision_is_at_least_as_accurate() {
    let b = 16;
    let coeffs = So3Coeffs::random(b, 77);
    let run = |precision| {
        let fft = So3Plan::builder(b).allow_any_bandwidth().precision(precision).build().unwrap();
        let grid = fft.inverse(&coeffs).unwrap();
        let back = fft.forward(&grid).unwrap();
        coeffs.max_abs_error(&back)
    };
    let double = run(Precision::Double);
    let extended = run(Precision::Extended);
    assert!(
        extended <= double * 1.5,
        "extended ({extended}) should not be worse than double ({double})"
    );
}

#[test]
fn fast_transforms_match_direct_definition_b3() {
    let coeffs = So3Coeffs::random(3, 9);
    let fft = So3Plan::builder(3).allow_any_bandwidth().build().unwrap();
    let fast_grid = fft.inverse(&coeffs).unwrap();
    let slow_grid = direct::synthesis(&coeffs).unwrap();
    assert!(fast_grid.max_abs_error(&slow_grid) < 1e-10);
    let fast_coeffs = fft.forward(&fast_grid).unwrap();
    let slow_coeffs = direct::analysis(&slow_grid).unwrap();
    assert!(fast_coeffs.max_abs_error(&slow_coeffs) < 1e-10);
}

#[test]
fn linearity_of_transform() {
    // FSOFT is linear: T(a·x + y) = a·T(x) + T(y).
    let b = 8;
    let fft = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
    let c1 = So3Coeffs::random(b, 1);
    let c2 = So3Coeffs::random(b, 2);
    let g1 = fft.inverse(&c1).unwrap();
    let g2 = fft.inverse(&c2).unwrap();
    // combined coefficients: 2*c1 + c2
    let mut c3 = So3Coeffs::zeros(b);
    for (i, v) in c3.as_mut_slice().iter_mut().enumerate() {
        *v = c1.as_slice()[i].scale(2.0) + c2.as_slice()[i];
    }
    let g3 = fft.inverse(&c3).unwrap();
    for i in 0..g3.as_slice().len() {
        let want = g1.as_slice()[i].scale(2.0) + g2.as_slice()[i];
        assert!((g3.as_slice()[i] - want).abs() < 1e-11);
    }
}

#[test]
fn bandwidth_one_degenerate_case() {
    // B = 1: a single coefficient (l = m = m' = 0), constant functions.
    let fft = So3Plan::builder(1).allow_any_bandwidth().build().unwrap();
    let coeffs = So3Coeffs::random(1, 3);
    let grid = fft.inverse(&coeffs).unwrap();
    // Constant over the 8 grid nodes.
    let v0 = grid.as_slice()[0];
    for v in grid.as_slice() {
        assert!((*v - v0).abs() < 1e-14);
    }
    let back = fft.forward(&grid).unwrap();
    assert!(coeffs.max_abs_error(&back) < 1e-14);
}

//! Integration: the `So3Service` front door — concurrent mixed-bandwidth
//! bit-parity against sequential `So3Plan` calls, plan-registry Arc
//! identity, workspace-pool high-watermark stability, zero-allocation
//! pointer stability of the steady-state serving loop, and micro-batch
//! coalescing parity.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use so3ft::service::{JobOutput, JobPriority, JobSpec, PlanOptions, So3Service};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::So3Plan;

/// Acceptance: M = 4 client threads submitting mixed-bandwidth jobs
/// (both directions, micro-batching enabled) produce results
/// bit-identical to sequential `So3Plan::forward`/`inverse` calls.
#[test]
fn concurrent_mixed_bandwidth_jobs_match_sequential_plans_bit_for_bit() {
    let bandwidths = [4usize, 8, 16];
    let jobs_per_client = 9;
    let clients = 4;

    // Sequential single-threaded references (parallel execution is
    // bit-identical to sequential by the pool runtime's contract, so
    // this is the strictest possible oracle).
    let mut reference: HashMap<usize, (Vec<So3Grid>, Vec<So3Coeffs>)> = HashMap::new();
    for &b in &bandwidths {
        let plan = So3Plan::builder(b).threads(1).build().unwrap();
        let mut grids = Vec::new();
        let mut coeffs = Vec::new();
        for seed in 0..(clients * jobs_per_client) as u64 {
            let c = So3Coeffs::random(b, seed);
            let g = plan.inverse(&c).unwrap();
            let f = plan.forward(&g).unwrap();
            grids.push(g);
            coeffs.push(f);
        }
        reference.insert(b, (grids, coeffs));
    }

    let service = So3Service::builder()
        .threads(2)
        .batch_window(Duration::from_micros(300))
        .build()
        .unwrap();

    std::thread::scope(|scope| {
        for client in 0..clients {
            let service = &service;
            let reference = &reference;
            scope.spawn(move || {
                for i in 0..jobs_per_client {
                    let b = bandwidths[(client + i) % bandwidths.len()];
                    let seed = (client * jobs_per_client + i) as u64;
                    let (ref_grids, ref_coeffs) = &reference[&b];
                    let input = So3Coeffs::random(b, seed);
                    if (client + i) % 2 == 0 {
                        // Inverse: must equal the sequential grid bit for bit.
                        let h = service.submit(JobSpec::inverse(b), input).unwrap();
                        let grid = h.wait().unwrap().into_grid().unwrap();
                        assert_eq!(
                            grid.as_slice(),
                            ref_grids[seed as usize].as_slice(),
                            "client {client} job {i} (inverse b={b})"
                        );
                        // Forward of that grid: must equal the sequential
                        // coefficients bit for bit.
                        let h = service.submit(JobSpec::forward(b), grid).unwrap();
                        let back = h.wait().unwrap().into_coeffs().unwrap();
                        assert_eq!(
                            back.as_slice(),
                            ref_coeffs[seed as usize].as_slice(),
                            "client {client} job {i} (forward b={b})"
                        );
                        service.recycle_coeffs(back);
                    } else {
                        let grid = service.inverse(input).unwrap();
                        assert_eq!(
                            grid.as_slice(),
                            ref_grids[seed as usize].as_slice(),
                            "client {client} job {i} (blocking inverse b={b})"
                        );
                        service.recycle_grid(grid);
                    }
                }
            });
        }
    });

    let stats = service.stats();
    assert_eq!(stats.jobs_completed, stats.jobs_submitted);
    assert_eq!(stats.registry.plans, bandwidths.len());
}

/// The registry hands out the SAME `Arc<So3Plan>` for equal keys and a
/// different one for different options.
#[test]
fn registry_returns_same_arc_for_equal_keys() {
    let service = So3Service::builder().threads(2).build().unwrap();
    let opts = PlanOptions::default();
    let a = service.plan(8, opts).unwrap();
    let b = service.plan(8, opts).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "equal keys must share one plan");
    let mut other = opts;
    other.real_input = true;
    let c = service.plan(8, other).unwrap();
    assert!(!Arc::ptr_eq(&a, &c), "distinct options are distinct keys");
    let d = service.plan(4, opts).unwrap();
    assert!(!Arc::ptr_eq(&a, &d), "distinct bandwidths are distinct keys");
    // Jobs executed through the service hit the same cached plans.
    let _ = service.inverse(So3Coeffs::random(8, 1)).unwrap();
    assert!(Arc::ptr_eq(&a, &service.plan(8, opts).unwrap()));
    // Every cached plan runs on the service's one shared worker pool.
    let pool = service.worker_pool().unwrap();
    for plan in [&a, &c, &d] {
        assert!(Arc::ptr_eq(plan.pool().unwrap(), pool));
    }
}

/// Acceptance: a steady-state serving loop performs zero per-job heap
/// allocation of grid/coeff/scratch buffers — pointer-stability
/// assertions on the pooled buffers across many jobs.
#[test]
fn steady_state_serving_is_pointer_stable_and_allocation_free() {
    let b = 8;
    let service = So3Service::builder().threads(1).build().unwrap();
    let template = So3Coeffs::random(b, 99);

    // Warm-up job creates the plan, one workspace, one input buffer and
    // one output buffer; everything after must reuse those allocations.
    let mut input = service.checkout_coeffs(b).unwrap();
    input.as_mut_slice().copy_from_slice(template.as_slice());
    let out = service
        .submit(JobSpec::inverse(b), input)
        .unwrap()
        .wait()
        .unwrap();
    let out_ptr = out.grid().unwrap().as_slice().as_ptr();
    service.recycle(out);
    let warm = service.stats().buffers;

    // After the warm-up, the (single-client) loop sees the exact same
    // input and output allocations on every iteration: checkout pops
    // the LIFO free list the previous iteration pushed.
    let input_ptr = {
        let input = service.checkout_coeffs(b).unwrap();
        let p = input.as_slice().as_ptr();
        service.recycle_coeffs(input);
        p
    };
    for i in 0..10 {
        let mut input = service.checkout_coeffs(b).unwrap();
        assert_eq!(
            input.as_slice().as_ptr(),
            input_ptr,
            "iteration {i}: input buffer must come from the pool"
        );
        input.as_mut_slice().copy_from_slice(template.as_slice());
        let out = service
            .submit(JobSpec::inverse(b), input)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(
            out.grid().unwrap().as_slice().as_ptr(),
            out_ptr,
            "iteration {i}: output buffer must come from the pool"
        );
        service.recycle(out);
    }

    // No new workspace/grid/coeff allocation happened after warm-up.
    let steady = service.stats().buffers;
    assert_eq!(
        (
            steady.workspaces_created,
            steady.grids_created,
            steady.coeffs_created
        ),
        (
            warm.workspaces_created,
            warm.grids_created,
            warm.coeffs_created
        ),
        "steady state must not allocate buffers per job"
    );
    assert_eq!(steady.workspaces_created, 1);
}

/// The workspace pool never grows past its warm high-watermark under
/// steady concurrent load.
#[test]
fn workspace_pool_high_watermark_is_stable_under_load() {
    let bandwidths = [4usize, 8];
    let service = So3Service::builder().threads(2).build().unwrap();
    let run_round = |round: u64| {
        std::thread::scope(|scope| {
            for client in 0..3u64 {
                let service = &service;
                scope.spawn(move || {
                    for i in 0..6u64 {
                        let b = bandwidths[((client + i) % 2) as usize];
                        let grid = service
                            .inverse(So3Coeffs::random(b, round * 1000 + client * 10 + i))
                            .unwrap();
                        service.recycle_grid(grid);
                    }
                });
            }
        });
    };
    run_round(0); // warm-up
    let warm = service.stats().buffers;
    for round in 1..6 {
        run_round(round);
    }
    let steady = service.stats().buffers;
    // The dispatcher holds exactly one workspace at a time and returns
    // it before the next batch, so the watermark is one per bandwidth —
    // reached in the warm round, never exceeded after.
    assert_eq!(steady.workspaces_created, bandwidths.len());
    assert_eq!(
        steady.workspaces_created, warm.workspaces_created,
        "workspace count grew past the warm high-watermark"
    );
    // Output buffers are bounded by the in-flight structural maximum
    // (each blocking client holds/awaits at most one output per
    // bandwidth list), independent of how many rounds ran.
    assert!(
        steady.grids_created <= 3 * bandwidths.len(),
        "pooled grids exceeded the in-flight bound: {steady:?}"
    );
    // Inputs arrive caller-allocated here, so the pool never creates any.
    assert_eq!(steady.coeffs_created, 0);
}

/// Micro-batching coalesces same-key jobs into few batches AND stays
/// bit-identical to per-job execution.
#[test]
fn micro_batching_coalesces_and_is_bit_identical() {
    let b = 8;
    let n = 6;
    let service = So3Service::builder()
        .threads(2)
        .batch_window(Duration::from_millis(150))
        .build()
        .unwrap();
    let inputs: Vec<So3Coeffs> = (0..n).map(|i| So3Coeffs::random(b, 300 + i)).collect();

    // Submit the burst up front, then wait: all jobs share one batch key
    // and land within the window.
    let handles: Vec<_> = inputs
        .iter()
        .map(|c| service.submit(JobSpec::inverse(b), c.clone()).unwrap())
        .collect();
    let outputs: Vec<JobOutput> = handles.into_iter().map(|h| h.wait().unwrap()).collect();

    let plan = So3Plan::builder(b).threads(1).build().unwrap();
    for (c, out) in inputs.iter().zip(&outputs) {
        let want = plan.inverse(c).unwrap();
        assert_eq!(
            out.grid().unwrap().as_slice(),
            want.as_slice(),
            "micro-batched result must be bit-identical to a per-job plan call"
        );
    }
    let stats = service.stats();
    assert_eq!(stats.jobs_completed, n as u64);
    assert!(
        stats.batches < n as u64,
        "jobs within the window must coalesce (got {} batches for {n} jobs)",
        stats.batches
    );
    assert!(stats.max_batch_size >= 2);
}

/// Priorities select the next batch leader: a High job submitted behind
/// a wall of Low jobs completes without waiting for all of them.
#[test]
fn priorities_are_honored_and_all_jobs_complete() {
    let service = So3Service::builder().threads(1).build().unwrap();
    let mut handles = Vec::new();
    for i in 0..4u64 {
        handles.push(
            service
                .submit(
                    JobSpec::inverse(8).priority(JobPriority::Low),
                    So3Coeffs::random(8, i),
                )
                .unwrap(),
        );
    }
    handles.push(
        service
            .submit(
                JobSpec::inverse(4).priority(JobPriority::High),
                So3Coeffs::random(4, 9),
            )
            .unwrap(),
    );
    for h in handles {
        assert!(h.wait().unwrap().bandwidth() > 0);
    }
    let stats = service.stats();
    assert_eq!(stats.jobs_completed, 5);
}

/// One data-dependent bad payload inside a micro-batch must not fail
/// its batch neighbors: the dispatcher falls back to per-job execution
/// and every handle gets its own typed outcome.
#[test]
fn bad_payload_in_batch_does_not_fail_neighbors() {
    use so3ft::Complex64;
    let b = 4;
    let real_opts = PlanOptions {
        real_input: true,
        ..PlanOptions::default()
    };
    let service = So3Service::builder()
        .threads(1)
        .batch_window(Duration::from_millis(100))
        .build()
        .unwrap();

    // Two valid real-sample grids and one with a nonzero imaginary part
    // (rejected by the real-input forward path at execution time — this
    // cannot be caught at submit).
    let plan = service.plan(b, real_opts).unwrap();
    let make_real = |seed: u64| {
        let g = plan.inverse(&So3Coeffs::random(b, seed)).unwrap();
        So3Grid::from_vec(
            b,
            g.as_slice()
                .iter()
                .map(|z| Complex64::new(z.re, 0.0))
                .collect(),
        )
        .unwrap()
    };
    let g0 = make_real(1);
    let mut g1 = make_real(2);
    g1.set(0, 0, 0, Complex64::new(0.5, 0.25)); // poison one payload
    let g2 = make_real(3);

    let spec = JobSpec::forward(b).options(real_opts);
    let h0 = service.submit(spec, g0.clone()).unwrap();
    let h1 = service.submit(spec, g1).unwrap();
    let h2 = service.submit(spec, g2.clone()).unwrap();

    let r0 = h0.wait();
    let r1 = h1.wait();
    let r2 = h2.wait();
    // The poisoned job fails alone, with its own typed error…
    match r1 {
        Err(so3ft::Error::Service(msg)) => {
            assert!(msg.contains("real-input"), "unexpected message: {msg}")
        }
        other => panic!("poisoned job must fail, got {:?}", other.map(|_| ())),
    }
    // …while its neighbors succeed bit-for-bit.
    let want0 = plan.forward(&g0).unwrap();
    let want2 = plan.forward(&g2).unwrap();
    assert_eq!(
        r0.unwrap().into_coeffs().unwrap().as_slice(),
        want0.as_slice()
    );
    assert_eq!(
        r2.unwrap().into_coeffs().unwrap().as_slice(),
        want2.as_slice()
    );
}

/// The registry byte budget evicts cold plans; serving keeps working.
#[test]
fn registry_budget_evicts_but_serving_survives() {
    let b4_bytes = So3Plan::new(4).unwrap().table_bytes();
    let service = So3Service::builder()
        .threads(1)
        .registry_budget_bytes(b4_bytes)
        .build()
        .unwrap();
    let _ = service.inverse(So3Coeffs::random(4, 1)).unwrap();
    let _ = service.inverse(So3Coeffs::random(8, 2)).unwrap();
    let stats = service.stats();
    assert!(stats.registry.evictions >= 1, "budget must evict");
    // The evicted bandwidth still serves (rebuilt on demand).
    let grid = service.inverse(So3Coeffs::random(4, 3)).unwrap();
    assert_eq!(grid.bandwidth(), 4);
}

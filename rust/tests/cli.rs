//! Integration: the CLI binary surface (through the library entry point,
//! which `main.rs` delegates to).

use so3ft::cli::{parse_args, run};

fn argv(s: &str) -> Vec<String> {
    std::iter::once("so3ft".to_string())
        .chain(s.split_whitespace().map(|t| t.to_string()))
        .collect()
}

#[test]
fn info_runs_clean() {
    assert_eq!(run(argv("info -b 4")), 0);
}

#[test]
fn roundtrip_runs_clean() {
    assert_eq!(run(argv("roundtrip -b 4 -t 2 --seed 1")), 0);
}

#[test]
fn forward_inverse_run_clean() {
    assert_eq!(run(argv("forward -b 4")), 0);
    assert_eq!(run(argv("inverse -b 4 --algorithm clenshaw")), 0);
    // The folded engine (the default) and the matvec baseline are both
    // selectable by name.
    assert_eq!(run(argv("inverse -b 4 --algorithm matvec-folded")), 0);
    assert_eq!(run(argv("forward -b 4 --algorithm matvec")), 0);
}

#[test]
fn match_runs_clean() {
    assert_eq!(run(argv("match -b 4 --seed 3")), 0);
}

#[test]
fn simulate_runs_clean() {
    assert_eq!(run(argv("simulate -b 4 --cores 1,4 --kind inv")), 0);
}

#[test]
fn serve_bench_runs_clean_and_writes_json() {
    let dir = std::env::temp_dir().join(format!("so3ft-servebench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let json = dir.join("BENCH_service.json");
    assert_eq!(
        run(argv(&format!(
            "serve-bench -t 2 --clients 2 --jobs 4 --bandwidths 4,8 --window-us 100 \
             --json {}",
            json.display()
        ))),
        0
    );
    let text = std::fs::read_to_string(&json).unwrap();
    assert!(text.contains("\"kind\": \"service_p99\""), "{text}");
    assert!(text.contains("\"kind\": \"service_throughput\""), "{text}");
    assert!(text.contains("\"per_job_s\""), "{text}");
    // Records for both bandwidths of the mix.
    assert!(text.contains("\"b\": 4") && text.contains("\"b\": 8"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn help_prints() {
    assert_eq!(run(argv("help")), 0);
    assert_eq!(run(argv("--help")), 0);
}

#[test]
fn extended_precision_flag_works() {
    assert_eq!(run(argv("roundtrip -b 4 --precision extended")), 0);
}

#[test]
fn storage_and_strategy_flags_work() {
    assert_eq!(
        run(argv("roundtrip -b 4 --storage onthefly --strategy sigma")),
        0
    );
    assert_eq!(run(argv("roundtrip -b 4 --storage auto:64")), 0);
}

#[test]
fn config_file_loading() {
    let dir = std::env::temp_dir().join(format!("so3ft-clitest-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "[transform]\nbandwidth = 4\nthreads = 2\nalgorithm = \"clenshaw\"\n",
    )
    .unwrap();
    assert_eq!(run(argv(&format!("roundtrip --config {}", path.display()))), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parse_errors_exit_2() {
    assert_eq!(run(argv("roundtrip --bandwidth")), 2);
    // An unknown leading token is treated as an unknown *command* (exit 1).
    assert_eq!(run(argv("--nonsense")), 1);
}

#[test]
fn parser_precedence_flag_over_config() {
    let dir = std::env::temp_dir().join(format!("so3ft-clitest2-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(&path, "[transform]\nbandwidth = 32\n").unwrap();
    let inv = parse_args(&[
        "info".to_string(),
        "--config".to_string(),
        path.display().to_string(),
        "-b".to_string(),
        "8".to_string(),
    ])
    .unwrap();
    assert_eq!(inv.run.bandwidth, 8);
    let _ = std::fs::remove_dir_all(&dir);
}

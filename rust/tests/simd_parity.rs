//! SIMD-vs-scalar parity suite (PR 7 acceptance): every ISA backend the
//! host supports must agree with the scalar baseline to ≤ 1e-12 across
//! bandwidths (including a non-power-of-two), both directions, both DWT
//! dataflows, and both Wigner sources; plus dispatch regressions — the
//! `Scalar` policy resolves to scalar kernels everywhere, `Force*`
//! policies fail typed on unsupported hosts, and `detect(force_scalar)`
//! honors the `SO3FT_FORCE_SCALAR` escape hatch.

use so3ft::dwt::tables::WignerStorage;
use so3ft::dwt::DwtAlgorithm;
use so3ft::error::Error;
use so3ft::simd::{avx2_supported, detect, neon_supported, SimdIsa, SimdPolicy};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn plan(
    b: usize,
    policy: SimdPolicy,
    algorithm: DwtAlgorithm,
    storage: WignerStorage,
) -> So3Plan {
    So3Plan::builder(b)
        .simd(policy)
        .algorithm(algorithm)
        .storage(storage)
        .allow_any_bandwidth()
        .build()
        .unwrap()
}

/// Every non-scalar policy the host can actually run.
fn host_vector_policies() -> Vec<SimdPolicy> {
    let mut v = vec![SimdPolicy::Auto];
    if avx2_supported() {
        v.push(SimdPolicy::ForceAvx2);
    }
    if neon_supported() {
        v.push(SimdPolicy::ForceNeon);
    }
    v
}

/// The headline acceptance matrix: every supported backend vs scalar at
/// b ∈ {1, 8, 13, 16, 32} (13 exercises the non-power-of-two tail
/// lanes) × both directions × both DWT dataflows × both Wigner sources.
#[test]
fn every_backend_matches_scalar_across_the_matrix() {
    for b in [1usize, 8, 13, 16, 32] {
        let coeffs = So3Coeffs::random(b, 0x51D0 + b as u64);
        for algorithm in [DwtAlgorithm::MatVecFolded, DwtAlgorithm::MatVec] {
            for storage in [WignerStorage::Precomputed, WignerStorage::OnTheFly] {
                let scalar = plan(b, SimdPolicy::Scalar, algorithm, storage);
                let g_scalar = scalar.inverse(&coeffs).unwrap();
                let c_scalar = scalar.forward(&g_scalar).unwrap();
                for policy in host_vector_policies() {
                    let vector = plan(b, policy, algorithm, storage);
                    let g_vec = vector.inverse(&coeffs).unwrap();
                    let inv_err = g_scalar.max_abs_error(&g_vec);
                    assert!(
                        inv_err < 1e-12,
                        "inverse b={b} {policy:?} {algorithm:?} {storage:?}: {inv_err:.3e}"
                    );
                    let c_vec = vector.forward(&g_scalar).unwrap();
                    let fwd_err = c_scalar.max_abs_error(&c_vec);
                    assert!(
                        fwd_err < 1e-12,
                        "forward b={b} {policy:?} {algorithm:?} {storage:?}: {fwd_err:.3e}"
                    );
                }
            }
        }
    }
}

/// `simd = scalar` must resolve to scalar kernels on every host — the
/// measurable-baseline contract the benches and `SO3FT_FORCE_SCALAR`
/// depend on.
#[test]
fn scalar_policy_always_resolves_scalar() {
    let p = So3Plan::builder(8).simd(SimdPolicy::Scalar).build().unwrap();
    assert_eq!(p.simd_isa(), SimdIsa::Scalar);
    assert_eq!(p.config().simd, SimdPolicy::Scalar);
    // And Auto resolves to whatever detection found, consistently.
    let auto = So3Plan::builder(8).simd(SimdPolicy::Auto).build().unwrap();
    assert_eq!(auto.simd_isa(), so3ft::simd::detected_isa());
}

/// The `force_scalar` leg of detection (what `SO3FT_FORCE_SCALAR=1`
/// feeds) pins the ISA to scalar regardless of the host; without it,
/// detection reports a host-supported ISA.
#[test]
fn forced_scalar_detection_overrides_the_host() {
    assert_eq!(detect(true), SimdIsa::Scalar);
    let free = detect(false);
    match free {
        SimdIsa::Scalar => {}
        SimdIsa::Avx2 => assert!(avx2_supported()),
        SimdIsa::Neon => assert!(neon_supported()),
    }
}

/// A `Force*` policy for an ISA the host lacks is a typed config error
/// at plan build, never a silent fallback.
#[test]
fn impossible_force_policy_is_a_typed_build_error() {
    let impossible = if cfg!(target_arch = "x86_64") {
        SimdPolicy::ForceNeon
    } else {
        SimdPolicy::ForceAvx2
    };
    let err = So3Plan::builder(8)
        .simd(impossible)
        .build()
        .map(|_| ())
        .expect_err("force policy for a missing ISA must fail the build");
    match err {
        Error::Config(msg) => assert!(msg.contains("simd"), "{msg}"),
        other => panic!("expected Error::Config, got {other:?}"),
    }
}

//! Ablation: loop-scheduling policy (the paper's `schedule(dynamic)`
//! choice) — simulated on the Opteron-like model where thread scaling is
//! visible, plus a real-pool smoke run on this container.

use so3ft::bench_util::{csv_sink, env_usize, time_fn, Table};
use so3ft::pool::Schedule;
use so3ft::simulator::cost::{measured_spec, TransformKind};
use so3ft::simulator::machine::{simulate_transform, MachineParams};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn main() {
    let b = env_usize("SO3FT_BENCH_B", 32);
    println!("== ablation: DWT-loop schedule at B={b} (simulated 8/64 cores) ==");

    let mut spec = measured_spec(b, TransformKind::Forward).expect("spec");
    let params = MachineParams::opteron_like();
    let schedules = [
        ("dynamic:1", Schedule::Dynamic { chunk: 1 }),
        ("dynamic:8", Schedule::Dynamic { chunk: 8 }),
        ("static", Schedule::Static),
        ("interleaved", Schedule::StaticInterleaved),
        ("guided:1", Schedule::Guided { min_chunk: 1 }),
    ];
    let mut table = Table::new(&["schedule", "sim speedup p=8", "sim speedup p=64"]);
    let mut csv = Vec::new();
    let t1 = simulate_transform(&spec, 1, &params);
    for (name, schedule) in schedules {
        // The DWT region is the last (forward) region in the spec.
        let dwt_idx = spec.regions.len() - 1;
        spec.regions[dwt_idx].schedule = schedule;
        let s8 = t1 / simulate_transform(&spec, 8, &params);
        let s64 = t1 / simulate_transform(&spec, 64, &params);
        table.row(&[name.into(), format!("{s8:.2}"), format!("{s64:.2}")]);
        csv.push(format!("{name},{b},{s8:.3},{s64:.3}"));
    }
    table.print();

    // Real pool on this container (1 core: validates overhead ordering,
    // not scaling).
    let reps = env_usize("SO3FT_BENCH_REPS", 3);
    let threads = env_usize("SO3FT_BENCH_THREADS", 4);
    println!("\n== real pool, {threads} threads (single-core container) ==");
    let coeffs = So3Coeffs::random(b, 5);
    let mut t2 = Table::new(&["schedule", "forward median (s)"]);
    for (name, schedule) in schedules {
        let fft = So3Plan::builder(b)
            .allow_any_bandwidth()
            .threads(threads)
            .schedule(schedule)
            .build()
            .unwrap();
        let grid = fft.inverse(&coeffs).unwrap();
        let s = time_fn(reps, || {
            std::hint::black_box(fft.forward(&grid).unwrap());
        });
        t2.row(&[name.into(), format!("{:.4}", s.median())]);
        csv.push(format!("real_{name},{b},{:.4},", s.median()));
    }
    t2.print();
    csv_sink("ablation_schedule", "schedule,b,s8_or_time,s64", &csv);
}

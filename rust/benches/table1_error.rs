//! Paper Table 1 — maximum absolute and relative error of an iFSOFT
//! followed by an FSOFT, mean ± std over `SO3FT_BENCH_ERROR_RUNS`
//! (paper: 10) runs per bandwidth.
//!
//! Bandwidths default to "8 16 32" (native double precision) plus an
//! extended-precision column when `SO3FT_BENCH_XPREC=1`. The paper's
//! B = 512 row needs ~hours on one core; raise SO3FT_BENCH_ERROR_BS to
//! reproduce it on a bigger box (the code path is identical).

use so3ft::bench_util::{csv_sink, env_usize, env_usize_list, fmt_mean_std_sci, Table};
use so3ft::dwt::Precision;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn mean_std(v: &[f64]) -> (f64, f64) {
    let m = v.iter().sum::<f64>() / v.len() as f64;
    let var = if v.len() < 2 {
        0.0
    } else {
        v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64
    };
    (m, var.sqrt())
}

fn main() {
    let bandwidths = env_usize_list("SO3FT_BENCH_ERROR_BS", &[8, 16, 32]);
    let runs = env_usize("SO3FT_BENCH_ERROR_RUNS", 10);
    let xprec = std::env::var("SO3FT_BENCH_XPREC").is_ok();

    println!("== table1: roundtrip error (iFSOFT then FSOFT), {runs} runs each ==");
    println!("paper reference (double→extended precision on 64-core Opteron):");
    println!("  B=32  (1.10±0.14)E-14 abs, (7.91±7.85)E-13 rel");
    println!("  B=64  (2.79±0.23)E-14 abs, (3.08±2.31)E-12 rel");
    println!("  B=128 (6.23±0.65)E-14 abs, (1.89±1.33)E-11 rel");
    println!("  B=256 (2.21±0.13)E-13 abs, (9.21±4.57)E-11 rel");
    println!("  B=512 (4.98±0.33)E-13 abs, (4.26±2.73)E-10 rel\n");

    let mut table = Table::new(&["B", "precision", "max abs error", "max rel error"]);
    let mut csv = Vec::new();
    for &b in &bandwidths {
        let precisions: &[Precision] = if xprec {
            &[Precision::Double, Precision::Extended]
        } else {
            &[Precision::Double]
        };
        for &precision in precisions {
            let fft = So3Plan::builder(b)
                .allow_any_bandwidth()
                .precision(precision)
                .build()
                .unwrap();
            let mut abs = Vec::with_capacity(runs);
            let mut rel = Vec::with_capacity(runs);
            for run in 0..runs {
                let coeffs = So3Coeffs::random(b, 1000 + run as u64);
                let grid = fft.inverse(&coeffs).unwrap();
                let back = fft.forward(&grid).unwrap();
                abs.push(coeffs.max_abs_error(&back));
                rel.push(coeffs.max_rel_error(&back));
            }
            let (am, astd) = mean_std(&abs);
            let (rm, rstd) = mean_std(&rel);
            let pname = match precision {
                Precision::Double => "double",
                Precision::Extended => "extended",
            };
            table.row(&[
                b.to_string(),
                pname.to_string(),
                fmt_mean_std_sci(am, astd),
                fmt_mean_std_sci(rm, rstd),
            ]);
            csv.push(format!("{b},{pname},{am:.3e},{astd:.3e},{rm:.3e},{rstd:.3e}"));
        }
    }
    table.print();
    csv_sink(
        "table1_error",
        "b,precision,abs_mean,abs_std,rel_mean,rel_std",
        &csv,
    );
}

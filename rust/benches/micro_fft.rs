//! Microbench: the FFT substrate — 1-D radix-2/Bluestein and the 2-D
//! slice transform at the sizes the FSOFT uses (2B for B = 16…512).

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::fft::fft2::Fft2;
use so3ft::fft::{Complex64, FftPlan, Sign};
use so3ft::prng::Xoshiro256;

fn signal(n: usize, seed: u64) -> Vec<Complex64> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
        .collect()
}

fn main() {
    let reps = env_usize("SO3FT_BENCH_REPS", 20);
    let mut csv = Vec::new();

    println!("== micro: 1-D FFT ==");
    let mut t1 = Table::new(&["n", "algo", "median", "ns/point"]);
    for &n in &[32usize, 64, 128, 256, 512, 1024, 96, 768] {
        let plan = FftPlan::new(n);
        let algo = if n.is_power_of_two() { "radix2" } else { "bluestein" };
        let mut buf = signal(n, n as u64);
        let s = time_fn(reps, || {
            plan.process(&mut buf, Sign::Negative);
            std::hint::black_box(&buf);
        });
        t1.row(&[
            n.to_string(),
            algo.into(),
            fmt_seconds(s.median()),
            format!("{:.1}", s.median() * 1e9 / n as f64),
        ]);
        csv.push(format!("fft1,{n},{algo},{:.4e}", s.median()));
    }
    t1.print();

    println!("\n== micro: 2-D slice FFT (the FSOFT's per-β work) ==");
    let mut t2 = Table::new(&["2B", "median", "ns/point"]);
    for &n in &[32usize, 64, 128, 256] {
        let fft2 = Fft2::with_size(n);
        let mut buf = signal(n * n, 7);
        let mut scratch = vec![Complex64::zero(); 4 * n];
        let s = time_fn(reps, || {
            fft2.process(&mut buf, &mut scratch, Sign::Positive);
            std::hint::black_box(&buf);
        });
        t2.row(&[
            n.to_string(),
            fmt_seconds(s.median()),
            format!("{:.1}", s.median() * 1e9 / (n * n) as f64),
        ]);
        csv.push(format!("fft2,{n},,{:.4e}", s.median()));
    }
    t2.print();
    csv_sink("micro_fft", "bench,n,algo,seconds", &csv);
}

//! Microbench: the FFT substrate — 1-D kernels (split-radix vs radix-2
//! vs Bluestein, with the split-radix SIMD backend vs its scalar
//! baseline), the 2-D slice transform's column-pass strategies
//! (copy-free panels vs gather/scatter), and the real-input path, at the
//! sizes the FSOFT uses (2B for B = 16…512).

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::fft::fft2::{ColumnPass, Fft2};
use so3ft::fft::real::RealFft2;
use so3ft::fft::{Complex64, FftAlgo, FftPlan, Sign};
use so3ft::prng::Xoshiro256;
use so3ft::simd::{detected_isa, SimdIsa};

fn signal(n: usize, seed: u64) -> Vec<Complex64> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
        .collect()
}

fn main() {
    let reps = env_usize("SO3FT_BENCH_REPS", 20);
    let mut csv = Vec::new();

    println!("== micro: 1-D FFT kernels (simd={}) ==", detected_isa().name());
    let mut t1 = Table::new(&["n", "algo", "median", "ns/point"]);
    for &n in &[32usize, 64, 128, 256, 512, 1024, 96, 768] {
        // (plan, label): split-radix runs twice — with the detected ISA
        // and pinned scalar — so the SIMD speedup is one column diff.
        let variants: Vec<(FftPlan, String)> = if n.is_power_of_two() {
            vec![
                (
                    FftPlan::with_algo(n, FftAlgo::SplitRadix),
                    "split-radix".into(),
                ),
                (
                    FftPlan::with_algo_isa(n, FftAlgo::SplitRadix, SimdIsa::Scalar),
                    "split-radix-sc".into(),
                ),
                (FftPlan::with_algo(n, FftAlgo::Radix2), "radix2".into()),
            ]
        } else {
            vec![(FftPlan::with_algo(n, FftAlgo::Bluestein), "bluestein".into())]
        };
        for (plan, name) in &variants {
            let mut buf = signal(n, n as u64);
            let s = time_fn(reps, || {
                plan.process(&mut buf, Sign::Negative);
                std::hint::black_box(&buf);
            });
            t1.row(&[
                n.to_string(),
                name.clone(),
                fmt_seconds(s.median()),
                format!("{:.1}", s.median() * 1e9 / n as f64),
            ]);
            csv.push(format!("fft1,{n},{name},{:.4e}", s.median()));
        }
    }
    t1.print();

    println!("\n== micro: 2-D slice FFT (the FSOFT's per-β work) ==");
    let mut t2 = Table::new(&["2B", "engine", "median", "ns/point"]);
    for &n in &[32usize, 64, 128, 256] {
        let variants: [(&str, Fft2); 4] = [
            (
                "split+panel",
                Fft2::new(n, std::sync::Arc::new(FftPlan::new(n))),
            ),
            (
                "split+panel-sc",
                Fft2::new(
                    n,
                    std::sync::Arc::new(FftPlan::with_algo_isa(
                        n,
                        FftAlgo::SplitRadix,
                        SimdIsa::Scalar,
                    )),
                ),
            ),
            (
                "split+gather",
                Fft2::with_column_pass(
                    n,
                    std::sync::Arc::new(FftPlan::new(n)),
                    ColumnPass::GatherScatter,
                ),
            ),
            (
                "radix2+gather",
                Fft2::with_column_pass(
                    n,
                    std::sync::Arc::new(FftPlan::with_algo(n, FftAlgo::Radix2)),
                    ColumnPass::GatherScatter,
                ),
            ),
        ];
        for (name, fft2) in &variants {
            let mut buf = signal(n * n, 7);
            let mut scratch = vec![Complex64::zero(); fft2.scratch_len()];
            let inv_n = 1.0 / n as f64;
            let s = time_fn(reps, || {
                fft2.process(&mut buf, &mut scratch, Sign::Positive);
                // Keep magnitudes bounded across reps (identical cost for
                // every variant).
                for v in buf.iter_mut() {
                    *v = v.scale(inv_n);
                }
                std::hint::black_box(&buf);
            });
            t2.row(&[
                n.to_string(),
                (*name).into(),
                fmt_seconds(s.median()),
                format!("{:.1}", s.median() * 1e9 / (n * n) as f64),
            ]);
            csv.push(format!("fft2,{n},{name},{:.4e}", s.median()));
        }
    }
    t2.print();

    println!("\n== micro: real-input 2-D slice FFT (conjugate-even stage 1) ==");
    let mut t3 = Table::new(&["2B", "path", "median", "ns/point"]);
    for &n in &[32usize, 64, 128, 256] {
        let plan = std::sync::Arc::new(FftPlan::new(n));
        let complex_fft2 = Fft2::new(n, plan.clone());
        let real_fft2 = RealFft2::new(n, plan);
        let base = signal(n * n, 11);
        let real_base: Vec<Complex64> =
            base.iter().map(|z| Complex64::new(z.re, 0.0)).collect();

        let mut buf = base.clone();
        let mut scratch = vec![Complex64::zero(); complex_fft2.scratch_len()];
        let inv_n = 1.0 / n as f64;
        let s_c = time_fn(reps, || {
            complex_fft2.process(&mut buf, &mut scratch, Sign::Positive);
            for v in buf.iter_mut() {
                *v = v.scale(inv_n);
            }
            std::hint::black_box(&buf);
        });

        let mut rbuf = real_base.clone();
        let mut rscratch = vec![Complex64::zero(); real_fft2.scratch_len()];
        let s_r = time_fn(reps, || {
            // The real kernel consumes real samples; restore them each
            // rep (a copy, ~1/log n of the transform cost).
            rbuf.copy_from_slice(&real_base);
            real_fft2.forward(&mut rbuf, &mut rscratch, Sign::Positive);
            std::hint::black_box(&rbuf);
        });

        for (name, s) in [("complex", &s_c), ("real", &s_r)] {
            t3.row(&[
                n.to_string(),
                name.into(),
                fmt_seconds(s.median()),
                format!("{:.1}", s.median() * 1e9 / (n * n) as f64),
            ]);
            csv.push(format!("fft2_real,{n},{name},{:.4e}", s.median()));
        }
    }
    t3.print();
    csv_sink("micro_fft", "bench,n,algo,seconds", &csv);
}

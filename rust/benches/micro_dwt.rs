//! Microbench: single-cluster DWT kernels — the transform's hot spot —
//! across cluster shapes and dataflows, including the β-parity-folded
//! engine vs the matvec baseline (ISSUE 4's headline comparison) and
//! the folded engine's SIMD backend vs its scalar baseline.

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::simd::{detected_isa, SimdIsa};
use so3ft::dwt::cluster::Cluster;
use so3ft::dwt::clenshaw;
use so3ft::dwt::folded::{forward_cluster_folded_tables, inverse_cluster_folded_tables};
use so3ft::dwt::kernels::{forward_cluster, inverse_cluster, DwtScratch};
use so3ft::dwt::tables::{OnTheFlySource, WignerSource, WignerTables};
use so3ft::dwt::SMatrix;
use so3ft::fft::Complex64;
use so3ft::prng::Xoshiro256;
use so3ft::so3::coeffs::{coeff_count, So3Coeffs};
use so3ft::so3::quadrature;
use so3ft::so3::sampling::GridAngles;
use so3ft::util::SyncUnsafeSlice;

fn main() {
    let b = env_usize("SO3FT_BENCH_B", 64);
    let reps = env_usize("SO3FT_BENCH_REPS", 30);
    let isa = detected_isa();
    println!(
        "== micro: per-cluster DWT kernels at B={b} (simd={}) ==",
        isa.name()
    );

    let angles = GridAngles::new(b).unwrap();
    let weights = quadrature::weights(b).unwrap();
    let tables = WignerTables::build(b, &angles.betas);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut smat = SMatrix::zeros(b).unwrap();
    for v in smat.as_mut_slice().iter_mut() {
        *v = Complex64::new(rng.next_signed(), rng.next_signed());
    }
    let coeffs = So3Coeffs::random(b, 2);
    let layout = SMatrix::zeros(b).unwrap();
    let mut scratch = DwtScratch::new(b);
    let mut out = vec![Complex64::zero(); coeff_count(b)];
    let mut smat_out = SMatrix::zeros(b).unwrap();

    // Representative clusters: full 8-member low-l0 (big), diagonal,
    // border (the parity fast path), high-l0 (small).
    let shapes = [
        ("8-member, l0=2", Cluster::symmetric(2, 1)),
        ("8-member, l0=B/2", Cluster::symmetric(b as i64 / 2, 1)),
        ("diagonal (4)", Cluster::symmetric(b as i64 / 2, b as i64 / 2)),
        ("border (4, parity)", Cluster::symmetric(b as i64 / 2, 0)),
        ("(0,0) single", Cluster::symmetric(0, 0)),
    ];
    let mut table = Table::new(&[
        "cluster",
        "fwd tables",
        "fwd folded",
        "fwd fold-sc",
        "fwd onthefly",
        "fwd clenshaw",
        "inv tables",
        "inv folded",
        "inv fold-sc",
        "inv clenshaw",
        "fwd fold spd",
        "inv fold spd",
        "fwd simd spd",
        "inv simd spd",
    ]);
    let mut csv = Vec::new();
    for (name, cluster) in &shapes {
        let shared = SyncUnsafeSlice::new(&mut out);
        let f_tab = time_fn(reps, || {
            let mut src = tables.source();
            forward_cluster(b, cluster, &mut src, &weights, &smat, &shared, &mut scratch);
        });
        let f_fold = time_fn(reps, || {
            forward_cluster_folded_tables(
                b, isa, cluster, &tables, &weights, &smat, &shared, &mut scratch,
            );
        });
        let f_fold_sc = time_fn(reps, || {
            forward_cluster_folded_tables(
                b,
                SimdIsa::Scalar,
                cluster,
                &tables,
                &weights,
                &smat,
                &shared,
                &mut scratch,
            );
        });
        let f_fly = time_fn(reps, || {
            let mut src = OnTheFlySource::new(&angles.betas);
            src.reset(cluster.m, cluster.mp);
            forward_cluster(b, cluster, &mut src, &weights, &smat, &shared, &mut scratch);
        });
        let mut acc = Vec::new();
        let f_cl = time_fn(reps, || {
            clenshaw::forward_cluster_clenshaw(
                b, cluster, &angles.betas, &weights, &smat, &shared, &mut acc,
            );
        });
        let shared_s = SyncUnsafeSlice::new(smat_out.as_mut_slice());
        let i_tab = time_fn(reps, || {
            let mut src = tables.source();
            inverse_cluster(
                b,
                cluster,
                &mut src,
                coeffs.as_slice(),
                &shared_s,
                &layout,
                &mut scratch,
            );
        });
        let i_fold = time_fn(reps, || {
            inverse_cluster_folded_tables(
                b,
                isa,
                cluster,
                &tables,
                coeffs.as_slice(),
                &shared_s,
                &layout,
                &mut scratch,
            );
        });
        let i_fold_sc = time_fn(reps, || {
            inverse_cluster_folded_tables(
                b,
                SimdIsa::Scalar,
                cluster,
                &tables,
                coeffs.as_slice(),
                &shared_s,
                &layout,
                &mut scratch,
            );
        });
        let mut buf = Vec::new();
        let i_cl = time_fn(reps, || {
            clenshaw::inverse_cluster_clenshaw(
                b,
                cluster,
                &angles.betas,
                coeffs.as_slice(),
                &shared_s,
                &layout,
                &mut buf,
            );
        });
        table.row(&[
            name.to_string(),
            fmt_seconds(f_tab.median()),
            fmt_seconds(f_fold.median()),
            fmt_seconds(f_fold_sc.median()),
            fmt_seconds(f_fly.median()),
            fmt_seconds(f_cl.median()),
            fmt_seconds(i_tab.median()),
            fmt_seconds(i_fold.median()),
            fmt_seconds(i_fold_sc.median()),
            fmt_seconds(i_cl.median()),
            format!("{:.2}x", f_tab.median() / f_fold.median()),
            format!("{:.2}x", i_tab.median() / i_fold.median()),
            format!("{:.2}x", f_fold_sc.median() / f_fold.median()),
            format!("{:.2}x", i_fold_sc.median() / i_fold.median()),
        ]);
        csv.push(format!(
            "{name},{b},{:.4e},{:.4e},{:.4e},{:.4e},{:.4e},{:.4e},{:.4e},{:.4e},{:.4e}",
            f_tab.median(),
            f_fold.median(),
            f_fold_sc.median(),
            f_fly.median(),
            f_cl.median(),
            i_tab.median(),
            i_fold.median(),
            i_fold_sc.median(),
            i_cl.median()
        ));
    }
    table.print();
    csv_sink(
        "micro_dwt",
        "cluster,b,fwd_tab,fwd_folded,fwd_folded_scalar,fwd_fly,fwd_clen,\
         inv_tab,inv_folded,inv_folded_scalar,inv_clen",
        &csv,
    );
}

//! Ablation: Wigner-d symmetry clustering (paper §3 agglomeration) on vs
//! off. Clustering shares one recurrence evaluation across ≤8 DWTs; the
//! no-symmetry baseline pays it per order pair.

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::coordinator::{PartitionStrategy, TransformPlan};
use so3ft::dwt::tables::WignerStorage;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn main() {
    let b = env_usize("SO3FT_BENCH_B", 16);
    let reps = env_usize("SO3FT_BENCH_REPS", 5);
    println!("== ablation: symmetry clustering at B={b} (on-the-fly rows) ==");

    let coeffs = So3Coeffs::random(b, 21);
    let mut table = Table::new(&[
        "variant",
        "packages",
        "est. flops",
        "forward",
        "inverse",
    ]);
    let mut csv = Vec::new();
    for (name, strategy) in [
        ("clustered", PartitionStrategy::GeometricClustered),
        ("no-symmetry", PartitionStrategy::NoSymmetry),
    ] {
        let fft = So3Plan::builder(b)
            .allow_any_bandwidth()
            .strategy(strategy)
            // On-the-fly isolates the symmetry effect (precomputed tables
            // would amortize the recurrence differently).
            .storage(WignerStorage::OnTheFly)
            .build()
            .unwrap();
        let plan = TransformPlan::new(b, strategy);
        let grid = fft.inverse(&coeffs).unwrap();
        let fs = time_fn(reps, || {
            std::hint::black_box(fft.forward(&grid).unwrap());
        });
        let is = time_fn(reps, || {
            std::hint::black_box(fft.inverse(&coeffs).unwrap());
        });
        table.row(&[
            name.into(),
            plan.clusters.len().to_string(),
            plan.total_flops().to_string(),
            fmt_seconds(fs.median()),
            fmt_seconds(is.median()),
        ]);
        csv.push(format!(
            "{name},{b},{},{:.4e},{:.4e}",
            plan.clusters.len(),
            fs.median(),
            is.median()
        ));
    }
    table.print();
    csv_sink("ablation_symmetry", "variant,b,packages,fwd_s,inv_s", &csv);
}

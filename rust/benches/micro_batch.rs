//! Microbench: per-call allocation vs the planner/session serving path.
//!
//! Compares, at b ∈ {8, 16, 32} (override with `SO3FT_BENCH_BATCH_BS`):
//!
//! * `alloc`  — the legacy pattern: allocating `forward`/`inverse`
//!   calls, fresh output + workspace buffers every time;
//! * `into`   — `So3Plan::forward_into`/`inverse_into` with one reused
//!   [`Workspace`] and caller-owned outputs (zero grid/coefficient
//!   allocation per call);
//! * `batch`  — `forward_batch_into`/`inverse_batch_into` pipelining
//!   `SO3FT_BENCH_BATCH_N` (default 8) signals through one plan.
//!
//! Per-item medians are printed so the allocation overhead is directly
//! readable; CSV rows land in `bench_results/micro_batch.csv` when
//! `SO3FT_BENCH_CSV` is set.
//!
//! A final section measures **region dispatch overhead**: the persistent
//! [`WorkerPool`] (parked workers, condvar wakeup) against the legacy
//! scoped-spawn `parallel_for` (fresh OS threads per region) at the
//! executor's FFT-stage region shape, b ∈ {8, 16, 32} — the spawn
//! overhead the pool runtime removes from every serving-path transform.

use std::sync::atomic::{AtomicU64, Ordering};

use so3ft::bench_util::{
    csv_sink, env_usize, env_usize_list, fmt_seconds, time_fn, Samples, Table,
};
use so3ft::fft::Complex64;
use so3ft::pool::{parallel_for, Schedule, WorkerPool};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::so3::sampling::So3Grid;
use so3ft::transform::{FftEngine, So3Plan};

fn main() {
    let reps = env_usize("SO3FT_BENCH_REPS", 10);
    let batch_n = env_usize("SO3FT_BENCH_BATCH_N", 8);
    let bandwidths = env_usize_list("SO3FT_BENCH_BATCH_BS", &[8, 16, 32]);
    let mut csv = Vec::new();

    println!("== micro: per-call allocation vs execute_into + workspace reuse ==");
    println!("(batch size {batch_n}, {reps} reps; per-item medians)\n");
    let mut table = Table::new(&[
        "B",
        "dir",
        "alloc/item",
        "into/item",
        "batch/item",
        "into speedup",
    ]);

    for &b in &bandwidths {
        let legacy = So3Plan::builder(b)
            .allow_any_bandwidth()
            .build()
            .expect("alloc-pattern plan");
        let plan = So3Plan::new(b).expect("plan");
        let specs: Vec<So3Coeffs> = (0..batch_n)
            .map(|i| So3Coeffs::random(b, 90 + i as u64))
            .collect();
        let grids: Vec<So3Grid> = plan.inverse_batch(&specs).expect("inputs");

        let mut ws = plan.make_workspace();
        let mut out_grid = So3Grid::zeros(b).expect("grid buffer");
        let mut out_spec = So3Coeffs::zeros(b);
        let mut batch_grids: Vec<So3Grid> =
            (0..batch_n).map(|_| So3Grid::zeros(b).unwrap()).collect();
        let mut batch_specs: Vec<So3Coeffs> =
            (0..batch_n).map(|_| So3Coeffs::zeros(b)).collect();

        for dir in ["fwd", "inv"] {
            let alloc = time_fn(reps, || match dir {
                "fwd" => {
                    let c = legacy.forward(&grids[0]).unwrap();
                    std::hint::black_box(&c);
                }
                _ => {
                    let g = legacy.inverse(&specs[0]).unwrap();
                    std::hint::black_box(&g);
                }
            })
            .median();

            let into = time_fn(reps, || match dir {
                "fwd" => {
                    plan.forward_into(&grids[0], &mut out_spec, &mut ws).unwrap();
                    std::hint::black_box(&out_spec);
                }
                _ => {
                    plan.inverse_into(&specs[0], &mut out_grid, &mut ws).unwrap();
                    std::hint::black_box(&out_grid);
                }
            })
            .median();

            let batch = time_fn(reps, || match dir {
                "fwd" => {
                    plan.forward_batch_into(&grids, &mut batch_specs, &mut ws)
                        .unwrap();
                    std::hint::black_box(&batch_specs);
                }
                _ => {
                    plan.inverse_batch_into(&specs, &mut batch_grids, &mut ws)
                        .unwrap();
                    std::hint::black_box(&batch_grids);
                }
            })
            .median()
                / batch_n as f64;

            table.row(&[
                b.to_string(),
                dir.into(),
                fmt_seconds(alloc),
                fmt_seconds(into),
                fmt_seconds(batch),
                format!("{:.2}x", alloc / into),
            ]);
            csv.push(format!(
                "{b},{dir},{batch_n},{alloc:.4e},{into:.4e},{batch:.4e}"
            ));
        }
    }
    table.print();
    println!(
        "\n`into` removes the per-call output+workspace allocations; `batch`\n\
         additionally amortizes them across {batch_n} signals through one plan."
    );
    csv_sink(
        "micro_batch",
        "b,dir,batch_n,alloc_item_s,into_item_s,batch_item_s",
        &csv,
    );

    // ------------------------------------------------------------------
    // FFT stage: split-radix panel engine vs radix-2 baseline vs the
    // real-input path, measured through the executor's own StageStats
    // (forward analysis, sequential).
    // ------------------------------------------------------------------
    let fft_bs = env_usize_list("SO3FT_BENCH_STAGE_BS", &[16, 32, 64]);
    let mut fft_csv = Vec::new();
    println!("\n== micro: forward FFT stage (per-transform medians) ==");
    let mut fft_table = Table::new(&["B", "split-radix", "radix2 base", "real-input", "speedup"]);
    for &b in &fft_bs {
        let split = So3Plan::new(b).expect("split plan");
        let baseline = So3Plan::builder(b)
            .fft_engine(FftEngine::Radix2Baseline)
            .build()
            .expect("baseline plan");
        let real = So3Plan::builder(b).real_input().build().expect("real plan");

        let coeffs = So3Coeffs::random(b, 321);
        let grid = split.inverse(&coeffs).expect("input grid");
        let real_grid = So3Grid::from_vec(
            b,
            grid.as_slice()
                .iter()
                .map(|z| Complex64::new(z.re, 0.0))
                .collect(),
        )
        .expect("real grid");

        let mut ws = split.make_workspace();
        let mut out = So3Coeffs::zeros(b);
        let fft_median = |plan: &So3Plan, g: &So3Grid, ws: &mut _, out: &mut So3Coeffs| {
            let mut seconds = Vec::with_capacity(reps);
            plan.forward_into(g, out, ws).expect("warmup");
            for _ in 0..reps {
                let stats = plan.forward_into(g, out, ws).expect("forward");
                seconds.push(stats.fft.as_secs_f64());
            }
            Samples { seconds }.median()
        };
        let s_split = fft_median(&split, &grid, &mut ws, &mut out);
        let s_base = fft_median(&baseline, &grid, &mut ws, &mut out);
        let s_real = fft_median(&real, &real_grid, &mut ws, &mut out);
        fft_table.row(&[
            b.to_string(),
            fmt_seconds(s_split),
            fmt_seconds(s_base),
            fmt_seconds(s_real),
            format!("{:.2}x", s_base / s_split),
        ]);
        fft_csv.push(format!("{b},{s_split:.4e},{s_base:.4e},{s_real:.4e}"));
    }
    fft_table.print();
    println!(
        "\nspeedup = radix-2 gather/scatter baseline over the split-radix\n\
         panel engine; `real-input` additionally halves stage-1 butterflies."
    );
    csv_sink(
        "micro_batch_fft_stage",
        "b,split_radix_s,radix2_baseline_s,real_input_s",
        &fft_csv,
    );

    // ------------------------------------------------------------------
    // Region dispatch: persistent parked workers vs legacy scoped spawn,
    // at the executor's FFT-stage region shape (n = 2B packages). The
    // per-package body is deliberately light so dispatch — OS thread
    // spawn/join vs condvar wakeup — dominates: exactly the overhead
    // that eats small/medium-B transforms, several regions per call.
    // ------------------------------------------------------------------
    let pool_threads = env_usize("SO3FT_BENCH_POOL_THREADS", 4);
    let pool_bs = env_usize_list("SO3FT_BENCH_POOL_BS", &[8, 16, 32]);
    let pool_reps = env_usize("SO3FT_BENCH_POOL_REPS", 30);
    let pool = WorkerPool::new(pool_threads).expect("worker pool");
    println!("\n== micro: region dispatch — persistent pool vs scoped spawn ==");
    println!("({pool_threads} workers, {pool_reps} reps; per-region medians)\n");
    let mut pool_table = Table::new(&[
        "B",
        "packages",
        "scoped spawn",
        "persistent",
        "dispatch speedup",
    ]);
    let mut pool_csv = Vec::new();
    for &b in &pool_bs {
        let n = 2 * b;
        let sink: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let sink = &sink;
        let body = move |i: usize| {
            // ~100 ns of register work per package: a stand-in for a
            // small per-slice kernel at low bandwidth.
            let mut acc = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            for _ in 0..32 {
                acc = acc.rotate_left(7) ^ acc.wrapping_mul(0x2545_F491_4F6C_DD1D);
            }
            sink[i].store(acc, Ordering::Relaxed);
        };
        let scoped = time_fn(pool_reps, || {
            parallel_for(pool_threads, n, Schedule::Dynamic { chunk: 1 }, body);
        })
        .median();
        let persistent = time_fn(pool_reps, || {
            pool.run_with(pool_threads, n, Schedule::Dynamic { chunk: 1 }, body);
        })
        .median();
        pool_table.row(&[
            b.to_string(),
            n.to_string(),
            fmt_seconds(scoped),
            fmt_seconds(persistent),
            format!("{:.2}x", scoped / persistent),
        ]);
        pool_csv.push(format!(
            "{b},{n},{pool_threads},{scoped:.4e},{persistent:.4e}"
        ));
    }
    pool_table.print();
    println!(
        "\nscoped spawn forks + joins {pool_threads} OS threads per region; the\n\
         persistent pool wakes parked workers (condvar/epoch) instead."
    );
    csv_sink(
        "micro_batch_pool",
        "b,packages,threads,scoped_region_s,persistent_region_s",
        &pool_csv,
    );
}

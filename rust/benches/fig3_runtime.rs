//! Paper Fig. 3 — runtime (log scale) of the parallel FSOFT/iFSOFT vs
//! core count. Same simulation methodology as fig2 (see DESIGN.md §3);
//! single-core times are the measured (or modeled) sequential runtimes
//! on this machine.

use so3ft::bench_util::{csv_sink, env_usize, env_usize_list, fmt_seconds, Table};
use so3ft::simulator::machine::MachineParams;
use so3ft::simulator::scaling::{figure_series, paper_core_counts};

fn main() {
    let measured = env_usize_list("SO3FT_BENCH_MEASURED", &[16, 32]);
    let analytic = env_usize_list("SO3FT_BENCH_ANALYTIC", &[64, 128, 256, 512]);
    let fit_b = env_usize("SO3FT_BENCH_FIT_B", 32);
    let cores = paper_core_counts();
    let params = MachineParams::opteron_like();

    println!("== fig3: runtime vs cores (simulated Opteron-like node) ==");
    println!(
        "measured bandwidths: {measured:?}; analytic: {analytic:?} (rates fit at B={fit_b})\n"
    );
    let series = figure_series(&measured, &analytic, fit_b, &cores, &params)
        .expect("figure series");

    let mut csv = Vec::new();
    for kind_label in ["fsoft", "ifsoft"] {
        println!("--- {kind_label} ---");
        let mut headers: Vec<String> = vec!["B".into(), "src".into()];
        headers.extend(cores.iter().map(|c| format!("p={c}")));
        let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for s in series.iter().filter(|s| s.kind.label() == kind_label) {
            let mut row = vec![
                s.b.to_string(),
                if s.measured { "meas" } else { "model" }.to_string(),
            ];
            for p in &s.points {
                row.push(fmt_seconds(p.seconds));
                csv.push(format!(
                    "{kind_label},{},{},{:.6e}",
                    s.b, p.cores, p.seconds
                ));
            }
            table.row(&row);
        }
        table.print();
        println!();
    }

    // The paper's §5 headline: B=512 forward ≈ 3 min on 64 cores vs
    // 1.53 h sequential; inverse ≈ 4.3 min vs 1.74 h.
    for s in series.iter().filter(|s| s.b == 512) {
        let t1 = s.points.iter().find(|p| p.cores == 1);
        let t64 = s.points.iter().find(|p| p.cores == 64);
        if let (Some(t1), Some(t64)) = (t1, t64) {
            println!(
                "B=512 {}: sequential {} -> 64-core {}  (paper: {} -> {})",
                s.kind.label(),
                fmt_seconds(t1.seconds),
                fmt_seconds(t64.seconds),
                if s.kind.label() == "fsoft" { "1.53 h" } else { "1.74 h" },
                if s.kind.label() == "fsoft" { "~3 min" } else { "~4.3 min" },
            );
        }
    }
    csv_sink("fig3_runtime", "kind,b,cores,seconds", &csv);
}

//! Paper Fig. 2 — speedup of the parallel FSOFT (left) and iFSOFT
//! (right) vs core count, for bandwidths 32…512.
//!
//! Methodology (DESIGN.md §3 substitution): per-package costs are
//! measured on this machine by instrumented sequential runs (bandwidths
//! in `SO3FT_BENCH_MEASURED`, default "16 32"); the paper's large
//! bandwidths (`SO3FT_BENCH_ANALYTIC`, default "64 128 256 512") use
//! operation counts scaled by rates fitted at `SO3FT_BENCH_FIT_B`
//! (default 32). The discrete-event machine model then replays the
//! dynamic schedule on 1…64 virtual cores.
//!
//! The paper's published 64-core speedups are printed alongside for
//! comparison.

use so3ft::bench_util::{csv_sink, env_usize, env_usize_list, Table};
use so3ft::simulator::machine::MachineParams;
use so3ft::simulator::scaling::{figure_series, paper_core_counts, paper_speedup_64};

fn main() {
    let measured = env_usize_list("SO3FT_BENCH_MEASURED", &[16, 32]);
    let analytic = env_usize_list("SO3FT_BENCH_ANALYTIC", &[64, 128, 256, 512]);
    let fit_b = env_usize("SO3FT_BENCH_FIT_B", 32);
    let cores = paper_core_counts();
    let params = MachineParams::opteron_like();

    println!("== fig2: speedup vs cores (simulated Opteron-like node) ==");
    println!(
        "measured bandwidths: {measured:?}; analytic: {analytic:?} (rates fit at B={fit_b})\n"
    );

    let series = figure_series(&measured, &analytic, fit_b, &cores, &params)
        .expect("figure series");

    let mut csv = Vec::new();
    for kind_label in ["fsoft", "ifsoft"] {
        println!("--- {kind_label} ---");
        let mut headers: Vec<String> = vec!["B".into(), "src".into()];
        headers.extend(cores.iter().map(|c| format!("p={c}")));
        headers.push("paper p=64".into());
        let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for s in series.iter().filter(|s| s.kind.label() == kind_label) {
            let mut row = vec![
                s.b.to_string(),
                if s.measured { "meas" } else { "model" }.to_string(),
            ];
            for p in &s.points {
                row.push(format!("{:.2}", p.speedup));
                csv.push(format!(
                    "{kind_label},{},{},{:.4},{:.6}",
                    s.b, p.cores, p.speedup, p.seconds
                ));
            }
            row.push(
                paper_speedup_64(s.b, s.kind)
                    .map(|v| format!("{v:.2}"))
                    .unwrap_or_else(|| "-".into()),
            );
            table.row(&row);
        }
        table.print();
        println!();
    }
    csv_sink("fig2_speedup", "kind,b,cores,speedup,seconds", &csv);
}

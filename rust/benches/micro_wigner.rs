//! Microbench: Wigner-d machinery — row-stepper throughput (the
//! recurrence that on-the-fly DWTs pay), full-table precomputation, and
//! quadrature weights (the paper notes weight time is negligible).

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::dwt::tables::WignerTables;
use so3ft::so3::quadrature;
use so3ft::so3::sampling::GridAngles;
use so3ft::so3::wigner::WignerRowStepper;
use so3ft::xprec::Dd;

fn main() {
    let reps = env_usize("SO3FT_BENCH_REPS", 10);
    let mut csv = Vec::new();

    println!("== micro: Wigner row stepper (full column sweep) ==");
    let mut t = Table::new(&["B", "f64", "dd (extended)", "ratio"]);
    for &b in &[32usize, 64, 128] {
        let angles = GridAngles::new(b).unwrap();
        let s_f64 = time_fn(reps, || {
            let mut st: WignerRowStepper<f64> = WignerRowStepper::new(2, 1, &angles.betas);
            for _ in 2..b {
                st.advance();
            }
            std::hint::black_box(st.row()[0]);
        });
        let s_dd = time_fn(reps, || {
            let mut st: WignerRowStepper<Dd> = WignerRowStepper::new(2, 1, &angles.betas);
            for _ in 2..b {
                st.advance();
            }
            std::hint::black_box(st.row()[0].to_f64());
        });
        t.row(&[
            b.to_string(),
            fmt_seconds(s_f64.median()),
            fmt_seconds(s_dd.median()),
            format!("{:.1}x", s_dd.median() / s_f64.median()),
        ]);
        csv.push(format!(
            "stepper,{b},{:.4e},{:.4e}",
            s_f64.median(),
            s_dd.median()
        ));
    }
    t.print();

    println!("\n== micro: full table precomputation (paper's setup phase) ==");
    let mut t2 = Table::new(&["B", "build time", "memory"]);
    for &b in &[16usize, 32, 64] {
        let angles = GridAngles::new(b).unwrap();
        let s = time_fn(3.min(reps), || {
            std::hint::black_box(WignerTables::build(b, &angles.betas));
        });
        let bytes = WignerTables::storage_len(b) * 8;
        t2.row(&[
            b.to_string(),
            fmt_seconds(s.median()),
            format!("{:.1} MiB", bytes as f64 / (1 << 20) as f64),
        ]);
        csv.push(format!("tables,{b},{:.4e},{bytes}", s.median()));
    }
    t2.print();

    println!("\n== micro: quadrature weights (paper: 'negligibly short') ==");
    let mut t3 = Table::new(&["B", "time"]);
    for &b in &[64usize, 128, 256, 512] {
        let s = time_fn(reps, || {
            std::hint::black_box(quadrature::weights(b).unwrap());
        });
        t3.row(&[b.to_string(), fmt_seconds(s.median())]);
        csv.push(format!("weights,{b},{:.4e},", s.median()));
    }
    t3.print();
    csv_sink("micro_wigner", "bench,b,seconds,extra", &csv);
}

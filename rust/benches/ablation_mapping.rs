//! Ablation: the paper's geometric κ index map (Fig. 1, integer ops)
//! vs the σ map (Eq. 7/8, float sqrt) — both as a pure index-
//! reconstruction microbench and end-to-end through the transform.

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::coordinator::partition::{kappa_count, kappa_to_pair, sigma_count, sigma_to_pair};
use so3ft::coordinator::PartitionStrategy;
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn main() {
    let b = env_usize("SO3FT_BENCH_B", 512);
    let reps = env_usize("SO3FT_BENCH_REPS", 20);

    println!("== ablation: index-map reconstruction at B={b} ==");
    let mut table = Table::new(&["map", "domain size", "time/loop", "ns/index"]);
    let mut csv = Vec::new();

    let nk = kappa_count(b);
    let s_kappa = time_fn(reps, || {
        let mut acc = 0i64;
        for k in 0..nk {
            let (m, mp) = kappa_to_pair(k, b);
            acc = acc.wrapping_add(m ^ mp);
        }
        std::hint::black_box(acc);
    });
    table.row(&[
        "geometric κ".into(),
        nk.to_string(),
        fmt_seconds(s_kappa.median()),
        format!("{:.2}", s_kappa.median() / nk as f64 * 1e9),
    ]);
    csv.push(format!("kappa,{b},{:.3e}", s_kappa.median() / nk as f64));

    let ns = sigma_count(b);
    let s_sigma = time_fn(reps, || {
        let mut acc = 0i64;
        for s in 0..ns {
            let (m, mp) = sigma_to_pair(s);
            acc = acc.wrapping_add(m ^ mp);
        }
        std::hint::black_box(acc);
    });
    table.row(&[
        "σ (sqrt)".into(),
        ns.to_string(),
        fmt_seconds(s_sigma.median()),
        format!("{:.2}", s_sigma.median() / ns as f64 * 1e9),
    ]);
    csv.push(format!("sigma,{b},{:.3e}", s_sigma.median() / ns as f64));
    table.print();
    println!(
        "\nκ per-index cost / σ per-index cost = {:.2}",
        (s_kappa.median() / nk as f64) / (s_sigma.median() / ns as f64)
    );

    // End-to-end: identical work, different package order — the paper's
    // point is that κ is cheaper to reconstruct and trivially loopable.
    let be = env_usize("SO3FT_BENCH_E2E_B", 16);
    let e2e_reps = env_usize("SO3FT_BENCH_E2E_REPS", 5);
    println!("\n== ablation: end-to-end FSOFT at B={be} ==");
    let coeffs = So3Coeffs::random(be, 9);
    let mut t2 = Table::new(&["strategy", "forward median"]);
    for (name, strategy) in [
        ("geometric", PartitionStrategy::GeometricClustered),
        ("sigma", PartitionStrategy::SigmaClustered),
    ] {
        let fft = So3Plan::builder(be).allow_any_bandwidth().strategy(strategy).build().unwrap();
        let grid = fft.inverse(&coeffs).unwrap();
        let s = time_fn(e2e_reps, || {
            std::hint::black_box(fft.forward(&grid).unwrap());
        });
        t2.row(&[name.into(), fmt_seconds(s.median())]);
        csv.push(format!("e2e_{name},{be},{:.3e}", s.median()));
    }
    t2.print();
    csv_sink("ablation_mapping", "variant,b,seconds", &csv);
}

//! Paper Fig. 4 — efficiency (speedup / cores) of the parallel FSOFT and
//! iFSOFT vs core count. Same methodology as fig2.

use so3ft::bench_util::{csv_sink, env_usize, env_usize_list, Table};
use so3ft::simulator::machine::MachineParams;
use so3ft::simulator::scaling::{figure_series, paper_core_counts};

fn main() {
    let measured = env_usize_list("SO3FT_BENCH_MEASURED", &[16, 32]);
    let analytic = env_usize_list("SO3FT_BENCH_ANALYTIC", &[64, 128, 256, 512]);
    let fit_b = env_usize("SO3FT_BENCH_FIT_B", 32);
    let cores = paper_core_counts();
    let params = MachineParams::opteron_like();

    println!("== fig4: efficiency vs cores (simulated Opteron-like node) ==");
    println!(
        "measured bandwidths: {measured:?}; analytic: {analytic:?} (rates fit at B={fit_b})\n"
    );
    let series = figure_series(&measured, &analytic, fit_b, &cores, &params)
        .expect("figure series");

    let mut csv = Vec::new();
    for kind_label in ["fsoft", "ifsoft"] {
        println!("--- {kind_label} ---");
        let mut headers: Vec<String> = vec!["B".into(), "src".into()];
        headers.extend(cores.iter().map(|c| format!("p={c}")));
        let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for s in series.iter().filter(|s| s.kind.label() == kind_label) {
            let mut row = vec![
                s.b.to_string(),
                if s.measured { "meas" } else { "model" }.to_string(),
            ];
            for p in &s.points {
                row.push(format!("{:.3}", p.efficiency));
                csv.push(format!(
                    "{kind_label},{},{},{:.4}",
                    s.b, p.cores, p.efficiency
                ));
            }
            table.row(&row);
        }
        table.print();
        println!();
    }
    csv_sink("fig4_efficiency", "kind,b,cores,efficiency", &csv);
}

//! Ablation: DWT dataflow — the paper's benchmarked matvec (with
//! precomputed tables or on-the-fly rows) vs the Clenshaw dataflow the
//! paper's §5 announces as future work; plus the extended-precision
//! accumulation mode the paper used for B = 512.

use so3ft::bench_util::{csv_sink, env_usize, fmt_seconds, time_fn, Table};
use so3ft::dwt::tables::WignerStorage;
use so3ft::dwt::{DwtAlgorithm, Precision};
use so3ft::so3::coeffs::So3Coeffs;
use so3ft::transform::So3Plan;

fn main() {
    let b = env_usize("SO3FT_BENCH_B", 16);
    let reps = env_usize("SO3FT_BENCH_REPS", 5);
    println!("== ablation: DWT algorithm/storage/precision at B={b} ==");

    let coeffs = So3Coeffs::random(b, 33);
    let variants: &[(&str, DwtAlgorithm, WignerStorage, Precision)] = &[
        (
            "matvec+tables (paper)",
            DwtAlgorithm::MatVec,
            WignerStorage::Precomputed,
            Precision::Double,
        ),
        (
            "folded+tables (default)",
            DwtAlgorithm::MatVecFolded,
            WignerStorage::Precomputed,
            Precision::Double,
        ),
        (
            "matvec+onthefly",
            DwtAlgorithm::MatVec,
            WignerStorage::OnTheFly,
            Precision::Double,
        ),
        (
            "folded+onthefly",
            DwtAlgorithm::MatVecFolded,
            WignerStorage::OnTheFly,
            Precision::Double,
        ),
        (
            "clenshaw (paper §5 next)",
            DwtAlgorithm::Clenshaw,
            WignerStorage::OnTheFly,
            Precision::Double,
        ),
        (
            "matvec+tables, extended",
            DwtAlgorithm::MatVec,
            WignerStorage::Precomputed,
            Precision::Extended,
        ),
    ];
    let mut table = Table::new(&["variant", "table mem", "forward", "inverse", "rt err"]);
    let mut csv = Vec::new();
    for &(name, algorithm, storage, precision) in variants {
        let fft = So3Plan::builder(b)
            .allow_any_bandwidth()
            .algorithm(algorithm)
            .storage(storage)
            .precision(precision)
            .build()
            .unwrap();
        let grid = fft.inverse(&coeffs).unwrap();
        let back = fft.forward(&grid).unwrap();
        let err = coeffs.max_abs_error(&back);
        let fs = time_fn(reps, || {
            std::hint::black_box(fft.forward(&grid).unwrap());
        });
        let is = time_fn(reps, || {
            std::hint::black_box(fft.inverse(&coeffs).unwrap());
        });
        let mem = fft.executor().table_bytes();
        table.row(&[
            name.into(),
            if mem == 0 {
                "-".into()
            } else {
                format!("{:.1} MiB", mem as f64 / (1 << 20) as f64)
            },
            fmt_seconds(fs.median()),
            fmt_seconds(is.median()),
            format!("{err:.1e}"),
        ]);
        csv.push(format!(
            "{name},{b},{mem},{:.4e},{:.4e},{err:.3e}",
            fs.median(),
            is.median()
        ));
    }
    table.print();
    csv_sink(
        "ablation_dwt_algo",
        "variant,b,table_bytes,fwd_s,inv_s,rt_err",
        &csv,
    );
}

//! Deterministic schedule explorer for the crate's concurrent state
//! machines (compiled only under the `sched-test` feature).
//!
//! The racy protocols in this crate — registry single-flight builds,
//! admission caps, the dispatcher's batch drain, the watchdog restart,
//! the worker-pool epoch/park handshake, drain-with-deadline shutdown —
//! are instrumented with named yield points via the
//! [`sched_point!`](crate::sched_point) macro. Without the `sched-test`
//! feature the macro expands to nothing; with it, each point calls
//! [`point`], which hands control to an installed [`Controller`].
//!
//! # How interleavings are explored
//!
//! The controller *serializes* instrumented threads: a thread reaching a
//! yield point parks until the controller grants it the right to
//! continue, and the controller grants one thread at a time, chosen by a
//! seeded PRNG (random sweeps / replay) or by a choice script (bounded
//! DFS). The sequence of grants — the *schedule* — is recorded as a
//! trace and printed alongside the seed whenever a scenario fails, so
//! every failure is replayable with [`Explorer::replay`].
//!
//! Instrumented code also blocks on *real* mutexes and condvars between
//! yield points, which the controller cannot see. To stay live when the
//! granted thread blocks invisibly (or finishes), parked threads wait
//! with a grace timeout and then force a grant; forced grants are marked
//! in the trace. This keeps exploration sound (it only ever *adds*
//! schedules the OS scheduler could produce) at the cost of exhaustive-
//! ness — which bounded DFS over the choice script recovers up to its
//! depth bound.
//!
//! # Typical use
//!
//! ```ignore
//! let explorer = Explorer::default();
//! explorer.sweep(0..64, || {
//!     // spawn threads that hit sched_point!(...) sites, join them,
//!     // then return Err(reason) if an invariant broke.
//!     Ok(())
//! });
//! // On failure: panics, printing `seed=0x...` and the full schedule.
//! // Reproduce with: explorer.replay(0x..., scenario)
//! ```

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::ThreadId;
use std::time::Duration;

use crate::prng::Xoshiro256;
use crate::util::lock_unpoisoned;

/// Fast-path gate checked by [`point`] before touching any lock, so an
/// instrumented binary with no controller installed pays one relaxed
/// load per yield point.
static ACTIVE: AtomicBool = AtomicBool::new(false);

/// The installed controller, if any. `OnceLock<Mutex<...>>` rather than
/// a `static Mutex` keeps the initializer const-free on MSRV 1.75.
static CONTROLLER: OnceLock<Mutex<Option<Arc<Inner>>>> = OnceLock::new();

fn controller_slot() -> &'static Mutex<Option<Arc<Inner>>> {
    CONTROLLER.get_or_init(|| Mutex::new(None))
}

/// How the controller picks the next thread to release.
enum Chooser {
    /// Seeded PRNG — random sweeps and seed replay.
    Random(Xoshiro256),
    /// Scripted prefix (bounded DFS): `script[step]` indexes into the
    /// parked set at that step; past the end, fall back to the PRNG.
    Script { script: Vec<usize>, rng: Xoshiro256 },
}

/// One grant in a recorded schedule.
#[derive(Clone, Debug)]
pub struct TraceStep {
    /// Name of the thread that was released (its `std::thread` name, or
    /// `"?"` for unnamed threads).
    pub thread: String,
    /// The yield point it was parked at.
    pub point: &'static str,
    /// Index chosen among the parked candidates at this step.
    pub chosen: usize,
    /// Number of parked candidates the chooser picked from.
    pub arity: usize,
    /// True when the grant was forced by the grace timeout (a running
    /// thread was blocked on a real lock or had finished).
    pub forced: bool,
}

struct Parked {
    id: ThreadId,
    name: String,
    point: &'static str,
    granted: bool,
}

struct State {
    /// Threads currently parked at a yield point, in arrival order
    /// (arrival order is itself schedule-dependent, which is fine: the
    /// seed still pins the schedule given a deterministic scenario).
    parked: Vec<Parked>,
    /// Registered threads believed to be running between yield points.
    running: usize,
    chooser: Chooser,
    trace: Vec<TraceStep>,
    /// Grants already issued; once `max_steps` is reached the controller
    /// stops serializing and releases everyone immediately.
    exhausted: bool,
    max_steps: usize,
    seen: HashSet<ThreadId>,
}

struct Inner {
    state: Mutex<State>,
    cv: Condvar,
    grace: Duration,
}

impl Inner {
    /// Release one parked thread if nothing (visible) is running, or if
    /// `forced`. Returns true if a grant was issued.
    fn try_grant(&self, st: &mut State, forced: bool) -> bool {
        if st.parked.iter().any(|p| p.granted) {
            return false; // a grant is already in flight
        }
        if st.parked.is_empty() || (!forced && st.running > 0) {
            return false;
        }
        let arity = st.parked.len();
        let chosen = match &mut st.chooser {
            Chooser::Random(rng) => rng.next_below(arity as u64) as usize,
            Chooser::Script { script, rng } => {
                let step = st.trace.len();
                match script.get(step) {
                    Some(&i) => i.min(arity - 1),
                    None => rng.next_below(arity as u64) as usize,
                }
            }
        };
        let p = &mut st.parked[chosen];
        p.granted = true;
        st.trace.push(TraceStep {
            thread: p.name.clone(),
            point: p.point,
            chosen,
            arity,
            forced,
        });
        if st.trace.len() >= st.max_steps {
            st.exhausted = true;
        }
        true
    }

    fn point(&self, name: &'static str) {
        let me = std::thread::current();
        let id = me.id();
        let thread_name = me.name().unwrap_or("?").to_string();
        let mut st = lock_unpoisoned(&self.state);
        if st.exhausted {
            return;
        }
        // First contact leaves `running` alone: until now this thread
        // was invisible and never counted as running.
        if !st.seen.insert(id) {
            st.running = st.running.saturating_sub(1);
        }
        st.parked.push(Parked {
            id,
            name: thread_name,
            point: name,
            granted: false,
        });
        // No grant yet: hold an *arrival window* (grace/4) first, so
        // threads racing toward their own yield points make it into the
        // parked set before a choice is made — otherwise a lone early
        // arrival would always be granted at arity 1 and the chooser
        // would never see the race it exists to explore.
        let mut arrival_window = true;
        loop {
            if st.exhausted {
                // Tear-down or step budget hit: stop serializing.
                if let Some(i) = st.parked.iter().position(|p| p.id == id) {
                    st.parked.remove(i);
                }
                st.running += 1;
                self.cv.notify_all();
                return;
            }
            if let Some(i) = st.parked.iter().position(|p| p.id == id) {
                if st.parked[i].granted {
                    st.parked.remove(i);
                    st.running += 1;
                    // Our grant is consumed; the next grant waits until
                    // we park again or the grace timer fires.
                    self.cv.notify_all();
                    return;
                }
            } else {
                // Should not happen (only we remove our own entry), but
                // never spin-park on a missing entry.
                st.running += 1;
                return;
            }
            let window = if arrival_window {
                self.grace / 4
            } else {
                self.grace
            };
            let (guard, timeout) = self
                .cv
                .wait_timeout(st, window)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
            if timeout.timed_out() {
                if arrival_window {
                    // Arrival window over: serialize normally (grant
                    // only when nothing visible is running).
                    arrival_window = false;
                    if self.try_grant(&mut st, false) {
                        self.cv.notify_all();
                    }
                } else if self.try_grant(&mut st, true) {
                    // Liveness fallback: whatever is nominally running
                    // is blocked on a real lock (or exited without a
                    // further yield point). Force a grant so the
                    // schedule advances.
                    self.cv.notify_all();
                }
            }
        }
    }

    /// Stop serializing and wake every parked thread (tear-down).
    fn release_all(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.exhausted = true;
        self.cv.notify_all();
    }
}

/// Yield-point hook called by [`sched_point!`](crate::sched_point); a
/// no-op unless a [`Controller`] is installed.
pub fn point(name: &'static str) {
    // ordering: Acquire — pairs with the Release store in
    // `Controller::install`: seeing `true` guarantees the slot's
    // `Some(inner)` write (published under the slot mutex anyway) is
    // observed; the flag exists only to keep the uninstrumented path to
    // a single load.
    if !ACTIVE.load(Ordering::Acquire) {
        return;
    }
    let inner = { lock_unpoisoned(controller_slot()).clone() };
    if let Some(inner) = inner {
        inner.point(name);
    }
}

/// An installed schedule controller. Dropping it uninstalls the
/// controller and releases every parked thread.
///
/// Only one controller can be installed at a time; tests sharing a
/// process must serialize (see `rust/tests/sched_explorer.rs`).
pub struct Controller {
    inner: Arc<Inner>,
}

impl Controller {
    /// Install a controller choosing schedules with the given `seed`
    /// (script empty) or scripted prefix.
    fn install(seed: u64, script: Vec<usize>, grace: Duration, max_steps: usize) -> Controller {
        let rng = Xoshiro256::seed_from_u64(seed);
        let chooser = if script.is_empty() {
            Chooser::Random(rng)
        } else {
            Chooser::Script { script, rng }
        };
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                parked: Vec::new(),
                running: 0,
                chooser,
                trace: Vec::new(),
                exhausted: false,
                max_steps,
                seen: HashSet::new(),
            }),
            cv: Condvar::new(),
            grace,
        });
        {
            let mut slot = lock_unpoisoned(controller_slot());
            assert!(slot.is_none(), "a schedule controller is already installed");
            *slot = Some(Arc::clone(&inner));
        }
        // ordering: Release — pairs with the Acquire load in `point`
        // (see there); stored after the slot is populated so a reader
        // that sees `true` finds the controller.
        ACTIVE.store(true, Ordering::Release);
        Controller { inner }
    }

    /// The schedule recorded so far.
    pub fn trace(&self) -> Vec<TraceStep> {
        lock_unpoisoned(&self.inner.state).trace.clone()
    }
}

impl Drop for Controller {
    fn drop(&mut self) {
        // ordering: Release — flips the `point` fast path off before the
        // slot is cleared; stragglers that already loaded `true` still
        // find the slot (cleared under its mutex) or a released inner.
        ACTIVE.store(false, Ordering::Release);
        {
            let mut slot = lock_unpoisoned(controller_slot());
            *slot = None;
        }
        self.inner.release_all();
    }
}

/// Render a schedule the way failure reports print it.
pub fn format_trace(trace: &[TraceStep]) -> String {
    let mut out = String::new();
    for (i, s) in trace.iter().enumerate() {
        out.push_str(&format!(
            "  step {i:3}: {thread} @ {point} (choice {chosen}/{arity}{forced})\n",
            thread = s.thread,
            point = s.point,
            chosen = s.chosen,
            arity = s.arity,
            forced = if s.forced { ", forced" } else { "" },
        ));
    }
    out
}

/// Result of one explored schedule.
pub struct RunReport {
    /// Seed the chooser was installed with.
    pub seed: u64,
    /// The schedule that was executed.
    pub trace: Vec<TraceStep>,
    /// `Err(reason)` when the scenario reported a violated invariant (or
    /// panicked — the panic message becomes the reason).
    pub outcome: Result<(), String>,
}

/// Sweeps seeds, replays pinned seeds, and enumerates scripted prefixes
/// (bounded DFS) over a scenario instrumented with yield points.
pub struct Explorer {
    /// Grace window before a parked thread forces a grant past a thread
    /// that is blocked outside the controller's view.
    pub grace: Duration,
    /// Hard cap on grants per run; past it the controller stops
    /// serializing (the scenario still runs to completion).
    pub max_steps: usize,
}

impl Default for Explorer {
    fn default() -> Self {
        Explorer {
            grace: Duration::from_millis(2),
            max_steps: 2_000,
        }
    }
}

impl Explorer {
    /// Run `scenario` once under the given seed (and optional script),
    /// returning the recorded schedule and outcome.
    fn run_once(
        &self,
        seed: u64,
        script: Vec<usize>,
        scenario: &mut dyn FnMut() -> Result<(), String>,
    ) -> RunReport {
        let controller = Controller::install(seed, script, self.grace, self.max_steps);
        let outcome = match catch_unwind(AssertUnwindSafe(&mut *scenario)) {
            Ok(r) => r,
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "scenario panicked".into());
                Err(format!("panic: {msg}"))
            }
        };
        let trace = controller.trace();
        drop(controller);
        RunReport { seed, trace, outcome }
    }

    /// Panic with a replayable report if `report` failed.
    fn check(report: RunReport) {
        if let Err(reason) = &report.outcome {
            panic!(
                "schedule violation under seed=0x{seed:016x}: {reason}\n\
                 schedule ({n} steps):\n{trace}\
                 replay with Explorer::replay(0x{seed:016x}, ..)",
                seed = report.seed,
                n = report.trace.len(),
                trace = format_trace(&report.trace),
            );
        }
    }

    /// Run `scenario` once per seed; on the first failing seed, panic
    /// with the seed and the printed schedule.
    pub fn sweep(
        &self,
        seeds: impl IntoIterator<Item = u64>,
        mut scenario: impl FnMut() -> Result<(), String>,
    ) {
        for seed in seeds {
            Self::check(self.run_once(seed, Vec::new(), &mut scenario));
        }
    }

    /// Deterministically re-run the schedule a failing sweep printed.
    pub fn replay(&self, seed: u64, mut scenario: impl FnMut() -> Result<(), String>) {
        Self::check(self.run_once(seed, Vec::new(), &mut scenario));
    }

    /// Bounded DFS: systematically enumerate every choice prefix up to
    /// `depth` grants (deeper grants fall back to the seed's PRNG).
    /// Returns the number of schedules explored; panics with a printed
    /// schedule on the first failure.
    pub fn dfs(
        &self,
        depth: usize,
        seed: u64,
        mut scenario: impl FnMut() -> Result<(), String>,
    ) -> usize {
        let mut stack: Vec<Vec<usize>> = vec![Vec::new()];
        let mut explored = 0usize;
        while let Some(script) = stack.pop() {
            let from = script.len();
            let report = self.run_once(seed, script, &mut scenario);
            explored += 1;
            // Expand: at every step past the scripted prefix (up to the
            // depth bound), branch into each untaken alternative. The
            // prefix replayed to reach that step is the *chosen* indices
            // recorded in this run's trace.
            for (step, t) in report.trace.iter().enumerate().take(depth).skip(from) {
                for alt in 0..t.arity {
                    if alt == t.chosen {
                        continue;
                    }
                    let mut next: Vec<usize> =
                        report.trace[..step].iter().map(|s| s.chosen).collect();
                    next.push(alt);
                    stack.push(next);
                }
            }
            Self::check(report);
        }
        explored
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Lib tests in this module share the process-global controller
    /// slot, so they serialize on this lock.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static SERIAL: Mutex<()> = Mutex::new(());
        SERIAL.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The explorer's own smoke test: two threads racing through a
    /// yield point are driven into *both* interleavings across a band
    /// of seeds — the chooser genuinely explores, it does not just
    /// rubber-stamp arrival order.
    #[test]
    fn seeds_explore_both_interleavings() {
        let _guard = serial();
        let explorer = Explorer::default();
        let order = |seed: u64| -> Vec<String> {
            let controller = Controller::install(seed, Vec::new(), explorer.grace, 100);
            let log = Arc::new(Mutex::new(Vec::new()));
            let mut handles = Vec::new();
            for name in ["racer-a", "racer-b"] {
                let log = Arc::clone(&log);
                handles.push(
                    std::thread::Builder::new()
                        .name(name.into())
                        .spawn(move || {
                            crate::sched_point!("test.step");
                            lock_unpoisoned(&log).push(name.to_string());
                            crate::sched_point!("test.step");
                        })
                        .unwrap(),
                );
            }
            for h in handles {
                h.join().unwrap();
            }
            drop(controller);
            lock_unpoisoned(&log).clone()
        };
        let mut seen = HashSet::new();
        for seed in 0..32 {
            let o = order(seed);
            assert_eq!(o.len(), 2, "both racers log exactly once");
            seen.insert(o);
        }
        assert!(seen.len() >= 2, "the chooser explores both orders");
    }

    #[test]
    fn sweep_reports_failing_seed_and_schedule() {
        let _guard = serial();
        let explorer = Explorer { grace: Duration::from_millis(1), max_steps: 50 };
        let hits = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            explorer.sweep(0..3, || {
                // ordering: Relaxed — test tally, single thread.
                if hits.fetch_add(1, Ordering::Relaxed) == 1 {
                    Err("invariant broken".into())
                } else {
                    Ok(())
                }
            });
        }));
        let msg = match result {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .expect("panic carries a String report"),
            Ok(()) => panic!("sweep must fail on the failing seed"),
        };
        assert!(msg.contains("seed=0x"), "report names the seed: {msg}");
        assert!(msg.contains("replay with"), "report tells how to replay");
    }
}

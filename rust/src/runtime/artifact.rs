//! Artifact discovery: which bandwidths have AOT-compiled DWT graphs on
//! disk, and where.
//!
//! Naming convention (see `python/compile/aot.py`):
//! `artifacts/dwt_fwd_b{B}.hlo.txt` and `artifacts/dwt_inv_b{B}.hlo.txt`,
//! plus a `manifest.json` (informational; discovery is convention-based
//! so the registry works even without it).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_DIR: &str = "artifacts";

/// Paths for one bandwidth's artifact pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactPair {
    /// Bandwidth the artifacts were compiled for.
    pub b: usize,
    /// Path of the forward-DWT HLO artifact.
    pub forward: PathBuf,
    /// Path of the inverse-DWT HLO artifact.
    pub inverse: PathBuf,
}

/// Registry over an artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
}

impl ArtifactRegistry {
    /// Registry rooted at `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    /// Registry over the default `artifacts/` directory (or the
    /// `SO3FT_ARTIFACTS` environment override).
    pub fn default_location() -> Self {
        let dir = std::env::var("SO3FT_ARTIFACTS").unwrap_or_else(|_| DEFAULT_DIR.to_string());
        Self::new(dir)
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Expected paths for bandwidth `b` (no existence check).
    pub fn pair_paths(&self, b: usize) -> ArtifactPair {
        ArtifactPair {
            b,
            forward: self.dir.join(format!("dwt_fwd_b{b}.hlo.txt")),
            inverse: self.dir.join(format!("dwt_inv_b{b}.hlo.txt")),
        }
    }

    /// Paths for bandwidth `b`, verifying both files exist.
    pub fn resolve(&self, b: usize) -> Result<ArtifactPair> {
        let pair = self.pair_paths(b);
        for p in [&pair.forward, &pair.inverse] {
            if !p.exists() {
                return Err(Error::MissingArtifact {
                    b,
                    path: p.display().to_string(),
                });
            }
        }
        Ok(pair)
    }

    /// Bandwidths with a complete artifact pair on disk, ascending.
    pub fn available(&self) -> Vec<usize> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return out,
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name
                .strip_prefix("dwt_fwd_b")
                .and_then(|r| r.strip_suffix(".hlo.txt"))
            {
                if let Ok(b) = rest.parse::<usize>() {
                    if self.pair_paths(b).inverse.exists() {
                        out.push(b);
                    }
                }
            }
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("so3ft-artifacts-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn discovery_finds_complete_pairs_only() {
        let d = tmpdir("disc");
        std::fs::write(d.join("dwt_fwd_b4.hlo.txt"), "x").unwrap();
        std::fs::write(d.join("dwt_inv_b4.hlo.txt"), "x").unwrap();
        std::fs::write(d.join("dwt_fwd_b8.hlo.txt"), "x").unwrap(); // no inverse
        std::fs::write(d.join("dwt_fwd_b16.hlo.txt"), "x").unwrap();
        std::fs::write(d.join("dwt_inv_b16.hlo.txt"), "x").unwrap();
        let reg = ArtifactRegistry::new(&d);
        assert_eq!(reg.available(), vec![4, 16]);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn resolve_reports_missing() {
        let d = tmpdir("miss");
        let reg = ArtifactRegistry::new(&d);
        let err = reg.resolve(4).unwrap_err();
        assert!(matches!(err, Error::MissingArtifact { b: 4, .. }));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn empty_dir_yields_no_bandwidths() {
        let reg = ArtifactRegistry::new("/nonexistent-so3ft-path");
        assert!(reg.available().is_empty());
    }

    #[test]
    fn naming_convention() {
        let reg = ArtifactRegistry::new("a");
        let p = reg.pair_paths(32);
        assert_eq!(p.forward, PathBuf::from("a/dwt_fwd_b32.hlo.txt"));
        assert_eq!(p.inverse, PathBuf::from("a/dwt_inv_b32.hlo.txt"));
    }
}

//! The XLA/PJRT DWT backend.
//!
//! Loads the per-bandwidth HLO-text artifact pair, compiles both on a
//! PJRT CPU client, and implements [`DwtOffload`]: the coordinator hands
//! over packed base Wigner rows and member vectors, this backend runs
//! the compiled Pallas-kernel graph and returns the contraction.
//!
//! Threading: PJRT wrapper types hold raw pointers without `Send`/`Sync`
//! markers, so the whole backend state lives behind one mutex — offload
//! calls serialize. This is deliberate: the artifact executes the whole
//! cluster contraction in one call, so the lock is held for package-sized
//! work, and the native path remains the default for thread-scaling
//! benchmarks (the offload path demonstrates the AOT architecture and is
//! validated for bit-level agreement in `tests/xla_backend.rs`).
//!
//! Feature gating: the `xla` bindings crate is not available in offline
//! builds, so the compiled-executable path is behind the `xla` cargo
//! feature. Without it, [`XlaDwt::load`] still resolves artifacts (so
//! missing-artifact handling is identical) but then reports a typed
//! [`Error::Runtime`] instead of compiling — the native DWT paths are
//! unaffected.

use std::path::Path;

use crate::coordinator::exec::DwtOffload;
use crate::error::{Error, Result};
use crate::fft::Complex64;
use crate::runtime::artifact::ArtifactRegistry;

/// Padded member-axis size (must match `python/compile/model.py`).
pub const MEMBER_PAD: usize = 8;

#[cfg(feature = "xla")]
mod backend {
    use std::sync::Mutex;

    use super::*;

    pub(super) struct Inner {
        #[allow(dead_code)]
        pub(super) client: xla::PjRtClient,
        pub(super) forward: xla::PjRtLoadedExecutable,
        pub(super) inverse: xla::PjRtLoadedExecutable,
    }

    // SAFETY: `Inner` is only touched under the XlaDwt mutex; the PJRT CPU
    // client itself is thread-safe, the wrapper just lacks the marker.
    unsafe impl Send for Inner {}

    /// Compiled DWT artifacts for one bandwidth.
    pub struct XlaDwt {
        pub(super) b: usize,
        pub(super) inner: Mutex<Inner>,
    }

    pub(super) fn xerr(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }

    impl XlaDwt {
        /// Load and compile the artifact pair for bandwidth `b` from `dir`.
        pub fn load(dir: impl AsRef<Path>, b: usize) -> Result<Self> {
            let registry = ArtifactRegistry::new(dir.as_ref());
            let pair = registry.resolve(b)?;
            let client = xla::PjRtClient::cpu().map_err(xerr)?;
            let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str()
                        .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
                )
                .map_err(xerr)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client.compile(&comp).map_err(xerr)
            };
            let forward = compile(&pair.forward)?;
            let inverse = compile(&pair.inverse)?;
            Ok(Self {
                b,
                inner: Mutex::new(Inner {
                    client,
                    forward,
                    inverse,
                }),
            })
        }

        /// f64 literal of shape `dims` from a padded copy of `data`.
        pub(super) fn literal(data: &[f64], dims: &[usize]) -> Result<xla::Literal> {
            let len: usize = dims.iter().product();
            debug_assert_eq!(data.len(), len);
            // SAFETY: viewing `len` f64s as `len * 8` bytes; the source
            // slice outlives the view (same scope), u8 has no alignment
            // requirement, and every byte of an f64 is initialized.
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, len * 8)
            };
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F64,
                dims,
                bytes,
            )
            .map_err(xerr)?)
        }

        /// Run one compiled contraction; returns the two output planes.
        pub(super) fn run(
            exe: &xla::PjRtLoadedExecutable,
            args: &[xla::Literal],
            out_len: usize,
        ) -> Result<(Vec<f64>, Vec<f64>)> {
            let result = exe.execute::<xla::Literal>(args).map_err(xerr)?;
            let lit = result[0][0].to_literal_sync().map_err(xerr)?;
            let (re_lit, im_lit) = lit.to_tuple2().map_err(xerr)?;
            let re = re_lit.to_vec::<f64>().map_err(xerr)?;
            let im = im_lit.to_vec::<f64>().map_err(xerr)?;
            if re.len() != out_len || im.len() != out_len {
                return Err(Error::Runtime(format!(
                    "artifact output length {} (want {out_len})",
                    re.len()
                )));
            }
            Ok((re, im))
        }
    }
}

#[cfg(not(feature = "xla"))]
mod backend {
    use super::*;

    /// Stub backend: artifact discovery works, compilation is unavailable.
    pub struct XlaDwt {
        pub(super) b: usize,
    }

    impl XlaDwt {
        /// Resolve the artifact pair for bandwidth `b` from `dir`, then
        /// report that the compiled path is unavailable in this build.
        ///
        /// Missing artifacts still surface as [`Error::MissingArtifact`],
        /// so callers (and tests) see the same discovery behavior as the
        /// real backend; present-but-uncompilable artifacts surface as a
        /// typed [`Error::Runtime`].
        pub fn load(dir: impl AsRef<Path>, b: usize) -> Result<Self> {
            let registry = ArtifactRegistry::new(dir.as_ref());
            let _pair = registry.resolve(b)?;
            Err(Error::Runtime(
                "so3ft was built without the `xla` feature; enabling it \
                 requires the PJRT `xla` bindings crate as a dependency \
                 (see rust/Cargo.toml — not available in offline builds)"
                    .into(),
            ))
        }
    }
}

pub use backend::XlaDwt;

impl XlaDwt {
    /// Load from the default artifact location.
    pub fn load_default(b: usize) -> Result<Self> {
        let reg = ArtifactRegistry::default_location();
        Self::load(reg.dir(), b)
    }

    /// Bandwidth the loaded executables were compiled for.
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Split interleaved complex members into padded re/im planes.
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    fn split_planes(t: &[Complex64], nm: usize, width: usize) -> (Vec<f64>, Vec<f64>) {
        let mut re = vec![0.0f64; MEMBER_PAD * width];
        let mut im = vec![0.0f64; MEMBER_PAD * width];
        for mi in 0..nm {
            for k in 0..width {
                let z = t[mi * width + k];
                re[mi * width + k] = z.re;
                im[mi * width + k] = z.im;
            }
        }
        (re, im)
    }

    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    fn check_dims(&self, b: usize, nl: usize, nm: usize) -> Result<()> {
        if b != self.b {
            return Err(Error::Runtime(format!(
                "bandwidth mismatch: executor b={b}, artifact b={}",
                self.b
            )));
        }
        if nl > b || nm > MEMBER_PAD {
            return Err(Error::Runtime(format!(
                "cluster dims out of range: nl={nl} (<= {b}), nm={nm} (<= {MEMBER_PAD})"
            )));
        }
        Ok(())
    }

    /// Pad `nl` rows of length `2b` into the fixed [b, 2b] plane.
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    fn pad_rows(&self, rows: &[f64], nl: usize) -> Vec<f64> {
        let n = 2 * self.b;
        let mut d = vec![0.0f64; self.b * n];
        d[..nl * n].copy_from_slice(&rows[..nl * n]);
        d
    }
}

#[cfg(feature = "xla")]
impl DwtOffload for XlaDwt {
    fn contract_forward(
        &self,
        b: usize,
        nl: usize,
        nm: usize,
        rows: &[f64],
        t: &[Complex64],
    ) -> Result<Vec<Complex64>> {
        self.check_dims(b, nl, nm)?;
        let n = 2 * b;
        let d = self.pad_rows(rows, nl);
        let (t_re, t_im) = Self::split_planes(t, nm, n);
        let inner = self.inner.lock().expect("xla backend poisoned");
        let args = [
            Self::literal(&d, &[b, n])?,
            Self::literal(&t_re, &[MEMBER_PAD, n])?,
            Self::literal(&t_im, &[MEMBER_PAD, n])?,
        ];
        let (re, im) = Self::run(&inner.forward, &args, MEMBER_PAD * b)?;
        // Repack [MEMBER_PAD, b] → [nm, nl].
        let mut out = vec![Complex64::zero(); nm * nl];
        for mi in 0..nm {
            for li in 0..nl {
                out[mi * nl + li] = Complex64::new(re[mi * b + li], im[mi * b + li]);
            }
        }
        Ok(out)
    }

    fn contract_inverse(
        &self,
        b: usize,
        nl: usize,
        nm: usize,
        rows: &[f64],
        chat: &[Complex64],
    ) -> Result<Vec<Complex64>> {
        self.check_dims(b, nl, nm)?;
        let n = 2 * b;
        let d = self.pad_rows(rows, nl);
        // chat is [nm, nl]; pad to [MEMBER_PAD, b].
        let mut c_re = vec![0.0f64; MEMBER_PAD * b];
        let mut c_im = vec![0.0f64; MEMBER_PAD * b];
        for mi in 0..nm {
            for li in 0..nl {
                let z = chat[mi * nl + li];
                c_re[mi * b + li] = z.re;
                c_im[mi * b + li] = z.im;
            }
        }
        let inner = self.inner.lock().expect("xla backend poisoned");
        let args = [
            Self::literal(&d, &[b, n])?,
            Self::literal(&c_re, &[MEMBER_PAD, b])?,
            Self::literal(&c_im, &[MEMBER_PAD, b])?,
        ];
        let (re, im) = Self::run(&inner.inverse, &args, MEMBER_PAD * n)?;
        // Repack [MEMBER_PAD, 2b] → [nm, 2b].
        let mut out = vec![Complex64::zero(); nm * n];
        for mi in 0..nm {
            for j in 0..n {
                out[mi * n + j] = Complex64::new(re[mi * n + j], im[mi * n + j]);
            }
        }
        Ok(out)
    }
}

#[cfg(not(feature = "xla"))]
impl DwtOffload for XlaDwt {
    fn contract_forward(
        &self,
        _b: usize,
        _nl: usize,
        _nm: usize,
        _rows: &[f64],
        _t: &[Complex64],
    ) -> Result<Vec<Complex64>> {
        Err(Error::Runtime(
            "PJRT backend unavailable: built without the `xla` feature".into(),
        ))
    }

    fn contract_inverse(
        &self,
        _b: usize,
        _nl: usize,
        _nm: usize,
        _rows: &[f64],
        _chat: &[Complex64],
    ) -> Result<Vec<Complex64>> {
        Err(Error::Runtime(
            "PJRT backend unavailable: built without the `xla` feature".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn member_pad_matches_python_model() {
        // python/compile/model.py: MEMBER_PAD = 8 (max symmetry cluster).
        assert_eq!(MEMBER_PAD, 8);
    }

    #[test]
    fn load_missing_artifacts_is_clean_error() {
        match XlaDwt::load("/nonexistent-so3ft", 4) {
            Err(Error::MissingArtifact { b: 4, .. }) => {}
            Err(e) => panic!("wrong error: {e}"),
            Ok(_) => panic!("load should fail without artifacts"),
        }
    }
}

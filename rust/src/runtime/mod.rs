//! PJRT runtime: load the AOT-compiled DWT artifacts and expose them as a
//! [`crate::coordinator::exec::DwtOffload`] backend.
//!
//! Build-time python (`python/compile/aot.py`) lowers the L2 JAX graphs
//! (wrapping the L1 Pallas kernels) to **HLO text**; this module loads a
//! per-bandwidth pair of artifacts, compiles them once on the PJRT CPU
//! client, and serves cluster contractions from the rust hot path.
//! Python is never on the request path.
//!
//! * [`artifact`] — artifact discovery and file naming conventions.
//! * [`xla_dwt`] — the compiled-executable backend.

pub mod artifact;
pub mod xla_dwt;

pub use artifact::ArtifactRegistry;
pub use xla_dwt::XlaDwt;

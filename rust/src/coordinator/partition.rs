//! Index maps over the order domain — the paper's *mapping* phase.
//!
//! The DWT work items live on the triangle m ≥ m' ≥ 0 (one symmetry
//! cluster per base pair). To hand them to workers through a single
//! linear loop index, the paper considers two bijections:
//!
//! * **σ map** (Eq. 7/8): `σ = m(m+1)/2 + m'` over the full triangle.
//!   Inversion needs a floating-point square root per package.
//! * **geometric κ map** (Fig. 1): the strict sub-triangle
//!   {m = 2…B−1, m' = 1…m−1} is cut at m = ⌈(B+1)/2⌉ and the lower part
//!   re-packed into a ⌊(B−1)/2⌋ × (B−1) rectangle, so κ inverts with one
//!   integer division, one modulus and a conditional. The special cases
//!   (m' = 0, m = m', and (0,0)) are "treated in advance" as a prologue.
//!
//! Both maps are exercised by the transforms (config-selectable) and
//! compared in `benches/ablation_mapping.rs`.

/// Total σ range for bandwidth b: the triangle m ≥ m' ≥ 0 has
/// B(B+1)/2 cells.
#[inline]
pub fn sigma_count(b: usize) -> usize {
    b * (b + 1) / 2
}

/// σ = m(m+1)/2 + m' (paper Eq. 7).
#[inline]
pub fn pair_to_sigma(m: i64, mp: i64) -> usize {
    debug_assert!(m >= mp && mp >= 0);
    (m * (m + 1) / 2 + mp) as usize
}

/// Invert σ with the paper's Eq. 8 — floating-point sqrt required.
#[inline]
pub fn sigma_to_pair(sigma: usize) -> (i64, i64) {
    let m = ((2.0 * sigma as f64 + 0.25).sqrt() - 0.5).floor() as i64;
    let mp = sigma as i64 - m * (m + 1) / 2;
    (m, mp)
}

/// Number of κ cells: the strict triangle has (B−1)(B−2)/2 cells.
#[inline]
pub fn kappa_count(b: usize) -> usize {
    if b < 3 {
        0
    } else {
        (b - 1) * (b - 2) / 2
    }
}

/// Invert κ via the geometric map (paper Fig. 1): integer ops only.
///
/// κ = (i−1)(B−1) + (j−1) with i = 1…⌊(B−1)/2⌋, j = 1…B−1, and
/// `m = B−i, m' = B−j` when j > i (upper part), `m = i+1, m' = j`
/// otherwise (lower part). For odd B the final row is only half used;
/// the κ range cap guarantees those cells are never requested.
#[inline]
pub fn kappa_to_pair(kappa: usize, b: usize) -> (i64, i64) {
    debug_assert!(kappa < kappa_count(b));
    let bm1 = b - 1;
    let i = (kappa / bm1 + 1) as i64;
    let j = (kappa % bm1 + 1) as i64;
    let bb = b as i64;
    if j > i {
        (bb - i, bb - j)
    } else {
        (i + 1, j)
    }
}

/// Forward κ map (inverse of [`kappa_to_pair`]); used by tests and by
/// the plan builder's bijectivity assertions.
#[inline]
pub fn pair_to_kappa(m: i64, mp: i64, b: usize) -> usize {
    debug_assert!(m > mp && mp > 0, "κ domain is the strict triangle");
    let half = ((b - 1) / 2) as i64;
    let (i, j) = if m - 1 <= half {
        // Lower part, stored at (i, j) = (m−1, m') with j ≤ i.
        (m - 1, mp)
    } else {
        // Upper part, mirrored: (i, j) = (B−m, B−m') with j > i.
        (b as i64 - m, b as i64 - mp)
    };
    ((i - 1) * (b as i64 - 1) + (j - 1)) as usize
}

/// The prologue pairs handled before the κ loop: (0,0), the m' = 0
/// border, and the m = m' diagonal (paper Fig. 1 caption).
pub fn prologue_pairs(b: usize) -> Vec<(i64, i64)> {
    let mut v = Vec::with_capacity(2 * b);
    v.push((0, 0));
    for m in 1..b as i64 {
        v.push((m, 0));
        v.push((m, m));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Prop;
    use std::collections::HashSet;

    #[test]
    fn sigma_bijective_over_triangle() {
        let b = 40usize;
        let mut seen = HashSet::new();
        for m in 0..b as i64 {
            for mp in 0..=m {
                let s = pair_to_sigma(m, mp);
                assert!(s < sigma_count(b));
                assert!(seen.insert(s), "σ collision at ({m},{mp})");
                assert_eq!(sigma_to_pair(s), (m, mp), "σ inversion at ({m},{mp})");
            }
        }
        assert_eq!(seen.len(), sigma_count(b));
    }

    #[test]
    fn kappa_bijective_over_strict_triangle() {
        for b in [3usize, 4, 5, 6, 7, 16, 17, 64, 65] {
            let mut seen = HashSet::new();
            for kappa in 0..kappa_count(b) {
                let (m, mp) = kappa_to_pair(kappa, b);
                assert!(
                    m > mp && mp > 0 && (m as usize) < b,
                    "b={b} κ={kappa} → ({m},{mp}) outside strict triangle"
                );
                assert!(seen.insert((m, mp)), "b={b}: pair ({m},{mp}) twice");
                assert_eq!(
                    pair_to_kappa(m, mp, b),
                    kappa,
                    "b={b}: κ inversion failed at ({m},{mp})"
                );
            }
            // Surjectivity: every strict pair covered.
            for m in 2..b as i64 {
                for mp in 1..m {
                    assert!(
                        seen.contains(&(m, mp)),
                        "b={b}: pair ({m},{mp}) never produced"
                    );
                }
            }
        }
    }

    #[test]
    fn kappa_and_sigma_cover_same_domain_with_prologue() {
        // prologue ∪ κ-domain = σ-domain (the full triangle).
        for b in [3usize, 8, 31] {
            let mut from_kappa: HashSet<(i64, i64)> =
                prologue_pairs(b).into_iter().collect();
            for kappa in 0..kappa_count(b) {
                assert!(from_kappa.insert(kappa_to_pair(kappa, b)));
            }
            let mut from_sigma = HashSet::new();
            for sigma in 0..sigma_count(b) {
                from_sigma.insert(sigma_to_pair(sigma));
            }
            assert_eq!(from_kappa, from_sigma, "b={b}");
        }
    }

    #[test]
    fn property_random_bandwidths() {
        Prop::new("κ bijection random b").cases(60).run(|g| {
            let b = g.usize_in(3, 200);
            let k = if kappa_count(b) == 0 {
                return Ok(());
            } else {
                g.usize_in(0, kappa_count(b) - 1)
            };
            let (m, mp) = kappa_to_pair(k, b);
            Prop::assert_true(m > mp && mp > 0, "strict triangle")?;
            Prop::assert_eq_msg(pair_to_kappa(m, mp, b), k, "roundtrip")
        });
    }

    #[test]
    fn prologue_sizes() {
        assert_eq!(prologue_pairs(1).len(), 1);
        assert_eq!(prologue_pairs(2).len(), 3);
        assert_eq!(prologue_pairs(8).len(), 15); // 1 + 2·7
    }

    #[test]
    fn counts_consistency() {
        // triangle = prologue + strict triangle.
        for b in 1..50usize {
            assert_eq!(
                sigma_count(b),
                prologue_pairs(b).len() + kappa_count(b),
                "b={b}"
            );
        }
    }
}

//! Transform plans: the ordered work-package (cluster) list for one
//! bandwidth — the paper's *partitioning* + *agglomeration* output.

use crate::coordinator::partition;
use crate::dwt::cluster::Cluster;

/// How the order domain is partitioned into work packages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionStrategy {
    /// Geometric κ map with symmetry clusters, specials in a prologue —
    /// the paper's design.
    GeometricClustered,
    /// σ map (Eq. 7/8) with symmetry clusters — the paper's intermediate
    /// design (sqrt-based index reconstruction).
    SigmaClustered,
    /// No symmetry exploitation: one singleton package per (m, m') pair
    /// over the full (2B−1)² square — the ablation baseline.
    NoSymmetry,
}

impl PartitionStrategy {
    /// Parse from a CLI/config string (`rect` | `triangle`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "geometric" => Some(Self::GeometricClustered),
            "sigma" => Some(Self::SigmaClustered),
            "nosym" => Some(Self::NoSymmetry),
            _ => None,
        }
    }

    /// Canonical name (round-trips through [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Self::GeometricClustered => "geometric",
            Self::SigmaClustered => "sigma",
            Self::NoSymmetry => "nosym",
        }
    }
}

/// The ordered package list for one transform.
#[derive(Debug, Clone)]
pub struct TransformPlan {
    /// Transform bandwidth B.
    pub b: usize,
    /// Partition strategy the clusters were built with.
    pub strategy: PartitionStrategy,
    /// The symmetry clusters, in execution order.
    pub clusters: Vec<Cluster>,
}

impl TransformPlan {
    /// Build the cluster partition for bandwidth `b`.
    pub fn new(b: usize, strategy: PartitionStrategy) -> Self {
        assert!(b >= 1);
        let clusters = match strategy {
            PartitionStrategy::GeometricClustered => {
                // Prologue (specials) first — "we treat these cases in
                // advance" — then the κ loop.
                let mut v: Vec<Cluster> = partition::prologue_pairs(b)
                    .into_iter()
                    .map(|(m, mp)| Cluster::symmetric(m, mp))
                    .collect();
                v.extend((0..partition::kappa_count(b)).map(|kappa| {
                    let (m, mp) = partition::kappa_to_pair(kappa, b);
                    Cluster::symmetric(m, mp)
                }));
                v
            }
            PartitionStrategy::SigmaClustered => (0..partition::sigma_count(b))
                .map(|sigma| {
                    let (m, mp) = partition::sigma_to_pair(sigma);
                    Cluster::symmetric(m, mp)
                })
                .collect(),
            PartitionStrategy::NoSymmetry => {
                let bb = b as i64;
                let mut v = Vec::with_capacity((2 * b - 1) * (2 * b - 1));
                for m in (1 - bb)..bb {
                    for mp in (1 - bb)..bb {
                        v.push(Cluster::singleton(m, mp));
                    }
                }
                v
            }
        };
        Self {
            b,
            strategy,
            clusters,
        }
    }

    /// Total member (order-pair) count — must equal (2B−1)² for any
    /// strategy (the coverage invariant).
    pub fn member_count(&self) -> usize {
        self.clusters.iter().map(|c| c.members.len()).sum()
    }

    /// Estimated flops over all packages (simulator input).
    pub fn total_flops(&self) -> usize {
        self.clusters.iter().map(|c| c.flops(self.b)).sum()
    }

    /// Per-package flop estimates, in plan order (simulator input).
    pub fn package_flops(&self) -> Vec<usize> {
        self.clusters.iter().map(|c| c.flops(self.b)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Prop;
    use std::collections::HashSet;

    fn assert_full_coverage(plan: &TransformPlan) {
        let b = plan.b as i64;
        let mut seen = HashSet::new();
        for c in &plan.clusters {
            for m in &c.members {
                assert!(
                    seen.insert((m.m, m.mp)),
                    "{:?}: pair ({},{}) in two packages",
                    plan.strategy,
                    m.m,
                    m.mp
                );
            }
        }
        assert_eq!(seen.len(), ((2 * b - 1) * (2 * b - 1)) as usize);
        for m in (1 - b)..b {
            for mp in (1 - b)..b {
                assert!(seen.contains(&(m, mp)));
            }
        }
    }

    #[test]
    fn all_strategies_cover_order_square_exactly_once() {
        for b in [1usize, 2, 3, 4, 5, 8, 16, 33] {
            for strategy in [
                PartitionStrategy::GeometricClustered,
                PartitionStrategy::SigmaClustered,
                PartitionStrategy::NoSymmetry,
            ] {
                let plan = TransformPlan::new(b, strategy);
                assert_full_coverage(&plan);
                assert_eq!(plan.member_count(), (2 * b - 1) * (2 * b - 1));
            }
        }
    }

    #[test]
    fn geometric_and_sigma_have_same_cluster_multiset() {
        let b = 12;
        let norm = |plan: &TransformPlan| {
            let mut v: Vec<(i64, i64, usize)> = plan
                .clusters
                .iter()
                .map(|c| (c.m, c.mp, c.members.len()))
                .collect();
            v.sort_unstable();
            v
        };
        let g = TransformPlan::new(b, PartitionStrategy::GeometricClustered);
        let s = TransformPlan::new(b, PartitionStrategy::SigmaClustered);
        assert_eq!(norm(&g), norm(&s));
    }

    #[test]
    fn geometric_prologue_comes_first() {
        let b = 9;
        let plan = TransformPlan::new(b, PartitionStrategy::GeometricClustered);
        let n_prologue = 2 * b - 1;
        for c in &plan.clusters[..n_prologue] {
            assert!(c.mp == 0 || c.m == c.mp, "specials first");
        }
        for c in &plan.clusters[n_prologue..] {
            assert!(c.m > c.mp && c.mp > 0, "strict pairs after");
        }
    }

    #[test]
    fn package_count_matches_paper_formulas() {
        Prop::new("package counts").cases(50).run(|g| {
            let b = g.usize_in(1, 128);
            let plan = TransformPlan::new(b, PartitionStrategy::GeometricClustered);
            // clusters = B(B+1)/2 base pairs.
            Prop::assert_eq_msg(plan.clusters.len(), b * (b + 1) / 2, "cluster count")
        });
    }

    #[test]
    fn nosym_does_more_flops_than_clustered() {
        let b = 16;
        let sym = TransformPlan::new(b, PartitionStrategy::GeometricClustered);
        let nosym = TransformPlan::new(b, PartitionStrategy::NoSymmetry);
        // Without clustering every pair pays its own recurrence: strictly
        // more work (that's the point of the symmetry design).
        assert!(nosym.total_flops() > sym.total_flops());
    }
}

//! The parallel FSOFT/iFSOFT executor.
//!
//! A transform is three parallel regions over the worker pool:
//!
//! forward:  [FFT]   per-β-slice 2-D FFT (positive sign)
//!           [TRN]   transpose slices → S-matrix (contiguous j)
//!           [DWT]   symmetry-cluster loop under the configured schedule
//! inverse:  [DWT]   iDWT cluster loop → S-matrix
//!           [TRN]   transpose S-matrix → slices
//!           [FFT]   per-slice 2-D FFT (negative sign)
//!
//! Every output element belongs to exactly one package in its region
//! (slices, (m,m') vectors, (l,m,m') triples), so workers write through
//! [`SyncUnsafeSlice`] without locks — the paper's "memory access of the
//! different nodes can be made exclusive".

use std::cell::RefCell;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::plan::{PartitionStrategy, TransformPlan};
use crate::dwt::cluster::Cluster;
use crate::dwt::clenshaw;
use crate::dwt::folded;
use crate::dwt::kernels::{self, DwtScratch};
use crate::dwt::tables::{OnTheFlySource, WignerSource, WignerStorage, WignerTables};
use crate::dwt::{DwtAlgorithm, Precision, SMatrix};
use crate::error::{Error, Result};
use crate::fft::fft2::{ColumnPass, Fft2};
use crate::fft::plan::{FftAlgo, FftPlan};
use crate::fft::real::RealFft2;
use crate::fft::{Complex64, FftEngine, Sign};
use crate::pool::{self, PoolSpec, RegionStats, Schedule, WorkerPool};
use crate::simd::{SimdIsa, SimdPolicy};
use crate::so3::coeffs::{coeff_count, So3Coeffs};
use crate::so3::quadrature;
use crate::so3::sampling::{GridAngles, So3Grid};
use crate::util::{AlignedVec, SyncUnsafeSlice};

/// Offload interface for the DWT contraction (implemented by the PJRT
/// runtime in `runtime::xla_dwt`). The executor hands over the packed
/// base Wigner rows and member vectors; reflection/signs/V-scaling stay
/// in the coordinator so native and offloaded paths share them.
pub trait DwtOffload: Send + Sync {
    /// `c[mi·nl + li] = Σ_j rows[li·2B + j] · t[mi·2B + j]`.
    fn contract_forward(
        &self,
        b: usize,
        nl: usize,
        nm: usize,
        rows: &[f64],
        t: &[Complex64],
    ) -> Result<Vec<Complex64>>;

    /// `s[mi·2B + j] = Σ_li rows[li·2B + j] · chat[mi·nl + li]`.
    fn contract_inverse(
        &self,
        b: usize,
        nl: usize,
        nm: usize,
        rows: &[f64],
        chat: &[Complex64],
    ) -> Result<Vec<Complex64>>;
}

/// Executor configuration (the library's "launcher" level config).
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Worker threads (1 = the sequential algorithm).
    pub threads: usize,
    /// Loop schedule for the DWT region (paper: `dynamic`).
    pub schedule: Schedule,
    /// Order-domain partitioning.
    pub strategy: PartitionStrategy,
    /// DWT dataflow: the β-parity-folded engine (default), the full-row
    /// matvec baseline, or the Clenshaw recurrence.
    pub algorithm: DwtAlgorithm,
    /// Wigner row storage.
    pub storage: WignerStorage,
    /// Accumulation precision.
    pub precision: Precision,
    /// FFT-stage kernels: the split-radix panel engine (default) or the
    /// pre-overhaul radix-2 gather/scatter baseline.
    pub fft_engine: FftEngine,
    /// Opt-in real-input analysis: the forward FFT stage runs the
    /// conjugate-even path (~half the butterfly work). Grids with any
    /// nonzero imaginary part are rejected with
    /// [`Error::RealInputRequired`]. The inverse direction is unaffected
    /// (synthesis output is complex in general).
    pub real_input: bool,
    /// Where parallel regions execute: an owned pool of `threads`
    /// persistent workers (default), the lazily-initialized
    /// process-global pool, or an explicitly shared pool (see
    /// [`PoolSpec`]). Ignored when `threads == 1` — the sequential path
    /// runs regions inline and never touches a pool.
    pub pool: PoolSpec,
    /// Butterfly/contraction instruction set for the DWT and FFT hot
    /// loops: [`SimdPolicy::Auto`] (default) picks the widest ISA the
    /// host supports (AVX2+FMA on x86_64, NEON on aarch64) and falls
    /// back to scalar elsewhere; [`SimdPolicy::Scalar`] pins the
    /// measurable scalar baseline; the `Force*` variants are typed
    /// config errors on hosts without that ISA. Resolved once at plan
    /// construction — never re-detected per call.
    pub simd: SimdPolicy,
    /// Memory budget, resolved once at plan build into table
    /// materialization / streaming choices (see [`MemoryBudget`]).
    pub memory: MemoryBudget,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        Self {
            threads: 1,
            schedule: Schedule::PAPER,
            strategy: PartitionStrategy::GeometricClustered,
            algorithm: DwtAlgorithm::MatVecFolded,
            storage: WignerStorage::Precomputed,
            precision: Precision::Double,
            fft_engine: FftEngine::SplitRadix,
            real_input: false,
            pool: PoolSpec::Owned,
            simd: SimdPolicy::Auto,
            memory: MemoryBudget::Auto,
        }
    }
}

/// Soft table cap applied by [`MemoryBudget::Auto`]: 2 GiB, matching the
/// historical `storage = "auto"` default of 2048 MiB.
const AUTO_TABLE_CAP: usize = 2048 << 20;

/// Typed memory budget for one plan — the single knob that replaces the
/// scattered `WignerStorage::auto` byte heuristics (ISSUE 8).
///
/// Resolution happens once at plan build ([`Executor::new`]):
///
/// * [`MemoryBudget::Auto`] (default) — tables are materialized up to a
///   soft 2 GiB cap and streamed beyond it; never errors. The transform
///   workspace is *not* counted (it is irreducible, and Auto preserves
///   the pre-0.9 behaviour at every bandwidth).
/// * [`MemoryBudget::Unlimited`] — full tables regardless of size (the
///   paper's benchmarked setup).
/// * [`MemoryBudget::Bytes`] — a hard cap over workspace *plus* tables:
///   tables are partially materialized to fit
///   ([`crate::dwt::tables::WignerTables::build_partial`]), and a cap the
///   workspace alone exceeds is a typed [`Error::BudgetExceeded`], not a
///   silent fallback.
///
/// The outcome is inspectable via [`Executor::memory_report`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum MemoryBudget {
    /// Table-only soft cap of 2 GiB; streams beyond it, never errors.
    #[default]
    Auto,
    /// No cap: full tables at any bandwidth.
    Unlimited,
    /// Hard cap in bytes over workspace + tables.
    Bytes(usize),
}

impl MemoryBudget {
    /// Parse the config/CLI surface: `auto`, `unlimited`, `bytes:<n>`
    /// (exact bytes), or a bare integer meaning MiB.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim();
        match s {
            "auto" => Some(MemoryBudget::Auto),
            "unlimited" => Some(MemoryBudget::Unlimited),
            _ => {
                if let Some(n) = s.strip_prefix("bytes:") {
                    n.parse::<usize>().ok().map(MemoryBudget::Bytes)
                } else {
                    s.parse::<usize>()
                        .ok()
                        .map(|mib| MemoryBudget::Bytes(mib << 20))
                }
            }
        }
    }

    /// Canonical text form — round-trips through [`Self::parse`]; used by
    /// the config serializer and the wisdom store's `mem=` token.
    pub fn name(&self) -> String {
        match self {
            MemoryBudget::Auto => "auto".into(),
            MemoryBudget::Unlimited => "unlimited".into(),
            MemoryBudget::Bytes(n) => format!("bytes:{n}"),
        }
    }

    /// Resolve to a table byte budget for bandwidth `b`: `None` means
    /// "no cap" (build full tables); `Some(t)` caps the table set at `t`
    /// bytes. [`MemoryBudget::Bytes`] charges the irreducible workspace
    /// first and errors if the cap cannot even hold that.
    pub fn table_budget_bytes(&self, b: usize) -> Result<Option<usize>> {
        match *self {
            MemoryBudget::Unlimited => Ok(None),
            MemoryBudget::Auto => Ok(Some(AUTO_TABLE_CAP)),
            MemoryBudget::Bytes(cap) => {
                let ws = workspace_bytes(b);
                if ws > cap {
                    Err(Error::BudgetExceeded {
                        required: ws,
                        budget: cap,
                        context: "irreducible transform workspace",
                    })
                } else {
                    Ok(Some(cap - ws))
                }
            }
        }
    }
}

impl std::fmt::Display for MemoryBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// Bytes of the irreducible per-transform workspace at bandwidth `b`:
/// the (2B)³ staging slab plus the (2B−1)²×2B S-matrix, both complex.
pub fn workspace_bytes(b: usize) -> usize {
    let n = 2 * b;
    let o = 2 * b - 1;
    (n * n * n + o * o * n) * std::mem::size_of::<Complex64>()
}

/// How a plan's [`MemoryBudget`] resolved — predicted footprint versus
/// budget, surfaced by [`Executor::memory_report`] /
/// `So3Plan::memory_report`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryReport {
    /// The budget the plan was built under.
    pub budget: MemoryBudget,
    /// Bytes of Wigner table actually materialized (0 when fully
    /// streamed).
    pub table_bytes: usize,
    /// Bytes a *complete* table set would take at this bandwidth.
    pub table_bytes_full: usize,
    /// Irreducible per-workspace scratch ([`workspace_bytes`]).
    pub workspace_bytes: usize,
    /// Whether any base pair is streamed from the recurrence instead of
    /// read from tables.
    pub streamed: bool,
}

impl MemoryReport {
    /// Predicted steady-state bytes: materialized tables plus one
    /// workspace.
    pub fn total_bytes(&self) -> usize {
        self.table_bytes + self.workspace_bytes
    }
}

/// Per-package wall times for each region of one sequential run — the
/// multicore simulator's calibration input.
#[derive(Debug, Clone, Default)]
pub struct RegionProfiles {
    /// One entry per β-slice 2-D FFT.
    pub fft: Vec<f64>,
    /// One entry per (m, m') transposition package.
    pub transpose: Vec<f64>,
    /// One entry per DWT cluster, in plan order.
    pub dwt: Vec<f64>,
}

impl RegionProfiles {
    /// Total sequential time across regions.
    pub fn total(&self) -> f64 {
        self.fft.iter().sum::<f64>()
            + self.transpose.iter().sum::<f64>()
            + self.dwt.iter().sum::<f64>()
    }
}

/// Wall-clock breakdown of one transform run.
#[derive(Debug, Clone, Default)]
pub struct TransformStats {
    /// Time in the FFT stage.
    pub fft: Duration,
    /// Time in the transpose stages.
    pub transpose: Duration,
    /// Time in the DWT stage.
    pub dwt: Duration,
    /// End-to-end wall time of the transform.
    pub total: Duration,
    /// Region stats of the DWT loop (imbalance diagnostics).
    pub dwt_region: Option<RegionStats>,
    /// Peak ledgered bytes during this transform (`util::ledger`):
    /// live tables + workspaces at the high-water mark, rebased at
    /// transform start so it reflects this run's steady state.
    /// Best-effort under concurrency — the ledger is process-wide, so
    /// transforms running simultaneously on other threads inflate each
    /// other's peaks.
    pub peak_bytes: usize,
}

/// Per-stage alias for [`TransformStats`] — the name the perf tooling
/// (benches, `BENCH_fft.json`, docs/PERF.md) uses for the breakdown.
pub type StageStats = TransformStats;

impl TransformStats {
    /// Fraction of total time in the FFT stage (the paper's §5 ~5–8%
    /// observation).
    pub fn fft_fraction(&self) -> f64 {
        if self.total.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.fft.as_secs_f64() / self.total.as_secs_f64()
        }
    }
}

/// A prepared transform engine for one bandwidth.
pub struct Executor {
    b: usize,
    config: ExecutorConfig,
    plan: TransformPlan,
    angles: GridAngles,
    weights: Vec<f64>,
    fft2: Fft2,
    /// Conjugate-even stage-1 companion, built in `real_input` mode.
    real_fft2: Option<RealFft2>,
    tables: Option<WignerTables>,
    offload: Option<Arc<dyn DwtOffload>>,
    /// Persistent worker pool serving every parallel region of this
    /// executor; `None` when `threads == 1` (regions run inline on the
    /// caller). Possibly shared with other executors — see
    /// [`ExecutorConfig::pool`].
    pool: Option<Arc<WorkerPool>>,
    /// The ISA the hot kernels run with — `config.simd` resolved once at
    /// construction (so dispatch is branch-free and thread-count
    /// independent).
    isa: SimdIsa,
    /// FFT bin of each order index: `order_bins[mi] = (mi - (B-1)) mod 2B`.
    order_bins: Vec<usize>,
    /// Storage-free layout oracle consulted by the iDWT kernels for
    /// `vec_index` (holds no element data — see [`SMatrix::layout_only`]).
    smat_layout: SMatrix,
}

thread_local! {
    /// Per-thread DWT scratch, grown to the largest bandwidth seen.
    /// Parallel regions run on a persistent [`WorkerPool`], whose OS
    /// threads are stable for the pool's lifetime — so this scratch is
    /// pinned per worker and reused across regions, transforms, and
    /// every plan sharing the pool. Mixed-bandwidth plans sharing one
    /// pool never reallocate on a bandwidth switch: the scratch grows
    /// to the max and serves every smaller plan in place (kernels slice
    /// by their own bandwidth).
    static SCRATCH: RefCell<Option<DwtScratch>> = const { RefCell::new(None) };
    /// Per-thread FFT column scratch, grown on demand. On the sequential
    /// path the main thread reuses it across slices AND transforms; on
    /// the pooled path it is likewise pinned to the persistent workers
    /// (grown once per worker, not once per region as under the legacy
    /// scoped-spawn substrate). 64-byte aligned so the SIMD column
    /// kernels in `fft::simd` run on cache-line-aligned panels.
    static FFT_SCRATCH: RefCell<AlignedVec<Complex64>> = const { RefCell::new(AlignedVec::new()) };
}

fn with_scratch<R>(b: usize, f: impl FnOnce(&mut DwtScratch) -> R) -> R {
    SCRATCH.with(|cell| {
        let mut slot = cell.borrow_mut();
        let scratch = slot.get_or_insert_with(Default::default);
        scratch.ensure(b);
        f(scratch)
    })
}

fn with_fft_scratch<R>(len: usize, f: impl FnOnce(&mut [Complex64]) -> R) -> R {
    FFT_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < len {
            buf.resize(len, Complex64::zero());
        }
        f(&mut buf[..len])
    })
}

/// Caller-owned scratch buffers for the allocation-free transform entry
/// points ([`Executor::forward_into`] / [`Executor::inverse_into`]).
///
/// A workspace is built once per bandwidth — typically via
/// [`Executor::make_workspace`] — and reused across calls and across
/// batches; the executor validates the bandwidth on every call, so
/// passing a workspace of the wrong size is a typed [`Error`], never UB.
#[derive(Debug, Clone)]
pub struct Workspace {
    b: usize,
    /// β-major staging buffer, (2B)³ — the forward FFT stage's in-place
    /// working copy of the input grid.
    work: Vec<Complex64>,
    /// The intermediate S-matrix shared by both directions.
    smat: SMatrix,
    /// Charges this workspace's footprint against the process allocation
    /// ledger (`util::ledger`) for its lifetime.
    ledger: crate::util::ledger::LedgerSlot,
}

impl Workspace {
    /// Allocate every per-transform buffer for bandwidth `b`.
    pub fn new(b: usize) -> Result<Self> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(b));
        }
        let n = 2 * b;
        let work = vec![Complex64::zero(); n * n * n];
        let smat = SMatrix::zeros(b)?;
        let ledger = crate::util::ledger::LedgerSlot::new(
            (work.len() + smat.len()) * std::mem::size_of::<Complex64>(),
        );
        Ok(Self {
            b,
            work,
            smat,
            ledger,
        })
    }

    /// Bandwidth the workspace was sized for.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Stable address of the staging buffer (used by the reuse tests to
    /// assert that `*_into` never reallocates workspace storage).
    pub fn work_ptr(&self) -> *const Complex64 {
        self.work.as_ptr()
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("bandwidth", &self.b)
            .field("config", &self.config)
            .field("table_bytes", &self.table_bytes())
            .field("offload", &self.offload.is_some())
            .field("pool", &self.pool)
            .finish()
    }
}

impl Executor {
    /// Build an executor for bandwidth `b` (plans, tables, pool).
    pub fn new(b: usize, config: ExecutorConfig) -> Result<Self> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(b));
        }
        if config.threads == 0 {
            return Err(Error::InvalidThreads(0));
        }
        // Unsupported combinations are config errors, not silent fallbacks.
        if config.algorithm == DwtAlgorithm::Clenshaw
            && config.precision == Precision::Extended
        {
            return Err(Error::Config(
                "extended precision requires the matvec DWT".into(),
            ));
        }
        if config.algorithm == DwtAlgorithm::Clenshaw
            && config.strategy == PartitionStrategy::NoSymmetry
        {
            return Err(Error::Config(
                "the Clenshaw DWT requires canonical (clustered) partitioning".into(),
            ));
        }
        let angles = GridAngles::new(b)?;
        let weights = quadrature::weights(b)?;
        let plan = TransformPlan::new(b, config.strategy);
        // Folded + extended streams exact rows from the recurrence
        // instead: the folded tables' reconstructed O halves carry an
        // O(B·ε) term that would defeat double-double accumulation, and
        // unfolding rows only to re-fold them in the kernel is pure
        // waste — so no tables are built (table_bytes() reports 0).
        let folded_extended = config.algorithm == DwtAlgorithm::MatVecFolded
            && config.precision == Precision::Extended;
        // Resolve the memory budget once: a Bytes cap the workspace alone
        // exceeds is a typed error here, before any table is built.
        let table_budget = config.memory.table_budget_bytes(b)?;
        // Fault site: exercised by the chaos suite to prove a failed
        // table load surfaces as a typed constructor error, not a panic.
        if let Some(action) = crate::faults::fire(crate::faults::WIGNER_LOAD) {
            action.apply(crate::faults::WIGNER_LOAD)?;
        }
        let tables = match (config.storage, config.algorithm) {
            (
                WignerStorage::Precomputed,
                DwtAlgorithm::MatVec | DwtAlgorithm::MatVecFolded,
            ) if config.strategy != PartitionStrategy::NoSymmetry && !folded_extended => {
                Some(match table_budget {
                    // Streamed large-B mode: materialize what fits, the
                    // executor streams the rest per base pair.
                    Some(budget) if WignerTables::full_bytes(b) > budget => {
                        WignerTables::build_partial(b, &angles.betas, budget)
                    }
                    _ => WignerTables::build(b, &angles.betas),
                })
            }
            _ => None,
        };
        // Resolve the SIMD policy once: Force* on an unsupported host is
        // a typed config error (not a silent scalar fallback), and the
        // resolved ISA is pinned so every region of every transform of
        // this executor dispatches identically.
        let isa = config.simd.resolve()?;
        let fft2 = match config.fft_engine {
            FftEngine::SplitRadix => Fft2::new(
                2 * b,
                Arc::new(FftPlan::with_algo_isa(2 * b, FftAlgo::Auto, isa)),
            ),
            // The baseline engine stays scalar by construction (radix-2 /
            // Bluestein carry no vector stages), so it keeps measuring
            // the pre-overhaul kernels regardless of policy.
            FftEngine::Radix2Baseline => Fft2::with_column_pass(
                2 * b,
                Arc::new(FftPlan::with_algo(2 * b, FftAlgo::Radix2)),
                ColumnPass::GatherScatter,
            ),
        };
        let real_fft2 = config.real_input.then(|| RealFft2::from_fft2(&fft2));
        let pool = config.pool.resolve(config.threads)?;
        let n = 2 * b as i64;
        let order_bins = (0..SMatrix::orders(b))
            .map(|mi| (mi as i64 - (b as i64 - 1)).rem_euclid(n) as usize)
            .collect();
        let smat_layout = SMatrix::layout_only(b)?;
        Ok(Self {
            b,
            config,
            plan,
            angles,
            weights,
            fft2,
            real_fft2,
            tables,
            offload: None,
            pool,
            isa,
            order_bins,
            smat_layout,
        })
    }

    /// Attach a DWT offload backend (the PJRT runtime). Only the matvec /
    /// double-precision path offloads; other configs keep the native path.
    pub fn with_offload(mut self, offload: Arc<dyn DwtOffload>) -> Self {
        self.offload = Some(offload);
        self
    }

    /// Bandwidth this executor was built for.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// The configuration the executor was built with.
    pub fn config(&self) -> &ExecutorConfig {
        &self.config
    }

    /// The cluster partition plan in use.
    pub fn plan(&self) -> &TransformPlan {
        &self.plan
    }

    /// Quadrature weights for the β grid.
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// The sampling grid angles.
    pub fn angles(&self) -> &GridAngles {
        &self.angles
    }

    /// Memory held by precomputed Wigner tables (bytes).
    pub fn table_bytes(&self) -> usize {
        self.tables.as_ref().map_or(0, |t| t.bytes())
    }

    /// How this plan's [`MemoryBudget`] resolved: materialized table
    /// bytes versus a full set, the irreducible workspace size, and
    /// whether any base pair streams from the recurrence.
    pub fn memory_report(&self) -> MemoryReport {
        let (table_bytes, complete) = match &self.tables {
            Some(t) => (t.bytes(), t.is_complete()),
            None => (0, false),
        };
        MemoryReport {
            budget: self.config.memory,
            table_bytes,
            table_bytes_full: WignerTables::full_bytes(self.b),
            workspace_bytes: workspace_bytes(self.b),
            streamed: !complete,
        }
    }

    /// The instruction set the DWT/FFT hot kernels actually run with —
    /// [`ExecutorConfig::simd`] resolved against the host at
    /// construction.
    #[inline]
    pub fn simd_isa(&self) -> SimdIsa {
        self.isa
    }

    /// The persistent worker pool serving this executor's parallel
    /// regions (`None` on the sequential path). With
    /// [`PoolSpec::Shared`] / [`PoolSpec::Global`] this is the shared
    /// instance, so callers can verify sharing via `Arc::ptr_eq`.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// Run one parallel region: on the persistent pool when configured
    /// with `threads > 1` (region width `min(threads, pool.threads())`),
    /// inline on the caller otherwise. No OS thread is ever spawned
    /// here — the pool's workers are created once at construction.
    fn run_region<F>(&self, n: usize, schedule: Schedule, body: F) -> RegionStats
    where
        F: Fn(usize) + Sync,
    {
        match &self.pool {
            Some(pool) => pool.run_with(self.config.threads, n, schedule, body),
            None => pool::sequential_region(n, body),
        }
    }

    // ------------------------------------------------------------------
    // Forward (FSOFT)
    // ------------------------------------------------------------------

    /// Analysis: grid samples → Fourier coefficients (paper Eq. 5).
    pub fn forward(&self, grid: &So3Grid) -> Result<So3Coeffs> {
        self.forward_with_stats(grid).map(|(c, _)| c)
    }

    /// Allocating convenience wrapper over [`Self::forward_into`].
    pub fn forward_with_stats(&self, grid: &So3Grid) -> Result<(So3Coeffs, TransformStats)> {
        let mut out = So3Coeffs::zeros(self.b);
        let mut ws = self.make_workspace();
        let stats = self.forward_into(grid, &mut out, &mut ws)?;
        Ok((out, stats))
    }

    /// A workspace sized for this executor's bandwidth.
    pub fn make_workspace(&self) -> Workspace {
        Workspace::new(self.b).expect("bandwidth validated at construction")
    }

    fn check_workspace(&self, ws: &Workspace) -> Result<()> {
        if ws.bandwidth() != self.b {
            return Err(Error::bandwidth(
                self.b,
                ws.bandwidth(),
                "workspace bandwidth",
            ));
        }
        Ok(())
    }

    /// Analysis into caller-owned storage: no grid/coefficient allocation
    /// after plan construction. `out` is fully overwritten (every
    /// coefficient belongs to exactly one work package).
    pub fn forward_into(
        &self,
        grid: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        if grid.bandwidth() != self.b {
            return Err(Error::bandwidth(
                self.b,
                grid.bandwidth(),
                "forward: grid bandwidth",
            ));
        }
        if out.bandwidth() != self.b {
            return Err(Error::bandwidth(
                self.b,
                out.bandwidth(),
                "forward: output coefficient bandwidth",
            ));
        }
        self.check_workspace(ws)?;
        let t_total = Instant::now();
        // Rebase the allocation ledger so the reported peak covers this
        // run's steady state (live tables + workspaces), not process
        // history.
        crate::util::ledger::rebase_peak();
        let n = 2 * self.b;
        let mut stats = TransformStats::default();

        // [FFT] per-slice 2-D FFT with the positive-sign kernel:
        // Ŝ_j[u][v] = Σ_{i,k} f e^{+i(uα_i + vγ_k)}. In `real_input`
        // mode the conjugate-even kernel does ~half the butterfly work;
        // its realness validation is fused into the staging copy (one
        // pass, and its cost is visible in `stats.fft` rather than
        // hidden outside the timers).
        let t0 = Instant::now();
        let work = &mut ws.work;
        if self.real_fft2.is_some() {
            for (dst, &src) in work.iter_mut().zip(grid.as_slice()) {
                if src.im != 0.0 {
                    return Err(Error::RealInputRequired {
                        context: "forward: grid samples",
                    });
                }
                *dst = src;
            }
        } else {
            work.copy_from_slice(grid.as_slice());
        }
        {
            let shared = SyncUnsafeSlice::new(work);
            let slen = self
                .real_fft2
                .as_ref()
                .map_or_else(|| self.fft2.scratch_len(), |rf| rf.scratch_len());
            self.run_region(n, Schedule::Dynamic { chunk: 1 }, |j| {
                // SAFETY: slice j is exclusive to this package.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(shared.ptr_at(j * n * n), n * n)
                };
                with_fft_scratch(slen, |scratch| match &self.real_fft2 {
                    Some(rf) => rf.forward(slice, scratch, Sign::Positive),
                    None => self.fft2.process(slice, scratch, Sign::Positive),
                });
            });
        }
        stats.fft = t0.elapsed();

        // [TRN] gather into the S-matrix layout (contiguous j) via the
        // cache-oblivious tiler: one u-row per package, each writing its
        // o×n destination block through `transpose::gather_permuted`
        // (recursive square-block split, unit-stride stores in the base
        // case). Pure copies — bit-identical to any traversal order,
        // pinned by tests/transpose_parity.rs.
        let t0 = Instant::now();
        let smat = &mut ws.smat;
        let o = SMatrix::orders(self.b);
        {
            let shared = SyncUnsafeSlice::new(smat.as_mut_slice());
            let work_ref = &ws.work;
            let bins = &self.order_bins;
            self.run_region(o, Schedule::Dynamic { chunk: 1 }, |mi| {
                let u = bins[mi];
                // SAFETY: the o×n destination block of order mi is
                // package-exclusive and contiguous in the S-matrix.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(shared.ptr_at(mi * o * n), o * n)
                };
                crate::transpose::gather_permuted(
                    dst,
                    n,
                    &work_ref[u * n..],
                    n * n,
                    bins,
                    o,
                    n,
                );
            });
        }
        stats.transpose = t0.elapsed();

        // [DWT] the cluster loop — the paper's parallel region. Every
        // coefficient (l, μ, μ') belongs to exactly one cluster, so the
        // caller's buffer is fully overwritten without pre-zeroing.
        let t0 = Instant::now();
        {
            let shared = SyncUnsafeSlice::new(out.as_mut_slice());
            let smat_ref: &SMatrix = &ws.smat;
            let clusters = self.plan.clusters.len();
            let region = self.run_region(clusters, self.config.schedule, |ci| {
                let cluster = &self.plan.clusters[ci];
                self.forward_cluster_dispatch(cluster, smat_ref, &shared);
            });
            stats.dwt_region = Some(region);
        }
        stats.dwt = t0.elapsed();
        stats.peak_bytes = crate::util::ledger::peak_bytes();
        stats.total = t_total.elapsed();
        Ok(stats)
    }

    fn forward_cluster_dispatch(
        &self,
        cluster: &Cluster,
        smat: &SMatrix,
        out: &SyncUnsafeSlice<'_, Complex64>,
    ) {
        let b = self.b;
        match (self.config.algorithm, self.config.precision) {
            (DwtAlgorithm::Clenshaw, _) => with_scratch(b, |_s| {
                let mut acc = Vec::new();
                clenshaw::forward_cluster_clenshaw(
                    b,
                    cluster,
                    &self.angles.betas,
                    &self.weights,
                    smat,
                    out,
                    &mut acc,
                );
            }),
            (algorithm, precision) => with_scratch(b, |scratch| {
                if precision == Precision::Double {
                    if let Some(off) = &self.offload {
                        self.forward_cluster_offload(cluster, smat, out, scratch, off.as_ref());
                        return;
                    }
                }
                let folded = algorithm == DwtAlgorithm::MatVecFolded;
                // The folded table kernels consume the half-row storage
                // directly (zero-copy E slices, reconstructed O block).
                if folded && precision == Precision::Double {
                    if let Some(t) = &self.tables {
                        if t.has(cluster.m, cluster.mp) {
                            folded::forward_cluster_folded_tables(
                                b,
                                self.isa,
                                cluster,
                                t,
                                &self.weights,
                                smat,
                                out,
                                scratch,
                            );
                            return;
                        }
                    }
                }
                let mut fly;
                let mut tab;
                let source: &mut dyn WignerSource = match &self.tables {
                    Some(t) if t.has(cluster.m, cluster.mp) => {
                        tab = t.source();
                        &mut tab
                    }
                    _ => {
                        fly = OnTheFlySource::new(&self.angles.betas);
                        &mut fly
                    }
                };
                match (folded, precision) {
                    (false, Precision::Double) => kernels::forward_cluster(
                        b,
                        cluster,
                        source,
                        &self.weights,
                        smat,
                        out,
                        scratch,
                    ),
                    (false, Precision::Extended) => kernels::forward_cluster_extended(
                        b,
                        cluster,
                        source,
                        &self.weights,
                        smat,
                        out,
                        scratch,
                    ),
                    (true, Precision::Double) => folded::forward_cluster_folded(
                        b,
                        self.isa,
                        cluster,
                        source,
                        &self.weights,
                        smat,
                        out,
                        scratch,
                    ),
                    (true, Precision::Extended) => folded::forward_cluster_folded_extended(
                        b,
                        cluster,
                        source,
                        &self.weights,
                        smat,
                        out,
                        scratch,
                    ),
                }
            }),
        }
    }

    /// Offloaded forward cluster: pack rows + member vectors, call the
    /// backend, apply V·sign, store.
    fn forward_cluster_offload(
        &self,
        cluster: &Cluster,
        smat: &SMatrix,
        out: &SyncUnsafeSlice<'_, Complex64>,
        scratch: &mut DwtScratch,
        off: &dyn DwtOffload,
    ) {
        let b = self.b;
        let n = 2 * b;
        let l0 = cluster.l_min();
        let nl = b - l0;
        let nm = cluster.members.len();
        // Weighted member vectors (reversed for reflected members).
        for (mi, member) in cluster.members.iter().enumerate() {
            let s = smat.vec(member.m, member.mp);
            let t = &mut scratch.t[mi * n..(mi + 1) * n];
            if member.reflected {
                for j in 0..n {
                    t[j] = s[n - 1 - j].scale(self.weights[n - 1 - j]);
                }
            } else {
                for j in 0..n {
                    t[j] = s[j].scale(self.weights[j]);
                }
            }
        }
        let rows = self.pack_rows(cluster, nl);
        let c = off
            .contract_forward(b, nl, nm, &rows, &scratch.t[..nm * n])
            .expect("offload backend failed");
        for (mi, member) in cluster.members.iter().enumerate() {
            for li in 0..nl {
                let l = l0 + li;
                let v = c[mi * nl + li]
                    .scale(crate::dwt::v_scale(l, b) * member.sign(l));
                let idx = crate::so3::coeffs::flat_index(l, member.m, member.mp);
                // SAFETY: (l, μ, μ') triples are cluster-exclusive.
                unsafe { out.write(idx, v) };
            }
        }
    }

    /// Pack base Wigner rows d[l0..B][0..2B] densely for the offload.
    fn pack_rows(&self, cluster: &Cluster, nl: usize) -> Vec<f64> {
        let b = self.b;
        let n = 2 * b;
        let l0 = cluster.l_min();
        let mut rows = vec![0.0f64; nl * n];
        let mut fly;
        let mut tab;
        let source: &mut dyn WignerSource = match &self.tables {
            Some(t) if t.has(cluster.m, cluster.mp) => {
                tab = t.source();
                &mut tab
            }
            _ => {
                fly = OnTheFlySource::new(&self.angles.betas);
                &mut fly
            }
        };
        source.reset(cluster.m, cluster.mp);
        let mut buf = vec![0.0f64; n];
        for li in 0..nl {
            let r = source.row(l0 + li, &mut buf);
            rows[li * n..(li + 1) * n].copy_from_slice(r);
        }
        rows
    }

    // ------------------------------------------------------------------
    // Profiling (simulator calibration)
    // ------------------------------------------------------------------

    /// Sequential instrumented forward run: per-package wall times for
    /// each region, feeding the multicore simulator (DESIGN.md §3).
    /// Runs the same FFT kernel `forward` would (including the
    /// real-input path and its validation), so the calibration measures
    /// the engine that actually serves.
    pub fn profile_forward(&self, grid: &So3Grid) -> Result<(So3Coeffs, RegionProfiles)> {
        if grid.bandwidth() != self.b {
            return Err(Error::bandwidth(self.b, grid.bandwidth(), "profile_forward"));
        }
        if self.real_fft2.is_some() && grid.as_slice().iter().any(|z| z.im != 0.0) {
            return Err(Error::RealInputRequired {
                context: "profile_forward: grid samples",
            });
        }
        let n = 2 * self.b;
        let mut profiles = RegionProfiles::default();

        let mut work = grid.as_slice().to_vec();
        let slen = self
            .real_fft2
            .as_ref()
            .map_or_else(|| self.fft2.scratch_len(), |rf| rf.scratch_len());
        let mut scratch = vec![Complex64::zero(); slen];
        for j in 0..n {
            let t0 = Instant::now();
            let slice = &mut work[j * n * n..(j + 1) * n * n];
            match &self.real_fft2 {
                Some(rf) => rf.forward(slice, &mut scratch, Sign::Positive),
                None => self.fft2.process(slice, &mut scratch, Sign::Positive),
            }
            profiles.fft.push(t0.elapsed().as_secs_f64());
        }

        let mut smat = SMatrix::zeros(self.b)?;
        let o = SMatrix::orders(self.b);
        let layout = &self.smat_layout;
        {
            let shared = SyncUnsafeSlice::new(smat.as_mut_slice());
            for p in 0..o * o {
                let t0 = Instant::now();
                let m = (p / o) as i64 - (self.b as i64 - 1);
                let mp = (p % o) as i64 - (self.b as i64 - 1);
                let u = m.rem_euclid(n as i64) as usize;
                let v = mp.rem_euclid(n as i64) as usize;
                let base = layout.vec_index(m, mp);
                for j in 0..n {
                    // SAFETY: sequential loop.
                    unsafe { shared.write(base + j, work[(j * n + u) * n + v]) };
                }
                profiles.transpose.push(t0.elapsed().as_secs_f64());
            }
        }

        let mut out = vec![Complex64::zero(); coeff_count(self.b)];
        {
            let shared = SyncUnsafeSlice::new(&mut out);
            for cluster in &self.plan.clusters {
                let t0 = Instant::now();
                self.forward_cluster_dispatch(cluster, &smat, &shared);
                profiles.dwt.push(t0.elapsed().as_secs_f64());
            }
        }
        Ok((So3Coeffs::from_vec(self.b, out)?, profiles))
    }

    /// Sequential instrumented inverse run.
    pub fn profile_inverse(&self, coeffs: &So3Coeffs) -> Result<(So3Grid, RegionProfiles)> {
        if coeffs.bandwidth() != self.b {
            return Err(Error::bandwidth(self.b, coeffs.bandwidth(), "profile_inverse"));
        }
        let n = 2 * self.b;
        let mut profiles = RegionProfiles::default();

        let mut smat = SMatrix::zeros(self.b)?;
        let layout = &self.smat_layout;
        {
            let shared = SyncUnsafeSlice::new(smat.as_mut_slice());
            for cluster in &self.plan.clusters {
                let t0 = Instant::now();
                self.inverse_cluster_dispatch(cluster, coeffs, &shared, layout);
                profiles.dwt.push(t0.elapsed().as_secs_f64());
            }
        }

        let mut work = vec![Complex64::zero(); n * n * n];
        let o = SMatrix::orders(self.b);
        let bi = self.b as i64;
        {
            let shared = SyncUnsafeSlice::new(&mut work);
            for p in 0..o * o {
                let t0 = Instant::now();
                let m = (p / o) as i64 - (bi - 1);
                let mp = (p % o) as i64 - (bi - 1);
                let u = m.rem_euclid(n as i64) as usize;
                let v = mp.rem_euclid(n as i64) as usize;
                let s = smat.vec(m, mp);
                for j in 0..n {
                    // SAFETY: sequential loop.
                    unsafe { shared.write((j * n + u) * n + v, s[j]) };
                }
                profiles.transpose.push(t0.elapsed().as_secs_f64());
            }
        }

        let mut scratch = vec![Complex64::zero(); self.fft2.scratch_len()];
        for j in 0..n {
            let t0 = Instant::now();
            self.fft2
                .process(&mut work[j * n * n..(j + 1) * n * n], &mut scratch, Sign::Negative);
            profiles.fft.push(t0.elapsed().as_secs_f64());
        }
        Ok((So3Grid::from_vec(self.b, work)?, profiles))
    }

    // ------------------------------------------------------------------
    // Inverse (iFSOFT)
    // ------------------------------------------------------------------

    /// Synthesis: Fourier coefficients → grid samples (paper Eq. 4).
    pub fn inverse(&self, coeffs: &So3Coeffs) -> Result<So3Grid> {
        self.inverse_with_stats(coeffs).map(|(g, _)| g)
    }

    /// Allocating convenience wrapper over the iDWT core. Allocates only
    /// the buffers the inverse direction actually uses (output grid +
    /// S-matrix) — not a full [`Workspace`].
    pub fn inverse_with_stats(
        &self,
        coeffs: &So3Coeffs,
    ) -> Result<(So3Grid, TransformStats)> {
        let mut out = So3Grid::zeros(self.b)?;
        let mut smat = SMatrix::zeros(self.b)?;
        let stats = self.inverse_core(coeffs, &mut out, &mut smat)?;
        Ok((out, stats))
    }

    /// Synthesis into caller-owned storage: no grid/coefficient allocation
    /// after plan construction. `out` is fully overwritten. (Only the
    /// workspace's S-matrix is used; its forward staging buffer is not
    /// touched.)
    pub fn inverse_into(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        self.check_workspace(ws)?;
        self.inverse_core(coeffs, out, &mut ws.smat)
    }

    fn inverse_core(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        smat: &mut SMatrix,
    ) -> Result<TransformStats> {
        if coeffs.bandwidth() != self.b {
            return Err(Error::bandwidth(
                self.b,
                coeffs.bandwidth(),
                "inverse: coefficient bandwidth",
            ));
        }
        if out.bandwidth() != self.b {
            return Err(Error::bandwidth(
                self.b,
                out.bandwidth(),
                "inverse: output grid bandwidth",
            ));
        }
        let t_total = Instant::now();
        // Same steady-state peak semantics as the forward direction.
        crate::util::ledger::rebase_peak();
        let n = 2 * self.b;
        let mut stats = TransformStats::default();

        // [DWT] iDWT cluster loop → S-matrix. Every (μ, μ') j-vector
        // belongs to exactly one cluster, so the S-matrix is fully
        // overwritten without pre-zeroing.
        let t0 = Instant::now();
        let layout = &self.smat_layout;
        {
            let shared = SyncUnsafeSlice::new(smat.as_mut_slice());
            let clusters = self.plan.clusters.len();
            let region = self.run_region(clusters, self.config.schedule, |ci| {
                let cluster = &self.plan.clusters[ci];
                self.inverse_cluster_dispatch(cluster, coeffs, &shared, layout);
            });
            stats.dwt_region = Some(region);
        }
        stats.dwt = t0.elapsed();

        // [TRN] scatter to per-slice layout (Nyquist bins stay zero: the
        // output buffer is zeroed first, matching the fresh-allocation
        // semantics bit for bit) via the cache-oblivious tiler: one
        // target u-row per package, `transpose::tile_recurse` blocking
        // its o×n source block. Destination indices are disjoint across
        // packages (distinct u) but the byte ranges interleave, so writes
        // stay on `SyncUnsafeSlice` rather than `&mut` sub-slices.
        let t0 = Instant::now();
        let work = out.as_mut_slice();
        work.fill(Complex64::zero());
        {
            let shared = SyncUnsafeSlice::new(work);
            let smat_ref: &SMatrix = smat;
            let o = SMatrix::orders(self.b);
            let bins = &self.order_bins;
            let smat_data = smat_ref.as_slice();
            self.run_region(o, Schedule::Dynamic { chunk: 1 }, |mi| {
                let u = bins[mi];
                let src = &smat_data[mi * o * n..(mi + 1) * o * n];
                crate::transpose::tile_recurse(
                    0,
                    o,
                    0,
                    n,
                    crate::transpose::BLOCK,
                    &mut |r0, r1, c0, c1| {
                        for mpi in r0..r1 {
                            let row = &src[mpi * n..(mpi + 1) * n];
                            let v = bins[mpi];
                            for j in c0..c1 {
                                // SAFETY: bin (u, v) of slice j is
                                // written only by the row package
                                // owning u.
                                unsafe { shared.write((j * n + u) * n + v, row[j]) };
                            }
                        }
                    },
                );
            });
        }
        stats.transpose = t0.elapsed();

        // [FFT] per-slice negative-sign 2-D FFT: the synthesis sum
        // f = Σ_{m,m'} S e^{-i(mα + m'γ)}.
        let t0 = Instant::now();
        {
            let shared = SyncUnsafeSlice::new(out.as_mut_slice());
            let slen = self.fft2.scratch_len();
            self.run_region(n, Schedule::Dynamic { chunk: 1 }, |j| {
                // SAFETY: slice j is exclusive to this package.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(shared.ptr_at(j * n * n), n * n)
                };
                with_fft_scratch(slen, |scratch| {
                    self.fft2.process(slice, scratch, Sign::Negative)
                });
            });
        }
        stats.fft = t0.elapsed();
        stats.peak_bytes = crate::util::ledger::peak_bytes();
        stats.total = t_total.elapsed();
        Ok(stats)
    }

    fn inverse_cluster_dispatch(
        &self,
        cluster: &Cluster,
        coeffs: &So3Coeffs,
        smat_out: &SyncUnsafeSlice<'_, Complex64>,
        layout: &SMatrix,
    ) {
        let b = self.b;
        match self.config.algorithm {
            DwtAlgorithm::Clenshaw => {
                let mut buf = Vec::new();
                clenshaw::inverse_cluster_clenshaw(
                    b,
                    cluster,
                    &self.angles.betas,
                    coeffs.as_slice(),
                    smat_out,
                    layout,
                    &mut buf,
                );
            }
            algorithm => with_scratch(b, |scratch| {
                if self.config.precision == Precision::Double {
                    if let Some(off) = &self.offload {
                        self.inverse_cluster_offload(
                            cluster, coeffs, smat_out, layout, scratch, off.as_ref(),
                        );
                        return;
                    }
                }
                let folded = algorithm == DwtAlgorithm::MatVecFolded;
                // Fast path: register-blocked folded sweep over the
                // half-row tables (half table stream; ≥4× fewer
                // accumulator loads/stores than the per-degree axpy).
                if folded && self.config.precision == Precision::Double {
                    if let Some(t) = &self.tables {
                        if t.has(cluster.m, cluster.mp) {
                            folded::inverse_cluster_folded_tables(
                                b,
                                self.isa,
                                cluster,
                                t,
                                coeffs.as_slice(),
                                smat_out,
                                layout,
                                scratch,
                            );
                            return;
                        }
                    }
                }
                let mut fly;
                let mut tab;
                let source: &mut dyn WignerSource = match &self.tables {
                    Some(t) if t.has(cluster.m, cluster.mp) => {
                        tab = t.source();
                        &mut tab
                    }
                    _ => {
                        fly = OnTheFlySource::new(&self.angles.betas);
                        &mut fly
                    }
                };
                match (folded, self.config.precision) {
                    (false, Precision::Double) => kernels::inverse_cluster(
                        b,
                        cluster,
                        source,
                        coeffs.as_slice(),
                        smat_out,
                        layout,
                        scratch,
                    ),
                    (false, Precision::Extended) => kernels::inverse_cluster_extended(
                        b,
                        cluster,
                        source,
                        coeffs.as_slice(),
                        smat_out,
                        layout,
                        scratch,
                    ),
                    (true, Precision::Double) => folded::inverse_cluster_folded(
                        b,
                        self.isa,
                        cluster,
                        source,
                        coeffs.as_slice(),
                        smat_out,
                        layout,
                        scratch,
                    ),
                    (true, Precision::Extended) => folded::inverse_cluster_folded_extended(
                        b,
                        cluster,
                        source,
                        coeffs.as_slice(),
                        smat_out,
                        layout,
                        scratch,
                    ),
                }
            }),
        }
    }

    fn inverse_cluster_offload(
        &self,
        cluster: &Cluster,
        coeffs: &So3Coeffs,
        smat_out: &SyncUnsafeSlice<'_, Complex64>,
        layout: &SMatrix,
        scratch: &mut DwtScratch,
        off: &dyn DwtOffload,
    ) {
        let b = self.b;
        let n = 2 * b;
        let l0 = cluster.l_min();
        let nl = b - l0;
        let nm = cluster.members.len();
        // ĉ with member signs folded in.
        let mut chat = vec![Complex64::zero(); nm * nl];
        for (mi, member) in cluster.members.iter().enumerate() {
            for li in 0..nl {
                let l = l0 + li;
                chat[mi * nl + li] = coeffs.at(l, member.m, member.mp).scale(member.sign(l));
            }
        }
        let rows = self.pack_rows(cluster, nl);
        let s = off
            .contract_inverse(b, nl, nm, &rows, &chat)
            .expect("offload backend failed");
        let _ = scratch;
        for (mi, member) in cluster.members.iter().enumerate() {
            let base = layout.vec_index(member.m, member.mp);
            for j in 0..n {
                let src = if member.reflected { n - 1 - j } else { j };
                // SAFETY: each (μ, μ') j-vector belongs to one cluster.
                unsafe { smat_out.write(base + j, s[mi * n + src]) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_error(b: usize, config: ExecutorConfig) -> f64 {
        let exec = Executor::new(b, config).unwrap();
        let coeffs = So3Coeffs::random(b, 42);
        let grid = exec.inverse(&coeffs).unwrap();
        let back = exec.forward(&grid).unwrap();
        coeffs.max_abs_error(&back)
    }

    #[test]
    fn roundtrip_default_config() {
        for b in [1usize, 2, 4, 8] {
            let err = roundtrip_error(b, ExecutorConfig::default());
            assert!(err < 1e-11, "b={b}: roundtrip error {err}");
        }
    }

    #[test]
    fn roundtrip_non_power_of_two_bandwidth() {
        // Exercises the Bluestein FFT path end to end.
        for b in [3usize, 5, 6] {
            let err = roundtrip_error(b, ExecutorConfig::default());
            assert!(err < 1e-11, "b={b}: roundtrip error {err}");
        }
    }

    #[test]
    fn roundtrip_all_algorithm_storage_combos() {
        for algorithm in [
            DwtAlgorithm::MatVec,
            DwtAlgorithm::MatVecFolded,
            DwtAlgorithm::Clenshaw,
        ] {
            for storage in [WignerStorage::Precomputed, WignerStorage::OnTheFly] {
                let config = ExecutorConfig {
                    algorithm,
                    storage,
                    ..Default::default()
                };
                let err = roundtrip_error(6, config);
                assert!(
                    err < 1e-11,
                    "{algorithm:?}/{storage:?}: roundtrip error {err}"
                );
            }
        }
    }

    #[test]
    fn folded_is_the_default_algorithm_and_matches_baseline() {
        assert_eq!(
            ExecutorConfig::default().algorithm,
            DwtAlgorithm::MatVecFolded
        );
        let b = 8;
        let coeffs = So3Coeffs::random(b, 19);
        let folded = Executor::new(b, ExecutorConfig::default()).unwrap();
        let baseline = Executor::new(
            b,
            ExecutorConfig {
                algorithm: DwtAlgorithm::MatVec,
                ..Default::default()
            },
        )
        .unwrap();
        let g_f = folded.inverse(&coeffs).unwrap();
        let g_b = baseline.inverse(&coeffs).unwrap();
        assert!(g_f.max_abs_error(&g_b) < 1e-12);
        let c_f = folded.forward(&g_f).unwrap();
        let c_b = baseline.forward(&g_b).unwrap();
        assert!(c_f.max_abs_error(&c_b) < 1e-12);
    }

    #[test]
    fn roundtrip_extended_precision() {
        let config = ExecutorConfig {
            precision: Precision::Extended,
            ..Default::default()
        };
        let err = roundtrip_error(6, config);
        assert!(err < 1e-12, "extended precision roundtrip error {err}");
    }

    #[test]
    fn parallel_matches_sequential_exactly() {
        // Same plan, same kernels ⇒ bit-identical outputs regardless of
        // thread count or schedule.
        let b = 8;
        let coeffs = So3Coeffs::random(b, 7);
        let seq = Executor::new(b, ExecutorConfig::default()).unwrap();
        let grid_seq = seq.inverse(&coeffs).unwrap();
        let back_seq = seq.forward(&grid_seq).unwrap();
        for threads in [2usize, 3, 4, 7] {
            for schedule in [
                Schedule::Dynamic { chunk: 1 },
                Schedule::Static,
                Schedule::Guided { min_chunk: 1 },
            ] {
                let par = Executor::new(
                    b,
                    ExecutorConfig {
                        threads,
                        schedule,
                        ..Default::default()
                    },
                )
                .unwrap();
                let grid_par = par.inverse(&coeffs).unwrap();
                assert_eq!(
                    grid_seq.as_slice(),
                    grid_par.as_slice(),
                    "inverse differs ({threads} threads, {schedule:?})"
                );
                let back_par = par.forward(&grid_par).unwrap();
                assert_eq!(
                    back_seq.as_slice(),
                    back_par.as_slice(),
                    "forward differs ({threads} threads, {schedule:?})"
                );
            }
        }
    }

    #[test]
    fn all_strategies_agree() {
        let b = 6;
        let coeffs = So3Coeffs::random(b, 11);
        let mk = |strategy| {
            let exec = Executor::new(
                b,
                ExecutorConfig {
                    strategy,
                    storage: WignerStorage::OnTheFly,
                    ..Default::default()
                },
            )
            .unwrap();
            let g = exec.inverse(&coeffs).unwrap();
            let c = exec.forward(&g).unwrap();
            (g, c)
        };
        let (g_geo, c_geo) = mk(PartitionStrategy::GeometricClustered);
        let (g_sig, c_sig) = mk(PartitionStrategy::SigmaClustered);
        let (g_non, c_non) = mk(PartitionStrategy::NoSymmetry);
        assert!(g_geo.max_abs_error(&g_sig) < 1e-13);
        assert!(g_geo.max_abs_error(&g_non) < 1e-11);
        assert!(c_geo.max_abs_error(&c_sig) < 1e-13);
        assert!(c_geo.max_abs_error(&c_non) < 1e-11);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Executor::new(0, ExecutorConfig::default()).is_err());
        assert!(Executor::new(
            4,
            ExecutorConfig {
                threads: 0,
                ..Default::default()
            }
        )
        .is_err());
        assert!(Executor::new(
            4,
            ExecutorConfig {
                algorithm: DwtAlgorithm::Clenshaw,
                precision: Precision::Extended,
                ..Default::default()
            }
        )
        .is_err());
        assert!(Executor::new(
            4,
            ExecutorConfig {
                algorithm: DwtAlgorithm::Clenshaw,
                strategy: PartitionStrategy::NoSymmetry,
                ..Default::default()
            }
        )
        .is_err());
        // Forcing an ISA the host cannot run is a typed config error,
        // not a silent scalar fallback. At most one vector ISA exists
        // per architecture, so the *other* one must always be rejected.
        let impossible = if cfg!(target_arch = "x86_64") {
            SimdPolicy::ForceNeon
        } else {
            SimdPolicy::ForceAvx2
        };
        assert!(matches!(
            Executor::new(
                4,
                ExecutorConfig {
                    simd: impossible,
                    ..Default::default()
                }
            ),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn scalar_simd_policy_matches_default_exactly() {
        // The scalar dispatch arms are the pre-SIMD loops verbatim, and
        // Auto must agree with them to full parity tolerance (bitwise
        // when Auto resolves to Scalar).
        let b = 8;
        let coeffs = So3Coeffs::random(b, 23);
        let auto = Executor::new(b, ExecutorConfig::default()).unwrap();
        let scalar = Executor::new(
            b,
            ExecutorConfig {
                simd: SimdPolicy::Scalar,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(scalar.simd_isa(), crate::simd::SimdIsa::Scalar);
        assert_eq!(auto.simd_isa(), crate::simd::detected_isa());
        let g_a = auto.inverse(&coeffs).unwrap();
        let g_s = scalar.inverse(&coeffs).unwrap();
        assert!(g_a.max_abs_error(&g_s) < 1e-12);
        let c_a = auto.forward(&g_a).unwrap();
        let c_s = scalar.forward(&g_s).unwrap();
        assert!(c_a.max_abs_error(&c_s) < 1e-12);
        if auto.simd_isa() == crate::simd::SimdIsa::Scalar {
            assert_eq!(g_a.as_slice(), g_s.as_slice());
            assert_eq!(c_a.as_slice(), c_s.as_slice());
        }
    }

    #[test]
    fn pool_resolution_matches_thread_config() {
        // Sequential executors run regions inline and own no pool.
        let seq = Executor::new(4, ExecutorConfig::default()).unwrap();
        assert!(seq.pool().is_none());
        // Parallel executors own a persistent pool of exactly `threads`
        // workers (PoolSpec::Owned default).
        let par = Executor::new(
            4,
            ExecutorConfig {
                threads: 3,
                ..Default::default()
            },
        )
        .unwrap();
        let pool = par.pool().expect("parallel executor owns a pool");
        assert_eq!(pool.threads(), 3);
        // A shared pool is reused, not copied.
        let shared = Arc::new(WorkerPool::new(2).unwrap());
        let exec = Executor::new(
            4,
            ExecutorConfig {
                threads: 2,
                pool: PoolSpec::Shared(Arc::clone(&shared)),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(Arc::ptr_eq(exec.pool().unwrap(), &shared));
    }

    #[test]
    fn shape_mismatches_rejected() {
        let exec = Executor::new(4, ExecutorConfig::default()).unwrap();
        let wrong_grid = So3Grid::zeros(5).unwrap();
        assert!(exec.forward(&wrong_grid).is_err());
        let wrong_coeffs = So3Coeffs::random(3, 1);
        assert!(exec.inverse(&wrong_coeffs).is_err());
    }

    #[test]
    fn into_variants_match_allocating_and_validate_workspace() {
        let b = 6;
        let exec = Executor::new(b, ExecutorConfig::default()).unwrap();
        let coeffs = So3Coeffs::random(b, 9);
        let (grid, _) = exec.inverse_with_stats(&coeffs).unwrap();
        let mut ws = exec.make_workspace();
        let mut out_c = So3Coeffs::zeros(b);
        exec.forward_into(&grid, &mut out_c, &mut ws).unwrap();
        let reference = exec.forward(&grid).unwrap();
        assert_eq!(out_c.as_slice(), reference.as_slice());
        let mut out_g = So3Grid::zeros(b).unwrap();
        exec.inverse_into(&coeffs, &mut out_g, &mut ws).unwrap();
        assert_eq!(out_g.as_slice(), grid.as_slice());
        // Wrong-bandwidth workspace (or outputs) are typed errors, not UB.
        let mut wrong_ws = Workspace::new(b + 1).unwrap();
        assert!(exec.forward_into(&grid, &mut out_c, &mut wrong_ws).is_err());
        assert!(exec.inverse_into(&coeffs, &mut out_g, &mut wrong_ws).is_err());
        let mut wrong_out = So3Coeffs::zeros(b + 2);
        assert!(exec.forward_into(&grid, &mut wrong_out, &mut ws).is_err());
        let mut wrong_grid_out = So3Grid::zeros(b + 2).unwrap();
        assert!(exec
            .inverse_into(&coeffs, &mut wrong_grid_out, &mut ws)
            .is_err());
    }

    #[test]
    fn radix2_baseline_engine_matches_default() {
        let b = 8;
        let coeffs = So3Coeffs::random(b, 5);
        let new_engine = Executor::new(b, ExecutorConfig::default()).unwrap();
        let baseline = Executor::new(
            b,
            ExecutorConfig {
                fft_engine: FftEngine::Radix2Baseline,
                ..Default::default()
            },
        )
        .unwrap();
        let g_new = new_engine.inverse(&coeffs).unwrap();
        let g_old = baseline.inverse(&coeffs).unwrap();
        assert!(g_new.max_abs_error(&g_old) < 1e-12);
        let c_new = new_engine.forward(&g_new).unwrap();
        let c_old = baseline.forward(&g_old).unwrap();
        assert!(c_new.max_abs_error(&c_old) < 1e-12);
    }

    #[test]
    fn real_input_mode_parity_and_typed_error() {
        let b = 4;
        let coeffs = So3Coeffs::random(b, 6);
        let complex_exec = Executor::new(b, ExecutorConfig::default()).unwrap();
        let real_exec = Executor::new(
            b,
            ExecutorConfig {
                real_input: true,
                ..Default::default()
            },
        )
        .unwrap();
        let g = complex_exec.inverse(&coeffs).unwrap();
        // Complex samples are a typed error in real-input mode.
        assert!(matches!(
            real_exec.forward(&g),
            Err(Error::RealInputRequired { .. })
        ));
        // The real part of a bandlimited function is bandlimited; the
        // conjugate-even path must agree with the complex path on it.
        let real_grid = So3Grid::from_vec(
            b,
            g.as_slice()
                .iter()
                .map(|z| Complex64::new(z.re, 0.0))
                .collect(),
        )
        .unwrap();
        let want = complex_exec.forward(&real_grid).unwrap();
        let got = real_exec.forward(&real_grid).unwrap();
        assert!(want.max_abs_error(&got) < 1e-12);
    }

    #[test]
    fn stats_are_populated() {
        let exec = Executor::new(8, ExecutorConfig::default()).unwrap();
        let coeffs = So3Coeffs::random(8, 3);
        let (grid, istats) = exec.inverse_with_stats(&coeffs).unwrap();
        let (_, fstats) = exec.forward_with_stats(&grid).unwrap();
        for s in [&istats, &fstats] {
            assert!(s.total >= s.dwt);
            assert!(s.dwt.as_nanos() > 0);
            assert!(s.dwt_region.is_some());
            let frac = s.fft_fraction();
            assert!((0.0..=1.0).contains(&frac));
            // The executor's tables are ledgered and live across the
            // call, so the steady-state peak is always nonzero.
            assert!(s.peak_bytes > 0);
        }
    }

    #[test]
    fn memory_budget_streaming_and_typed_error() {
        let b = 8;
        let ws = workspace_bytes(b);
        // Auto at tiny b: full tables, nothing streamed.
        let auto = Executor::new(b, ExecutorConfig::default()).unwrap();
        let report = auto.memory_report();
        assert_eq!(report.budget, MemoryBudget::Auto);
        assert!(!report.streamed);
        assert_eq!(report.table_bytes, report.table_bytes_full);
        assert_eq!(report.workspace_bytes, ws);
        assert_eq!(report.total_bytes(), report.table_bytes + ws);

        // A cap holding the workspace plus ~half the tables: the plan
        // builds, partially materialized, and stays under the cap.
        let cap = ws + WignerTables::full_bytes(b) / 2;
        let tight = Executor::new(
            b,
            ExecutorConfig {
                memory: MemoryBudget::Bytes(cap),
                ..Default::default()
            },
        )
        .unwrap();
        let r = tight.memory_report();
        assert!(r.streamed);
        assert!(r.table_bytes < r.table_bytes_full);
        assert!(r.total_bytes() <= cap, "{} > {cap}", r.total_bytes());
        // The streamed plan still transforms correctly...
        let coeffs = So3Coeffs::random(b, 31);
        let grid = tight.inverse(&coeffs).unwrap();
        let back = tight.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-11);
        // ...and agrees with the unlimited plan (streamed bases use
        // exact recurrence rows; table rows carry an O(B·ε)
        // reconstruction term, so parity is tolerance, not bitwise).
        let unl = Executor::new(
            b,
            ExecutorConfig {
                memory: MemoryBudget::Unlimited,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(!unl.memory_report().streamed);
        let g2 = unl.inverse(&coeffs).unwrap();
        assert!(grid.max_abs_error(&g2) < 1e-11);

        // A cap below the irreducible workspace is a typed error, not a
        // silent fallback.
        assert!(matches!(
            Executor::new(
                b,
                ExecutorConfig {
                    memory: MemoryBudget::Bytes(1024),
                    ..Default::default()
                }
            ),
            Err(Error::BudgetExceeded { .. })
        ));
    }

    #[test]
    fn memory_budget_parse_roundtrip() {
        for mb in [
            MemoryBudget::Auto,
            MemoryBudget::Unlimited,
            MemoryBudget::Bytes(123_456),
        ] {
            assert_eq!(MemoryBudget::parse(&mb.name()), Some(mb), "{mb}");
        }
        // Bare integers are MiB.
        assert_eq!(
            MemoryBudget::parse("64"),
            Some(MemoryBudget::Bytes(64 << 20))
        );
        assert_eq!(MemoryBudget::parse("bogus"), None);
        assert_eq!(MemoryBudget::parse("bytes:"), None);
        assert_eq!(MemoryBudget::default(), MemoryBudget::Auto);
    }

    /// The analysis operator applied to a single basis function must
    /// produce a single coefficient — tests forward alone against the
    /// mathematical definition (not just roundtrip consistency).
    #[test]
    fn forward_of_pure_basis_function() {
        use crate::so3::wigner::d_single;
        let b = 4usize;
        let n = 2 * b;
        let exec = Executor::new(b, ExecutorConfig::default()).unwrap();
        let angles = GridAngles::new(b).unwrap();
        let (l, m, mp) = (2usize, 1i64, -2i64);
        let mut grid = So3Grid::zeros(b).unwrap();
        for j in 0..n {
            let d = d_single(l, m, mp, angles.betas[j]);
            for i in 0..n {
                for k in 0..n {
                    let phase = -(m as f64 * angles.alphas[i] + mp as f64 * angles.gammas[k]);
                    grid.set(i, j, k, Complex64::cis(phase).scale(d));
                }
            }
        }
        let coeffs = exec.forward(&grid).unwrap();
        for (ll, mm, mmp, v) in coeffs.iter() {
            let want = if (ll, mm, mmp) == (l, m, mp) { 1.0 } else { 0.0 };
            assert!(
                (v - Complex64::new(want, 0.0)).abs() < 1e-12,
                "coeff ({ll},{mm},{mmp}) = {v}, want {want}"
            );
        }
    }
}

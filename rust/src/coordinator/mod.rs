//! The parallel coordinator — the paper's contribution (Section 3).
//!
//! * [`partition`] — the two order-domain index maps: the sqrt-based σ
//!   map (paper Eq. 7/8, the baseline) and the geometric
//!   triangle→rectangle κ map (paper Fig. 1) that reconstructs (m, m')
//!   with integer ops only.
//! * [`plan`] — builds the ordered work-package list (symmetry clusters,
//!   with the m=0 / m'=0 / m=m' specials "treated in advance") for a
//!   bandwidth and partitioning strategy.
//! * [`exec`] — the three-stage parallel FSOFT/iFSOFT executor: per-slice
//!   2-D FFT region, transposition region, DWT-cluster region, all run
//!   over the worker pool with the configured schedule.

pub mod exec;
pub mod partition;
pub mod plan;

pub use exec::{
    workspace_bytes, Executor, ExecutorConfig, MemoryBudget, MemoryReport, StageStats,
    TransformStats, Workspace,
};
pub use plan::{PartitionStrategy, TransformPlan};

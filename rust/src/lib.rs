//! # so3ft — parallel fast Fourier transforms on the rotation group SO(3)
//!
//! A production-grade reproduction of
//! *Lux, Wülker & Chirikjian, “Parallelization of the FFT on SO(3)” (2018)*,
//! which parallelizes Kostelec & Rockmore's fast SO(3) Fourier transform
//! (FSOFT) and its inverse (iFSOFT).
//!
//! The crate is the L3 (coordination) layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: symmetry-clustered
//!   partitioning of the discrete Wigner transforms (DWTs), the geometric
//!   triangle→rectangle index mapping of the order domain, and dynamic
//!   self-scheduling over a thread pool ([`coordinator`], [`pool`]); plus
//!   every substrate the transforms need: an FFT library ([`fft`]),
//!   Wigner-d functions, quadrature and sampling ([`so3`]), the DWT itself
//!   ([`dwt`]), sequential reference transforms ([`transform`]), a
//!   multicore execution simulator ([`simulator`]), and an application
//!   layer ([`apps`]).
//! * **L2/L1 (build time, `python/compile/`)** — the DWT contraction as a
//!   JAX graph wrapping a Pallas kernel, AOT-lowered to HLO text per
//!   bandwidth. The [`runtime`] module loads those artifacts through PJRT
//!   and exposes them as an alternative DWT backend; Python is never on
//!   the request path.
//!
//! ## Quickstart
//!
//! Plan once, execute many times (the FFTW model): [`transform::So3Plan`]
//! owns the precomputed Wigner tables, partition plan, and FFT twiddles;
//! execution goes through caller-owned buffers and a reusable
//! [`transform::Workspace`], so the serving path performs **zero**
//! grid/coefficient allocation per transform.
//!
//! ```no_run
//! use so3ft::transform::So3Plan;
//! use so3ft::so3::coeffs::So3Coeffs;
//! use so3ft::so3::sampling::So3Grid;
//!
//! let b = 16; // bandwidth (power of two on the strict planner path)
//! let plan = So3Plan::builder(b).threads(4).build().unwrap();
//!
//! // One-off (allocating) conveniences:
//! let coeffs = So3Coeffs::random(b, 42);
//! let grid = plan.inverse(&coeffs).unwrap();  // synthesis (iFSOFT)
//! let back = plan.forward(&grid).unwrap();    // analysis  (FSOFT)
//! assert!(coeffs.max_abs_error(&back) < 1e-10);
//!
//! // Serving path: caller-owned buffers, no allocation per call.
//! let mut ws = plan.make_workspace();
//! let mut grid_buf = So3Grid::zeros(b).unwrap();
//! let mut coeff_buf = So3Coeffs::zeros(b);
//! plan.inverse_into(&coeffs, &mut grid_buf, &mut ws).unwrap();
//! plan.forward_into(&grid_buf, &mut coeff_buf, &mut ws).unwrap();
//!
//! // Batches amortize the workspace across many signals:
//! let batch: Vec<So3Coeffs> = (0..8).map(|i| So3Coeffs::random(b, i)).collect();
//! let grids = plan.inverse_batch(&batch).unwrap();
//! assert_eq!(grids.len(), 8);
//! ```
//!
//! The pre-planner handle `transform::So3Fft` remains as a soft-deprecated
//! facade over `So3Plan`; see `docs/MIGRATION.md`.

pub mod apps;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dwt;
pub mod error;
pub mod fft;
pub mod pool;
pub mod prng;
pub mod runtime;
pub mod simulator;
pub mod so3;
pub mod testkit;
pub mod transform;
pub mod util;
pub mod xprec;

pub use error::{Error, Result};
pub use fft::complex::Complex64;

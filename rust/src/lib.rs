//! # so3ft — parallel fast Fourier transforms on the rotation group SO(3)
//!
//! A production-grade reproduction of
//! *Lux, Wülker & Chirikjian, “Parallelization of the FFT on SO(3)” (2018)*,
//! which parallelizes Kostelec & Rockmore's fast SO(3) Fourier transform
//! (FSOFT) and its inverse (iFSOFT).
//!
//! The crate is the L3 (coordination) layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: symmetry-clustered
//!   partitioning of the discrete Wigner transforms (DWTs), the geometric
//!   triangle→rectangle index mapping of the order domain, and dynamic
//!   self-scheduling over a thread pool ([`coordinator`], [`pool`]); plus
//!   every substrate the transforms need: an FFT library ([`fft`]),
//!   Wigner-d functions, quadrature and sampling ([`so3`]), the DWT itself
//!   ([`dwt`]), sequential reference transforms ([`transform`]), a
//!   multicore execution simulator ([`simulator`]), and an application
//!   layer ([`apps`]).
//! * **L2/L1 (build time, `python/compile/`)** — the DWT contraction as a
//!   JAX graph wrapping a Pallas kernel, AOT-lowered to HLO text per
//!   bandwidth. The [`runtime`] module loads those artifacts through PJRT
//!   and exposes them as an alternative DWT backend; Python is never on
//!   the request path.
//!
//! ## Quickstart — the serving front door
//!
//! [`service::So3Service`] is the documented entry point: one object
//! that owns a shared worker pool, a registry of lazily-built plans
//! keyed by `(bandwidth, options)`, and a workspace/buffer pool — so
//! many concurrent callers at mixed bandwidths share one substrate and
//! the steady state allocates nothing per job. Same-key jobs arriving
//! within the configured batch window are micro-batched (bit-identical
//! to per-job execution).
//!
//! ```no_run
//! use so3ft::service::{JobSpec, So3Service};
//! use so3ft::so3::coeffs::So3Coeffs;
//!
//! let service = So3Service::builder().threads(4).build().unwrap();
//!
//! // Blocking conveniences (bandwidth comes from the payload):
//! let coeffs = So3Coeffs::random(16, 42);
//! let grid = service.inverse(coeffs).unwrap();       // synthesis (iFSOFT)
//! let back = service.forward(grid).unwrap();         // analysis  (FSOFT)
//!
//! // The async job API — submit from any thread, wait on the handle:
//! let grid = service.inverse(back).unwrap();
//! let handle = service.submit(JobSpec::forward(16), grid).unwrap();
//! let out = handle.wait().unwrap().into_coeffs().unwrap();
//! service.recycle_coeffs(out); // return buffers for the zero-alloc steady state
//! ```
//!
//! ## The power-user path
//!
//! [`transform::So3Plan`] stays the explicit planner/session API (the
//! FFTW model): plan once per `(bandwidth, config)`, execute
//! allocation-free through caller-owned buffers and a reusable
//! [`transform::Workspace`] (`forward_into` / `inverse_into`, batch
//! variants). `So3Service::plan` hands out the registry's shared
//! `Arc<So3Plan>` when you want both worlds.
//!
//! ```no_run
//! use so3ft::transform::So3Plan;
//! use so3ft::so3::coeffs::So3Coeffs;
//! use so3ft::so3::sampling::So3Grid;
//!
//! let b = 16; // bandwidth (power of two on the strict planner path)
//! let plan = So3Plan::builder(b).threads(4).build().unwrap();
//! let mut ws = plan.make_workspace();
//! let coeffs = So3Coeffs::random(b, 42);
//! let mut grid_buf = So3Grid::zeros(b).unwrap();
//! let mut coeff_buf = So3Coeffs::zeros(b);
//! plan.inverse_into(&coeffs, &mut grid_buf, &mut ws).unwrap();
//! plan.forward_into(&grid_buf, &mut coeff_buf, &mut ws).unwrap();
//! assert!(coeffs.max_abs_error(&coeff_buf) < 1e-10);
//! ```
//!
//! The pre-planner handle `transform::So3Fft` is **deprecated** (a thin
//! facade over `So3Plan`); see `docs/MIGRATION.md`.

// Concurrency-soundness gates (see docs/CONCURRENCY.md): every unsafe
// operation must sit inside an explicit `unsafe {}` block with its own
// `// SAFETY:` justification, even inside `unsafe fn` bodies; and every
// public item carries docs so the unsafe/atomic contracts stay written
// down next to the API they protect.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_docs)]

pub mod apps;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dwt;
pub mod error;
pub mod faults;
pub mod fft;
pub mod pool;
pub mod prng;
pub mod runtime;
#[cfg(feature = "sched-test")]
pub mod schedtest;
pub mod service;
pub mod simd;
pub mod simulator;
pub mod so3;
pub mod testkit;
pub mod transform;
pub mod transpose;
pub mod util;
pub mod wisdom;
pub mod xprec;

/// Named concurrency yield point for the deterministic schedule
/// explorer (the `schedtest` module, `sched-test` feature).
///
/// Placed at the decision points of the crate's concurrent state
/// machines (registry single-flight, admission, dispatcher, worker
/// pool, shutdown drain). Without the `sched-test` feature the macro
/// expands to **nothing** — not even an atomic load — so instrumented
/// hot paths cost zero in release builds. With the feature, the point
/// hands control to an installed `schedtest::Controller`, which decides
/// which instrumented thread runs next.
#[macro_export]
macro_rules! sched_point {
    ($name:expr) => {
        #[cfg(feature = "sched-test")]
        $crate::schedtest::point($name);
    };
}

pub use coordinator::{MemoryBudget, MemoryReport};
pub use error::{Error, Result};
pub use fft::complex::Complex64;
pub use service::So3Service;

//! # so3ft — parallel fast Fourier transforms on the rotation group SO(3)
//!
//! A production-grade reproduction of
//! *Lux, Wülker & Chirikjian, “Parallelization of the FFT on SO(3)” (2018)*,
//! which parallelizes Kostelec & Rockmore's fast SO(3) Fourier transform
//! (FSOFT) and its inverse (iFSOFT).
//!
//! The crate is the L3 (coordination) layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: symmetry-clustered
//!   partitioning of the discrete Wigner transforms (DWTs), the geometric
//!   triangle→rectangle index mapping of the order domain, and dynamic
//!   self-scheduling over a thread pool ([`coordinator`], [`pool`]); plus
//!   every substrate the transforms need: an FFT library ([`fft`]),
//!   Wigner-d functions, quadrature and sampling ([`so3`]), the DWT itself
//!   ([`dwt`]), sequential reference transforms ([`transform`]), a
//!   multicore execution simulator ([`simulator`]), and an application
//!   layer ([`apps`]).
//! * **L2/L1 (build time, `python/compile/`)** — the DWT contraction as a
//!   JAX graph wrapping a Pallas kernel, AOT-lowered to HLO text per
//!   bandwidth. The [`runtime`] module loads those artifacts through PJRT
//!   and exposes them as an alternative DWT backend; Python is never on
//!   the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use so3ft::transform::So3Fft;
//! use so3ft::so3::coeffs::So3Coeffs;
//!
//! let b = 16; // bandwidth
//! let fft = So3Fft::new(b).unwrap();
//! let mut coeffs = So3Coeffs::random(b, 42);
//! let grid = fft.inverse(&coeffs).unwrap();   // synthesis  (iFSOFT)
//! let back = fft.forward(&grid).unwrap();     // analysis   (FSOFT)
//! let err = coeffs.max_abs_error(&back);
//! assert!(err < 1e-10);
//! ```

pub mod apps;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dwt;
pub mod error;
pub mod fft;
pub mod pool;
pub mod prng;
pub mod runtime;
pub mod simulator;
pub mod so3;
pub mod testkit;
pub mod transform;
pub mod util;
pub mod xprec;

pub use error::{Error, Result};
pub use fft::complex::Complex64;

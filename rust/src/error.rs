//! Crate-wide error type (hand-rolled `Display`/`Error` impls — no
//! derive crates are available offline).

use std::fmt;
use std::time::Duration;

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Which admission limit rejected a job (see [`Error::Overloaded`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OverloadCause {
    /// The bounded queue is full (`max_queue`).
    QueueDepth,
    /// Admitting the payload would exceed `max_inflight_bytes`.
    InflightBytes,
    /// The submitting tenant is at its `tenant_quota`.
    TenantQuota,
}

impl fmt::Display for OverloadCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            OverloadCause::QueueDepth => "queue depth",
            OverloadCause::InflightBytes => "in-flight bytes",
            OverloadCause::TenantQuota => "tenant quota",
        })
    }
}

/// Errors surfaced by the SO(3) transform stack.
#[derive(Debug)]
pub enum Error {
    /// Bandwidth outside the supported range (must be ≥ 1).
    InvalidBandwidth(usize),

    /// Bandwidth rejected by the strict planner builder: the serving path
    /// requires a power of two (radix-2 FFT grid edge, table alignment).
    NonPowerOfTwoBandwidth(usize),

    /// A buffer had the wrong length for the requested bandwidth.
    ShapeMismatch {
        /// Element count the operation required.
        expected: usize,
        /// Element count actually supplied.
        got: usize,
        /// Which buffer or call site failed the check.
        context: &'static str,
    },

    /// An input, output, or workspace was built for a different bandwidth
    /// than the plan executing it (the values are bandwidths, not element
    /// counts).
    BandwidthMismatch {
        /// Bandwidth the plan was built for.
        expected: usize,
        /// Bandwidth of the offending buffer.
        got: usize,
        /// Which buffer or call site failed the check.
        context: &'static str,
    },

    /// An (l, m, m') index outside the coefficient domain.
    IndexOutOfRange {
        /// Requested degree.
        l: i64,
        /// Requested order m.
        m: i64,
        /// Requested order m'.
        mp: i64,
        /// Bandwidth bounding the domain.
        b: usize,
    },

    /// A plan built in `real_input` mode received data with nonzero
    /// imaginary parts (the conjugate-even FFT path is only valid for
    /// real samples).
    RealInputRequired {
        /// Which call site rejected the data.
        context: &'static str,
    },

    /// Thread-count request the pool cannot satisfy.
    InvalidThreads(usize),

    /// A `MemoryBudget::Bytes` cap that the plan cannot fit under even
    /// with every table streamed — the irreducible part (`context` says
    /// which) alone exceeds the cap. Raised at plan build, never as a
    /// silent fallback.
    BudgetExceeded {
        /// Bytes the irreducible part needs.
        required: usize,
        /// The configured cap in bytes.
        budget: usize,
        /// Which component could not fit.
        context: &'static str,
    },

    /// Job-service problems: a payload that does not match the job
    /// direction, a submission to a shut-down service, or a batch whose
    /// plan could not be built (the build error is embedded in the
    /// message, once per affected job).
    Service(String),

    /// Admission control rejected the job: the service is saturated.
    /// `retry_after_hint` estimates when the backlog will have drained
    /// (queued work × the observed per-job rate) — a cooperative client
    /// backs off at least that long before resubmitting.
    Overloaded {
        /// Which admission limit rejected the job.
        cause: OverloadCause,
        /// Estimated backlog-drain time; back off at least this long.
        retry_after_hint: Duration,
    },

    /// The job's (relative) deadline expired while it was still queued;
    /// the dispatcher resolved it without executing it.
    DeadlineExceeded {
        /// The relative deadline the job was submitted with.
        deadline: Duration,
    },

    /// The job was cancelled via `JobHandle::cancel` before dispatch.
    Cancelled,

    /// A drain-with-deadline shutdown (`So3Service::shutdown`) hit its
    /// deadline while this job was still queued.
    ShutdownDrain,

    /// An armed fault fired at a named injection site (see
    /// [`crate::faults`]). Only ever produced when faults are explicitly
    /// armed — chaos tests and `serve-bench --inject`.
    FaultInjected {
        /// The injection site that fired.
        site: String,
        /// The armed fault's message.
        msg: String,
    },

    /// A recent plan build for this registry key failed; the registry
    /// serves the cached failure without rebuilding until the
    /// exponential backoff elapses (`retry_in`).
    PlanBuildFailed {
        /// The original build error, rendered.
        msg: String,
        /// Consecutive failed build attempts for this key.
        attempts: u32,
        /// Time until the registry will try building again.
        retry_in: Duration,
    },

    /// Configuration file / CLI parsing problems.
    Config(String),

    /// PJRT / XLA runtime problems (artifact loading, compilation, execution).
    Runtime(String),

    /// Requested AOT artifact is not present on disk.
    MissingArtifact {
        /// Bandwidth the artifact would serve.
        b: usize,
        /// Path that was probed.
        path: String,
    },

    /// I/O errors (artifact files, config files, trace dumps).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidBandwidth(b) => {
                write!(f, "invalid bandwidth {b}: must be >= 1")
            }
            Error::NonPowerOfTwoBandwidth(b) => {
                write!(
                    f,
                    "invalid bandwidth {b}: So3Plan requires a power of two \
                     (use So3PlanBuilder::allow_any_bandwidth for the Bluestein path)"
                )
            }
            Error::ShapeMismatch {
                expected,
                got,
                context,
            } => write!(
                f,
                "shape mismatch: expected {expected} elements, got {got} ({context})"
            ),
            Error::BandwidthMismatch {
                expected,
                got,
                context,
            } => write!(
                f,
                "bandwidth mismatch: expected {expected}, got {got} ({context})"
            ),
            Error::IndexOutOfRange { l, m, mp, b } => write!(
                f,
                "coefficient index out of range: l={l}, m={m}, m'={mp} (bandwidth {b})"
            ),
            Error::RealInputRequired { context } => write!(
                f,
                "real-input plan received complex data ({context}); drop \
                 `real_input()` from the builder or zero the imaginary parts"
            ),
            Error::InvalidThreads(t) => {
                write!(f, "invalid thread count {t}: must be >= 1")
            }
            Error::BudgetExceeded {
                required,
                budget,
                context,
            } => write!(
                f,
                "memory budget exceeded ({context}): needs {required} bytes, \
                 budget is {budget} bytes"
            ),
            Error::Service(msg) => write!(f, "service error: {msg}"),
            Error::Overloaded {
                cause,
                retry_after_hint,
            } => write!(
                f,
                "service overloaded ({cause}); retry after ~{}ms",
                retry_after_hint.as_millis()
            ),
            Error::DeadlineExceeded { deadline } => write!(
                f,
                "job deadline of {}ms expired before dispatch",
                deadline.as_millis()
            ),
            Error::Cancelled => write!(f, "job cancelled before dispatch"),
            Error::ShutdownDrain => write!(
                f,
                "service shut down before the job was dispatched \
                 (drain deadline reached)"
            ),
            Error::FaultInjected { site, msg } => {
                write!(f, "injected fault at {site}: {msg}")
            }
            Error::PlanBuildFailed {
                msg,
                attempts,
                retry_in,
            } => write!(
                f,
                "plan build failed ({attempts} attempt(s), cached): {msg}; \
                 next retry allowed in ~{}ms",
                retry_in.as_millis()
            ),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Runtime(msg) => write!(f, "xla runtime error: {msg}"),
            Error::MissingArtifact { b, path } => write!(
                f,
                "missing artifact for bandwidth {b}: {path} (run `make artifacts`)"
            ),
            Error::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Helper for shape checks.
    pub fn shape(expected: usize, got: usize, context: &'static str) -> Self {
        Error::ShapeMismatch {
            expected,
            got,
            context,
        }
    }

    /// Helper for bandwidth checks.
    pub fn bandwidth(expected: usize, got: usize, context: &'static str) -> Self {
        Error::BandwidthMismatch {
            expected,
            got,
            context,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_descriptive() {
        assert!(Error::InvalidBandwidth(0).to_string().contains("bandwidth 0"));
        assert!(Error::NonPowerOfTwoBandwidth(12)
            .to_string()
            .contains("power of two"));
        assert!(Error::InvalidThreads(0).to_string().contains("thread count 0"));
        let budget = Error::BudgetExceeded {
            required: 1024,
            budget: 512,
            context: "irreducible transform workspace",
        }
        .to_string();
        assert!(budget.contains("memory budget exceeded"));
        assert!(budget.contains("1024") && budget.contains("512"));
        assert!(budget.contains("workspace"));
        assert!(Error::Service("queue closed".into())
            .to_string()
            .contains("queue closed"));
        assert!(Error::shape(4, 5, "ctx").to_string().contains("ctx"));
        assert!(Error::RealInputRequired { context: "forward" }
            .to_string()
            .contains("real-input"));
        let bw = Error::bandwidth(8, 16, "workspace bandwidth").to_string();
        assert!(bw.contains("bandwidth mismatch") && bw.contains("workspace"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
    }

    #[test]
    fn overload_and_failure_variants_display_their_fields() {
        let overloaded = Error::Overloaded {
            cause: OverloadCause::QueueDepth,
            retry_after_hint: Duration::from_millis(25),
        }
        .to_string();
        assert!(overloaded.contains("overloaded"));
        assert!(overloaded.contains("queue depth"));
        assert!(overloaded.contains("25"));
        assert_eq!(OverloadCause::InflightBytes.to_string(), "in-flight bytes");
        assert_eq!(OverloadCause::TenantQuota.to_string(), "tenant quota");
        let deadline = Error::DeadlineExceeded {
            deadline: Duration::from_millis(50),
        }
        .to_string();
        assert!(deadline.contains("deadline") && deadline.contains("50"));
        assert!(Error::Cancelled.to_string().contains("cancelled"));
        assert!(Error::ShutdownDrain.to_string().contains("shut down"));
        let fault = Error::FaultInjected {
            site: "plan-build".into(),
            msg: "chaos".into(),
        }
        .to_string();
        assert!(fault.contains("plan-build") && fault.contains("chaos"));
        let cached = Error::PlanBuildFailed {
            msg: "bad table".into(),
            attempts: 3,
            retry_in: Duration::from_millis(400),
        }
        .to_string();
        assert!(cached.contains("bad table"));
        assert!(cached.contains("3 attempt"));
        assert!(cached.contains("400"));
    }
}

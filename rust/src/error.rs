//! Crate-wide error type (hand-rolled `Display`/`Error` impls — no
//! derive crates are available offline).

use std::fmt;

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the SO(3) transform stack.
#[derive(Debug)]
pub enum Error {
    /// Bandwidth outside the supported range (must be ≥ 1).
    InvalidBandwidth(usize),

    /// Bandwidth rejected by the strict planner builder: the serving path
    /// requires a power of two (radix-2 FFT grid edge, table alignment).
    NonPowerOfTwoBandwidth(usize),

    /// A buffer had the wrong length for the requested bandwidth.
    ShapeMismatch {
        expected: usize,
        got: usize,
        context: &'static str,
    },

    /// An input, output, or workspace was built for a different bandwidth
    /// than the plan executing it (the values are bandwidths, not element
    /// counts).
    BandwidthMismatch {
        expected: usize,
        got: usize,
        context: &'static str,
    },

    /// An (l, m, m') index outside the coefficient domain.
    IndexOutOfRange { l: i64, m: i64, mp: i64, b: usize },

    /// A plan built in `real_input` mode received data with nonzero
    /// imaginary parts (the conjugate-even FFT path is only valid for
    /// real samples).
    RealInputRequired { context: &'static str },

    /// Thread-count request the pool cannot satisfy.
    InvalidThreads(usize),

    /// A `MemoryBudget::Bytes` cap that the plan cannot fit under even
    /// with every table streamed — the irreducible part (`context` says
    /// which) alone exceeds the cap. Raised at plan build, never as a
    /// silent fallback.
    BudgetExceeded {
        required: usize,
        budget: usize,
        context: &'static str,
    },

    /// Job-service problems: a payload that does not match the job
    /// direction, a submission to a shut-down service, or a batch whose
    /// plan could not be built (the build error is embedded in the
    /// message, once per affected job).
    Service(String),

    /// Configuration file / CLI parsing problems.
    Config(String),

    /// PJRT / XLA runtime problems (artifact loading, compilation, execution).
    Runtime(String),

    /// Requested AOT artifact is not present on disk.
    MissingArtifact { b: usize, path: String },

    /// I/O errors (artifact files, config files, trace dumps).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidBandwidth(b) => {
                write!(f, "invalid bandwidth {b}: must be >= 1")
            }
            Error::NonPowerOfTwoBandwidth(b) => {
                write!(
                    f,
                    "invalid bandwidth {b}: So3Plan requires a power of two \
                     (use So3PlanBuilder::allow_any_bandwidth for the Bluestein path)"
                )
            }
            Error::ShapeMismatch {
                expected,
                got,
                context,
            } => write!(
                f,
                "shape mismatch: expected {expected} elements, got {got} ({context})"
            ),
            Error::BandwidthMismatch {
                expected,
                got,
                context,
            } => write!(
                f,
                "bandwidth mismatch: expected {expected}, got {got} ({context})"
            ),
            Error::IndexOutOfRange { l, m, mp, b } => write!(
                f,
                "coefficient index out of range: l={l}, m={m}, m'={mp} (bandwidth {b})"
            ),
            Error::RealInputRequired { context } => write!(
                f,
                "real-input plan received complex data ({context}); drop \
                 `real_input()` from the builder or zero the imaginary parts"
            ),
            Error::InvalidThreads(t) => {
                write!(f, "invalid thread count {t}: must be >= 1")
            }
            Error::BudgetExceeded {
                required,
                budget,
                context,
            } => write!(
                f,
                "memory budget exceeded ({context}): needs {required} bytes, \
                 budget is {budget} bytes"
            ),
            Error::Service(msg) => write!(f, "service error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Runtime(msg) => write!(f, "xla runtime error: {msg}"),
            Error::MissingArtifact { b, path } => write!(
                f,
                "missing artifact for bandwidth {b}: {path} (run `make artifacts`)"
            ),
            Error::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Helper for shape checks.
    pub fn shape(expected: usize, got: usize, context: &'static str) -> Self {
        Error::ShapeMismatch {
            expected,
            got,
            context,
        }
    }

    /// Helper for bandwidth checks.
    pub fn bandwidth(expected: usize, got: usize, context: &'static str) -> Self {
        Error::BandwidthMismatch {
            expected,
            got,
            context,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_descriptive() {
        assert!(Error::InvalidBandwidth(0).to_string().contains("bandwidth 0"));
        assert!(Error::NonPowerOfTwoBandwidth(12)
            .to_string()
            .contains("power of two"));
        assert!(Error::InvalidThreads(0).to_string().contains("thread count 0"));
        let budget = Error::BudgetExceeded {
            required: 1024,
            budget: 512,
            context: "irreducible transform workspace",
        }
        .to_string();
        assert!(budget.contains("memory budget exceeded"));
        assert!(budget.contains("1024") && budget.contains("512"));
        assert!(budget.contains("workspace"));
        assert!(Error::Service("queue closed".into())
            .to_string()
            .contains("queue closed"));
        assert!(Error::shape(4, 5, "ctx").to_string().contains("ctx"));
        assert!(Error::RealInputRequired { context: "forward" }
            .to_string()
            .contains("real-input"));
        let bw = Error::bandwidth(8, 16, "workspace bandwidth").to_string();
        assert!(bw.contains("bandwidth mismatch") && bw.contains("workspace"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
    }
}

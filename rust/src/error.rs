//! Crate-wide error type.

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the SO(3) transform stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Bandwidth outside the supported range (must be ≥ 1).
    #[error("invalid bandwidth {0}: must be >= 1")]
    InvalidBandwidth(usize),

    /// A buffer had the wrong length for the requested bandwidth.
    #[error("shape mismatch: expected {expected} elements, got {got} ({context})")]
    ShapeMismatch {
        expected: usize,
        got: usize,
        context: &'static str,
    },

    /// An (l, m, m') index outside the coefficient domain.
    #[error("coefficient index out of range: l={l}, m={m}, m'={mp} (bandwidth {b})")]
    IndexOutOfRange { l: i64, m: i64, mp: i64, b: usize },

    /// Thread-count request the pool cannot satisfy.
    #[error("invalid thread count {0}: must be >= 1")]
    InvalidThreads(usize),

    /// Configuration file / CLI parsing problems.
    #[error("config error: {0}")]
    Config(String),

    /// PJRT / XLA runtime problems (artifact loading, compilation, execution).
    #[error("xla runtime error: {0}")]
    Runtime(String),

    /// Requested AOT artifact is not present on disk.
    #[error("missing artifact for bandwidth {b}: {path} (run `make artifacts`)")]
    MissingArtifact { b: usize, path: String },

    /// I/O errors (artifact files, config files, trace dumps).
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Helper for shape checks.
    pub fn shape(expected: usize, got: usize, context: &'static str) -> Self {
        Error::ShapeMismatch {
            expected,
            got,
            context,
        }
    }
}

//! The user-facing transform handle.
//!
//! [`So3Fft`] wraps a prepared executor; [`So3FftBuilder`] is the fluent
//! configuration surface (threads, schedule, DWT algorithm, storage,
//! precision, partitioning — every design axis the paper discusses).
//!
//! ```no_run
//! use so3ft::transform::So3Fft;
//! use so3ft::so3::coeffs::So3Coeffs;
//!
//! let fft = So3Fft::builder(16).threads(4).build().unwrap();
//! let coeffs = So3Coeffs::random(16, 1);
//! let grid = fft.inverse(&coeffs).unwrap();
//! let back = fft.forward(&grid).unwrap();
//! assert!(coeffs.max_abs_error(&back) < 1e-10);
//! ```

use std::sync::Arc;

use crate::coordinator::exec::DwtOffload;
use crate::coordinator::{Executor, ExecutorConfig, PartitionStrategy, TransformStats};
use crate::dwt::tables::WignerStorage;
use crate::dwt::{DwtAlgorithm, Precision};
use crate::error::Result;
use crate::pool::Schedule;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;

/// A prepared fast SO(3) Fourier transform (FSOFT + iFSOFT) for one
/// bandwidth.
pub struct So3Fft {
    exec: Executor,
}

impl So3Fft {
    /// Default configuration (sequential, paper defaults).
    pub fn new(b: usize) -> Result<Self> {
        Self::builder(b).build()
    }

    /// Start configuring a transform.
    pub fn builder(b: usize) -> So3FftBuilder {
        So3FftBuilder {
            b,
            config: ExecutorConfig::default(),
            offload: None,
        }
    }

    /// Analysis (FSOFT): grid samples → Fourier coefficients.
    pub fn forward(&self, grid: &So3Grid) -> Result<So3Coeffs> {
        self.exec.forward(grid)
    }

    /// Synthesis (iFSOFT): Fourier coefficients → grid samples.
    pub fn inverse(&self, coeffs: &So3Coeffs) -> Result<So3Grid> {
        self.exec.inverse(coeffs)
    }

    /// Analysis with a wall-clock phase breakdown.
    pub fn forward_with_stats(&self, grid: &So3Grid) -> Result<(So3Coeffs, TransformStats)> {
        self.exec.forward_with_stats(grid)
    }

    /// Synthesis with a wall-clock phase breakdown.
    pub fn inverse_with_stats(
        &self,
        coeffs: &So3Coeffs,
    ) -> Result<(So3Grid, TransformStats)> {
        self.exec.inverse_with_stats(coeffs)
    }

    pub fn bandwidth(&self) -> usize {
        self.exec.bandwidth()
    }

    /// The underlying executor (plans, weights, diagnostics).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }
}

/// Fluent configuration for [`So3Fft`].
pub struct So3FftBuilder {
    b: usize,
    config: ExecutorConfig,
    offload: Option<Arc<dyn DwtOffload>>,
}

impl So3FftBuilder {
    /// Worker thread count (1 = the sequential algorithm).
    pub fn threads(mut self, threads: usize) -> Self {
        self.config.threads = threads;
        self
    }

    /// DWT-loop schedule (paper default: `dynamic`).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.config.schedule = schedule;
        self
    }

    /// Order-domain partitioning strategy.
    pub fn strategy(mut self, strategy: PartitionStrategy) -> Self {
        self.config.strategy = strategy;
        self
    }

    /// DWT dataflow (matvec = paper's benchmarked version; clenshaw =
    /// the paper's announced follow-up).
    pub fn algorithm(mut self, algorithm: DwtAlgorithm) -> Self {
        self.config.algorithm = algorithm;
        self
    }

    /// Wigner row storage (precomputed tables vs on-the-fly recurrence).
    pub fn storage(mut self, storage: WignerStorage) -> Self {
        self.config.storage = storage;
        self
    }

    /// DWT accumulation precision (extended ≈ the paper's 80-bit mode).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.config.precision = precision;
        self
    }

    /// Attach a DWT offload backend (the PJRT/XLA runtime).
    pub fn offload(mut self, offload: Arc<dyn DwtOffload>) -> Self {
        self.offload = Some(offload);
        self
    }

    /// Full config override.
    pub fn config(mut self, config: ExecutorConfig) -> Self {
        self.config = config;
        self
    }

    pub fn build(self) -> Result<So3Fft> {
        let mut exec = Executor::new(self.b, self.config)?;
        if let Some(off) = self.offload {
            exec = exec.with_offload(off);
        }
        Ok(So3Fft { exec })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_roundtrip_with_options() {
        let fft = So3Fft::builder(6)
            .threads(2)
            .schedule(Schedule::Dynamic { chunk: 2 })
            .algorithm(DwtAlgorithm::Clenshaw)
            .storage(WignerStorage::OnTheFly)
            .build()
            .unwrap();
        assert_eq!(fft.bandwidth(), 6);
        let coeffs = So3Coeffs::random(6, 5);
        let grid = fft.inverse(&coeffs).unwrap();
        let back = fft.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-11);
    }

    #[test]
    fn doc_example_works() {
        let fft = So3Fft::builder(8).threads(2).build().unwrap();
        let coeffs = So3Coeffs::random(8, 1);
        let grid = fft.inverse(&coeffs).unwrap();
        let back = fft.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-10);
    }

    #[test]
    fn invalid_builder_combo_errors() {
        let r = So3Fft::builder(4)
            .algorithm(DwtAlgorithm::Clenshaw)
            .precision(Precision::Extended)
            .build();
        assert!(r.is_err());
    }
}

//! The legacy transform handle — a thin facade over [`So3Plan`].
//!
//! [`So3Fft`] predates the planner/session API and is now **formally
//! deprecated** (`#[deprecated]`, still fully working) so remaining
//! callers migrate (see `docs/MIGRATION.md`). New code should use
//! [`crate::transform::So3Plan`] (the power-user path: same
//! configuration axes plus the allocation-free `*_into` and batch entry
//! points) or [`crate::service::So3Service`] (the serving front door).
//! Bit-for-bit facade/plan parity is pinned by
//! `rust/tests/plan_api.rs::facade_parity_with_plan`.
//!
//! Unlike the strict [`So3PlanBuilder`](crate::transform::So3PlanBuilder),
//! this facade accepts non-power-of-two bandwidths (the historical
//! behavior, served by the Bluestein FFT fallback).
//!
//! ```no_run
//! use so3ft::transform::So3Fft;
//! use so3ft::so3::coeffs::So3Coeffs;
//!
//! let fft = So3Fft::builder(16).threads(4).build().unwrap();
//! let coeffs = So3Coeffs::random(16, 1);
//! let grid = fft.inverse(&coeffs).unwrap();
//! let back = fft.forward(&grid).unwrap();
//! assert!(coeffs.max_abs_error(&back) < 1e-10);
//! ```

use std::sync::Arc;

use crate::coordinator::exec::DwtOffload;
use crate::coordinator::{
    Executor, ExecutorConfig, PartitionStrategy, TransformStats, Workspace,
};
use crate::dwt::tables::WignerStorage;
use crate::dwt::{DwtAlgorithm, Precision};
use crate::error::Result;
use crate::pool::Schedule;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;
use crate::transform::plan::{So3Plan, Transform};

/// A prepared fast SO(3) Fourier transform (FSOFT + iFSOFT) for one
/// bandwidth. Deprecated facade over [`So3Plan`].
#[deprecated(
    since = "0.6.0",
    note = "use So3Plan (explicit planning) or So3Service (serving front door)"
)]
/// Deprecated pre-planner transform handle (facade over `So3Plan`).
pub struct So3Fft {
    plan: So3Plan,
}

#[allow(deprecated)]
impl So3Fft {
    /// Default configuration (sequential, paper defaults).
    pub fn new(b: usize) -> Result<Self> {
        Self::builder(b).build()
    }

    /// Start configuring a transform.
    pub fn builder(b: usize) -> So3FftBuilder {
        So3FftBuilder {
            b,
            config: ExecutorConfig::default(),
            offload: None,
        }
    }

    /// Analysis (FSOFT): grid samples → Fourier coefficients.
    pub fn forward(&self, grid: &So3Grid) -> Result<So3Coeffs> {
        self.plan.forward(grid)
    }

    /// Synthesis (iFSOFT): Fourier coefficients → grid samples.
    pub fn inverse(&self, coeffs: &So3Coeffs) -> Result<So3Grid> {
        self.plan.inverse(coeffs)
    }

    /// Analysis with a wall-clock phase breakdown.
    pub fn forward_with_stats(&self, grid: &So3Grid) -> Result<(So3Coeffs, TransformStats)> {
        self.plan.forward_with_stats(grid)
    }

    /// Synthesis with a wall-clock phase breakdown.
    pub fn inverse_with_stats(
        &self,
        coeffs: &So3Coeffs,
    ) -> Result<(So3Grid, TransformStats)> {
        self.plan.inverse_with_stats(coeffs)
    }

    /// Bandwidth this handle was built for.
    pub fn bandwidth(&self) -> usize {
        self.plan.bandwidth()
    }

    /// The underlying plan (the API new code should hold directly).
    pub fn plan(&self) -> &So3Plan {
        &self.plan
    }

    /// Unwrap the facade into the plan it carries.
    pub fn into_plan(self) -> So3Plan {
        self.plan
    }

    /// The underlying executor (plans, weights, diagnostics).
    pub fn executor(&self) -> &Executor {
        self.plan.executor()
    }
}

#[allow(deprecated)]
impl Transform for So3Fft {
    fn bandwidth(&self) -> usize {
        So3Fft::bandwidth(self)
    }

    fn forward_into(
        &self,
        grid: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        self.plan.forward_into(grid, out, ws)
    }

    fn inverse_into(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        self.plan.inverse_into(coeffs, out, ws)
    }
}

/// Fluent configuration for [`So3Fft`].
#[deprecated(
    since = "0.6.0",
    note = "use So3PlanBuilder (explicit planning) or So3ServiceBuilder (serving front door)"
)]
/// Builder for the deprecated [`So3Fft`] handle.
#[allow(deprecated)]
pub struct So3FftBuilder {
    b: usize,
    config: ExecutorConfig,
    offload: Option<Arc<dyn DwtOffload>>,
}

#[allow(deprecated)]
impl So3FftBuilder {
    /// Worker thread count (1 = the sequential algorithm).
    pub fn threads(mut self, threads: usize) -> Self {
        self.config.threads = threads;
        self
    }

    /// DWT-loop schedule (paper default: `dynamic`).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.config.schedule = schedule;
        self
    }

    /// Order-domain partitioning strategy.
    pub fn strategy(mut self, strategy: PartitionStrategy) -> Self {
        self.config.strategy = strategy;
        self
    }

    /// DWT dataflow (matvec = paper's benchmarked version; clenshaw =
    /// the paper's announced follow-up).
    pub fn algorithm(mut self, algorithm: DwtAlgorithm) -> Self {
        self.config.algorithm = algorithm;
        self
    }

    /// Wigner row storage (precomputed tables vs on-the-fly recurrence).
    pub fn storage(mut self, storage: WignerStorage) -> Self {
        self.config.storage = storage;
        self
    }

    /// DWT accumulation precision (extended ≈ the paper's 80-bit mode).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.config.precision = precision;
        self
    }

    /// Attach a DWT offload backend (the PJRT/XLA runtime).
    pub fn offload(mut self, offload: Arc<dyn DwtOffload>) -> Self {
        self.offload = Some(offload);
        self
    }

    /// Full config override.
    pub fn config(mut self, config: ExecutorConfig) -> Self {
        self.config = config;
        self
    }

    /// Build the deprecated handle.
    pub fn build(self) -> Result<So3Fft> {
        // Historical behavior: any bandwidth >= 1 is accepted here (the
        // strict power-of-two validation lives on So3PlanBuilder).
        let mut builder = So3Plan::builder(self.b)
            .config(self.config)
            .allow_any_bandwidth();
        if let Some(off) = self.offload {
            builder = builder.offload(off);
        }
        Ok(So3Fft {
            plan: builder.build()?,
        })
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    #[test]
    fn builder_roundtrip_with_options() {
        let fft = So3Fft::builder(6)
            .threads(2)
            .schedule(Schedule::Dynamic { chunk: 2 })
            .algorithm(DwtAlgorithm::Clenshaw)
            .storage(WignerStorage::OnTheFly)
            .build()
            .unwrap();
        assert_eq!(fft.bandwidth(), 6);
        let coeffs = So3Coeffs::random(6, 5);
        let grid = fft.inverse(&coeffs).unwrap();
        let back = fft.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-11);
    }

    #[test]
    fn doc_example_works() {
        let fft = So3Fft::builder(8).threads(2).build().unwrap();
        let coeffs = So3Coeffs::random(8, 1);
        let grid = fft.inverse(&coeffs).unwrap();
        let back = fft.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-10);
    }

    #[test]
    fn invalid_builder_combo_errors() {
        let r = So3Fft::builder(4)
            .algorithm(DwtAlgorithm::Clenshaw)
            .precision(Precision::Extended)
            .build();
        assert!(r.is_err());
    }

    // Bit-for-bit facade/plan parity is pinned once, in
    // `rust/tests/plan_api.rs::facade_parity_with_plan`.

    #[test]
    fn facade_accepts_non_power_of_two() {
        // Historical lenient behavior preserved for migration.
        let fft = So3Fft::new(6).unwrap();
        assert_eq!(fft.bandwidth(), 6);
    }
}

//! Public transform API and reference implementations.
//!
//! * [`api`] — [`So3Fft`]: the user-facing handle combining a prepared
//!   [`crate::coordinator::Executor`] with a validated configuration.
//! * [`direct`] — the O(B⁶) discrete SO(3) Fourier transform straight
//!   from the definitions (Eq. 4/5), the end-to-end correctness oracle.

pub mod api;
pub mod direct;

pub use api::{So3Fft, So3FftBuilder};

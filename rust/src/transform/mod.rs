//! The transform layer: explicit plans and reference implementations.
//!
//! The **documented front door for serving** is
//! [`crate::service::So3Service`] (shared pool, plan registry,
//! micro-batching job API); this module is the **power-user path** it is
//! built on.
//!
//! * [`plan`] — [`So3Plan`]: the FFTW-style planner/session API. Build a
//!   plan once per `(bandwidth, config)`, then execute allocation-free
//!   (`forward_into`/`inverse_into` + [`Workspace`]) or in batches
//!   (`forward_batch`/`inverse_batch`). All backends (CPU-sequential,
//!   CPU-parallel, PJRT offload) sit behind the [`Transform`] trait.
//! * [`api`] — [`So3Fft`]: the **deprecated** facade over [`So3Plan`]
//!   kept for incremental migration (see `docs/MIGRATION.md`).
//! * [`direct`] — the O(B⁶) discrete SO(3) Fourier transform straight
//!   from the definitions (Eq. 4/5), the end-to-end correctness oracle.

pub mod api;
pub mod direct;
pub mod plan;

pub use crate::coordinator::{StageStats, Workspace};
pub use crate::fft::FftEngine;
pub use crate::pool::{PoolSpec, WorkerPool};
#[allow(deprecated)]
pub use api::{So3Fft, So3FftBuilder};
pub use plan::{BackendKind, So3Plan, So3PlanBuilder, Transform};

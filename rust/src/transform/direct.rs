//! The direct (slow) discrete SO(3) Fourier transform — the end-to-end
//! oracle.
//!
//! Evaluates Eq. 5 (analysis) and Eq. 4 (synthesis) literally, one
//! triple/double sum per output element: O(B⁶) per transform (the paper's
//! "unacceptable for most practical purposes" baseline, which is exactly
//! why it makes a trustworthy oracle for small B).

use crate::error::Result;
use crate::fft::Complex64;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::quadrature;
use crate::so3::sampling::{GridAngles, So3Grid};
use crate::so3::wigner::{d_column, WignerRowBuf};

/// Direct synthesis (Eq. 4): f(α_i, β_j, γ_k) = Σ f°(l,m,m')·D(l,m,m').
pub fn synthesis(coeffs: &So3Coeffs) -> Result<So3Grid> {
    let b = coeffs.bandwidth();
    let n = 2 * b;
    let angles = GridAngles::new(b)?;
    let mut grid = So3Grid::zeros(b)?;
    let mut dbuf = WignerRowBuf::new(b);
    let bb = b as i64;
    let o = 2 * b - 1;
    for j in 0..n {
        // Radial sums g(m, m') = Σ_l f°(l,m,m')·d(l,m,m';β_j), hoisted out
        // of the (i, k) loops.
        let mut radial = vec![Complex64::zero(); o * o];
        for m in (1 - bb)..bb {
            for mp in (1 - bb)..bb {
                d_column(b, m, mp, angles.betas[j], &mut dbuf);
                let l0 = m.unsigned_abs().max(mp.unsigned_abs()) as usize;
                let mut acc = Complex64::zero();
                for l in l0..b {
                    acc += coeffs.at(l, m, mp).scale(dbuf.values[l]);
                }
                radial[((m + bb - 1) * o as i64 + (mp + bb - 1)) as usize] = acc;
            }
        }
        for i in 0..n {
            for k in 0..n {
                let mut acc = Complex64::zero();
                for m in (1 - bb)..bb {
                    for mp in (1 - bb)..bb {
                        let phase = Complex64::cis(
                            -(m as f64 * angles.alphas[i] + mp as f64 * angles.gammas[k]),
                        );
                        acc += radial[((m + bb - 1) * o as i64 + (mp + bb - 1)) as usize]
                            * phase;
                    }
                }
                grid.set(i, j, k, acc);
            }
        }
    }
    Ok(grid)
}

/// Direct analysis (Eq. 5): the weighted triple sum per coefficient.
pub fn analysis(grid: &So3Grid) -> Result<So3Coeffs> {
    let b = grid.bandwidth();
    let n = 2 * b;
    let angles = GridAngles::new(b)?;
    let weights = quadrature::weights(b)?;
    let mut coeffs = So3Coeffs::zeros(b);
    let mut dbuf = WignerRowBuf::new(b);
    let bb = b as i64;
    for l in 0..b {
        let li = l as i64;
        for m in -li..=li {
            for mp in -li..=li {
                let mut acc = Complex64::zero();
                for j in 0..n {
                    d_column(b, m, mp, angles.betas[j], &mut dbuf);
                    let d = dbuf.values[l];
                    for i in 0..n {
                        for k in 0..n {
                            // conj(D) = e^{+imα} d e^{+im'γ}.
                            let phase = Complex64::cis(
                                m as f64 * angles.alphas[i] + mp as f64 * angles.gammas[k],
                            );
                            acc += grid.get(i, j, k) * phase.scale(weights[j] * d);
                        }
                    }
                }
                let scale = (2 * l + 1) as f64 / (8.0 * std::f64::consts::PI * bb as f64);
                *coeffs.at_mut(l, m, mp) = acc.scale(scale);
            }
        }
    }
    Ok(coeffs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Executor, ExecutorConfig};

    #[test]
    fn direct_roundtrip_tiny() {
        let b = 2;
        let coeffs = So3Coeffs::random(b, 1);
        let grid = synthesis(&coeffs).unwrap();
        let back = analysis(&grid).unwrap();
        let err = coeffs.max_abs_error(&back);
        assert!(err < 1e-12, "direct roundtrip error {err}");
    }

    #[test]
    fn fast_synthesis_matches_direct() {
        let b = 3;
        let coeffs = So3Coeffs::random(b, 2);
        let slow = synthesis(&coeffs).unwrap();
        let exec = Executor::new(b, ExecutorConfig::default()).unwrap();
        let fast = exec.inverse(&coeffs).unwrap();
        let err = slow.max_abs_error(&fast);
        assert!(err < 1e-10, "iFSOFT vs direct synthesis: {err}");
    }

    #[test]
    fn fast_analysis_matches_direct() {
        let b = 3;
        // Build a bandlimited grid via direct synthesis, then compare
        // analyses.
        let coeffs = So3Coeffs::random(b, 3);
        let grid = synthesis(&coeffs).unwrap();
        let slow = analysis(&grid).unwrap();
        let exec = Executor::new(b, ExecutorConfig::default()).unwrap();
        let fast = exec.forward(&grid).unwrap();
        let err = slow.max_abs_error(&fast);
        assert!(err < 1e-10, "FSOFT vs direct analysis: {err}");
    }
}

//! The planner/session API: [`So3Plan`].
//!
//! FFTW-style separation of *planning* from *execution*: an [`So3Plan`]
//! is built once per `(bandwidth, config)` and owns everything expensive
//! — the partition plan (symmetry clusters + index maps), precomputed
//! Wigner tables, FFT twiddles, quadrature weights. Execution then runs
//! through caller-owned buffers:
//!
//! * [`So3Plan::forward`] / [`So3Plan::inverse`] — allocating
//!   conveniences for one-off transforms;
//! * [`So3Plan::forward_into`] / [`So3Plan::inverse_into`] — the
//!   allocation-free serving path (`&grid, &mut coeffs, &mut Workspace`);
//! * [`So3Plan::forward_batch`] / [`So3Plan::inverse_batch`] — pipeline
//!   many signals through one plan, reusing the workspace (and the
//!   dynamic self-scheduled pool configuration) across items.
//!
//! All execution backends — CPU-sequential (`threads = 1`), CPU-parallel
//! (the worker pool), and the PJRT/XLA DWT offload — sit behind the
//! direction-agnostic [`Transform`] trait, so they are interchangeable
//! as `&dyn Transform` / `Arc<dyn Transform>`; [`BackendKind`] reports
//! which one a plan resolved to.
//!
//! ```no_run
//! use so3ft::transform::So3Plan;
//! use so3ft::so3::coeffs::So3Coeffs;
//! use so3ft::so3::sampling::So3Grid;
//!
//! let b = 16;
//! let plan = So3Plan::builder(b).threads(4).build().unwrap();
//! let mut ws = plan.make_workspace();           // once per session
//! let mut grid = So3Grid::zeros(b).unwrap();    // caller-owned buffers
//! let mut back = So3Coeffs::zeros(b);
//! let coeffs = So3Coeffs::random(b, 42);
//! plan.inverse_into(&coeffs, &mut grid, &mut ws).unwrap();
//! plan.forward_into(&grid, &mut back, &mut ws).unwrap();   // no allocation
//! assert!(coeffs.max_abs_error(&back) < 1e-10);
//! ```

use std::sync::Arc;

use crate::coordinator::exec::DwtOffload;
use crate::coordinator::{
    Executor, ExecutorConfig, MemoryBudget, MemoryReport, PartitionStrategy, TransformStats,
    Workspace,
};
use crate::dwt::tables::WignerStorage;
use crate::dwt::{DwtAlgorithm, Precision};
use crate::error::{Error, Result};
use crate::fft::FftEngine;
use crate::pool::{PoolSpec, Schedule, WorkerPool};
use crate::simd::{SimdIsa, SimdPolicy};
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;
use crate::wisdom::{self, PlanRigor, WisdomOutcome, WisdomSource, WisdomStore, WisdomWarning};

/// Which execution backend a plan resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Single-threaded: the paper's sequential baseline algorithm.
    CpuSequential,
    /// The persistent worker pool (parked workers, woken per region)
    /// with the configured loop schedule — owned, process-global, or
    /// shared across plans (see [`crate::pool::PoolSpec`]).
    CpuParallel,
    /// DWT contractions offloaded to a compiled PJRT/XLA artifact
    /// (FFT + transposition stages still run on the CPU backend).
    PjrtOffload,
}

/// Direction-agnostic transform backend: one vtable for the sequential,
/// parallel, and offloaded engines (and for the [`super::So3Fft`] facade).
///
/// The `*_into` methods are the primary surface — allocation-free, with
/// caller-owned outputs and workspace. The allocating `forward`/`inverse`
/// conveniences are provided for one-off use.
pub trait Transform: Send + Sync {
    /// Bandwidth this transform was built for.
    fn bandwidth(&self) -> usize;

    /// Analysis (FSOFT) into caller-owned storage.
    fn forward_into(
        &self,
        grid: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats>;

    /// Synthesis (iFSOFT) into caller-owned storage.
    fn inverse_into(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats>;

    /// A workspace sized for this transform's bandwidth.
    fn make_workspace(&self) -> Workspace {
        Workspace::new(self.bandwidth()).expect("transform bandwidth is >= 1")
    }

    /// Allocating analysis convenience.
    fn forward(&self, grid: &So3Grid) -> Result<So3Coeffs> {
        let mut out = So3Coeffs::zeros(self.bandwidth());
        let mut ws = self.make_workspace();
        self.forward_into(grid, &mut out, &mut ws)?;
        Ok(out)
    }

    /// Allocating synthesis convenience.
    fn inverse(&self, coeffs: &So3Coeffs) -> Result<So3Grid> {
        let mut out = So3Grid::zeros(self.bandwidth())?;
        let mut ws = self.make_workspace();
        self.inverse_into(coeffs, &mut out, &mut ws)?;
        Ok(out)
    }
}

impl Transform for Executor {
    fn bandwidth(&self) -> usize {
        Executor::bandwidth(self)
    }

    fn forward_into(
        &self,
        grid: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        Executor::forward_into(self, grid, out, ws)
    }

    fn inverse_into(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        Executor::inverse_into(self, coeffs, out, ws)
    }
}

/// A prepared fast SO(3) Fourier transform plan (FSOFT + iFSOFT) for one
/// bandwidth: Wigner tables, partition plan, FFT twiddles, quadrature —
/// built once, executed many times.
pub struct So3Plan {
    exec: Executor,
    backend: BackendKind,
    /// What `PlanRigor::Measure` did during the build (`None` under
    /// Estimate).
    wisdom: Option<WisdomOutcome>,
}

impl So3Plan {
    /// Default configuration (sequential, paper defaults). The bandwidth
    /// must be a power of two; see [`So3PlanBuilder::allow_any_bandwidth`]
    /// for the Bluestein escape hatch.
    pub fn new(b: usize) -> Result<Self> {
        Self::builder(b).build()
    }

    /// Start configuring a plan.
    pub fn builder(b: usize) -> So3PlanBuilder {
        So3PlanBuilder {
            b,
            config: ExecutorConfig::default(),
            offload: None,
            allow_any_bandwidth: false,
            rigor: PlanRigor::Estimate,
            wisdom: None,
            time_budget: std::time::Duration::from_millis(250),
        }
    }

    /// Bandwidth this plan was built for.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.exec.bandwidth()
    }

    /// Which backend this plan executes on.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// What the wisdom machinery did while building this plan: `None`
    /// for Estimate-built plans, otherwise the source (cache hit,
    /// fresh measurement, or a typed fallback warning), the applied
    /// knobs, and the wall time spent searching.
    pub fn wisdom(&self) -> Option<&WisdomOutcome> {
        self.wisdom.as_ref()
    }

    /// The plan as a backend-agnostic transform handle.
    pub fn as_transform(&self) -> &dyn Transform {
        self
    }

    /// The underlying executor (plans, weights, diagnostics).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// The executor configuration the plan resolved to.
    pub fn config(&self) -> &ExecutorConfig {
        self.exec.config()
    }

    /// Memory held by precomputed Wigner tables (bytes).
    pub fn table_bytes(&self) -> usize {
        self.exec.table_bytes()
    }

    /// How this plan's [`MemoryBudget`] resolved at build time:
    /// materialized table bytes versus a full set, the irreducible
    /// workspace size, and whether any base pair streams from the
    /// recurrence instead of tables.
    pub fn memory_report(&self) -> MemoryReport {
        self.exec.memory_report()
    }

    /// The instruction set the DWT/FFT hot kernels run with — the
    /// builder's [`SimdPolicy`] resolved against the host at build time.
    pub fn simd_isa(&self) -> SimdIsa {
        self.exec.simd_isa()
    }

    /// The persistent worker pool this plan's parallel regions execute
    /// on (`None` for the sequential backend). For plans built with
    /// [`So3PlanBuilder::pool`] or [`PoolSpec::Global`] this is the
    /// shared instance (`Arc::ptr_eq`-comparable).
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.exec.pool()
    }

    /// A workspace sized for this plan. Build one per session/thread and
    /// reuse it across calls; the `*_into` entry points then perform no
    /// grid/coefficient allocation at all.
    pub fn make_workspace(&self) -> Workspace {
        self.exec.make_workspace()
    }

    // ------------------------------------------------------------------
    // Single-transform entry points
    // ------------------------------------------------------------------

    /// Analysis (FSOFT): grid samples → Fourier coefficients (allocating).
    pub fn forward(&self, grid: &So3Grid) -> Result<So3Coeffs> {
        self.exec.forward(grid)
    }

    /// Synthesis (iFSOFT): Fourier coefficients → grid samples (allocating).
    pub fn inverse(&self, coeffs: &So3Coeffs) -> Result<So3Grid> {
        self.exec.inverse(coeffs)
    }

    /// Analysis with a wall-clock phase breakdown.
    pub fn forward_with_stats(&self, grid: &So3Grid) -> Result<(So3Coeffs, TransformStats)> {
        self.exec.forward_with_stats(grid)
    }

    /// Synthesis with a wall-clock phase breakdown.
    pub fn inverse_with_stats(
        &self,
        coeffs: &So3Coeffs,
    ) -> Result<(So3Grid, TransformStats)> {
        self.exec.inverse_with_stats(coeffs)
    }

    /// Allocation-free analysis: writes into `out` using `ws` scratch.
    /// Both are validated against the plan bandwidth (typed [`Error`] on
    /// mismatch — a workspace from another plan is never UB).
    pub fn forward_into(
        &self,
        grid: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        self.exec.forward_into(grid, out, ws)
    }

    /// Allocation-free synthesis: writes into `out` using `ws` scratch.
    pub fn inverse_into(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        self.exec.inverse_into(coeffs, out, ws)
    }

    // ------------------------------------------------------------------
    // Batch entry points
    // ------------------------------------------------------------------

    /// Analyze a batch of grids through one plan. The workspace (and the
    /// per-thread kernel scratch) is reused across items, so the plan's
    /// amortized cost is paid once for the whole batch; results are
    /// bit-identical to calling [`Self::forward`] per item.
    pub fn forward_batch(&self, grids: &[So3Grid]) -> Result<Vec<So3Coeffs>> {
        let mut ws = self.make_workspace();
        let mut out = Vec::with_capacity(grids.len());
        for grid in grids {
            let mut coeffs = So3Coeffs::zeros(self.bandwidth());
            self.exec.forward_into(grid, &mut coeffs, &mut ws)?;
            out.push(coeffs);
        }
        Ok(out)
    }

    /// Synthesize a batch of coefficient sets through one plan.
    pub fn inverse_batch(&self, coeffs: &[So3Coeffs]) -> Result<Vec<So3Grid>> {
        let mut ws = self.make_workspace();
        let mut out = Vec::with_capacity(coeffs.len());
        for c in coeffs {
            let mut grid = So3Grid::zeros(self.bandwidth())?;
            self.exec.inverse_into(c, &mut grid, &mut ws)?;
            out.push(grid);
        }
        Ok(out)
    }

    /// Fully allocation-free batch analysis into caller-owned outputs
    /// (`outs.len()` must equal `grids.len()`).
    pub fn forward_batch_into(
        &self,
        grids: &[So3Grid],
        outs: &mut [So3Coeffs],
        ws: &mut Workspace,
    ) -> Result<()> {
        if grids.len() != outs.len() {
            return Err(Error::shape(
                grids.len(),
                outs.len(),
                "forward_batch_into: outputs per input",
            ));
        }
        for (grid, out) in grids.iter().zip(outs.iter_mut()) {
            self.exec.forward_into(grid, out, ws)?;
        }
        Ok(())
    }

    /// Fully allocation-free batch synthesis into caller-owned outputs.
    pub fn inverse_batch_into(
        &self,
        coeffs: &[So3Coeffs],
        outs: &mut [So3Grid],
        ws: &mut Workspace,
    ) -> Result<()> {
        if coeffs.len() != outs.len() {
            return Err(Error::shape(
                coeffs.len(),
                outs.len(),
                "inverse_batch_into: outputs per input",
            ));
        }
        for (c, out) in coeffs.iter().zip(outs.iter_mut()) {
            self.exec.inverse_into(c, out, ws)?;
        }
        Ok(())
    }
}

impl std::fmt::Debug for So3Plan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("So3Plan")
            .field("bandwidth", &self.bandwidth())
            .field("backend", &self.backend)
            .field("config", self.exec.config())
            .field("table_bytes", &self.table_bytes())
            .finish()
    }
}

impl Transform for So3Plan {
    fn bandwidth(&self) -> usize {
        So3Plan::bandwidth(self)
    }

    fn forward_into(
        &self,
        grid: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        So3Plan::forward_into(self, grid, out, ws)
    }

    fn inverse_into(
        &self,
        coeffs: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        So3Plan::inverse_into(self, coeffs, out, ws)
    }
}

/// Fluent configuration for [`So3Plan`] — every design axis the paper
/// discusses (threads, schedule, partitioning, DWT dataflow, storage,
/// precision) plus the PJRT offload attachment.
pub struct So3PlanBuilder {
    b: usize,
    config: ExecutorConfig,
    offload: Option<Arc<dyn DwtOffload>>,
    allow_any_bandwidth: bool,
    rigor: PlanRigor,
    wisdom: Option<Arc<WisdomStore>>,
    time_budget: std::time::Duration,
}

impl std::fmt::Debug for So3PlanBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("So3PlanBuilder")
            .field("bandwidth", &self.b)
            .field("config", &self.config)
            .field("offload", &self.offload.is_some())
            .field("allow_any_bandwidth", &self.allow_any_bandwidth)
            .field("rigor", &self.rigor)
            .field("time_budget", &self.time_budget)
            .finish()
    }
}

impl So3PlanBuilder {
    /// Worker thread count (1 = the sequential algorithm).
    pub fn threads(mut self, threads: usize) -> Self {
        self.config.threads = threads;
        self
    }

    /// DWT-loop schedule (paper default: `dynamic`).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.config.schedule = schedule;
        self
    }

    /// Order-domain partitioning strategy.
    pub fn strategy(mut self, strategy: PartitionStrategy) -> Self {
        self.config.strategy = strategy;
        self
    }

    /// DWT dataflow: `MatVecFolded` (default) is the β-parity-folded,
    /// register-blocked engine; `MatVec` is the paper's benchmarked
    /// full-row version, kept as the measurable baseline; `Clenshaw` is
    /// the paper's announced follow-up.
    pub fn algorithm(mut self, algorithm: DwtAlgorithm) -> Self {
        self.config.algorithm = algorithm;
        self
    }

    /// Wigner row storage (precomputed tables vs on-the-fly recurrence).
    pub fn storage(mut self, storage: WignerStorage) -> Self {
        self.config.storage = storage;
        self
    }

    /// Memory budget for the plan, resolved once at build time into
    /// table materialization / streaming choices (see [`MemoryBudget`]):
    /// `Auto` (default) caps tables at a soft 2 GiB and streams beyond;
    /// `Unlimited` always materializes; `Bytes(cap)` is a hard cap over
    /// workspace + tables, with [`Error::BudgetExceeded`] when even the
    /// workspace alone does not fit. Inspect the outcome via
    /// [`So3Plan::memory_report`].
    pub fn memory_budget(mut self, budget: MemoryBudget) -> Self {
        self.config.memory = budget;
        self
    }

    /// DWT accumulation precision (extended ≈ the paper's 80-bit mode).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.config.precision = precision;
        self
    }

    /// FFT-stage engine: the split-radix panel engine (default) or the
    /// radix-2 gather/scatter baseline kept for benchmarking.
    pub fn fft_engine(mut self, engine: FftEngine) -> Self {
        self.config.fft_engine = engine;
        self
    }

    /// SIMD dispatch policy for the DWT/FFT hot kernels:
    /// [`SimdPolicy::Auto`] (default) uses the widest ISA the host
    /// supports, [`SimdPolicy::Scalar`] pins the measurable scalar
    /// baseline, and the `Force*` variants fail the build with a typed
    /// [`Error::Config`] on hosts without that ISA.
    pub fn simd(mut self, policy: SimdPolicy) -> Self {
        self.config.simd = policy;
        self
    }

    /// Opt into the real-input analysis path: the forward FFT stage
    /// exploits Hermitian symmetry of real samples (~half the butterfly
    /// work and memory traffic). Grids with any nonzero imaginary part
    /// are rejected with a typed [`Error::RealInputRequired`]; synthesis
    /// (`inverse*`) is unaffected.
    pub fn real_input(mut self) -> Self {
        self.config.real_input = true;
        self
    }

    /// Execute this plan's parallel regions on a caller-supplied
    /// persistent [`WorkerPool`], shared with other plans and with
    /// concurrent callers (regions interleave safely; results are
    /// bit-identical to exclusive use). Also widens `threads` to the
    /// pool size — call [`Self::threads`] *afterwards* to narrow the
    /// region width (always clamped to the pool size at execution).
    pub fn pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.config.threads = pool.threads();
        self.config.pool = PoolSpec::Shared(pool);
        self
    }

    /// Pool sourcing policy: [`PoolSpec::Owned`] (default — a private
    /// pool of `threads` workers), [`PoolSpec::Global`] (the
    /// lazily-initialized process-global pool), or [`PoolSpec::Shared`].
    /// Unlike [`Self::pool`] this never touches `threads`.
    pub fn pool_spec(mut self, spec: PoolSpec) -> Self {
        self.config.pool = spec;
        self
    }

    /// Attach a DWT offload backend (the PJRT/XLA runtime).
    pub fn offload(mut self, offload: Arc<dyn DwtOffload>) -> Self {
        self.offload = Some(offload);
        self
    }

    /// Full config override.
    pub fn config(mut self, config: ExecutorConfig) -> Self {
        self.config = config;
        self
    }

    /// Accept non-power-of-two bandwidths (served by the Bluestein FFT
    /// fallback). The strict default rejects them with a typed error
    /// because the serving path assumes the radix-2 grid edge.
    pub fn allow_any_bandwidth(mut self) -> Self {
        self.allow_any_bandwidth = true;
        self
    }

    /// Planning rigor (FFTW-style): [`PlanRigor::Estimate`] (default)
    /// keeps the builder's static configuration untouched;
    /// [`PlanRigor::Measure`] searches the knob space at build time,
    /// reusing persisted wisdom when available (see [`crate::wisdom`]).
    pub fn rigor(mut self, rigor: PlanRigor) -> Self {
        self.rigor = rigor;
        self
    }

    /// The wisdom store `Measure` builds consult and record into
    /// (default: [`WisdomStore::global`], backed by
    /// `util::cache_dir()/wisdom.so3wis`).
    pub fn wisdom_store(mut self, store: Arc<WisdomStore>) -> Self {
        self.wisdom = Some(store);
        self
    }

    /// Wall-time budget for one `Measure` search (default 250 ms). The
    /// budget is split across the timed candidates; each still gets at
    /// least one repetition, so a tiny budget degrades accuracy, not
    /// correctness.
    pub fn wisdom_time_budget_ms(mut self, ms: u64) -> Self {
        self.time_budget = std::time::Duration::from_millis(ms);
        self
    }

    /// Build the plan (validates bandwidth and configuration).
    pub fn build(self) -> Result<So3Plan> {
        if self.b == 0 {
            return Err(Error::InvalidBandwidth(0));
        }
        if self.config.threads == 0 {
            return Err(Error::InvalidThreads(0));
        }
        if !self.b.is_power_of_two() && !self.allow_any_bandwidth {
            return Err(Error::NonPowerOfTwoBandwidth(self.b));
        }
        let mut config = self.config;
        let wisdom = match self.rigor {
            PlanRigor::Estimate => None,
            PlanRigor::Measure if self.offload.is_some() => {
                // The search times the CPU engines; tuning an offloaded
                // plan from those timings would be wrong. Typed
                // fallback, not an error.
                Some(WisdomOutcome {
                    source: WisdomSource::Fallback(WisdomWarning::OffloadAttached),
                    choice: None,
                    search_seconds: 0.0,
                })
            }
            PlanRigor::Measure => {
                let store = self.wisdom.unwrap_or_else(WisdomStore::global);
                Some(wisdom::tune(&store, self.b, &mut config, self.time_budget))
            }
        };
        let mut exec = Executor::new(self.b, config)?;
        let backend = if self.offload.is_some() {
            BackendKind::PjrtOffload
        } else if exec.config().threads == 1 {
            BackendKind::CpuSequential
        } else {
            BackendKind::CpuParallel
        };
        if let Some(off) = self.offload {
            exec = exec.with_offload(off);
        }
        Ok(So3Plan {
            exec,
            backend,
            wisdom,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_roundtrip_default() {
        let plan = So3Plan::new(8).unwrap();
        assert_eq!(plan.backend(), BackendKind::CpuSequential);
        let coeffs = So3Coeffs::random(8, 1);
        let grid = plan.inverse(&coeffs).unwrap();
        let back = plan.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-10);
    }

    #[test]
    fn builder_rejects_invalid_inputs_typed() {
        assert!(matches!(
            So3Plan::builder(0).build(),
            Err(Error::InvalidBandwidth(0))
        ));
        assert!(matches!(
            So3Plan::builder(8).threads(0).build(),
            Err(Error::InvalidThreads(0))
        ));
        assert!(matches!(
            So3Plan::builder(12).build(),
            Err(Error::NonPowerOfTwoBandwidth(12))
        ));
        // The escape hatch routes through the Bluestein FFT.
        let plan = So3Plan::builder(6).allow_any_bandwidth().build().unwrap();
        let coeffs = So3Coeffs::random(6, 3);
        let grid = plan.inverse(&coeffs).unwrap();
        let back = plan.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-10);
    }

    #[test]
    fn backend_kind_tracks_threads() {
        assert_eq!(
            So3Plan::builder(4).threads(1).build().unwrap().backend(),
            BackendKind::CpuSequential
        );
        assert_eq!(
            So3Plan::builder(4).threads(3).build().unwrap().backend(),
            BackendKind::CpuParallel
        );
    }

    #[test]
    fn dyn_transform_is_object_safe_and_works() {
        let plan: Arc<dyn Transform> =
            Arc::new(So3Plan::builder(4).threads(2).build().unwrap());
        let coeffs = So3Coeffs::random(4, 5);
        let grid = plan.inverse(&coeffs).unwrap();
        let back = plan.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-11);
    }

    #[test]
    fn builder_fft_engine_and_real_input() {
        let plan = So3Plan::builder(4)
            .fft_engine(FftEngine::Radix2Baseline)
            .build()
            .unwrap();
        assert_eq!(plan.config().fft_engine, FftEngine::Radix2Baseline);
        let rplan = So3Plan::builder(4).real_input().build().unwrap();
        assert!(rplan.config().real_input);
        let coeffs = So3Coeffs::random(4, 2);
        // Synthesis is unaffected by real-input mode; analysis of complex
        // samples is a typed error.
        let g = rplan.inverse(&coeffs).unwrap();
        assert!(matches!(
            rplan.forward(&g),
            Err(Error::RealInputRequired { .. })
        ));
    }

    #[test]
    fn builder_simd_policy_resolves_and_matches_auto() {
        let scalar = So3Plan::builder(8).simd(SimdPolicy::Scalar).build().unwrap();
        assert_eq!(scalar.simd_isa(), SimdIsa::Scalar);
        assert_eq!(scalar.config().simd, SimdPolicy::Scalar);
        let auto = So3Plan::new(8).unwrap();
        assert_eq!(auto.simd_isa(), crate::simd::detected_isa());
        let coeffs = So3Coeffs::random(8, 17);
        let g_a = auto.inverse(&coeffs).unwrap();
        let g_s = scalar.inverse(&coeffs).unwrap();
        assert!(g_a.max_abs_error(&g_s) < 1e-12);
        // Forcing an ISA the host lacks is a typed build error.
        let impossible = if cfg!(target_arch = "x86_64") {
            SimdPolicy::ForceNeon
        } else {
            SimdPolicy::ForceAvx2
        };
        assert!(matches!(
            So3Plan::builder(8).simd(impossible).build(),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn builder_memory_budget_resolves_and_reports() {
        // Auto at a tiny bandwidth: everything fits, nothing streams.
        let auto = So3Plan::builder(8).build().unwrap();
        let report = auto.memory_report();
        assert_eq!(report.budget, MemoryBudget::Auto);
        assert!(!report.streamed);
        assert_eq!(report.table_bytes, report.table_bytes_full);
        assert!(report.table_bytes > 0);
        // A cap that admits the workspace plus half a table set streams
        // the rest and still reproduces the unconstrained answer.
        let cap = crate::coordinator::workspace_bytes(8)
            + crate::dwt::tables::WignerTables::full_bytes(8) / 2;
        let tight = So3Plan::builder(8)
            .memory_budget(MemoryBudget::Bytes(cap))
            .build()
            .unwrap();
        let treport = tight.memory_report();
        assert!(treport.streamed);
        assert!(treport.table_bytes < treport.table_bytes_full);
        assert!(treport.total_bytes() <= cap);
        let coeffs = So3Coeffs::random(8, 23);
        let g_auto = auto.inverse(&coeffs).unwrap();
        let g_tight = tight.inverse(&coeffs).unwrap();
        assert!(g_auto.max_abs_error(&g_tight) < 1e-11);
        // A cap below the irreducible workspace is a typed build error.
        assert!(matches!(
            So3Plan::builder(8)
                .memory_budget(MemoryBudget::Bytes(1024))
                .build(),
            Err(Error::BudgetExceeded { .. })
        ));
    }

    #[test]
    fn shared_pool_plans_match_owned_pool_plans() {
        let pool = Arc::new(WorkerPool::new(2).unwrap());
        let builder = So3Plan::builder(8).pool(Arc::clone(&pool));
        let shared = builder.build().unwrap();
        // `.pool(...)` widens threads to the pool size and reuses the
        // shared instance instead of spawning a private pool.
        assert_eq!(shared.config().threads, 2);
        assert_eq!(shared.backend(), BackendKind::CpuParallel);
        assert!(Arc::ptr_eq(shared.pool().unwrap(), &pool));
        let owned = So3Plan::builder(8).threads(2).build().unwrap();
        assert!(!Arc::ptr_eq(owned.pool().unwrap(), &pool));
        let coeffs = So3Coeffs::random(8, 31);
        let g_shared = shared.inverse(&coeffs).unwrap();
        let g_owned = owned.inverse(&coeffs).unwrap();
        assert_eq!(g_shared.as_slice(), g_owned.as_slice());
        let c_shared = shared.forward(&g_shared).unwrap();
        let c_owned = owned.forward(&g_owned).unwrap();
        assert_eq!(c_shared.as_slice(), c_owned.as_slice());
    }

    #[test]
    fn global_pool_spec_builds_and_roundtrips() {
        let plan = So3Plan::builder(4)
            .threads(2)
            .pool_spec(PoolSpec::Global)
            .build()
            .unwrap();
        // The global pool is one process-wide instance.
        assert!(Arc::ptr_eq(plan.pool().unwrap(), &WorkerPool::global()));
        let coeffs = So3Coeffs::random(4, 8);
        let grid = plan.inverse(&coeffs).unwrap();
        let back = plan.forward(&grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-11);
        // Sequential plans never resolve a pool, whatever the spec.
        let seq = So3Plan::builder(4)
            .pool_spec(PoolSpec::Global)
            .build()
            .unwrap();
        assert!(seq.pool().is_none());
        assert_eq!(seq.backend(), BackendKind::CpuSequential);
    }

    #[test]
    fn batch_matches_sequential_loop() {
        let b = 8;
        let plan = So3Plan::builder(b).threads(2).build().unwrap();
        let inputs: Vec<So3Coeffs> = (0..4).map(|i| So3Coeffs::random(b, i)).collect();
        let grids = plan.inverse_batch(&inputs).unwrap();
        for (c, g) in inputs.iter().zip(&grids) {
            let single = plan.inverse(c).unwrap();
            assert_eq!(single.as_slice(), g.as_slice());
        }
        let specs = plan.forward_batch(&grids).unwrap();
        for (g, s) in grids.iter().zip(&specs) {
            let single = plan.forward(g).unwrap();
            assert_eq!(single.as_slice(), s.as_slice());
        }
    }

    #[test]
    fn measure_rigor_tunes_and_estimate_is_untouched() {
        let store = WisdomStore::in_memory();
        let measured = So3Plan::builder(4)
            .rigor(PlanRigor::Measure)
            .wisdom_store(Arc::clone(&store))
            .wisdom_time_budget_ms(30)
            .build()
            .unwrap();
        let outcome = measured.wisdom().expect("Measure records an outcome");
        assert_eq!(outcome.source, WisdomSource::Measured);
        assert!(outcome.choice.is_some());
        assert_eq!(store.stats().measurements, 1);
        // A second Measure build is served from the in-process memo.
        let again = So3Plan::builder(4)
            .rigor(PlanRigor::Measure)
            .wisdom_store(Arc::clone(&store))
            .build()
            .unwrap();
        assert_eq!(again.wisdom().unwrap().source, WisdomSource::CacheHit);
        assert_eq!(store.stats().measurements, 1);
        // Estimate plans carry no outcome at all.
        assert!(So3Plan::new(4).unwrap().wisdom().is_none());
    }

    #[test]
    fn batch_into_length_mismatch_is_error() {
        let plan = So3Plan::new(4).unwrap();
        let grids = vec![So3Grid::zeros(4).unwrap(); 2];
        let mut outs = vec![So3Coeffs::zeros(4); 3];
        let mut ws = plan.make_workspace();
        assert!(plan
            .forward_batch_into(&grids, &mut outs, &mut ws)
            .is_err());
    }
}

//! Command-line interface (hand-rolled parser — no clap offline).
//!
//! ```text
//! so3ft <command> [options]
//!
//! commands:
//!   info        plan / memory / artifact diagnostics for a bandwidth
//!   roundtrip   iFSOFT then FSOFT on random coefficients; report errors
//!   forward     time the FSOFT on a synthesized grid
//!   inverse     time the iFSOFT on random coefficients
//!   match       rotational-matching demo (plant + recover a rotation)
//!   simulate    multicore scaling curves (the Figs. 2-4 machinery)
//!   serve-bench So3Service under concurrent mixed-bandwidth load
//!   wisdom      plan auto-tuning cache: train | show | clear
//!
//! common options:
//!   --config <file.toml>      load defaults from a config file
//!   --bandwidth/-b <B>        transform bandwidth
//!   --threads/-t <N>          worker threads
//!   --schedule <spec>         dynamic[:c] | static | interleaved | guided[:m]
//!   --strategy <spec>         geometric | sigma | nosym
//!   --algorithm <spec>        matvec-folded | matvec | clenshaw
//!   --storage <spec>          precomputed | onthefly | auto[:mb]
//!   --memory-budget <spec>    auto | unlimited | bytes:N | <MiB>
//!   --precision <spec>        double | extended
//!   --simd <spec>             auto | scalar | force-avx2 | force-neon
//!   --pool <spec>             owned | global (persistent worker pool)
//!   --seed <N>                workload seed
//!   --rigor <spec>            estimate | measure (plan auto-tuning)
//!   --time-budget-ms <N>      per-plan measurement budget (measure)
//!   --wisdom-cache <path>     wisdom-store file override
//!   --xla                     offload the DWT to the PJRT artifacts
//!   --artifacts <dir>         artifact directory
//!   --cores <list>            (simulate) core counts, e.g. "1,8,64"
//!   --kind <fwd|inv>          (simulate) transform direction
//!
//! serve-bench options:
//!   --clients <N>             client threads (default 4)
//!   --jobs <N>                jobs per client (default 16)
//!   --bandwidths <list>       mixed bandwidths, e.g. "8,16" (default)
//!   --window-us <N>           micro-batch window override (µs)
//!   --rate <jobs/s>           open-loop arrival rate per client
//!                             (0 = burst, the default)
//!   --rate-ramp               double the rate each round until the
//!                             service sheds load (typed rejections)
//!   --max-queue <N>           admission cap on queued jobs
//!   --deadline-ms <N>         default per-job deadline, milliseconds
//!   --inject <spec>           arm fault injection, e.g.
//!                             "batch-runner=3*err(chaos);plan-build=1*sleep(20)"
//!                             (needs the `fault-injection` build feature)
//!   --json <path>             merge service_* records into a
//!                             BENCH_fft.json-format report
//!   --metrics-json <path>     write the final So3Service metrics
//!                             snapshot as JSON
//!
//! wisdom usage:
//!   so3ft wisdom train [--bandwidths 8,16] [-t N] [--time-budget-ms N]
//!   so3ft wisdom show
//!   so3ft wisdom clear
//! ```

pub mod commands;

use crate::config::{parse_algorithm, parse_precision, parse_rigor, parse_storage, RunConfig};
use crate::coordinator::{MemoryBudget, PartitionStrategy};
use crate::error::{Error, Result};
use crate::pool::{PoolSpec, Schedule};
use crate::simd::SimdPolicy;

/// `serve-bench` options: N client threads × mixed bandwidths ×
/// open-loop arrival against one `So3Service`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeBenchOpts {
    /// Concurrent client threads.
    pub clients: usize,
    /// Jobs submitted per client.
    pub jobs: usize,
    /// Bandwidth mix, round-robin per client.
    pub bandwidths: Vec<usize>,
    /// Open-loop arrival rate per client in jobs/s (0 = burst).
    pub rate: f64,
    /// Overload mode: double `rate` each round until the service sheds
    /// load with typed rejections (then one final burst round).
    pub rate_ramp: bool,
    /// Fault-injection spec, armed before the run (see
    /// [`crate::faults::arm_from_spec`]).
    pub inject: Option<String>,
    /// Merge `service_*` records into this BENCH_fft.json-format file.
    pub json: Option<String>,
    /// Write the final service metrics snapshot as JSON to this path.
    pub metrics_json: Option<String>,
}

impl Default for ServeBenchOpts {
    fn default() -> Self {
        Self {
            clients: 4,
            jobs: 16,
            bandwidths: vec![8, 16],
            rate: 0.0,
            rate_ramp: false,
            inject: None,
            json: None,
            metrics_json: None,
        }
    }
}

/// Parsed invocation.
#[derive(Debug, Clone)]
pub struct Invocation {
    /// Subcommand name.
    pub command: String,
    /// Fully-resolved run configuration.
    pub run: RunConfig,
    /// Core counts for `simulate` sweeps.
    pub cores: Vec<usize>,
    /// Transform kind argument (`forward` | `inverse`).
    pub kind: String,
    /// `serve-bench` options.
    pub serve: ServeBenchOpts,
    /// `wisdom` subcommand action (`train` | `show` | `clear`); empty
    /// for every other command.
    pub wisdom_action: String,
}

/// Parse argv (excluding the program name).
pub fn parse_args(args: &[String]) -> Result<Invocation> {
    if args.is_empty() {
        return Err(Error::Config(
            "missing command; try `so3ft info` (see --help)".into(),
        ));
    }
    if args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
        return Ok(Invocation {
            command: "help".into(),
            run: RunConfig::default(),
            cores: vec![],
            kind: "fwd".into(),
            serve: ServeBenchOpts::default(),
            wisdom_action: String::new(),
        });
    }
    let command = args[0].clone();
    // `wisdom` takes a positional action before the flags.
    let mut wisdom_action = String::new();
    let mut flag_start = 1;
    if command == "wisdom" {
        let action = args.get(1).map(|s| s.as_str()).unwrap_or("");
        if !matches!(action, "train" | "show" | "clear") {
            return Err(Error::Config(format!(
                "wisdom needs an action: train | show | clear (got {action:?})"
            )));
        }
        wisdom_action = action.to_string();
        flag_start = 2;
    }
    // First pass: --config loads defaults, then flags override.
    let mut run = RunConfig::default();
    let mut i = flag_start;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args
                .get(i + 1)
                .ok_or_else(|| Error::Config("--config needs a path".into()))?;
            run = RunConfig::load(path)?;
            break;
        }
        i += 1;
    }
    let mut cores = vec![1, 2, 4, 8, 16, 32, 64];
    let mut kind = "fwd".to_string();
    let mut serve = ServeBenchOpts::default();
    let mut i = flag_start;
    let need = |args: &[String], i: usize, flag: &str| -> Result<String> {
        args.get(i + 1)
            .cloned()
            .ok_or_else(|| Error::Config(format!("{flag} needs a value")))
    };
    while i < args.len() {
        let a = args[i].as_str();
        match a {
            "--config" => {
                i += 1; // handled above
            }
            "--bandwidth" | "-b" => {
                run.bandwidth = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --bandwidth".into()))?;
                i += 1;
            }
            "--threads" | "-t" => {
                run.exec.threads = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --threads".into()))?;
                i += 1;
            }
            "--schedule" => {
                let v = need(args, i, a)?;
                run.exec.schedule = Schedule::parse(&v)
                    .ok_or_else(|| Error::Config(format!("bad --schedule {v:?}")))?;
                i += 1;
            }
            "--strategy" => {
                let v = need(args, i, a)?;
                run.exec.strategy = PartitionStrategy::parse(&v)
                    .ok_or_else(|| Error::Config(format!("bad --strategy {v:?}")))?;
                i += 1;
            }
            "--algorithm" => {
                run.exec.algorithm = parse_algorithm(&need(args, i, a)?)?;
                i += 1;
            }
            "--storage" => {
                let v = need(args, i, a)?;
                run.exec.storage = parse_storage(&v, run.bandwidth)?;
                i += 1;
            }
            "--memory-budget" => {
                let v = need(args, i, a)?;
                run.exec.memory = MemoryBudget::parse(&v).ok_or_else(|| {
                    Error::Config(format!(
                        "bad --memory-budget {v:?} (auto|unlimited|bytes:N|MiB)"
                    ))
                })?;
                i += 1;
            }
            "--precision" => {
                run.exec.precision = parse_precision(&need(args, i, a)?)?;
                i += 1;
            }
            "--simd" => {
                run.exec.simd = SimdPolicy::parse(&need(args, i, a)?)?;
                i += 1;
            }
            "--pool" => {
                let v = need(args, i, a)?;
                run.exec.pool = PoolSpec::parse(&v)
                    .ok_or_else(|| Error::Config(format!("bad --pool {v:?} (owned|global)")))?;
                i += 1;
            }
            "--seed" => {
                run.seed = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --seed".into()))?;
                i += 1;
            }
            "--rigor" => {
                run.wisdom.rigor = parse_rigor(&need(args, i, a)?)?;
                i += 1;
            }
            "--time-budget-ms" => {
                run.wisdom.time_budget_ms = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --time-budget-ms".into()))?;
                i += 1;
            }
            "--wisdom-cache" => {
                run.wisdom.cache_path = Some(need(args, i, a)?);
                i += 1;
            }
            "--xla" => run.use_xla = true,
            "--artifacts" => {
                run.artifacts_dir = need(args, i, a)?;
                i += 1;
            }
            "--cores" => {
                let v = need(args, i, a)?;
                cores = v
                    .replace(',', " ")
                    .split_whitespace()
                    .map(|t| t.parse().map_err(|_| Error::Config("bad --cores".into())))
                    .collect::<Result<Vec<usize>>>()?;
                i += 1;
            }
            "--kind" => {
                kind = need(args, i, a)?;
                if kind != "fwd" && kind != "inv" {
                    return Err(Error::Config("--kind must be fwd or inv".into()));
                }
                i += 1;
            }
            "--clients" => {
                serve.clients = need(args, i, a)?
                    .parse()
                    .ok()
                    .filter(|&c: &usize| c >= 1)
                    .ok_or_else(|| Error::Config("bad --clients (need >= 1)".into()))?;
                i += 1;
            }
            "--jobs" => {
                serve.jobs = need(args, i, a)?
                    .parse()
                    .ok()
                    .filter(|&j: &usize| j >= 1)
                    .ok_or_else(|| Error::Config("bad --jobs (need >= 1)".into()))?;
                i += 1;
            }
            "--bandwidths" => {
                let v = need(args, i, a)?;
                serve.bandwidths = v
                    .replace(',', " ")
                    .split_whitespace()
                    .map(|t| {
                        t.parse()
                            .map_err(|_| Error::Config("bad --bandwidths".into()))
                    })
                    .collect::<Result<Vec<usize>>>()?;
                if serve.bandwidths.is_empty() {
                    return Err(Error::Config("--bandwidths needs at least one value".into()));
                }
                i += 1;
            }
            "--window-us" => {
                run.service.batch_window_us = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --window-us".into()))?;
                i += 1;
            }
            "--rate" => {
                serve.rate = need(args, i, a)?
                    .parse()
                    .ok()
                    .filter(|r: &f64| r.is_finite() && *r >= 0.0)
                    .ok_or_else(|| Error::Config("bad --rate (jobs/s, >= 0)".into()))?;
                i += 1;
            }
            "--rate-ramp" => serve.rate_ramp = true,
            "--max-queue" => {
                let q = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --max-queue".into()))?;
                run.service.max_queue = Some(q);
                i += 1;
            }
            "--deadline-ms" => {
                let ms = need(args, i, a)?
                    .parse()
                    .map_err(|_| Error::Config("bad --deadline-ms".into()))?;
                run.service.default_deadline_ms = Some(ms);
                i += 1;
            }
            "--inject" => {
                serve.inject = Some(need(args, i, a)?);
                i += 1;
            }
            "--json" => {
                serve.json = Some(need(args, i, a)?);
                i += 1;
            }
            "--metrics-json" => {
                serve.metrics_json = Some(need(args, i, a)?);
                i += 1;
            }
            _ => {
                return Err(Error::Config(format!("unknown option {a:?}")));
            }
        }
        i += 1;
    }
    Ok(Invocation {
        command,
        run,
        cores,
        kind,
        serve,
        wisdom_action,
    })
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let args = &argv[1.min(argv.len())..];
    let inv = match parse_args(args) {
        Ok(inv) => inv,
        Err(e) => {
            eprintln!("so3ft: {e}");
            return 2;
        }
    };
    let result = match inv.command.as_str() {
        "help" => {
            print!("{}", commands::HELP);
            Ok(())
        }
        "info" => commands::info(&inv),
        "roundtrip" => commands::roundtrip(&inv),
        "forward" => commands::forward(&inv),
        "inverse" => commands::inverse(&inv),
        "match" => commands::match_demo(&inv),
        "simulate" => commands::simulate(&inv),
        "serve-bench" => commands::serve_bench(&inv),
        "wisdom" => commands::wisdom(&inv),
        other => Err(Error::Config(format!(
            "unknown command {other:?}; try `so3ft help`"
        ))),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("so3ft {}: {e}", inv.command);
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_typical_invocation() {
        let inv = parse_args(&argv(
            "roundtrip -b 8 -t 4 --schedule dynamic:2 --strategy sigma --seed 9 --xla",
        ))
        .unwrap();
        assert_eq!(inv.command, "roundtrip");
        assert_eq!(inv.run.bandwidth, 8);
        assert_eq!(inv.run.exec.threads, 4);
        assert_eq!(inv.run.exec.schedule, Schedule::Dynamic { chunk: 2 });
        assert_eq!(inv.run.exec.strategy, PartitionStrategy::SigmaClustered);
        assert_eq!(inv.run.seed, 9);
        assert!(inv.run.use_xla);
        assert!(matches!(inv.run.exec.pool, PoolSpec::Owned));
    }

    #[test]
    fn pool_flag_parses_and_rejects_bad_values() {
        let inv = parse_args(&argv("roundtrip -b 8 -t 2 --pool global")).unwrap();
        assert!(matches!(inv.run.exec.pool, PoolSpec::Global));
        let inv = parse_args(&argv("roundtrip --pool owned")).unwrap();
        assert!(matches!(inv.run.exec.pool, PoolSpec::Owned));
        assert!(parse_args(&argv("roundtrip --pool rented")).is_err());
        assert!(parse_args(&argv("roundtrip --pool")).is_err());
    }

    #[test]
    fn simd_flag_parses_and_rejects_bad_values() {
        let inv = parse_args(&argv("roundtrip -b 8 --simd scalar")).unwrap();
        assert_eq!(inv.run.exec.simd, SimdPolicy::Scalar);
        let inv = parse_args(&argv("forward --simd force-avx2")).unwrap();
        assert_eq!(inv.run.exec.simd, SimdPolicy::ForceAvx2);
        // Default is auto.
        let inv = parse_args(&argv("roundtrip")).unwrap();
        assert_eq!(inv.run.exec.simd, SimdPolicy::Auto);
        assert!(parse_args(&argv("roundtrip --simd avx512")).is_err());
        assert!(parse_args(&argv("roundtrip --simd")).is_err());
    }

    #[test]
    fn memory_budget_flag_parses_and_rejects_bad_values() {
        let inv = parse_args(&argv("roundtrip -b 8 --memory-budget unlimited")).unwrap();
        assert_eq!(inv.run.exec.memory, MemoryBudget::Unlimited);
        // A bare integer is MiB.
        let inv = parse_args(&argv("forward --memory-budget 512")).unwrap();
        assert_eq!(inv.run.exec.memory, MemoryBudget::Bytes(512 << 20));
        let inv = parse_args(&argv("forward --memory-budget bytes:4096")).unwrap();
        assert_eq!(inv.run.exec.memory, MemoryBudget::Bytes(4096));
        // Default is auto.
        let inv = parse_args(&argv("roundtrip")).unwrap();
        assert_eq!(inv.run.exec.memory, MemoryBudget::Auto);
        assert!(parse_args(&argv("roundtrip --memory-budget lots")).is_err());
        assert!(parse_args(&argv("roundtrip --memory-budget")).is_err());
    }

    #[test]
    fn serve_bench_flags_parse() {
        let inv = parse_args(&argv(
            "serve-bench -t 2 --clients 3 --jobs 5 --bandwidths 4,8 --window-us 250 \
             --rate 100 --rate-ramp --max-queue 16 --deadline-ms 2000 \
             --inject batch-runner=3*err(chaos) --json out.json --metrics-json m.json",
        ))
        .unwrap();
        assert_eq!(inv.command, "serve-bench");
        assert_eq!(inv.serve.clients, 3);
        assert_eq!(inv.serve.jobs, 5);
        assert_eq!(inv.serve.bandwidths, vec![4, 8]);
        assert_eq!(inv.run.service.batch_window_us, 250);
        assert_eq!(inv.serve.rate, 100.0);
        assert!(inv.serve.rate_ramp);
        assert_eq!(inv.run.service.max_queue, Some(16));
        assert_eq!(inv.run.service.default_deadline_ms, Some(2000));
        assert_eq!(inv.serve.inject.as_deref(), Some("batch-runner=3*err(chaos)"));
        assert_eq!(inv.serve.json.as_deref(), Some("out.json"));
        assert_eq!(inv.serve.metrics_json.as_deref(), Some("m.json"));
        // Defaults.
        let inv = parse_args(&argv("serve-bench")).unwrap();
        assert_eq!(inv.serve, ServeBenchOpts::default());
        assert!(inv.run.service.max_queue.is_none());
        // Validation.
        assert!(parse_args(&argv("serve-bench --clients 0")).is_err());
        assert!(parse_args(&argv("serve-bench --jobs zero")).is_err());
        assert!(parse_args(&argv("serve-bench --bandwidths ,")).is_err());
        assert!(parse_args(&argv("serve-bench --rate -3")).is_err());
        assert!(parse_args(&argv("serve-bench --max-queue many")).is_err());
        assert!(parse_args(&argv("serve-bench --deadline-ms")).is_err());
    }

    #[test]
    fn wisdom_command_parses() {
        let inv = parse_args(&argv(
            "wisdom train --bandwidths 8,16 -t 2 --time-budget-ms 100 --wisdom-cache /tmp/w",
        ))
        .unwrap();
        assert_eq!(inv.command, "wisdom");
        assert_eq!(inv.wisdom_action, "train");
        assert_eq!(inv.serve.bandwidths, vec![8, 16]);
        assert_eq!(inv.run.exec.threads, 2);
        assert_eq!(inv.run.wisdom.time_budget_ms, 100);
        assert_eq!(inv.run.wisdom.cache_path.as_deref(), Some("/tmp/w"));
        assert_eq!(parse_args(&argv("wisdom show")).unwrap().wisdom_action, "show");
        assert_eq!(parse_args(&argv("wisdom clear")).unwrap().wisdom_action, "clear");
        // Missing/unknown action, bad values.
        assert!(parse_args(&argv("wisdom")).is_err());
        assert!(parse_args(&argv("wisdom retrain")).is_err());
        assert!(parse_args(&argv("wisdom train --time-budget-ms soon")).is_err());
        // Non-wisdom commands carry no action but accept the rigor flags.
        let inv = parse_args(&argv("inverse -b 8 --rigor measure")).unwrap();
        assert_eq!(inv.wisdom_action, "");
        assert_eq!(inv.run.wisdom.rigor, crate::wisdom::PlanRigor::Measure);
        assert!(parse_args(&argv("inverse --rigor exhaustive")).is_err());
    }

    #[test]
    fn cores_list_parses() {
        let inv = parse_args(&argv("simulate --cores 1,8,64 --kind inv")).unwrap();
        assert_eq!(inv.cores, vec![1, 8, 64]);
        assert_eq!(inv.kind, "inv");
    }

    #[test]
    fn rejects_unknown_flags_and_bad_values() {
        assert!(parse_args(&argv("info --wat")).is_err());
        assert!(parse_args(&argv("info -b x")).is_err());
        assert!(parse_args(&argv("simulate --kind sideways")).is_err());
        assert!(parse_args(&[]).is_err());
    }

    #[test]
    fn config_file_then_flag_override() {
        let dir = std::env::temp_dir().join(format!("so3ft-cli-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.toml");
        std::fs::write(&path, "[transform]\nbandwidth = 32\nthreads = 2\n").unwrap();
        let inv = parse_args(&argv(&format!(
            "info --config {} -b 8",
            path.display()
        )))
        .unwrap();
        // Flag overrides file; file supplies threads.
        assert_eq!(inv.run.bandwidth, 8);
        assert_eq!(inv.run.exec.threads, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

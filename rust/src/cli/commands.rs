//! CLI command implementations.

use std::sync::Arc;

use crate::apps::{matching, sphere};
use crate::cli::Invocation;
use crate::coordinator::TransformPlan;
use crate::error::Result;
use crate::runtime::{ArtifactRegistry, XlaDwt};
use crate::simulator::cost::{measured_spec, TransformKind};
use crate::simulator::machine::MachineParams;
use crate::simulator::scaling::scaling_curve;
use crate::so3::coeffs::{coeff_count, So3Coeffs};
use crate::so3::quadrature;
use crate::so3::rotation::Rotation;
use crate::so3::sampling::GridAngles;
use crate::transform::So3Plan;

pub const HELP: &str = "\
so3ft — parallel fast Fourier transforms on SO(3)

usage: so3ft <command> [options]

commands:
  info        plan / memory / artifact diagnostics for a bandwidth
  roundtrip   iFSOFT then FSOFT on random coefficients; report errors
  forward     time the FSOFT on a synthesized grid
  inverse     time the iFSOFT on random coefficients
  match       rotational-matching demo (plant + recover a rotation)
  simulate    multicore scaling curves (simulated Opteron-like node)
  help        this text

options: --config FILE, --bandwidth/-b B, --threads/-t N,
  --schedule dynamic[:c]|static|interleaved|guided[:m],
  --strategy geometric|sigma|nosym,
  --algorithm matvec-folded|matvec|clenshaw,
  --storage precomputed|onthefly|auto[:mb], --precision double|extended,
  --pool owned|global (pair global with --threads N; width is
  min(threads, pool)), --seed N, --xla, --artifacts DIR, --cores LIST,
  --kind fwd|inv
";

fn build_plan(inv: &Invocation) -> Result<So3Plan> {
    // The CLI keeps the historical lenient bandwidth behavior (Bluestein
    // fallback for non-powers of two).
    let mut builder = So3Plan::builder(inv.run.bandwidth)
        .config(inv.run.exec.clone())
        .allow_any_bandwidth();
    if inv.run.use_xla {
        let xla = XlaDwt::load(&inv.run.artifacts_dir, inv.run.bandwidth)?;
        builder = builder.offload(Arc::new(xla));
    }
    builder.build()
}

pub fn info(inv: &Invocation) -> Result<()> {
    let b = inv.run.bandwidth;
    let plan = TransformPlan::new(b, inv.run.exec.strategy);
    let weights = quadrature::weights(b)?;
    let angles = GridAngles::new(b)?;
    println!("so3ft bandwidth {b}");
    println!("  grid:            {n}^3 = {} nodes (n = 2B)", (2 * b) * (2 * b) * (2 * b), n = 2 * b);
    println!("  coefficients:    {} (B(4B^2-1)/3)", coeff_count(b));
    println!(
        "  work packages:   {} clusters ({} order pairs), strategy {}",
        plan.clusters.len(),
        plan.member_count(),
        plan.strategy.name()
    );
    println!("  est. DWT flops:  {}", plan.total_flops());
    println!(
        "  wigner tables:   {:.1} MiB when precomputed",
        (crate::dwt::tables::WignerTables::storage_len(b) * 8) as f64 / (1 << 20) as f64
    );
    println!(
        "  weight checksum: {:.6e} (expect {:.6e})",
        weights.iter().sum::<f64>(),
        quadrature::weight_sum_expected(b)
    );
    println!(
        "  beta range:      [{:.4}, {:.4}]",
        angles.betas[0],
        angles.betas[2 * b - 1]
    );
    let reg = ArtifactRegistry::new(&inv.run.artifacts_dir);
    let avail = reg.available();
    println!(
        "  artifacts:       {} in {:?}{}",
        if avail.is_empty() {
            "none".to_string()
        } else {
            format!("{avail:?}")
        },
        reg.dir(),
        if avail.contains(&b) { " (this B: ok)" } else { "" }
    );
    Ok(())
}

pub fn roundtrip(inv: &Invocation) -> Result<()> {
    let fft = build_plan(inv)?;
    let b = inv.run.bandwidth;
    let coeffs = So3Coeffs::random(b, inv.run.seed);
    let (grid, istats) = fft.inverse_with_stats(&coeffs)?;
    let (back, fstats) = fft.forward_with_stats(&grid)?;
    println!(
        "roundtrip b={b} threads={} seed={}",
        inv.run.exec.threads, inv.run.seed
    );
    println!(
        "  iFSOFT: {:?} (dwt {:?}, transpose {:?}, fft {:?})",
        istats.total, istats.dwt, istats.transpose, istats.fft
    );
    println!(
        "  FSOFT:  {:?} (fft {:?}, transpose {:?}, dwt {:?})",
        fstats.total, fstats.fft, fstats.transpose, fstats.dwt
    );
    println!("  max abs error: {:.3e}", coeffs.max_abs_error(&back));
    println!("  max rel error: {:.3e}", coeffs.max_rel_error(&back));
    if let Some(r) = &fstats.dwt_region {
        println!(
            "  fwd DWT region: imbalance {:.3}, overhead {:.1}%",
            r.imbalance(),
            100.0 * r.overhead_fraction()
        );
    }
    Ok(())
}

pub fn forward(inv: &Invocation) -> Result<()> {
    let fft = build_plan(inv)?;
    let coeffs = So3Coeffs::random(inv.run.bandwidth, inv.run.seed);
    let grid = fft.inverse(&coeffs)?;
    let (_, stats) = fft.forward_with_stats(&grid)?;
    println!(
        "forward b={} threads={}: total {:?} (fft {:?}, transpose {:?}, dwt {:?}; fft fraction {:.1}%)",
        inv.run.bandwidth,
        inv.run.exec.threads,
        stats.total,
        stats.fft,
        stats.transpose,
        stats.dwt,
        100.0 * stats.fft_fraction()
    );
    Ok(())
}

pub fn inverse(inv: &Invocation) -> Result<()> {
    let fft = build_plan(inv)?;
    let coeffs = So3Coeffs::random(inv.run.bandwidth, inv.run.seed);
    let (_, stats) = fft.inverse_with_stats(&coeffs)?;
    println!(
        "inverse b={} threads={}: total {:?} (dwt {:?}, transpose {:?}, fft {:?})",
        inv.run.bandwidth, inv.run.exec.threads, stats.total, stats.dwt, stats.transpose, stats.fft
    );
    Ok(())
}

pub fn match_demo(inv: &Invocation) -> Result<()> {
    let b = inv.run.bandwidth;
    let fft = build_plan(inv)?;
    let f = sphere::SphCoeffs::random(b, inv.run.seed);
    let angles = GridAngles::new(b)?;
    // Plant a grid-aligned rotation (reproducible from the seed).
    let idx = (
        (inv.run.seed as usize * 7 + 3) % (2 * b),
        (inv.run.seed as usize * 5 + 1) % (2 * b),
        (inv.run.seed as usize * 11 + 4) % (2 * b),
    );
    let planted = angles.euler(idx.0, idx.1, idx.2);
    let g = f.rotate(planted);
    let t0 = std::time::Instant::now();
    let result = matching::match_rotation(&fft, &f, &g)?;
    let dt = t0.elapsed();
    let dist = Rotation::from_euler(planted).angular_distance(&Rotation::from_euler(result.euler));
    println!("rotational matching b={b} ({} grid nodes searched in {dt:?})", (2 * b) * (2 * b) * (2 * b));
    println!(
        "  planted: alpha={:.4} beta={:.4} gamma={:.4}",
        planted.alpha, planted.beta, planted.gamma
    );
    println!(
        "  found:   alpha={:.4} beta={:.4} gamma={:.4} (peak {:.4})",
        result.euler.alpha, result.euler.beta, result.euler.gamma, result.peak
    );
    println!(
        "  angular distance {:.5} rad (grid cell ~{:.5} rad)",
        dist,
        std::f64::consts::PI / b as f64
    );
    Ok(())
}

pub fn simulate(inv: &Invocation) -> Result<()> {
    let b = inv.run.bandwidth;
    let kind = if inv.kind == "inv" {
        TransformKind::Inverse
    } else {
        TransformKind::Forward
    };
    println!("measuring per-package costs for b={b} {} ...", kind.label());
    let spec = measured_spec(b, kind)?;
    let params = MachineParams::opteron_like();
    let curve = scaling_curve(&spec, &inv.cores, &params);
    println!(
        "simulated Opteron-like scaling ({}; sequential {:.4}s):",
        spec.label,
        spec.sequential_seconds()
    );
    println!("  cores  seconds    speedup  efficiency");
    for p in curve {
        println!(
            "  {:5}  {:9.4}  {:7.2}  {:9.3}",
            p.cores, p.seconds, p.speedup, p.efficiency
        );
    }
    Ok(())
}

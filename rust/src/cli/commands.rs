//! CLI command implementations.

use std::sync::Arc;

use crate::apps::{matching, sphere};
use crate::cli::Invocation;
use crate::coordinator::TransformPlan;
use crate::error::{Error, Result};
use crate::runtime::{ArtifactRegistry, XlaDwt};
use crate::simulator::cost::{measured_spec, TransformKind};
use crate::simulator::machine::MachineParams;
use crate::simulator::scaling::scaling_curve;
use crate::so3::coeffs::{coeff_count, So3Coeffs};
use crate::so3::quadrature;
use crate::so3::rotation::Rotation;
use crate::so3::sampling::GridAngles;
use crate::transform::So3Plan;
use crate::wisdom::{MachineFingerprint, PlanRigor, WisdomSource, WisdomStore};

/// Top-level usage text for the `so3ft` binary.
pub const HELP: &str = "\
so3ft — parallel fast Fourier transforms on SO(3)

usage: so3ft <command> [options]

commands:
  info        plan / memory / artifact diagnostics for a bandwidth
  roundtrip   iFSOFT then FSOFT on random coefficients; report errors
  forward     time the FSOFT on a synthesized grid
  inverse     time the iFSOFT on random coefficients
  match       rotational-matching demo (plant + recover a rotation)
  simulate    multicore scaling curves (simulated Opteron-like node)
  serve-bench So3Service under concurrent mixed-bandwidth job load
  wisdom      plan auto-tuning cache: train | show | clear
  help        this text

options: --config FILE, --bandwidth/-b B, --threads/-t N,
  --schedule dynamic[:c]|static|interleaved|guided[:m],
  --strategy geometric|sigma|nosym,
  --algorithm matvec-folded|matvec|clenshaw,
  --storage precomputed|onthefly|auto[:mb], --precision double|extended,
  --memory-budget auto|unlimited|bytes:N|MiB (plan memory cap; tight
  caps stream Wigner degrees instead of materializing full tables),
  --simd auto|scalar|force-avx2|force-neon (kernel ISA dispatch),
  --pool owned|global (pair global with --threads N; width is
  min(threads, pool)), --seed N, --xla, --artifacts DIR, --cores LIST,
  --kind fwd|inv, --rigor estimate|measure (plan auto-tuning),
  --time-budget-ms N (measurement budget), --wisdom-cache PATH

serve-bench options: --clients N, --jobs N (per client),
  --bandwidths LIST (default 8,16), --window-us N (micro-batch window),
  --rate JOBS_PER_S (open-loop arrival per client; 0 = burst),
  --rate-ramp (double the rate each round until the service sheds load
  with typed rejections), --max-queue N (admission cap on queued jobs),
  --deadline-ms N (default per-job deadline), --inject SPEC (arm fault
  injection, e.g. "batch-runner=3*err(chaos);plan-build=1*sleep(20)"),
  --json PATH (merge service_* records into a BENCH_fft.json
  report), --metrics-json PATH (write the final service metrics
  snapshot as JSON); the worker pool is sized by [service] threads,
  falling back to -t

wisdom usage: so3ft wisdom train [--bandwidths 8,16] [-t N]
  [--time-budget-ms N] [--wisdom-cache PATH]; `show` lists the stored
  entries for this machine, `clear` deletes the store
";

/// The wisdom store this invocation addresses: an explicit
/// `--wisdom-cache` / `[wisdom] cache_path` file, or the process-global
/// store in the shared cache dir.
fn wisdom_store(inv: &Invocation) -> Arc<WisdomStore> {
    match &inv.run.wisdom.cache_path {
        Some(path) => WisdomStore::open(path.as_str()),
        None => WisdomStore::global(),
    }
}

fn build_plan(inv: &Invocation) -> Result<So3Plan> {
    // The CLI keeps the historical lenient bandwidth behavior (Bluestein
    // fallback for non-powers of two).
    let mut builder = So3Plan::builder(inv.run.bandwidth)
        .config(inv.run.exec.clone())
        .rigor(inv.run.wisdom.rigor)
        .wisdom_store(wisdom_store(inv))
        .wisdom_time_budget_ms(inv.run.wisdom.time_budget_ms)
        .allow_any_bandwidth();
    if inv.run.use_xla {
        let xla = XlaDwt::load(&inv.run.artifacts_dir, inv.run.bandwidth)?;
        builder = builder.offload(Arc::new(xla));
    }
    builder.build()
}

/// `info`: print the resolved configuration and plan summary.
pub fn info(inv: &Invocation) -> Result<()> {
    let b = inv.run.bandwidth;
    let plan = TransformPlan::new(b, inv.run.exec.strategy);
    let weights = quadrature::weights(b)?;
    let angles = GridAngles::new(b)?;
    println!("so3ft bandwidth {b}");
    println!("  grid:            {n}^3 = {} nodes (n = 2B)", (2 * b) * (2 * b) * (2 * b), n = 2 * b);
    println!("  coefficients:    {} (B(4B^2-1)/3)", coeff_count(b));
    println!(
        "  work packages:   {} clusters ({} order pairs), strategy {}",
        plan.clusters.len(),
        plan.member_count(),
        plan.strategy.name()
    );
    println!("  est. DWT flops:  {}", plan.total_flops());
    println!(
        "  wigner tables:   {:.1} MiB when precomputed",
        (crate::dwt::tables::WignerTables::storage_len(b) * 8) as f64 / (1 << 20) as f64
    );
    let mib = |x: usize| x as f64 / (1 << 20) as f64;
    let ws_bytes = crate::coordinator::workspace_bytes(b);
    println!("  workspace:       {:.1} MiB (FFT cube + S-matrix)", mib(ws_bytes));
    let budget = inv.run.exec.memory;
    let full = crate::dwt::tables::WignerTables::full_bytes(b);
    match budget.table_budget_bytes(b) {
        Ok(table_budget) => println!(
            "  memory budget:   {budget} -> {}",
            if table_budget.is_some_and(|t| full > t) {
                "streamed Wigner tables (per-degree on-the-fly fallback)"
            } else {
                "fully materialized Wigner tables"
            }
        ),
        Err(e) => println!("  memory budget:   {budget} -> infeasible ({e})"),
    }
    println!(
        "  weight checksum: {:.6e} (expect {:.6e})",
        weights.iter().sum::<f64>(),
        quadrature::weight_sum_expected(b)
    );
    println!(
        "  beta range:      [{:.4}, {:.4}]",
        angles.betas[0],
        angles.betas[2 * b - 1]
    );
    let reg = ArtifactRegistry::new(&inv.run.artifacts_dir);
    let avail = reg.available();
    println!(
        "  artifacts:       {} in {:?}{}",
        if avail.is_empty() {
            "none".to_string()
        } else {
            format!("{avail:?}")
        },
        reg.dir(),
        if avail.contains(&b) { " (this B: ok)" } else { "" }
    );
    Ok(())
}

/// `roundtrip`: inverse-then-forward accuracy check.
pub fn roundtrip(inv: &Invocation) -> Result<()> {
    let fft = build_plan(inv)?;
    let b = inv.run.bandwidth;
    let coeffs = So3Coeffs::random(b, inv.run.seed);
    let (grid, istats) = fft.inverse_with_stats(&coeffs)?;
    let (back, fstats) = fft.forward_with_stats(&grid)?;
    println!(
        "roundtrip b={b} threads={} seed={}",
        inv.run.exec.threads, inv.run.seed
    );
    println!(
        "  iFSOFT: {:?} (dwt {:?}, transpose {:?}, fft {:?})",
        istats.total, istats.dwt, istats.transpose, istats.fft
    );
    println!(
        "  FSOFT:  {:?} (fft {:?}, transpose {:?}, dwt {:?})",
        fstats.total, fstats.fft, fstats.transpose, fstats.dwt
    );
    println!("  max abs error: {:.3e}", coeffs.max_abs_error(&back));
    println!("  max rel error: {:.3e}", coeffs.max_rel_error(&back));
    if let Some(r) = &fstats.dwt_region {
        println!(
            "  fwd DWT region: imbalance {:.3}, overhead {:.1}%",
            r.imbalance(),
            100.0 * r.overhead_fraction()
        );
    }
    Ok(())
}

/// `forward`: run and time one analysis (FSOFT) transform.
pub fn forward(inv: &Invocation) -> Result<()> {
    let fft = build_plan(inv)?;
    let coeffs = So3Coeffs::random(inv.run.bandwidth, inv.run.seed);
    let grid = fft.inverse(&coeffs)?;
    let (_, stats) = fft.forward_with_stats(&grid)?;
    println!(
        "forward b={} threads={}: total {:?} (fft {:?}, transpose {:?}, dwt {:?}; fft fraction {:.1}%)",
        inv.run.bandwidth,
        inv.run.exec.threads,
        stats.total,
        stats.fft,
        stats.transpose,
        stats.dwt,
        100.0 * stats.fft_fraction()
    );
    Ok(())
}

/// `inverse`: run and time one synthesis (iFSOFT) transform.
pub fn inverse(inv: &Invocation) -> Result<()> {
    let fft = build_plan(inv)?;
    let coeffs = So3Coeffs::random(inv.run.bandwidth, inv.run.seed);
    let (_, stats) = fft.inverse_with_stats(&coeffs)?;
    println!(
        "inverse b={} threads={}: total {:?} (dwt {:?}, transpose {:?}, fft {:?})",
        inv.run.bandwidth, inv.run.exec.threads, stats.total, stats.dwt, stats.transpose, stats.fft
    );
    Ok(())
}

/// `match`: rotation-estimation demo via SO(3) correlation.
pub fn match_demo(inv: &Invocation) -> Result<()> {
    let b = inv.run.bandwidth;
    let fft = build_plan(inv)?;
    let f = sphere::SphCoeffs::random(b, inv.run.seed);
    let angles = GridAngles::new(b)?;
    // Plant a grid-aligned rotation (reproducible from the seed).
    let idx = (
        (inv.run.seed as usize * 7 + 3) % (2 * b),
        (inv.run.seed as usize * 5 + 1) % (2 * b),
        (inv.run.seed as usize * 11 + 4) % (2 * b),
    );
    let planted = angles.euler(idx.0, idx.1, idx.2);
    let g = f.rotate(planted);
    let t0 = std::time::Instant::now();
    let result = matching::match_rotation(&fft, &f, &g)?;
    let dt = t0.elapsed();
    let dist = Rotation::from_euler(planted).angular_distance(&Rotation::from_euler(result.euler));
    println!("rotational matching b={b} ({} grid nodes searched in {dt:?})", (2 * b) * (2 * b) * (2 * b));
    println!(
        "  planted: alpha={:.4} beta={:.4} gamma={:.4}",
        planted.alpha, planted.beta, planted.gamma
    );
    println!(
        "  found:   alpha={:.4} beta={:.4} gamma={:.4} (peak {:.4})",
        result.euler.alpha, result.euler.beta, result.euler.gamma, result.peak
    );
    println!(
        "  angular distance {:.5} rad (grid cell ~{:.5} rad)",
        dist,
        std::f64::consts::PI / b as f64
    );
    Ok(())
}

/// Latency percentile over a sorted sample (nearest-rank).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// One prewarmed (input, reference) set for a `serve-bench` bandwidth;
/// references come from the service's own registry plans, so the parity
/// check demands bit-identical results.
struct Template {
    b: usize,
    coeffs: So3Coeffs,
    grid: crate::so3::sampling::So3Grid,
    fwd: So3Coeffs,
}

/// One `serve-bench` round's outcome: latencies of completed jobs plus
/// every shed-load bucket. `completed + rejected + expired + faulted`
/// equals the round's submission attempts.
#[derive(Default)]
struct RoundTally {
    /// `(bandwidth, seconds)` per completed, parity-checked job.
    latencies: Vec<(usize, f64)>,
    /// Submissions refused with a typed `Error::Overloaded`.
    rejected: u64,
    /// Jobs resolved `DeadlineExceeded` or `Cancelled`.
    expired: u64,
    /// Jobs resolved with an injected or plan-build failure.
    faulted: u64,
}

impl RoundTally {
    fn merge(&mut self, other: RoundTally) {
        self.latencies.extend(other.latencies);
        self.rejected += other.rejected;
        self.expired += other.expired;
        self.faulted += other.faulted;
    }
}

/// Run one `serve-bench` round: `clients` threads each submit `jobs`
/// mixed-bandwidth jobs open-loop (paced by `rate` jobs/s when > 0),
/// then collect. Saturation is the measurement, not a failure: typed
/// `Overloaded` rejections and deadline expiries are tallied; with
/// `tolerate_failures` (fault injection armed) execution failures are
/// tallied too instead of aborting the round. A parity mismatch is
/// always fatal.
fn serve_round(
    service: &crate::service::So3Service,
    templates: &[Template],
    clients: usize,
    jobs: usize,
    options: crate::service::PlanOptions,
    rate: f64,
    tolerate_failures: bool,
) -> Result<RoundTally> {
    use crate::service::{Direction, JobHandle, JobSpec};

    let mut per_client: Vec<Result<RoundTally>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for client in 0..clients {
            handles.push(scope.spawn(move || -> Result<RoundTally> {
                let interval =
                    (rate > 0.0).then(|| std::time::Duration::from_secs_f64(1.0 / rate));
                let mut tally = RoundTally::default();
                let mut pending: Vec<(usize, Direction, JobHandle)> = Vec::with_capacity(jobs);
                for i in 0..jobs {
                    let ti = (client + i) % templates.len();
                    let t = &templates[ti];
                    let direction = if (client + i) % 2 == 0 {
                        Direction::Inverse
                    } else {
                        Direction::Forward
                    };
                    // Inputs come from the buffer pool (filled from the
                    // template), so the client side allocates nothing
                    // per job in the steady state either.
                    let submitted = match direction {
                        Direction::Inverse => {
                            let mut input = service.checkout_coeffs(t.b)?;
                            input.as_mut_slice().copy_from_slice(t.coeffs.as_slice());
                            service.submit(JobSpec::inverse(t.b).options(options), input)
                        }
                        Direction::Forward => {
                            let mut input = service.checkout_grid(t.b)?;
                            input.as_mut_slice().copy_from_slice(t.grid.as_slice());
                            service.submit(JobSpec::forward(t.b).options(options), input)
                        }
                    };
                    match submitted {
                        Ok(handle) => pending.push((ti, direction, handle)),
                        Err(Error::Overloaded { .. }) => tally.rejected += 1,
                        Err(e) => return Err(e),
                    }
                    // Pace the NEXT arrival only — sleeping after the
                    // final submission would pad the measured wall time.
                    if let (Some(interval), true) = (interval, i + 1 < jobs) {
                        std::thread::sleep(interval);
                    }
                }
                for (ti, direction, handle) in pending {
                    let t = &templates[ti];
                    match handle.wait_timed() {
                        Ok((out, latency)) => {
                            let ok = match direction {
                                Direction::Inverse => out
                                    .grid()
                                    .is_some_and(|g| g.as_slice() == t.grid.as_slice()),
                                Direction::Forward => out
                                    .coeffs()
                                    .is_some_and(|c| c.as_slice() == t.fwd.as_slice()),
                            };
                            if !ok {
                                return Err(Error::Service(format!(
                                    "parity mismatch: {direction:?} b={} diverged from the plan",
                                    t.b
                                )));
                            }
                            service.recycle(out);
                            tally.latencies.push((t.b, latency.as_secs_f64()));
                        }
                        Err(Error::DeadlineExceeded { .. }) | Err(Error::Cancelled) => {
                            tally.expired += 1;
                        }
                        Err(Error::FaultInjected { .. }) | Err(Error::PlanBuildFailed { .. }) => {
                            tally.faulted += 1;
                        }
                        Err(_) if tolerate_failures => tally.faulted += 1,
                        Err(e) => return Err(e),
                    }
                }
                Ok(tally)
            }));
        }
        for h in handles {
            per_client.push(h.join().expect("client thread panicked"));
        }
    });
    let mut total = RoundTally::default();
    for r in per_client {
        total.merge(r?);
    }
    Ok(total)
}

/// `serve-bench`: N client threads submit mixed-bandwidth jobs to one
/// `So3Service` at an open-loop arrival rate; reports throughput and
/// latency percentiles, verifies every result bit-for-bit against the
/// registry plan, and (with `--json`) merges `service_throughput` /
/// `service_p99` records into a BENCH_fft.json-format report for the CI
/// gate. `--rate-ramp` turns it into an overload probe: the arrival
/// rate doubles each round until the service sheds load with typed
/// rejections (a final burst round guarantees saturation), and
/// `service_rejected` / `service_admitted_p99` records capture the
/// saturation behavior for the chaos gate. `--inject` arms
/// [`crate::faults`] before the run.
pub fn serve_bench(inv: &Invocation) -> Result<()> {
    use crate::bench_util::{append_json_records, fmt_seconds, Table};
    use crate::service::PlanOptions;

    let sb = &inv.serve;
    if let Some(spec) = &sb.inject {
        crate::faults::arm_from_spec(spec)?;
        println!("fault injection armed: {spec}");
    }
    let threads = if inv.run.service.threads > 0 {
        inv.run.service.threads
    } else {
        inv.run.exec.threads
    };
    let options = PlanOptions::from_exec(&inv.run.exec);
    let service = inv
        .run
        .service
        .to_builder()
        .threads(threads)
        .default_options(options)
        .allow_any_bandwidth()
        .build()?;

    // Prewarm: one plan + one input/reference pair per bandwidth, built
    // through the service registry so the bench measures serving, not
    // first-touch planning.
    let mut templates = Vec::with_capacity(sb.bandwidths.len());
    for &b in &sb.bandwidths {
        let plan = service.plan(b, options)?;
        let coeffs = So3Coeffs::random(b, inv.run.seed.wrapping_add(b as u64));
        let grid = plan.inverse(&coeffs)?;
        let fwd = plan.forward(&grid)?;
        templates.push(Template {
            b,
            coeffs,
            grid,
            fwd,
        });
    }

    println!(
        "serve-bench: {} clients x {} jobs, bandwidths {:?}, {} worker threads, \
         window {} us, rate {}{}",
        sb.clients,
        sb.jobs,
        sb.bandwidths,
        threads,
        inv.run.service.batch_window_us,
        if sb.rate > 0.0 {
            format!("{} jobs/s/client", sb.rate)
        } else {
            "burst".to_string()
        },
        if sb.rate_ramp { " (ramping)" } else { "" }
    );

    let tolerate = sb.inject.is_some();
    let t0 = std::time::Instant::now();
    let mut tally = RoundTally::default();
    if sb.rate_ramp {
        // Overload probe: double the per-client rate each round until
        // the service sheds load with a typed rejection; a final burst
        // round guarantees saturation even if pacing never outran the
        // workers.
        const MAX_RAMP_ROUNDS: usize = 6;
        let mut rate = if sb.rate > 0.0 { sb.rate } else { 50.0 };
        for round in 1..=MAX_RAMP_ROUNDS {
            println!("ramp round {round}: {rate} jobs/s/client");
            let r = serve_round(
                &service, &templates, sb.clients, sb.jobs, options, rate, tolerate,
            )?;
            let shed = r.rejected > 0;
            tally.merge(r);
            if shed {
                break;
            }
            rate *= 2.0;
        }
        if tally.rejected == 0 {
            println!("ramp final round: burst");
            let r = serve_round(
                &service, &templates, sb.clients, sb.jobs, options, 0.0, tolerate,
            )?;
            tally.merge(r);
        }
    } else {
        tally = serve_round(
            &service, &templates, sb.clients, sb.jobs, options, sb.rate, tolerate,
        )?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let all = &tally.latencies;
    let completed = all.len();
    let throughput = completed as f64 / wall;
    let stats = service.stats();

    let mut table = Table::new(&["B", "jobs", "p50", "p95", "p99", "max"]);
    let mut records: Vec<String> = Vec::new();
    for &b in &sb.bandwidths {
        let mut lat: Vec<f64> = all
            .iter()
            .filter(|(lb, _)| *lb == b)
            .map(|&(_, s)| s)
            .collect();
        lat.sort_by(|x, y| x.partial_cmp(y).expect("latencies are finite"));
        let (p50, p95, p99) = (
            percentile(&lat, 50.0),
            percentile(&lat, 95.0),
            percentile(&lat, 99.0),
        );
        let max = lat.last().copied().unwrap_or(0.0);
        table.row(&[
            b.to_string(),
            lat.len().to_string(),
            fmt_seconds(p50),
            fmt_seconds(p95),
            fmt_seconds(p99),
            fmt_seconds(max),
        ]);
        records.push(format!(
            "{{\"kind\": \"service_p99\", \"b\": {b}, \"threads\": {threads}, \
             \"engine\": \"service\", \"jobs\": {}, \"p50_s\": {p50:.6e}, \
             \"p95_s\": {p95:.6e}, \"p99_s\": {p99:.6e}, \"max_s\": {max:.6e}}}",
            lat.len()
        ));
    }
    table.print();
    println!(
        "throughput: {throughput:.1} jobs/s ({completed} completed in {}); \
         batches {} (max size {}), registry {} plans ({} hits / {} misses / {} evictions), \
         buffers created: {} workspaces, {} grids, {} coeffs",
        fmt_seconds(wall),
        stats.batches,
        stats.max_batch_size,
        stats.registry.plans,
        stats.registry.hits,
        stats.registry.misses,
        stats.registry.evictions,
        stats.buffers.workspaces_created,
        stats.buffers.grids_created,
        stats.buffers.coeffs_created,
    );
    println!("parity: all {completed} completed results bit-identical to the registry plans");
    if sb.rate_ramp || tally.rejected + tally.expired + tally.faulted > 0 {
        println!(
            "shed load: {} rejected (typed Overloaded), {} deadline-expired/cancelled, \
             {} faulted",
            tally.rejected, tally.expired, tally.faulted
        );
    }
    // b = 0 marks the mixed-traffic aggregate (the per-bandwidth rows
    // carry their own keys); per_job_s is gated in CI (lower = better,
    // unlike raw throughput).
    records.push(format!(
        "{{\"kind\": \"service_throughput\", \"b\": 0, \"threads\": {threads}, \
         \"engine\": \"service\", \"jobs\": {completed}, \"wall_s\": {wall:.6e}, \
         \"throughput_jobs_per_s\": {throughput:.3}, \"per_job_s\": {:.6e}}}",
        wall / completed.max(1) as f64
    ));
    if sb.rate_ramp {
        // Chaos-gate records: `rejected_jobs` is gated as a FLOOR (the
        // ramp must actually reach typed saturation), `p99_s` as the
        // usual ceiling over admitted jobs.
        let mut all_lat: Vec<f64> = all.iter().map(|&(_, s)| s).collect();
        all_lat.sort_by(|x, y| x.partial_cmp(y).expect("latencies are finite"));
        let p99_all = percentile(&all_lat, 99.0);
        records.push(format!(
            "{{\"kind\": \"service_rejected\", \"b\": 0, \"threads\": {threads}, \
             \"engine\": \"service\", \"rejected_jobs\": {}}}",
            tally.rejected
        ));
        records.push(format!(
            "{{\"kind\": \"service_admitted_p99\", \"b\": 0, \"threads\": {threads}, \
             \"engine\": \"service\", \"jobs\": {completed}, \"p99_s\": {p99_all:.6e}}}"
        ));
    }
    if let Some(path) = &sb.json {
        append_json_records(path, &records)?;
        println!("merged {} service records into {path}", records.len());
    }
    let metrics = service.metrics();
    print!("{metrics}");
    if let Some(path) = &sb.metrics_json {
        std::fs::write(path, format!("{}\n", metrics.to_json()))?;
        println!("wrote service metrics snapshot to {path}");
    }
    if sb.inject.is_some() {
        crate::faults::disarm_all();
    }
    Ok(())
}

/// `wisdom train|show|clear`: manage the measured-planning cache.
///
/// `train` runs `PlanRigor::Measure` builds for each `--bandwidths`
/// entry (default 8,16) so later `--rigor measure` runs — and service
/// registries pointed at the same store — start from cache hits. The
/// per-bandwidth "cache hit" / "measured" lines are stable output the
/// CI smoke test greps.
pub fn wisdom(inv: &Invocation) -> Result<()> {
    use crate::wisdom::store::{algorithm_name, fft_engine_name};

    let store = wisdom_store(inv);
    let location = store
        .path()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "(in-memory)".into());
    let fp = MachineFingerprint::current();
    match inv.wisdom_action.as_str() {
        "train" => {
            println!(
                "wisdom train: store {location}, machine {fp} (digest {:016x})",
                fp.digest()
            );
            for &b in &inv.serve.bandwidths {
                let plan = So3Plan::builder(b)
                    .config(inv.run.exec.clone())
                    .rigor(PlanRigor::Measure)
                    .wisdom_store(Arc::clone(&store))
                    .wisdom_time_budget_ms(inv.run.wisdom.time_budget_ms)
                    .allow_any_bandwidth()
                    .build()?;
                let out = plan
                    .wisdom()
                    .expect("a Measure build always reports a wisdom outcome");
                let knobs = out.choice.as_ref().map(|c| {
                    format!(
                        "schedule={} strategy={} algorithm={} fft={} simd={}",
                        c.schedule.name(),
                        c.strategy.name(),
                        algorithm_name(c.algorithm),
                        fft_engine_name(c.fft_engine),
                        c.simd.name()
                    )
                });
                match (&out.source, knobs) {
                    (WisdomSource::CacheHit, Some(k)) => println!(
                        "  b={b}: cache hit ({k}) in {:.1} ms",
                        1e3 * out.search_seconds
                    ),
                    (WisdomSource::Measured, Some(k)) => println!(
                        "  b={b}: measured ({k}) in {:.1} ms",
                        1e3 * out.search_seconds
                    ),
                    (WisdomSource::Fallback(w), _) => println!("  b={b}: fallback ({w})"),
                    // CacheHit/Measured always carry a choice.
                    (_, None) => unreachable!("tuned outcome without a choice"),
                }
            }
            let stats = store.stats();
            println!(
                "  totals: {} hits, {} misses, {} measurement passes",
                stats.hits, stats.misses, stats.measurements
            );
        }
        "show" => {
            println!(
                "wisdom store: {location}, machine {fp} (digest {:016x})",
                fp.digest()
            );
            let entries = store.entries();
            if entries.is_empty() {
                println!("  no entries for this machine (run `so3ft wisdom train`)");
            }
            for (key, entry) in entries {
                println!(
                    "  b={} dir={} threads={}: {}",
                    key.bandwidth,
                    key.direction.name(),
                    key.threads,
                    entry.describe()
                );
            }
        }
        "clear" => {
            store.clear();
            println!("wisdom store cleared: {location}");
        }
        other => {
            // parse_args validates; belt and braces for library callers.
            return Err(Error::Config(format!(
                "wisdom: unknown action {other:?} (train | show | clear)"
            )));
        }
    }
    Ok(())
}

/// `simulate`: multicore scaling prediction (paper Figs. 4–7).
pub fn simulate(inv: &Invocation) -> Result<()> {
    let b = inv.run.bandwidth;
    let kind = if inv.kind == "inv" {
        TransformKind::Inverse
    } else {
        TransformKind::Forward
    };
    println!("measuring per-package costs for b={b} {} ...", kind.label());
    let spec = measured_spec(b, kind)?;
    let params = MachineParams::opteron_like();
    let curve = scaling_curve(&spec, &inv.cores, &params);
    println!(
        "simulated Opteron-like scaling ({}; sequential {:.4}s):",
        spec.label,
        spec.sequential_seconds()
    );
    println!("  cores  seconds    speedup  efficiency");
    for p in curve {
        println!(
            "  {:5}  {:9.4}  {:7.2}  {:9.3}",
            p.cores, p.seconds, p.speedup, p.efficiency
        );
    }
    Ok(())
}

//! Fast rotational matching on SO(3) — the paper's flagship application
//! family (Kovacs & Wriggers 2002; EM density fitting, molecular
//! replacement, docking, spherical image registration).
//!
//! Given two band-limited spherical functions f and g, the rotational
//! correlation
//!
//! `C(R) = ∫_{S²} f(ω) · conj(g(R⁻¹ω)) dω`
//!
//! expands (in our conventions — see `apps::sphere` and the rotation
//! formula validated there) into SO(3) Fourier coefficients
//!
//! `C°(l, a, b) = 4π/(2l+1) · f_{l,−b} · conj(g_{l,−a})`,
//!
//! so one **iFSOFT** evaluates C on the whole (2B)³ Euler grid at once;
//! the arg-max node is the matching rotation. This is exactly the
//! workload whose parallelization the paper targets.

use crate::apps::sphere::SphCoeffs;
use crate::coordinator::Workspace;
use crate::error::Result;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::rotation::EulerZyz;
use crate::so3::sampling::{GridAngles, So3Grid};
use crate::transform::Transform;

/// Correlation coefficients C°(l, a, b) for the pair (f, g).
pub fn correlation_coeffs(f: &SphCoeffs, g: &SphCoeffs) -> So3Coeffs {
    assert_eq!(f.bandwidth(), g.bandwidth());
    let b = f.bandwidth();
    let mut out = So3Coeffs::zeros(b);
    for l in 0..b {
        let li = l as i64;
        let nl = 4.0 * std::f64::consts::PI / (2 * l + 1) as f64;
        for a in -li..=li {
            for bb in -li..=li {
                *out.at_mut(l, a, bb) = (f.at(l, -bb) * g.at(l, -a).conj()).scale(nl);
            }
        }
    }
    out
}

/// Result of a rotational match.
#[derive(Debug, Clone)]
pub struct MatchResult {
    /// The aligning rotation: `Λ_R f ≈ g`, i.e. g ≈ f rotated by `euler`.
    /// (The raw correlation peak sits at its inverse: C(R) = ⟨f, Λ_R g⟩
    /// is maximal where Λ_R g ≈ f.)
    pub euler: EulerZyz,
    /// Euler angles of the best grid node itself (argmax of Re C).
    pub peak_euler: EulerZyz,
    /// Correlation value at the peak (real part).
    pub peak: f64,
    /// Grid indices (i, j, k) of the peak.
    pub index: (usize, usize, usize),
    /// The full correlation grid (for refinement / inspection).
    pub grid: So3Grid,
}

/// Find the rotation aligning f to g (so that `f.rotate(result.euler)`
/// best matches g), by maximizing Re C(R) over the (2B)³ grid with one
/// iFSOFT through the provided transform engine (any [`Transform`]
/// backend: an `So3Plan`, a raw executor, or the deprecated `So3Fft`
/// facade).
pub fn match_rotation<T: Transform + ?Sized>(
    fft: &T,
    f: &SphCoeffs,
    g: &SphCoeffs,
) -> Result<MatchResult> {
    let mut ws = fft.make_workspace();
    match_rotation_with(fft, f, g, &mut ws)
}

/// Serving-path variant of [`match_rotation`]: the caller owns the
/// workspace, so repeated matches through one plan reuse all transform
/// scratch (one correlation-grid allocation per call remains — it is
/// returned in the result).
pub fn match_rotation_with<T: Transform + ?Sized>(
    fft: &T,
    f: &SphCoeffs,
    g: &SphCoeffs,
    ws: &mut Workspace,
) -> Result<MatchResult> {
    let b = f.bandwidth();
    let coeffs = correlation_coeffs(f, g);
    let mut grid = So3Grid::zeros(b)?;
    fft.inverse_into(&coeffs, &mut grid, ws)?;
    let n = 2 * b;
    let mut best = f64::NEG_INFINITY;
    let mut best_idx = (0usize, 0usize, 0usize);
    for j in 0..n {
        for i in 0..n {
            for k in 0..n {
                let v = grid.get(i, j, k).re;
                if v > best {
                    best = v;
                    best_idx = (i, j, k);
                }
            }
        }
    }
    let angles = GridAngles::new(b)?;
    let peak_euler = angles.euler(best_idx.0, best_idx.1, best_idx.2);
    let aligning = crate::so3::rotation::Rotation::from_euler(peak_euler)
        .inverse()
        .to_euler();
    Ok(MatchResult {
        euler: aligning,
        peak_euler,
        peak: best,
        index: best_idx,
        grid,
    })
}

/// Direct-evaluation correlation at one rotation (the O(B⁴)-per-point
/// oracle used to validate the fast path):
/// `C(R) = Σ_lm N_l · f_lm · conj((Λ_R g)_lm)`.
pub fn correlation_direct(f: &SphCoeffs, g: &SphCoeffs, e: EulerZyz) -> f64 {
    let b = f.bandwidth();
    let rotated = g.rotate(e);
    let mut acc = 0.0;
    for l in 0..b {
        let li = l as i64;
        let nl = 4.0 * std::f64::consts::PI / (2 * l + 1) as f64;
        for m in -li..=li {
            acc += (f.at(l, m) * rotated.at(l, m).conj()).re * nl;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::rotation::Rotation;
    use crate::transform::So3Plan;

    /// The generic entry point accepts every backend handle type
    /// (sequential and pooled plans here; facade parity lives in
    /// `rust/tests/plan_api.rs`).
    #[test]
    fn match_rotation_accepts_any_transform_backend() {
        let b = 4;
        let f = SphCoeffs::random(b, 31);
        let g = f.rotate(EulerZyz::new(0.3, 0.9, 1.2));
        let seq = So3Plan::new(b).unwrap();
        let par = So3Plan::builder(b).threads(2).build().unwrap();
        let via_par = match_rotation(&par, &f, &g).unwrap();
        let via_seq = match_rotation(&seq, &f, &g).unwrap();
        assert_eq!(via_par.index, via_seq.index);
        assert_eq!(via_par.grid.as_slice(), via_seq.grid.as_slice());
        // Workspace-reusing variant agrees bit for bit.
        let mut ws = seq.make_workspace();
        let with_ws = match_rotation_with(&seq, &f, &g, &mut ws).unwrap();
        assert_eq!(with_ws.grid.as_slice(), via_seq.grid.as_slice());
    }

    /// The fast correlation grid must equal the direct correlation at
    /// every probed node — validates the C°(l,a,b) formula end to end.
    #[test]
    fn fast_correlation_matches_direct() {
        let b = 4;
        let f = SphCoeffs::random(b, 1);
        let g = SphCoeffs::random(b, 2);
        let fft = So3Plan::new(b).unwrap();
        let coeffs = correlation_coeffs(&f, &g);
        let grid = fft.inverse(&coeffs).unwrap();
        let angles = GridAngles::new(b).unwrap();
        for (i, j, k) in [(0, 0, 0), (1, 3, 5), (7, 2, 4), (3, 6, 1)] {
            let e = angles.euler(i, j, k);
            let want = correlation_direct(&f, &g, e);
            let got = grid.get(i, j, k);
            // (C is complex for complex-valued f, g; correlation_direct
            // returns its real part, which is what matching maximizes.)
            assert!(
                (got.re - want).abs() < 1e-9 * (1.0 + want.abs()),
                "node ({i},{j},{k}): {} vs {want}",
                got.re
            );
        }
    }

    /// Rotate g by a known rotation; matching must recover it within
    /// grid resolution.
    #[test]
    fn recovers_planted_rotation() {
        let b = 8;
        let f = SphCoeffs::random(b, 42);
        let angles = GridAngles::new(b).unwrap();
        // Plant a rotation close to a grid node so the discrete arg-max
        // can hit it. g = Λ_{R0} f so C(R) peaks at R = R0.
        let planted = angles.euler(3, 5, 9);
        let g = f.rotate(planted);
        let fft = So3Plan::new(b).unwrap();
        let result = match_rotation(&fft, &f, &g).unwrap();
        let r_planted = Rotation::from_euler(planted);
        let r_found = Rotation::from_euler(result.euler);
        let dist = r_planted.angular_distance(&r_found);
        // Grid resolution is ~π/B; the peak must land within one cell.
        let cell = std::f64::consts::PI / b as f64;
        assert!(
            dist <= 1.5 * cell,
            "planted rotation missed: angular distance {dist} (cell {cell})"
        );
        // And the peak value should be close to the autocorrelation bound
        // C(R0) = Σ N_l |f_lm|².
        let bound = correlation_direct(&f, &f, EulerZyz::new(0.0, 1e-14, 0.0));
        assert!(result.peak > 0.9 * bound, "peak {} vs bound {bound}", result.peak);
    }

    #[test]
    fn self_correlation_peaks_at_identity() {
        let b = 6;
        let f = SphCoeffs::random(b, 7);
        let fft = So3Plan::builder(b).allow_any_bandwidth().build().unwrap();
        let result = match_rotation(&fft, &f, &f).unwrap();
        let r = Rotation::from_euler(result.euler);
        let dist = r.angular_distance(&Rotation::IDENTITY);
        // β grid nodes don't include 0 exactly; allow ~1.5 cells.
        assert!(
            dist <= 1.5 * std::f64::consts::PI / b as f64,
            "self-match should peak near identity, got distance {dist}"
        );
    }

    #[test]
    fn correlation_coeffs_shape() {
        let b = 3;
        let f = SphCoeffs::random(b, 1);
        let g = SphCoeffs::random(b, 2);
        let c = correlation_coeffs(&f, &g);
        assert_eq!(c.bandwidth(), b);
        // Spot-check the formula at (l, a, b) = (2, 1, -2).
        let nl = 4.0 * std::f64::consts::PI / 5.0;
        let want = (f.at(2, 2) * g.at(2, -1).conj()).scale(nl);
        assert!((c.at(2, 1, -2) - want).abs() < 1e-15);
    }
}

//! Band-limited functions on the sphere S².
//!
//! Basis convention (internal, self-consistent with the SO(3) stack):
//! `Y(l, m; θ, φ) = e^{imφ} d(l, m, 0; θ)` with our Wigner-d convention,
//! orthogonal with `⟨Y_lm, Y_lm⟩ = 4π/(2l+1)`.
//!
//! Grid: θ_j = (2j+1)π/4B (the K&R β nodes, reusing the SO(3) quadrature
//! weights), φ_k = kπ/B; both axes 2B points.
//!
//! Rotation (validated numerically in tests, derivation in
//! DESIGN.md §apps): for R = R(α, β, γ) (z-y-z) and (Λ_R f)(ω) := f(R⁻¹ω),
//!
//! `(Λ_R f)_{l,m} = Σ_{m'} e^{-imγ} d(l, m, m'; β) e^{-im'α} f_{l,m'}`.

use crate::error::Result;
use crate::fft::Complex64;
use crate::so3::quadrature;
use crate::so3::rotation::EulerZyz;
use crate::so3::sampling::GridAngles;
use crate::so3::wigner::{d_column, WignerRowBuf};

/// Coefficients a_{l,m} of a band-limited spherical function, l < B,
/// |m| ≤ l, stored flat with `index = l² + (m + l)`.
#[derive(Debug, Clone, PartialEq)]
pub struct SphCoeffs {
    b: usize,
    data: Vec<Complex64>,
}

/// Number of spherical coefficients for bandwidth B: B².
#[inline]
pub fn sph_coeff_count(b: usize) -> usize {
    b * b
}

#[inline]
fn sph_index(l: usize, m: i64) -> usize {
    l * l + (m + l as i64) as usize
}

impl SphCoeffs {
    /// Zero-filled spherical coefficients for bandwidth `b`.
    pub fn zeros(b: usize) -> Self {
        assert!(b >= 1);
        Self {
            b,
            data: vec![Complex64::zero(); sph_coeff_count(b)],
        }
    }

    /// Random coefficients, uniform re/im on [-1, 1].
    pub fn random(b: usize, seed: u64) -> Self {
        let mut rng = crate::prng::Xoshiro256::seed_from_u64(seed);
        let mut c = Self::zeros(b);
        for v in c.data.iter_mut() {
            *v = Complex64::new(rng.next_signed(), rng.next_signed());
        }
        c
    }

    /// Bandwidth B of this coefficient set.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Coefficient `f_l^m`.
    #[inline]
    pub fn at(&self, l: usize, m: i64) -> Complex64 {
        debug_assert!(l < self.b && m.unsigned_abs() as usize <= l);
        self.data[sph_index(l, m)]
    }

    /// Mutable coefficient `f_l^m`.
    #[inline]
    pub fn at_mut(&mut self, l: usize, m: i64) -> &mut Complex64 {
        debug_assert!(l < self.b && m.unsigned_abs() as usize <= l);
        &mut self.data[sph_index(l, m)]
    }

    /// Flat coefficient storage.
    pub fn as_slice(&self) -> &[Complex64] {
        &self.data
    }

    /// Largest elementwise absolute difference.
    pub fn max_abs_error(&self, other: &SphCoeffs) -> f64 {
        assert_eq!(self.b, other.b);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (*a - *b).abs())
            .fold(0.0, f64::max)
    }

    /// Evaluate the function at an arbitrary point (θ, φ).
    pub fn eval(&self, theta: f64, phi: f64) -> Complex64 {
        let mut buf = WignerRowBuf::new(self.b);
        let mut acc = Complex64::zero();
        for m in (1 - self.b as i64)..self.b as i64 {
            d_column(self.b, m, 0, theta, &mut buf);
            let mut radial = Complex64::zero();
            let l0 = m.unsigned_abs() as usize;
            for l in l0..self.b {
                radial += self.at(l, m).scale(buf.values[l]);
            }
            acc += radial * Complex64::cis(m as f64 * phi);
        }
        acc
    }

    /// Rotate in coefficient space: returns the coefficients of
    /// `ω ↦ f(R⁻¹ω)` for R = R(e).
    pub fn rotate(&self, e: EulerZyz) -> SphCoeffs {
        let b = self.b;
        let mut out = SphCoeffs::zeros(b);
        let mut buf = WignerRowBuf::new(b);
        for l in 0..b {
            let li = l as i64;
            for m in -li..=li {
                let mut acc = Complex64::zero();
                for mp in -li..=li {
                    d_column(b, m, mp, e.beta, &mut buf);
                    let phase = Complex64::cis(-(m as f64) * e.gamma - mp as f64 * e.alpha);
                    acc += self.at(l, mp) * phase.scale(buf.values[l]);
                }
                *out.at_mut(l, m) = acc;
            }
        }
        out
    }
}

/// Sampled spherical function on the 2B×2B (θ, φ) grid, row-major
/// `[j (θ)][k (φ)]`.
#[derive(Debug, Clone)]
pub struct SphGrid {
    b: usize,
    /// Row-major samples, `2B × 2B`.
    pub data: Vec<Complex64>,
}

impl SphGrid {
    /// Zero-filled sphere grid for bandwidth `b`.
    pub fn zeros(b: usize) -> Self {
        Self {
            b,
            data: vec![Complex64::zero(); 4 * b * b],
        }
    }

    /// Bandwidth B of this grid.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Sample at colatitude index `j`, longitude index `k`.
    #[inline]
    pub fn at(&self, j: usize, k: usize) -> Complex64 {
        self.data[j * 2 * self.b + k]
    }
}

/// Grid angles for the sphere (θ from the K&R β nodes, φ = kπ/B).
pub fn sphere_angles(b: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    let angles = GridAngles::new(b)?;
    Ok((angles.betas, angles.alphas))
}

/// Synthesis: coefficients → grid samples.
pub fn synthesis(coeffs: &SphCoeffs) -> Result<SphGrid> {
    let b = coeffs.bandwidth();
    let n = 2 * b;
    let (thetas, phis) = sphere_angles(b)?;
    let mut grid = SphGrid::zeros(b);
    let mut buf = WignerRowBuf::new(b);
    for (j, &theta) in thetas.iter().enumerate() {
        // Radial sums per order, then a short Fourier sum over φ.
        let mut radial = vec![Complex64::zero(); 2 * b - 1];
        for m in (1 - b as i64)..b as i64 {
            d_column(b, m, 0, theta, &mut buf);
            let l0 = m.unsigned_abs() as usize;
            let mut acc = Complex64::zero();
            for l in l0..b {
                acc += coeffs.at(l, m).scale(buf.values[l]);
            }
            radial[(m + b as i64 - 1) as usize] = acc;
        }
        for (k, &phi) in phis.iter().enumerate() {
            let mut acc = Complex64::zero();
            for m in (1 - b as i64)..b as i64 {
                acc += radial[(m + b as i64 - 1) as usize] * Complex64::cis(m as f64 * phi);
            }
            grid.data[j * n + k] = acc;
        }
    }
    Ok(grid)
}

/// Analysis: grid samples → coefficients, via the S² quadrature
/// `a_lm = (2l+1)/(4π) Σ_{j,k} w_B(j) d(l,m,0;θ_j) f(θ_j,φ_k) e^{-imφ_k}`.
pub fn analysis(grid: &SphGrid) -> Result<SphCoeffs> {
    let b = grid.bandwidth();
    let n = 2 * b;
    let (thetas, phis) = sphere_angles(b)?;
    let weights = quadrature::weights(b)?;
    let mut coeffs = SphCoeffs::zeros(b);
    let mut buf = WignerRowBuf::new(b);
    for m in (1 - b as i64)..b as i64 {
        // φ inner sums per θ row.
        let mut phi_sums = vec![Complex64::zero(); n];
        for j in 0..n {
            let mut acc = Complex64::zero();
            for (k, &phi) in phis.iter().enumerate() {
                acc += grid.data[j * n + k] * Complex64::cis(-(m as f64) * phi);
            }
            phi_sums[j] = acc;
        }
        // One Wigner column per θ node (not per (l, θ) pair — the column
        // holds every degree at once), accumulated degree-wise in the
        // same j order as the naive double loop, so results are
        // bit-identical while the d_column work drops by a factor of B.
        let l0 = m.unsigned_abs() as usize;
        let mut acc = vec![Complex64::zero(); b];
        for (j, &theta) in thetas.iter().enumerate() {
            d_column(b, m, 0, theta, &mut buf);
            let wj = weights[j];
            let pj = phi_sums[j];
            for (slot, &d) in acc[l0..b].iter_mut().zip(&buf.values[l0..b]) {
                *slot += pj.scale(wj * d);
            }
        }
        for (l, &a) in acc.iter().enumerate().take(b).skip(l0) {
            let scale = (2 * l + 1) as f64 / (4.0 * std::f64::consts::PI);
            *coeffs.at_mut(l, m) = a.scale(scale);
        }
    }
    Ok(coeffs)
}

/// Sample `f(R⁻¹ω)` pointwise on the grid (the slow oracle for
/// [`SphCoeffs::rotate`]).
pub fn rotate_pointwise(coeffs: &SphCoeffs, e: EulerZyz) -> Result<SphGrid> {
    use crate::so3::rotation::Rotation;
    let b = coeffs.bandwidth();
    let n = 2 * b;
    let (thetas, phis) = sphere_angles(b)?;
    let rinv = Rotation::from_euler(e).inverse();
    let mut grid = SphGrid::zeros(b);
    for (j, &theta) in thetas.iter().enumerate() {
        for (k, &phi) in phis.iter().enumerate() {
            let v = [
                theta.sin() * phi.cos(),
                theta.sin() * phi.sin(),
                theta.cos(),
            ];
            let w = rinv.apply(v);
            let t2 = w[2].clamp(-1.0, 1.0).acos();
            let p2 = w[1].atan2(w[0]).rem_euclid(std::f64::consts::TAU);
            grid.data[j * n + k] = coeffs.eval(t2, p2);
        }
    }
    Ok(grid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Prop;

    #[test]
    fn analysis_inverts_synthesis() {
        for b in [2usize, 4, 8] {
            let coeffs = SphCoeffs::random(b, b as u64);
            let grid = synthesis(&coeffs).unwrap();
            let back = analysis(&grid).unwrap();
            let err = coeffs.max_abs_error(&back);
            assert!(err < 1e-12, "b={b}: sphere roundtrip error {err}");
        }
    }

    #[test]
    fn constant_function_has_only_l0() {
        let b = 4;
        let mut grid = SphGrid::zeros(b);
        for v in grid.data.iter_mut() {
            *v = Complex64::new(3.5, -1.0);
        }
        let coeffs = analysis(&grid).unwrap();
        for l in 0..b {
            let li = l as i64;
            for m in -li..=li {
                let want = if l == 0 {
                    Complex64::new(3.5, -1.0)
                } else {
                    Complex64::zero()
                };
                assert!((coeffs.at(l, m) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn eval_matches_grid_synthesis() {
        let b = 4;
        let coeffs = SphCoeffs::random(b, 9);
        let grid = synthesis(&coeffs).unwrap();
        let (thetas, phis) = sphere_angles(b).unwrap();
        for j in [0usize, 3, 7] {
            for k in [1usize, 4, 6] {
                let direct = coeffs.eval(thetas[j], phis[k]);
                assert!((direct - grid.at(j, k)).abs() < 1e-11);
            }
        }
    }

    /// The rotation formula — coefficient-space rotation must equal
    /// pointwise rotation followed by analysis. This pins down the
    /// convention the matching app depends on.
    #[test]
    fn coefficient_rotation_matches_pointwise() {
        let b = 4;
        let coeffs = SphCoeffs::random(b, 11);
        Prop::new("sphere rotation convention").cases(8).run(|g| {
            let e = EulerZyz::new(
                g.f64_in(0.0, std::f64::consts::TAU),
                g.f64_in(0.1, std::f64::consts::PI - 0.1),
                g.f64_in(0.0, std::f64::consts::TAU),
            );
            let fast = coeffs.rotate(e);
            let slow = analysis(&rotate_pointwise(&coeffs, e).unwrap()).unwrap();
            Prop::assert_close(fast.max_abs_error(&slow), 0.0, 1e-9, "rotation")
        });
    }

    #[test]
    fn rotation_by_identity_is_identity() {
        let b = 5;
        let coeffs = SphCoeffs::random(b, 13);
        let rotated = coeffs.rotate(EulerZyz::new(0.0, 1e-15, 0.0));
        assert!(coeffs.max_abs_error(&rotated) < 1e-10);
    }

    #[test]
    fn rotation_preserves_norm_per_degree() {
        // Λ_R is unitary on each degree-l subspace.
        let b = 5;
        let coeffs = SphCoeffs::random(b, 17);
        let e = EulerZyz::new(1.0, 0.7, 2.0);
        let rot = coeffs.rotate(e);
        for l in 0..b {
            let li = l as i64;
            let n0: f64 = (-li..=li).map(|m| coeffs.at(l, m).norm_sqr()).sum();
            let n1: f64 = (-li..=li).map(|m| rot.at(l, m).norm_sqr()).sum();
            assert!((n0 - n1).abs() < 1e-10 * n0.max(1.0), "l={l}: {n0} vs {n1}");
        }
    }
}

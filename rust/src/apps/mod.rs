//! Application layer: the workloads the paper's introduction motivates.
//!
//! * [`sphere`] — a spherical-harmonic transform substrate on S²
//!   (analysis/synthesis/rotation of band-limited spherical functions),
//!   built on the same Wigner-d machinery and quadrature as the SO(3)
//!   transforms.
//! * [`matching`] — fast rotational matching (Kovacs–Wriggers style):
//!   find the rotation maximizing the correlation of two spherical
//!   functions by evaluating the correlation on the full SO(3) grid with
//!   one iFSOFT (the paper's flagship application family: EM fitting,
//!   molecular replacement, docking, shape registration).

pub mod matching;
pub mod sphere;

//! `so3ft` — the launcher binary. All logic lives in [`so3ft::cli`] so it
//! is unit- and integration-testable.

fn main() {
    let code = so3ft::cli::run(std::env::args().collect());
    std::process::exit(code);
}

//! Minimal property-based testing framework.
//!
//! `proptest` is not in the vendored registry, so this module provides the
//! subset the suite needs: seeded generators, a size ramp (small inputs
//! first, so failures are found near-minimal by construction), an optional
//! shrinking pass, and reproducible failure reports (`seed=… case=…`).
//!
//! ```no_run
//! use so3ft::testkit::{Prop, Gen};
//!
//! Prop::new("addition commutes")
//!     .cases(200)
//!     .run(|g| {
//!         let a = g.i64_in(-1000, 1000);
//!         let b = g.i64_in(-1000, 1000);
//!         Prop::assert_eq_msg(a + b, b + a, "a+b vs b+a")
//!     });
//! ```

use crate::prng::Xoshiro256;

/// Generator handle passed to property closures. Wraps the PRNG and the
/// current size hint (grows over the run so early cases are small).
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint in [0, 1]; multiplied into range widths by the helpers.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
            size,
        }
    }

    /// Uniform usize in [lo, hi] (inclusive), scaled by the size ramp:
    /// early cases draw from the low end of the range.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let hi_eff = lo + (((hi - lo) as f64) * self.size).round() as usize;
        self.rng.next_range(lo, hi_eff + 1)
    }

    /// Uniform i64 in [lo, hi] (inclusive), no size scaling.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform f64 in [-1, 1) (the paper's coefficient distribution).
    pub fn signed_unit(&mut self) -> f64 {
        self.rng.next_signed()
    }

    /// Boolean coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.rng.next_range(0, items.len())]
    }

    /// Fresh u64 (for nested seeding).
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// A named property with run configuration.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
}

impl Prop {
    /// Start a named property check.
    pub fn new(name: &'static str) -> Self {
        // Honor SO3FT_PROP_SEED for replaying failures.
        let seed = std::env::var("SO3FT_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_0BAD_CAFE_F00D);
        Self {
            name,
            cases: 64,
            seed,
        }
    }

    /// Number of random cases (default 64).
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Explicit base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run the property; panics with a reproducible report on failure.
    pub fn run<F>(self, mut prop: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        for case in 0..self.cases {
            // Size ramps from ~0.15 to 1.0 over the run.
            let size = 0.15 + 0.85 * (case as f64 / self.cases.max(1) as f64);
            let case_seed = self.seed.wrapping_add((case as u64).wrapping_mul(0x9E37_79B9));
            let mut g = Gen::new(case_seed, size);
            if let Err(msg) = prop(&mut g) {
                panic!(
                    "property '{}' failed at case {case}/{}: {msg}\n  replay: SO3FT_PROP_SEED={} (case seed {case_seed})",
                    self.name, self.cases, self.seed
                );
            }
        }
    }

    /// Helper: approximate float equality with context.
    pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
        if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
            Ok(())
        } else {
            Err(format!("{what}: {a} vs {b} (tol {tol})"))
        }
    }

    /// Helper: exact equality with context.
    pub fn assert_eq_msg<T: PartialEq + std::fmt::Debug>(
        a: T,
        b: T,
        what: &str,
    ) -> Result<(), String> {
        if a == b {
            Ok(())
        } else {
            Err(format!("{what}: {a:?} != {b:?}"))
        }
    }

    /// Helper: boolean condition with context.
    pub fn assert_true(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new("counter").cases(32).run(|g| {
            let _ = g.usize_in(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_report() {
        Prop::new("always fails").cases(4).run(|_| Err("nope".into()));
    }

    #[test]
    fn size_ramp_starts_small() {
        let mut first_sizes = Vec::new();
        Prop::new("ramp").cases(50).run(|g| {
            first_sizes.push(g.usize_in(0, 1000));
            Ok(())
        });
        // Early draws must be well below the cap.
        assert!(first_sizes[0] <= 300, "first draw {}", first_sizes[0]);
        assert!(first_sizes.iter().max().unwrap() > &500);
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut v = Vec::new();
            Prop::new("det").cases(8).seed(seed).run(|g| {
                v.push(g.u64());
                Ok(())
            });
            v
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn helpers() {
        assert!(Prop::assert_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(Prop::assert_close(1.0, 2.0, 1e-9, "x").is_err());
        assert!(Prop::assert_eq_msg(3, 3, "y").is_ok());
        assert!(Prop::assert_true(true, "z").is_ok());
    }

    #[test]
    fn gen_ranges_respected() {
        Prop::new("ranges").cases(100).run(|g| {
            let u = g.usize_in(3, 17);
            Prop::assert_true((3..=17).contains(&u), "usize_in range")?;
            let i = g.i64_in(-5, 5);
            Prop::assert_true((-5..=5).contains(&i), "i64_in range")?;
            let f = g.f64_in(-2.0, 2.0);
            Prop::assert_true((-2.0..2.0).contains(&f), "f64_in range")?;
            let c = *g.choose(&[1, 2, 3]);
            Prop::assert_true([1, 2, 3].contains(&c), "choose")
        });
    }
}

//! Persistent worker-pool runtime: parked workers, woken per region.
//!
//! The legacy [`parallel_for`](super::parallel_for) spawns and joins
//! fresh OS threads for *every* parallel region — several regions per
//! transform, per request. [`WorkerPool`] replaces that with a serving
//! substrate in the spirit of OpenMP's persistent thread team (and of
//! the tuned execution layers in OpenFFT / P3DFFT):
//!
//! * workers are spawned **once** ([`WorkerPool::new`]) and park on a
//!   condvar; a region submission bumps an epoch and wakes them;
//! * a pool is `Arc`-shareable: many [`So3Plan`]s and concurrent caller
//!   threads can execute on one pool (regions are serialized at region
//!   granularity, and every caller blocks until its own region
//!   completes — results are identical to exclusive use);
//! * worker ids (and therefore OS threads) are **stable for the pool's
//!   lifetime**, so per-worker thread-local scratch — the executor's
//!   DWT/FFT buffers — is allocated once and reused across regions and
//!   across transforms instead of once per region;
//! * all four [`Schedule`] policies and the [`RegionStats`] /
//!   [`WorkerStats`] accounting are identical to the scoped-spawn path
//!   (both run the same per-worker scheduling loop).
//!
//! A region body must not submit another region to the same pool
//! (nested submission would deadlock on the region lock); the SO(3)
//! executor never nests regions.
//!
//! [`So3Plan`]: crate::transform::So3Plan

use std::any::Any;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::{JoinHandle, ThreadId};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::pool::schedule::Schedule;
use crate::pool::stats::{RegionStats, WorkerStats};

/// Type-erased, lifetime-erased pointer to a region body.
///
/// Soundness contract: the submitting thread keeps the pointee alive —
/// it blocks in [`WorkerPool::run_with`] until every participant has
/// reported completion — so workers never dereference it after the
/// borrow ends. The pointee is `Sync`, so shared `&`-calls from many
/// workers are fine.
#[derive(Clone, Copy)]
struct JobBody(*const (dyn Fn(usize) + Sync));

// SAFETY: see the contract on the type — the pointer is only
// dereferenced while the submitting thread keeps the (Sync) pointee
// alive and borrowed.
unsafe impl Send for JobBody {}

/// One submitted region (copied out of the shared state by each worker).
#[derive(Clone, Copy)]
struct Job {
    body: JobBody,
    n: usize,
    schedule: Schedule,
    /// Workers 0..participants execute; higher-indexed workers skip the
    /// epoch (a region may be narrower than the pool).
    participants: usize,
}

struct PoolState {
    /// Region generation; bumped once per submitted region.
    epoch: u64,
    /// The region being executed at the current epoch.
    job: Option<Job>,
    /// Participants that have completed the current region.
    finished: usize,
    /// Per-worker stats for the current region (`len == participants`).
    stats: Vec<Option<WorkerStats>>,
    /// First panic payload caught from a worker body this region
    /// (resumed on the submitting thread, like scoped `join` would).
    panic: Option<Box<dyn Any + Send + 'static>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes parked workers on a new epoch (or shutdown).
    work_cv: Condvar,
    /// Wakes the submitting thread when the last participant finishes.
    done_cv: Condvar,
    /// Shared claim cursor for the dynamic/guided schedules. Only one
    /// region runs at a time (the region lock), so one pool-wide cursor
    /// is enough; it is reset before each region.
    cursor: AtomicUsize,
}

fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    crate::util::lock_unpoisoned(m)
}

fn wait<'a>(cv: &Condvar, guard: MutexGuard<'a, PoolState>) -> MutexGuard<'a, PoolState> {
    cv.wait(guard).unwrap_or_else(|p| p.into_inner())
}

fn worker_loop(shared: Arc<PoolShared>, index: usize) {
    let mut last_epoch = 0u64;
    loop {
        crate::sched_point!("pool.worker.park");
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    break;
                }
                st = wait(&shared.work_cv, st);
            }
            last_epoch = st.epoch;
            st.job
        };
        crate::sched_point!("pool.worker.wake");
        let Some(job) = job else { continue };
        if index >= job.participants {
            continue;
        }
        // SAFETY: the submitting thread keeps the body alive and
        // borrowed until this worker (a participant) reports completion
        // below — see [`JobBody`].
        let body = unsafe { &*job.body.0 };
        let Job { n, schedule, participants, .. } = job;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            super::execute_worker(index, participants, n, schedule, &shared.cursor, body)
        }));
        let mut st = lock(&shared.state);
        match result {
            Ok(stats) => st.stats[index] = Some(stats),
            Err(payload) => {
                if st.panic.is_none() {
                    st.panic = Some(payload);
                }
            }
        }
        st.finished += 1;
        if st.finished >= job.participants {
            shared.done_cv.notify_all();
        }
    }
}

fn shutdown_workers(shared: &PoolShared, handles: &mut Vec<JoinHandle<()>>) {
    {
        let mut st = lock(&shared.state);
        st.shutdown = true;
        shared.work_cv.notify_all();
    }
    for h in handles.drain(..) {
        let _ = h.join();
    }
}

/// A persistent pool of parked worker threads executing parallel
/// regions (see the [module docs](self)).
///
/// Build one with [`WorkerPool::new`], or take the lazily-initialized
/// process-global pool with [`WorkerPool::global`]. Dropping a pool
/// signals shutdown and joins its workers; the global pool lives for
/// the process.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Serializes region submissions: one region executes at a time, so
    /// concurrent callers interleave at region granularity.
    region: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `threads` parked workers (`threads >= 1`).
    pub fn new(threads: usize) -> Result<Self> {
        if threads == 0 {
            return Err(Error::InvalidThreads(0));
        }
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                finished: 0,
                stats: Vec::new(),
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            cursor: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(threads);
        for index in 0..threads {
            let worker_shared = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("so3ft-worker-{index}"))
                .spawn(move || worker_loop(worker_shared, index));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // Roll back the workers spawned so far before failing.
                    shutdown_workers(&shared, &mut handles);
                    return Err(Error::Io(e));
                }
            }
        }
        Ok(Self {
            shared,
            region: Mutex::new(()),
            handles,
        })
    }

    /// The lazily-initialized process-global pool, sized to the
    /// machine's available parallelism. Shared by every plan configured
    /// with [`PoolSpec::Global`]; lives for the process.
    pub fn global() -> Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| {
            let threads = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1);
            Arc::new(WorkerPool::new(threads).expect("thread count >= 1"))
        }))
    }

    /// Number of (persistent) worker threads.
    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// The worker thread ids — stable for the pool's lifetime (the
    /// stability contract the scratch pinning and the runtime tests
    /// rely on).
    pub fn thread_ids(&self) -> Vec<ThreadId> {
        self.handles.iter().map(|h| h.thread().id()).collect()
    }

    /// Run `body(index)` for every index in `0..n` over all pool
    /// workers under `schedule`. See [`Self::run_with`].
    pub fn run<F>(&self, n: usize, schedule: Schedule, body: F) -> RegionStats
    where
        F: Fn(usize) + Sync,
    {
        self.run_with(self.threads(), n, schedule, body)
    }

    /// Run a region `threads` wide (clamped to the pool size) under
    /// `schedule`, blocking until it completes. Single-width or trivial
    /// regions (`threads == 1` or `n <= 1`) execute inline on the
    /// calling thread with identical [`RegionStats`] accounting.
    ///
    /// Submission wakes *all* parked workers (one condvar); workers
    /// beyond the region width immediately re-park. On a pool much
    /// wider than the regions it serves, prefer sizing the pool to the
    /// widest expected region over one machine-sized pool.
    ///
    /// Safe to call from many threads concurrently (regions serialize);
    /// must **not** be called from inside a region body on the same
    /// pool. A panic in `body` is caught on the worker, the region is
    /// drained, and the payload is resumed on the calling thread.
    pub fn run_with<F>(&self, threads: usize, n: usize, schedule: Schedule, body: F) -> RegionStats
    where
        F: Fn(usize) + Sync,
    {
        assert!(threads >= 1, "thread count must be >= 1");
        let started = Instant::now();
        let participants = threads.min(self.threads());
        if participants == 1 || n <= 1 {
            return super::sequential_region_timed(started, n, &body);
        }

        let region = self.region.lock().unwrap_or_else(|p| p.into_inner());
        // ordering: Relaxed — cursor reset happens-before the workers
        // see the new job via the `state` mutex + condvar below; the
        // cursor itself never publishes data (see pool/mod.rs).
        self.shared.cursor.store(0, Ordering::Relaxed);
        let body_ref: &(dyn Fn(usize) + Sync) = &body;
        // SAFETY: lifetime erasure only. This function does not return
        // (or unwind) before every participant has reported completion,
        // so no worker can dereference the pointer after `body` dies.
        let body_erased = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                body_ref,
            )
        };

        crate::sched_point!("pool.epoch.bump");
        let mut st = lock(&self.shared.state);
        st.epoch = st.epoch.wrapping_add(1);
        st.job = Some(Job {
            body: JobBody(body_erased as *const (dyn Fn(usize) + Sync)),
            n,
            schedule,
            participants,
        });
        st.finished = 0;
        st.panic = None;
        st.stats.clear();
        st.stats.resize_with(participants, || None);
        self.shared.work_cv.notify_all();
        while st.finished < participants {
            st = wait(&self.shared.done_cv, st);
        }
        st.job = None;
        let panic = st.panic.take();
        let workers: Vec<WorkerStats> = if panic.is_none() {
            st.stats
                .drain(..)
                .map(|s| s.expect("every participant records stats"))
                .collect()
        } else {
            st.stats.clear();
            Vec::new()
        };
        drop(st);
        drop(region);
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }

        RegionStats {
            workers,
            wall: started.elapsed(),
            items: n,
        }
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads())
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        shutdown_workers(&self.shared, &mut self.handles);
    }
}

/// Where an executor's parallel regions run (`ExecutorConfig::pool`).
///
/// Config files accept `pool = "owned" | "global"` under `[transform]`;
/// the CLI accepts `--pool owned|global`; an explicit shared pool is
/// attached with `So3PlanBuilder::pool(...)`.
#[derive(Clone, Debug, Default)]
pub enum PoolSpec {
    /// The executor creates and owns a pool of exactly `threads`
    /// workers (the default — matches the legacy per-plan behavior,
    /// minus the per-region spawning).
    #[default]
    Owned,
    /// Execute on the process-global pool ([`WorkerPool::global`]).
    /// Region width is `min(threads, pool.threads())`.
    Global,
    /// Execute on a caller-supplied shared pool. Region width is
    /// `min(threads, pool.threads())`.
    Shared(Arc<WorkerPool>),
}

impl PoolSpec {
    /// Resolve to a concrete pool for an executor configured with
    /// `threads` workers; `None` when `threads <= 1` (the sequential
    /// path runs regions inline and needs no pool).
    pub(crate) fn resolve(&self, threads: usize) -> Result<Option<Arc<WorkerPool>>> {
        if threads <= 1 {
            return Ok(None);
        }
        Ok(Some(match self {
            PoolSpec::Owned => Arc::new(WorkerPool::new(threads)?),
            PoolSpec::Global => WorkerPool::global(),
            PoolSpec::Shared(pool) => Arc::clone(pool),
        }))
    }

    /// Parse a config/CLI spec: `owned` or `global` (a shared pool has
    /// no textual form — it is attached programmatically).
    pub fn parse(s: &str) -> Option<PoolSpec> {
        match s {
            "owned" => Some(PoolSpec::Owned),
            "global" => Some(PoolSpec::Global),
            _ => None,
        }
    }

    /// Canonical name (`owned` / `global` / `shared`).
    pub fn name(&self) -> &'static str {
        match self {
            PoolSpec::Owned => "owned",
            PoolSpec::Global => "global",
            PoolSpec::Shared(_) => "shared",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
    use std::sync::Mutex;

    const ALL_SCHEDULES: [Schedule; 5] = [
        Schedule::Dynamic { chunk: 1 },
        Schedule::Dynamic { chunk: 16 },
        Schedule::Static,
        Schedule::StaticInterleaved,
        Schedule::Guided { min_chunk: 1 },
    ];

    #[test]
    fn every_index_exactly_once_all_schedules_reusing_one_pool() {
        // The wide-pool / large-n combinations are shrunk under Miri
        // (interpreted threads are slow); the claim/park protocol under
        // test is identical at the smaller sizes.
        const THREADS: &[usize] = if cfg!(miri) { &[1, 2, 3] } else { &[1, 2, 3, 8] };
        const SIZES: &[usize] = if cfg!(miri) {
            &[0, 1, 7, 64]
        } else {
            &[0, 1, 7, 64, 500]
        };
        for &threads in THREADS {
            let pool = WorkerPool::new(threads).unwrap();
            // Many regions through the same pool: reuse is the point.
            for &n in SIZES {
                for &schedule in &ALL_SCHEDULES {
                    let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                    let stats = pool.run(n, schedule, |i| {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(
                            h.load(Ordering::Relaxed),
                            1,
                            "index {i} ({threads} workers, {schedule:?}, n={n})"
                        );
                    }
                    assert_eq!(
                        stats.workers.iter().map(|w| w.packages).sum::<usize>(),
                        n,
                        "package accounting ({threads} workers, {schedule:?}, n={n})"
                    );
                    assert_eq!(stats.items, n);
                }
            }
        }
    }

    #[test]
    fn results_match_scoped_spawn_path() {
        let n = 400;
        let want: u64 = (0..n as u64).map(|i| i * 3 + 1).sum();
        let pool = WorkerPool::new(4).unwrap();
        for &schedule in &ALL_SCHEDULES {
            let total = AtomicU64::new(0);
            pool.run(n, schedule, |i| {
                total.fetch_add(i as u64 * 3 + 1, Ordering::Relaxed);
            });
            assert_eq!(total.into_inner(), want, "{schedule:?}");
        }
    }

    #[test]
    fn stats_shape_matches_region_width() {
        let pool = WorkerPool::new(4).unwrap();
        let stats = pool.run(256, Schedule::Dynamic { chunk: 4 }, |_| {
            std::hint::black_box(());
        });
        assert_eq!(stats.items, 256);
        assert_eq!(stats.workers.len(), 4);
        assert!(stats.wall.as_nanos() > 0);
        // Narrower region than the pool: stats report the region width.
        let narrow = pool.run_with(2, 256, Schedule::Static, |_| {});
        assert_eq!(narrow.workers.len(), 2);
        // Wider request clamps to the pool size.
        let clamped = pool.run_with(64, 256, Schedule::Static, |_| {});
        assert_eq!(clamped.workers.len(), 4);
    }

    #[test]
    fn single_worker_and_trivial_regions_take_sequential_fast_path() {
        let pool = WorkerPool::new(1).unwrap();
        for &schedule in &ALL_SCHEDULES {
            for &n in &[0usize, 1, 33] {
                let stats = pool.run(n, schedule, |_| {});
                assert_eq!(stats.workers.len(), 1, "{schedule:?} n={n}");
                assert_eq!(stats.workers[0].packages, n, "{schedule:?} n={n}");
                assert_eq!(stats.items, n);
            }
        }
        // n <= 1 on a wide pool also runs inline.
        let pool = WorkerPool::new(4).unwrap();
        let stats = pool.run(1, Schedule::Static, |_| {});
        assert_eq!(stats.workers.len(), 1);
        assert_eq!(stats.workers[0].packages, 1);
    }

    #[test]
    fn worker_threads_are_stable_across_regions() {
        let pool = WorkerPool::new(2).unwrap();
        let ids: HashSet<_> = pool.thread_ids().into_iter().collect();
        assert_eq!(ids.len(), 2);
        let observe = || {
            let seen = Mutex::new(HashSet::new());
            // Static over n == workers: every worker executes exactly
            // one package, deterministically.
            pool.run(2, Schedule::Static, |_| {
                seen.lock().unwrap().insert(std::thread::current().id());
            });
            seen.into_inner().unwrap()
        };
        let first = observe();
        let second = observe();
        assert_eq!(first, ids, "regions must run on the persistent workers");
        assert_eq!(first, second, "worker threads must not be respawned");
        assert!(
            !first.contains(&std::thread::current().id()),
            "the caller does not execute packages on the pooled path"
        );
    }

    #[test]
    fn concurrent_callers_interleave_safely() {
        let pool = Arc::new(WorkerPool::new(3).unwrap());
        // Fewer rounds under Miri; the caller-interleaving coverage
        // comes from the four concurrent submitters, not round count.
        const ROUNDS: usize = if cfg!(miri) { 3 } else { 20 };
        std::thread::scope(|scope| {
            for caller in 0..4usize {
                let pool = Arc::clone(&pool);
                scope.spawn(move || {
                    for round in 0..ROUNDS {
                        let n = 16 + caller + round;
                        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                        pool.run(n, Schedule::Dynamic { chunk: 1 }, |i| {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        });
                        for (i, h) in hits.iter().enumerate() {
                            assert_eq!(
                                h.load(Ordering::Relaxed),
                                1,
                                "caller {caller} round {round} index {i}"
                            );
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, Schedule::Static, |i| {
                if i == 3 {
                    panic!("injected body panic");
                }
            });
        }));
        let payload = caught.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("injected body panic"), "payload: {msg:?}");
        // The pool keeps serving after a body panic.
        let total = AtomicU64::new(0);
        pool.run(10, Schedule::Dynamic { chunk: 1 }, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.into_inner(), 45);
    }

    #[test]
    fn zero_threads_is_a_typed_error() {
        assert!(matches!(WorkerPool::new(0), Err(Error::InvalidThreads(0))));
    }

    #[test]
    fn global_pool_is_one_instance() {
        let a = WorkerPool::global();
        let b = WorkerPool::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }

    #[test]
    fn pool_spec_parse_and_resolve() {
        assert!(matches!(PoolSpec::parse("owned"), Some(PoolSpec::Owned)));
        assert!(matches!(PoolSpec::parse("global"), Some(PoolSpec::Global)));
        assert!(PoolSpec::parse("bogus").is_none());
        assert_eq!(PoolSpec::Owned.name(), "owned");
        assert_eq!(PoolSpec::Global.name(), "global");
        // threads == 1 resolves to no pool at all (sequential path).
        assert!(PoolSpec::Owned.resolve(1).unwrap().is_none());
        let owned = PoolSpec::Owned.resolve(3).unwrap().unwrap();
        assert_eq!(owned.threads(), 3);
        let shared = Arc::new(WorkerPool::new(2).unwrap());
        let spec = PoolSpec::Shared(Arc::clone(&shared));
        assert_eq!(spec.name(), "shared");
        let resolved = spec.resolve(8).unwrap().unwrap();
        assert!(Arc::ptr_eq(&resolved, &shared));
    }
}

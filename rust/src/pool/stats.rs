//! Execution statistics for parallel regions — the measurement substrate
//! for the efficiency figures and the simulator calibration.

use std::time::Duration;

/// Per-worker counters for one parallel region.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    /// Loop iterations this worker executed.
    pub packages: usize,
    /// Time from worker start to completion of its last package.
    pub busy: Duration,
}

/// Aggregated statistics for one parallel region.
#[derive(Debug, Clone)]
pub struct RegionStats {
    /// Per-worker execution statistics, one entry per participant.
    pub workers: Vec<WorkerStats>,
    /// Wall-clock time of the whole region (including spawn/join).
    pub wall: Duration,
    /// Total iterations.
    pub items: usize,
}

impl RegionStats {
    /// Load imbalance: max worker busy time / mean busy time (1.0 = perfectly
    /// balanced). The quantity the paper's §5 "workload imbalance" refers to.
    pub fn imbalance(&self) -> f64 {
        if self.workers.is_empty() {
            return 1.0;
        }
        let times: Vec<f64> = self.workers.iter().map(|w| w.busy.as_secs_f64()).collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Fraction of wall time spent outside worker bodies (spawn/join and
    /// scheduling overhead).
    pub fn overhead_fraction(&self) -> f64 {
        let wall = self.wall.as_secs_f64();
        if wall == 0.0 || self.workers.is_empty() {
            return 0.0;
        }
        let max_busy = self
            .workers
            .iter()
            .map(|w| w.busy.as_secs_f64())
            .fold(0.0, f64::max);
        ((wall - max_busy) / wall).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(ms: u64, packages: usize) -> WorkerStats {
        WorkerStats {
            packages,
            busy: Duration::from_millis(ms),
        }
    }

    #[test]
    fn imbalance_metric() {
        let balanced = RegionStats {
            workers: vec![w(10, 5), w(10, 5)],
            wall: Duration::from_millis(11),
            items: 10,
        };
        assert!((balanced.imbalance() - 1.0).abs() < 1e-9);
        let skewed = RegionStats {
            workers: vec![w(30, 9), w(10, 1)],
            wall: Duration::from_millis(31),
            items: 10,
        };
        assert!((skewed.imbalance() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn overhead_fraction_bounds() {
        let r = RegionStats {
            workers: vec![w(8, 4)],
            wall: Duration::from_millis(10),
            items: 4,
        };
        let f = r.overhead_fraction();
        assert!(f > 0.15 && f < 0.25, "{f}");
        let empty = RegionStats {
            workers: vec![],
            wall: Duration::ZERO,
            items: 0,
        };
        assert_eq!(empty.overhead_fraction(), 0.0);
    }
}

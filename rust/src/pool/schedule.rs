//! Loop scheduling policies (the OpenMP `schedule(...)` clause).

/// How loop iterations are assigned to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Schedule {
    /// Self-scheduling from a shared atomic cursor, `chunk` iterations at
    /// a time — OpenMP `schedule(dynamic, chunk)`. The paper's choice
    /// (`schedule(dynamic)` = chunk 1).
    Dynamic {
        /// Iterations claimed per cursor fetch.
        chunk: usize,
    },
    /// One contiguous block per worker — OpenMP default `schedule(static)`.
    Static,
    /// Round-robin single iterations — OpenMP `schedule(static, 1)`.
    StaticInterleaved,
    /// Exponentially decreasing chunks with a floor — OpenMP
    /// `schedule(guided, min_chunk)`.
    Guided {
        /// Smallest chunk the decreasing schedule hands out.
        min_chunk: usize,
    },
}

impl Schedule {
    /// The paper's configuration.
    pub const PAPER: Schedule = Schedule::Dynamic { chunk: 1 };

    /// Parse from a CLI/config string: `dynamic[:chunk]`, `static`,
    /// `interleaved`, `guided[:min]`.
    pub fn parse(s: &str) -> Option<Schedule> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "dynamic" => {
                let chunk = match arg {
                    Some(a) => a.parse().ok()?,
                    None => 1,
                };
                Some(Schedule::Dynamic { chunk })
            }
            "static" => Some(Schedule::Static),
            "interleaved" => Some(Schedule::StaticInterleaved),
            "guided" => {
                let min_chunk = match arg {
                    Some(a) => a.parse().ok()?,
                    None => 1,
                };
                Some(Schedule::Guided { min_chunk })
            }
            _ => None,
        }
    }

    /// Canonical name (round-trips through `parse`).
    pub fn name(&self) -> String {
        match self {
            Schedule::Dynamic { chunk } => format!("dynamic:{chunk}"),
            Schedule::Static => "static".to_string(),
            Schedule::StaticInterleaved => "interleaved".to_string(),
            Schedule::Guided { min_chunk } => format!("guided:{min_chunk}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_forms() {
        assert_eq!(Schedule::parse("dynamic"), Some(Schedule::Dynamic { chunk: 1 }));
        assert_eq!(
            Schedule::parse("dynamic:8"),
            Some(Schedule::Dynamic { chunk: 8 })
        );
        assert_eq!(Schedule::parse("static"), Some(Schedule::Static));
        assert_eq!(
            Schedule::parse("interleaved"),
            Some(Schedule::StaticInterleaved)
        );
        assert_eq!(
            Schedule::parse("guided:4"),
            Some(Schedule::Guided { min_chunk: 4 })
        );
        assert_eq!(Schedule::parse("bogus"), None);
        assert_eq!(Schedule::parse("dynamic:x"), None);
    }

    #[test]
    fn name_roundtrips() {
        for s in [
            Schedule::Dynamic { chunk: 1 },
            Schedule::Dynamic { chunk: 32 },
            Schedule::Static,
            Schedule::StaticInterleaved,
            Schedule::Guided { min_chunk: 2 },
        ] {
            assert_eq!(Schedule::parse(&s.name()), Some(s));
        }
    }
}

//! Fork-join worker pool with OpenMP-style loop scheduling.
//!
//! The paper parallelizes with OpenMP `#pragma omp parallel for
//! schedule(dynamic)`; this module is the equivalent substrate:
//! [`parallel_for`] runs an index range over scoped threads under a
//! [`Schedule`] policy. `Dynamic` reproduces OpenMP's dynamic
//! self-scheduling (a shared atomic cursor), `Static` the default static
//! blocking, `Guided` the decreasing-chunk variant — all three are
//! benchmarked against each other in `benches/ablation_schedule.rs`.
//!
//! Per-worker execution statistics (packages executed, busy time) feed
//! the multicore simulator's calibration.

pub mod schedule;
pub mod stats;

pub use schedule::Schedule;
pub use stats::{RegionStats, WorkerStats};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Run `body(index)` for every index in `0..n` on `threads` workers under
/// the given scheduling policy. Returns per-region execution statistics.
///
/// `body` must be safe to call concurrently for distinct indices (the
/// SO(3) executor guarantees output disjointness per index — see
/// `coordinator::plan`).
pub fn parallel_for<F>(threads: usize, n: usize, schedule: Schedule, body: F) -> RegionStats
where
    F: Fn(usize) + Sync,
{
    assert!(threads >= 1, "thread count must be >= 1");
    let started = Instant::now();
    if threads == 1 || n <= 1 {
        // Fast path: no spawn overhead — this is also the "sequential
        // algorithm" the paper's speedups are measured against.
        let t0 = Instant::now();
        for i in 0..n {
            body(i);
        }
        let stats = WorkerStats {
            packages: n,
            busy: t0.elapsed(),
        };
        return RegionStats {
            workers: vec![stats],
            wall: started.elapsed(),
            items: n,
        };
    }

    let cursor = AtomicUsize::new(0);
    let body = &body;
    let mut workers: Vec<WorkerStats> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let cursor = &cursor;
                scope.spawn(move || {
                    let t0 = Instant::now();
                    let mut packages = 0usize;
                    match schedule {
                        Schedule::Dynamic { chunk } => {
                            let chunk = chunk.max(1);
                            loop {
                                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                                if start >= n {
                                    break;
                                }
                                let end = (start + chunk).min(n);
                                for i in start..end {
                                    body(i);
                                }
                                packages += end - start;
                            }
                        }
                        Schedule::Static => {
                            // Contiguous block per worker (OpenMP default).
                            let per = n.div_ceil(threads);
                            let start = t * per;
                            let end = ((t + 1) * per).min(n);
                            for i in start..end {
                                body(i);
                            }
                            packages += end.saturating_sub(start);
                        }
                        Schedule::StaticInterleaved => {
                            // Round-robin (OpenMP schedule(static,1)).
                            let mut i = t;
                            while i < n {
                                body(i);
                                packages += 1;
                                i += threads;
                            }
                        }
                        Schedule::Guided { min_chunk } => {
                            let min_chunk = min_chunk.max(1);
                            loop {
                                // Claim max(remaining/(2T), min) items.
                                let start = {
                                    let mut cur = cursor.load(Ordering::Relaxed);
                                    loop {
                                        if cur >= n {
                                            break usize::MAX;
                                        }
                                        let remaining = n - cur;
                                        let take =
                                            (remaining / (2 * threads)).max(min_chunk);
                                        match cursor.compare_exchange_weak(
                                            cur,
                                            cur + take,
                                            Ordering::Relaxed,
                                            Ordering::Relaxed,
                                        ) {
                                            Ok(_) => break cur,
                                            Err(now) => cur = now,
                                        }
                                    }
                                };
                                if start == usize::MAX {
                                    break;
                                }
                                let remaining = n - start;
                                let take = (remaining / (2 * threads)).max(min_chunk);
                                let end = (start + take).min(n);
                                for i in start..end {
                                    body(i);
                                }
                                packages += end - start;
                            }
                        }
                    }
                    WorkerStats {
                        packages,
                        busy: t0.elapsed(),
                    }
                })
            })
            .collect();
        for h in handles {
            workers.push(h.join().expect("worker panicked"));
        }
    });

    RegionStats {
        workers,
        wall: started.elapsed(),
        items: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    fn run_and_check(threads: usize, n: usize, schedule: Schedule) {
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let stats = parallel_for(threads, n, schedule, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(
                h.load(Ordering::Relaxed),
                1,
                "index {i} executed wrong number of times ({threads} threads, {schedule:?})"
            );
        }
        assert_eq!(
            stats.workers.iter().map(|w| w.packages).sum::<usize>(),
            n,
            "package accounting"
        );
    }

    #[test]
    fn every_index_exactly_once_all_schedules() {
        for threads in [1usize, 2, 3, 8] {
            for n in [0usize, 1, 7, 64, 1000] {
                run_and_check(threads, n, Schedule::Dynamic { chunk: 1 });
                run_and_check(threads, n, Schedule::Dynamic { chunk: 16 });
                run_and_check(threads, n, Schedule::Static);
                run_and_check(threads, n, Schedule::StaticInterleaved);
                run_and_check(threads, n, Schedule::Guided { min_chunk: 1 });
            }
        }
    }

    #[test]
    fn results_independent_of_schedule() {
        // Sum of f(i) must not depend on scheduling.
        let n = 500;
        let collect = |threads, schedule| {
            let total = AtomicU64::new(0);
            parallel_for(threads, n, schedule, |i| {
                total.fetch_add((i * i) as u64, Ordering::Relaxed);
            });
            total.into_inner()
        };
        let want: u64 = (0..n as u64).map(|i| i * i).sum();
        for threads in [1, 2, 5] {
            for schedule in [
                Schedule::Dynamic { chunk: 3 },
                Schedule::Static,
                Schedule::StaticInterleaved,
                Schedule::Guided { min_chunk: 2 },
            ] {
                assert_eq!(collect(threads, schedule), want);
            }
        }
    }

    #[test]
    fn single_thread_takes_fast_path() {
        let stats = parallel_for(1, 100, Schedule::Dynamic { chunk: 1 }, |_| {});
        assert_eq!(stats.workers.len(), 1);
        assert_eq!(stats.workers[0].packages, 100);
    }

    #[test]
    fn stats_fields_populated() {
        let stats = parallel_for(4, 256, Schedule::Dynamic { chunk: 4 }, |_| {
            std::hint::black_box(());
        });
        assert_eq!(stats.items, 256);
        assert_eq!(stats.workers.len(), 4);
        assert!(stats.wall.as_nanos() > 0);
    }

    #[test]
    #[should_panic(expected = "thread count")]
    fn zero_threads_rejected() {
        parallel_for(0, 10, Schedule::Static, |_| {});
    }
}

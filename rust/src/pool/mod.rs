//! Worker-pool substrate with OpenMP-style loop scheduling.
//!
//! The paper parallelizes with OpenMP `#pragma omp parallel for
//! schedule(dynamic)`; this module is the equivalent substrate. Two
//! execution engines share the same scheduling policies and statistics:
//!
//! * [`WorkerPool`] (`runtime`) — the serving engine: workers are
//!   spawned **once**, parked on a condvar, and woken per region by an
//!   epoch bump. A pool is `Arc`-shareable across plans and concurrent
//!   callers; per-worker thread-local scratch (DWT/FFT) stays pinned to
//!   the same OS threads across regions *and* across transforms.
//! * [`parallel_for`] — the legacy fork-join path that spawns scoped OS
//!   threads for every region. It is kept as the measurable baseline for
//!   the persistent runtime (see `benches/micro_batch.rs`); the executor
//!   no longer uses it.
//!
//! Scheduling ([`Schedule`]): `Dynamic` reproduces OpenMP's dynamic
//! self-scheduling (a shared atomic cursor), `Static` the default static
//! blocking, `StaticInterleaved` round-robin, `Guided` the
//! decreasing-chunk variant — benchmarked against each other in
//! `benches/ablation_schedule.rs`.
//!
//! Per-worker execution statistics ([`RegionStats`], [`WorkerStats`] —
//! packages executed, busy time) feed the multicore simulator's
//! calibration; both engines and the sequential fast path record the
//! same stats shape.

pub mod runtime;
pub mod schedule;
pub mod stats;

pub use runtime::{PoolSpec, WorkerPool};
pub use schedule::Schedule;
pub use stats::{RegionStats, WorkerStats};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Run one worker's share of a region under `schedule`. `t` is the
/// worker's index among the `threads` participants; `cursor` is the
/// shared claim cursor (dynamic/guided), reset to 0 before the region.
///
/// Shared by the scoped-spawn path ([`parallel_for`]) and the persistent
/// runtime ([`WorkerPool`]) so the two engines are package-for-package
/// identical under every policy.
fn execute_worker<F>(
    t: usize,
    threads: usize,
    n: usize,
    schedule: Schedule,
    cursor: &AtomicUsize,
    body: &F,
) -> WorkerStats
where
    F: Fn(usize) + Sync + ?Sized,
{
    // Fault site: an `Err` action escalates to a panic here, which the
    // per-worker `catch_unwind` in `runtime.rs` (and the scoped-spawn
    // join in `parallel_for`) converts into a resumed panic on the
    // caller — the shape a real worker-body bug would take.
    if let Some(action) = crate::faults::fire(crate::faults::WORKER_BODY) {
        action.apply_infallible(crate::faults::WORKER_BODY);
    }
    let t0 = Instant::now();
    let mut packages = 0usize;
    match schedule {
        Schedule::Dynamic { chunk } => {
            let chunk = chunk.max(1);
            loop {
                // ordering: Relaxed — the cursor only partitions the
                // index space; workers never read data through it. The
                // region data is published by the epoch/mutex handoff in
                // `runtime.rs` (or the thread spawn in `parallel_for`).
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    body(i);
                }
                packages += end - start;
            }
        }
        Schedule::Static => {
            // Contiguous block per worker (OpenMP default).
            let per = n.div_ceil(threads);
            let start = t * per;
            let end = ((t + 1) * per).min(n);
            for i in start..end {
                body(i);
            }
            packages += end.saturating_sub(start);
        }
        Schedule::StaticInterleaved => {
            // Round-robin (OpenMP schedule(static,1)).
            let mut i = t;
            while i < n {
                body(i);
                packages += 1;
                i += threads;
            }
        }
        Schedule::Guided { min_chunk } => {
            let min_chunk = min_chunk.max(1);
            loop {
                // Claim max(remaining/(2T), min) items.
                let start = {
                    // ordering: Relaxed — claim-cursor CAS loop, same
                    // protocol as the Dynamic arm above: the cursor
                    // partitions indices, it does not publish data.
                    let mut cur = cursor.load(Ordering::Relaxed);
                    loop {
                        if cur >= n {
                            break usize::MAX;
                        }
                        let remaining = n - cur;
                        let take = (remaining / (2 * threads)).max(min_chunk);
                        // ordering: Relaxed/Relaxed — index-claim CAS,
                        // no data published through the cursor.
                        match cursor.compare_exchange_weak(
                            cur,
                            cur + take,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        ) {
                            Ok(_) => break cur,
                            Err(now) => cur = now,
                        }
                    }
                };
                if start == usize::MAX {
                    break;
                }
                let remaining = n - start;
                let take = (remaining / (2 * threads)).max(min_chunk);
                let end = (start + take).min(n);
                for i in start..end {
                    body(i);
                }
                packages += end - start;
            }
        }
    }
    WorkerStats {
        packages,
        busy: t0.elapsed(),
    }
}

/// Sequential region execution with `started` as the region start (so
/// callers that decide on the fast path late still report a full wall).
fn sequential_region_timed<F>(started: Instant, n: usize, mut body: F) -> RegionStats
where
    F: FnMut(usize),
{
    // The single-worker accounting must match the policy accounting of
    // the parallel paths exactly: one worker entry, `packages == n`,
    // `items == n` — under *every* [`Schedule`] (one worker executes all
    // iterations regardless of policy), so the simulator calibration
    // can consume sequential and parallel regions uniformly.
    let t0 = Instant::now();
    for i in 0..n {
        body(i);
    }
    let stats = WorkerStats {
        packages: n,
        busy: t0.elapsed(),
    };
    RegionStats {
        workers: vec![stats],
        wall: started.elapsed(),
        items: n,
    }
}

/// Run a region inline on the calling thread — the "sequential
/// algorithm" the paper's speedups are measured against.
///
/// Records the same [`RegionStats`] shape as a one-worker parallel
/// region under every [`Schedule`]: exactly one [`WorkerStats`] entry
/// with `packages == n`. Both [`parallel_for`] and
/// [`WorkerPool::run_with`] delegate here when the region is effectively
/// single-threaded (`threads == 1` or `n <= 1`).
pub fn sequential_region<F: FnMut(usize)>(n: usize, body: F) -> RegionStats {
    sequential_region_timed(Instant::now(), n, body)
}

/// Run `body(index)` for every index in `0..n` on `threads` freshly
/// spawned scoped workers under the given scheduling policy. Returns
/// per-region execution statistics.
///
/// This is the **legacy fork-join path**: it spawns and joins `threads`
/// OS threads per call. Production code should execute on a persistent
/// [`WorkerPool`] instead (the executor does); this entry point is kept
/// as the spawn-overhead baseline benchmarked in
/// `benches/micro_batch.rs`.
///
/// `body` must be safe to call concurrently for distinct indices (the
/// SO(3) executor guarantees output disjointness per index — see
/// `coordinator::plan`).
pub fn parallel_for<F>(threads: usize, n: usize, schedule: Schedule, body: F) -> RegionStats
where
    F: Fn(usize) + Sync,
{
    assert!(threads >= 1, "thread count must be >= 1");
    let started = Instant::now();
    if threads == 1 || n <= 1 {
        return sequential_region_timed(started, n, body);
    }

    let cursor = AtomicUsize::new(0);
    let body = &body;
    let mut workers: Vec<WorkerStats> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let cursor = &cursor;
                scope.spawn(move || execute_worker(t, threads, n, schedule, cursor, body))
            })
            .collect();
        for h in handles {
            workers.push(h.join().expect("worker panicked"));
        }
    });

    RegionStats {
        workers,
        wall: started.elapsed(),
        items: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    fn run_and_check(threads: usize, n: usize, schedule: Schedule) {
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let stats = parallel_for(threads, n, schedule, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(
                h.load(Ordering::Relaxed),
                1,
                "index {i} executed wrong number of times ({threads} threads, {schedule:?})"
            );
        }
        assert_eq!(
            stats.workers.iter().map(|w| w.packages).sum::<usize>(),
            n,
            "package accounting"
        );
    }

    #[test]
    fn every_index_exactly_once_all_schedules() {
        for threads in [1usize, 2, 3, 8] {
            for n in [0usize, 1, 7, 64, 1000] {
                run_and_check(threads, n, Schedule::Dynamic { chunk: 1 });
                run_and_check(threads, n, Schedule::Dynamic { chunk: 16 });
                run_and_check(threads, n, Schedule::Static);
                run_and_check(threads, n, Schedule::StaticInterleaved);
                run_and_check(threads, n, Schedule::Guided { min_chunk: 1 });
            }
        }
    }

    #[test]
    fn results_independent_of_schedule() {
        // Sum of f(i) must not depend on scheduling.
        let n = 500;
        let collect = |threads, schedule| {
            let total = AtomicU64::new(0);
            parallel_for(threads, n, schedule, |i| {
                total.fetch_add((i * i) as u64, Ordering::Relaxed);
            });
            total.into_inner()
        };
        let want: u64 = (0..n as u64).map(|i| i * i).sum();
        for threads in [1, 2, 5] {
            for schedule in [
                Schedule::Dynamic { chunk: 3 },
                Schedule::Static,
                Schedule::StaticInterleaved,
                Schedule::Guided { min_chunk: 2 },
            ] {
                assert_eq!(collect(threads, schedule), want);
            }
        }
    }

    #[test]
    fn single_thread_takes_fast_path() {
        let stats = parallel_for(1, 100, Schedule::Dynamic { chunk: 1 }, |_| {});
        assert_eq!(stats.workers.len(), 1);
        assert_eq!(stats.workers[0].packages, 100);
    }

    #[test]
    fn single_thread_stats_shape_identical_under_every_schedule() {
        // Regression (ISSUE 3): the sequential fast path must record the
        // same RegionStats shape the simulator calibration expects — one
        // worker, packages == n, items == n — under *every* policy, for
        // both entry points that take the fast path.
        for schedule in [
            Schedule::Dynamic { chunk: 1 },
            Schedule::Dynamic { chunk: 16 },
            Schedule::Static,
            Schedule::StaticInterleaved,
            Schedule::Guided { min_chunk: 2 },
        ] {
            for n in [0usize, 1, 5, 100] {
                let from_for = parallel_for(1, n, schedule, |_| {});
                let from_seq = sequential_region(n, |_| {});
                for (label, s) in [("parallel_for", &from_for), ("sequential_region", &from_seq)]
                {
                    assert_eq!(
                        s.workers.len(),
                        1,
                        "{label}: one worker entry ({schedule:?}, n={n})"
                    );
                    assert_eq!(
                        s.workers[0].packages, n,
                        "{label}: packages == n ({schedule:?}, n={n})"
                    );
                    assert_eq!(s.items, n, "{label}: items ({schedule:?}, n={n})");
                }
            }
        }
    }

    #[test]
    fn stats_fields_populated() {
        let stats = parallel_for(4, 256, Schedule::Dynamic { chunk: 4 }, |_| {
            std::hint::black_box(());
        });
        assert_eq!(stats.items, 256);
        assert_eq!(stats.workers.len(), 4);
        assert!(stats.wall.as_nanos() > 0);
    }

    #[test]
    #[should_panic(expected = "thread count")]
    fn zero_threads_rejected() {
        parallel_for(0, 10, Schedule::Static, |_| {});
    }
}

//! Cache-oblivious transpose kernels for the [TRN] stage.
//!
//! The executor's transpose stage moves the n³ FFT slab into the S-matrix
//! layout (forward) and back (inverse). Before this module existed those
//! moves were hand-tiled double loops with fixed tile sizes; large-B
//! frameworks (P3DFFT, OpenFFT) show that the transpose organization is
//! what decides whether b=512 is reachable at all, so the kernels here are
//! written once, recursively, and reused by the executor:
//!
//! * [`tile_recurse`] — the cache-oblivious driver: recursively split the
//!   longer dimension of an index rectangle until both sides fit a blocked
//!   base case, then hand the block to a caller-supplied kernel. Every
//!   other routine in the module (and the executor's scatter) is built on
//!   it, so the traversal order — and therefore the floating-point result —
//!   is identical across the copy-based, in-place, and parallel paths.
//! * [`transpose_into`] / [`gather_permuted`] — out-of-place copies with
//!   contiguous destination writes in the base case (SIMD-friendly: the
//!   inner loop is a unit-stride store stream).
//! * [`transpose_square_in_place`] / [`transpose_in_place`] — in-place
//!   transposes. The square case is a recursive diagonal-block split that
//!   swaps mirror blocks and never allocates. The rectangular case follows
//!   permutation cycles (index j receives old index (j·cols) mod (rows·cols−1))
//!   with a visited bitmap — O(rows·cols) bits of scratch instead of a full
//!   element copy, the classic in-place trade.
//! * [`transpose_into_parallel`] — column-band decomposition over the
//!   existing [`WorkerPool`], engaged above [`PARALLEL_THRESHOLD`]. Each
//!   band's destination rows are disjoint and contiguous, so bands write
//!   through exclusive `&mut` sub-slices. Never call this from inside a
//!   pool region (regions must not nest — see `pool`).
//!
//! All kernels are generic over `T: Copy` — the executor moves
//! `Complex64`, which is `Copy` but deliberately not `util::Pod`.

use crate::pool::{Schedule, WorkerPool};
use crate::util::SyncUnsafeSlice;

/// Base-case block edge for the recursive splits. 32×32 `Complex64`
/// elements is 16 KiB — half of a typical 32 KiB L1D, leaving room for the
/// source stream.
pub const BLOCK: usize = 32;

/// Minimum element count (`rows*cols`) before [`transpose_into_parallel`]
/// engages the pool; below this the fork/join overhead exceeds the copy.
pub const PARALLEL_THRESHOLD: usize = 1 << 16;

/// Cache-oblivious tiling driver over the index rectangle
/// `[r0, r1) × [c0, c1)`: recursively halve the longer dimension until both
/// extents are at most `base`, then invoke `f(r0, r1, c0, c1)` on the leaf
/// block. The recursion depth is O(log(max extent)) and the leaf visit
/// order is deterministic, which the parity tests rely on.
pub fn tile_recurse<F: FnMut(usize, usize, usize, usize)>(
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
    base: usize,
    f: &mut F,
) {
    let rn = r1 - r0;
    let cn = c1 - c0;
    if rn == 0 || cn == 0 {
        return;
    }
    if rn <= base && cn <= base {
        f(r0, r1, c0, c1);
        return;
    }
    if rn >= cn {
        let rm = r0 + rn / 2;
        tile_recurse(r0, rm, c0, c1, base, f);
        tile_recurse(rm, r1, c0, c1, base, f);
    } else {
        let cm = c0 + cn / 2;
        tile_recurse(r0, r1, c0, cm, base, f);
        tile_recurse(r0, r1, cm, c1, base, f);
    }
}

/// Out-of-place transpose: `dst` (row-major `cols × rows`) receives the
/// transpose of `src` (row-major `rows × cols`).
pub fn transpose_into<T: Copy>(dst: &mut [T], src: &[T], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols, "transpose_into: src length mismatch");
    assert_eq!(dst.len(), rows * cols, "transpose_into: dst length mismatch");
    tile_recurse(0, rows, 0, cols, BLOCK, &mut |r0, r1, c0, c1| {
        for c in c0..c1 {
            let drow = c * rows;
            for r in r0..r1 {
                dst[drow + r] = src[r * cols + c];
            }
        }
    });
}

/// Permuted gather used by the forward [TRN] stage: for each destination
/// row `r` (of `rows`) and column `c` (of `cols`),
/// `dst[r*dst_stride + c] = src[c*src_stride + perm[r]]`.
/// Destination writes are unit-stride within the inner loop.
pub fn gather_permuted<T: Copy>(
    dst: &mut [T],
    dst_stride: usize,
    src: &[T],
    src_stride: usize,
    perm: &[usize],
    rows: usize,
    cols: usize,
) {
    assert!(rows <= perm.len(), "gather_permuted: perm too short");
    assert!(
        rows == 0 || (rows - 1) * dst_stride + cols <= dst.len(),
        "gather_permuted: dst too short"
    );
    tile_recurse(0, rows, 0, cols, BLOCK, &mut |r0, r1, c0, c1| {
        for r in r0..r1 {
            let p = perm[r];
            let drow = r * dst_stride;
            for c in c0..c1 {
                dst[drow + c] = src[c * src_stride + p];
            }
        }
    });
}

/// Recursive in-place transpose of the `s × s` sub-matrix whose top-left
/// element lives at flat offset `off` in a row-major matrix of row stride
/// `stride`. Splits on the diagonal: transpose the two diagonal halves,
/// then swap the off-diagonal mirror blocks.
fn ip_diag<T: Copy>(a: &mut [T], stride: usize, off: usize, s: usize) {
    if s <= BLOCK {
        for i in 0..s {
            for j in 0..i {
                a.swap(off + i * stride + j, off + j * stride + i);
            }
        }
        return;
    }
    let h = s / 2;
    ip_diag(a, stride, off, h);
    ip_diag(a, stride, off + h * stride + h, s - h);
    ip_swap(a, stride, off + h * stride, off + h, s - h, h);
}

/// Swap block A (`ra × ca`, top-left at `off_a`) with the transpose of
/// block B (`ca × ra`, top-left at `off_b`): `A[i][j] <-> B[j][i]`.
fn ip_swap<T: Copy>(a: &mut [T], stride: usize, off_a: usize, off_b: usize, ra: usize, ca: usize) {
    if ra <= BLOCK && ca <= BLOCK {
        for i in 0..ra {
            for j in 0..ca {
                a.swap(off_a + i * stride + j, off_b + j * stride + i);
            }
        }
        return;
    }
    if ra >= ca {
        let h = ra / 2;
        ip_swap(a, stride, off_a, off_b, h, ca);
        ip_swap(a, stride, off_a + h * stride, off_b + h, ra - h, ca);
    } else {
        let h = ca / 2;
        ip_swap(a, stride, off_a, off_b, ra, h);
        ip_swap(a, stride, off_a + h, off_b + h * stride, ra, ca - h);
    }
}

/// In-place transpose of a row-major `n × n` matrix. No allocation; the
/// recursion mirrors [`tile_recurse`] so blocks stay cache-resident.
pub fn transpose_square_in_place<T: Copy>(a: &mut [T], n: usize) {
    assert_eq!(a.len(), n * n, "transpose_square_in_place: length mismatch");
    if n > 1 {
        ip_diag(a, n, 0, n);
    }
}

/// In-place transpose of a row-major `rows × cols` matrix into row-major
/// `cols × rows`. Square matrices delegate to the allocation-free
/// [`transpose_square_in_place`]; rectangular matrices follow permutation
/// cycles — destination index `j` receives old index `(j·cols) mod m` with
/// `m = rows·cols − 1` — using a visited bitmap (`rows·cols` bools of
/// scratch, versus a full element copy for the out-of-place route).
pub fn transpose_in_place<T: Copy>(a: &mut [T], rows: usize, cols: usize) {
    assert_eq!(a.len(), rows * cols, "transpose_in_place: length mismatch");
    if rows == cols {
        transpose_square_in_place(a, rows);
        return;
    }
    let len = rows * cols;
    if len < 2 {
        return;
    }
    let m = len - 1;
    let mut visited = vec![false; len];
    for start in 1..m {
        if visited[start] {
            continue;
        }
        let mut j = start;
        let saved = a[start];
        loop {
            visited[j] = true;
            // The element that must land at j came from i = (j*cols) mod m:
            // new index j = c*rows + r corresponds to old index i = r*cols + c,
            // and i·rows ≡ j (mod m) because rows·cols ≡ 1 (mod m).
            let i = (j * cols) % m;
            if i == start {
                a[j] = saved;
                break;
            }
            a[j] = a[i];
            j = i;
        }
    }
}

/// Parallel out-of-place transpose over `pool`: the destination (row-major
/// `cols × rows`) is split into contiguous row bands, one region item per
/// band. Falls back to the sequential [`transpose_into`] below
/// [`PARALLEL_THRESHOLD`] elements or when `threads <= 1`.
///
/// Band boundaries only affect which thread writes a destination row, not
/// the per-element arithmetic (these are pure copies), so the result is
/// bit-identical to the sequential path — pinned by `transpose_parity.rs`.
///
/// # Panics
/// Panics on length mismatch. Must not be called from inside an active
/// pool region (regions do not nest).
pub fn transpose_into_parallel<T: Copy + Send + Sync>(
    dst: &mut [T],
    src: &[T],
    rows: usize,
    cols: usize,
    pool: &WorkerPool,
    threads: usize,
) {
    assert_eq!(src.len(), rows * cols, "transpose_into_parallel: src length mismatch");
    assert_eq!(dst.len(), rows * cols, "transpose_into_parallel: dst length mismatch");
    if rows * cols < PARALLEL_THRESHOLD || threads <= 1 {
        transpose_into(dst, src, rows, cols);
        return;
    }
    let bands = cols.min(threads * 4).max(1);
    let shared = SyncUnsafeSlice::new(dst);
    pool.run_with(threads, bands, Schedule::Static, |band| {
        let c0 = band * cols / bands;
        let c1 = (band + 1) * cols / bands;
        if c0 == c1 {
            return;
        }
        // SAFETY: destination rows c0..c1 form a contiguous region owned
        // exclusively by this band (bands partition 0..cols).
        let dst_band = unsafe {
            std::slice::from_raw_parts_mut(shared.ptr_at(c0 * rows), (c1 - c0) * rows)
        };
        tile_recurse(0, rows, c0, c1, BLOCK, &mut |r0, r1, b0, b1| {
            for c in b0..b1 {
                let drow = (c - c0) * rows;
                for r in r0..r1 {
                    dst_band[drow + r] = src[r * cols + c];
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_transpose<T: Copy + Default>(src: &[T], rows: usize, cols: usize) -> Vec<T> {
        let mut out = vec![T::default(); rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = src[r * cols + c];
            }
        }
        out
    }

    fn ramp(len: usize) -> Vec<f64> {
        (0..len).map(|i| i as f64 * 1.5 - 7.0).collect()
    }

    #[test]
    fn tile_recurse_covers_every_cell_once() {
        let (rows, cols) = (67, 41);
        let mut seen = vec![0u32; rows * cols];
        tile_recurse(0, rows, 0, cols, 8, &mut |r0, r1, c0, c1| {
            assert!(r1 - r0 <= 8 && c1 - c0 <= 8);
            for r in r0..r1 {
                for c in c0..c1 {
                    seen[r * cols + c] += 1;
                }
            }
        });
        assert!(seen.iter().all(|&v| v == 1));
    }

    #[test]
    fn transpose_into_matches_naive() {
        for &(rows, cols) in &[(1, 1), (5, 3), (7, 4), (33, 17), (64, 64), (65, 65), (1, 9)] {
            let src = ramp(rows * cols);
            let mut dst = vec![0.0; rows * cols];
            transpose_into(&mut dst, &src, rows, cols);
            assert_eq!(dst, naive_transpose(&src, rows, cols), "{rows}x{cols}");
        }
    }

    #[test]
    fn square_in_place_matches_naive() {
        for &n in &[1usize, 2, 3, 31, 32, 33, 64, 65, 100] {
            let src: Vec<u32> = (0..n * n).map(|i| i as u32) .collect();
            let mut a = src.clone();
            transpose_square_in_place(&mut a, n);
            assert_eq!(a, naive_transpose(&src, n, n), "n={n}");
        }
    }

    #[test]
    fn rect_in_place_matches_naive() {
        for &(rows, cols) in &[(2, 3), (5, 3), (3, 5), (7, 4), (33, 17), (17, 33), (1, 8), (8, 1)] {
            let src = ramp(rows * cols);
            let mut a = src.clone();
            transpose_in_place(&mut a, rows, cols);
            assert_eq!(a, naive_transpose(&src, rows, cols), "{rows}x{cols}");
        }
    }

    #[test]
    fn in_place_is_involutive() {
        let (rows, cols) = (12, 29);
        let src = ramp(rows * cols);
        let mut a = src.clone();
        transpose_in_place(&mut a, rows, cols);
        transpose_in_place(&mut a, cols, rows);
        assert_eq!(a, src);
    }

    #[test]
    fn gather_permuted_matches_double_loop() {
        let (rows, cols) = (9, 13);
        let src_stride = 15;
        let src = ramp(cols * src_stride);
        let perm: Vec<usize> = (0..rows).map(|r| (r * 7 + 3) % src_stride).collect();
        let dst_stride = cols + 2;
        let mut dst = vec![0.0; rows * dst_stride];
        let mut want = vec![0.0; rows * dst_stride];
        for r in 0..rows {
            for c in 0..cols {
                want[r * dst_stride + c] = src[c * src_stride + perm[r]];
            }
        }
        gather_permuted(&mut dst, dst_stride, &src, src_stride, &perm, rows, cols);
        assert_eq!(dst, want);
    }

    #[test]
    fn parallel_falls_back_below_threshold() {
        let (rows, cols) = (10, 10);
        let pool = WorkerPool::new(2).unwrap();
        let src = ramp(rows * cols);
        let mut dst = vec![0.0; rows * cols];
        transpose_into_parallel(&mut dst, &src, rows, cols, &pool, 2);
        assert_eq!(dst, naive_transpose(&src, rows, cols));
    }

    #[test]
    fn parallel_matches_sequential_above_threshold() {
        // 512*512 = 262144 > PARALLEL_THRESHOLD.
        let (rows, cols) = (512, 512);
        let pool = WorkerPool::new(3).unwrap();
        let src = ramp(rows * cols);
        let mut seq = vec![0.0; rows * cols];
        transpose_into(&mut seq, &src, rows, cols);
        let mut par = vec![0.0; rows * cols];
        transpose_into_parallel(&mut par, &src, rows, cols, &pool, 3);
        assert_eq!(par, seq);
    }
}

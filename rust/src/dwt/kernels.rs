//! Cluster-at-a-time DWT/iDWT kernels (matvec dataflow) — the measurable
//! baseline for the β-parity-folded default engine in [`super::folded`].
//!
//! One call processes one symmetry cluster: the Wigner-d base rows are
//! produced once — streamed from the three-term recurrence or unfolded
//! from the half-row tables — and applied to all ≤8 members. Reflected
//! members are handled by pre-reversing their j-vectors (forward) or by
//! writing through a reversed view (inverse), so the inner loops are
//! always unit stride.
//!
//! All writes land in caller-provided buffers at cluster-exclusive
//! locations; the parallel executor exploits this for lock-free output
//! (see `coordinator::exec`).

use crate::dwt::cluster::Cluster;
use crate::dwt::tables::WignerSource;
use crate::dwt::{v_scale, SMatrix};
use crate::fft::Complex64;
use crate::so3::coeffs;
use crate::util::{AlignedVec, SyncUnsafeSlice};
use crate::xprec::DdComplex;

/// Per-worker scratch for the DWT kernels (allocated once, reused across
/// clusters). Sized for the worst case: 8 members × 2B nodes.
///
/// The buffers are capacities, not exact sizes: every kernel slices by
/// its own bandwidth, so one scratch serves any plan with
/// `b <= capacity` — [`Self::ensure`] grows (never shrinks) it, letting
/// mixed-bandwidth plans share a worker's scratch without reallocating
/// on each bandwidth switch.
///
/// Every buffer is an [`AlignedVec`] (64-byte aligned) so the SIMD
/// micro-kernels in `dwt::simd` operate on cache-line-aligned data.
#[derive(Debug, Clone, Default)]
pub struct DwtScratch {
    /// Weighted (forward) or accumulated (inverse) member j-vectors.
    /// The folded kernels overlay the same storage as per-member
    /// (t⁺ | t⁻) half-vector pairs.
    pub t: AlignedVec<Complex64>,
    /// Row buffer when reading from a table source.
    pub row: AlignedVec<f64>,
    /// Folded row halves (E | O) for the source-fed folded kernels.
    pub fold: AlignedVec<f64>,
    /// Reconstructed O-row block for the register-blocked table kernels
    /// (lazily sized to `DEG_BLOCK · B`).
    pub oblock: AlignedVec<f64>,
    /// Extended-precision accumulators (lazily sized).
    pub xacc: AlignedVec<DdComplex>,
}

impl DwtScratch {
    /// Allocate scratch for bandwidth `b`.
    pub fn new(b: usize) -> Self {
        let mut s = Self::default();
        s.ensure(b);
        s
    }

    /// Grow the scratch to serve bandwidth `b` (no-op when it already
    /// does). Growth is monotone: capacity is the max bandwidth seen.
    pub fn ensure(&mut self, b: usize) {
        let n = 2 * b;
        if self.t.len() < 8 * n {
            self.t.resize(8 * n, Complex64::zero());
        }
        if self.row.len() < n {
            self.row.resize(n, 0.0);
        }
        if self.fold.len() < n {
            self.fold.resize(n, 0.0);
        }
        // `oblock`/`xacc` are sized lazily by the kernels that use them.
    }

    /// The largest bandwidth this scratch currently serves.
    pub fn capacity(&self) -> usize {
        self.t.len() / 16
    }
}

/// Forward DWT for one cluster.
///
/// Reads `S(μ, μ'; ·)` for every member from `smat`, applies quadrature
/// weights, contracts against the base Wigner rows, and writes the
/// coefficients `f°(l, μ, μ')` (flat (l,m,m') layout, see
/// [`crate::so3::coeffs::flat_index`]) through `out`.
///
/// # Safety contract
/// `out` writes are exclusive to this cluster: distinct clusters write
/// distinct (l, μ, μ') triples (guaranteed by the cluster tiling property
/// tested in `dwt::cluster`).
pub fn forward_cluster(
    b: usize,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    weights: &[f64],
    smat: &SMatrix,
    out: &SyncUnsafeSlice<'_, Complex64>,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nm = cluster.members.len();
    debug_assert!(nm <= 8);
    // Gather weighted member vectors; reflected members are reversed here
    // so every inner dot is a forward unit-stride scan.
    for (mi, member) in cluster.members.iter().enumerate() {
        let s = smat.vec(member.m, member.mp);
        let t = &mut scratch.t[mi * n..(mi + 1) * n];
        if member.reflected {
            for j in 0..n {
                t[j] = s[n - 1 - j].scale(weights[n - 1 - j]);
            }
        } else {
            for j in 0..n {
                t[j] = s[j].scale(weights[j]);
            }
        }
    }
    // Contract row-by-row.
    source.reset(cluster.m, cluster.mp);
    // lint: hot-loop-begin
    for l in l0..b {
        let row = source.row(l, &mut scratch.row);
        let vs = v_scale(l, b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let t = &scratch.t[mi * n..(mi + 1) * n];
            let mut acc = Complex64::zero();
            for j in 0..n {
                acc += t[j].scale(row[j]);
            }
            let value = acc.scale(vs * member.sign(l));
            let idx = coeffs::flat_index(l, member.m, member.mp);
            // SAFETY: (l, μ, μ') triples are cluster-exclusive.
            unsafe { out.write(idx, value) };
        }
    }
    // lint: hot-loop-end
}

/// Extended-precision forward DWT (double-double accumulation), used for
/// the paper's accuracy-critical large bandwidths.
pub fn forward_cluster_extended(
    b: usize,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    weights: &[f64],
    smat: &SMatrix,
    out: &SyncUnsafeSlice<'_, Complex64>,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    for (mi, member) in cluster.members.iter().enumerate() {
        let s = smat.vec(member.m, member.mp);
        let t = &mut scratch.t[mi * n..(mi + 1) * n];
        if member.reflected {
            for j in 0..n {
                t[j] = s[n - 1 - j].scale(weights[n - 1 - j]);
            }
        } else {
            for j in 0..n {
                t[j] = s[j].scale(weights[j]);
            }
        }
    }
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        let row = source.row(l, &mut scratch.row);
        let vs = v_scale(l, b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let t = &scratch.t[mi * n..(mi + 1) * n];
            let mut acc = DdComplex::ZERO;
            for j in 0..n {
                acc.acc_scaled(t[j].re, t[j].im, row[j]);
            }
            let (re, im) = acc.to_f64();
            let value = Complex64::new(re, im).scale(vs * member.sign(l));
            let idx = coeffs::flat_index(l, member.m, member.mp);
            // SAFETY: (l, μ, μ') triples are cluster-exclusive.
            unsafe { out.write(idx, value) };
        }
    }
}

/// Inverse DWT for one cluster: `S(j; μ, μ') = Σ_l d(l,μ,μ';β_j) f°(l,μ,μ')`.
///
/// Reads coefficients from the flat (l,m,m') layout and writes the member
/// j-vectors into the S-matrix through `smat_out` (cluster-exclusive
/// vectors — each (μ, μ') belongs to exactly one cluster).
pub fn inverse_cluster(
    b: usize,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    coeff_data: &[Complex64],
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
    smat_layout: &SMatrix,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nm = cluster.members.len();
    // Accumulate into scratch (zeroed), then scatter once.
    for v in scratch.t[..nm * n].iter_mut() {
        *v = Complex64::zero();
    }
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        let row = source.row(l, &mut scratch.row);
        for (mi, member) in cluster.members.iter().enumerate() {
            let c = coeff_data[coeffs::flat_index(l, member.m, member.mp)]
                .scale(member.sign(l));
            let t = &mut scratch.t[mi * n..(mi + 1) * n];
            // axpy: t[j] += c · row[j] — reflection applied at scatter.
            // lint: hot-loop-begin
            for j in 0..n {
                t[j] += c.scale(row[j]);
            }
            // lint: hot-loop-end
        }
    }
    for (mi, member) in cluster.members.iter().enumerate() {
        let t = &scratch.t[mi * n..(mi + 1) * n];
        let base = smat_layout.vec_index(member.m, member.mp);
        for j in 0..n {
            let src = if member.reflected { n - 1 - j } else { j };
            // SAFETY: each (μ, μ') j-vector belongs to exactly one cluster.
            unsafe { smat_out.write(base + j, t[src]) };
        }
    }
}

/// Extended-precision inverse DWT: the l-accumulation per (member, j)
/// runs in double-double, matching the paper's extended-precision
/// iDWT at accuracy-critical bandwidths.
pub fn inverse_cluster_extended(
    b: usize,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    coeff_data: &[Complex64],
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
    smat_layout: &SMatrix,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nm = cluster.members.len();
    scratch.xacc.clear();
    scratch.xacc.resize(nm * n, DdComplex::ZERO);
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        let row = source.row(l, &mut scratch.row);
        for (mi, member) in cluster.members.iter().enumerate() {
            let c = coeff_data[coeffs::flat_index(l, member.m, member.mp)]
                .scale(member.sign(l));
            let acc = &mut scratch.xacc[mi * n..(mi + 1) * n];
            for j in 0..n {
                acc[j].acc_scaled(c.re, c.im, row[j]);
            }
        }
    }
    for (mi, member) in cluster.members.iter().enumerate() {
        let acc = &scratch.xacc[mi * n..(mi + 1) * n];
        let base = smat_layout.vec_index(member.m, member.mp);
        for j in 0..n {
            let src = if member.reflected { n - 1 - j } else { j };
            let (re, im) = acc[src].to_f64();
            // SAFETY: each (μ, μ') j-vector belongs to exactly one cluster.
            unsafe { smat_out.write(base + j, Complex64::new(re, im)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dwt::tables::{OnTheFlySource, WignerTables};
    use crate::prng::Xoshiro256;
    use crate::so3::coeffs::So3Coeffs;
    use crate::so3::quadrature;
    use crate::so3::sampling::GridAngles;
    use crate::so3::wigner::d_single;

    /// Scalar oracle: forward DWT for one order pair straight from the
    /// definition (Eq. 5's β-sum).
    fn dwt_pair_oracle(
        b: usize,
        m: i64,
        mp: i64,
        smat: &SMatrix,
        weights: &[f64],
        betas: &[f64],
    ) -> Vec<Complex64> {
        let l0 = m.unsigned_abs().max(mp.unsigned_abs()) as usize;
        let s = smat.vec(m, mp);
        (l0..b)
            .map(|l| {
                let mut acc = Complex64::zero();
                for j in 0..2 * b {
                    acc += s[j].scale(weights[j] * d_single(l, m, mp, betas[j]));
                }
                acc.scale(v_scale(l, b))
            })
            .collect()
    }

    fn random_smat(b: usize, seed: u64) -> SMatrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut smat = SMatrix::zeros(b).unwrap();
        for v in smat.as_mut_slice().iter_mut() {
            *v = Complex64::new(rng.next_signed(), rng.next_signed());
        }
        smat
    }

    #[test]
    fn forward_cluster_matches_pair_oracle() {
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 3);
        let mut out = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut scratch = DwtScratch::new(b);
        let mut source = OnTheFlySource::new(&angles.betas);
        for (m, mp) in [(0i64, 0i64), (1, 0), (3, 3), (5, 2), (7, 6)] {
            let cluster = Cluster::symmetric(m, mp);
            {
                let shared = SyncUnsafeSlice::new(&mut out);
                forward_cluster(b, &cluster, &mut source, &weights, &smat, &shared, &mut scratch);
            }
            for member in &cluster.members {
                let want = dwt_pair_oracle(b, member.m, member.mp, &smat, &weights, &angles.betas);
                let l0 = cluster.l_min();
                for (i, l) in (l0..b).enumerate() {
                    let got = out[coeffs::flat_index(l, member.m, member.mp)];
                    assert!(
                        (got - want[i]).abs() < 1e-12,
                        "base=({m},{mp}) member=({},{}) l={l}: {got} vs {}",
                        member.m,
                        member.mp,
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn forward_matches_with_precomputed_tables() {
        let b = 6usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 9);
        let tables = WignerTables::build(b, &angles.betas);
        let mut out_fly = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut out_tab = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut scratch = DwtScratch::new(b);
        for m in 0..b as i64 {
            for mp in 0..=m {
                let cluster = Cluster::symmetric(m, mp);
                {
                    let shared = SyncUnsafeSlice::new(&mut out_fly);
                    let mut src = OnTheFlySource::new(&angles.betas);
                    forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch);
                }
                {
                    let shared = SyncUnsafeSlice::new(&mut out_tab);
                    let mut src = tables.source();
                    forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch);
                }
            }
        }
        for (a, c) in out_fly.iter().zip(out_tab.iter()) {
            assert!((*a - *c).abs() < 1e-13);
        }
    }

    #[test]
    fn extended_precision_agrees_with_double() {
        let b = 6usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 17);
        let mut out_d = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut out_x = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut scratch = DwtScratch::new(b);
        let cluster = Cluster::symmetric(4, 2);
        {
            let shared = SyncUnsafeSlice::new(&mut out_d);
            let mut src = OnTheFlySource::new(&angles.betas);
            forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch);
        }
        {
            let shared = SyncUnsafeSlice::new(&mut out_x);
            let mut src = OnTheFlySource::new(&angles.betas);
            forward_cluster_extended(b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch);
        }
        for member in &cluster.members {
            for l in cluster.l_min()..b {
                let i = coeffs::flat_index(l, member.m, member.mp);
                assert!((out_d[i] - out_x[i]).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn inverse_extended_agrees_with_double() {
        let b = 6usize;
        let angles = GridAngles::new(b).unwrap();
        let coeffs_in = So3Coeffs::random(b, 31);
        let layout = SMatrix::zeros(b).unwrap();
        let mut scratch = DwtScratch::new(b);
        let mut s_d = SMatrix::zeros(b).unwrap();
        let mut s_x = SMatrix::zeros(b).unwrap();
        let cluster = Cluster::symmetric(3, 1);
        {
            let shared = SyncUnsafeSlice::new(s_d.as_mut_slice());
            let mut src = OnTheFlySource::new(&angles.betas);
            inverse_cluster(
                b, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout, &mut scratch,
            );
        }
        {
            let shared = SyncUnsafeSlice::new(s_x.as_mut_slice());
            let mut src = OnTheFlySource::new(&angles.betas);
            inverse_cluster_extended(
                b, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout, &mut scratch,
            );
        }
        for member in &cluster.members {
            let a = s_d.vec(member.m, member.mp);
            let c = s_x.vec(member.m, member.mp);
            for (x, y) in a.iter().zip(c.iter()) {
                assert!((*x - *y).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn inverse_cluster_matches_synthesis_oracle() {
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let coeffs_in = So3Coeffs::random(b, 5);
        let mut smat = SMatrix::zeros(b).unwrap();
        let layout = SMatrix::zeros(b).unwrap();
        let mut scratch = DwtScratch::new(b);
        let mut source = OnTheFlySource::new(&angles.betas);
        for m in 0..b as i64 {
            for mp in 0..=m {
                let cluster = Cluster::symmetric(m, mp);
                let shared = SyncUnsafeSlice::new(smat.as_mut_slice());
                inverse_cluster(
                    b,
                    &cluster,
                    &mut source,
                    coeffs_in.as_slice(),
                    &shared,
                    &layout,
                    &mut scratch,
                );
            }
        }
        // Oracle: S(j; m, m') = Σ_l d(l,m,m';β_j)·f°(l,m,m').
        for m in (1 - (b as i64))..b as i64 {
            for mp in (1 - (b as i64))..b as i64 {
                let l0 = m.unsigned_abs().max(mp.unsigned_abs()) as usize;
                let got = smat.vec(m, mp);
                for j in 0..2 * b {
                    let mut want = Complex64::zero();
                    for l in l0..b {
                        want += coeffs_in
                            .at(l, m, mp)
                            .scale(d_single(l, m, mp, angles.betas[j]));
                    }
                    assert!(
                        (got[j] - want).abs() < 1e-12,
                        "({m},{mp}) j={j}: {} vs {want}",
                        got[j]
                    );
                }
            }
        }
    }

    #[test]
    fn scratch_grows_to_max_and_serves_smaller_bandwidths() {
        let mut s = DwtScratch::new(16);
        let len16 = s.t.len();
        let ptr16 = s.t.as_ptr();
        // Serving a smaller bandwidth is a no-op (no shrink, no realloc).
        s.ensure(8);
        assert_eq!(s.t.len(), len16);
        assert_eq!(s.t.as_ptr(), ptr16);
        assert_eq!(s.capacity(), 16);
        s.ensure(32);
        assert_eq!(s.capacity(), 32);
        // An oversized scratch computes identical results at a smaller b.
        let b = 6usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 21);
        let cluster = Cluster::symmetric(3, 2);
        let mut out_small = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut out_big = out_small.clone();
        let mut small = DwtScratch::new(b);
        {
            let shared = SyncUnsafeSlice::new(&mut out_small);
            let mut src = OnTheFlySource::new(&angles.betas);
            forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut small);
        }
        {
            let shared = SyncUnsafeSlice::new(&mut out_big);
            let mut src = OnTheFlySource::new(&angles.betas);
            forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut s);
        }
        for member in &cluster.members {
            for l in cluster.l_min()..b {
                let i = coeffs::flat_index(l, member.m, member.mp);
                assert_eq!(out_small[i], out_big[i]);
            }
        }
    }

    #[test]
    fn forward_then_inverse_is_projection_identity() {
        // By quadrature orthogonality, DWT ∘ iDWT on the coefficient side
        // is the identity *up to the 1/(4B²) factor* that the FFT stage
        // contributes in the full transform (the unnormalized 2-D FFT
        // roundtrip supplies the missing (2B)² = 4B²).
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let coeffs_in = So3Coeffs::random(b, 7);
        let mut smat = SMatrix::zeros(b).unwrap();
        let layout = SMatrix::zeros(b).unwrap();
        let mut back = vec![Complex64::zero(); crate::so3::coeffs::coeff_count(b)];
        let mut scratch = DwtScratch::new(b);
        let mut source = OnTheFlySource::new(&angles.betas);
        for m in 0..b as i64 {
            for mp in 0..=m {
                let cluster = Cluster::symmetric(m, mp);
                let shared = SyncUnsafeSlice::new(smat.as_mut_slice());
                inverse_cluster(
                    b,
                    &cluster,
                    &mut source,
                    coeffs_in.as_slice(),
                    &shared,
                    &layout,
                    &mut scratch,
                );
            }
        }
        for m in 0..b as i64 {
            for mp in 0..=m {
                let cluster = Cluster::symmetric(m, mp);
                let shared = SyncUnsafeSlice::new(&mut back);
                forward_cluster(b, &cluster, &mut source, &weights, &smat, &shared, &mut scratch);
            }
        }
        let scale = (4 * b * b) as f64;
        for v in back.iter_mut() {
            *v = v.scale(scale);
        }
        let back = So3Coeffs::from_vec(b, back).unwrap();
        let err = coeffs_in.max_abs_error(&back);
        assert!(err < 1e-12, "4B²·(DWT∘iDWT) identity error {err}");
    }
}

//! Symmetry clusters of DWTs — the paper's *communication / agglomeration*
//! design.
//!
//! The seven Wigner-d symmetries (paper Eq. 3) relate the eight order
//! pairs {(±m, ±m'), (±m', ±m)} to a single base evaluation
//! `D_l[j] = d(l, m, m'; β_j)` with m ≥ m' ≥ 0:
//!
//! * *direct* members read `D_l[j]` with an l-independent sign;
//! * *reflected* members read `D_l[2B−1−j]` (because π − β_j = β_{2B−1−j}
//!   on the K&R grid) with a sign that alternates with l.
//!
//! Derivation used here (validated by `member_signs_match_wigner`):
//!
//! | member        | source           | sign(l)            |
//! |---------------|------------------|--------------------|
//! | ( m,  m')     | D_l[j]           | +1                 |
//! | ( m', m)      | D_l[j]           | (−1)^{m−m'}        |
//! | (−m, −m')     | D_l[j]           | (−1)^{m−m'}        |
//! | (−m', −m)     | D_l[j]           | +1                 |
//! | (−m,  m')     | D_l[2B−1−j]      | (−1)^{l−m'}        |
//! | (−m', m)      | D_l[2B−1−j]      | (−1)^{l−m'}        |
//! | ( m, −m')     | D_l[2B−1−j]      | (−1)^{l+m}         |
//! | ( m', −m)     | D_l[2B−1−j]      | (−1)^{l+m}         |
//!
//! For m = m', m' = 0, or m = 0 some of these coincide; the cluster
//! builder deduplicates, which is exactly the paper's "smaller DWT
//! groups" for the special cases.

use crate::util::parity_sign;

/// One order pair inside a cluster and how to obtain its Wigner-d values
/// from the base rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Member {
    /// The order pair (μ, μ') this member computes.
    pub m: i64,
    /// See [`Self::m`].
    pub mp: i64,
    /// Read the base row reversed in j (the π−β reflection)?
    pub reflected: bool,
    /// Constant part of the sign.
    pub s0: f64,
    /// When true the sign also alternates with l: sign(l) = s0·(−1)^l.
    pub alt: bool,
}

impl Member {
    /// The sign applied at degree l.
    #[inline]
    pub fn sign(&self, l: usize) -> f64 {
        if self.alt {
            self.s0 * parity_sign(l as i64)
        } else {
            self.s0
        }
    }
}

/// A work package: one base order pair plus all members derivable from it
/// through the Wigner-d symmetries.
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    /// Base orders, m ≥ m' ≥ 0.
    pub m: i64,
    /// See [`Self::m`].
    pub mp: i64,
    /// The (μ, μ') pairs computed from this base pair's tables.
    pub members: Vec<Member>,
}

impl Cluster {
    /// Build the symmetry cluster for base pair m ≥ m' ≥ 0.
    pub fn symmetric(m: i64, mp: i64) -> Cluster {
        assert!(m >= mp && mp >= 0, "base pair must satisfy m >= m' >= 0");
        let eps = parity_sign(m - mp);
        let mut members: Vec<Member> = Vec::with_capacity(8);
        let mut push = |mm: i64, mmp: i64, reflected: bool, s0: f64, alt: bool| {
            if !members.iter().any(|x| x.m == mm && x.mp == mmp) {
                members.push(Member {
                    m: mm,
                    mp: mmp,
                    reflected,
                    s0,
                    alt,
                });
            }
        };
        // Direct group.
        push(m, mp, false, 1.0, false);
        push(mp, m, false, eps, false);
        push(-m, -mp, false, eps, false);
        push(-mp, -m, false, 1.0, false);
        // Reflected group (skip when it would duplicate a direct member,
        // i.e. when m = 0 — then -m = m and the β-reflection identities
        // degenerate).
        if m > 0 {
            // (−1)^{l−m'} = parity(m')·(−1)^l ; (−1)^{l+m} = parity(m)·(−1)^l.
            push(-m, mp, true, parity_sign(mp), true);
            push(-mp, m, true, parity_sign(mp), true);
            push(m, -mp, true, parity_sign(m), true);
            push(mp, -m, true, parity_sign(m), true);
        }
        Cluster { m, mp, members }
    }

    /// A singleton cluster (no symmetry exploitation — the ablation
    /// baseline): one member computing (m, m') directly from its own
    /// Wigner evaluation at base orders (|reduced| handled by the
    /// stepper itself).
    pub fn singleton(m: i64, mp: i64) -> Cluster {
        Cluster {
            m,
            mp,
            members: vec![Member {
                m,
                mp,
                reflected: false,
                s0: 1.0,
                alt: false,
            }],
        }
    }

    /// Lowest degree carrying this cluster: l₀ = max(|m|, |m'|) of the
    /// base (all members share it since |±m|, |±m'| have the same max).
    #[inline]
    pub fn l_min(&self) -> usize {
        self.m.abs().max(self.mp.abs()) as usize
    }

    /// β-reflection parity of this cluster's base rows, when they have
    /// one: `d(l, m, m'; π−β) = σ₀·(−1)^l · d(l, m, m'; β)` with the
    /// returned σ₀. The π−β symmetries (paper Eq. 3 lines 3–6) map
    /// (m, m') to a pair with exactly one order negated, so they reduce
    /// to a *same-pair* identity only when m·m' = 0: for (m, 0) the
    /// parity is (−1)^{l+m} (σ₀ = (−1)^m), for (0, m') it is
    /// (−1)^{l−m'} (σ₀ = (−1)^{m'}). General bases return `None` — their
    /// reflected half-row carries independent information (the folded
    /// tables store the symmetric half and reconstruct the antisymmetric
    /// one from the recurrence; see `dwt::tables`).
    #[inline]
    pub fn beta_parity(&self) -> Option<f64> {
        if self.mp == 0 {
            Some(parity_sign(self.m))
        } else if self.m == 0 {
            Some(parity_sign(self.mp))
        } else {
            None
        }
    }

    /// Number of degrees l₀..B−1 each member computes.
    #[inline]
    pub fn degrees(&self, b: usize) -> usize {
        b - self.l_min()
    }

    /// Operation count estimate for the cost model / simulator: each
    /// member performs one length-2B dot (or axpy) per degree, plus the
    /// base recurrence itself.
    pub fn flops(&self, b: usize) -> usize {
        let j = 2 * b;
        let deg = self.degrees(b);
        // 8 flops per complex-real MAC, 4 per recurrence point.
        deg * j * (8 * self.members.len() + 4)
    }
}

/// Expected member count for a base pair (paper §3 *Communication*):
/// 8 in general, fewer for the m=0 / m'=0 / m=m' special cases.
pub fn expected_member_count(m: i64, mp: i64) -> usize {
    match (m, mp) {
        (0, 0) => 1,
        (m, 0) if m > 0 => 4,
        (m, mp) if m == mp => 4,
        _ => 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::sampling::GridAngles;
    use crate::so3::wigner::{d_single, WignerRowStepper};
    use crate::testkit::Prop;

    #[test]
    fn member_counts_match_paper_special_cases() {
        assert_eq!(Cluster::symmetric(0, 0).members.len(), 1);
        for m in 1..6i64 {
            assert_eq!(
                Cluster::symmetric(m, 0).members.len(),
                expected_member_count(m, 0),
                "m={m}, mp=0"
            );
            assert_eq!(
                Cluster::symmetric(m, m).members.len(),
                expected_member_count(m, m),
                "m=mp={m}"
            );
        }
        for (m, mp) in [(2i64, 1i64), (5, 3), (7, 1)] {
            assert_eq!(Cluster::symmetric(m, mp).members.len(), 8);
        }
    }

    #[test]
    fn members_are_distinct_pairs() {
        Prop::new("cluster members distinct").cases(100).run(|g| {
            let m = g.i64_in(0, 20);
            let mp = g.i64_in(0, m.max(0));
            let c = Cluster::symmetric(m, mp);
            for (i, a) in c.members.iter().enumerate() {
                for b in &c.members[i + 1..] {
                    Prop::assert_true(
                        (a.m, a.mp) != (b.m, b.mp),
                        "duplicate member pair",
                    )?;
                }
            }
            Ok(())
        });
    }

    /// The core correctness of the whole parallel design: every member's
    /// sign/reflection rule reproduces the true Wigner-d values.
    #[test]
    fn member_signs_match_wigner() {
        let b = 10usize;
        let angles = GridAngles::new(b).unwrap();
        let n = 2 * b;
        for (m, mp) in [(0i64, 0i64), (1, 0), (3, 0), (2, 2), (5, 5), (3, 1), (7, 4), (9, 8)] {
            let cluster = Cluster::symmetric(m, mp);
            let mut stepper: WignerRowStepper<f64> =
                WignerRowStepper::new(m, mp, &angles.betas);
            for l in cluster.l_min()..b {
                let row = stepper.row().to_vec();
                for member in &cluster.members {
                    let sign = member.sign(l);
                    for j in 0..n {
                        let src = if member.reflected { n - 1 - j } else { j };
                        let expect = d_single(l, member.m, member.mp, angles.betas[j]);
                        let got = sign * row[src];
                        assert!(
                            (expect - got).abs() < 1e-12,
                            "base=({m},{mp}) member=({},{}) l={l} j={j}: {got} vs {expect}",
                            member.m,
                            member.mp
                        );
                    }
                }
                stepper.advance();
            }
        }
    }

    /// `beta_parity` must reproduce the true π−β behavior of the base
    /// rows: exact alternating parity for m·m' = 0, none otherwise.
    #[test]
    fn beta_parity_matches_wigner_reflection() {
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let n = 2 * b;
        for (m, mp) in [(0i64, 0i64), (1, 0), (4, 0), (7, 0)] {
            let cluster = Cluster::symmetric(m, mp);
            let sigma0 = cluster.beta_parity().expect("m'=0 bases have parity");
            for l in cluster.l_min()..b {
                let sig = sigma0 * crate::util::parity_sign(l as i64);
                for j in 0..n {
                    let a = d_single(l, m, mp, angles.betas[j]);
                    let r = d_single(l, m, mp, angles.betas[n - 1 - j]);
                    assert!(
                        (r - sig * a).abs() < 1e-12,
                        "(m={m}) l={l} j={j}: {r} vs {}",
                        sig * a
                    );
                }
            }
        }
        // General bases have no same-pair reflection parity: neither
        // sign choice explains the reflected row.
        for (m, mp) in [(2i64, 1i64), (5, 3), (3, 3)] {
            assert!(Cluster::symmetric(m, mp).beta_parity().is_none());
            let l = (m.max(mp) + 1) as usize;
            let beta = angles.betas[1];
            let a = d_single(l, m, mp, beta);
            let r = d_single(l, m, mp, std::f64::consts::PI - beta);
            assert!((r - a).abs() > 1e-6 && (r + a).abs() > 1e-6, "({m},{mp})");
        }
        // Singleton clusters from the no-symmetry ablation also report
        // parity for m·m' = 0 order pairs (either sign of m).
        assert_eq!(Cluster::singleton(-3, 0).beta_parity(), Some(-1.0));
        assert_eq!(Cluster::singleton(0, 2).beta_parity(), Some(1.0));
        assert_eq!(Cluster::singleton(-2, 5).beta_parity(), None);
    }

    #[test]
    fn union_of_clusters_covers_order_square_exactly_once() {
        // Base pairs m >= mp >= 0 tile the full (2B−1)² order square.
        let b = 7i64;
        let mut seen = std::collections::HashSet::new();
        for m in 0..b {
            for mp in 0..=m {
                for member in Cluster::symmetric(m, mp).members {
                    assert!(
                        seen.insert((member.m, member.mp)),
                        "pair ({}, {}) covered twice",
                        member.m,
                        member.mp
                    );
                }
            }
        }
        assert_eq!(seen.len(), ((2 * b - 1) * (2 * b - 1)) as usize);
        for m in (1 - b)..b {
            for mp in (1 - b)..b {
                assert!(seen.contains(&(m, mp)), "pair ({m}, {mp}) missing");
            }
        }
    }

    #[test]
    fn flops_monotone_in_members_and_degrees() {
        let b = 16;
        let big = Cluster::symmetric(3, 1);
        let small = Cluster::symmetric(15, 1);
        assert!(big.flops(b) > small.flops(b), "lower l0 ⇒ more work");
        let single = Cluster::singleton(3, 1);
        assert!(single.flops(b) < big.flops(b));
    }

    #[test]
    fn singleton_covers_itself_only() {
        let c = Cluster::singleton(-4, 2);
        assert_eq!(c.members.len(), 1);
        assert_eq!(c.members[0].m, -4);
        assert_eq!(c.members[0].mp, 2);
        assert!(!c.members[0].reflected);
    }
}

//! Wigner-d row sources: β-parity-folded precomputed tables vs.
//! on-the-fly recurrence.
//!
//! The paper's benchmark build precomputes the DWT matrices, exploiting
//! all seven symmetries "in the precomputation of the matrices using the
//! three-term recurrence relation". Symmetry-shared storage keeps only
//! the base pairs m ≥ m' ≥ 0 (≈⅛ of the full table set) — exactly what
//! the clusters need. This module folds one level deeper: the K&R β-grid
//! is reflection-symmetric (π − β_j = β_{2B−1−j}), so [`WignerTables`]
//! stores only **half-length rows over j < B** — half the bytes of the
//! full-row layout, doubling what fits under a given
//! [`WignerStorage::auto`] budget:
//!
//! * **Parity bases (m' = 0).** The row itself has β-reflection parity,
//!   `d(l, m, 0; π−β) = (−1)^{l+m} d(l, m, 0; β)`, so the half row
//!   `H_l[j] = d(l, m, 0; β_j)` (j < B) *is* the full row.
//! * **General bases (m' > 0).** The π−β symmetries map (m, m') to a
//!   different order pair, so no same-row parity exists. Stored instead
//!   is the symmetric half `E_l[j] = D_l[j] + D_l[2B−1−j]` for
//!   l₀ ≤ l ≤ B (one guard degree past the spectrum). The antisymmetric
//!   half `O_l[j] = D_l[j] − D_l[2B−1−j]` follows *exactly* from the
//!   three-term recurrence (paper Eq. 2): cos β is odd under the node
//!   reflection, so taking even parts of
//!   `d_{l+1} = (a₁cosβ + a₂)d_l − a₃d_{l−1}` gives
//!   `E_{l+1} = a₁cosβ·O_l + a₂E_l − a₃E_{l−1}`, i.e.
//!   `O_l[j] = (E_{l+1}[j] − a₂E_l[j] + a₃E_{l−1}[j]) / (a₁ cos β_j)`.
//!   cos β_j never vanishes on the grid (β_j = (2j+1)π/4B with 2j+1 odd,
//!   2B even), and 1/(a₁ cos β_{B−1}) ≈ 4B/π bounds the rounding
//!   amplification at O(B·ε) — ~1e-13 absolute at B = 512, documented in
//!   docs/PERF.md (`storage = "onthefly"` streams exact rows when that
//!   matters, e.g. strict extended-precision runs).
//!
//! At memory-critical bandwidths the same rows can be streamed from the
//! recurrence instead ([`OnTheFlySource`]), trading ~2× arithmetic for
//! O(B) instead of O(B⁴) memory.

use crate::so3::wigner::{step_coeffs, WignerRowStepper};

/// Abstract producer of base Wigner-d rows `d(l, m, m'; β_j)` for a fixed
/// base pair, consumed degree-by-degree (l ascending from the cluster's
/// l₀). `reset` rebinds the source to a new base pair.
pub trait WignerSource {
    /// Re-seed the source for the order pair `(m, mp)`.
    fn reset(&mut self, m: i64, mp: i64);
    /// The row at degree `l`; rows must be requested with l strictly
    /// increasing between resets. `buf` (len ≥ 2B) may be used as backing
    /// storage; the returned slice is valid until the next call.
    fn row<'a>(&'a mut self, l: usize, buf: &'a mut [f64]) -> &'a [f64];
}

/// Streams rows from the three-term recurrence, never materializing a
/// table. ~zero memory; each cluster pays the recurrence (4 flops per
/// (l, j) point) once for all its members.
pub struct OnTheFlySource<'b> {
    betas: &'b [f64],
    stepper: Option<WignerRowStepper<f64>>,
    m: i64,
    mp: i64,
}

impl<'b> OnTheFlySource<'b> {
    /// Source recurring over the given β angles.
    pub fn new(betas: &'b [f64]) -> Self {
        Self {
            betas,
            stepper: None,
            m: 0,
            mp: 0,
        }
    }
}

impl WignerSource for OnTheFlySource<'_> {
    fn reset(&mut self, m: i64, mp: i64) {
        self.m = m;
        self.mp = mp;
        self.stepper = Some(WignerRowStepper::new(m, mp, self.betas));
    }

    fn row<'a>(&'a mut self, l: usize, _buf: &'a mut [f64]) -> &'a [f64] {
        let stepper = self.stepper.as_mut().expect("reset() before row()");
        debug_assert!(l >= stepper.l_min(), "row below l0");
        while stepper.current_l() < l {
            stepper.advance();
        }
        stepper.row()
    }
}

/// Precomputed symmetry-shared, β-parity-folded tables: half-length rows
/// for every base pair m ≥ m' ≥ 0, packed contiguously (see module docs
/// for the per-base layout).
#[derive(Debug, Clone)]
pub struct WignerTables {
    b: usize,
    /// Packed half-rows: for base (m, m'), degrees l₀.. (B−1 for parity
    /// bases, B for general bases — the guard degree), each row B long.
    /// Under [`Self::build_partial`] only the `present` bases are packed.
    data: Vec<f64>,
    /// Offset of base pair (m, m') in `data` (absent bases carry the
    /// running offset and contribute zero rows).
    offsets: Vec<usize>,
    /// 1/cos(β_j) for j < B — the O-row reconstruction divisors.
    inv_cos: Vec<f64>,
    /// Which base pairs are materialized (all `true` for [`Self::build`]
    /// and [`Self::load`]); the executor streams the rest from the
    /// recurrence per base pair ([`Self::has`]).
    present: Vec<bool>,
    /// Charges this table set's footprint against the process allocation
    /// ledger for the lifetime of the struct (`util::ledger`).
    ledger: crate::util::ledger::LedgerSlot,
}

/// Triangle index of a base pair m ≥ m' ≥ 0 (the paper's σ map, Eq. 7,
/// restricted to the canonical triangle).
#[inline]
pub fn base_index(m: i64, mp: i64) -> usize {
    debug_assert!(m >= mp && mp >= 0);
    (m * (m + 1) / 2 + mp) as usize
}

/// Half-rows stored for base (m, m') at bandwidth b: B − l₀ for parity
/// bases (m' = 0), B − l₀ + 1 for general bases (the E_B guard row).
#[inline]
fn rows_per_base(b: usize, m: usize, mp: usize) -> usize {
    let l0 = m.max(mp);
    if mp == 0 {
        b - l0
    } else {
        b - l0 + 1
    }
}

impl WignerTables {
    /// Total f64 slots needed for bandwidth `b` (diagnostics / memory
    /// planning) — ~half of the pre-fold full-row layout (~B⁴/6 entries
    /// instead of ~B⁴/3).
    pub fn storage_len(b: usize) -> usize {
        let mut total = 0;
        for m in 0..b {
            for mp in 0..=m {
                total += rows_per_base(b, m, mp) * b;
            }
        }
        total
    }

    /// Build all base tables sequentially. (The sequential transform and
    /// tests use this constructor; plans build it at construction.)
    /// `betas` must be the reflection-symmetric K&R grid
    /// (π − β_j = β_{2B−1−j}) — the folding identity depends on it.
    pub fn build(b: usize, betas: &[f64]) -> Self {
        Self::build_with_budget(b, betas, None)
    }

    /// Build only as many base tables as fit under `budget_bytes`
    /// (streamed large-B mode, ISSUE 8): the divisor vector is reserved
    /// first, then bases are admitted greedily in canonical (m asc,
    /// m' asc) order while their half-row block fits the remainder.
    /// Absent bases are streamed from the recurrence at transform time —
    /// the executor checks [`Self::has`] per base pair, so the
    /// precompute/stream decision is per-degree-pair, not global.
    pub fn build_partial(b: usize, betas: &[f64], budget_bytes: usize) -> Self {
        Self::build_with_budget(b, betas, Some(budget_bytes))
    }

    fn build_with_budget(b: usize, betas: &[f64], budget_bytes: Option<usize>) -> Self {
        assert_eq!(betas.len(), 2 * b);
        for j in 0..b {
            assert!(
                (betas[j] + betas[2 * b - 1 - j] - std::f64::consts::PI).abs() < 1e-9,
                "folded tables require the reflection-symmetric β grid"
            );
        }
        let n = 2 * b;
        let n_bases = b * (b + 1) / 2;
        let mut present = vec![true; n_bases];
        if let Some(budget) = budget_bytes {
            // inv_cos is unconditional (needed by every present base).
            let mut remaining = budget.saturating_sub(b * 8);
            for m in 0..b {
                for mp in 0..=m {
                    let bi = base_index(m as i64, mp as i64);
                    let bytes = rows_per_base(b, m, mp) * b * 8;
                    if bytes <= remaining {
                        remaining -= bytes;
                    } else {
                        present[bi] = false;
                    }
                }
            }
        }
        let mut offsets = vec![0usize; n_bases + 1];
        let mut total = 0usize;
        for m in 0..b {
            for mp in 0..=m {
                let bi = base_index(m as i64, mp as i64);
                offsets[bi] = total;
                if present[bi] {
                    total += rows_per_base(b, m, mp) * b;
                }
            }
        }
        offsets[n_bases] = total;
        let mut data = vec![0.0f64; total];
        for m in 0..b as i64 {
            for mp in 0..=m {
                let bi = base_index(m, mp);
                if !present[bi] {
                    continue;
                }
                let off = offsets[bi];
                let rows = rows_per_base(b, m as usize, mp as usize);
                let mut stepper: WignerRowStepper<f64> = WignerRowStepper::new(m, mp, betas);
                for r in 0..rows {
                    let row = stepper.row();
                    let dst = &mut data[off + r * b..off + (r + 1) * b];
                    if mp == 0 {
                        // Parity base: the half row is the full row.
                        dst.copy_from_slice(&row[..b]);
                    } else {
                        // General base: symmetric half E_l.
                        for (j, d) in dst.iter_mut().enumerate() {
                            *d = row[j] + row[n - 1 - j];
                        }
                    }
                    stepper.advance();
                }
            }
        }
        let inv_cos: Vec<f64> = betas[..b].iter().map(|&beta| 1.0 / beta.cos()).collect();
        let ledger = crate::util::ledger::LedgerSlot::new(
            (data.len() + inv_cos.len()) * std::mem::size_of::<f64>(),
        );
        Self {
            b,
            data,
            offsets,
            inv_cos,
            present,
            ledger,
        }
    }

    /// Bandwidth the tables were built for.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Approximate memory footprint in bytes — ~half the pre-fold layout
    /// for the same bandwidth (less when partially materialized).
    pub fn bytes(&self) -> usize {
        (self.data.len() + self.inv_cos.len()) * std::mem::size_of::<f64>()
    }

    /// Bytes of a *fully* materialized table set at bandwidth `b`
    /// (half-rows plus the divisor vector) — the budget planner's
    /// predicted-table-size input.
    pub fn full_bytes(b: usize) -> usize {
        (Self::storage_len(b) + b) * std::mem::size_of::<f64>()
    }

    /// Whether the base pair (m, m') is materialized in this table set.
    /// Non-canonical pairs (m < m' or m' < 0) are never stored; partial
    /// sets ([`Self::build_partial`]) may omit canonical ones too — the
    /// executor streams those from the recurrence.
    #[inline]
    pub fn has(&self, m: i64, mp: i64) -> bool {
        m >= mp && mp >= 0 && self.present[base_index(m, mp)]
    }

    /// `true` iff every canonical base pair is materialized (i.e. this is
    /// not a [`Self::build_partial`] set with streamed gaps).
    pub fn is_complete(&self) -> bool {
        self.present.iter().all(|&p| p)
    }

    #[inline]
    fn base_slice(&self, m: i64, mp: i64, l: usize) -> &[f64] {
        let l0 = m.max(mp) as usize;
        debug_assert!(l >= l0);
        debug_assert!(if mp == 0 { l < self.b } else { l <= self.b });
        debug_assert!(
            self.present[base_index(m, mp)],
            "base ({m}, {mp}) not materialized — callers must check has()"
        );
        let off = self.offsets[base_index(m, mp)] + (l - l0) * self.b;
        &self.data[off..off + self.b]
    }

    /// Half row `H_l[j] = d(l, m, 0; β_j)` (j < B) of a parity base; the
    /// reflected half is `(−1)^{l+m} H_l[j]`.
    #[inline]
    pub fn half_row(&self, m: i64, l: usize) -> &[f64] {
        self.base_slice(m, 0, l)
    }

    /// Symmetric half `E_l[j] = D_l[j] + D_l[2B−1−j]` (j < B) of a
    /// general base; valid for l₀ ≤ l ≤ B (the guard degree included).
    #[inline]
    pub fn e_row(&self, m: i64, mp: i64, l: usize) -> &[f64] {
        debug_assert!(mp > 0, "e_row is for general bases; use half_row");
        self.base_slice(m, mp, l)
    }

    /// Reconstruct the antisymmetric half `O_l[j] = D_l[j] − D_l[2B−1−j]`
    /// of a general base into `out[..B]` (exact up to O(B·ε) rounding;
    /// see module docs).
    pub fn recon_o_into(&self, m: i64, mp: i64, l: usize, out: &mut [f64]) {
        let out = &mut out[..self.b];
        self.recon_o_with(m, mp, l, |j, o| out[j] = o);
    }

    /// Reconstruct the full 2B-node row for base pair (m, m') at degree l
    /// into `buf[..2B]` (unfolding the stored halves). This is the
    /// compatibility surface for full-row consumers ([`TableSource`],
    /// the offload packing, the `matvec` baseline); the folded kernels
    /// consume the halves directly.
    pub fn row_into<'a>(&self, m: i64, mp: i64, l: usize, buf: &'a mut [f64]) -> &'a [f64] {
        let b = self.b;
        let n = 2 * b;
        assert!(buf.len() >= n, "row_into needs a 2B-length buffer");
        let buf = &mut buf[..n];
        if mp == 0 {
            let h = self.half_row(m, l);
            let sig = crate::util::parity_sign(l as i64 + m);
            for j in 0..b {
                buf[j] = h[j];
                buf[n - 1 - j] = sig * h[j];
            }
        } else {
            // D[j] = (E+O)/2, D[2B−1−j] = (E−O)/2. O goes through a
            // stack-free two-phase write: E first, then fold O in.
            let e = self.e_row(m, mp, l);
            for j in 0..b {
                buf[j] = 0.5 * e[j];
                buf[n - 1 - j] = 0.5 * e[j];
            }
            let (lo, hi) = buf.split_at_mut(b);
            self.recon_o_with(m, mp, l, |j, o| {
                lo[j] += 0.5 * o;
                hi[b - 1 - j] -= 0.5 * o;
            });
        }
        buf
    }

    /// Streaming core of the O-half reconstruction: calls `f(j, O_l[j])`
    /// for j < B. General bases have m ≥ m' ≥ 1 ⇒ l ≥ l₀ ≥ 1, so the
    /// step coefficients are always defined; at l = l₀ the a₃ term
    /// carries d_{l₀−1} ≡ 0 (and a₃ itself vanishes there).
    fn recon_o_with(&self, m: i64, mp: i64, l: usize, mut f: impl FnMut(usize, f64)) {
        debug_assert!(mp > 0);
        let b = self.b;
        let l0 = m.max(mp) as usize;
        debug_assert!(l >= l0 && l < b);
        let c = step_coeffs(l, m, mp);
        let inv_a1 = 1.0 / c.a1;
        let e0 = self.e_row(m, mp, l);
        let e1 = self.e_row(m, mp, l + 1);
        if l == l0 {
            for j in 0..b {
                f(j, (e1[j] - c.a2 * e0[j]) * inv_a1 * self.inv_cos[j]);
            }
        } else {
            let em1 = self.e_row(m, mp, l - 1);
            for j in 0..b {
                f(
                    j,
                    (e1[j] - c.a2 * e0[j] + c.a3 * em1[j]) * inv_a1 * self.inv_cos[j],
                );
            }
        }
    }

    /// A [`WignerSource`] view over these tables (shared, cheap). Rows
    /// are unfolded into the caller's buffer on demand.
    pub fn source(&self) -> TableSource<'_> {
        TableSource {
            tables: self,
            m: 0,
            mp: 0,
        }
    }

    /// Persist to disk so the precomputation (the dominant setup cost at
    /// large B — the paper precomputes per run) is paid once per machine.
    /// Format (v2, folded): `SO3W2` magic, LE u64 bandwidth, LE u64
    /// count, B raw LE f64 reconstruction divisors (1/cos β_j), `count`
    /// raw LE f64 half-row values. v1 (`SO3W1`, full rows) caches are
    /// rejected — rebuild them (docs/MIGRATION.md).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::error::Result<()> {
        use std::io::Write;
        if self.data.len() != Self::storage_len(self.b) {
            return Err(crate::error::Error::Runtime(
                "refusing to persist a partially materialized (streamed) table set"
                    .into(),
            ));
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"SO3W2")?;
        f.write_all(&(self.b as u64).to_le_bytes())?;
        f.write_all(&(self.data.len() as u64).to_le_bytes())?;
        for v in self.inv_cos.iter().chain(self.data.iter()) {
            f.write_all(&v.to_le_bytes())?;
        }
        f.flush()?;
        Ok(())
    }

    /// Load tables written by [`Self::save`]; validates magic, bandwidth
    /// and length. Pre-fold (`SO3W1`) caches fail with a clear rebuild
    /// message.
    pub fn load(
        path: impl AsRef<std::path::Path>,
        expect_b: usize,
    ) -> crate::error::Result<Self> {
        use crate::error::Error;
        use std::io::Read;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 5];
        f.read_exact(&mut magic)?;
        if &magic == b"SO3W1" {
            return Err(Error::Runtime(
                "wigner table cache: pre-fold v1 format (SO3W1); delete and rebuild \
                 the cache with this version"
                    .into(),
            ));
        }
        if &magic != b"SO3W2" {
            return Err(Error::Runtime("wigner table cache: bad magic".into()));
        }
        let mut u = [0u8; 8];
        f.read_exact(&mut u)?;
        let b = u64::from_le_bytes(u) as usize;
        if b != expect_b {
            return Err(Error::Runtime(format!(
                "wigner table cache: bandwidth {b}, expected {expect_b}"
            )));
        }
        f.read_exact(&mut u)?;
        let len = u64::from_le_bytes(u) as usize;
        if len != Self::storage_len(b) {
            return Err(Error::Runtime("wigner table cache: bad length".into()));
        }
        let mut inv_cos = vec![0.0f64; b];
        let mut data = vec![0.0f64; len];
        let mut buf = [0u8; 8];
        for v in inv_cos.iter_mut().chain(data.iter_mut()) {
            f.read_exact(&mut buf)?;
            *v = f64::from_le_bytes(buf);
        }
        // Rebuild offsets (derived, not stored).
        let n_bases = b * (b + 1) / 2;
        let mut offsets = vec![0usize; n_bases + 1];
        let mut total = 0usize;
        for m in 0..b {
            for mp in 0..=m {
                offsets[base_index(m as i64, mp as i64)] = total;
                total += rows_per_base(b, m, mp) * b;
            }
        }
        offsets[n_bases] = total;
        let ledger = crate::util::ledger::LedgerSlot::new(
            (data.len() + inv_cos.len()) * std::mem::size_of::<f64>(),
        );
        Ok(Self {
            b,
            data,
            offsets,
            inv_cos,
            present: vec![true; n_bases],
            ledger,
        })
    }

    /// Canonical file name for bandwidth-`b` tables inside `dir`
    /// (`wigner-b{b}.so3w2`). Callers should not invent their own
    /// layouts; this and [`crate::util::cache_dir`] are the single
    /// source of truth for where cached artifacts live.
    pub fn cache_path_in(dir: impl AsRef<std::path::Path>, b: usize) -> std::path::PathBuf {
        dir.as_ref().join(format!("wigner-b{b}.so3w2"))
    }

    /// [`Self::cache_path_in`] under the crate cache directory
    /// ([`crate::util::cache_dir`]), where the wisdom store also lives.
    pub fn cache_path(b: usize) -> std::path::PathBuf {
        Self::cache_path_in(crate::util::cache_dir(), b)
    }

    /// Persist at the canonical name inside `dir` (created if missing).
    pub fn save_cached_in(&self, dir: impl AsRef<std::path::Path>) -> crate::error::Result<()> {
        std::fs::create_dir_all(dir.as_ref())?;
        self.save(Self::cache_path_in(dir.as_ref(), self.b))
    }

    /// Persist at [`Self::cache_path`] in the crate cache directory.
    pub fn save_cached(&self) -> crate::error::Result<()> {
        self.save_cached_in(crate::util::cache_dir())
    }

    /// Load bandwidth-`b` tables from `dir`'s canonical path.
    pub fn load_cached_in(
        dir: impl AsRef<std::path::Path>,
        b: usize,
    ) -> crate::error::Result<Self> {
        Self::load(Self::cache_path_in(dir, b), b)
    }

    /// Load bandwidth-`b` tables from the crate cache directory.
    pub fn load_cached(b: usize) -> crate::error::Result<Self> {
        Self::load(Self::cache_path(b), b)
    }
}

/// Table-backed row source (unfolds half-rows into the caller's buffer).
pub struct TableSource<'t> {
    tables: &'t WignerTables,
    m: i64,
    mp: i64,
}

impl WignerSource for TableSource<'_> {
    fn reset(&mut self, m: i64, mp: i64) {
        debug_assert!(m >= mp && mp >= 0, "tables store canonical bases only");
        self.m = m;
        self.mp = mp;
    }

    fn row<'a>(&'a mut self, l: usize, buf: &'a mut [f64]) -> &'a [f64] {
        self.tables.row_into(self.m, self.mp, l, buf)
    }
}

/// Storage strategy selector used by the transform configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WignerStorage {
    /// Precompute symmetry-shared folded tables (paper's benchmarked
    /// setup, at half the pre-fold footprint).
    Precomputed,
    /// Stream rows from the recurrence (memory-critical bandwidths, or
    /// strict extended-precision accuracy — exact rows, no O(B·ε)
    /// reconstruction term).
    OnTheFly,
}

impl WignerStorage {
    /// Pick a default: precompute while the tables stay under `budget`
    /// bytes, stream otherwise (the B=512 regime of the paper). The
    /// folded layout fits ~2× the bandwidth range of the pre-fold one
    /// under the same budget.
    pub fn auto(b: usize, budget: usize) -> Self {
        if WignerTables::storage_len(b) * 8 <= budget {
            WignerStorage::Precomputed
        } else {
            WignerStorage::OnTheFly
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::sampling::GridAngles;
    use crate::so3::wigner::d_single;

    #[test]
    fn tables_match_direct_evaluation() {
        let b = 8;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        let mut buf = vec![0.0; 2 * b];
        for m in 0..b as i64 {
            for mp in 0..=m {
                let l0 = m.max(mp) as usize;
                for l in l0..b {
                    let row = tables.row_into(m, mp, l, &mut buf).to_vec();
                    for (j, &bj) in angles.betas.iter().enumerate() {
                        let want = d_single(l, m, mp, bj);
                        assert!(
                            (row[j] - want).abs() < 1e-12,
                            "m={m} mp={mp} l={l} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn folded_halves_match_direct_evaluation() {
        let b = 8;
        let n = 2 * b;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        let mut obuf = vec![0.0; b];
        for m in 1..b as i64 {
            for mp in 1..=m {
                let l0 = m as usize;
                for l in l0..b {
                    let e = tables.e_row(m, mp, l);
                    tables.recon_o_into(m, mp, l, &mut obuf);
                    for j in 0..b {
                        let d = d_single(l, m, mp, angles.betas[j]);
                        let dr = d_single(l, m, mp, angles.betas[n - 1 - j]);
                        assert!((e[j] - (d + dr)).abs() < 1e-13, "E m={m} mp={mp} l={l} j={j}");
                        assert!(
                            (obuf[j] - (d - dr)).abs() < 1e-13,
                            "O m={m} mp={mp} l={l} j={j}: {} vs {}",
                            obuf[j],
                            d - dr
                        );
                    }
                }
            }
        }
        // Parity bases: the half row is the literal row, the reflected
        // half is sign-implied.
        for m in 0..b as i64 {
            for l in m as usize..b {
                let h = tables.half_row(m, l);
                let sig = crate::util::parity_sign(l as i64 + m);
                for j in 0..b {
                    let d = d_single(l, m, 0, angles.betas[j]);
                    let dr = d_single(l, m, 0, angles.betas[n - 1 - j]);
                    assert!((h[j] - d).abs() < 1e-13);
                    assert!((dr - sig * d).abs() < 1e-12, "parity m={m} l={l} j={j}");
                }
            }
        }
    }

    #[test]
    fn storage_len_matches_build_and_is_half_of_full_rows() {
        for b in [1usize, 2, 5, 8, 16] {
            let angles = GridAngles::new(b).unwrap();
            let tables = WignerTables::build(b, &angles.betas);
            assert_eq!(tables.data.len(), WignerTables::storage_len(b));
            // Pre-fold layout: (B − l0) full 2B rows per base.
            let full: usize = (0..b)
                .flat_map(|m| (0..=m).map(move |_| (b - m) * 2 * b))
                .sum();
            let folded = WignerTables::storage_len(b);
            assert!(folded * 2 <= full + 2 * b * b * b, "b={b}: {folded} vs {full}");
            if b >= 8 {
                // The guard rows add O(B³) on top of the halved O(B⁴):
                // 0.617 at b = 8, 0.574 at 16, → ½ asymptotically.
                let ratio = folded as f64 / full as f64;
                assert!(
                    (0.45..=0.63).contains(&ratio),
                    "b={b}: folded/full = {ratio}"
                );
            }
        }
    }

    #[test]
    fn base_index_is_triangular() {
        assert_eq!(base_index(0, 0), 0);
        assert_eq!(base_index(1, 0), 1);
        assert_eq!(base_index(1, 1), 2);
        assert_eq!(base_index(2, 0), 3);
        assert_eq!(base_index(3, 3), 9);
        // Bijective over the triangle.
        let mut seen = std::collections::HashSet::new();
        for m in 0..20i64 {
            for mp in 0..=m {
                assert!(seen.insert(base_index(m, mp)));
            }
        }
        assert_eq!(seen.len(), 20 * 21 / 2);
    }

    #[test]
    fn on_the_fly_source_matches_tables() {
        let b = 6;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        let mut fly = OnTheFlySource::new(&angles.betas);
        let mut buf = vec![0.0; 2 * b];
        let mut tbuf = vec![0.0; 2 * b];
        for m in 0..b as i64 {
            for mp in 0..=m {
                fly.reset(m, mp);
                let mut tab = tables.source();
                tab.reset(m, mp);
                let l0 = m.max(mp) as usize;
                for l in l0..b {
                    let a = fly.row(l, &mut buf).to_vec();
                    let t = tab.row(l, &mut tbuf);
                    for (x, y) in a.iter().zip(t.iter()) {
                        // 1e-13, not 1e-14: the unfolded O half carries
                        // the O(B·ε) reconstruction term (module docs).
                        assert!((x - y).abs() < 1e-13);
                    }
                }
            }
        }
    }

    #[test]
    fn auto_storage_thresholds() {
        // Tiny budget forces on-the-fly; huge budget allows precompute.
        assert_eq!(WignerStorage::auto(64, 100), WignerStorage::OnTheFly);
        assert_eq!(
            WignerStorage::auto(8, 1 << 30),
            WignerStorage::Precomputed
        );
        // The fold doubles what fits: a budget of ~0.7× the pre-fold
        // footprint now selects Precomputed.
        let b = 32;
        let full_bytes: usize = (0..b)
            .flat_map(|m| (0..=m).map(move |_| (b - m) * 2 * b * 8))
            .sum();
        assert_eq!(
            WignerStorage::auto(b, full_bytes * 7 / 10),
            WignerStorage::Precomputed
        );
        assert!(WignerTables::storage_len(b) * 8 > full_bytes * 4 / 10);
    }

    #[test]
    fn disk_cache_roundtrips() {
        let b = 6;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        let path = std::env::temp_dir().join(format!("so3ft-wcache-{}.bin", std::process::id()));
        tables.save(&path).unwrap();
        let loaded = WignerTables::load(&path, b).unwrap();
        assert_eq!(tables.data, loaded.data);
        assert_eq!(tables.offsets, loaded.offsets);
        assert_eq!(tables.inv_cos, loaded.inv_cos);
        // Wrong bandwidth and corrupt magic are clean errors.
        assert!(WignerTables::load(&path, 7).is_err());
        std::fs::write(&path, b"JUNKJUNKJUNK").unwrap();
        assert!(WignerTables::load(&path, b).is_err());
        // The pre-fold v1 format is rejected with a rebuild hint.
        std::fs::write(&path, b"SO3W1old-format-payload").unwrap();
        let err = WignerTables::load(&path, b).unwrap_err();
        assert!(format!("{err}").contains("rebuild"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn partial_build_respects_budget_and_matches_full() {
        let b = 8;
        let angles = GridAngles::new(b).unwrap();
        let full = WignerTables::build(b, &angles.betas);
        assert!(full.is_complete());
        // Half the full footprint: some bases present, some streamed.
        let budget = WignerTables::full_bytes(b) / 2;
        let part = WignerTables::build_partial(b, &angles.betas, budget);
        assert!(!part.is_complete());
        assert!(part.bytes() <= budget, "{} > {budget}", part.bytes());
        let mut any_present = false;
        let mut any_absent = false;
        let mut buf_f = vec![0.0; 2 * b];
        let mut buf_p = vec![0.0; 2 * b];
        for m in 0..b as i64 {
            for mp in 0..=m {
                if part.has(m, mp) {
                    any_present = true;
                    // Present bases are bit-identical to the full build.
                    let l0 = m.max(mp) as usize;
                    for l in l0..b {
                        let want = full.row_into(m, mp, l, &mut buf_f).to_vec();
                        let got = part.row_into(m, mp, l, &mut buf_p).to_vec();
                        assert_eq!(got, want, "m={m} mp={mp} l={l}");
                    }
                } else {
                    any_absent = true;
                }
            }
        }
        assert!(any_present && any_absent, "budget should split the bases");
        // Non-canonical pairs are never "present".
        assert!(!part.has(0, 1));
        assert!(!part.has(1, -1));
        // A zero budget streams everything; a full budget streams nothing.
        let none = WignerTables::build_partial(b, &angles.betas, 0);
        assert!((0..b as i64).all(|m| (0..=m).all(|mp| !none.has(m, mp))));
        let all = WignerTables::build_partial(b, &angles.betas, WignerTables::full_bytes(b));
        assert!(all.is_complete());
    }

    #[test]
    fn save_refuses_partial_tables() {
        let b = 6;
        let angles = GridAngles::new(b).unwrap();
        let part = WignerTables::build_partial(b, &angles.betas, WignerTables::full_bytes(b) / 2);
        let path =
            std::env::temp_dir().join(format!("so3ft-wcache-part-{}.bin", std::process::id()));
        let err = part.save(&path).unwrap_err();
        assert!(format!("{err}").contains("partially materialized"), "{err}");
        assert!(!path.exists(), "partial save must not create the file");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn memory_scales_quartically() {
        // Sanity-check the paper's memory-criticality claim: storage
        // grows ~16× per bandwidth doubling (folding halves the constant,
        // not the exponent).
        let s32 = WignerTables::storage_len(32);
        let s64 = WignerTables::storage_len(64);
        let ratio = s64 as f64 / s32 as f64;
        assert!((ratio - 16.0).abs() < 2.0, "ratio {ratio}");
    }

    #[test]
    fn build_rejects_asymmetric_grid() {
        let b = 4;
        let betas: Vec<f64> = (0..2 * b).map(|j| 0.1 + 0.3 * j as f64).collect();
        let r = std::panic::catch_unwind(|| WignerTables::build(b, &betas));
        assert!(r.is_err());
    }
}

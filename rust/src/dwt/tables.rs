//! Wigner-d row sources: precomputed tables vs. on-the-fly recurrence.
//!
//! The paper's benchmark build precomputes the DWT matrices, exploiting
//! all seven symmetries "in the precomputation of the matrices using the
//! three-term recurrence relation". Symmetry-shared storage keeps only
//! the base pairs m ≥ m' ≥ 0 (≈⅛ of the full table set) — exactly what
//! the clusters need. At memory-critical bandwidths the same rows can be
//! streamed from the recurrence instead ([`OnTheFlySource`]), trading
//! ~2× arithmetic for O(B) instead of O(B⁴) memory.

use crate::so3::wigner::WignerRowStepper;

/// Abstract producer of base Wigner-d rows `d(l, m, m'; β_j)` for a fixed
/// base pair, consumed degree-by-degree (l ascending from the cluster's
/// l₀). `reset` rebinds the source to a new base pair.
pub trait WignerSource {
    fn reset(&mut self, m: i64, mp: i64);
    /// The row at degree `l`; rows must be requested with l strictly
    /// increasing between resets. `buf` (len 2B) may be used as backing
    /// storage; the returned slice is valid until the next call.
    fn row<'a>(&'a mut self, l: usize, buf: &'a mut [f64]) -> &'a [f64];
}

/// Streams rows from the three-term recurrence, never materializing a
/// table. ~zero memory; each cluster pays the recurrence (4 flops per
/// (l, j) point) once for all its members.
pub struct OnTheFlySource<'b> {
    betas: &'b [f64],
    stepper: Option<WignerRowStepper<f64>>,
    m: i64,
    mp: i64,
}

impl<'b> OnTheFlySource<'b> {
    pub fn new(betas: &'b [f64]) -> Self {
        Self {
            betas,
            stepper: None,
            m: 0,
            mp: 0,
        }
    }
}

impl WignerSource for OnTheFlySource<'_> {
    fn reset(&mut self, m: i64, mp: i64) {
        self.m = m;
        self.mp = mp;
        self.stepper = Some(WignerRowStepper::new(m, mp, self.betas));
    }

    fn row<'a>(&'a mut self, l: usize, _buf: &'a mut [f64]) -> &'a [f64] {
        let stepper = self.stepper.as_mut().expect("reset() before row()");
        debug_assert!(l >= stepper.l_min(), "row below l0");
        while stepper.current_l() < l {
            stepper.advance();
        }
        stepper.row()
    }
}

/// Precomputed symmetry-shared tables: rows for every base pair
/// m ≥ m' ≥ 0, packed contiguously.
#[derive(Debug, Clone)]
pub struct WignerTables {
    b: usize,
    /// Packed rows: for base (m, m'), degrees l₀..B−1, each row 2B long.
    data: Vec<f64>,
    /// Offset of base pair (m, m') in `data`.
    offsets: Vec<usize>,
}

/// Triangle index of a base pair m ≥ m' ≥ 0 (the paper's σ map, Eq. 7,
/// restricted to the canonical triangle).
#[inline]
pub fn base_index(m: i64, mp: i64) -> usize {
    debug_assert!(m >= mp && mp >= 0);
    (m * (m + 1) / 2 + mp) as usize
}

impl WignerTables {
    /// Total f64 slots needed for bandwidth `b` (diagnostics / memory
    /// planning: ~B⁴/3 · 2 entries).
    pub fn storage_len(b: usize) -> usize {
        let mut total = 0;
        for m in 0..b {
            for mp in 0..=m {
                let l0 = m.max(mp);
                total += (b - l0) * 2 * b;
            }
        }
        total
    }

    /// Build all base tables sequentially. (The parallel executor builds
    /// them per-cluster on first touch instead; this constructor is for
    /// the sequential transform and tests.)
    pub fn build(b: usize, betas: &[f64]) -> Self {
        assert_eq!(betas.len(), 2 * b);
        let n_bases = b * (b + 1) / 2;
        let mut offsets = vec![0usize; n_bases + 1];
        let mut total = 0usize;
        for m in 0..b as i64 {
            for mp in 0..=m {
                offsets[base_index(m, mp)] = total;
                let l0 = m.max(mp) as usize;
                total += (b - l0) * 2 * b;
            }
        }
        offsets[n_bases] = total;
        let mut data = vec![0.0f64; total];
        for m in 0..b as i64 {
            for mp in 0..=m {
                let off = offsets[base_index(m, mp)];
                let l0 = m.max(mp) as usize;
                let mut stepper: WignerRowStepper<f64> = WignerRowStepper::new(m, mp, betas);
                for (i, _l) in (l0..b).enumerate() {
                    let row = stepper.row();
                    data[off + i * 2 * b..off + (i + 1) * 2 * b].copy_from_slice(row);
                    stepper.advance();
                }
            }
        }
        Self { b, data, offsets }
    }

    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Approximate memory footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }

    /// Row for base pair (m, m') at degree l.
    #[inline]
    pub fn row(&self, m: i64, mp: i64, l: usize) -> &[f64] {
        let l0 = m.max(mp) as usize;
        debug_assert!(l >= l0 && l < self.b);
        let off = self.offsets[base_index(m, mp)] + (l - l0) * 2 * self.b;
        &self.data[off..off + 2 * self.b]
    }

    /// A [`WignerSource`] view over these tables (shared, cheap).
    pub fn source(&self) -> TableSource<'_> {
        TableSource {
            tables: self,
            m: 0,
            mp: 0,
        }
    }

    /// Persist to disk so the precomputation (the dominant setup cost at
    /// large B — the paper precomputes per run) is paid once per machine.
    /// Format: `SO3W1` magic, LE u64 bandwidth, LE u64 count, raw LE f64s.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::error::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"SO3W1")?;
        f.write_all(&(self.b as u64).to_le_bytes())?;
        f.write_all(&(self.data.len() as u64).to_le_bytes())?;
        for v in &self.data {
            f.write_all(&v.to_le_bytes())?;
        }
        f.flush()?;
        Ok(())
    }

    /// Load tables written by [`Self::save`]; validates magic, bandwidth
    /// and length.
    pub fn load(
        path: impl AsRef<std::path::Path>,
        expect_b: usize,
    ) -> crate::error::Result<Self> {
        use crate::error::Error;
        use std::io::Read;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 5];
        f.read_exact(&mut magic)?;
        if &magic != b"SO3W1" {
            return Err(Error::Runtime("wigner table cache: bad magic".into()));
        }
        let mut u = [0u8; 8];
        f.read_exact(&mut u)?;
        let b = u64::from_le_bytes(u) as usize;
        if b != expect_b {
            return Err(Error::Runtime(format!(
                "wigner table cache: bandwidth {b}, expected {expect_b}"
            )));
        }
        f.read_exact(&mut u)?;
        let len = u64::from_le_bytes(u) as usize;
        if len != Self::storage_len(b) {
            return Err(Error::Runtime("wigner table cache: bad length".into()));
        }
        let mut data = vec![0.0f64; len];
        let mut buf = [0u8; 8];
        for v in data.iter_mut() {
            f.read_exact(&mut buf)?;
            *v = f64::from_le_bytes(buf);
        }
        // Rebuild offsets (derived, not stored).
        let n_bases = b * (b + 1) / 2;
        let mut offsets = vec![0usize; n_bases + 1];
        let mut total = 0usize;
        for m in 0..b as i64 {
            for mp in 0..=m {
                offsets[base_index(m, mp)] = total;
                let l0 = m.max(mp) as usize;
                total += (b - l0) * 2 * b;
            }
        }
        offsets[n_bases] = total;
        Ok(Self { b, data, offsets })
    }
}

/// Table-backed row source.
pub struct TableSource<'t> {
    tables: &'t WignerTables,
    m: i64,
    mp: i64,
}

impl WignerSource for TableSource<'_> {
    fn reset(&mut self, m: i64, mp: i64) {
        debug_assert!(m >= mp && mp >= 0, "tables store canonical bases only");
        self.m = m;
        self.mp = mp;
    }

    fn row<'a>(&'a mut self, l: usize, _buf: &'a mut [f64]) -> &'a [f64] {
        self.tables.row(self.m, self.mp, l)
    }
}

/// Storage strategy selector used by the transform configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WignerStorage {
    /// Precompute symmetry-shared tables (paper's benchmarked setup).
    Precomputed,
    /// Stream rows from the recurrence (memory-critical bandwidths).
    OnTheFly,
}

impl WignerStorage {
    /// Pick a default: precompute while the tables stay under `budget`
    /// bytes, stream otherwise (the B=512 regime of the paper).
    pub fn auto(b: usize, budget: usize) -> Self {
        if WignerTables::storage_len(b) * 8 <= budget {
            WignerStorage::Precomputed
        } else {
            WignerStorage::OnTheFly
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::sampling::GridAngles;
    use crate::so3::wigner::d_single;

    #[test]
    fn tables_match_direct_evaluation() {
        let b = 8;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        for m in 0..b as i64 {
            for mp in 0..=m {
                let l0 = m.max(mp) as usize;
                for l in l0..b {
                    let row = tables.row(m, mp, l);
                    for (j, &bj) in angles.betas.iter().enumerate() {
                        let want = d_single(l, m, mp, bj);
                        assert!(
                            (row[j] - want).abs() < 1e-12,
                            "m={m} mp={mp} l={l} j={j}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn storage_len_matches_build() {
        for b in [1usize, 2, 5, 8] {
            let angles = GridAngles::new(b).unwrap();
            let tables = WignerTables::build(b, &angles.betas);
            assert_eq!(tables.data.len(), WignerTables::storage_len(b));
        }
    }

    #[test]
    fn base_index_is_triangular() {
        assert_eq!(base_index(0, 0), 0);
        assert_eq!(base_index(1, 0), 1);
        assert_eq!(base_index(1, 1), 2);
        assert_eq!(base_index(2, 0), 3);
        assert_eq!(base_index(3, 3), 9);
        // Bijective over the triangle.
        let mut seen = std::collections::HashSet::new();
        for m in 0..20i64 {
            for mp in 0..=m {
                assert!(seen.insert(base_index(m, mp)));
            }
        }
        assert_eq!(seen.len(), 20 * 21 / 2);
    }

    #[test]
    fn on_the_fly_source_matches_tables() {
        let b = 6;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        let mut fly = OnTheFlySource::new(&angles.betas);
        let mut buf = vec![0.0; 2 * b];
        for m in 0..b as i64 {
            for mp in 0..=m {
                fly.reset(m, mp);
                let l0 = m.max(mp) as usize;
                for l in l0..b {
                    let a = fly.row(l, &mut buf).to_vec();
                    let t = tables.row(m, mp, l);
                    for (x, y) in a.iter().zip(t.iter()) {
                        assert!((x - y).abs() < 1e-14);
                    }
                }
            }
        }
    }

    #[test]
    fn auto_storage_thresholds() {
        // Tiny budget forces on-the-fly; huge budget allows precompute.
        assert_eq!(WignerStorage::auto(64, 100), WignerStorage::OnTheFly);
        assert_eq!(
            WignerStorage::auto(8, 1 << 30),
            WignerStorage::Precomputed
        );
    }

    #[test]
    fn disk_cache_roundtrips() {
        let b = 6;
        let angles = GridAngles::new(b).unwrap();
        let tables = WignerTables::build(b, &angles.betas);
        let path = std::env::temp_dir().join(format!("so3ft-wcache-{}.bin", std::process::id()));
        tables.save(&path).unwrap();
        let loaded = WignerTables::load(&path, b).unwrap();
        assert_eq!(tables.data, loaded.data);
        assert_eq!(tables.offsets, loaded.offsets);
        // Wrong bandwidth and corrupt magic are clean errors.
        assert!(WignerTables::load(&path, 7).is_err());
        std::fs::write(&path, b"JUNKJUNKJUNK").unwrap();
        assert!(WignerTables::load(&path, b).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn memory_scales_quartically() {
        // Sanity-check the paper's memory-criticality claim: storage
        // grows ~16× per bandwidth doubling.
        let s32 = WignerTables::storage_len(32);
        let s64 = WignerTables::storage_len(64);
        let ratio = s64 as f64 / s32 as f64;
        assert!((ratio - 16.0).abs() < 2.0, "ratio {ratio}");
    }
}

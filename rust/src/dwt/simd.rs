//! Arch-specific micro-kernels for the folded DWT hot loops.
//!
//! Each helper here is the vector twin of one inner loop in
//! [`super::folded`]: the half-length complex·real dot, the
//! [`DEG_BLOCK`]-degree forward accumulator block, the blocked inverse
//! (u | v) update, and the two axpy shapes of the inverse parity /
//! source-fed paths. Dispatch is a plain match on a pre-resolved
//! [`SimdIsa`] — the scalar arms reproduce the `folded.rs` loops
//! *exactly* (same `mul_add` chains, same order), so `SimdPolicy::Scalar`
//! stays bit-identical to the pre-SIMD kernels.
//!
//! Lane layout: `Complex64` is `#[repr(C)] { re, im }` (pinned by the
//! `repr_c_interleave` test), so a 256-bit AVX2 register holds two
//! complexes `[re0, im0, re1, im1]` and a 128-bit NEON register holds
//! one. Real Wigner-row factors are duplicated across the (re, im)
//! sub-lanes; one FMA then advances both parts of the complex
//! accumulator. All loads are unaligned (`loadu`) — the 64-byte scratch
//! alignment from `util::AlignedVec` is a throughput bonus, never a
//! correctness requirement.
//!
//! The AVX2 dots split the sum into per-lane partial sums (reduced once
//! at the end), so they are not bit-identical to scalar — parity suites
//! pin agreement at ≤ 1e-12. The blocked inverse kernels preserve the
//! scalar FMA order per element and *are* bit-identical.

use crate::dwt::folded::DEG_BLOCK;
use crate::fft::Complex64;
use crate::simd::SimdIsa;

/// Per-degree accumulator block produced by [`forward_block`]:
/// the (E, O) half-contraction sums, real and imaginary parts, for
/// [`DEG_BLOCK`] consecutive degrees.
pub struct BlockAcc {
    /// Even-row real dot products, one per degree.
    pub er: [f64; DEG_BLOCK],
    /// Even-row imaginary dot products, one per degree.
    pub ei: [f64; DEG_BLOCK],
    /// Odd-row real dot products, one per degree.
    pub or: [f64; DEG_BLOCK],
    /// Odd-row imaginary dot products, one per degree.
    pub oi: [f64; DEG_BLOCK],
}

/// Half-length complex·real dot `Σ_j t[j]·r[j]`, dispatched on `isa`.
#[inline]
pub fn dot_half(isa: SimdIsa, t: &[Complex64], r: &[f64]) -> Complex64 {
    debug_assert_eq!(t.len(), r.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `isa == Avx2` only when AVX2+FMA was detected (or
        // asserted by a Force resolve), per `SimdPolicy::resolve`.
        SimdIsa::Avx2 => unsafe { avx2::dot_half(t, r) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdIsa::Neon => unsafe { neon::dot_half(t, r) },
        _ => dot_half_scalar(t, r),
    }
}

/// Forward [`DEG_BLOCK`]-degree register-blocked half-contractions:
/// for each degree `k`, accumulate `Σ_j tp[j]·e[k][j]` into
/// `(er[k], ei[k])` and `Σ_j tm[j]·o[k·b + j]` into `(or[k], oi[k])`,
/// where `b = tp.len()` and `o` is the packed O block.
#[inline]
pub fn forward_block(
    isa: SimdIsa,
    tp: &[Complex64],
    tm: &[Complex64],
    e: &[&[f64]; DEG_BLOCK],
    o: &[f64],
) -> BlockAcc {
    debug_assert_eq!(tp.len(), tm.len());
    debug_assert!(o.len() >= DEG_BLOCK * tp.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `dot_half`.
        SimdIsa::Avx2 => unsafe { avx2::forward_block(tp, tm, e, o) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdIsa::Neon => unsafe { neon::forward_block(tp, tm, e, o) },
        _ => forward_block_scalar(tp, tm, e, o),
    }
}

/// Inverse [`DEG_BLOCK`]-degree register-blocked (u | v) update:
/// `u[j] += Σ_k c[k]·e[k][j]`, `v[j] += Σ_k c[k]·o[k·b + j]` with
/// `b = u.len()`, preserving the scalar per-element FMA order (the
/// vector path is bit-identical to scalar).
#[inline]
pub fn inverse_block(
    isa: SimdIsa,
    u: &mut [Complex64],
    v: &mut [Complex64],
    c: &[Complex64; DEG_BLOCK],
    e: &[&[f64]; DEG_BLOCK],
    o: &[f64],
) {
    debug_assert_eq!(u.len(), v.len());
    debug_assert!(o.len() >= DEG_BLOCK * u.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `dot_half`.
        SimdIsa::Avx2 => unsafe { avx2::inverse_block(u, v, c, e, o) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdIsa::Neon => unsafe { neon::inverse_block(u, v, c, e, o) },
        _ => inverse_block_scalar(u, v, c, e, o),
    }
}

/// Paired axpy against one real row with two coefficients (the inverse
/// parity path): `u[j] += c·h[j]`, `v[j] += cs·h[j]`.
#[inline]
pub fn axpy_pair_coeffs(
    isa: SimdIsa,
    u: &mut [Complex64],
    v: &mut [Complex64],
    c: Complex64,
    cs: Complex64,
    h: &[f64],
) {
    debug_assert_eq!(u.len(), h.len());
    debug_assert_eq!(v.len(), h.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `dot_half`.
        SimdIsa::Avx2 => unsafe { avx2::axpy_pair_coeffs(u, v, c, cs, h) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdIsa::Neon => unsafe { neon::axpy_pair_coeffs(u, v, c, cs, h) },
        _ => {
            for j in 0..h.len() {
                u[j] += c.scale(h[j]);
                v[j] += cs.scale(h[j]);
            }
        }
    }
}

/// Paired axpy against two real rows with one coefficient (the inverse
/// source-fed / degree-tail path): `u[j] += c·e[j]`, `v[j] += c·o[j]`.
#[inline]
pub fn axpy_pair_rows(
    isa: SimdIsa,
    u: &mut [Complex64],
    v: &mut [Complex64],
    c: Complex64,
    e: &[f64],
    o: &[f64],
) {
    debug_assert_eq!(u.len(), e.len());
    debug_assert_eq!(v.len(), o.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `dot_half`.
        SimdIsa::Avx2 => unsafe { avx2::axpy_pair_rows(u, v, c, e, o) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdIsa::Neon => unsafe { neon::axpy_pair_rows(u, v, c, e, o) },
        _ => {
            for j in 0..e.len() {
                u[j] += c.scale(e[j]);
                v[j] += c.scale(o[j]);
            }
        }
    }
}

/// Scalar dot — byte-for-byte the loop `folded.rs` shipped before the
/// SIMD dispatch existed.
fn dot_half_scalar(t: &[Complex64], r: &[f64]) -> Complex64 {
    let mut re = 0.0f64;
    let mut im = 0.0f64;
    // lint: hot-loop-begin
    for (v, &x) in t.iter().zip(r.iter()) {
        re = v.re.mul_add(x, re);
        im = v.im.mul_add(x, im);
    }
    // lint: hot-loop-end
    Complex64::new(re, im)
}

/// Scalar forward block — the original 16-chain register-blocked loop.
fn forward_block_scalar(
    tp: &[Complex64],
    tm: &[Complex64],
    e: &[&[f64]; DEG_BLOCK],
    o: &[f64],
) -> BlockAcc {
    let b = tp.len();
    let mut er = [0.0f64; DEG_BLOCK];
    let mut ei = [0.0f64; DEG_BLOCK];
    let mut or = [0.0f64; DEG_BLOCK];
    let mut oi = [0.0f64; DEG_BLOCK];
    for j in 0..b {
        let pr = tp[j].re;
        let pi = tp[j].im;
        let qr = tm[j].re;
        let qi = tm[j].im;
        for k in 0..DEG_BLOCK {
            er[k] = pr.mul_add(e[k][j], er[k]);
            ei[k] = pi.mul_add(e[k][j], ei[k]);
            or[k] = qr.mul_add(o[k * b + j], or[k]);
            oi[k] = qi.mul_add(o[k * b + j], oi[k]);
        }
    }
    BlockAcc { er, ei, or, oi }
}

/// Scalar inverse block — the original blocked (u | v) update.
fn inverse_block_scalar(
    u: &mut [Complex64],
    v: &mut [Complex64],
    c: &[Complex64; DEG_BLOCK],
    e: &[&[f64]; DEG_BLOCK],
    o: &[f64],
) {
    let b = u.len();
    for j in 0..b {
        let mut ur = u[j].re;
        let mut ui = u[j].im;
        let mut vr = v[j].re;
        let mut vi = v[j].im;
        for k in 0..DEG_BLOCK {
            ur = c[k].re.mul_add(e[k][j], ur);
            ui = c[k].im.mul_add(e[k][j], ui);
            vr = c[k].re.mul_add(o[k * b + j], vr);
            vi = c[k].im.mul_add(o[k * b + j], vi);
        }
        u[j] = Complex64::new(ur, ui);
        v[j] = Complex64::new(vr, vi);
    }
}

// `unsafe_op_in_unsafe_fn` straddle: on the 1.75 MSRV every intrinsic
// call is an unsafe op, so the bodies below carry explicit `unsafe {}`
// blocks; on newer toolchains (target_feature 1.1) intrinsic calls
// inside a matching `#[target_feature]` fn are safe and those same
// blocks would trip `unused_unsafe` under `-D warnings`. Allow the
// lint so both toolchains stay warning-clean.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
mod avx2 {
    //! AVX2+FMA kernels: 4-wide f64 = two interleaved complexes per
    //! register. Callers guarantee AVX2+FMA support (dispatch only
    //! selects these behind a successful `SimdPolicy` resolve).

    use super::{BlockAcc, Complex64, DEG_BLOCK};
    use std::arch::x86_64::*;

    /// Duplicate two consecutive reals `[r0, r1]` across complex
    /// sub-lanes: `[r0, r0, r1, r1]`.
    ///
    /// # Safety
    /// Requires AVX2; `p` must be readable for two f64.
    #[inline(always)]
    unsafe fn dup2(p: *const f64) -> __m256d {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let lo = _mm256_castpd128_pd256(_mm_loadu_pd(p));
            _mm256_permute4x64_pd(lo, 0x50)
        }
    }

    /// Horizontal reduce of an interleaved accumulator to one complex.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline(always)]
    unsafe fn reduce(acc: __m256d) -> Complex64 {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let mut lanes = [0.0f64; 4];
            _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
            Complex64::new(lanes[0] + lanes[2], lanes[1] + lanes[3])
        }
    }

    /// # Safety
    /// Requires AVX2+FMA and `t.len() == r.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot_half(t: &[Complex64], r: &[f64]) -> Complex64 {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let n = t.len();
            let tp = t.as_ptr() as *const f64;
            let rp = r.as_ptr();
            let mut acc0 = _mm256_setzero_pd();
            let mut acc1 = _mm256_setzero_pd();
            let mut j = 0usize;
            while j + 4 <= n {
                let t0 = _mm256_loadu_pd(tp.add(2 * j));
                let t1 = _mm256_loadu_pd(tp.add(2 * j + 4));
                acc0 = _mm256_fmadd_pd(t0, dup2(rp.add(j)), acc0);
                acc1 = _mm256_fmadd_pd(t1, dup2(rp.add(j + 2)), acc1);
                j += 4;
            }
            if j + 2 <= n {
                let t0 = _mm256_loadu_pd(tp.add(2 * j));
                acc0 = _mm256_fmadd_pd(t0, dup2(rp.add(j)), acc0);
                j += 2;
            }
            let mut acc = reduce(_mm256_add_pd(acc0, acc1));
            if j < n {
                acc.re = t[j].re.mul_add(r[j], acc.re);
                acc.im = t[j].im.mul_add(r[j], acc.im);
            }
            acc
        }
    }

    /// # Safety
    /// Requires AVX2+FMA; `tp.len() == tm.len()`, each `e[k]` at least
    /// `tp.len()` long, `o.len() >= DEG_BLOCK * tp.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn forward_block(
        tp: &[Complex64],
        tm: &[Complex64],
        e: &[&[f64]; DEG_BLOCK],
        o: &[f64],
    ) -> BlockAcc {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = tp.len();
            let tpp = tp.as_ptr() as *const f64;
            let tmp = tm.as_ptr() as *const f64;
            let op = o.as_ptr();
            let mut acc_e = [_mm256_setzero_pd(); DEG_BLOCK];
            let mut acc_o = [_mm256_setzero_pd(); DEG_BLOCK];
            let mut j = 0usize;
            while j + 2 <= b {
                let tpv = _mm256_loadu_pd(tpp.add(2 * j));
                let tmv = _mm256_loadu_pd(tmp.add(2 * j));
                for k in 0..DEG_BLOCK {
                    acc_e[k] = _mm256_fmadd_pd(tpv, dup2(e[k].as_ptr().add(j)), acc_e[k]);
                    acc_o[k] = _mm256_fmadd_pd(tmv, dup2(op.add(k * b + j)), acc_o[k]);
                }
                j += 2;
            }
            let mut out = BlockAcc {
                er: [0.0; DEG_BLOCK],
                ei: [0.0; DEG_BLOCK],
                or: [0.0; DEG_BLOCK],
                oi: [0.0; DEG_BLOCK],
            };
            for k in 0..DEG_BLOCK {
                let ce = reduce(acc_e[k]);
                out.er[k] = ce.re;
                out.ei[k] = ce.im;
                let co = reduce(acc_o[k]);
                out.or[k] = co.re;
                out.oi[k] = co.im;
            }
            if j < b {
                let pr = tp[j].re;
                let pi = tp[j].im;
                let qr = tm[j].re;
                let qi = tm[j].im;
                for k in 0..DEG_BLOCK {
                    out.er[k] = pr.mul_add(e[k][j], out.er[k]);
                    out.ei[k] = pi.mul_add(e[k][j], out.ei[k]);
                    out.or[k] = qr.mul_add(o[k * b + j], out.or[k]);
                    out.oi[k] = qi.mul_add(o[k * b + j], out.oi[k]);
                }
            }
            out
        }
    }

    /// # Safety
    /// Requires AVX2+FMA; `u.len() == v.len()`, each `e[k]` at least
    /// `u.len()` long, `o.len() >= DEG_BLOCK * u.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn inverse_block(
        u: &mut [Complex64],
        v: &mut [Complex64],
        c: &[Complex64; DEG_BLOCK],
        e: &[&[f64]; DEG_BLOCK],
        o: &[f64],
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = u.len();
            let up = u.as_mut_ptr() as *mut f64;
            let vp = v.as_mut_ptr() as *mut f64;
            let op = o.as_ptr();
            let mut cv = [_mm256_setzero_pd(); DEG_BLOCK];
            for k in 0..DEG_BLOCK {
                cv[k] = _mm256_setr_pd(c[k].re, c[k].im, c[k].re, c[k].im);
            }
            let mut j = 0usize;
            while j + 2 <= b {
                let mut uv = _mm256_loadu_pd(up.add(2 * j));
                let mut vv = _mm256_loadu_pd(vp.add(2 * j));
                for k in 0..DEG_BLOCK {
                    uv = _mm256_fmadd_pd(cv[k], dup2(e[k].as_ptr().add(j)), uv);
                    vv = _mm256_fmadd_pd(cv[k], dup2(op.add(k * b + j)), vv);
                }
                _mm256_storeu_pd(up.add(2 * j), uv);
                _mm256_storeu_pd(vp.add(2 * j), vv);
                j += 2;
            }
            if j < b {
                let mut ur = u[j].re;
                let mut ui = u[j].im;
                let mut vr = v[j].re;
                let mut vi = v[j].im;
                for k in 0..DEG_BLOCK {
                    ur = c[k].re.mul_add(e[k][j], ur);
                    ui = c[k].im.mul_add(e[k][j], ui);
                    vr = c[k].re.mul_add(o[k * b + j], vr);
                    vi = c[k].im.mul_add(o[k * b + j], vi);
                }
                u[j] = Complex64::new(ur, ui);
                v[j] = Complex64::new(vr, vi);
            }
        }
    }

    /// # Safety
    /// Requires AVX2+FMA and `u.len() == v.len() == h.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy_pair_coeffs(
        u: &mut [Complex64],
        v: &mut [Complex64],
        c: Complex64,
        cs: Complex64,
        h: &[f64],
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = h.len();
            let up = u.as_mut_ptr() as *mut f64;
            let vp = v.as_mut_ptr() as *mut f64;
            let cv = _mm256_setr_pd(c.re, c.im, c.re, c.im);
            let csv = _mm256_setr_pd(cs.re, cs.im, cs.re, cs.im);
            let mut j = 0usize;
            while j + 2 <= b {
                let hd = dup2(h.as_ptr().add(j));
                let uv = _mm256_fmadd_pd(cv, hd, _mm256_loadu_pd(up.add(2 * j)));
                _mm256_storeu_pd(up.add(2 * j), uv);
                let vv = _mm256_fmadd_pd(csv, hd, _mm256_loadu_pd(vp.add(2 * j)));
                _mm256_storeu_pd(vp.add(2 * j), vv);
                j += 2;
            }
            if j < b {
                u[j] += c.scale(h[j]);
                v[j] += cs.scale(h[j]);
            }
        }
    }

    /// # Safety
    /// Requires AVX2+FMA and `u.len() == e.len()`, `v.len() == o.len()`,
    /// `e.len() == o.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy_pair_rows(
        u: &mut [Complex64],
        v: &mut [Complex64],
        c: Complex64,
        e: &[f64],
        o: &[f64],
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = e.len();
            let up = u.as_mut_ptr() as *mut f64;
            let vp = v.as_mut_ptr() as *mut f64;
            let cv = _mm256_setr_pd(c.re, c.im, c.re, c.im);
            let mut j = 0usize;
            while j + 2 <= b {
                let uv =
                    _mm256_fmadd_pd(cv, dup2(e.as_ptr().add(j)), _mm256_loadu_pd(up.add(2 * j)));
                _mm256_storeu_pd(up.add(2 * j), uv);
                let vv =
                    _mm256_fmadd_pd(cv, dup2(o.as_ptr().add(j)), _mm256_loadu_pd(vp.add(2 * j)));
                _mm256_storeu_pd(vp.add(2 * j), vv);
                j += 2;
            }
            if j < b {
                u[j] += c.scale(e[j]);
                v[j] += c.scale(o[j]);
            }
        }
    }
}

// `unsafe_op_in_unsafe_fn` straddle: on the 1.75 MSRV every intrinsic
// call is an unsafe op, so the bodies below carry explicit `unsafe {}`
// blocks; on newer toolchains (target_feature 1.1) intrinsic calls
// inside a matching `#[target_feature]` fn are safe and those same
// blocks would trip `unused_unsafe` under `-D warnings`. Allow the
// lint so both toolchains stay warning-clean.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
mod neon {
    //! NEON kernels: 2-wide f64 = one interleaved complex per register.
    //! NEON is baseline on aarch64, so these are unconditionally sound
    //! there; they keep the scalar accumulation order per element.

    use super::{BlockAcc, Complex64, DEG_BLOCK};
    use std::arch::aarch64::*;

    /// # Safety
    /// Requires `t.len() == r.len()` (NEON is baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_half(t: &[Complex64], r: &[f64]) -> Complex64 {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let n = t.len();
            let tp = t.as_ptr() as *const f64;
            let mut acc0 = vdupq_n_f64(0.0);
            let mut acc1 = vdupq_n_f64(0.0);
            let mut j = 0usize;
            while j + 2 <= n {
                acc0 = vfmaq_n_f64(acc0, vld1q_f64(tp.add(2 * j)), r[j]);
                acc1 = vfmaq_n_f64(acc1, vld1q_f64(tp.add(2 * j + 2)), r[j + 1]);
                j += 2;
            }
            let acc = vaddq_f64(acc0, acc1);
            let mut re = vgetq_lane_f64::<0>(acc);
            let mut im = vgetq_lane_f64::<1>(acc);
            if j < n {
                re = t[j].re.mul_add(r[j], re);
                im = t[j].im.mul_add(r[j], im);
            }
            Complex64::new(re, im)
        }
    }

    /// # Safety
    /// Same bounds contract as the dispatching `forward_block`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn forward_block(
        tp: &[Complex64],
        tm: &[Complex64],
        e: &[&[f64]; DEG_BLOCK],
        o: &[f64],
    ) -> BlockAcc {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = tp.len();
            let tpp = tp.as_ptr() as *const f64;
            let tmp = tm.as_ptr() as *const f64;
            let mut acc_e = [vdupq_n_f64(0.0); DEG_BLOCK];
            let mut acc_o = [vdupq_n_f64(0.0); DEG_BLOCK];
            for j in 0..b {
                let tpv = vld1q_f64(tpp.add(2 * j));
                let tmv = vld1q_f64(tmp.add(2 * j));
                for k in 0..DEG_BLOCK {
                    acc_e[k] = vfmaq_n_f64(acc_e[k], tpv, e[k][j]);
                    acc_o[k] = vfmaq_n_f64(acc_o[k], tmv, o[k * b + j]);
                }
            }
            let mut out = BlockAcc {
                er: [0.0; DEG_BLOCK],
                ei: [0.0; DEG_BLOCK],
                or: [0.0; DEG_BLOCK],
                oi: [0.0; DEG_BLOCK],
            };
            for k in 0..DEG_BLOCK {
                out.er[k] = vgetq_lane_f64::<0>(acc_e[k]);
                out.ei[k] = vgetq_lane_f64::<1>(acc_e[k]);
                out.or[k] = vgetq_lane_f64::<0>(acc_o[k]);
                out.oi[k] = vgetq_lane_f64::<1>(acc_o[k]);
            }
            out
        }
    }

    /// # Safety
    /// Same bounds contract as the dispatching `inverse_block`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn inverse_block(
        u: &mut [Complex64],
        v: &mut [Complex64],
        c: &[Complex64; DEG_BLOCK],
        e: &[&[f64]; DEG_BLOCK],
        o: &[f64],
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = u.len();
            let up = u.as_mut_ptr() as *mut f64;
            let vp = v.as_mut_ptr() as *mut f64;
            let mut cv = [vdupq_n_f64(0.0); DEG_BLOCK];
            for k in 0..DEG_BLOCK {
                cv[k] = vld1q_f64(&c[k] as *const Complex64 as *const f64);
            }
            for j in 0..b {
                let mut uv = vld1q_f64(up.add(2 * j));
                let mut vv = vld1q_f64(vp.add(2 * j));
                for k in 0..DEG_BLOCK {
                    uv = vfmaq_n_f64(uv, cv[k], e[k][j]);
                    vv = vfmaq_n_f64(vv, cv[k], o[k * b + j]);
                }
                vst1q_f64(up.add(2 * j), uv);
                vst1q_f64(vp.add(2 * j), vv);
            }
        }
    }

    /// # Safety
    /// Same bounds contract as the dispatching `axpy_pair_coeffs`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_pair_coeffs(
        u: &mut [Complex64],
        v: &mut [Complex64],
        c: Complex64,
        cs: Complex64,
        h: &[f64],
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = h.len();
            let up = u.as_mut_ptr() as *mut f64;
            let vp = v.as_mut_ptr() as *mut f64;
            let cv = vld1q_f64(&c as *const Complex64 as *const f64);
            let csv = vld1q_f64(&cs as *const Complex64 as *const f64);
            for j in 0..b {
                vst1q_f64(up.add(2 * j), vfmaq_n_f64(vld1q_f64(up.add(2 * j)), cv, h[j]));
                vst1q_f64(vp.add(2 * j), vfmaq_n_f64(vld1q_f64(vp.add(2 * j)), csv, h[j]));
            }
        }
    }

    /// # Safety
    /// Same bounds contract as the dispatching `axpy_pair_rows`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_pair_rows(
        u: &mut [Complex64],
        v: &mut [Complex64],
        c: Complex64,
        e: &[f64],
        o: &[f64],
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let b = e.len();
            let up = u.as_mut_ptr() as *mut f64;
            let vp = v.as_mut_ptr() as *mut f64;
            let cv = vld1q_f64(&c as *const Complex64 as *const f64);
            for j in 0..b {
                vst1q_f64(up.add(2 * j), vfmaq_n_f64(vld1q_f64(up.add(2 * j)), cv, e[j]));
                vst1q_f64(vp.add(2 * j), vfmaq_n_f64(vld1q_f64(vp.add(2 * j)), cv, o[j]));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256;
    use crate::simd::detected_isa;

    fn random_complex(n: usize, seed: u64) -> Vec<Complex64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect()
    }

    fn random_real(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n).map(|_| rng.next_signed()).collect()
    }

    // Odd lengths exercise every tail path; 1 and 2 the degenerate ones.
    const LENS: [usize; 6] = [1, 2, 3, 8, 13, 32];

    #[test]
    fn scalar_dispatch_is_the_scalar_kernel() {
        let t = random_complex(13, 1);
        let r = random_real(13, 2);
        let via_dispatch = dot_half(SimdIsa::Scalar, &t, &r);
        let direct = dot_half_scalar(&t, &r);
        assert_eq!(via_dispatch.re.to_bits(), direct.re.to_bits());
        assert_eq!(via_dispatch.im.to_bits(), direct.im.to_bits());
    }

    #[test]
    fn dot_half_matches_scalar() {
        let isa = detected_isa();
        for &n in &LENS {
            let t = random_complex(n, 10 + n as u64);
            let r = random_real(n, 20 + n as u64);
            let want = dot_half_scalar(&t, &r);
            let got = dot_half(isa, &t, &r);
            assert!((want - got).abs() < 1e-12, "n={n} {got} vs {want}");
        }
    }

    #[test]
    fn forward_block_matches_scalar() {
        let isa = detected_isa();
        for &b in &LENS {
            let tp = random_complex(b, 30 + b as u64);
            let tm = random_complex(b, 40 + b as u64);
            let rows: Vec<Vec<f64>> = (0..DEG_BLOCK)
                .map(|k| random_real(b, 50 + b as u64 + k as u64))
                .collect();
            let e = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
            let o = random_real(DEG_BLOCK * b, 60 + b as u64);
            let want = forward_block_scalar(&tp, &tm, &e, &o);
            let got = forward_block(isa, &tp, &tm, &e, &o);
            for k in 0..DEG_BLOCK {
                assert!((want.er[k] - got.er[k]).abs() < 1e-12, "b={b} k={k}");
                assert!((want.ei[k] - got.ei[k]).abs() < 1e-12, "b={b} k={k}");
                assert!((want.or[k] - got.or[k]).abs() < 1e-12, "b={b} k={k}");
                assert!((want.oi[k] - got.oi[k]).abs() < 1e-12, "b={b} k={k}");
            }
        }
    }

    #[test]
    fn inverse_block_matches_scalar() {
        let isa = detected_isa();
        for &b in &LENS {
            let mut u = random_complex(b, 70 + b as u64);
            let mut v = random_complex(b, 80 + b as u64);
            let mut u2 = u.clone();
            let mut v2 = v.clone();
            let cvec = random_complex(DEG_BLOCK, 90 + b as u64);
            let c = [cvec[0], cvec[1], cvec[2], cvec[3]];
            let rows: Vec<Vec<f64>> = (0..DEG_BLOCK)
                .map(|k| random_real(b, 100 + b as u64 + k as u64))
                .collect();
            let e = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
            let o = random_real(DEG_BLOCK * b, 110 + b as u64);
            inverse_block_scalar(&mut u, &mut v, &c, &e, &o);
            inverse_block(isa, &mut u2, &mut v2, &c, &e, &o);
            for j in 0..b {
                assert!((u[j] - u2[j]).abs() < 1e-12, "b={b} j={j}");
                assert!((v[j] - v2[j]).abs() < 1e-12, "b={b} j={j}");
            }
        }
    }

    #[test]
    fn axpy_pairs_match_scalar() {
        let isa = detected_isa();
        for &b in &LENS {
            let c = Complex64::new(0.3, -0.7);
            let cs = Complex64::new(-1.1, 0.2);
            let h = random_real(b, 120 + b as u64);
            let o = random_real(b, 130 + b as u64);
            let u0 = random_complex(b, 140 + b as u64);
            let v0 = random_complex(b, 150 + b as u64);

            let (mut u, mut v) = (u0.clone(), v0.clone());
            let (mut u2, mut v2) = (u0.clone(), v0.clone());
            axpy_pair_coeffs(SimdIsa::Scalar, &mut u, &mut v, c, cs, &h);
            axpy_pair_coeffs(isa, &mut u2, &mut v2, c, cs, &h);
            for j in 0..b {
                assert!((u[j] - u2[j]).abs() < 1e-12, "coeffs b={b} j={j}");
                assert!((v[j] - v2[j]).abs() < 1e-12, "coeffs b={b} j={j}");
            }

            let (mut u, mut v) = (u0.clone(), v0.clone());
            let (mut u2, mut v2) = (u0, v0);
            axpy_pair_rows(SimdIsa::Scalar, &mut u, &mut v, c, &h, &o);
            axpy_pair_rows(isa, &mut u2, &mut v2, c, &h, &o);
            for j in 0..b {
                assert!((u[j] - u2[j]).abs() < 1e-12, "rows b={b} j={j}");
                assert!((v[j] - v2[j]).abs() < 1e-12, "rows b={b} j={j}");
            }
        }
    }
}

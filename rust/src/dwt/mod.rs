//! The discrete Wigner transform (DWT) and its inverse — the FSOFT's
//! compute hot spot (paper Section 2.4).
//!
//! For one order pair (m, m') the forward DWT maps the 2B intermediate
//! values `S(m, m'; j)` to the B−l₀ coefficients
//!
//! `f°(l, m, m') = V(l) · Σ_j w_B(j) · d(l, m, m'; β_j) · S(m, m'; j)`,
//!
//! with `V(l) = (2l+1)/(8πB)`; the inverse DWT is the transpose (no
//! weights, no V):  `S(j; m, m') = Σ_l d(l, m, m'; β_j) · f°(l, m, m')`.
//!
//! Submodules:
//! * [`cluster`] — symmetry clusters: the ≤8 order pairs that share one
//!   Wigner-d evaluation via paper Eq. 3 (the paper's *communication /
//!   agglomeration* design), with the m=0 / m'=0 / m=m' special cases.
//! * [`kernels`] — the cluster-at-a-time forward/inverse kernels (matvec
//!   dataflow, f64 and double-double variants) — the measurable baseline.
//! * [`folded`] — the β-parity-folded, register-blocked kernels (the
//!   default dataflow): member vectors and Wigner rows fold over the
//!   reflection-symmetric β grid, halving table bytes/traffic and (for
//!   the m' = 0 parity clusters) FLOPs.
//! * [`clenshaw`] — the Clenshaw-recurrence dataflow (the paper's §5
//!   "next version" improvement, implemented here as an extension).
//! * [`tables`] — precomputed Wigner-d tables with symmetry-shared,
//!   β-parity-folded half-row storage (half the pre-fold bytes), or
//!   on-the-fly generation for memory-critical bandwidths.

pub mod clenshaw;
pub mod cluster;
pub mod folded;
pub mod kernels;
pub(crate) mod simd;
pub mod tables;

use crate::error::{Error, Result};
use crate::fft::Complex64;

/// Coefficient scale of the forward DWT: V(l) = (2l+1)/(8πB).
#[inline]
pub fn v_scale(l: usize, b: usize) -> f64 {
    (2 * l + 1) as f64 / (8.0 * std::f64::consts::PI * b as f64)
}

/// Which dataflow evaluates the DWT/iDWT.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DwtAlgorithm {
    /// Row-wise matrix–vector products against full Wigner-d rows (the
    /// paper's benchmarked version; vectorizes over the ≤8 cluster
    /// members). Kept as the measurable baseline for
    /// [`Self::MatVecFolded`], mirroring `FftEngine::Radix2Baseline`.
    MatVec,
    /// β-parity-folded, register-blocked matvec (the default): member
    /// vectors and Wigner rows are folded over the reflection-symmetric
    /// β grid (`dwt::folded`), halving the precomputed-table bytes and
    /// stream and — for the m' = 0 parity clusters — the FLOPs, with a
    /// 4-degree register-blocked micro-kernel on the table path.
    MatVecFolded,
    /// Clenshaw-recurrence dataflow (paper §5 outlook): no Wigner rows are
    /// materialized; the iDWT runs the classical Clenshaw downward
    /// recursion per β-node, the DWT its transposed (adjoint) form.
    Clenshaw,
}

/// Numerical precision of the DWT accumulation (paper §4 uses 80-bit
/// extended precision; we use double-double, see [`crate::xprec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE double accumulation.
    Double,
    /// Double-double (~31 significant digits) accumulation.
    Extended,
}

/// The intermediate S-matrix: `S(m, m'; j)` for m, m' ∈ {1−B, …, B−1},
/// stored `[m-index][m'-index][j]` with **contiguous j** — the layout the
/// DWT stage reads/writes linearly. The FFT stage produces/consumes the
/// per-slice layout, and an explicit transposition pass converts between
/// the two (the paper discusses exactly this transposition cost in §5).
#[derive(Debug, Clone)]
pub struct SMatrix {
    b: usize,
    data: Vec<Complex64>,
}

impl SMatrix {
    /// Number of distinct orders per axis: 2B−1.
    #[inline]
    pub fn orders(b: usize) -> usize {
        2 * b - 1
    }

    /// Zero-filled coefficient storage for bandwidth `b`.
    pub fn zeros(b: usize) -> Result<Self> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(b));
        }
        let o = Self::orders(b);
        Ok(Self {
            b,
            data: vec![Complex64::zero(); o * o * 2 * b],
        })
    }

    /// An index-only S-matrix: `vec_index`/`orders` work, but it holds no
    /// element storage (`len() == 0`), so `vec`/`vec_mut` on it would
    /// panic. Crate-internal by design — used only as the layout oracle
    /// the iDWT kernels consult, so plans don't pay for a second full
    /// S-matrix.
    pub(crate) fn layout_only(b: usize) -> Result<Self> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(b));
        }
        Ok(Self { b, data: Vec::new() })
    }

    /// Bandwidth B of this coefficient set.
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.b
    }

    /// Flat offset of the j-vector for orders (m, m').
    #[inline]
    pub fn vec_index(&self, m: i64, mp: i64) -> usize {
        let b = self.b as i64;
        debug_assert!(m.abs() < b && mp.abs() < b);
        let o = Self::orders(self.b) as i64;
        let mi = m + b - 1;
        let mpi = mp + b - 1;
        ((mi * o + mpi) * 2 * b) as usize
    }

    /// The j-vector S(m, m'; ·).
    #[inline]
    pub fn vec(&self, m: i64, mp: i64) -> &[Complex64] {
        let i = self.vec_index(m, mp);
        &self.data[i..i + 2 * self.b]
    }

    /// Mutable j-vector for the order pair `(m, mp)`.
    #[inline]
    pub fn vec_mut(&mut self, m: i64, mp: i64) -> &mut [Complex64] {
        let i = self.vec_index(m, mp);
        &mut self.data[i..i + 2 * self.b]
    }

    /// Flat coefficient storage.
    pub fn as_slice(&self) -> &[Complex64] {
        &self.data
    }

    /// Flat mutable coefficient storage.
    pub fn as_mut_slice(&mut self) -> &mut [Complex64] {
        &mut self.data
    }

    /// Total number of stored coefficients.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the storage is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Gather from per-slice FFT output: `self[m][m'][j] = slice_j[u][v]`
    /// with u = m mod 2B, v = m' mod 2B. `slices` is the β-major grid
    /// buffer (each slice a 2B×2B row-major matrix).
    pub fn gather_from_slices(&mut self, slices: &[Complex64]) {
        let b = self.b as i64;
        let n = 2 * self.b;
        assert_eq!(slices.len(), n * n * n);
        for m in (1 - b)..b {
            let u = m.rem_euclid(n as i64) as usize;
            for mp in (1 - b)..b {
                let v = mp.rem_euclid(n as i64) as usize;
                let base = self.vec_index(m, mp);
                for j in 0..n {
                    self.data[base + j] = slices[(j * n + u) * n + v];
                }
            }
        }
    }

    /// Scatter into per-slice buffers for the inverse FFT stage, zeroing
    /// the unused Nyquist row/column (|order| = B is not part of the
    /// spectrum).
    pub fn scatter_to_slices(&self, slices: &mut [Complex64]) {
        let b = self.b as i64;
        let n = 2 * self.b;
        assert_eq!(slices.len(), n * n * n);
        for v in slices.iter_mut() {
            *v = Complex64::zero();
        }
        for m in (1 - b)..b {
            let u = m.rem_euclid(n as i64) as usize;
            for mp in (1 - b)..b {
                let v = mp.rem_euclid(n as i64) as usize;
                let base = self.vec_index(m, mp);
                for j in 0..n {
                    slices[(j * n + u) * n + v] = self.data[base + j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smatrix_indexing_disjoint_and_total() {
        let b = 3;
        let s = SMatrix::zeros(b).unwrap();
        let o = SMatrix::orders(b);
        assert_eq!(s.len(), o * o * 2 * b);
        let mut seen = vec![false; s.len()];
        for m in -2i64..=2 {
            for mp in -2i64..=2 {
                let i = s.vec_index(m, mp);
                for j in 0..2 * b {
                    assert!(!seen[i + j]);
                    seen[i + j] = true;
                }
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let b = 4;
        let n = 2 * b;
        let mut smat = SMatrix::zeros(b).unwrap();
        // Fill S with distinct values, scatter to slices, gather back.
        for (idx, v) in smat.as_mut_slice().iter_mut().enumerate() {
            *v = Complex64::new(idx as f64, -(idx as f64));
        }
        let reference = smat.clone();
        let mut slices = vec![Complex64::zero(); n * n * n];
        smat.scatter_to_slices(&mut slices);
        let mut back = SMatrix::zeros(b).unwrap();
        back.gather_from_slices(&slices);
        for (a, c) in reference.as_slice().iter().zip(back.as_slice()) {
            assert_eq!(*a, *c);
        }
    }

    #[test]
    fn scatter_zeroes_nyquist_bins() {
        let b = 2;
        let n = 2 * b;
        let mut smat = SMatrix::zeros(b).unwrap();
        for v in smat.as_mut_slice().iter_mut() {
            *v = Complex64::one();
        }
        let mut slices = vec![Complex64::new(9.0, 9.0); n * n * n];
        smat.scatter_to_slices(&mut slices);
        // Frequency u = B (here 2) is the unused Nyquist row: stays zero.
        for j in 0..n {
            for v in 0..n {
                assert_eq!(slices[(j * n + b) * n + v], Complex64::zero());
            }
            for u in 0..n {
                assert_eq!(slices[(j * n + u) * n + b], Complex64::zero());
            }
        }
    }

    #[test]
    fn layout_only_indexes_without_storage() {
        let b = 4usize;
        let layout = SMatrix::layout_only(b).unwrap();
        let full = SMatrix::zeros(b).unwrap();
        assert_eq!(layout.len(), 0);
        assert_eq!(layout.bandwidth(), b);
        for m in (1 - b as i64)..b as i64 {
            for mp in (1 - b as i64)..b as i64 {
                assert_eq!(layout.vec_index(m, mp), full.vec_index(m, mp));
            }
        }
        assert!(SMatrix::layout_only(0).is_err());
    }

    #[test]
    fn v_scale_formula() {
        let b = 8;
        assert!((v_scale(0, b) - 1.0 / (8.0 * std::f64::consts::PI * 8.0)).abs() < 1e-18);
        assert!(
            (v_scale(5, b) - 11.0 / (8.0 * std::f64::consts::PI * 8.0)).abs() < 1e-16
        );
    }
}

//! Clenshaw-recurrence DWT/iDWT dataflow — the faster DWT the paper's §5
//! announces for "the next version of our software", built here as a
//! first-class extension.
//!
//! Using the three-term recurrence `d_{l+1} = α_l(x)·d_l − β_l·d_{l−1}`
//! (x = cosβ, coefficients from [`crate::so3::wigner::step_coeffs`]):
//!
//! * **iDWT** evaluates `S(x_j) = Σ_l c_l d_l(x_j)` by the classical
//!   downward Clenshaw recursion — 3 fused ops per term, no Wigner rows
//!   in memory at all.
//! * **DWT** runs the transposed (adjoint) dataflow: per β-node an upward
//!   scalar recurrence generates d_l(x_j) and scatters
//!   `c_l += t_j · d_l(x_j)` — the adjoint Clenshaw algorithm.
//!
//! Both support the symmetry clusters: recurrence coefficients α, β are
//! shared by all members; reflected members read/write through the
//! mirrored node index; the l-alternating signs are folded into the
//! member coefficients.

use crate::dwt::cluster::Cluster;
use crate::dwt::{v_scale, SMatrix};
use crate::fft::Complex64;
use crate::so3::coeffs;
use crate::so3::wigner::{d_seed, step_coeffs};
use crate::util::SyncUnsafeSlice;

/// Precomputed per-degree recurrence coefficients for a base pair.
#[derive(Debug, Clone)]
pub struct ClenshawCoeffs {
    /// l₀ of the base pair.
    pub l0: usize,
    /// (a1, a2, a3) for steps l = max(l0,1) … B−2 (step l → l+1), indexed
    /// by l − l0; the l = 0 step (only for l0 = 0) is the special
    /// `d₁ = x·d₀`.
    pub steps: Vec<(f64, f64, f64)>,
}

impl ClenshawCoeffs {
    /// Coefficients for base orders m ≥ m' ≥ 0 up to bandwidth b.
    pub fn new(b: usize, m: i64, mp: i64) -> Self {
        debug_assert!(m >= mp && mp >= 0);
        let l0 = m.max(mp) as usize;
        let mut steps = Vec::with_capacity(b.saturating_sub(l0));
        for l in l0..b.saturating_sub(1) {
            if l == 0 {
                // d₁ = x·d₀ (m = m' = 0 only).
                steps.push((1.0, 0.0, 0.0));
            } else {
                let s = step_coeffs(l, m, mp);
                steps.push((s.a1, s.a2, s.a3));
            }
        }
        Self { l0, steps }
    }

    /// α_l(x) = a1·x + a2 for step l (absolute degree).
    #[inline]
    fn alpha(&self, l: usize, x: f64) -> f64 {
        let (a1, a2, _) = self.steps[l - self.l0];
        a1 * x + a2
    }

    /// β_l for step l (absolute degree).
    #[inline]
    fn beta(&self, l: usize) -> f64 {
        self.steps[l - self.l0].2
    }
}

/// Inverse DWT for one cluster via downward Clenshaw.
///
/// Same I/O contract as [`crate::dwt::kernels::inverse_cluster`].
#[allow(clippy::too_many_arguments)]
pub fn inverse_cluster_clenshaw(
    b: usize,
    cluster: &Cluster,
    betas: &[f64],
    coeff_data: &[Complex64],
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
    smat_layout: &SMatrix,
    member_coeff_buf: &mut Vec<Complex64>,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nl = b - l0;
    let cc = ClenshawCoeffs::new(b, cluster.m, cluster.mp);
    for member in &cluster.members {
        // Fold the member sign into its coefficient vector ĉ_l.
        member_coeff_buf.clear();
        member_coeff_buf.extend((l0..b).map(|l| {
            coeff_data[coeffs::flat_index(l, member.m, member.mp)].scale(member.sign(l))
        }));
        let base = smat_layout.vec_index(member.m, member.mp);
        for j in 0..n {
            // Output node j of this member reads base node `src`.
            let src = if member.reflected { n - 1 - j } else { j };
            let x = betas[src].cos();
            // Downward Clenshaw: y_l = ĉ_l + α_l(x)·y_{l+1} − β_{l+1}·y_{l+2}.
            let mut y1 = Complex64::zero();
            let mut y2 = Complex64::zero();
            for li in (0..nl).rev() {
                let l = l0 + li;
                let mut y0 = member_coeff_buf[li];
                if l + 1 < b {
                    y0 += y1.scale(cc.alpha(l, x));
                }
                if l + 2 < b {
                    y0 -= y2.scale(cc.beta(l + 1));
                }
                y2 = y1;
                y1 = y0;
            }
            let value = y1.scale(d_seed(cluster.m.max(cluster.mp), cluster.m.min(cluster.mp), betas[src]));
            // SAFETY: each (μ, μ') j-vector belongs to exactly one cluster.
            unsafe { smat_out.write(base + j, value) };
        }
    }
}

/// Forward DWT for one cluster via the adjoint-Clenshaw (j-outer) dataflow.
///
/// Same I/O contract as [`crate::dwt::kernels::forward_cluster`]; `acc`
/// is caller scratch of length ≥ (B−l₀)·members.
#[allow(clippy::too_many_arguments)]
pub fn forward_cluster_clenshaw(
    b: usize,
    cluster: &Cluster,
    betas: &[f64],
    weights: &[f64],
    smat: &SMatrix,
    out: &SyncUnsafeSlice<'_, Complex64>,
    acc: &mut Vec<Complex64>,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nl = b - l0;
    let nm = cluster.members.len();
    let cc = ClenshawCoeffs::new(b, cluster.m, cluster.mp);
    acc.clear();
    acc.resize(nl * nm, Complex64::zero());
    // Member input vectors t (weighted, reversed for reflected members).
    let member_vecs: Vec<&[Complex64]> = cluster
        .members
        .iter()
        .map(|mem| smat.vec(mem.m, mem.mp))
        .collect();
    for j in 0..n {
        let x = betas[j].cos();
        // Upward scalar recurrence for the base pair at node j.
        let mut d_prev = 0.0f64;
        let mut d_cur = d_seed(cluster.m.max(cluster.mp), cluster.m.min(cluster.mp), betas[j]);
        for li in 0..nl {
            let l = l0 + li;
            for (mi, member) in cluster.members.iter().enumerate() {
                // Forward: c_member(l) = Σ_j d_l(x_j) · t_member[rev? j].
                let src = if member.reflected { n - 1 - j } else { j };
                let t = member_vecs[mi][src].scale(weights[src]);
                acc[li * nm + mi] += t.scale(d_cur);
            }
            if li + 1 < nl {
                let next = if l == 0 {
                    x * d_cur
                } else {
                    cc.alpha(l, x) * d_cur - cc.beta(l) * d_prev
                };
                d_prev = d_cur;
                d_cur = next;
            }
        }
    }
    // Apply V(l) and the member signs, write out.
    for li in 0..nl {
        let l = l0 + li;
        let vs = v_scale(l, b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let value = acc[li * nm + mi].scale(vs * member.sign(l));
            let idx = coeffs::flat_index(l, member.m, member.mp);
            // SAFETY: (l, μ, μ') triples are cluster-exclusive.
            unsafe { out.write(idx, value) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dwt::kernels::{forward_cluster, inverse_cluster, DwtScratch};
    use crate::dwt::tables::OnTheFlySource;
    use crate::prng::Xoshiro256;
    use crate::so3::coeffs::So3Coeffs;
    use crate::so3::quadrature;
    use crate::so3::sampling::GridAngles;

    fn random_smat(b: usize, seed: u64) -> SMatrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut smat = SMatrix::zeros(b).unwrap();
        for v in smat.as_mut_slice().iter_mut() {
            *v = Complex64::new(rng.next_signed(), rng.next_signed());
        }
        smat
    }

    #[test]
    fn forward_clenshaw_matches_matvec() {
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 13);
        let nco = crate::so3::coeffs::coeff_count(b);
        let mut out_mv = vec![Complex64::zero(); nco];
        let mut out_cl = vec![Complex64::zero(); nco];
        let mut scratch = DwtScratch::new(b);
        let mut acc = Vec::new();
        for m in 0..b as i64 {
            for mp in 0..=m {
                let cluster = Cluster::symmetric(m, mp);
                {
                    let shared = SyncUnsafeSlice::new(&mut out_mv);
                    let mut src = OnTheFlySource::new(&angles.betas);
                    forward_cluster(
                        b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch,
                    );
                }
                {
                    let shared = SyncUnsafeSlice::new(&mut out_cl);
                    forward_cluster_clenshaw(
                        b, &cluster, &angles.betas, &weights, &smat, &shared, &mut acc,
                    );
                }
            }
        }
        for (i, (a, c)) in out_mv.iter().zip(out_cl.iter()).enumerate() {
            assert!((*a - *c).abs() < 1e-12, "coeff {i}: {a} vs {c}");
        }
    }

    #[test]
    fn inverse_clenshaw_matches_matvec() {
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let coeffs_in = So3Coeffs::random(b, 23);
        let mut smat_mv = SMatrix::zeros(b).unwrap();
        let mut smat_cl = SMatrix::zeros(b).unwrap();
        let layout = SMatrix::zeros(b).unwrap();
        let mut scratch = DwtScratch::new(b);
        let mut buf = Vec::new();
        for m in 0..b as i64 {
            for mp in 0..=m {
                let cluster = Cluster::symmetric(m, mp);
                {
                    let shared = SyncUnsafeSlice::new(smat_mv.as_mut_slice());
                    let mut src = OnTheFlySource::new(&angles.betas);
                    inverse_cluster(
                        b,
                        &cluster,
                        &mut src,
                        coeffs_in.as_slice(),
                        &shared,
                        &layout,
                        &mut scratch,
                    );
                }
                {
                    let shared = SyncUnsafeSlice::new(smat_cl.as_mut_slice());
                    inverse_cluster_clenshaw(
                        b,
                        &cluster,
                        &angles.betas,
                        coeffs_in.as_slice(),
                        &shared,
                        &layout,
                        &mut buf,
                    );
                }
            }
        }
        for (a, c) in smat_mv.as_slice().iter().zip(smat_cl.as_slice()) {
            assert!((*a - *c).abs() < 1e-11, "{a} vs {c}");
        }
    }

    #[test]
    fn clenshaw_coeffs_reproduce_recurrence() {
        // Stepping with (α, β) from ClenshawCoeffs must equal the stepper.
        use crate::so3::wigner::WignerRowStepper;
        let b = 10usize;
        let angles = GridAngles::new(b).unwrap();
        for (m, mp) in [(0i64, 0i64), (2, 1), (4, 4), (6, 0)] {
            let cc = ClenshawCoeffs::new(b, m, mp);
            let l0 = cc.l0;
            for (j, &bj) in angles.betas.iter().enumerate().take(4) {
                let x = bj.cos();
                let mut d_prev = 0.0;
                let mut d_cur = d_seed(m.max(mp), m.min(mp), bj);
                let mut st: WignerRowStepper<f64> = WignerRowStepper::new(m, mp, &angles.betas);
                for l in l0..b {
                    assert!(
                        (d_cur - st.row()[j]).abs() < 1e-12,
                        "m={m} mp={mp} l={l} j={j}"
                    );
                    if l + 1 < b {
                        let next = if l == 0 {
                            x * d_cur
                        } else {
                            cc.alpha(l, x) * d_cur - cc.beta(l) * d_prev
                        };
                        d_prev = d_cur;
                        d_cur = next;
                        st.advance();
                    }
                }
            }
        }
    }
}

//! β-parity-folded, register-blocked DWT/iDWT kernels
//! (`DwtAlgorithm::MatVecFolded` — the default dataflow).
//!
//! The K&R β grid is reflection-symmetric (π − β_j = β_{2B−1−j}), so
//! every contraction over 2B nodes folds into two half-contractions over
//! j < B against the symmetric/antisymmetric row halves
//! `E_l[j] = D_l[j] + D_l[2B−1−j]`, `O_l[j] = D_l[j] − D_l[2B−1−j]`:
//!
//! `Σ_j t[j]·D_l[j] = ½ Σ_{j<B} (t⁺[j]·E_l[j] + t⁻[j]·O_l[j])`
//!
//! with `t±[j] = t[j] ± t[2B−1−j]` folded **once per cluster** (the
//! reflected-member j-reversal of the matvec kernels disappears into the
//! fold — a reflected member only flips the sign of its O term). What
//! the fold buys, per cluster shape:
//!
//! * **Parity clusters (base m' = 0, ≤4 members, all direct).** The rows
//!   have exact β-parity σ(l) = σ₀·(−1)^l ([`Cluster::beta_parity`]), so
//!   one half-contraction vanishes: each member contracts only t⁺ (even
//!   σ) or t⁻ (odd σ) against the stored half row — **half the FLOPs and
//!   half the table traffic**.
//! * **General clusters.** Both halves carry information (the MAC count
//!   is invariant — the fold is an orthogonal basis change), but the
//!   folded tables store only E (O is reconstructed from the recurrence,
//!   amortized over all ≤8 members), **halving the table stream**, and
//!   the register-blocked micro-kernel contracts [`DEG_BLOCK`] degrees
//!   per pass of t± — quartering the member-vector traffic the per-`l`
//!   re-scan of the matvec kernels pays.
//!
//! The `matvec` kernels in [`super::kernels`] remain the measurable
//! baseline (mirroring `FftEngine::Radix2Baseline`). Agreement ≤ 1e-12
//! in both directions, both precisions, and both Wigner sources is
//! pinned by `rust/tests/dwt_parity.rs` and the module tests below.

use crate::dwt::cluster::Cluster;
use crate::dwt::kernels::DwtScratch;
use crate::dwt::simd as dsimd;
use crate::dwt::tables::{WignerSource, WignerTables};
use crate::dwt::{v_scale, SMatrix};
use crate::fft::Complex64;
use crate::simd::SimdIsa;
use crate::so3::coeffs;
use crate::util::{parity_sign, SyncUnsafeSlice};
use crate::xprec::DdComplex;

/// Degrees contracted per register-blocked pass of the table kernels.
pub const DEG_BLOCK: usize = 4;

/// Fold the weighted member vectors into (t⁺ | t⁻) half-pairs, overlaid
/// on `scratch.t`: member `mi` owns `t[mi·2B .. mi·2B+B)` = t⁺ and
/// `t[mi·2B+B .. (mi+1)·2B)` = t⁻. No member vector is ever reversed —
/// reflection is a sign on the t⁻ contraction.
fn fold_weighted_members(
    b: usize,
    cluster: &Cluster,
    weights: &[f64],
    smat: &SMatrix,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    for (mi, member) in cluster.members.iter().enumerate() {
        let s = smat.vec(member.m, member.mp);
        let t = &mut scratch.t[mi * n..(mi + 1) * n];
        let (tp, tm) = t.split_at_mut(b);
        for j in 0..b {
            let lo = s[j].scale(weights[j]);
            let hi = s[n - 1 - j].scale(weights[n - 1 - j]);
            tp[j] = lo + hi;
            tm[j] = lo - hi;
        }
    }
}

/// Fold a full 2B-node row into its symmetric/antisymmetric halves:
/// `fold[j] = row[j] + row[2B−1−j]`, `fold[B+j] = row[j] − row[2B−1−j]`
/// for j < B (`fold.len() == 2B`).
#[inline]
fn fold_row(b: usize, row: &[f64], fold: &mut [f64]) {
    let n = 2 * b;
    for j in 0..b {
        fold[j] = row[j] + row[n - 1 - j];
        fold[b + j] = row[j] - row[n - 1 - j];
    }
}

/// Forward DWT for one cluster, folded, fed by a generic [`WignerSource`]
/// (the on-the-fly path, non-canonical singleton clusters, and the
/// extended-precision variants' double sibling). Rows are produced in
/// full and folded per degree; exactness does not depend on any row
/// parity, so this kernel serves every cluster shape.
///
/// # Safety contract
/// Same as [`super::kernels::forward_cluster`]: `out` writes are
/// cluster-exclusive (l, μ, μ') triples.
#[allow(clippy::too_many_arguments)]
pub fn forward_cluster_folded(
    b: usize,
    isa: SimdIsa,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    weights: &[f64],
    smat: &SMatrix,
    out: &SyncUnsafeSlice<'_, Complex64>,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    fold_weighted_members(b, cluster, weights, smat, scratch);
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        {
            let row = source.row(l, &mut scratch.row);
            fold_row(b, row, &mut scratch.fold[..n]);
        }
        let (e, o) = scratch.fold[..n].split_at(b);
        let vs = v_scale(l, b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let t = &scratch.t[mi * n..(mi + 1) * n];
            let acc_e = dsimd::dot_half(isa, &t[..b], e);
            let acc_o = dsimd::dot_half(isa, &t[b..], o);
            let acc = if member.reflected {
                acc_e - acc_o
            } else {
                acc_e + acc_o
            };
            let value = acc.scale(0.5 * vs * member.sign(l));
            let idx = coeffs::flat_index(l, member.m, member.mp);
            // SAFETY: (l, μ, μ') triples are cluster-exclusive.
            unsafe { out.write(idx, value) };
        }
    }
}

/// Forward DWT for one canonical cluster against the folded tables — the
/// hot path. Parity clusters contract one σ-selected half per degree
/// (half FLOPs); general clusters run the [`DEG_BLOCK`]-degree
/// register-blocked micro-kernel over zero-copy E slices and a
/// reconstructed O block.
#[allow(clippy::too_many_arguments)]
pub fn forward_cluster_folded_tables(
    b: usize,
    isa: SimdIsa,
    cluster: &Cluster,
    tables: &WignerTables,
    weights: &[f64],
    smat: &SMatrix,
    out: &SyncUnsafeSlice<'_, Complex64>,
    scratch: &mut DwtScratch,
) {
    debug_assert!(cluster.m >= cluster.mp && cluster.mp >= 0);
    debug_assert_eq!(tables.bandwidth(), b);
    let n = 2 * b;
    let l0 = cluster.l_min();
    fold_weighted_members(b, cluster, weights, smat, scratch);

    if let Some(sigma0) = cluster.beta_parity() {
        // Parity fast path: half the FLOPs — one half-dot per member
        // per degree, selected by σ(l) = σ₀·(−1)^l. No ½: the half row
        // is the literal row, not a folded sum.
        for l in l0..b {
            let h = tables.half_row(cluster.m, l);
            let even = sigma0 * parity_sign(l as i64) > 0.0;
            let vs = v_scale(l, b);
            for (mi, member) in cluster.members.iter().enumerate() {
                debug_assert!(!member.reflected, "parity clusters are all-direct");
                let t = &scratch.t[mi * n..(mi + 1) * n];
                let acc = if even {
                    dsimd::dot_half(isa, &t[..b], h)
                } else {
                    dsimd::dot_half(isa, &t[b..], h)
                };
                let value = acc.scale(vs * member.sign(l));
                let idx = coeffs::flat_index(l, member.m, member.mp);
                // SAFETY: (l, μ, μ') triples are cluster-exclusive.
                unsafe { out.write(idx, value) };
            }
        }
        return;
    }

    if scratch.oblock.len() < DEG_BLOCK * b {
        scratch.oblock.resize(DEG_BLOCK * b, 0.0);
    }
    let mut l = l0;
    // lint: hot-loop-begin
    while l < b {
        let nb = DEG_BLOCK.min(b - l);
        for k in 0..nb {
            tables.recon_o_into(
                cluster.m,
                cluster.mp,
                l + k,
                &mut scratch.oblock[k * b..(k + 1) * b],
            );
        }
        if nb == DEG_BLOCK {
            let e = [
                tables.e_row(cluster.m, cluster.mp, l),
                tables.e_row(cluster.m, cluster.mp, l + 1),
                tables.e_row(cluster.m, cluster.mp, l + 2),
                tables.e_row(cluster.m, cluster.mp, l + 3),
            ];
            let o = &scratch.oblock;
            for (mi, member) in cluster.members.iter().enumerate() {
                let t = &scratch.t[mi * n..(mi + 1) * n];
                let (tp, tm) = t.split_at(b);
                // 4 degrees × (E, O) × (re, im) = 16 FMA chains; t± is
                // loaded once per four degrees instead of re-scanned
                // per degree. The chains live in `dwt::simd` behind the
                // ISA dispatch.
                let acc4 = dsimd::forward_block(isa, tp, tm, &e, o);
                for k in 0..DEG_BLOCK {
                    let lk = l + k;
                    let acc = if member.reflected {
                        Complex64::new(acc4.er[k] - acc4.or[k], acc4.ei[k] - acc4.oi[k])
                    } else {
                        Complex64::new(acc4.er[k] + acc4.or[k], acc4.ei[k] + acc4.oi[k])
                    };
                    let value = acc.scale(0.5 * v_scale(lk, b) * member.sign(lk));
                    let idx = coeffs::flat_index(lk, member.m, member.mp);
                    // SAFETY: (l, μ, μ') triples are cluster-exclusive.
                    unsafe { out.write(idx, value) };
                }
            }
        } else {
            for k in 0..nb {
                let lk = l + k;
                let e = tables.e_row(cluster.m, cluster.mp, lk);
                let o = &scratch.oblock[k * b..(k + 1) * b];
                let vs = v_scale(lk, b);
                for (mi, member) in cluster.members.iter().enumerate() {
                    let t = &scratch.t[mi * n..(mi + 1) * n];
                    let acc_e = dsimd::dot_half(isa, &t[..b], e);
                    let acc_o = dsimd::dot_half(isa, &t[b..], o);
                    let acc = if member.reflected {
                        acc_e - acc_o
                    } else {
                        acc_e + acc_o
                    };
                    let value = acc.scale(0.5 * vs * member.sign(lk));
                    let idx = coeffs::flat_index(lk, member.m, member.mp);
                    // SAFETY: (l, μ, μ') triples are cluster-exclusive.
                    unsafe { out.write(idx, value) };
                }
            }
        }
        l += nb;
    }
    // lint: hot-loop-end
}

/// Extended-precision folded forward (double-double accumulation over
/// the folded halves). Source-fed; the executor always feeds it exact
/// streamed rows (it builds no folded tables for the extended + folded
/// combo — reconstructed O halves would defeat double-double
/// accumulation; docs/PERF.md).
pub fn forward_cluster_folded_extended(
    b: usize,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    weights: &[f64],
    smat: &SMatrix,
    out: &SyncUnsafeSlice<'_, Complex64>,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    fold_weighted_members(b, cluster, weights, smat, scratch);
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        {
            let row = source.row(l, &mut scratch.row);
            fold_row(b, row, &mut scratch.fold[..n]);
        }
        let (e, o) = scratch.fold[..n].split_at(b);
        let vs = v_scale(l, b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let t = &scratch.t[mi * n..(mi + 1) * n];
            let mut acc_e = DdComplex::ZERO;
            let mut acc_o = DdComplex::ZERO;
            for j in 0..b {
                acc_e.acc_scaled(t[j].re, t[j].im, e[j]);
                acc_o.acc_scaled(t[b + j].re, t[b + j].im, o[j]);
            }
            let (re, im) = if member.reflected {
                (
                    (acc_e.re - acc_o.re).to_f64(),
                    (acc_e.im - acc_o.im).to_f64(),
                )
            } else {
                (
                    (acc_e.re + acc_o.re).to_f64(),
                    (acc_e.im + acc_o.im).to_f64(),
                )
            };
            let value = Complex64::new(re, im).scale(0.5 * vs * member.sign(l));
            let idx = coeffs::flat_index(l, member.m, member.mp);
            // SAFETY: (l, μ, μ') triples are cluster-exclusive.
            unsafe { out.write(idx, value) };
        }
    }
}

/// Scatter one member's folded accumulator pair (u | v) into the
/// S-matrix, unfolding `t[j] = ½(u+v)`, `t[2B−1−j] = ½(u−v)`; a
/// reflected member swaps the two targets (the unfold absorbs its
/// j-reversal).
#[inline]
fn scatter_unfolded(
    b: usize,
    u: &[Complex64],
    v: &[Complex64],
    reflected: bool,
    base: usize,
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
) {
    let n = 2 * b;
    for j in 0..b {
        let lo = (u[j] + v[j]).scale(0.5);
        let hi = (u[j] - v[j]).scale(0.5);
        let (a, z) = if reflected { (hi, lo) } else { (lo, hi) };
        // SAFETY: each (μ, μ') j-vector belongs to exactly one cluster.
        unsafe {
            smat_out.write(base + j, a);
            smat_out.write(base + n - 1 - j, z);
        }
    }
}

/// Inverse DWT for one cluster, folded, fed by a generic
/// [`WignerSource`].
#[allow(clippy::too_many_arguments)]
pub fn inverse_cluster_folded(
    b: usize,
    isa: SimdIsa,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    coeff_data: &[Complex64],
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
    smat_layout: &SMatrix,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nm = cluster.members.len();
    for t in scratch.t[..nm * n].iter_mut() {
        *t = Complex64::zero();
    }
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        {
            let row = source.row(l, &mut scratch.row);
            fold_row(b, row, &mut scratch.fold[..n]);
        }
        let (e, o) = scratch.fold[..n].split_at(b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let c = coeff_data[coeffs::flat_index(l, member.m, member.mp)]
                .scale(member.sign(l));
            let t = &mut scratch.t[mi * n..(mi + 1) * n];
            let (u, v) = t.split_at_mut(b);
            dsimd::axpy_pair_rows(isa, u, v, c, e, o);
        }
    }
    for (mi, member) in cluster.members.iter().enumerate() {
        let t = &scratch.t[mi * n..(mi + 1) * n];
        let base = smat_layout.vec_index(member.m, member.mp);
        scatter_unfolded(b, &t[..b], &t[b..], member.reflected, base, smat_out);
    }
}

/// Inverse DWT for one canonical cluster against the folded tables,
/// register-blocked over [`DEG_BLOCK`] degrees: the (u | v) accumulators
/// are loaded and stored once per block instead of once per degree.
#[allow(clippy::too_many_arguments)]
pub fn inverse_cluster_folded_tables(
    b: usize,
    isa: SimdIsa,
    cluster: &Cluster,
    tables: &WignerTables,
    coeff_data: &[Complex64],
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
    smat_layout: &SMatrix,
    scratch: &mut DwtScratch,
) {
    debug_assert!(cluster.m >= cluster.mp && cluster.mp >= 0);
    debug_assert_eq!(tables.bandwidth(), b);
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nm = cluster.members.len();
    for t in scratch.t[..nm * n].iter_mut() {
        *t = Complex64::zero();
    }

    if let Some(sigma0) = cluster.beta_parity() {
        // Parity path: accumulate u (plain) and v (σ-signed) directly
        // from the half rows — half the table stream, and the unfold is
        // the identity (u, v are the literal halves of t).
        for l in l0..b {
            let h = tables.half_row(cluster.m, l);
            let sig = sigma0 * parity_sign(l as i64);
            for (mi, member) in cluster.members.iter().enumerate() {
                debug_assert!(!member.reflected);
                let c = coeff_data[coeffs::flat_index(l, member.m, member.mp)]
                    .scale(member.sign(l));
                let cs = c.scale(sig);
                let t = &mut scratch.t[mi * n..(mi + 1) * n];
                let (u, v) = t.split_at_mut(b);
                dsimd::axpy_pair_coeffs(isa, u, v, c, cs, h);
            }
        }
        for (mi, member) in cluster.members.iter().enumerate() {
            let t = &scratch.t[mi * n..(mi + 1) * n];
            let base = smat_layout.vec_index(member.m, member.mp);
            for j in 0..b {
                // SAFETY: each (μ, μ') j-vector belongs to one cluster.
                unsafe {
                    smat_out.write(base + j, t[j]);
                    smat_out.write(base + n - 1 - j, t[b + j]);
                }
            }
        }
        return;
    }

    if scratch.oblock.len() < DEG_BLOCK * b {
        scratch.oblock.resize(DEG_BLOCK * b, 0.0);
    }
    let mut l = l0;
    // lint: hot-loop-begin
    while l < b {
        let nb = DEG_BLOCK.min(b - l);
        for k in 0..nb {
            tables.recon_o_into(
                cluster.m,
                cluster.mp,
                l + k,
                &mut scratch.oblock[k * b..(k + 1) * b],
            );
        }
        for (mi, member) in cluster.members.iter().enumerate() {
            let mut c = [Complex64::zero(); DEG_BLOCK];
            for (k, ck) in c.iter_mut().enumerate().take(nb) {
                let lk = l + k;
                *ck = coeff_data[coeffs::flat_index(lk, member.m, member.mp)]
                    .scale(member.sign(lk));
            }
            let t = &mut scratch.t[mi * n..(mi + 1) * n];
            let (u, v) = t.split_at_mut(b);
            if nb == DEG_BLOCK {
                let e = [
                    tables.e_row(cluster.m, cluster.mp, l),
                    tables.e_row(cluster.m, cluster.mp, l + 1),
                    tables.e_row(cluster.m, cluster.mp, l + 2),
                    tables.e_row(cluster.m, cluster.mp, l + 3),
                ];
                let o = &scratch.oblock;
                dsimd::inverse_block(isa, u, v, &c, &e, o);
            } else {
                for (k, &ck) in c.iter().enumerate().take(nb) {
                    let e = tables.e_row(cluster.m, cluster.mp, l + k);
                    let o = &scratch.oblock[k * b..(k + 1) * b];
                    dsimd::axpy_pair_rows(isa, u, v, ck, e, o);
                }
            }
        }
        l += nb;
    }
    // lint: hot-loop-end
    for (mi, member) in cluster.members.iter().enumerate() {
        let t = &scratch.t[mi * n..(mi + 1) * n];
        let base = smat_layout.vec_index(member.m, member.mp);
        scatter_unfolded(b, &t[..b], &t[b..], member.reflected, base, smat_out);
    }
}

/// Extended-precision folded inverse (double-double (u | v)
/// accumulators).
pub fn inverse_cluster_folded_extended(
    b: usize,
    cluster: &Cluster,
    source: &mut dyn WignerSource,
    coeff_data: &[Complex64],
    smat_out: &SyncUnsafeSlice<'_, Complex64>,
    smat_layout: &SMatrix,
    scratch: &mut DwtScratch,
) {
    let n = 2 * b;
    let l0 = cluster.l_min();
    let nm = cluster.members.len();
    scratch.xacc.clear();
    scratch.xacc.resize(nm * n, DdComplex::ZERO);
    source.reset(cluster.m, cluster.mp);
    for l in l0..b {
        {
            let row = source.row(l, &mut scratch.row);
            fold_row(b, row, &mut scratch.fold[..n]);
        }
        let (e, o) = scratch.fold[..n].split_at(b);
        for (mi, member) in cluster.members.iter().enumerate() {
            let c = coeff_data[coeffs::flat_index(l, member.m, member.mp)]
                .scale(member.sign(l));
            let acc = &mut scratch.xacc[mi * n..(mi + 1) * n];
            let (u, v) = acc.split_at_mut(b);
            for j in 0..b {
                u[j].acc_scaled(c.re, c.im, e[j]);
                v[j].acc_scaled(c.re, c.im, o[j]);
            }
        }
    }
    for (mi, member) in cluster.members.iter().enumerate() {
        let acc = &scratch.xacc[mi * n..(mi + 1) * n];
        let (u, v) = acc.split_at(b);
        let base = smat_layout.vec_index(member.m, member.mp);
        for j in 0..b {
            let lo = Complex64::new(
                (u[j].re + v[j].re).to_f64() * 0.5,
                (u[j].im + v[j].im).to_f64() * 0.5,
            );
            let hi = Complex64::new(
                (u[j].re - v[j].re).to_f64() * 0.5,
                (u[j].im - v[j].im).to_f64() * 0.5,
            );
            let (a, z) = if member.reflected { (hi, lo) } else { (lo, hi) };
            // SAFETY: each (μ, μ') j-vector belongs to exactly one cluster.
            unsafe {
                smat_out.write(base + j, a);
                smat_out.write(base + n - 1 - j, z);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dwt::kernels::{
        forward_cluster, forward_cluster_extended, inverse_cluster, inverse_cluster_extended,
    };
    use crate::dwt::tables::OnTheFlySource;
    use crate::prng::Xoshiro256;
    use crate::so3::coeffs::{coeff_count, So3Coeffs};
    use crate::so3::quadrature;
    use crate::so3::sampling::GridAngles;

    fn random_smat(b: usize, seed: u64) -> SMatrix {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut smat = SMatrix::zeros(b).unwrap();
        for v in smat.as_mut_slice().iter_mut() {
            *v = Complex64::new(rng.next_signed(), rng.next_signed());
        }
        smat
    }

    fn cluster_shapes(b: usize) -> Vec<Cluster> {
        let bi = b as i64;
        let mut shapes = vec![
            Cluster::symmetric(0, 0),
            Cluster::symmetric(1, 0),
            Cluster::symmetric(bi - 1, 0),
            Cluster::symmetric(1, 1),
            Cluster::symmetric(bi - 1, bi - 1),
            Cluster::symmetric(2, 1),
            Cluster::symmetric(bi - 1, 1),
            Cluster::symmetric(bi / 2, bi / 4),
        ];
        // Non-canonical singletons (the no-symmetry ablation).
        shapes.push(Cluster::singleton(-(bi / 2), 1));
        shapes.push(Cluster::singleton(2, -(bi - 1)));
        shapes
    }

    /// Every folded forward kernel matches the matvec baseline on every
    /// cluster shape — including the degree-block tail (l₀ near B) and
    /// the parity fast path.
    #[test]
    fn folded_forward_matches_baseline_all_shapes() {
        let isa = crate::simd::detected_isa();
        for b in [4usize, 8, 13] {
            let angles = GridAngles::new(b).unwrap();
            let weights = quadrature::weights(b).unwrap();
            let smat = random_smat(b, 40 + b as u64);
            let tables = WignerTables::build(b, &angles.betas);
            let mut scratch = DwtScratch::new(b);
            let mut want = vec![Complex64::zero(); coeff_count(b)];
            let mut got = vec![Complex64::zero(); coeff_count(b)];
            for cluster in cluster_shapes(b) {
                {
                    let shared = SyncUnsafeSlice::new(&mut want);
                    let mut src = OnTheFlySource::new(&angles.betas);
                    forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch);
                }
                let canonical = cluster.m >= cluster.mp && cluster.mp >= 0;
                {
                    let shared = SyncUnsafeSlice::new(&mut got);
                    if canonical {
                        forward_cluster_folded_tables(
                            b, isa, &cluster, &tables, &weights, &smat, &shared, &mut scratch,
                        );
                    } else {
                        let mut src = OnTheFlySource::new(&angles.betas);
                        forward_cluster_folded(
                            b, isa, &cluster, &mut src, &weights, &smat, &shared, &mut scratch,
                        );
                    }
                }
                for member in &cluster.members {
                    for l in cluster.l_min()..b {
                        let i = coeffs::flat_index(l, member.m, member.mp);
                        assert!(
                            (want[i] - got[i]).abs() < 1e-13,
                            "b={b} base=({},{}) member=({},{}) l={l}: {} vs {}",
                            cluster.m,
                            cluster.mp,
                            member.m,
                            member.mp,
                            got[i],
                            want[i]
                        );
                    }
                }
                // The source-fed folded kernel agrees too (all shapes).
                {
                    let shared = SyncUnsafeSlice::new(&mut got);
                    let mut src = OnTheFlySource::new(&angles.betas);
                    forward_cluster_folded(
                        b, isa, &cluster, &mut src, &weights, &smat, &shared, &mut scratch,
                    );
                }
                for member in &cluster.members {
                    for l in cluster.l_min()..b {
                        let i = coeffs::flat_index(l, member.m, member.mp);
                        assert!((want[i] - got[i]).abs() < 1e-13);
                    }
                }
            }
        }
    }

    #[test]
    fn folded_inverse_matches_baseline_all_shapes() {
        let isa = crate::simd::detected_isa();
        for b in [4usize, 8, 13] {
            let angles = GridAngles::new(b).unwrap();
            let coeffs_in = So3Coeffs::random(b, 50 + b as u64);
            let tables = WignerTables::build(b, &angles.betas);
            let layout = SMatrix::zeros(b).unwrap();
            let mut scratch = DwtScratch::new(b);
            let mut want = SMatrix::zeros(b).unwrap();
            let mut got = SMatrix::zeros(b).unwrap();
            for cluster in cluster_shapes(b) {
                {
                    let shared = SyncUnsafeSlice::new(want.as_mut_slice());
                    let mut src = OnTheFlySource::new(&angles.betas);
                    inverse_cluster(
                        b, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout,
                        &mut scratch,
                    );
                }
                let canonical = cluster.m >= cluster.mp && cluster.mp >= 0;
                {
                    let shared = SyncUnsafeSlice::new(got.as_mut_slice());
                    if canonical {
                        inverse_cluster_folded_tables(
                            b, isa, &cluster, &tables, coeffs_in.as_slice(), &shared, &layout,
                            &mut scratch,
                        );
                    } else {
                        let mut src = OnTheFlySource::new(&angles.betas);
                        inverse_cluster_folded(
                            b, isa, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout,
                            &mut scratch,
                        );
                    }
                }
                for member in &cluster.members {
                    let a = want.vec(member.m, member.mp);
                    let c = got.vec(member.m, member.mp);
                    for (j, (x, y)) in a.iter().zip(c.iter()).enumerate() {
                        assert!(
                            (*x - *y).abs() < 1e-12,
                            "b={b} base=({},{}) member=({},{}) j={j}",
                            cluster.m,
                            cluster.mp,
                            member.m,
                            member.mp
                        );
                    }
                }
                // Source-fed folded inverse agrees as well.
                {
                    let shared = SyncUnsafeSlice::new(got.as_mut_slice());
                    let mut src = OnTheFlySource::new(&angles.betas);
                    inverse_cluster_folded(
                        b, isa, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout,
                        &mut scratch,
                    );
                }
                for member in &cluster.members {
                    let a = want.vec(member.m, member.mp);
                    let c = got.vec(member.m, member.mp);
                    for (x, y) in a.iter().zip(c.iter()) {
                        assert!((*x - *y).abs() < 1e-13);
                    }
                }
            }
        }
    }

    #[test]
    fn folded_extended_matches_baseline_extended() {
        let b = 8usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 60);
        let coeffs_in = So3Coeffs::random(b, 61);
        let layout = SMatrix::zeros(b).unwrap();
        let mut scratch = DwtScratch::new(b);
        for cluster in [
            Cluster::symmetric(0, 0),
            Cluster::symmetric(3, 0),
            Cluster::symmetric(4, 2),
            Cluster::symmetric(5, 5),
        ] {
            let mut want = vec![Complex64::zero(); coeff_count(b)];
            let mut got = vec![Complex64::zero(); coeff_count(b)];
            {
                let shared = SyncUnsafeSlice::new(&mut want);
                let mut src = OnTheFlySource::new(&angles.betas);
                forward_cluster_extended(
                    b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch,
                );
            }
            {
                let shared = SyncUnsafeSlice::new(&mut got);
                let mut src = OnTheFlySource::new(&angles.betas);
                forward_cluster_folded_extended(
                    b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch,
                );
            }
            for member in &cluster.members {
                for l in cluster.l_min()..b {
                    let i = coeffs::flat_index(l, member.m, member.mp);
                    assert!((want[i] - got[i]).abs() < 1e-13);
                }
            }
            let mut s_want = SMatrix::zeros(b).unwrap();
            let mut s_got = SMatrix::zeros(b).unwrap();
            {
                let shared = SyncUnsafeSlice::new(s_want.as_mut_slice());
                let mut src = OnTheFlySource::new(&angles.betas);
                inverse_cluster_extended(
                    b, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout, &mut scratch,
                );
            }
            {
                let shared = SyncUnsafeSlice::new(s_got.as_mut_slice());
                let mut src = OnTheFlySource::new(&angles.betas);
                inverse_cluster_folded_extended(
                    b, &cluster, &mut src, coeffs_in.as_slice(), &shared, &layout, &mut scratch,
                );
            }
            for member in &cluster.members {
                let a = s_want.vec(member.m, member.mp);
                let c = s_got.vec(member.m, member.mp);
                for (x, y) in a.iter().zip(c.iter()) {
                    assert!((*x - *y).abs() < 1e-13);
                }
            }
        }
    }

    /// b = 1 exercises the degenerate single-node fold (the (0,0) parity
    /// cluster with one β pair).
    #[test]
    fn folded_handles_bandwidth_one() {
        let b = 1usize;
        let angles = GridAngles::new(b).unwrap();
        let weights = quadrature::weights(b).unwrap();
        let smat = random_smat(b, 70);
        let tables = WignerTables::build(b, &angles.betas);
        let cluster = Cluster::symmetric(0, 0);
        let mut scratch = DwtScratch::new(b);
        let mut want = vec![Complex64::zero(); coeff_count(b)];
        let mut got = vec![Complex64::zero(); coeff_count(b)];
        {
            let shared = SyncUnsafeSlice::new(&mut want);
            let mut src = OnTheFlySource::new(&angles.betas);
            forward_cluster(b, &cluster, &mut src, &weights, &smat, &shared, &mut scratch);
        }
        {
            let shared = SyncUnsafeSlice::new(&mut got);
            forward_cluster_folded_tables(
                b,
                crate::simd::detected_isa(),
                &cluster,
                &tables,
                &weights,
                &smat,
                &shared,
                &mut scratch,
            );
        }
        assert!((want[0] - got[0]).abs() < 1e-15);
    }
}

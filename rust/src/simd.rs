//! Runtime SIMD dispatch: policy, detection, and the resolved ISA.
//!
//! The DWT and FFT hot loops have explicit arch-specific micro-kernels
//! (AVX2+FMA on x86_64, NEON on aarch64) living in `dwt::simd` and
//! `fft::simd`. This module owns the *selection* machinery, following
//! the crate's engine-selection pattern (`DwtAlgorithm` / `FftEngine`):
//!
//! * [`SimdPolicy`] is the user-facing knob (config key `simd`, CLI
//!   `--simd`, builder method [`crate::transform::So3PlanBuilder::simd`]).
//!   `Auto` (the default) uses whatever the host supports; `Scalar`
//!   keeps the portable kernels as the measurable baseline; the
//!   `Force*` variants fail loudly on unsupported hardware instead of
//!   silently degrading.
//! * [`SimdIsa`] is the *resolved* instruction set a plan actually runs
//!   with. It is decided once at plan-build time (and memoized once per
//!   process for `Auto`), so dispatch is a plain enum match on a
//!   pre-resolved value — never a feature probe in a hot loop.
//! * `SO3FT_FORCE_SCALAR=1` is the environment escape hatch: it pins
//!   auto-detection to scalar for the whole process (CI runs the test
//!   matrix once under it so both dispatch paths stay green).
//!
//! All `unsafe` lives in the kernel modules; everything here is safe.

use crate::error::{Error, Result};
use std::sync::OnceLock;

/// User-facing SIMD dispatch policy (the `simd` config/CLI knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SimdPolicy {
    /// Use the best instruction set the host supports (the default).
    #[default]
    Auto,
    /// Portable scalar kernels — the measurable baseline.
    Scalar,
    /// Require AVX2+FMA; plan construction fails if unsupported.
    ForceAvx2,
    /// Require NEON; plan construction fails if unsupported.
    ForceNeon,
}

impl SimdPolicy {
    /// Canonical lowercase name, as accepted by [`SimdPolicy::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            SimdPolicy::Auto => "auto",
            SimdPolicy::Scalar => "scalar",
            SimdPolicy::ForceAvx2 => "force-avx2",
            SimdPolicy::ForceNeon => "force-neon",
        }
    }

    /// Parse a policy name (config / CLI / wisdom store).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(SimdPolicy::Auto),
            "scalar" => Ok(SimdPolicy::Scalar),
            "force-avx2" => Ok(SimdPolicy::ForceAvx2),
            "force-neon" => Ok(SimdPolicy::ForceNeon),
            other => Err(Error::Config(format!(
                "unknown simd policy '{other}' (expected auto|scalar|force-avx2|force-neon)"
            ))),
        }
    }

    /// Resolve the policy against the host, yielding the ISA the plan
    /// will run with. `Force*` variants return a typed config error on
    /// unsupported hardware rather than silently falling back.
    pub fn resolve(&self) -> Result<SimdIsa> {
        match self {
            SimdPolicy::Auto => Ok(detected_isa()),
            SimdPolicy::Scalar => Ok(SimdIsa::Scalar),
            SimdPolicy::ForceAvx2 => {
                if avx2_supported() {
                    Ok(SimdIsa::Avx2)
                } else {
                    Err(Error::Config(
                        "simd=force-avx2 but this host does not support AVX2+FMA".into(),
                    ))
                }
            }
            SimdPolicy::ForceNeon => {
                if neon_supported() {
                    Ok(SimdIsa::Neon)
                } else {
                    Err(Error::Config(
                        "simd=force-neon but this host does not support NEON".into(),
                    ))
                }
            }
        }
    }
}

/// The instruction set a plan actually executes with — the *resolved*
/// form of [`SimdPolicy`]. Hot loops match on this pre-resolved value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdIsa {
    /// Portable scalar kernels.
    Scalar,
    /// x86_64 AVX2 + FMA (4-wide f64).
    Avx2,
    /// aarch64 NEON (2-wide f64).
    Neon,
}

impl SimdIsa {
    /// Canonical lowercase name (bench records, fingerprint, logs).
    pub fn name(&self) -> &'static str {
        match self {
            SimdIsa::Scalar => "scalar",
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Neon => "neon",
        }
    }
}

/// Does this host support the AVX2+FMA kernels?
///
/// Always `false` under Miri: the interpreter does not model the vector
/// intrinsics, so detection reports "unsupported" and every dispatch
/// (including the parity tests, which gate on this) takes the scalar
/// path instead of hitting an unsupported-intrinsic error.
pub fn avx2_supported() -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    let ok = std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma");
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    let ok = false;
    ok
}

/// Does this host support the NEON kernels? (NEON is baseline on
/// aarch64, so this is a compile-time fact — except under Miri, which
/// does not model the intrinsics; see [`avx2_supported`].)
pub fn neon_supported() -> bool {
    cfg!(all(target_arch = "aarch64", not(miri)))
}

/// Pure detection logic: the ISA `Auto` resolves to, given whether the
/// scalar escape hatch is engaged. Exposed (rather than only the
/// memoized [`detected_isa`]) so tests can exercise the hatch without
/// racing on process-global environment state.
pub fn detect(force_scalar: bool) -> SimdIsa {
    if force_scalar {
        return SimdIsa::Scalar;
    }
    if avx2_supported() {
        SimdIsa::Avx2
    } else if neon_supported() {
        SimdIsa::Neon
    } else {
        SimdIsa::Scalar
    }
}

/// The host's best supported ISA, honouring `SO3FT_FORCE_SCALAR=1`.
/// Memoized once per process: feature probes and the env read happen at
/// most once, never in a hot loop.
pub fn detected_isa() -> SimdIsa {
    static DETECTED: OnceLock<SimdIsa> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        let force = std::env::var("SO3FT_FORCE_SCALAR").as_deref() == Ok("1");
        detect(force)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            SimdPolicy::Auto,
            SimdPolicy::Scalar,
            SimdPolicy::ForceAvx2,
            SimdPolicy::ForceNeon,
        ] {
            assert_eq!(SimdPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(SimdPolicy::parse("avx512").is_err());
    }

    #[test]
    fn default_policy_is_auto() {
        assert_eq!(SimdPolicy::default(), SimdPolicy::Auto);
    }

    #[test]
    fn scalar_policy_always_resolves_scalar() {
        assert_eq!(SimdPolicy::Scalar.resolve().unwrap(), SimdIsa::Scalar);
    }

    #[test]
    fn auto_resolves_to_detected() {
        assert_eq!(SimdPolicy::Auto.resolve().unwrap(), detected_isa());
    }

    #[test]
    fn force_scalar_hatch_wins_over_features() {
        assert_eq!(detect(true), SimdIsa::Scalar);
    }

    #[test]
    fn detect_matches_host_features() {
        let isa = detect(false);
        if avx2_supported() {
            assert_eq!(isa, SimdIsa::Avx2);
        } else if neon_supported() {
            assert_eq!(isa, SimdIsa::Neon);
        } else {
            assert_eq!(isa, SimdIsa::Scalar);
        }
    }

    #[test]
    fn force_variants_error_on_unsupported_hosts() {
        if !avx2_supported() {
            assert!(matches!(
                SimdPolicy::ForceAvx2.resolve(),
                Err(Error::Config(_))
            ));
        }
        if !neon_supported() {
            assert!(matches!(
                SimdPolicy::ForceNeon.resolve(),
                Err(Error::Config(_))
            ));
        }
    }

    #[test]
    fn at_most_one_vector_isa_per_host() {
        // AVX2 and NEON are mutually exclusive arches; both being
        // reported would mean the cfg gates are wrong.
        assert!(!(avx2_supported() && neon_supported()));
    }

    #[test]
    fn isa_names_are_stable() {
        // These strings appear in bench records and the wisdom
        // fingerprint; renaming them is a store-invalidating change.
        assert_eq!(SimdIsa::Scalar.name(), "scalar");
        assert_eq!(SimdIsa::Avx2.name(), "avx2");
        assert_eq!(SimdIsa::Neon.name(), "neon");
    }
}

//! Shared low-level utilities: disjoint-write shared slices,
//! cache-line-aligned scratch buffers, poison-recovering lock helpers,
//! on-disk cache path resolution, and the few special functions the
//! Wigner-d seeds need.

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Marker for element types that may live in an [`AlignedVec`].
///
/// # Safety
/// Implementors must be plain old data: `Copy`, no drop glue, valid for
/// every bit pattern (the backing storage is zero-initialized bytes),
/// and alignment ≤ 64 bytes.
pub unsafe trait Pod: Copy {}

// SAFETY: primitive floats satisfy every Pod requirement.
unsafe impl Pod for f64 {}

/// One cache line of backing storage; the `align(64)` is what gives
/// [`AlignedVec`] its guarantee.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct Chunk64([u8; 64]);

/// A growable buffer whose data pointer is always 64-byte aligned — the
/// allocation helper behind the thread-local DWT and FFT scratch.
///
/// Alignment matters to the SIMD micro-kernels (`dwt::simd`,
/// `fft::simd`): 64 bytes covers a full cache line, so a hot scratch
/// vector never straddles lines at its head and every 32-byte AVX2 (or
/// 16-byte NEON) access inside it stays naturally aligned. `Vec<f64>`
/// only guarantees 8.
///
/// The API is the `Vec` subset the kernels use — `resize`, `clear`, and
/// slice access through `Deref` — with `Vec::resize` fill semantics:
/// `resize` writes `value` into slots past the previous length only.
/// Shrinking is O(1) (capacity is retained, like `Vec`).
pub struct AlignedVec<T: Pod> {
    chunks: Vec<Chunk64>,
    len: usize,
    _elem: PhantomData<T>,
}

impl<T: Pod> AlignedVec<T> {
    /// An empty buffer. `const`, so it can seed
    /// `const { RefCell::new(...) }` thread-local slots.
    pub const fn new() -> Self {
        Self {
            chunks: Vec::new(),
            len: 0,
            _elem: PhantomData,
        }
    }

    /// Resize to `new_len` elements, filling any slots past the previous
    /// length with `value` (exactly `Vec::resize`).
    pub fn resize(&mut self, new_len: usize, value: T) {
        let bytes = new_len
            .checked_mul(std::mem::size_of::<T>())
            .expect("AlignedVec byte length overflow");
        let chunks = bytes.div_ceil(64);
        if chunks > self.chunks.len() {
            self.chunks.resize(chunks, Chunk64([0u8; 64]));
        }
        let old = self.len;
        self.len = new_len;
        if new_len > old {
            for slot in &mut self.as_mut_slice()[old..] {
                *slot = value;
            }
        }
    }

    /// Drop every element (capacity is retained).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// The elements as a plain slice.
    pub fn as_slice(&self) -> &[T] {
        debug_assert!(std::mem::align_of::<T>() <= 64);
        let ptr = self.chunks.as_ptr() as *const T;
        debug_assert_eq!(
            ptr as usize % 64,
            0,
            "AlignedVec backing lost 64-byte alignment"
        );
        // SAFETY: the chunk storage holds at least `len` elements (see
        // `resize`), every byte of it is initialized, and `T: Pod`
        // accepts any bit pattern.
        unsafe { std::slice::from_raw_parts(ptr, self.len) }
    }

    /// The elements as a plain mutable slice.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        debug_assert!(std::mem::align_of::<T>() <= 64);
        let ptr = self.chunks.as_mut_ptr() as *mut T;
        debug_assert_eq!(
            ptr as usize % 64,
            0,
            "AlignedVec backing lost 64-byte alignment"
        );
        // SAFETY: as in `as_slice`, plus `&mut self` gives exclusivity.
        unsafe { std::slice::from_raw_parts_mut(ptr, self.len) }
    }
}

impl<T: Pod> Deref for AlignedVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> DerefMut for AlignedVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Pod> Default for AlignedVec<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Pod> Clone for AlignedVec<T> {
    fn clone(&self) -> Self {
        Self {
            chunks: self.chunks.clone(),
            len: self.len,
            _elem: PhantomData,
        }
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for AlignedVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice().iter()).finish()
    }
}

/// Lock a mutex, recovering the guard from a poisoned lock — the
/// crate's uniform poison policy: a panicked holder leaves data that is
/// either fully overwritten by the next user or consistent by
/// construction, so propagating the poison would only turn one panic
/// into many.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// [`lock_unpoisoned`] for `RwLock` readers.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

/// [`lock_unpoisoned`] for `RwLock` writers.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

/// A shared slice that permits concurrent writes to *provably disjoint*
/// index sets from multiple worker threads.
///
/// The SO(3) coordinator assigns every output element — a coefficient
/// (l, μ, μ') or an intermediate S(m, m'; j) entry — to exactly one work
/// package (see `coordinator::plan`), so parallel workers never alias.
/// This type encodes that contract: `write` is unsafe and the caller
/// guarantees disjointness, exactly like the underlying OpenMP code the
/// paper describes ("memory access of the different nodes can be made
/// exclusive").
pub struct SyncUnsafeSlice<'a, T> {
    data: &'a [UnsafeCell<T>],
}

// SAFETY: the slice is only a view over `&[UnsafeCell<T>]`; sending or
// sharing it across threads is sound because every access goes through
// the `unsafe fn` surface below, whose contract (disjoint index sets per
// worker, enforced by the coordinator's work partition) rules out
// concurrent aliasing. `T: Send + Sync` bounds keep the element type
// itself thread-safe.
unsafe impl<'a, T: Send + Sync> Send for SyncUnsafeSlice<'a, T> {}
// SAFETY: see the Send impl above — same disjointness contract.
unsafe impl<'a, T: Send + Sync> Sync for SyncUnsafeSlice<'a, T> {}

impl<'a, T> SyncUnsafeSlice<'a, T> {
    /// Wrap a mutable slice for disjoint concurrent writes.
    pub fn new(slice: &'a mut [T]) -> Self {
        // SAFETY: UnsafeCell<T> has the same layout as T.
        let data = unsafe {
            std::slice::from_raw_parts(slice.as_mut_ptr() as *const UnsafeCell<T>, slice.len())
        };
        Self { data }
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the slice is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Write `value` at `index`.
    ///
    /// # Safety
    /// No other thread may read or write `index` concurrently; each index
    /// must be written by at most one work package per parallel region.
    #[inline]
    pub unsafe fn write(&self, index: usize, value: T) {
        debug_assert!(index < self.data.len());
        // SAFETY: caller guarantees exclusive access to `index` (see
        // `# Safety` above), so this write cannot alias a concurrent
        // read or write of the same element.
        unsafe { *self.data[index].get() = value };
    }

    /// Read the value at `index`.
    ///
    /// # Safety
    /// No other thread may be writing `index` concurrently.
    #[inline]
    pub unsafe fn read(&self, index: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(index < self.data.len());
        // SAFETY: caller guarantees no concurrent writer for `index`
        // (see `# Safety` above), so the element is readable.
        unsafe { *self.data[index].get() }
    }

    /// Raw pointer to element `index` (for slice-at-a-time writes).
    ///
    /// # Safety
    /// Same disjointness contract as [`Self::write`].
    #[inline]
    pub unsafe fn ptr_at(&self, index: usize) -> *mut T {
        debug_assert!(index < self.data.len());
        self.data[index].get()
    }
}

/// Natural log of the Gamma function via the Lanczos approximation
/// (g = 7, n = 9 coefficients; |relative error| < 2e-10 for x > 0,
/// which the Wigner seed magnitudes — built from *differences* of
/// lgamma values — comfortably survive at B = 512).
///
/// Only needed for x ≥ 1 here (factorials), but handles all x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    // Lanczos coefficients (g = 7).
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    assert!(x > 0.0, "ln_gamma domain: x > 0 (got {x})");
    if x < 0.5 {
        // Reflection formula keeps accuracy near zero.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (std::f64::consts::TAU).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln(n!) — exact table for small n, lgamma beyond.
pub fn ln_factorial(n: u64) -> f64 {
    // Factorials up to 20! fit exactly in u64/f64.
    const EXACT: usize = 21;
    static TABLE: std::sync::OnceLock<[f64; EXACT]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0.0f64; EXACT];
        let mut acc = 1.0f64;
        for (i, slot) in t.iter_mut().enumerate() {
            if i > 0 {
                acc *= i as f64;
            }
            *slot = acc.ln();
        }
        t
    });
    if (n as usize) < EXACT {
        table[n as usize]
    } else {
        ln_gamma(n as f64 + 1.0)
    }
}

/// Integer parity sign: (-1)^k for possibly-negative k.
#[inline]
pub fn parity_sign(k: i64) -> f64 {
    if k & 1 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// The crate's on-disk cache directory — the single resolution point
/// for every persistent artifact (the Wigner `SO3W2` tables and the
/// wisdom `SO3WIS1` store live side by side here):
///
/// 1. `$SO3FT_CACHE_DIR` (explicit override; CI uses a workspace-local
///    directory so runs stay hermetic),
/// 2. `$XDG_CACHE_HOME/so3ft`,
/// 3. `$HOME/.cache/so3ft`,
/// 4. `<temp_dir>/so3ft-cache` (last resort; always writable-ish).
///
/// Resolution only — nothing is created until a writer calls
/// `create_dir_all`.
pub fn cache_dir() -> PathBuf {
    if let Some(dir) = std::env::var_os("SO3FT_CACHE_DIR").filter(|v| !v.is_empty()) {
        return PathBuf::from(dir);
    }
    if let Some(xdg) = std::env::var_os("XDG_CACHE_HOME").filter(|v| !v.is_empty()) {
        return PathBuf::from(xdg).join("so3ft");
    }
    if let Some(home) = std::env::var_os("HOME").filter(|v| !v.is_empty()) {
        return PathBuf::from(home).join(".cache").join("so3ft");
    }
    std::env::temp_dir().join("so3ft-cache")
}

/// `cache_dir()/name` — the canonical path of one cached artifact.
pub fn cache_file(name: &str) -> PathBuf {
    cache_dir().join(name)
}

/// Process-wide allocation ledger for the big numeric buffers.
///
/// The large-B work (ISSUE 8) needs *peak* memory numbers that CI can
/// gate on, and `malloc` stats are neither portable nor attributable.
/// Instead, the handful of structures that dominate the footprint — the
/// Wigner table sets and the transform workspaces — each hold a
/// [`ledger::LedgerSlot`] that charges its byte size on construction and
/// discharges on drop. [`ledger::peak_bytes`] then reports the
/// high-water mark of everything charged since the last
/// [`ledger::rebase_peak`], which the executor calls at the start of
/// every transform so `StageStats::peak_bytes` reflects the steady-state
/// footprint of *that* run (tables + workspaces live across the call, so
/// they are included; transient spikes from concurrent plans in other
/// threads may inflate the number — it is a best-effort process-wide
/// gauge, not a per-plan accountant).
///
/// [`ledger::peak_rss_bytes`] complements the ledger with the OS view
/// (`VmHWM` on Linux) where available.
pub mod ledger {
    use std::sync::atomic::{AtomicUsize, Ordering};

    static CURRENT: AtomicUsize = AtomicUsize::new(0);
    static PEAK: AtomicUsize = AtomicUsize::new(0);

    /// Charge `bytes` to the ledger, updating the high-water mark.
    pub fn charge(bytes: usize) {
        // ordering: Relaxed — monotonic gauge counters; readers only
        // need an eventually-consistent byte total, no data is
        // published through these atomics.
        let now = CURRENT.fetch_add(bytes, Ordering::Relaxed) + bytes;
        PEAK.fetch_max(now, Ordering::Relaxed);
    }

    /// Discharge `bytes` previously charged.
    pub fn discharge(bytes: usize) {
        // ordering: Relaxed — gauge counter, see `charge`.
        CURRENT.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Bytes currently charged across the process.
    pub fn current_bytes() -> usize {
        // ordering: Relaxed — best-effort gauge read, see `charge`.
        CURRENT.load(Ordering::Relaxed)
    }

    /// High-water mark since the last [`rebase_peak`] (never below the
    /// current charge).
    pub fn peak_bytes() -> usize {
        // ordering: Relaxed — best-effort gauge read, see `charge`.
        PEAK.load(Ordering::Relaxed).max(current_bytes())
    }

    /// Reset the high-water mark to the current charge. The executor
    /// calls this at the start of each transform so the reported peak
    /// covers that run's steady state rather than all of process
    /// history.
    pub fn rebase_peak() {
        // ordering: Relaxed — gauge reset; concurrent charges may land
        // on either side of the rebase, which the best-effort contract
        // of this module (see module docs) explicitly allows.
        PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// RAII charge: holds `bytes` against the ledger for its lifetime.
    /// `Clone` re-charges (a cloned table set really does occupy more
    /// memory); `Drop` discharges.
    pub struct LedgerSlot {
        bytes: usize,
    }

    impl LedgerSlot {
        /// Record a ledger charge of `bytes` (charged on construction).
        pub fn new(bytes: usize) -> Self {
            charge(bytes);
            Self { bytes }
        }

        /// The charged size in bytes.
        pub fn bytes(&self) -> usize {
            self.bytes
        }
    }

    impl Clone for LedgerSlot {
        fn clone(&self) -> Self {
            Self::new(self.bytes)
        }
    }

    impl Drop for LedgerSlot {
        fn drop(&mut self) {
            discharge(self.bytes);
        }
    }

    impl std::fmt::Debug for LedgerSlot {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("LedgerSlot").field("bytes", &self.bytes).finish()
        }
    }

    /// The process peak resident set size as the OS reports it, if it
    /// does: `VmHWM` from `/proc/self/status` on Linux (kB → bytes),
    /// `None` elsewhere. Unlike the ledger this includes code, stacks,
    /// allocator slack — and it never decreases.
    pub fn peak_rss_bytes() -> Option<usize> {
        #[cfg(target_os = "linux")]
        {
            let status = std::fs::read_to_string("/proc/self/status").ok()?;
            for line in status.lines() {
                if let Some(rest) = line.strip_prefix("VmHWM:") {
                    let kb: usize = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
                    return Some(kb * 1024);
                }
            }
            None
        }
        #[cfg(not(target_os = "linux"))]
        {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        let mut fact = 1.0f64;
        for n in 1..=30u64 {
            fact *= n as f64;
            let lg = ln_gamma(n as f64 + 1.0);
            assert!(
                (lg - fact.ln()).abs() < 1e-9 * fact.ln().abs().max(1.0),
                "n={n}: {lg} vs {}",
                fact.ln()
            );
        }
    }

    #[test]
    fn ln_gamma_half_integer() {
        // Γ(1/2) = √π.
        let want = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - want).abs() < 1e-10);
        // Γ(3/2) = √π / 2.
        let want = (std::f64::consts::PI.sqrt() / 2.0).ln();
        assert!((ln_gamma(1.5) - want).abs() < 1e-10);
    }

    #[test]
    fn ln_factorial_consistency() {
        for n in 0..200u64 {
            let a = ln_factorial(n);
            let b = ln_gamma(n as f64 + 1.0);
            assert!((a - b).abs() < 1e-8 * b.abs().max(1.0));
        }
        // Recurrence ln((n+1)!) = ln(n!) + ln(n+1). Miri interprets
        // ~1000x slower than native; a shorter sweep still covers the
        // small-n table edge and the asymptotic branch.
        const RECURRENCE_SWEEP: u64 = if cfg!(miri) { 64 } else { 1024 };
        for n in 0..RECURRENCE_SWEEP {
            let lhs = ln_factorial(n + 1);
            let rhs = ln_factorial(n) + ((n + 1) as f64).ln();
            assert!((lhs - rhs).abs() < 1e-8 * rhs.abs().max(1.0));
        }
    }

    #[test]
    fn parity() {
        assert_eq!(parity_sign(0), 1.0);
        assert_eq!(parity_sign(1), -1.0);
        assert_eq!(parity_sign(-1), -1.0);
        assert_eq!(parity_sign(-4), 1.0);
    }

    #[test]
    fn round_up_cases() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn cache_dir_resolution_precedence() {
        // Self-contained: this is the only test touching these env vars
        // (parallel tests never race on them).
        let saved: Vec<(&str, Option<std::ffi::OsString>)> =
            ["SO3FT_CACHE_DIR", "XDG_CACHE_HOME", "HOME"]
                .iter()
                .map(|k| (*k, std::env::var_os(k)))
                .collect();
        std::env::set_var("SO3FT_CACHE_DIR", "/explicit/cache");
        assert_eq!(cache_dir(), PathBuf::from("/explicit/cache"));
        assert_eq!(
            cache_file("wisdom.so3wis"),
            PathBuf::from("/explicit/cache/wisdom.so3wis")
        );
        std::env::remove_var("SO3FT_CACHE_DIR");
        std::env::set_var("XDG_CACHE_HOME", "/xdg");
        assert_eq!(cache_dir(), PathBuf::from("/xdg/so3ft"));
        std::env::remove_var("XDG_CACHE_HOME");
        std::env::set_var("HOME", "/home/user");
        assert_eq!(cache_dir(), PathBuf::from("/home/user/.cache/so3ft"));
        std::env::remove_var("HOME");
        assert_eq!(cache_dir(), std::env::temp_dir().join("so3ft-cache"));
        for (k, v) in saved {
            match v {
                Some(v) => std::env::set_var(k, v),
                None => std::env::remove_var(k),
            }
        }
    }

    #[test]
    fn aligned_vec_is_64_byte_aligned_and_grows() {
        let mut v: AlignedVec<f64> = AlignedVec::new();
        assert!(v.is_empty());
        for len in [1usize, 7, 8, 9, 63, 64, 65, 1000] {
            v.resize(len, 0.0);
            assert_eq!(v.len(), len);
            assert_eq!(v.as_slice().as_ptr() as usize % 64, 0, "len={len}");
        }
    }

    #[test]
    fn aligned_vec_matches_vec_resize_semantics() {
        let mut a: AlignedVec<f64> = AlignedVec::new();
        let mut v: Vec<f64> = Vec::new();
        a.resize(4, 1.0);
        v.resize(4, 1.0);
        a[2] = 9.0;
        v[2] = 9.0;
        // Shrink keeps the prefix; regrow fills only the new tail.
        a.resize(3, 7.0);
        v.resize(3, 7.0);
        a.resize(6, 5.0);
        v.resize(6, 5.0);
        assert_eq!(a.as_slice(), v.as_slice());
        a.clear();
        v.clear();
        a.resize(2, 3.0);
        v.resize(2, 3.0);
        assert_eq!(a.as_slice(), v.as_slice());
    }

    #[test]
    fn aligned_vec_clone_and_iter() {
        let mut v: AlignedVec<f64> = AlignedVec::new();
        v.resize(5, 2.0);
        v[0] = -1.0;
        let c = v.clone();
        assert_eq!(c.as_slice(), v.as_slice());
        assert_eq!(v.iter().sum::<f64>(), -1.0 + 4.0 * 2.0);
        // Mutation through DerefMut.
        for x in v.iter_mut() {
            *x *= 2.0;
        }
        assert_eq!(v[1], 4.0);
        assert_eq!(c[1], 2.0, "clone is independent storage");
    }

    #[test]
    fn ledger_slot_charges_and_discharges() {
        // Other tests in this process create Workspaces and table sets
        // concurrently, so exact global counts are racy. Charge a
        // sentinel far beyond any real test allocation (8 GiB — these
        // are ledger *numbers*, no memory is actually allocated) and
        // make tolerant assertions around it.
        const SENTINEL: usize = 1 << 33;
        let before = ledger::current_bytes();
        assert!(before < SENTINEL, "sentinel not distinctive: {before}");
        {
            let slot = ledger::LedgerSlot::new(SENTINEL);
            assert_eq!(slot.bytes(), SENTINEL);
            assert!(ledger::current_bytes() >= SENTINEL);
            assert!(ledger::peak_bytes() >= SENTINEL);
            let cloned = slot.clone();
            assert!(ledger::current_bytes() >= 2 * SENTINEL);
            drop(cloned);
            assert!(ledger::current_bytes() < 2 * SENTINEL);
        }
        assert!(ledger::current_bytes() < SENTINEL);
        // The executor calls rebase_peak() at every transform start, and
        // other tests in this process run transforms concurrently — so
        // "peak survives until rebased" cannot be asserted here without
        // racing. The race-free invariant: peak never drops below the
        // current charge.
        ledger::rebase_peak();
        assert!(ledger::peak_bytes() >= ledger::current_bytes());
    }

    #[test]
    fn peak_rss_is_plausible_when_available() {
        if let Some(rss) = ledger::peak_rss_bytes() {
            // A running test binary occupies at least a megabyte and
            // (comfortably) less than a terabyte.
            assert!(rss > 1 << 20, "peak RSS too small: {rss}");
            assert!(rss < 1 << 40, "peak RSS too large: {rss}");
        }
    }

    #[test]
    fn sync_slice_disjoint_parallel_writes() {
        // Shrunk under Miri (threads + per-element interpretation are
        // slow); the aliasing structure is identical at any length.
        const LEN: usize = if cfg!(miri) { 128 } else { 1000 };
        let mut data = vec![0usize; LEN];
        {
            let shared = SyncUnsafeSlice::new(&mut data);
            std::thread::scope(|s| {
                for t in 0..4 {
                    let shared = &shared;
                    s.spawn(move || {
                        for i in (t..LEN).step_by(4) {
                            // SAFETY: indices are partitioned by residue class.
                            unsafe { shared.write(i, i * 2) };
                        }
                    });
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }
}

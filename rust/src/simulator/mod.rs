//! Multicore execution simulator — the documented substitution for the
//! paper's 64-core AMD Opteron testbed (DESIGN.md §3).
//!
//! This container exposes a single CPU core, so the speedup/efficiency
//! figures (paper Figs. 2–4) cannot be measured as wall-clock. They are
//! instead *replayed*: the real per-package costs of the real schedule
//! are measured on this machine (`Executor::profile_*`), and a
//! discrete-event machine model executes the same dynamic-scheduling
//! discipline on P virtual cores. The model captures exactly the effects
//! the paper discusses in §5:
//!
//! * **workload imbalance** — real (heterogeneous) package costs are
//!   list-scheduled; the critical path and tail packages limit speedup
//!   for small bandwidths,
//! * **scheduling overhead** — a per-claim dispatch cost and a per-region
//!   fork/join barrier,
//! * **memory contention** — each region has a memory-boundedness
//!   fraction; its memory share stops scaling once the active cores
//!   saturate the socket's bandwidth (the paper's "increasingly
//!   complicated memory management" plateau, strongest in the iDWT whose
//!   on-the-fly transposition streams the most data).
//!
//! Parameters are calibrated once against the paper's published 64-core
//! speedups (see [`machine::MachineParams::opteron_like`]) and validated
//! in `benches/fig2_speedup.rs`.
//!
//! * [`machine`] — the discrete-event model itself.
//! * [`cost`] — package-cost acquisition: measured profiles for
//!   bandwidths this container can run, analytic extrapolation (fitted
//!   rates × operation counts) for the paper's B = 256, 512.
//! * [`scaling`] — speedup/runtime/efficiency curves (Figs. 2–4 series).

pub mod cost;
pub mod machine;
pub mod scaling;

pub use cost::{analytic_spec, measured_spec, FittedRates, TransformKind};
pub use machine::{MachineParams, RegionSpec, TransformSpec};
pub use scaling::{scaling_curve, ScalingPoint};

//! Package-cost acquisition for the simulator.
//!
//! Two sources:
//! * [`measured_spec`] — run the real sequential transform instrumented
//!   per package on this machine (`Executor::profile_*`) and wrap the
//!   measured costs in a [`TransformSpec`]. Exact workload, exact
//!   imbalance; available for any bandwidth the container can execute.
//! * [`analytic_spec`] — operation-count model (cluster flops, FFT
//!   points, transpose bytes) scaled by rates fitted from a measured
//!   bandwidth. Used for the paper's B = 256/512, whose sequential runs
//!   take hours.
//!
//! Memory-boundedness fractions are calibrated against the paper's
//! published 64-core speedups (see EXPERIMENTS.md §fig2-calibration) and
//! interpolated in log₂B between anchors.

use crate::coordinator::{Executor, ExecutorConfig, TransformPlan};
use crate::error::Result;
use crate::pool::Schedule;
use crate::simulator::machine::{RegionSpec, TransformSpec};
use crate::so3::coeffs::So3Coeffs;

/// Which direction of the transform is being modeled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransformKind {
    /// Analysis (FSOFT).
    Forward,
    /// Synthesis (iFSOFT).
    Inverse,
}

impl TransformKind {
    /// Short label for tables and plots.
    pub fn label(&self) -> &'static str {
        match self {
            TransformKind::Forward => "fsoft",
            TransformKind::Inverse => "ifsoft",
        }
    }
}

/// Calibration anchors: memory-boundedness of the DWT region per
/// bandwidth, forward transform. (Fitted so the simulated 64-core
/// speedups reproduce the paper's Fig. 2 within a few percent.)
const MU_DWT_FWD: &[(usize, f64)] = &[
    (32, 0.50),
    (64, 0.48),
    (128, 0.47),
    (256, 0.27),
    (512, 0.33),
];

/// Inverse-transform anchors: the iDWT's on-the-fly transposition
/// streams more memory per flop (paper §5), hence higher μ.
const MU_DWT_INV: &[(usize, f64)] = &[
    (32, 0.68),
    (64, 0.67),
    (128, 0.68),
    (256, 0.565),
    (512, 0.655),
];

/// Memory-boundedness of the 2-D FFT region (cache-friendly per slice).
const MU_FFT: f64 = 0.30;
/// The transposition region is pure memory movement.
const MU_TRANSPOSE: f64 = 0.90;

/// Piecewise-linear interpolation in log₂(B) over anchor tables.
fn interp_mu(table: &[(usize, f64)], b: usize) -> f64 {
    let x = (b as f64).log2();
    let first = table.first().unwrap();
    let last = table.last().unwrap();
    if b <= first.0 {
        return first.1;
    }
    if b >= last.0 {
        return last.1;
    }
    for w in table.windows(2) {
        let (b0, m0) = w[0];
        let (b1, m1) = w[1];
        if b >= b0 && b <= b1 {
            let x0 = (b0 as f64).log2();
            let x1 = (b1 as f64).log2();
            return m0 + (m1 - m0) * (x - x0) / (x1 - x0);
        }
    }
    last.1
}

/// μ for the DWT region of bandwidth `b`.
pub fn mu_dwt(b: usize, kind: TransformKind) -> f64 {
    match kind {
        TransformKind::Forward => interp_mu(MU_DWT_FWD, b),
        TransformKind::Inverse => interp_mu(MU_DWT_INV, b),
    }
}

/// Build a [`TransformSpec`] from a real instrumented run.
pub fn measured_spec(b: usize, kind: TransformKind) -> Result<TransformSpec> {
    let exec = Executor::new(b, ExecutorConfig::default())?;
    let coeffs = So3Coeffs::random(b, 0xC0FFEE);
    let profiles = match kind {
        TransformKind::Inverse => exec.profile_inverse(&coeffs)?.1,
        TransformKind::Forward => {
            let grid = exec.inverse(&coeffs)?;
            exec.profile_forward(&grid)?.1
        }
    };
    let dwt_region = RegionSpec {
        costs: profiles.dwt,
        mem_fraction: mu_dwt(b, kind),
        schedule: Schedule::PAPER,
    };
    let fft_region = RegionSpec {
        costs: profiles.fft,
        mem_fraction: MU_FFT,
        schedule: Schedule::Dynamic { chunk: 1 },
    };
    let trn_region = RegionSpec {
        costs: profiles.transpose,
        mem_fraction: MU_TRANSPOSE,
        schedule: Schedule::Dynamic { chunk: 64 },
    };
    let regions = match kind {
        TransformKind::Forward => vec![fft_region, trn_region, dwt_region],
        TransformKind::Inverse => vec![dwt_region, trn_region, fft_region],
    };
    Ok(TransformSpec {
        regions,
        serial: 0.0,
        label: format!("{} b={b} (measured)", kind.label()),
    })
}

/// Rates fitted from a measured bandwidth, used to extrapolate costs.
#[derive(Debug, Clone)]
pub struct FittedRates {
    /// Seconds per cluster "flop" (the [`crate::dwt::cluster::Cluster::flops`] unit).
    pub sec_per_dwt_flop: f64,
    /// Seconds per FFT point-log: slice cost = rate · (2B)² log₂(2B).
    pub sec_per_fft_unit: f64,
    /// Seconds per transposed element: package cost = rate · 2B.
    pub sec_per_trn_elem: f64,
}

impl FittedRates {
    /// Fit from an instrumented run at bandwidth `b` (B = 32/64 are good
    /// choices: large enough to be past cache warm-up artifacts).
    pub fn fit(b: usize, kind: TransformKind) -> Result<FittedRates> {
        let spec = measured_spec(b, kind)?;
        let plan = TransformPlan::new(b, crate::coordinator::PartitionStrategy::GeometricClustered);
        let flops: usize = plan.total_flops();
        let (fft_i, trn_i, dwt_i) = match kind {
            TransformKind::Forward => (0usize, 1usize, 2usize),
            TransformKind::Inverse => (2, 1, 0),
        };
        let n = 2 * b;
        let fft_units = (n * n) as f64 * (n as f64).log2() * n as f64; // all slices
        let trn_elems = ((2 * b - 1) * (2 * b - 1) * n) as f64;
        Ok(FittedRates {
            sec_per_dwt_flop: spec.regions[dwt_i].costs.iter().sum::<f64>() / flops as f64,
            sec_per_fft_unit: spec.regions[fft_i].costs.iter().sum::<f64>() / fft_units,
            sec_per_trn_elem: spec.regions[trn_i].costs.iter().sum::<f64>() / trn_elems,
        })
    }
}

/// Operation-count spec for any bandwidth (no execution required).
pub fn analytic_spec(b: usize, kind: TransformKind, rates: &FittedRates) -> TransformSpec {
    let plan = TransformPlan::new(b, crate::coordinator::PartitionStrategy::GeometricClustered);
    let n = 2 * b;
    let dwt_costs: Vec<f64> = plan
        .package_flops()
        .iter()
        .map(|&f| f as f64 * rates.sec_per_dwt_flop)
        .collect();
    let fft_slice = (n * n) as f64 * (n as f64).log2() * rates.sec_per_fft_unit;
    let fft_costs = vec![fft_slice; n];
    let trn_pkg = n as f64 * rates.sec_per_trn_elem;
    let trn_costs = vec![trn_pkg; (2 * b - 1) * (2 * b - 1)];
    let dwt_region = RegionSpec {
        costs: dwt_costs,
        mem_fraction: mu_dwt(b, kind),
        schedule: Schedule::PAPER,
    };
    let fft_region = RegionSpec {
        costs: fft_costs,
        mem_fraction: MU_FFT,
        schedule: Schedule::Dynamic { chunk: 1 },
    };
    let trn_region = RegionSpec {
        costs: trn_costs,
        mem_fraction: MU_TRANSPOSE,
        schedule: Schedule::Dynamic { chunk: 64 },
    };
    let regions = match kind {
        TransformKind::Forward => vec![fft_region, trn_region, dwt_region],
        TransformKind::Inverse => vec![dwt_region, trn_region, fft_region],
    };
    TransformSpec {
        regions,
        serial: 0.0,
        label: format!("{} b={b} (analytic)", kind.label()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_interpolation_monotone_segments() {
        assert!((mu_dwt(32, TransformKind::Forward) - 0.50).abs() < 1e-12);
        assert!((mu_dwt(512, TransformKind::Forward) - 0.33).abs() < 1e-12);
        let mid = mu_dwt(90, TransformKind::Forward);
        assert!(mid < 0.50 && mid > 0.44);
        // Below/above anchors clamps.
        assert_eq!(mu_dwt(8, TransformKind::Forward), 0.50);
        assert_eq!(mu_dwt(1024, TransformKind::Forward), 0.33);
        // Inverse is always more memory-bound than forward.
        for b in [32, 64, 128, 256, 512] {
            assert!(mu_dwt(b, TransformKind::Inverse) > mu_dwt(b, TransformKind::Forward));
        }
    }

    #[test]
    fn measured_spec_structure() {
        let spec = measured_spec(8, TransformKind::Forward).unwrap();
        assert_eq!(spec.regions.len(), 3);
        assert_eq!(spec.regions[0].costs.len(), 16); // 2B slices
        assert_eq!(spec.regions[1].costs.len(), 15 * 15); // (2B-1)² pairs
        assert_eq!(spec.regions[2].costs.len(), 8 * 9 / 2); // clusters
        assert!(spec.sequential_seconds() > 0.0);
        assert!(spec.regions.iter().all(|r| r.costs.iter().all(|&c| c >= 0.0)));
    }

    #[test]
    fn analytic_matches_measured_order_of_magnitude() {
        let rates = FittedRates::fit(8, TransformKind::Forward).unwrap();
        let analytic = analytic_spec(8, TransformKind::Forward, &rates);
        let measured = measured_spec(8, TransformKind::Forward).unwrap();
        let a = analytic.sequential_seconds();
        let m = measured.sequential_seconds();
        // Same workload, rates fitted at the same b: totals should agree
        // closely (package-level shapes differ slightly).
        assert!(
            (a / m - 1.0).abs() < 0.5,
            "analytic {a} vs measured {m}"
        );
    }

    #[test]
    fn analytic_scales_like_b4() {
        let rates = FittedRates::fit(8, TransformKind::Forward).unwrap();
        let t16 = analytic_spec(16, TransformKind::Forward, &rates).sequential_seconds();
        let t32 = analytic_spec(32, TransformKind::Forward, &rates).sequential_seconds();
        let ratio = t32 / t16;
        // DWT dominates asymptotically: ~16× per doubling.
        assert!(ratio > 8.0 && ratio < 20.0, "ratio {ratio}");
    }
}

//! Speedup / runtime / efficiency curves — the series behind the paper's
//! Figs. 2, 3 and 4.

use crate::simulator::machine::{simulate_transform, MachineParams, TransformSpec};

/// One point of a scaling curve.
#[derive(Debug, Clone, Copy)]
pub struct ScalingPoint {
    /// Core count of this point.
    pub cores: usize,
    /// Simulated (or measured) seconds.
    pub seconds: f64,
    /// Speedup relative to one core.
    pub speedup: f64,
    /// Parallel efficiency (`speedup / cores`).
    pub efficiency: f64,
}

/// Simulate the spec across `cores_list`; speedup is measured against the
/// simulated single-core run (which equals the measured sequential time
/// by construction — the paper's methodology).
pub fn scaling_curve(
    spec: &TransformSpec,
    cores_list: &[usize],
    params: &MachineParams,
) -> Vec<ScalingPoint> {
    let t1 = simulate_transform(spec, 1, params);
    cores_list
        .iter()
        .map(|&p| {
            let tp = simulate_transform(spec, p, params);
            ScalingPoint {
                cores: p,
                seconds: tp,
                speedup: t1 / tp,
                efficiency: t1 / tp / p as f64,
            }
        })
        .collect()
}

/// The paper's core counts: 1, then 2..64.
pub fn paper_core_counts() -> Vec<usize> {
    let mut v = vec![1usize];
    v.extend([2, 4, 8, 16, 24, 32, 40, 48, 56, 64]);
    v
}

/// One bandwidth's scaling series for one transform direction.
#[derive(Debug, Clone)]
pub struct FigureSeries {
    /// Transform bandwidth B.
    pub b: usize,
    /// Forward or inverse transform.
    pub kind: crate::simulator::cost::TransformKind,
    /// Whether the points are measured (vs. simulated).
    pub measured: bool,
    /// The scaling curve.
    pub points: Vec<ScalingPoint>,
}

/// Build the full data set behind Figs. 2–4: measured specs for the
/// bandwidths this container can execute, analytic extrapolation (rates
/// fitted at `fit_b`) for the large ones.
pub fn figure_series(
    measured_bs: &[usize],
    analytic_bs: &[usize],
    fit_b: usize,
    cores: &[usize],
    params: &MachineParams,
) -> crate::error::Result<Vec<FigureSeries>> {
    use crate::simulator::cost::{analytic_spec, measured_spec, FittedRates, TransformKind};
    let mut out = Vec::new();
    for kind in [TransformKind::Forward, TransformKind::Inverse] {
        let rates = FittedRates::fit(fit_b, kind)?;
        for &b in measured_bs {
            let spec = measured_spec(b, kind)?;
            out.push(FigureSeries {
                b,
                kind,
                measured: true,
                points: scaling_curve(&spec, cores, params),
            });
        }
        for &b in analytic_bs {
            let spec = analytic_spec(b, kind, &rates);
            out.push(FigureSeries {
                b,
                kind,
                measured: false,
                points: scaling_curve(&spec, cores, params),
            });
        }
    }
    Ok(out)
}

/// The paper's published 64-core speedups (§4/§5) — the calibration and
/// validation targets.
pub fn paper_speedup_64(b: usize, kind: crate::simulator::cost::TransformKind) -> Option<f64> {
    use crate::simulator::cost::TransformKind;
    match (kind, b) {
        (TransformKind::Forward, 128) => Some(29.57),
        (TransformKind::Forward, 256) => Some(36.86),
        (TransformKind::Forward, 512) => Some(34.36),
        (TransformKind::Inverse, 128) => Some(24.57),
        (TransformKind::Inverse, 256) => Some(26.69),
        (TransformKind::Inverse, 512) => Some(24.25),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::Schedule;
    use crate::simulator::machine::RegionSpec;

    fn spec(n: usize, mu: f64) -> TransformSpec {
        TransformSpec {
            regions: vec![RegionSpec {
                costs: vec![1e-4; n],
                mem_fraction: mu,
                schedule: Schedule::PAPER,
            }],
            serial: 0.0,
            label: "t".into(),
        }
    }

    #[test]
    fn curve_shape_rises_then_plateaus() {
        let params = MachineParams::opteron_like();
        let curve = scaling_curve(&spec(4096, 0.35), &paper_core_counts(), &params);
        // Monotone non-decreasing speedup.
        for w in curve.windows(2) {
            assert!(w[1].speedup >= w[0].speedup * 0.98);
        }
        // Near-linear early...
        let s8 = curve.iter().find(|p| p.cores == 8).unwrap().speedup;
        assert!(s8 > 6.5, "8-core speedup {s8}");
        // ...sublinear late.
        let s64 = curve.iter().find(|p| p.cores == 64).unwrap().speedup;
        assert!(s64 < 50.0 && s64 > 15.0, "64-core speedup {s64}");
        // Efficiency decreases.
        let e2 = curve.iter().find(|p| p.cores == 2).unwrap().efficiency;
        let e64 = curve.iter().find(|p| p.cores == 64).unwrap().efficiency;
        assert!(e2 > e64);
    }

    #[test]
    fn speedup_at_one_core_is_one() {
        let params = MachineParams::opteron_like();
        let curve = scaling_curve(&spec(100, 0.5), &[1], &params);
        assert!((curve[0].speedup - 1.0).abs() < 1e-12);
        assert!((curve[0].efficiency - 1.0).abs() < 1e-12);
    }

    #[test]
    fn higher_mu_lower_plateau() {
        let params = MachineParams::opteron_like();
        let lo = scaling_curve(&spec(4096, 0.2), &[64], &params)[0].speedup;
        let hi = scaling_curve(&spec(4096, 0.7), &[64], &params)[0].speedup;
        assert!(lo > hi, "mu=0.2 → {lo} must beat mu=0.7 → {hi}");
    }
}

//! The discrete-event machine model.

use crate::pool::Schedule;

/// One parallel region: a bag of packages with their sequential costs.
#[derive(Debug, Clone)]
pub struct RegionSpec {
    /// Sequential cost (seconds on one core of the reference machine) of
    /// each package, in schedule order.
    pub costs: Vec<f64>,
    /// Memory-boundedness μ ∈ [0, 1]: the fraction of each package's time
    /// that scales with memory bandwidth rather than core count.
    pub mem_fraction: f64,
    /// Scheduling discipline for this region.
    pub schedule: Schedule,
}

/// A full transform: regions executed back to back, plus any purely
/// serial time between them.
#[derive(Debug, Clone)]
pub struct TransformSpec {
    /// The parallel regions of the transform, in order.
    pub regions: Vec<RegionSpec>,
    /// Serial (non-parallelizable) seconds outside the regions.
    pub serial: f64,
    /// Human label ("fsoft b=128" etc.) for reports.
    pub label: String,
}

impl TransformSpec {
    /// Sequential total (the simulator's p = 1 wall time, by construction).
    pub fn sequential_seconds(&self) -> f64 {
        self.serial
            + self
                .regions
                .iter()
                .map(|r| r.costs.iter().sum::<f64>())
                .sum::<f64>()
    }
}

/// Machine parameters for the simulated shared-memory node.
#[derive(Debug, Clone)]
pub struct MachineParams {
    /// Cost of one dynamic-schedule claim (atomic RMW + cache transfer).
    pub dispatch_overhead: f64,
    /// Fork/join barrier cost per parallel region, per core involved.
    pub region_barrier: f64,
    /// Active cores that saturate the socket's memory bandwidth; beyond
    /// this the memory-bound fraction of package time stops scaling.
    pub bw_cores: f64,
}

impl MachineParams {
    /// Calibrated against the paper's AMD Opteron 6272 results (64-core
    /// speedups: FSOFT 29.57/36.86/34.36 and iFSOFT 24.57/26.69/24.25 for
    /// B = 128/256/512 — see EXPERIMENTS.md for the calibration log).
    pub fn opteron_like() -> Self {
        Self {
            dispatch_overhead: 0.3e-6,
            region_barrier: 6.0e-6,
            bw_cores: 18.0,
        }
    }

    /// An ideal PRAM-like machine (no overheads) — for tests and the
    /// work-optimality check.
    pub fn ideal() -> Self {
        Self {
            dispatch_overhead: 0.0,
            region_barrier: 0.0,
            bw_cores: f64::INFINITY,
        }
    }
}

/// Contention-scaled cost of a package when `p` cores are active.
#[inline]
fn scaled_cost(cost: f64, mem_fraction: f64, p: usize, params: &MachineParams) -> f64 {
    let congestion = (p as f64 / params.bw_cores).max(1.0);
    cost * ((1.0 - mem_fraction) + mem_fraction * congestion)
}

/// Simulate one region on `p` cores; returns the region wall time.
pub fn simulate_region(region: &RegionSpec, p: usize, params: &MachineParams) -> f64 {
    assert!(p >= 1);
    let n = region.costs.len();
    if n == 0 {
        return 0.0;
    }
    if p == 1 {
        // One core: no contention, no dispatch contention, no barrier —
        // matches the measured sequential run by construction.
        return region.costs.iter().sum();
    }
    let barrier = params.region_barrier * p as f64 / 64.0 + params.region_barrier;
    match region.schedule {
        Schedule::Dynamic { chunk } => {
            let chunk = chunk.max(1);
            // List scheduling: the next chunk goes to the earliest-free
            // core (exactly what the atomic-cursor pool does, modulo
            // claim-order nondeterminism that does not affect makespan
            // materially for chunk-ordered claims).
            let mut clocks = vec![0.0f64; p];
            let mut i = 0usize;
            while i < n {
                // Earliest-free core (p ≤ 64: linear scan is fine).
                let (core, _) = clocks
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                let end = (i + chunk).min(n);
                let mut t = params.dispatch_overhead;
                for c in &region.costs[i..end] {
                    t += scaled_cost(*c, region.mem_fraction, p, params);
                }
                clocks[core] += t;
                i = end;
            }
            clocks.iter().cloned().fold(0.0, f64::max) + barrier
        }
        Schedule::Static => {
            // Contiguous blocks.
            let per = n.div_ceil(p);
            let mut makespan = 0.0f64;
            for t in 0..p {
                let lo = t * per;
                let hi = ((t + 1) * per).min(n);
                if lo >= hi {
                    continue;
                }
                let sum: f64 = region.costs[lo..hi]
                    .iter()
                    .map(|c| scaled_cost(*c, region.mem_fraction, p, params))
                    .sum();
                makespan = makespan.max(sum);
            }
            makespan + barrier
        }
        Schedule::StaticInterleaved => {
            let mut makespan = 0.0f64;
            for t in 0..p {
                let sum: f64 = region.costs[t..]
                    .iter()
                    .step_by(p)
                    .map(|c| scaled_cost(*c, region.mem_fraction, p, params))
                    .sum();
                makespan = makespan.max(sum);
            }
            makespan + barrier
        }
        Schedule::Guided { min_chunk } => {
            // Approximate guided as dynamic with the min chunk (guided's
            // large head chunks only matter for very long regions).
            let approx = RegionSpec {
                costs: region.costs.clone(),
                mem_fraction: region.mem_fraction,
                schedule: Schedule::Dynamic {
                    chunk: min_chunk.max(1),
                },
            };
            simulate_region(&approx, p, params)
        }
    }
}

/// Simulate the whole transform on `p` cores.
pub fn simulate_transform(spec: &TransformSpec, p: usize, params: &MachineParams) -> f64 {
    spec.serial
        + spec
            .regions
            .iter()
            .map(|r| simulate_region(r, p, params))
            .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_region(n: usize, cost: f64, mu: f64) -> RegionSpec {
        RegionSpec {
            costs: vec![cost; n],
            mem_fraction: mu,
            schedule: Schedule::Dynamic { chunk: 1 },
        }
    }

    #[test]
    fn one_core_equals_sequential_sum() {
        let r = uniform_region(100, 1e-3, 0.5);
        let params = MachineParams::opteron_like();
        let t = simulate_region(&r, 1, &params);
        assert!((t - 0.1).abs() < 1e-12);
    }

    #[test]
    fn ideal_machine_scales_linearly_on_uniform_load() {
        let r = uniform_region(6400, 1e-4, 0.0);
        let params = MachineParams::ideal();
        let t1 = simulate_region(&r, 1, &params);
        for p in [2usize, 4, 8, 16, 64] {
            let tp = simulate_region(&r, p, &params);
            let s = t1 / tp;
            assert!(
                (s - p as f64).abs() < 0.05 * p as f64,
                "p={p}: speedup {s}"
            );
        }
    }

    #[test]
    fn contention_caps_speedup() {
        let mut params = MachineParams::ideal();
        params.bw_cores = 8.0;
        let r = uniform_region(6400, 1e-4, 1.0); // fully memory-bound
        let t1 = simulate_region(&r, 1, &params);
        let t64 = simulate_region(&r, 64, &params);
        let s = t1 / t64;
        assert!(s < 8.5, "fully memory-bound speedup {s} must cap near bw_cores");
    }

    #[test]
    fn imbalance_limits_makespan() {
        // One giant package dominates: speedup ≤ total/max regardless of p.
        let mut costs = vec![1e-4; 100];
        costs[0] = 1e-2;
        let r = RegionSpec {
            costs,
            mem_fraction: 0.0,
            schedule: Schedule::Dynamic { chunk: 1 },
        };
        let params = MachineParams::ideal();
        let t1 = simulate_region(&r, 1, &params);
        let t64 = simulate_region(&r, 64, &params);
        assert!(t64 >= 1e-2 - 1e-12, "critical path bound");
        assert!(t1 / t64 <= 2.1, "speedup bounded by the giant package");
    }

    #[test]
    fn dynamic_beats_static_on_skewed_load() {
        // Decreasing costs + static blocks = first core overloaded.
        let costs: Vec<f64> = (0..64).map(|i| 1e-3 / (1.0 + i as f64)).collect();
        let params = MachineParams::ideal();
        let dynamic = RegionSpec {
            costs: costs.clone(),
            mem_fraction: 0.0,
            schedule: Schedule::Dynamic { chunk: 1 },
        };
        let stat = RegionSpec {
            costs,
            mem_fraction: 0.0,
            schedule: Schedule::Static,
        };
        let td = simulate_region(&dynamic, 8, &params);
        let ts = simulate_region(&stat, 8, &params);
        assert!(td < ts, "dynamic {td} should beat static {ts} on skew");
    }

    #[test]
    fn dispatch_overhead_hurts_tiny_packages() {
        let mut params = MachineParams::ideal();
        params.dispatch_overhead = 1e-5;
        // Packages of 1µs each: overhead 10× the work.
        let r = uniform_region(1000, 1e-6, 0.0);
        let t1 = simulate_region(&r, 1, &params); // p=1 path has no overhead
        let t8 = simulate_region(&r, 8, &params);
        let s = t1 / t8;
        assert!(s < 1.0, "dispatch-dominated region must not speed up: {s}");
    }

    #[test]
    fn transform_composes_regions_and_serial() {
        let spec = TransformSpec {
            regions: vec![uniform_region(10, 1e-3, 0.0), uniform_region(20, 5e-4, 0.0)],
            serial: 1e-3,
            label: "test".into(),
        };
        let params = MachineParams::ideal();
        let t1 = simulate_transform(&spec, 1, &params);
        assert!((t1 - spec.sequential_seconds()).abs() < 1e-12);
        let t2 = simulate_transform(&spec, 2, &params);
        // Serial part doesn't scale.
        assert!(t2 > spec.serial);
        assert!(t2 < t1);
    }
}

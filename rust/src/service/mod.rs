//! [`So3Service`] — the multi-tenant serving front door.
//!
//! [`So3Plan`] is the power-user path: one caller, one plan, one
//! workspace, explicit buffers. A serving process has the opposite
//! shape — **many concurrent callers, mixed bandwidths, no caller-owned
//! infrastructure** — and this module packages that as one object:
//!
//! * one shared [`WorkerPool`] executes every plan's parallel regions
//!   (workers are spawned once, per-worker kernel scratch stays pinned);
//! * a [`PlanRegistry`] lazily builds and caches [`So3Plan`]s keyed by
//!   `(bandwidth, PlanOptions)` behind an `RwLock`, with an optional
//!   LRU byte budget over `table_bytes()`;
//! * a [`WorkspacePool`] recycles workspaces and grid/coefficient
//!   buffers per bandwidth, so the steady state allocates **nothing**
//!   per job;
//! * a typed job API — [`JobSpec`] + [`So3Service::submit`] →
//!   [`JobHandle::wait`] — runs on a small dispatcher thread that
//!   **micro-batches same-key jobs** arriving within a configurable
//!   window through the plan's `forward_batch_into` /
//!   `inverse_batch_into` (bit-identical to per-job execution, proven
//!   by `rust/tests/service_api.rs`).
//!
//! ```no_run
//! use so3ft::service::{JobSpec, So3Service};
//! use so3ft::so3::coeffs::So3Coeffs;
//!
//! let service = So3Service::builder().threads(4).build().unwrap();
//! // Blocking conveniences…
//! let grid = service.inverse(So3Coeffs::random(16, 1)).unwrap();
//! let coeffs = service.forward(grid).unwrap();
//! // …or the async job API:
//! let grid = service.inverse(coeffs).unwrap();
//! let handle = service.submit(JobSpec::forward(16), grid).unwrap();
//! let out = handle.wait().unwrap().into_coeffs().unwrap();
//! service.recycle_coeffs(out); // keep the steady state allocation-free
//! ```

pub mod job;
pub mod registry;
pub mod workspace_pool;

pub use job::{Direction, JobHandle, JobInput, JobOutput, JobPriority, JobSpec};
pub use registry::{PlanKey, PlanOptions, PlanRegistry, RegistryStats};
pub use workspace_pool::{WorkspacePool, WorkspacePoolStats};

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{TransformStats, Workspace};
use crate::error::{Error, Result};
use crate::pool::WorkerPool;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;
use crate::transform::So3Plan;
use crate::util::lock_unpoisoned as lock;
use crate::wisdom::{PlanRigor, WisdomStore};
use job::{pick_leader, JobState, QueuedJob};

struct QueueState {
    /// Pending jobs in submission order.
    jobs: VecDeque<QueuedJob>,
    shutdown: bool,
}

struct JobQueue {
    state: Mutex<QueueState>,
    /// Wakes the dispatcher on submission and on shutdown.
    cv: Condvar,
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    max_batch: AtomicUsize,
}

/// Aggregate serving counters (see [`So3Service::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServiceStats {
    pub jobs_submitted: u64,
    /// Jobs fulfilled (successfully or with an error).
    pub jobs_completed: u64,
    /// Micro-batches executed; `jobs_completed / batches` is the mean
    /// coalescing factor.
    pub batches: u64,
    /// Largest micro-batch executed so far.
    pub max_batch_size: usize,
    pub registry: RegistryStats,
    pub buffers: WorkspacePoolStats,
}

struct ServiceInner {
    threads: usize,
    pool: Option<Arc<WorkerPool>>,
    registry: PlanRegistry,
    buffers: WorkspacePool,
    queue: JobQueue,
    batch_window: Duration,
    max_batch: usize,
    allow_any_bandwidth: bool,
    default_options: PlanOptions,
    stats: Counters,
}

/// See the [module docs](self). Shareable across caller threads as
/// `Arc<So3Service>` (all entry points take `&self`); dropping the last
/// handle drains the queue and joins the dispatcher.
pub struct So3Service {
    inner: Arc<ServiceInner>,
    dispatcher: Option<JoinHandle<()>>,
}

impl So3Service {
    /// Start configuring a service.
    pub fn builder() -> So3ServiceBuilder {
        So3ServiceBuilder::new()
    }

    /// Default configuration: worker pool sized to the machine, batching
    /// of already-queued same-key jobs, unbounded registry.
    pub fn new() -> Result<Self> {
        Self::builder().build()
    }

    /// Worker-pool size (the region width every cached plan runs at).
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// The shared worker pool (`None` when `threads == 1`: plans run
    /// regions inline on the dispatcher).
    pub fn worker_pool(&self) -> Option<&Arc<WorkerPool>> {
        self.inner.pool.as_ref()
    }

    /// The plan registry (diagnostics; plans are fetched via
    /// [`Self::plan`]).
    pub fn registry(&self) -> &PlanRegistry {
        &self.inner.registry
    }

    /// The cached plan for `(bandwidth, options)` — the power-user
    /// escape hatch: callers that want explicit `*_into` execution can
    /// take the `Arc<So3Plan>` and drive it directly (it shares the
    /// service's worker pool).
    pub fn plan(&self, bandwidth: usize, options: PlanOptions) -> Result<Arc<So3Plan>> {
        self.inner.registry.get(PlanKey { bandwidth, options })
    }

    /// Submit a job. Validation (payload kind vs direction, bandwidth
    /// match, power-of-two unless the builder allowed any) happens here,
    /// synchronously — a returned handle always receives a transform
    /// result unless the plan itself fails to build.
    pub fn submit(&self, spec: JobSpec, input: impl Into<JobInput>) -> Result<JobHandle> {
        let input = input.into();
        self.validate(&spec, &input)?;
        let state = JobState::new();
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        {
            let mut st = lock(&self.inner.queue.state);
            if st.shutdown {
                return Err(Error::Service("service is shutting down".into()));
            }
            // Count before the dispatcher can possibly complete the job,
            // so `submitted >= completed` holds for every observer.
            self.inner.stats.submitted.fetch_add(1, Ordering::Relaxed);
            st.jobs.push_back(QueuedJob {
                spec,
                input,
                state,
            });
        }
        self.inner.queue.cv.notify_all();
        Ok(handle)
    }

    fn validate(&self, spec: &JobSpec, input: &JobInput) -> Result<()> {
        if spec.bandwidth == 0 {
            return Err(Error::InvalidBandwidth(0));
        }
        if !spec.bandwidth.is_power_of_two() && !self.inner.allow_any_bandwidth {
            return Err(Error::NonPowerOfTwoBandwidth(spec.bandwidth));
        }
        match (spec.direction, input) {
            (Direction::Forward, JobInput::Grid(_)) => {}
            (Direction::Inverse, JobInput::Coeffs(_)) => {}
            (direction, input) => {
                return Err(Error::Service(format!(
                    "{direction:?} job cannot take a {} payload",
                    input.kind()
                )))
            }
        }
        if input.bandwidth() != spec.bandwidth {
            return Err(Error::bandwidth(
                spec.bandwidth,
                input.bandwidth(),
                "submit: input bandwidth",
            ));
        }
        Ok(())
    }

    /// Blocking analysis with the service's default options: submit,
    /// wait, unwrap. The input buffer is recycled into the pool.
    pub fn forward(&self, grid: So3Grid) -> Result<So3Coeffs> {
        let spec = JobSpec::forward(grid.bandwidth()).options(self.inner.default_options);
        match self.submit(spec, grid)?.wait()? {
            JobOutput::Coeffs(c) => Ok(c),
            JobOutput::Grid(_) => unreachable!("forward jobs yield coefficients"),
        }
    }

    /// Blocking synthesis with the service's default options.
    pub fn inverse(&self, coeffs: So3Coeffs) -> Result<So3Grid> {
        let spec = JobSpec::inverse(coeffs.bandwidth()).options(self.inner.default_options);
        match self.submit(spec, coeffs)?.wait()? {
            JobOutput::Grid(g) => Ok(g),
            JobOutput::Coeffs(_) => unreachable!("inverse jobs yield a grid"),
        }
    }

    /// A pooled grid buffer (contents unspecified — overwrite it). Fill
    /// and submit it; the service recycles it after execution.
    pub fn checkout_grid(&self, b: usize) -> Result<So3Grid> {
        self.inner.buffers.checkout_grid(b)
    }

    /// A pooled coefficient buffer (contents unspecified).
    pub fn checkout_coeffs(&self, b: usize) -> Result<So3Coeffs> {
        self.inner.buffers.checkout_coeffs(b)
    }

    /// Return a consumed job output to the buffer pool.
    pub fn recycle(&self, output: JobOutput) {
        match output {
            JobOutput::Grid(g) => self.inner.buffers.checkin_grid(g),
            JobOutput::Coeffs(c) => self.inner.buffers.checkin_coeffs(c),
        }
    }

    pub fn recycle_grid(&self, g: So3Grid) {
        self.inner.buffers.checkin_grid(g);
    }

    pub fn recycle_coeffs(&self, c: So3Coeffs) {
        self.inner.buffers.checkin_coeffs(c);
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            jobs_submitted: self.inner.stats.submitted.load(Ordering::Relaxed),
            jobs_completed: self.inner.stats.completed.load(Ordering::Relaxed),
            batches: self.inner.stats.batches.load(Ordering::Relaxed),
            max_batch_size: self.inner.stats.max_batch.load(Ordering::Relaxed),
            registry: self.inner.registry.stats(),
            buffers: self.inner.buffers.stats(),
        }
    }
}

impl fmt::Debug for So3Service {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("So3Service")
            .field("threads", &self.inner.threads)
            .field("batch_window", &self.inner.batch_window)
            .field("max_batch", &self.inner.max_batch)
            .field("stats", &self.stats())
            .finish()
    }
}

impl Drop for So3Service {
    /// Signal shutdown and join the dispatcher. Jobs already queued are
    /// drained (their handles resolve); new submissions are rejected.
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.queue.state);
            st.shutdown = true;
        }
        self.inner.queue.cv.notify_all();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Fluent configuration for [`So3Service`].
pub struct So3ServiceBuilder {
    threads: Option<usize>,
    shared_pool: Option<Arc<WorkerPool>>,
    batch_window: Duration,
    max_batch: usize,
    registry_budget: Option<usize>,
    default_options: PlanOptions,
    allow_any_bandwidth: bool,
    plan_rigor: PlanRigor,
    wisdom_store: Option<Arc<WisdomStore>>,
}

impl So3ServiceBuilder {
    fn new() -> Self {
        Self {
            threads: None,
            shared_pool: None,
            batch_window: Duration::ZERO,
            max_batch: 32,
            registry_budget: None,
            default_options: PlanOptions::default(),
            allow_any_bandwidth: false,
            plan_rigor: PlanRigor::Estimate,
            wisdom_store: None,
        }
    }

    /// Worker-pool size (default: the machine's available parallelism;
    /// `1` = sequential plans, no pool).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Execute on a caller-supplied shared [`WorkerPool`] instead of
    /// spawning one (also sets `threads` to the pool size).
    pub fn pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.threads = Some(pool.threads());
        self.shared_pool = Some(pool);
        self
    }

    /// How long the dispatcher holds a batch open for same-key jobs
    /// after picking its leader. `ZERO` (the default) still coalesces
    /// jobs that are *already queued* — it only skips the wait.
    pub fn batch_window(mut self, window: Duration) -> Self {
        self.batch_window = window;
        self
    }

    /// Upper bound on jobs per micro-batch (default 32, must be ≥ 1).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// LRU-evict cached plans once their summed `table_bytes()` exceeds
    /// this budget (default: unbounded).
    pub fn registry_budget_bytes(mut self, bytes: usize) -> Self {
        self.registry_budget = Some(bytes);
        self
    }

    /// Options used by the [`So3Service::forward`] /
    /// [`So3Service::inverse`] conveniences (explicit [`JobSpec`]s carry
    /// their own).
    pub fn default_options(mut self, options: PlanOptions) -> Self {
        self.default_options = options;
        self
    }

    /// Service-wide default [`MemoryBudget`](crate::coordinator::MemoryBudget),
    /// applied to the [`So3Service::forward`] / [`So3Service::inverse`]
    /// conveniences and any [`JobSpec`] built from the default options.
    ///
    /// Precedence: an explicit per-job budget
    /// ([`JobSpec::memory_budget`]) always wins over this service-level
    /// default; both default to `MemoryBudget::Auto`. Jobs with
    /// different budgets resolve to distinct registry plans.
    pub fn memory_budget(mut self, budget: crate::coordinator::MemoryBudget) -> Self {
        self.default_options.memory = budget;
        self
    }

    /// Accept non-power-of-two bandwidths (Bluestein FFT fallback).
    pub fn allow_any_bandwidth(mut self) -> Self {
        self.allow_any_bandwidth = true;
        self
    }

    /// Planning rigor for every registry build (default
    /// [`PlanRigor::Estimate`]). With [`PlanRigor::Measure`] every
    /// tenant gets measured-tuned plans; the registry's single-flight
    /// lock guarantees one measurement pass per key even under
    /// concurrent cold misses.
    pub fn plan_rigor(mut self, rigor: PlanRigor) -> Self {
        self.plan_rigor = rigor;
        self
    }

    /// The wisdom store `Measure` builds consult (default: the
    /// process-global store).
    pub fn wisdom_store(mut self, store: Arc<WisdomStore>) -> Self {
        self.wisdom_store = Some(store);
        self
    }

    pub fn build(self) -> Result<So3Service> {
        let threads = match self.threads {
            Some(0) => return Err(Error::InvalidThreads(0)),
            Some(t) => t,
            None => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        };
        if self.max_batch == 0 {
            return Err(Error::Service("max_batch must be >= 1".into()));
        }
        let pool = match self.shared_pool {
            Some(p) => Some(p),
            None if threads > 1 => Some(Arc::new(WorkerPool::new(threads)?)),
            None => None,
        };
        let inner = Arc::new(ServiceInner {
            threads,
            registry: PlanRegistry::new(
                threads,
                pool.clone(),
                self.registry_budget,
                self.allow_any_bandwidth,
                self.plan_rigor,
                self.wisdom_store,
            ),
            pool,
            buffers: WorkspacePool::new(),
            queue: JobQueue {
                state: Mutex::new(QueueState {
                    jobs: VecDeque::new(),
                    shutdown: false,
                }),
                cv: Condvar::new(),
            },
            batch_window: self.batch_window,
            max_batch: self.max_batch,
            allow_any_bandwidth: self.allow_any_bandwidth,
            default_options: self.default_options,
            stats: Counters::default(),
        });
        let dispatcher_inner = Arc::clone(&inner);
        let dispatcher = std::thread::Builder::new()
            .name("so3ft-service".into())
            .spawn(move || dispatcher_loop(&dispatcher_inner))
            .map_err(Error::Io)?;
        Ok(So3Service {
            inner,
            dispatcher: Some(dispatcher),
        })
    }
}

// ----------------------------------------------------------------------
// Dispatcher
// ----------------------------------------------------------------------

fn dispatcher_loop(inner: &ServiceInner) {
    while let Some(batch) = next_batch(inner) {
        execute_batch(inner, batch);
    }
}

/// Block for work, pick the leading job (priority, then FIFO), hold the
/// batch open for the window, and drain every queued job sharing the
/// leader's `(direction, bandwidth, options)` key in submission order.
/// `None` once the queue is drained after shutdown.
fn next_batch(inner: &ServiceInner) -> Option<Vec<QueuedJob>> {
    let queue = &inner.queue;
    let mut st = lock(&queue.state);
    loop {
        if !st.jobs.is_empty() {
            break;
        }
        if st.shutdown {
            return None;
        }
        st = queue.cv.wait(st).unwrap_or_else(|p| p.into_inner());
    }
    let lead = pick_leader(&st.jobs).expect("queue is non-empty");
    let key = st.jobs[lead].spec.batch_key();
    if !inner.batch_window.is_zero() && !st.shutdown {
        // Micro-batch window: wait for more same-key arrivals (the cv
        // releases the lock, so submitters get in). Cut short on
        // shutdown or once the batch is full.
        let deadline = Instant::now() + inner.batch_window;
        loop {
            let matching = st.jobs.iter().filter(|j| j.spec.batch_key() == key).count();
            if matching >= inner.max_batch || st.shutdown {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = queue
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
        }
    }
    // The leader joins its batch FIRST — under a hot key with more than
    // `max_batch` earlier same-key jobs queued, a FIFO-only drain would
    // leave the high-priority leader behind and void its priority.
    // (`lead` is still valid: the window wait only `push_back`s.)
    let mut batch = Vec::new();
    if let Some(job) = st.jobs.remove(lead) {
        batch.push(job);
    }
    let mut rest = VecDeque::with_capacity(st.jobs.len());
    while let Some(job) = st.jobs.pop_front() {
        if batch.len() < inner.max_batch && job.spec.batch_key() == key {
            batch.push(job);
        } else {
            rest.push_back(job);
        }
    }
    st.jobs = rest;
    Some(batch)
}

fn execute_batch(inner: &ServiceInner, batch: Vec<QueuedJob>) {
    let spec = batch[0].spec;
    inner.stats.batches.fetch_add(1, Ordering::Relaxed);
    inner
        .stats
        .max_batch
        .fetch_max(batch.len(), Ordering::Relaxed);

    let plan = match inner.plan_for(&spec) {
        Ok(plan) => plan,
        Err(e) => return fail_batch(inner, batch, format!("plan build failed: {e}")),
    };
    let ws = match inner.buffers.checkout_workspace(spec.bandwidth) {
        Ok(ws) => ws,
        Err(e) => return fail_batch(inner, batch, format!("workspace checkout failed: {e}")),
    };
    // Buffers go back to the pool (and the workspace is checked in)
    // *before* the handles resolve, so a caller that waits and then
    // checks a buffer out is guaranteed the pooled allocation —
    // the pointer-stability contract the serving tests pin.
    let (states, results) = run_batch(inner, &plan, ws, batch);
    debug_assert_eq!(states.len(), results.len());
    for (state, result) in states.iter().zip(results) {
        // Count before waking the waiter: a caller whose `wait` just
        // returned must observe its own job in `jobs_completed`.
        inner.stats.completed.fetch_add(1, Ordering::Relaxed);
        state.fulfill(result);
    }
}

impl ServiceInner {
    fn plan_for(&self, spec: &JobSpec) -> Result<Arc<So3Plan>> {
        self.registry.get(PlanKey {
            bandwidth: spec.bandwidth,
            options: spec.options,
        })
    }
}

/// Per-job results paired with the completion slots to fulfill.
type BatchOutcome = (Vec<Arc<JobState>>, Vec<Result<JobOutput>>);

/// The direction-specific types and hooks of one micro-batch. Two
/// zero-sized impls keep [`run_batch_dir`] generic instead of
/// duplicating the unpack -> checkout -> execute -> recycle sequence
/// once per payload type.
trait BatchDir {
    type In;
    type Out;
    fn unpack(input: JobInput) -> Self::In;
    fn checkout(pool: &WorkspacePool, b: usize) -> Result<Self::Out>;
    fn recycle_in(pool: &WorkspacePool, x: Self::In);
    fn recycle_out(pool: &WorkspacePool, x: Self::Out);
    fn wrap(out: Self::Out) -> JobOutput;
    fn batch(
        plan: &So3Plan,
        ins: &[Self::In],
        outs: &mut [Self::Out],
        ws: &mut Workspace,
    ) -> Result<()>;
    fn single(
        plan: &So3Plan,
        input: &Self::In,
        out: &mut Self::Out,
        ws: &mut Workspace,
    ) -> Result<TransformStats>;
}

/// Analysis (FSOFT): grid payloads -> coefficient outputs.
struct Fwd;

impl BatchDir for Fwd {
    type In = So3Grid;
    type Out = So3Coeffs;

    fn unpack(input: JobInput) -> So3Grid {
        match input {
            JobInput::Grid(g) => g,
            JobInput::Coeffs(_) => unreachable!("payload kind validated at submit"),
        }
    }

    fn checkout(pool: &WorkspacePool, b: usize) -> Result<So3Coeffs> {
        pool.checkout_coeffs(b)
    }

    fn recycle_in(pool: &WorkspacePool, g: So3Grid) {
        pool.checkin_grid(g);
    }

    fn recycle_out(pool: &WorkspacePool, c: So3Coeffs) {
        pool.checkin_coeffs(c);
    }

    fn wrap(out: So3Coeffs) -> JobOutput {
        JobOutput::Coeffs(out)
    }

    fn batch(
        plan: &So3Plan,
        ins: &[So3Grid],
        outs: &mut [So3Coeffs],
        ws: &mut Workspace,
    ) -> Result<()> {
        plan.forward_batch_into(ins, outs, ws)
    }

    fn single(
        plan: &So3Plan,
        input: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        plan.forward_into(input, out, ws)
    }
}

/// Synthesis (iFSOFT): coefficient payloads -> grid outputs.
struct Inv;

impl BatchDir for Inv {
    type In = So3Coeffs;
    type Out = So3Grid;

    fn unpack(input: JobInput) -> So3Coeffs {
        match input {
            JobInput::Coeffs(c) => c,
            JobInput::Grid(_) => unreachable!("payload kind validated at submit"),
        }
    }

    fn checkout(pool: &WorkspacePool, b: usize) -> Result<So3Grid> {
        pool.checkout_grid(b)
    }

    fn recycle_in(pool: &WorkspacePool, c: So3Coeffs) {
        pool.checkin_coeffs(c);
    }

    fn recycle_out(pool: &WorkspacePool, g: So3Grid) {
        pool.checkin_grid(g);
    }

    fn wrap(out: So3Grid) -> JobOutput {
        JobOutput::Grid(out)
    }

    fn batch(
        plan: &So3Plan,
        ins: &[So3Coeffs],
        outs: &mut [So3Grid],
        ws: &mut Workspace,
    ) -> Result<()> {
        plan.inverse_batch_into(ins, outs, ws)
    }

    fn single(
        plan: &So3Plan,
        input: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        plan.inverse_into(input, out, ws)
    }
}

/// Execute one micro-batch on pooled buffers: the whole batch through
/// the plan's `*_batch_into` fast path, falling back to per-job
/// execution on failure so one bad payload (or a kernel panic it
/// triggers — caught here, the dispatcher survives) cannot fail its
/// batch neighbors. Inputs are recycled and the workspace returned in
/// every path, before any handle resolves.
fn run_batch(
    inner: &ServiceInner,
    plan: &So3Plan,
    mut ws: Workspace,
    batch: Vec<QueuedJob>,
) -> BatchOutcome {
    let outcome = match batch[0].spec.direction {
        Direction::Forward => run_batch_dir::<Fwd>(inner, plan, &mut ws, batch),
        Direction::Inverse => run_batch_dir::<Inv>(inner, plan, &mut ws, batch),
    };
    inner.buffers.checkin_workspace(ws);
    outcome
}

fn run_batch_dir<D: BatchDir>(
    inner: &ServiceInner,
    plan: &So3Plan,
    ws: &mut Workspace,
    batch: Vec<QueuedJob>,
) -> BatchOutcome {
    let b = batch[0].spec.bandwidth;
    let n = batch.len();
    let mut states = Vec::with_capacity(n);
    let mut ins = Vec::with_capacity(n);
    for job in batch {
        ins.push(D::unpack(job.input));
        states.push(job.state);
    }
    // Pooled outputs. Checkout cannot fail for the b >= 1 validated at
    // submit; the graceful branch keeps the dispatcher alive anyway.
    let outs: Result<Vec<D::Out>> = (0..n).map(|_| D::checkout(&inner.buffers, b)).collect();
    let mut outs = match outs {
        Ok(outs) => outs,
        Err(e) => {
            for input in ins {
                D::recycle_in(&inner.buffers, input);
            }
            let msg = format!("output buffer checkout failed: {e}");
            let results = states
                .iter()
                .map(|_| Err(Error::Service(msg.clone())))
                .collect();
            return (states, results);
        }
    };
    // Fast path: the whole batch through one `*_batch_into` call.
    let batch_ok = matches!(
        catch_unwind(AssertUnwindSafe(|| D::batch(plan, &ins, &mut outs, ws))),
        Ok(Ok(()))
    );
    let results: Vec<Result<JobOutput>> = if batch_ok {
        outs.into_iter().map(|out| Ok(D::wrap(out))).collect()
    } else {
        // Per-job isolation: rerun each job individually so every
        // handle gets its own typed outcome. Outputs are fully
        // overwritten per run, so any partial batch state is moot.
        ins.iter()
            .zip(outs)
            .map(|(input, mut out)| {
                let run =
                    catch_unwind(AssertUnwindSafe(|| D::single(plan, input, &mut out, ws)));
                match run {
                    Ok(Ok(_stats)) => Ok(D::wrap(out)),
                    Ok(Err(e)) => {
                        D::recycle_out(&inner.buffers, out);
                        Err(Error::Service(format!("job execution failed: {e}")))
                    }
                    Err(_) => {
                        D::recycle_out(&inner.buffers, out);
                        Err(Error::Service("job execution panicked".into()))
                    }
                }
            })
            .collect()
    };
    for input in ins {
        D::recycle_in(&inner.buffers, input);
    }
    (states, results)
}

/// Fail every job of a batch with one (cloned) service error.
fn fail_batch(inner: &ServiceInner, batch: Vec<QueuedJob>, msg: String) {
    for job in batch {
        // Recycle the payloads: the buffers are reusable even though
        // the jobs failed.
        match job.input {
            JobInput::Grid(g) => inner.buffers.checkin_grid(g),
            JobInput::Coeffs(c) => inner.buffers.checkin_coeffs(c),
        }
        inner.stats.completed.fetch_add(1, Ordering::Relaxed);
        job.state.fulfill(Err(Error::Service(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_validation() {
        let service = So3Service::builder().threads(1).build().unwrap();
        assert_eq!(service.threads(), 1);
        assert!(service.worker_pool().is_none());
        assert!(matches!(
            So3Service::builder().threads(0).build(),
            Err(Error::InvalidThreads(0))
        ));
        assert!(So3Service::builder()
            .threads(1)
            .max_batch(0)
            .build()
            .is_err());
        let par = So3Service::builder().threads(2).build().unwrap();
        assert_eq!(par.worker_pool().unwrap().threads(), 2);
    }

    #[test]
    fn shared_pool_is_adopted() {
        let pool = Arc::new(WorkerPool::new(2).unwrap());
        let service = So3Service::builder()
            .pool(Arc::clone(&pool))
            .build()
            .unwrap();
        assert_eq!(service.threads(), 2);
        assert!(Arc::ptr_eq(service.worker_pool().unwrap(), &pool));
        // Cached plans run on the same pool instance.
        let plan = service.plan(4, PlanOptions::default()).unwrap();
        assert!(Arc::ptr_eq(plan.pool().unwrap(), &pool));
    }

    #[test]
    fn blocking_conveniences_roundtrip() {
        let service = So3Service::builder().threads(2).build().unwrap();
        let coeffs = So3Coeffs::random(8, 11);
        let grid = service.inverse(coeffs.clone()).unwrap();
        let back = service.forward(grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-10);
    }

    #[test]
    fn submit_validation_is_typed() {
        let service = So3Service::builder().threads(1).build().unwrap();
        // Payload kind mismatch.
        assert!(matches!(
            service.submit(JobSpec::forward(4), So3Coeffs::zeros(4)),
            Err(Error::Service(_))
        ));
        assert!(matches!(
            service.submit(JobSpec::inverse(4), So3Grid::zeros(4).unwrap()),
            Err(Error::Service(_))
        ));
        // Bandwidth mismatch between spec and payload.
        assert!(matches!(
            service.submit(JobSpec::inverse(8), So3Coeffs::zeros(4)),
            Err(Error::BandwidthMismatch { expected: 8, got: 4, .. })
        ));
        // Strict power-of-two validation (and the escape hatch).
        assert!(matches!(
            service.submit(JobSpec::inverse(6), So3Coeffs::zeros(6)),
            Err(Error::NonPowerOfTwoBandwidth(6))
        ));
        assert!(matches!(
            service.submit(JobSpec::inverse(0), So3Coeffs::zeros(4)),
            Err(Error::InvalidBandwidth(0))
        ));
        let lenient = So3Service::builder()
            .threads(1)
            .allow_any_bandwidth()
            .build()
            .unwrap();
        let g = lenient.inverse(So3Coeffs::random(6, 1)).unwrap();
        assert_eq!(g.bandwidth(), 6);
    }

    #[test]
    fn plan_build_failure_fails_the_job_not_the_service() {
        use crate::dwt::{DwtAlgorithm, Precision};
        let service = So3Service::builder().threads(1).build().unwrap();
        // clenshaw + extended is rejected at Executor::new — the plan
        // build fails inside the dispatcher, after submit validation.
        let bad = PlanOptions {
            algorithm: DwtAlgorithm::Clenshaw,
            precision: Precision::Extended,
            ..PlanOptions::default()
        };
        let handle = service
            .submit(JobSpec::inverse(4).options(bad), So3Coeffs::zeros(4))
            .unwrap();
        assert!(matches!(handle.wait(), Err(Error::Service(_))));
        // The dispatcher survives and keeps serving.
        let grid = service.inverse(So3Coeffs::random(4, 2)).unwrap();
        assert_eq!(grid.bandwidth(), 4);
    }

    #[test]
    fn drop_drains_pending_jobs() {
        let service = So3Service::builder().threads(1).build().unwrap();
        let handles: Vec<JobHandle> = (0..4)
            .map(|i| {
                service
                    .submit(JobSpec::inverse(4), So3Coeffs::random(4, i))
                    .unwrap()
            })
            .collect();
        drop(service);
        for h in handles {
            assert!(h.wait().is_ok(), "queued jobs must resolve across drop");
        }
    }

    #[test]
    fn service_memory_budget_default_flows_to_convenience_jobs() {
        use crate::coordinator::MemoryBudget;
        let service = So3Service::builder()
            .threads(1)
            .memory_budget(MemoryBudget::Unlimited)
            .build()
            .unwrap();
        let coeffs = So3Coeffs::random(4, 3);
        let grid = service.inverse(coeffs).unwrap();
        let _ = service.forward(grid).unwrap();
        // The conveniences built exactly one plan, under the default
        // budget; re-fetching under that key hits the cache.
        let plan = service.plan(4, service.inner.default_options).unwrap();
        assert_eq!(plan.memory_report().budget, MemoryBudget::Unlimited);
        assert_eq!(service.registry().stats().plans, 1);
    }

    #[test]
    fn stats_count_batches_and_jobs() {
        let service = So3Service::builder().threads(1).build().unwrap();
        for i in 0..3 {
            let _ = service.inverse(So3Coeffs::random(4, i)).unwrap();
        }
        let s = service.stats();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_completed, 3);
        assert!(s.batches >= 1 && s.batches <= 3);
        assert!(s.max_batch_size >= 1);
        assert_eq!(s.registry.plans, 1);
    }
}

//! [`So3Service`] — the multi-tenant serving front door.
//!
//! [`So3Plan`] is the power-user path: one caller, one plan, one
//! workspace, explicit buffers. A serving process has the opposite
//! shape — **many concurrent callers, mixed bandwidths, no caller-owned
//! infrastructure** — and this module packages that as one object:
//!
//! * one shared [`WorkerPool`] executes every plan's parallel regions
//!   (workers are spawned once, per-worker kernel scratch stays pinned);
//! * a [`PlanRegistry`] lazily builds and caches [`So3Plan`]s keyed by
//!   `(bandwidth, PlanOptions)` behind an `RwLock`, with an optional
//!   LRU byte budget over `table_bytes()`;
//! * a [`WorkspacePool`] recycles workspaces and grid/coefficient
//!   buffers per bandwidth, so the steady state allocates **nothing**
//!   per job;
//! * a typed job API — [`JobSpec`] + [`So3Service::submit`] →
//!   [`JobHandle::wait`] — runs on a small dispatcher thread that
//!   **micro-batches same-key jobs** arriving within a configurable
//!   window through the plan's `forward_batch_into` /
//!   `inverse_batch_into` (bit-identical to per-job execution, proven
//!   by `rust/tests/service_api.rs`).
//!
//! # Overload and failure behavior
//!
//! The service is hardened for saturation and partial failure:
//!
//! * **bounded admission** — optional queue-depth, in-flight-bytes, and
//!   per-tenant caps ([`So3ServiceBuilder::max_queue`] /
//!   [`max_inflight_bytes`](So3ServiceBuilder::max_inflight_bytes) /
//!   [`tenant_quota`](So3ServiceBuilder::tenant_quota)) turn overload
//!   into an immediate typed
//!   [`Error::Overloaded`](crate::error::Error::Overloaded) with a
//!   backlog-derived `retry_after_hint`, instead of unbounded queueing;
//! * **deadlines and cancellation** — [`JobSpec::deadline`] (or the
//!   service-wide [`default_deadline`](So3ServiceBuilder::default_deadline))
//!   expires still-queued jobs without executing them, and
//!   [`JobHandle::cancel`] / [`JobHandle::try_wait`] give callers a
//!   non-blocking surface;
//! * **graceful degradation** — a watchdog restarts the dispatcher
//!   after a panic with the queue intact, failed plan builds are cached
//!   with exponential backoff
//!   ([`PlanRegistry::set_build_backoff`]), and
//!   [`So3Service::shutdown`] drains with a deadline, resolving every
//!   outstanding handle with its result or
//!   [`Error::ShutdownDrain`](crate::error::Error::ShutdownDrain);
//! * **observability** — [`So3Service::metrics`] snapshots queue depth,
//!   rejections by cause, batch occupancy, and per-bandwidth p50/p99.
//!
//! See `docs/PERF.md` ("Failure semantics & overload behavior") for the
//! admission math and the full rejection taxonomy, and
//! [`crate::faults`] for the deterministic fault-injection sites the
//! chaos suite drives.
//!
//! ```no_run
//! use so3ft::service::{JobSpec, So3Service};
//! use so3ft::so3::coeffs::So3Coeffs;
//!
//! let service = So3Service::builder().threads(4).build().unwrap();
//! // Blocking conveniences…
//! let grid = service.inverse(So3Coeffs::random(16, 1)).unwrap();
//! let coeffs = service.forward(grid).unwrap();
//! // …or the async job API:
//! let grid = service.inverse(coeffs).unwrap();
//! let handle = service.submit(JobSpec::forward(16), grid).unwrap();
//! let out = handle.wait().unwrap().into_coeffs().unwrap();
//! service.recycle_coeffs(out); // keep the steady state allocation-free
//! ```

mod admission;
pub mod job;
pub mod metrics;
pub mod registry;
pub mod workspace_pool;

pub use job::{Direction, JobHandle, JobInput, JobOutput, JobPriority, JobSpec, TryWait};
pub use metrics::{BandwidthLatency, RejectionCounts, ServiceMetrics};
pub use registry::{PlanKey, PlanOptions, PlanRegistry, RegistryStats};
pub use workspace_pool::{WorkspacePool, WorkspacePoolStats, MAX_FREE_PER_KEY};

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{TransformStats, Workspace};
use crate::error::{Error, OverloadCause, Result};
use crate::faults;
use crate::pool::WorkerPool;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;
use crate::transform::So3Plan;
use crate::util::lock_unpoisoned as lock;
use crate::wisdom::{PlanRigor, WisdomStore};
use admission::{job_cost_bytes, Admission};
use job::{pick_leader, JobState, QueuedJob};
use metrics::LatencyHistogram;

struct QueueState {
    /// Pending jobs in submission order.
    jobs: VecDeque<QueuedJob>,
    shutdown: bool,
}

struct JobQueue {
    state: Mutex<QueueState>,
    /// Wakes the dispatcher on submission and on shutdown.
    cv: Condvar,
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    max_batch: AtomicUsize,
    rejected_queue: AtomicU64,
    rejected_bytes: AtomicU64,
    rejected_tenant: AtomicU64,
    deadline_expired: AtomicU64,
    cancelled: AtomicU64,
    shutdown_aborted: AtomicU64,
    dispatcher_restarts: AtomicU64,
}

impl Counters {
    fn count_rejection(&self, cause: OverloadCause) {
        let counter = match cause {
            OverloadCause::QueueDepth => &self.rejected_queue,
            OverloadCause::InflightBytes => &self.rejected_bytes,
            OverloadCause::TenantQuota => &self.rejected_tenant,
        };
        // ordering: Relaxed — standalone rejection tally for metrics.
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// Aggregate serving counters (see [`So3Service::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServiceStats {
    /// Jobs admitted since startup.
    pub jobs_submitted: u64,
    /// Jobs fulfilled (successfully or with an error).
    pub jobs_completed: u64,
    /// Micro-batches executed; `jobs_completed / batches` is the mean
    /// coalescing factor.
    pub batches: u64,
    /// Largest micro-batch executed so far.
    pub max_batch_size: usize,
    /// Plan-registry counters.
    pub registry: RegistryStats,
    /// Workspace/buffer-pool counters.
    pub buffers: WorkspacePoolStats,
}

struct ServiceInner {
    threads: usize,
    pool: Option<Arc<WorkerPool>>,
    registry: PlanRegistry,
    /// Shared (`Arc`) so abandoned `JobHandle` outputs can recycle from
    /// `JobState::drop` — see [`job::JobHandle`].
    buffers: Arc<WorkspacePool>,
    queue: JobQueue,
    admission: Admission,
    /// Applied to jobs whose spec carries no deadline of its own.
    default_deadline: Option<Duration>,
    /// Per-bandwidth completion-latency histograms (successful jobs).
    latencies: Mutex<HashMap<usize, LatencyHistogram>>,
    batch_window: Duration,
    max_batch: usize,
    allow_any_bandwidth: bool,
    default_options: PlanOptions,
    stats: Counters,
}

/// See the [module docs](self). Shareable across caller threads as
/// `Arc<So3Service>` (all entry points take `&self`); dropping the last
/// handle drains the queue and joins the dispatcher.
pub struct So3Service {
    inner: Arc<ServiceInner>,
    dispatcher: Option<JoinHandle<()>>,
}

impl So3Service {
    /// Start configuring a service.
    pub fn builder() -> So3ServiceBuilder {
        So3ServiceBuilder::new()
    }

    /// Default configuration: worker pool sized to the machine, batching
    /// of already-queued same-key jobs, unbounded registry.
    pub fn new() -> Result<Self> {
        Self::builder().build()
    }

    /// Worker-pool size (the region width every cached plan runs at).
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// The shared worker pool (`None` when `threads == 1`: plans run
    /// regions inline on the dispatcher).
    pub fn worker_pool(&self) -> Option<&Arc<WorkerPool>> {
        self.inner.pool.as_ref()
    }

    /// The plan registry (diagnostics; plans are fetched via
    /// [`Self::plan`]).
    pub fn registry(&self) -> &PlanRegistry {
        &self.inner.registry
    }

    /// The cached plan for `(bandwidth, options)` — the power-user
    /// escape hatch: callers that want explicit `*_into` execution can
    /// take the `Arc<So3Plan>` and drive it directly (it shares the
    /// service's worker pool).
    pub fn plan(&self, bandwidth: usize, options: PlanOptions) -> Result<Arc<So3Plan>> {
        self.inner.registry.get(PlanKey { bandwidth, options })
    }

    /// Submit a job. Validation (payload kind vs direction, bandwidth
    /// match, power-of-two unless the builder allowed any) and
    /// **admission control** happen here, synchronously — an admitted
    /// handle always resolves (result or typed error); a saturated
    /// service answers with
    /// [`Error::Overloaded`](crate::error::Error::Overloaded)
    /// immediately instead of queueing without bound.
    pub fn submit(&self, spec: JobSpec, input: impl Into<JobInput>) -> Result<JobHandle> {
        let input = input.into();
        self.validate(&spec, &input)?;
        crate::sched_point!("service.submit.start");
        let cost_bytes = job_cost_bytes(spec.bandwidth);
        let deadline_at = spec
            .deadline
            .or(self.inner.default_deadline)
            .and_then(|d| Instant::now().checked_add(d));
        let state = JobState::with_pool(Some(Arc::clone(&self.inner.buffers)));
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        {
            let mut st = lock(&self.inner.queue.state);
            if st.shutdown {
                return Err(Error::Service("service is shutting down".into()));
            }
            if let Err(e) = self
                .inner
                .admission
                .try_admit(st.jobs.len(), cost_bytes, spec.tenant)
            {
                if let Error::Overloaded { cause, .. } = &e {
                    self.inner.stats.count_rejection(*cause);
                }
                return Err(e);
            }
            // Count before the dispatcher can possibly complete the job,
            // so `submitted >= completed` holds for every observer.
            // ordering: Relaxed — the increment is published to the
            // dispatcher by the queue-lock release below; observers get
            // the `submitted >= completed` invariant from the Release
            // store in `finish_job` + Acquire loads (metrics/shutdown
            // read `completed` FIRST, so seeing a completion implies
            // seeing its submission).
            self.inner.stats.submitted.fetch_add(1, Ordering::Relaxed);
            st.jobs.push_back(QueuedJob {
                spec,
                input,
                state,
                deadline_at,
                cost_bytes,
            });
        }
        self.inner.queue.cv.notify_all();
        crate::sched_point!("service.submit.enqueued");
        Ok(handle)
    }

    fn validate(&self, spec: &JobSpec, input: &JobInput) -> Result<()> {
        if spec.bandwidth == 0 {
            return Err(Error::InvalidBandwidth(0));
        }
        if !spec.bandwidth.is_power_of_two() && !self.inner.allow_any_bandwidth {
            return Err(Error::NonPowerOfTwoBandwidth(spec.bandwidth));
        }
        match (spec.direction, input) {
            (Direction::Forward, JobInput::Grid(_)) => {}
            (Direction::Inverse, JobInput::Coeffs(_)) => {}
            (direction, input) => {
                return Err(Error::Service(format!(
                    "{direction:?} job cannot take a {} payload",
                    input.kind()
                )))
            }
        }
        if input.bandwidth() != spec.bandwidth {
            return Err(Error::bandwidth(
                spec.bandwidth,
                input.bandwidth(),
                "submit: input bandwidth",
            ));
        }
        Ok(())
    }

    /// Blocking analysis with the service's default options: submit,
    /// wait, unwrap. The input buffer is recycled into the pool.
    pub fn forward(&self, grid: So3Grid) -> Result<So3Coeffs> {
        let spec = JobSpec::forward(grid.bandwidth()).options(self.inner.default_options);
        match self.submit(spec, grid)?.wait()? {
            JobOutput::Coeffs(c) => Ok(c),
            JobOutput::Grid(_) => unreachable!("forward jobs yield coefficients"),
        }
    }

    /// Blocking synthesis with the service's default options.
    pub fn inverse(&self, coeffs: So3Coeffs) -> Result<So3Grid> {
        let spec = JobSpec::inverse(coeffs.bandwidth()).options(self.inner.default_options);
        match self.submit(spec, coeffs)?.wait()? {
            JobOutput::Grid(g) => Ok(g),
            JobOutput::Coeffs(_) => unreachable!("inverse jobs yield a grid"),
        }
    }

    /// A pooled grid buffer (contents unspecified — overwrite it). Fill
    /// and submit it; the service recycles it after execution.
    pub fn checkout_grid(&self, b: usize) -> Result<So3Grid> {
        self.inner.buffers.checkout_grid(b)
    }

    /// A pooled coefficient buffer (contents unspecified).
    pub fn checkout_coeffs(&self, b: usize) -> Result<So3Coeffs> {
        self.inner.buffers.checkout_coeffs(b)
    }

    /// Return a consumed job output to the buffer pool.
    pub fn recycle(&self, output: JobOutput) {
        match output {
            JobOutput::Grid(g) => self.inner.buffers.checkin_grid(g),
            JobOutput::Coeffs(c) => self.inner.buffers.checkin_coeffs(c),
        }
    }

    /// Return a grid buffer to the pool for reuse.
    pub fn recycle_grid(&self, g: So3Grid) {
        self.inner.buffers.checkin_grid(g);
    }

    /// Return a coefficient buffer to the pool for reuse.
    pub fn recycle_coeffs(&self, c: So3Coeffs) {
        self.inner.buffers.checkin_coeffs(c);
    }

    /// Aggregate serving counters (cheap; safe to poll).
    pub fn stats(&self) -> ServiceStats {
        // ordering: Acquire on `completed` (pairs with the Release in
        // `finish_job`), loaded BEFORE `submitted`: any completion we
        // observe happens-after its own submission, so the snapshot can
        // never report `completed > submitted`. The remaining counters
        // are Relaxed independent tallies.
        let jobs_completed = self.inner.stats.completed.load(Ordering::Acquire);
        ServiceStats {
            // ordering: Relaxed — read after the Acquire above; the
            // remaining counters are independent tallies.
            jobs_submitted: self.inner.stats.submitted.load(Ordering::Relaxed),
            jobs_completed,
            batches: self.inner.stats.batches.load(Ordering::Relaxed),
            max_batch_size: self.inner.stats.max_batch.load(Ordering::Relaxed),
            registry: self.inner.registry.stats(),
            buffers: self.inner.buffers.stats(),
        }
    }

    /// Point-in-time serving snapshot: queue depth, in-flight bytes,
    /// rejections by cause, batch occupancy, per-bandwidth latency
    /// (rendered by `serve-bench`; see [`ServiceMetrics`]).
    pub fn metrics(&self) -> ServiceMetrics {
        let inner = &self.inner;
        let queue_depth = lock(&inner.queue.state).jobs.len();
        // ordering: Acquire — pairs with the Release in `finish_job`;
        // loaded before `submitted` below so the snapshot never shows
        // `completed > submitted` (see `stats`).
        let completed = inner.stats.completed.load(Ordering::Acquire);
        // ordering: Relaxed — independent tally.
        let batches = inner.stats.batches.load(Ordering::Relaxed);
        let per_bandwidth = {
            let lat = lock(&inner.latencies);
            let mut rows: Vec<BandwidthLatency> = lat
                .iter()
                .map(|(&b, h)| BandwidthLatency {
                    bandwidth: b,
                    jobs: h.count(),
                    p50: h.quantile(0.50),
                    p99: h.quantile(0.99),
                })
                .collect();
            rows.sort_by_key(|r| r.bandwidth);
            rows
        };
        ServiceMetrics {
            queue_depth,
            inflight_bytes: inner.admission.inflight_bytes(),
            rejected: RejectionCounts {
                // ordering: Relaxed — independent tallies, not a
                // consistent cut across causes.
                queue_depth: inner.stats.rejected_queue.load(Ordering::Relaxed),
                inflight_bytes: inner.stats.rejected_bytes.load(Ordering::Relaxed),
                tenant_quota: inner.stats.rejected_tenant.load(Ordering::Relaxed),
            },
            // ordering: Relaxed — independent tallies (see above).
            deadline_expired: inner.stats.deadline_expired.load(Ordering::Relaxed),
            cancelled: inner.stats.cancelled.load(Ordering::Relaxed),
            shutdown_aborted: inner.stats.shutdown_aborted.load(Ordering::Relaxed),
            dispatcher_restarts: inner.stats.dispatcher_restarts.load(Ordering::Relaxed),
            // ordering: Relaxed — ordered AFTER the Acquire `completed`
            // load above, which is what keeps submitted >= completed.
            jobs_submitted: inner.stats.submitted.load(Ordering::Relaxed),
            jobs_completed: completed,
            batches,
            max_batch_size: inner.stats.max_batch.load(Ordering::Relaxed),
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                completed as f64 / batches as f64
            },
            per_bandwidth,
        }
    }

    /// Drain-with-deadline shutdown: stop admitting, give queued work up
    /// to `drain` to execute, then resolve every still-queued handle
    /// with [`Error::ShutdownDrain`](crate::error::Error::ShutdownDrain).
    /// A job already executing when the deadline hits finishes normally
    /// (the dispatcher join waits for it). **Every outstanding handle
    /// has been resolved — one way or the other — when this returns.**
    ///
    /// `Drop` remains the deadline-less variant: it drains everything,
    /// however long that takes.
    pub fn shutdown(mut self, drain: Duration) -> ShutdownReport {
        let inner = Arc::clone(&self.inner);
        // ordering: Acquire — pairs with the Release in `finish_job` so
        // the drained-count baseline includes every job whose
        // fulfillment we can observe.
        let completed_at_entry = inner.stats.completed.load(Ordering::Acquire);
        {
            let mut st = lock(&inner.queue.state);
            st.shutdown = true;
        }
        inner.queue.cv.notify_all();
        // `None` = an overflowing deadline: drain without bound.
        let deadline = Instant::now().checked_add(drain);
        let mut aborted = 0u64;
        loop {
            crate::sched_point!("service.shutdown.drain");
            // ordering: Acquire on `completed`, loaded FIRST: every
            // completion observed happens-after its own submission
            // (Release in `finish_job` + queue-lock handoff), so the
            // subsequent `submitted` read is >= it and the subtraction
            // cannot wrap. Admission is closed, so `submitted` can only
            // grow by jobs this loop will still observe.
            let completed_now = inner.stats.completed.load(Ordering::Acquire);
            let outstanding = inner.stats.submitted.load(Ordering::Relaxed) - completed_now;
            if outstanding == 0 {
                break;
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                // Deadline hit: abort what is still *queued*. The
                // dispatcher may be draining concurrently — the queue
                // lock makes each job resolve on exactly one side.
                let leftovers: Vec<QueuedJob> = {
                    let mut st = lock(&inner.queue.state);
                    st.jobs.drain(..).collect()
                };
                for job in leftovers {
                    recycle_input(&inner, job.input);
                    // ordering: Relaxed — standalone tally for metrics.
                    inner.stats.shutdown_aborted.fetch_add(1, Ordering::Relaxed);
                    aborted += 1;
                    let err = Err(Error::ShutdownDrain);
                    inner.finish_job(&job.spec, &job.state, job.cost_bytes, err);
                }
                break;
            }
            std::thread::sleep(Duration::from_micros(500));
        }
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        // ordering: Acquire — see `completed_at_entry`; the dispatcher
        // has joined, so this is the final count.
        let completed_total = inner.stats.completed.load(Ordering::Acquire);
        ShutdownReport {
            drained: (completed_total - completed_at_entry).saturating_sub(aborted),
            aborted,
        }
    }
}

/// What a [`So3Service::shutdown`] resolved: jobs that ran to completion
/// during the drain window vs. jobs aborted with
/// [`Error::ShutdownDrain`](crate::error::Error::ShutdownDrain) when the
/// deadline hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShutdownReport {
    /// Jobs that ran to completion during the drain window.
    pub drained: u64,
    /// Jobs aborted with `Error::ShutdownDrain` at the deadline.
    pub aborted: u64,
}

impl fmt::Debug for So3Service {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("So3Service")
            .field("threads", &self.inner.threads)
            .field("batch_window", &self.inner.batch_window)
            .field("max_batch", &self.inner.max_batch)
            .field("stats", &self.stats())
            .finish()
    }
}

impl Drop for So3Service {
    /// Signal shutdown and join the dispatcher. Jobs already queued are
    /// drained (their handles resolve); new submissions are rejected.
    fn drop(&mut self) {
        {
            let mut st = lock(&self.inner.queue.state);
            st.shutdown = true;
        }
        self.inner.queue.cv.notify_all();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Fluent configuration for [`So3Service`].
pub struct So3ServiceBuilder {
    threads: Option<usize>,
    shared_pool: Option<Arc<WorkerPool>>,
    batch_window: Duration,
    max_batch: usize,
    registry_budget: Option<usize>,
    default_options: PlanOptions,
    allow_any_bandwidth: bool,
    plan_rigor: PlanRigor,
    wisdom_store: Option<Arc<WisdomStore>>,
    max_queue: Option<usize>,
    max_inflight_bytes: Option<usize>,
    default_deadline: Option<Duration>,
    tenant_quota: Option<usize>,
}

impl So3ServiceBuilder {
    fn new() -> Self {
        Self {
            threads: None,
            shared_pool: None,
            batch_window: Duration::ZERO,
            max_batch: 32,
            registry_budget: None,
            default_options: PlanOptions::default(),
            allow_any_bandwidth: false,
            plan_rigor: PlanRigor::Estimate,
            wisdom_store: None,
            max_queue: None,
            max_inflight_bytes: None,
            default_deadline: None,
            tenant_quota: None,
        }
    }

    /// Worker-pool size (default: the machine's available parallelism;
    /// `1` = sequential plans, no pool).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Execute on a caller-supplied shared [`WorkerPool`] instead of
    /// spawning one (also sets `threads` to the pool size).
    pub fn pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.threads = Some(pool.threads());
        self.shared_pool = Some(pool);
        self
    }

    /// How long the dispatcher holds a batch open for same-key jobs
    /// after picking its leader. `ZERO` (the default) still coalesces
    /// jobs that are *already queued* — it only skips the wait.
    pub fn batch_window(mut self, window: Duration) -> Self {
        self.batch_window = window;
        self
    }

    /// Upper bound on jobs per micro-batch (default 32, must be ≥ 1).
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// LRU-evict cached plans once their summed `table_bytes()` exceeds
    /// this budget (default: unbounded).
    pub fn registry_budget_bytes(mut self, bytes: usize) -> Self {
        self.registry_budget = Some(bytes);
        self
    }

    /// Options used by the [`So3Service::forward`] /
    /// [`So3Service::inverse`] conveniences (explicit [`JobSpec`]s carry
    /// their own).
    pub fn default_options(mut self, options: PlanOptions) -> Self {
        self.default_options = options;
        self
    }

    /// Service-wide default [`MemoryBudget`](crate::coordinator::MemoryBudget),
    /// applied to the [`So3Service::forward`] / [`So3Service::inverse`]
    /// conveniences and any [`JobSpec`] built from the default options.
    ///
    /// Precedence: an explicit per-job budget
    /// ([`JobSpec::memory_budget`]) always wins over this service-level
    /// default; both default to `MemoryBudget::Auto`. Jobs with
    /// different budgets resolve to distinct registry plans.
    pub fn memory_budget(mut self, budget: crate::coordinator::MemoryBudget) -> Self {
        self.default_options.memory = budget;
        self
    }

    /// Accept non-power-of-two bandwidths (Bluestein FFT fallback).
    pub fn allow_any_bandwidth(mut self) -> Self {
        self.allow_any_bandwidth = true;
        self
    }

    /// Planning rigor for every registry build (default
    /// [`PlanRigor::Estimate`]). With [`PlanRigor::Measure`] every
    /// tenant gets measured-tuned plans; the registry's single-flight
    /// lock guarantees one measurement pass per key even under
    /// concurrent cold misses.
    pub fn plan_rigor(mut self, rigor: PlanRigor) -> Self {
        self.plan_rigor = rigor;
        self
    }

    /// The wisdom store `Measure` builds consult (default: the
    /// process-global store).
    pub fn wisdom_store(mut self, store: Arc<WisdomStore>) -> Self {
        self.wisdom_store = Some(store);
        self
    }

    /// Cap the number of queued (admitted, undispatched) jobs; a full
    /// queue rejects submissions with a typed
    /// [`Error::Overloaded`](crate::error::Error::Overloaded)
    /// (default: unbounded).
    pub fn max_queue(mut self, max_queue: usize) -> Self {
        self.max_queue = Some(max_queue);
        self
    }

    /// Cap the summed payload+output bytes of admitted, unresolved jobs
    /// (default: unbounded). A single job larger than the cap is still
    /// admitted when the service is idle — the cap bounds *concurrent*
    /// work, it never wedges the service.
    pub fn max_inflight_bytes(mut self, bytes: usize) -> Self {
        self.max_inflight_bytes = Some(bytes);
        self
    }

    /// Deadline applied to every job whose [`JobSpec::deadline`] is
    /// `None` (default: none). Expired jobs still queued at dispatch
    /// time resolve with
    /// [`Error::DeadlineExceeded`](crate::error::Error::DeadlineExceeded)
    /// and never execute.
    pub fn default_deadline(mut self, deadline: Duration) -> Self {
        self.default_deadline = Some(deadline);
        self
    }

    /// Cap the in-flight jobs of any single [`JobSpec::tenant`]
    /// (default: unbounded). Jobs without a tenant id are exempt.
    pub fn tenant_quota(mut self, quota: usize) -> Self {
        self.tenant_quota = Some(quota);
        self
    }

    /// Build the service (spawns the pool and dispatcher).
    pub fn build(self) -> Result<So3Service> {
        let threads = match self.threads {
            Some(0) => return Err(Error::InvalidThreads(0)),
            Some(t) => t,
            None => std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
        };
        if self.max_batch == 0 {
            return Err(Error::Service("max_batch must be >= 1".into()));
        }
        let pool = match self.shared_pool {
            Some(p) => Some(p),
            None if threads > 1 => Some(Arc::new(WorkerPool::new(threads)?)),
            None => None,
        };
        let inner = Arc::new(ServiceInner {
            threads,
            registry: PlanRegistry::new(
                threads,
                pool.clone(),
                self.registry_budget,
                self.allow_any_bandwidth,
                self.plan_rigor,
                self.wisdom_store,
            ),
            pool,
            buffers: Arc::new(WorkspacePool::new()),
            queue: JobQueue {
                state: Mutex::new(QueueState {
                    jobs: VecDeque::new(),
                    shutdown: false,
                }),
                cv: Condvar::new(),
            },
            admission: Admission::new(self.max_queue, self.max_inflight_bytes, self.tenant_quota),
            default_deadline: self.default_deadline,
            latencies: Mutex::new(HashMap::new()),
            batch_window: self.batch_window,
            max_batch: self.max_batch,
            allow_any_bandwidth: self.allow_any_bandwidth,
            default_options: self.default_options,
            stats: Counters::default(),
        });
        let dispatcher_inner = Arc::clone(&inner);
        let dispatcher = std::thread::Builder::new()
            .name("so3ft-service".into())
            .spawn(move || {
                // Watchdog: a dispatcher panic (injected fault, or a bug
                // outside the per-batch catch_unwind) restarts the loop
                // over the intact queue instead of stranding every
                // queued handle. The loop only holds dequeued jobs
                // inside panic-caught scopes, so none are in hand when
                // an unwind reaches this frame.
                loop {
                    let run =
                        catch_unwind(AssertUnwindSafe(|| dispatcher_loop(&dispatcher_inner)));
                    if run.is_ok() {
                        break;
                    }
                    crate::sched_point!("service.watchdog.restart");
                    // ordering: Relaxed — standalone tally; the queue
                    // itself survives the unwind under its own mutex.
                    dispatcher_inner
                        .stats
                        .dispatcher_restarts
                        .fetch_add(1, Ordering::Relaxed);
                }
            })
            .map_err(Error::Io)?;
        Ok(So3Service {
            inner,
            dispatcher: Some(dispatcher),
        })
    }
}

// ----------------------------------------------------------------------
// Dispatcher
// ----------------------------------------------------------------------

fn dispatcher_loop(inner: &ServiceInner) {
    while let Some(batch) = next_batch(inner) {
        crate::sched_point!("dispatch.batch.start");
        execute_batch(inner, batch);
    }
}

/// Block for work, pick the leading job (priority, then FIFO), hold the
/// batch open for the window, and drain every queued job sharing the
/// leader's `(direction, bandwidth, options)` key in submission order.
/// Jobs found **dead** at drain time — cancelled, or past their
/// deadline — are resolved with their typed error and never dispatched.
/// `None` once the queue is drained after shutdown.
fn next_batch(inner: &ServiceInner) -> Option<Vec<QueuedJob>> {
    let queue = &inner.queue;
    let mut st = lock(&queue.state);
    loop {
        loop {
            if !st.jobs.is_empty() {
                break;
            }
            if st.shutdown {
                return None;
            }
            st = queue.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        // Fault site: fires with the queue lock released and NO jobs
        // dequeued, so a dispatcher panic here strands nothing — the
        // watchdog restarts the loop over the intact queue.
        if let Some(action) = faults::fire(faults::DISPATCHER) {
            drop(st);
            action.apply_infallible(faults::DISPATCHER);
            st = lock(&queue.state);
            continue;
        }
        let lead = pick_leader(&st.jobs).expect("queue is non-empty");
        let key = st.jobs[lead].spec.batch_key();
        if !inner.batch_window.is_zero() && !st.shutdown {
            // Micro-batch window: wait for more same-key arrivals (the
            // cv releases the lock, so submitters get in). Cut short on
            // shutdown or once the batch is full.
            let deadline = Instant::now() + inner.batch_window;
            loop {
                let matching = st.jobs.iter().filter(|j| j.spec.batch_key() == key).count();
                if matching >= inner.max_batch || st.shutdown {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = queue
                    .cv
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                st = guard;
            }
        }
        // The leader joins its batch FIRST — under a hot key with more
        // than `max_batch` earlier same-key jobs queued, a FIFO-only
        // drain would leave the high-priority leader behind and void
        // its priority. (`lead` is still valid: the window wait only
        // `push_back`s.) Dead jobs are skimmed off into `dead`; when
        // the leader itself is dead no batch forms this round and the
        // outer loop picks the next leader.
        let now = Instant::now();
        let mut batch = Vec::new();
        let mut dead = Vec::new();
        let lead_job = st.jobs.remove(lead).expect("leader index is in range");
        match dead_reason(&lead_job, now) {
            Some(reason) => dead.push((lead_job, reason)),
            None => batch.push(lead_job),
        }
        let mut rest = VecDeque::with_capacity(st.jobs.len());
        while let Some(job) = st.jobs.pop_front() {
            if let Some(reason) = dead_reason(&job, now) {
                dead.push((job, reason));
            } else if !batch.is_empty()
                && batch.len() < inner.max_batch
                && job.spec.batch_key() == key
            {
                batch.push(job);
            } else {
                rest.push_back(job);
            }
        }
        st.jobs = rest;
        if !dead.is_empty() {
            // Resolve outside the queue lock: fulfill wakes waiters.
            drop(st);
            crate::sched_point!("dispatch.dead.skim");
            for (job, reason) in dead {
                resolve_dead(inner, job, reason);
            }
            st = lock(&queue.state);
        }
        if !batch.is_empty() {
            return Some(batch);
        }
    }
}

/// Why a queued job must not be dispatched.
enum DeadReason {
    Cancelled,
    Expired,
}

fn dead_reason(job: &QueuedJob, now: Instant) -> Option<DeadReason> {
    if job.state.is_cancelled() {
        return Some(DeadReason::Cancelled);
    }
    if job.deadline_at.is_some_and(|d| now >= d) {
        return Some(DeadReason::Expired);
    }
    None
}

/// Resolve a never-dispatched job with its typed error (input recycled).
fn resolve_dead(inner: &ServiceInner, job: QueuedJob, reason: DeadReason) {
    recycle_input(inner, job.input);
    let err = match reason {
        DeadReason::Cancelled => {
            // ordering: Relaxed — standalone tally for metrics.
            inner.stats.cancelled.fetch_add(1, Ordering::Relaxed);
            Error::Cancelled
        }
        DeadReason::Expired => {
            // ordering: Relaxed — standalone tally for metrics.
            inner.stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
            Error::DeadlineExceeded {
                deadline: job
                    .spec
                    .deadline
                    .or(inner.default_deadline)
                    .unwrap_or_default(),
            }
        }
    };
    inner.finish_job(&job.spec, &job.state, job.cost_bytes, Err(err));
}

fn execute_batch(inner: &ServiceInner, batch: Vec<QueuedJob>) {
    let spec = batch[0].spec;
    let n = batch.len() as u32;
    // ordering: Relaxed — batch statistics; independent tallies.
    inner.stats.batches.fetch_add(1, Ordering::Relaxed);
    inner
        .stats
        .max_batch
        .fetch_max(batch.len(), Ordering::Relaxed);

    // The registry re-raises builder panics (so a direct `plan()` caller
    // sees them); the dispatcher must not unwind holding this batch's
    // handles, so the panic is caught and typed here.
    let plan = match catch_unwind(AssertUnwindSafe(|| inner.plan_for(&spec))) {
        Ok(Ok(plan)) => plan,
        Ok(Err(e)) => return fail_batch(inner, batch, format!("plan build failed: {e}")),
        Err(_) => return fail_batch(inner, batch, "plan build panicked".into()),
    };
    let ws = match inner.buffers.checkout_workspace(spec.bandwidth) {
        Ok(ws) => ws,
        Err(e) => return fail_batch(inner, batch, format!("workspace checkout failed: {e}")),
    };
    // Buffers go back to the pool (and the workspace is checked in)
    // *before* the handles resolve, so a caller that waits and then
    // checks a buffer out is guaranteed the pooled allocation —
    // the pointer-stability contract the serving tests pin.
    let wall = Instant::now();
    let (metas, results) = run_batch(inner, &plan, ws, batch);
    inner.admission.observe_job(wall.elapsed() / n);
    debug_assert_eq!(metas.len(), results.len());
    crate::sched_point!("dispatch.batch.finish");
    for (meta, result) in metas.iter().zip(results) {
        inner.finish_job(&meta.spec, &meta.state, meta.cost_bytes, result);
    }
}

impl ServiceInner {
    fn plan_for(&self, spec: &JobSpec) -> Result<Arc<So3Plan>> {
        self.registry.get(PlanKey {
            bandwidth: spec.bandwidth,
            options: spec.options,
        })
    }

    /// The single resolution point of every admitted job: release its
    /// admission charges, record its latency (successes only), count it
    /// completed, and fulfill its handle. Called exactly once per job.
    fn finish_job(
        &self,
        spec: &JobSpec,
        state: &JobState,
        cost_bytes: usize,
        result: Result<JobOutput>,
    ) {
        crate::sched_point!("service.finish_job");
        self.admission.release(cost_bytes, spec.tenant);
        if result.is_ok() {
            let mut latencies = lock(&self.latencies);
            latencies
                .entry(spec.bandwidth)
                .or_default()
                .record(state.elapsed());
        }
        // Count before waking the waiter: a caller whose `wait` just
        // returned must observe its own job in `jobs_completed`.
        // ordering: Release — pairs with the Acquire loads in
        // `stats`/`metrics`/`shutdown`: an observer that sees this
        // completion also sees the submission that preceded it
        // (queue-lock handoff), keeping `submitted >= completed` in
        // every snapshot.
        self.stats.completed.fetch_add(1, Ordering::Release);
        state.fulfill(result);
    }
}

/// The parts of a dequeued job that outlive its payload: what
/// `finish_job` needs once the transform has run.
struct JobMeta {
    spec: JobSpec,
    state: Arc<JobState>,
    cost_bytes: usize,
}

/// Per-job results paired with the job metadata to resolve them with.
type BatchOutcome = (Vec<JobMeta>, Vec<Result<JobOutput>>);

/// The direction-specific types and hooks of one micro-batch. Two
/// zero-sized impls keep [`run_batch_dir`] generic instead of
/// duplicating the unpack -> checkout -> execute -> recycle sequence
/// once per payload type.
trait BatchDir {
    type In;
    type Out;
    fn unpack(input: JobInput) -> Self::In;
    fn checkout(pool: &WorkspacePool, b: usize) -> Result<Self::Out>;
    fn recycle_in(pool: &WorkspacePool, x: Self::In);
    fn recycle_out(pool: &WorkspacePool, x: Self::Out);
    fn wrap(out: Self::Out) -> JobOutput;
    fn batch(
        plan: &So3Plan,
        ins: &[Self::In],
        outs: &mut [Self::Out],
        ws: &mut Workspace,
    ) -> Result<()>;
    fn single(
        plan: &So3Plan,
        input: &Self::In,
        out: &mut Self::Out,
        ws: &mut Workspace,
    ) -> Result<TransformStats>;
}

/// Analysis (FSOFT): grid payloads -> coefficient outputs.
struct Fwd;

impl BatchDir for Fwd {
    type In = So3Grid;
    type Out = So3Coeffs;

    fn unpack(input: JobInput) -> So3Grid {
        match input {
            JobInput::Grid(g) => g,
            JobInput::Coeffs(_) => unreachable!("payload kind validated at submit"),
        }
    }

    fn checkout(pool: &WorkspacePool, b: usize) -> Result<So3Coeffs> {
        pool.checkout_coeffs(b)
    }

    fn recycle_in(pool: &WorkspacePool, g: So3Grid) {
        pool.checkin_grid(g);
    }

    fn recycle_out(pool: &WorkspacePool, c: So3Coeffs) {
        pool.checkin_coeffs(c);
    }

    fn wrap(out: So3Coeffs) -> JobOutput {
        JobOutput::Coeffs(out)
    }

    fn batch(
        plan: &So3Plan,
        ins: &[So3Grid],
        outs: &mut [So3Coeffs],
        ws: &mut Workspace,
    ) -> Result<()> {
        plan.forward_batch_into(ins, outs, ws)
    }

    fn single(
        plan: &So3Plan,
        input: &So3Grid,
        out: &mut So3Coeffs,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        plan.forward_into(input, out, ws)
    }
}

/// Synthesis (iFSOFT): coefficient payloads -> grid outputs.
struct Inv;

impl BatchDir for Inv {
    type In = So3Coeffs;
    type Out = So3Grid;

    fn unpack(input: JobInput) -> So3Coeffs {
        match input {
            JobInput::Coeffs(c) => c,
            JobInput::Grid(_) => unreachable!("payload kind validated at submit"),
        }
    }

    fn checkout(pool: &WorkspacePool, b: usize) -> Result<So3Grid> {
        pool.checkout_grid(b)
    }

    fn recycle_in(pool: &WorkspacePool, c: So3Coeffs) {
        pool.checkin_coeffs(c);
    }

    fn recycle_out(pool: &WorkspacePool, g: So3Grid) {
        pool.checkin_grid(g);
    }

    fn wrap(out: So3Grid) -> JobOutput {
        JobOutput::Grid(out)
    }

    fn batch(
        plan: &So3Plan,
        ins: &[So3Coeffs],
        outs: &mut [So3Grid],
        ws: &mut Workspace,
    ) -> Result<()> {
        plan.inverse_batch_into(ins, outs, ws)
    }

    fn single(
        plan: &So3Plan,
        input: &So3Coeffs,
        out: &mut So3Grid,
        ws: &mut Workspace,
    ) -> Result<TransformStats> {
        plan.inverse_into(input, out, ws)
    }
}

/// Execute one micro-batch on pooled buffers: the whole batch through
/// the plan's `*_batch_into` fast path, falling back to per-job
/// execution on failure so one bad payload (or a kernel panic it
/// triggers — caught here, the dispatcher survives) cannot fail its
/// batch neighbors. Inputs are recycled and the workspace returned in
/// every path, before any handle resolves.
fn run_batch(
    inner: &ServiceInner,
    plan: &So3Plan,
    mut ws: Workspace,
    batch: Vec<QueuedJob>,
) -> BatchOutcome {
    let outcome = match batch[0].spec.direction {
        Direction::Forward => run_batch_dir::<Fwd>(inner, plan, &mut ws, batch),
        Direction::Inverse => run_batch_dir::<Inv>(inner, plan, &mut ws, batch),
    };
    inner.buffers.checkin_workspace(ws);
    outcome
}

fn run_batch_dir<D: BatchDir>(
    inner: &ServiceInner,
    plan: &So3Plan,
    ws: &mut Workspace,
    batch: Vec<QueuedJob>,
) -> BatchOutcome {
    let b = batch[0].spec.bandwidth;
    let n = batch.len();
    let mut metas = Vec::with_capacity(n);
    let mut ins = Vec::with_capacity(n);
    for job in batch {
        ins.push(D::unpack(job.input));
        metas.push(JobMeta {
            spec: job.spec,
            state: job.state,
            cost_bytes: job.cost_bytes,
        });
    }
    // Pooled outputs. Checkout cannot fail for the b >= 1 validated at
    // submit; the graceful branch keeps the dispatcher alive anyway.
    let outs: Result<Vec<D::Out>> = (0..n).map(|_| D::checkout(&inner.buffers, b)).collect();
    let mut outs = match outs {
        Ok(outs) => outs,
        Err(e) => {
            for input in ins {
                D::recycle_in(&inner.buffers, input);
            }
            let msg = format!("output buffer checkout failed: {e}");
            let results = metas
                .iter()
                .map(|_| Err(Error::Service(msg.clone())))
                .collect();
            return (metas, results);
        }
    };
    // Fast path: the whole batch through one `*_batch_into` call. The
    // fault site fires INSIDE the catch_unwind: an injected panic or
    // error lands in the same recovery path as a real kernel failure.
    let batch_ok = matches!(
        catch_unwind(AssertUnwindSafe(|| -> Result<()> {
            if let Some(action) = faults::fire(faults::BATCH_RUNNER) {
                action.apply(faults::BATCH_RUNNER)?;
            }
            D::batch(plan, &ins, &mut outs, ws)
        })),
        Ok(Ok(()))
    );
    let results: Vec<Result<JobOutput>> = if batch_ok {
        outs.into_iter().map(|out| Ok(D::wrap(out))).collect()
    } else {
        // Per-job isolation: rerun each job individually so every
        // handle gets its own typed outcome. Outputs are fully
        // overwritten per run, so any partial batch state is moot.
        ins.iter()
            .zip(outs)
            .map(|(input, mut out)| {
                let run = catch_unwind(AssertUnwindSafe(|| -> Result<TransformStats> {
                    if let Some(action) = faults::fire(faults::BATCH_RUNNER) {
                        action.apply(faults::BATCH_RUNNER)?;
                    }
                    D::single(plan, input, &mut out, ws)
                }));
                match run {
                    Ok(Ok(_stats)) => Ok(D::wrap(out)),
                    Ok(Err(e @ Error::FaultInjected { .. })) => {
                        // Injected faults stay typed end to end — the
                        // chaos suite asserts on the variant.
                        D::recycle_out(&inner.buffers, out);
                        Err(e)
                    }
                    Ok(Err(e)) => {
                        D::recycle_out(&inner.buffers, out);
                        Err(Error::Service(format!("job execution failed: {e}")))
                    }
                    Err(_) => {
                        D::recycle_out(&inner.buffers, out);
                        Err(Error::Service("job execution panicked".into()))
                    }
                }
            })
            .collect()
    };
    for input in ins {
        D::recycle_in(&inner.buffers, input);
    }
    (metas, results)
}

/// Recycle a failed or never-run job's payload: the buffer is reusable
/// even though the job is not.
fn recycle_input(inner: &ServiceInner, input: JobInput) {
    match input {
        JobInput::Grid(g) => inner.buffers.checkin_grid(g),
        JobInput::Coeffs(c) => inner.buffers.checkin_coeffs(c),
    }
}

/// Fail every job of a batch with one (cloned) service error.
fn fail_batch(inner: &ServiceInner, batch: Vec<QueuedJob>, msg: String) {
    for job in batch {
        recycle_input(inner, job.input);
        let err = Err(Error::Service(msg.clone()));
        inner.finish_job(&job.spec, &job.state, job.cost_bytes, err);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_validation() {
        let service = So3Service::builder().threads(1).build().unwrap();
        assert_eq!(service.threads(), 1);
        assert!(service.worker_pool().is_none());
        assert!(matches!(
            So3Service::builder().threads(0).build(),
            Err(Error::InvalidThreads(0))
        ));
        assert!(So3Service::builder()
            .threads(1)
            .max_batch(0)
            .build()
            .is_err());
        let par = So3Service::builder().threads(2).build().unwrap();
        assert_eq!(par.worker_pool().unwrap().threads(), 2);
    }

    #[test]
    fn shared_pool_is_adopted() {
        let pool = Arc::new(WorkerPool::new(2).unwrap());
        let service = So3Service::builder()
            .pool(Arc::clone(&pool))
            .build()
            .unwrap();
        assert_eq!(service.threads(), 2);
        assert!(Arc::ptr_eq(service.worker_pool().unwrap(), &pool));
        // Cached plans run on the same pool instance.
        let plan = service.plan(4, PlanOptions::default()).unwrap();
        assert!(Arc::ptr_eq(plan.pool().unwrap(), &pool));
    }

    #[test]
    fn blocking_conveniences_roundtrip() {
        let service = So3Service::builder().threads(2).build().unwrap();
        let coeffs = So3Coeffs::random(8, 11);
        let grid = service.inverse(coeffs.clone()).unwrap();
        let back = service.forward(grid).unwrap();
        assert!(coeffs.max_abs_error(&back) < 1e-10);
    }

    #[test]
    fn submit_validation_is_typed() {
        let service = So3Service::builder().threads(1).build().unwrap();
        // Payload kind mismatch.
        assert!(matches!(
            service.submit(JobSpec::forward(4), So3Coeffs::zeros(4)),
            Err(Error::Service(_))
        ));
        assert!(matches!(
            service.submit(JobSpec::inverse(4), So3Grid::zeros(4).unwrap()),
            Err(Error::Service(_))
        ));
        // Bandwidth mismatch between spec and payload.
        assert!(matches!(
            service.submit(JobSpec::inverse(8), So3Coeffs::zeros(4)),
            Err(Error::BandwidthMismatch { expected: 8, got: 4, .. })
        ));
        // Strict power-of-two validation (and the escape hatch).
        assert!(matches!(
            service.submit(JobSpec::inverse(6), So3Coeffs::zeros(6)),
            Err(Error::NonPowerOfTwoBandwidth(6))
        ));
        assert!(matches!(
            service.submit(JobSpec::inverse(0), So3Coeffs::zeros(4)),
            Err(Error::InvalidBandwidth(0))
        ));
        let lenient = So3Service::builder()
            .threads(1)
            .allow_any_bandwidth()
            .build()
            .unwrap();
        let g = lenient.inverse(So3Coeffs::random(6, 1)).unwrap();
        assert_eq!(g.bandwidth(), 6);
    }

    #[test]
    fn plan_build_failure_fails_the_job_not_the_service() {
        use crate::dwt::{DwtAlgorithm, Precision};
        let service = So3Service::builder().threads(1).build().unwrap();
        // clenshaw + extended is rejected at Executor::new — the plan
        // build fails inside the dispatcher, after submit validation.
        let bad = PlanOptions {
            algorithm: DwtAlgorithm::Clenshaw,
            precision: Precision::Extended,
            ..PlanOptions::default()
        };
        let handle = service
            .submit(JobSpec::inverse(4).options(bad), So3Coeffs::zeros(4))
            .unwrap();
        assert!(matches!(handle.wait(), Err(Error::Service(_))));
        // The dispatcher survives and keeps serving.
        let grid = service.inverse(So3Coeffs::random(4, 2)).unwrap();
        assert_eq!(grid.bandwidth(), 4);
    }

    #[test]
    fn drop_drains_pending_jobs() {
        let service = So3Service::builder().threads(1).build().unwrap();
        let handles: Vec<JobHandle> = (0..4)
            .map(|i| {
                service
                    .submit(JobSpec::inverse(4), So3Coeffs::random(4, i))
                    .unwrap()
            })
            .collect();
        drop(service);
        for h in handles {
            assert!(h.wait().is_ok(), "queued jobs must resolve across drop");
        }
    }

    #[test]
    fn service_memory_budget_default_flows_to_convenience_jobs() {
        use crate::coordinator::MemoryBudget;
        let service = So3Service::builder()
            .threads(1)
            .memory_budget(MemoryBudget::Unlimited)
            .build()
            .unwrap();
        let coeffs = So3Coeffs::random(4, 3);
        let grid = service.inverse(coeffs).unwrap();
        let _ = service.forward(grid).unwrap();
        // The conveniences built exactly one plan, under the default
        // budget; re-fetching under that key hits the cache.
        let plan = service.plan(4, service.inner.default_options).unwrap();
        assert_eq!(plan.memory_report().budget, MemoryBudget::Unlimited);
        assert_eq!(service.registry().stats().plans, 1);
    }

    #[test]
    fn stats_count_batches_and_jobs() {
        let service = So3Service::builder().threads(1).build().unwrap();
        for i in 0..3 {
            let _ = service.inverse(So3Coeffs::random(4, i)).unwrap();
        }
        let s = service.stats();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_completed, 3);
        assert!(s.batches >= 1 && s.batches <= 3);
        assert!(s.max_batch_size >= 1);
        assert_eq!(s.registry.plans, 1);
    }

    #[test]
    fn admission_knobs_reject_with_typed_overload() {
        // max_queue = 0: every submission rejected before queueing.
        let service = So3Service::builder()
            .threads(1)
            .max_queue(0)
            .build()
            .unwrap();
        match service.submit(JobSpec::inverse(4), So3Coeffs::zeros(4)) {
            Err(Error::Overloaded {
                cause,
                retry_after_hint,
            }) => {
                assert_eq!(cause, OverloadCause::QueueDepth);
                assert!(retry_after_hint > Duration::ZERO);
            }
            other => panic!("expected Overloaded, got {:?}", other.map(|_| ())),
        }
        let m = service.metrics();
        assert_eq!(m.rejected.queue_depth, 1);
        assert_eq!(m.rejected.total(), 1);
        assert_eq!(m.jobs_submitted, 0, "rejected jobs are never submitted");
    }

    #[test]
    fn metrics_snapshot_counts_jobs_and_latency() {
        let service = So3Service::builder().threads(1).build().unwrap();
        for i in 0..3 {
            let _ = service.inverse(So3Coeffs::random(4, i)).unwrap();
        }
        let m = service.metrics();
        assert_eq!(m.jobs_submitted, 3);
        assert_eq!(m.jobs_completed, 3);
        assert_eq!(m.queue_depth, 0);
        assert_eq!(m.inflight_bytes, 0, "resolved jobs release their bytes");
        assert_eq!(m.rejected.total(), 0);
        assert_eq!(m.dispatcher_restarts, 0);
        assert_eq!(m.per_bandwidth.len(), 1);
        assert_eq!(m.per_bandwidth[0].bandwidth, 4);
        assert_eq!(m.per_bandwidth[0].jobs, 3);
        assert!(m.per_bandwidth[0].p99 >= m.per_bandwidth[0].p50);
        assert!(m.mean_batch_size >= 1.0);
        assert!(m.render().contains("b=4"));
    }

    #[test]
    fn shutdown_with_slack_drains_everything() {
        let service = So3Service::builder().threads(1).build().unwrap();
        let handles: Vec<JobHandle> = (0..3)
            .map(|i| {
                service
                    .submit(JobSpec::inverse(4), So3Coeffs::random(4, i))
                    .unwrap()
            })
            .collect();
        let report = service.shutdown(Duration::from_secs(60));
        assert_eq!(report.aborted, 0);
        // Jobs completing before the shutdown snapshot don't count as
        // drained, so only an upper bound is deterministic here.
        assert!(report.drained <= 3);
        for h in handles {
            assert!(h.wait().is_ok());
        }
    }

    #[test]
    fn shutdown_on_idle_service_reports_zero() {
        let service = So3Service::builder().threads(1).build().unwrap();
        let report = service.shutdown(Duration::from_secs(1));
        assert_eq!(report, ShutdownReport::default());
    }
}

//! Service metrics: per-bandwidth latency histograms and the
//! point-in-time [`ServiceMetrics`] snapshot returned by
//! [`So3Service::metrics`](super::So3Service::metrics).
//!
//! Latencies are recorded into **log2-bucketed histograms** (bucket `i`
//! holds submit-to-completion times in `[2^i, 2^(i+1))` nanoseconds), so
//! recording is O(1) with no per-sample allocation and quantiles are
//! approximate: a reported quantile is its bucket's upper bound, i.e.
//! within 2x of the true value. `serve-bench` computes exact percentiles
//! from raw samples for the regression gate; the snapshot here is the
//! always-on operational view.

use std::fmt;
use std::time::Duration;

/// Log2-bucketed latency histogram (see the [module docs](self)).
#[derive(Debug, Clone)]
pub(crate) struct LatencyHistogram {
    /// `buckets[i]` counts latencies in `[2^i, 2^(i+1))` ns.
    buckets: [u64; 64],
    count: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: [0; 64],
            count: 0,
        }
    }
}

impl LatencyHistogram {
    pub(crate) fn record(&mut self, latency: Duration) {
        let ns = latency.as_nanos().clamp(1, u64::MAX as u128) as u64;
        let idx = (63 - ns.leading_zeros()) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
    }

    /// Upper bound of the bucket holding the `q`-quantile (nearest-rank;
    /// `Duration::ZERO` when empty).
    pub(crate) fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let upper_ns = if i >= 63 { u64::MAX } else { 1u64 << (i + 1) };
                return Duration::from_nanos(upper_ns);
            }
        }
        Duration::ZERO
    }

    pub(crate) fn count(&self) -> u64 {
        self.count
    }
}

/// Admission rejections by cause (monotonic counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RejectionCounts {
    /// Rejections because the bounded queue was full.
    pub queue_depth: u64,
    /// Rejections because `max_inflight_bytes` would be exceeded.
    pub inflight_bytes: u64,
    /// Rejections because the tenant hit its quota.
    pub tenant_quota: u64,
}

impl RejectionCounts {
    /// Total rejections across all causes.
    pub fn total(&self) -> u64 {
        self.queue_depth + self.inflight_bytes + self.tenant_quota
    }
}

/// Approximate latency tail for one bandwidth (values are log2-bucket
/// upper bounds — within 2x; see the [module docs](self)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BandwidthLatency {
    /// Bandwidth this row describes.
    pub bandwidth: usize,
    /// Successfully completed jobs recorded at this bandwidth.
    pub jobs: u64,
    /// Median queue-to-completion latency (log2-bucket bound).
    pub p50: Duration,
    /// 99th-percentile queue-to-completion latency (log2-bucket bound).
    pub p99: Duration,
}

/// Point-in-time serving snapshot (see
/// [`So3Service::metrics`](super::So3Service::metrics)). Rendered by
/// `serve-bench` and exportable as JSON via [`Self::to_json`].
#[derive(Debug, Clone, Default)]
pub struct ServiceMetrics {
    /// Jobs queued right now (admitted, not yet dispatched).
    pub queue_depth: usize,
    /// Payload + output bytes of admitted, unresolved jobs.
    pub inflight_bytes: usize,
    /// Admission rejections by cause.
    pub rejected: RejectionCounts,
    /// Jobs whose deadline expired while queued (never executed).
    pub deadline_expired: u64,
    /// Jobs cancelled via `JobHandle::cancel` before dispatch.
    pub cancelled: u64,
    /// Jobs aborted by a drain-deadline shutdown.
    pub shutdown_aborted: u64,
    /// Dispatcher panics recovered by the watchdog.
    pub dispatcher_restarts: u64,
    /// Jobs admitted since startup.
    pub jobs_submitted: u64,
    /// Jobs fulfilled (successfully or with an error).
    pub jobs_completed: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Largest micro-batch executed so far.
    pub max_batch_size: usize,
    /// `jobs_completed / batches` (0 when no batch ran yet).
    pub mean_batch_size: f64,
    /// Per-bandwidth completion latency, sorted by bandwidth.
    pub per_bandwidth: Vec<BandwidthLatency>,
}

impl ServiceMetrics {
    /// Multi-line human-readable rendering (what `serve-bench` prints).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("service metrics:\n");
        out.push_str(&format!("  queue depth          {}\n", self.queue_depth));
        out.push_str(&format!("  in-flight bytes      {}\n", self.inflight_bytes));
        out.push_str(&format!(
            "  rejected             {} (queue {}, bytes {}, tenant {})\n",
            self.rejected.total(),
            self.rejected.queue_depth,
            self.rejected.inflight_bytes,
            self.rejected.tenant_quota
        ));
        out.push_str(&format!(
            "  deadline expired     {}\n  cancelled            {}\n",
            self.deadline_expired, self.cancelled
        ));
        out.push_str(&format!(
            "  shutdown aborted     {}\n  dispatcher restarts  {}\n",
            self.shutdown_aborted, self.dispatcher_restarts
        ));
        out.push_str(&format!(
            "  jobs                 submitted {}, completed {}, batches {} \
             (mean {:.2}, max {})\n",
            self.jobs_submitted,
            self.jobs_completed,
            self.batches,
            self.mean_batch_size,
            self.max_batch_size
        ));
        for l in &self.per_bandwidth {
            out.push_str(&format!(
                "  b={:<5} latency      n={:<6} p50 ~{:.3}ms  p99 ~{:.3}ms\n",
                l.bandwidth,
                l.jobs,
                l.p50.as_secs_f64() * 1e3,
                l.p99.as_secs_f64() * 1e3
            ));
        }
        out
    }

    /// One JSON object (hand-rolled — the crate is dependency-free).
    pub fn to_json(&self) -> String {
        let mut per_b = String::new();
        for (i, l) in self.per_bandwidth.iter().enumerate() {
            if i > 0 {
                per_b.push_str(", ");
            }
            per_b.push_str(&format!(
                "{{\"b\": {}, \"jobs\": {}, \"p50_s\": {:.6}, \"p99_s\": {:.6}}}",
                l.bandwidth,
                l.jobs,
                l.p50.as_secs_f64(),
                l.p99.as_secs_f64()
            ));
        }
        format!(
            "{{\"queue_depth\": {}, \"inflight_bytes\": {}, \
             \"rejected_queue_depth\": {}, \"rejected_inflight_bytes\": {}, \
             \"rejected_tenant_quota\": {}, \"deadline_expired\": {}, \
             \"cancelled\": {}, \"shutdown_aborted\": {}, \
             \"dispatcher_restarts\": {}, \"jobs_submitted\": {}, \
             \"jobs_completed\": {}, \"batches\": {}, \"max_batch_size\": {}, \
             \"mean_batch_size\": {:.3}, \"per_bandwidth\": [{}]}}",
            self.queue_depth,
            self.inflight_bytes,
            self.rejected.queue_depth,
            self.rejected.inflight_bytes,
            self.rejected.tenant_quota,
            self.deadline_expired,
            self.cancelled,
            self.shutdown_aborted,
            self.dispatcher_restarts,
            self.jobs_submitted,
            self.jobs_completed,
            self.batches,
            self.max_batch_size,
            self.mean_batch_size,
            per_b
        )
    }
}

impl fmt::Display for ServiceMetrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_within_a_bucket() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        for _ in 0..99 {
            h.record(Duration::from_micros(100)); // ~2^16.6 ns
        }
        h.record(Duration::from_millis(80)); // ~2^26.25 ns
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        assert!(p50 >= Duration::from_micros(100) && p50 <= Duration::from_micros(200));
        let p99 = h.quantile(0.99);
        assert!(p99 <= Duration::from_micros(200), "p99 is the 99th sample");
        let p100 = h.quantile(1.0);
        assert!(p100 >= Duration::from_millis(80) && p100 <= Duration::from_millis(160));
    }

    #[test]
    fn histogram_handles_extremes() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::ZERO); // clamps into the first bucket
        h.record(Duration::from_secs(u64::MAX)); // clamps into the last
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.01) <= Duration::from_nanos(2));
        assert!(h.quantile(1.0) >= Duration::from_secs(1 << 40));
    }

    #[test]
    fn render_and_json_carry_the_counters() {
        let m = ServiceMetrics {
            queue_depth: 2,
            inflight_bytes: 4096,
            rejected: RejectionCounts {
                queue_depth: 3,
                inflight_bytes: 1,
                tenant_quota: 0,
            },
            deadline_expired: 5,
            per_bandwidth: vec![BandwidthLatency {
                bandwidth: 8,
                jobs: 10,
                p50: Duration::from_millis(1),
                p99: Duration::from_millis(4),
            }],
            ..ServiceMetrics::default()
        };
        assert_eq!(m.rejected.total(), 4);
        let text = m.render();
        assert!(text.contains("queue depth"));
        assert!(text.contains("rejected             4"));
        assert!(text.contains("b=8"));
        assert_eq!(text, m.to_string());
        let json = m.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"rejected_queue_depth\": 3"));
        assert!(json.contains("\"deadline_expired\": 5"));
        assert!(json.contains("\"b\": 8"));
    }
}

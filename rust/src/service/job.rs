//! The typed job surface of [`So3Service`](super::So3Service): specs,
//! payloads, priorities, and completion handles.
//!
//! A job is one transform request: a [`JobSpec`] (direction, bandwidth,
//! [`PlanOptions`], priority) plus a [`JobInput`] payload. Submission
//! returns a [`JobHandle`]; the dispatcher fulfills it once the job's
//! micro-batch executes, and [`JobHandle::wait`] yields the
//! [`JobOutput`].

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::service::registry::{PlanKey, PlanOptions};
use crate::service::workspace_pool::WorkspacePool;
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;
use crate::util::lock_unpoisoned as lock;

/// Transform direction of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Analysis (FSOFT): grid samples → Fourier coefficients.
    Forward,
    /// Synthesis (iFSOFT): Fourier coefficients → grid samples.
    Inverse,
}

/// Dispatch priority. Higher levels are dequeued first; within one
/// level jobs run in submission (FIFO) order. Priority selects which
/// batch *leads*; micro-batching still coalesces same-key jobs of any
/// priority into the led batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum JobPriority {
    /// Drained only when no Normal/High work is pending.
    Low,
    /// Default priority.
    #[default]
    Normal,
    /// Drained before Normal/Low work.
    High,
}

/// What to run: direction, bandwidth, plan options, priority.
///
/// `(direction, bandwidth, options)` is the **batch key**: jobs sharing
/// it that arrive within the service's batch window execute as one
/// micro-batch through the plan's `*_batch_into` entry points
/// (bit-identical to per-job execution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobSpec {
    /// Analysis or synthesis.
    pub direction: Direction,
    /// Transform bandwidth B.
    pub bandwidth: usize,
    /// Plan options the job must execute under.
    pub options: PlanOptions,
    /// Queue priority.
    pub priority: JobPriority,
    /// Admission-control tenant id. Only consulted when the service has
    /// a `tenant_quota` configured; `None` is exempt from quotas.
    /// Not part of the batch key.
    pub tenant: Option<u32>,
    /// Relative deadline, measured from submission. A job still queued
    /// when it expires is resolved with
    /// [`Error::DeadlineExceeded`](crate::error::Error::DeadlineExceeded)
    /// and **never dispatched**; a job already executing runs to
    /// completion. `None` falls back to the service's
    /// `default_deadline` (if any). Not part of the batch key.
    pub deadline: Option<Duration>,
}

impl JobSpec {
    /// An analysis (FSOFT) job with default options and priority.
    pub fn forward(bandwidth: usize) -> Self {
        Self {
            direction: Direction::Forward,
            bandwidth,
            options: PlanOptions::default(),
            priority: JobPriority::default(),
            tenant: None,
            deadline: None,
        }
    }

    /// A synthesis (iFSOFT) job with default options and priority.
    pub fn inverse(bandwidth: usize) -> Self {
        Self {
            direction: Direction::Inverse,
            ..Self::forward(bandwidth)
        }
    }

    /// Override the plan options (a new options value is a new plan
    /// registry key — and a new batch key).
    pub fn options(mut self, options: PlanOptions) -> Self {
        self.options = options;
        self
    }

    /// Override the dispatch priority.
    pub fn priority(mut self, priority: JobPriority) -> Self {
        self.priority = priority;
        self
    }

    /// Tag the job with a tenant id (see the `tenant` field).
    pub fn tenant(mut self, tenant: u32) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// Set a relative deadline (see the `deadline` field).
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Override the memory budget for this job only (a new budget is a
    /// new plan registry key — and a new batch key). An explicit spec
    /// budget always wins over the service-level default set via
    /// [`So3ServiceBuilder::memory_budget`](super::So3ServiceBuilder::memory_budget).
    pub fn memory_budget(mut self, budget: crate::coordinator::MemoryBudget) -> Self {
        self.options.memory = budget;
        self
    }

    /// The coalescing key: jobs batch together iff this matches.
    pub(crate) fn batch_key(&self) -> BatchKey {
        BatchKey {
            direction: self.direction,
            plan: PlanKey {
                bandwidth: self.bandwidth,
                options: self.options,
            },
        }
    }
}

/// `(direction, plan-key)` — what micro-batching coalesces on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct BatchKey {
    pub direction: Direction,
    pub plan: PlanKey,
}

/// Job payload: a grid for forward jobs, coefficients for inverse jobs.
/// The service takes ownership and **recycles the buffer into its pool**
/// after execution — pair with
/// [`So3Service::checkout_grid`](super::So3Service::checkout_grid) /
/// [`checkout_coeffs`](super::So3Service::checkout_coeffs) for a
/// steady-state loop that allocates nothing per job.
#[derive(Debug, Clone)]
pub enum JobInput {
    /// Grid samples (forward/analysis input).
    Grid(So3Grid),
    /// SO(3) coefficients (inverse/synthesis input).
    Coeffs(So3Coeffs),
}

impl JobInput {
    /// Bandwidth of the payload.
    pub fn bandwidth(&self) -> usize {
        match self {
            JobInput::Grid(g) => g.bandwidth(),
            JobInput::Coeffs(c) => c.bandwidth(),
        }
    }

    /// Human-readable payload kind (for error messages).
    pub fn kind(&self) -> &'static str {
        match self {
            JobInput::Grid(_) => "grid",
            JobInput::Coeffs(_) => "coefficient",
        }
    }
}

impl From<So3Grid> for JobInput {
    fn from(g: So3Grid) -> Self {
        JobInput::Grid(g)
    }
}

impl From<So3Coeffs> for JobInput {
    fn from(c: So3Coeffs) -> Self {
        JobInput::Coeffs(c)
    }
}

/// Job result: coefficients for forward jobs, a grid for inverse jobs.
/// Hand it back to the service with
/// [`So3Service::recycle`](super::So3Service::recycle) once consumed to
/// keep the steady-state path allocation-free.
#[derive(Debug, Clone, PartialEq)]
pub enum JobOutput {
    /// SO(3) coefficients (forward/analysis output).
    Coeffs(So3Coeffs),
    /// Grid samples (inverse/synthesis output).
    Grid(So3Grid),
}

impl JobOutput {
    /// Bandwidth of the payload.
    pub fn bandwidth(&self) -> usize {
        match self {
            JobOutput::Coeffs(c) => c.bandwidth(),
            JobOutput::Grid(g) => g.bandwidth(),
        }
    }

    /// The coefficients of a forward job (`None` for an inverse result).
    pub fn into_coeffs(self) -> Option<So3Coeffs> {
        match self {
            JobOutput::Coeffs(c) => Some(c),
            JobOutput::Grid(_) => None,
        }
    }

    /// The grid of an inverse job (`None` for a forward result).
    pub fn into_grid(self) -> Option<So3Grid> {
        match self {
            JobOutput::Grid(g) => Some(g),
            JobOutput::Coeffs(_) => None,
        }
    }

    /// The coefficients, if this is a forward result.
    pub fn coeffs(&self) -> Option<&So3Coeffs> {
        match self {
            JobOutput::Coeffs(c) => Some(c),
            JobOutput::Grid(_) => None,
        }
    }

    /// The grid, if this is an inverse result.
    pub fn grid(&self) -> Option<&So3Grid> {
        match self {
            JobOutput::Grid(g) => Some(g),
            JobOutput::Coeffs(_) => None,
        }
    }
}

/// Completion slot shared between a [`JobHandle`] and the dispatcher.
pub(crate) struct JobState {
    /// `Some((result, latency))` once fulfilled; taken by `wait`.
    slot: Mutex<Option<(Result<JobOutput>, Duration)>>,
    cv: Condvar,
    submitted: Instant,
    /// Set (Release) after the slot is filled — the lock-free fast path
    /// for `is_done` / `try_wait`.
    done: AtomicBool,
    /// Set by `JobHandle::cancel`; honored by the dispatcher for jobs
    /// still queued at dequeue time.
    cancelled: AtomicBool,
    /// Pool to recycle an *unclaimed* successful output into when the
    /// last reference (handle + dispatcher) drops — see `JobHandle`.
    pool: Option<Arc<WorkspacePool>>,
}

impl JobState {
    pub(crate) fn new() -> Arc<Self> {
        Self::with_pool(None)
    }

    /// A state whose abandoned output recycles into `pool`.
    pub(crate) fn with_pool(pool: Option<Arc<WorkspacePool>>) -> Arc<Self> {
        Arc::new(Self {
            slot: Mutex::new(None),
            cv: Condvar::new(),
            submitted: Instant::now(),
            done: AtomicBool::new(false),
            cancelled: AtomicBool::new(false),
            pool,
        })
    }

    /// Publish the result (dispatcher side) and wake the waiter. The
    /// recorded latency is submit-to-fulfillment wall time.
    pub(crate) fn fulfill(&self, result: Result<JobOutput>) {
        let latency = self.submitted.elapsed();
        let mut slot = lock(&self.slot);
        *slot = Some((result, latency));
        // ordering: Release — publishes the filled slot above; pairs
        // with the Acquire load in `is_done` so a lock-free poll that
        // sees `done == true` also sees the result under the slot lock.
        self.done.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Wall time since submission (the latency an expiring job reports).
    pub(crate) fn elapsed(&self) -> Duration {
        self.submitted.elapsed()
    }

    pub(crate) fn is_cancelled(&self) -> bool {
        // ordering: Acquire — pairs with the Release store in `cancel`;
        // the dispatcher's skim must not act on a reordered-early read.
        self.cancelled.load(Ordering::Acquire)
    }
}

impl Drop for JobState {
    fn drop(&mut self) {
        // Last reference gone with the result still in the slot: the
        // handle was dropped without waiting. Recycle a successful
        // output into the pool (subject to `MAX_FREE_PER_KEY`) so
        // fire-and-forget traffic does not leak one buffer per job.
        let Some(pool) = self.pool.take() else {
            return;
        };
        let slot = self.slot.get_mut().unwrap_or_else(|p| p.into_inner());
        if let Some((Ok(out), _)) = slot.take() {
            match out {
                JobOutput::Grid(g) => pool.checkin_grid(g),
                JobOutput::Coeffs(c) => pool.checkin_coeffs(c),
            }
        }
    }
}

/// Outcome of a non-blocking [`JobHandle::try_wait`].
#[derive(Debug)]
pub enum TryWait {
    /// The job resolved; here is its result.
    Ready(Result<JobOutput>),
    /// Still in flight — the handle is returned for another poll.
    Pending(JobHandle),
}

/// Handle to a submitted job. Blocks on [`Self::wait`] until the
/// dispatcher fulfills it, or polls with [`Self::try_wait`].
///
/// Dropping the handle abandons the result: the job still runs, and an
/// unclaimed successful output is **recycled into the service's
/// [`WorkspacePool`]** (subject to
/// [`MAX_FREE_PER_KEY`](super::MAX_FREE_PER_KEY)) once the dispatcher
/// releases its reference — fire-and-forget traffic stays
/// allocation-free in steady state, same as `wait()` + `recycle()`.
pub struct JobHandle {
    pub(crate) state: Arc<JobState>,
}

impl JobHandle {
    /// Block until the job completes and return its output.
    pub fn wait(self) -> Result<JobOutput> {
        self.wait_timed().map(|(out, _)| out)
    }

    /// Block until the job completes; also return the submit-to-complete
    /// latency (what `serve-bench` aggregates into p50/p99).
    pub fn wait_timed(self) -> Result<(JobOutput, Duration)> {
        let mut slot = lock(&self.state.slot);
        loop {
            if let Some((result, latency)) = slot.take() {
                return result.map(|out| (out, latency));
            }
            slot = self.state.cv.wait(slot).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Non-blocking completion check: the result when the job has
    /// resolved, the handle back otherwise.
    pub fn try_wait(self) -> TryWait {
        if !self.is_done() {
            return TryWait::Pending(self);
        }
        match lock(&self.state.slot).take() {
            Some((result, _)) => TryWait::Ready(result),
            // `done` is set strictly after the slot is filled, so a
            // taken slot here means a concurrent waiter consumed it —
            // impossible for a by-value handle, but stay total.
            None => TryWait::Pending(self),
        }
    }

    /// Request cancellation. **Best-effort**: a job still queued when
    /// the dispatcher next looks at it resolves with
    /// [`Error::Cancelled`](crate::error::Error::Cancelled) and never
    /// executes; a job already dispatched runs to completion and
    /// fulfills normally. Returns `false` if the job had already
    /// resolved (the request is then a no-op), `true` if the request
    /// was recorded.
    pub fn cancel(&self) -> bool {
        if self.is_done() {
            return false;
        }
        // ordering: Release — pairs with the Acquire in `is_cancelled`
        // (dispatcher skim); everything the caller did before cancelling
        // is visible to whoever observes the flag.
        self.state.cancelled.store(true, Ordering::Release);
        true
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        // ordering: Acquire — pairs with `fulfill`'s Release store; see
        // the comment there.
        self.state.done.load(Ordering::Acquire)
    }
}

impl fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JobHandle")
            .field("done", &self.is_done())
            .finish()
    }
}

/// One queued job (spec + payload + completion slot + admission data).
pub(crate) struct QueuedJob {
    pub spec: JobSpec,
    pub input: JobInput,
    pub state: Arc<JobState>,
    /// Absolute expiry (`submit time + effective deadline`); `None` =
    /// no deadline.
    pub deadline_at: Option<Instant>,
    /// Bytes charged against the in-flight cap at admission; released
    /// when the job resolves.
    pub cost_bytes: usize,
}

/// Index of the job that leads the next batch: highest priority wins;
/// within a priority level the earliest submission (the deque is kept
/// in submission order) wins.
pub(crate) fn pick_leader(jobs: &VecDeque<QueuedJob>) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, job) in jobs.iter().enumerate() {
        match best {
            None => best = Some(i),
            Some(b) if job.spec.priority > jobs[b].spec.priority => best = Some(i),
            Some(_) => {}
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queued(spec: JobSpec) -> QueuedJob {
        QueuedJob {
            spec,
            input: JobInput::Coeffs(So3Coeffs::zeros(spec.bandwidth)),
            state: JobState::new(),
            deadline_at: None,
            cost_bytes: 0,
        }
    }

    #[test]
    fn priority_orders_low_normal_high() {
        assert!(JobPriority::Low < JobPriority::Normal);
        assert!(JobPriority::Normal < JobPriority::High);
        assert_eq!(JobPriority::default(), JobPriority::Normal);
    }

    #[test]
    fn leader_is_highest_priority_then_fifo() {
        let mut jobs = VecDeque::new();
        jobs.push_back(queued(JobSpec::inverse(4).priority(JobPriority::Low)));
        jobs.push_back(queued(JobSpec::inverse(4)));
        jobs.push_back(queued(JobSpec::inverse(8).priority(JobPriority::High)));
        jobs.push_back(queued(JobSpec::inverse(16).priority(JobPriority::High)));
        // The first High job leads, not the later one.
        assert_eq!(pick_leader(&jobs), Some(2));
        jobs.remove(2);
        jobs.remove(2);
        // Then Normal beats Low regardless of arrival order.
        assert_eq!(pick_leader(&jobs), Some(1));
        jobs.clear();
        assert_eq!(pick_leader(&jobs), None);
    }

    #[test]
    fn batch_key_separates_direction_bandwidth_options() {
        let a = JobSpec::forward(8);
        let b = JobSpec::inverse(8);
        let c = JobSpec::forward(16);
        let opts = PlanOptions {
            real_input: true,
            ..PlanOptions::default()
        };
        let d = JobSpec::forward(8).options(opts);
        assert_ne!(a.batch_key(), b.batch_key());
        assert_ne!(a.batch_key(), c.batch_key());
        assert_ne!(a.batch_key(), d.batch_key());
        // A per-job memory budget is part of the key too: capped and
        // uncapped jobs never share a plan or a micro-batch.
        let capped = JobSpec::forward(8)
            .memory_budget(crate::coordinator::MemoryBudget::Bytes(1 << 30));
        assert_ne!(a.batch_key(), capped.batch_key());
        // Priority does NOT split batches.
        assert_eq!(
            a.batch_key(),
            JobSpec::forward(8).priority(JobPriority::High).batch_key()
        );
        // Neither do tenant or deadline: they are admission/expiry
        // concerns, orthogonal to which plan executes the job.
        let tagged = JobSpec::forward(8)
            .tenant(42)
            .deadline(Duration::from_millis(5));
        assert_eq!(a.batch_key(), tagged.batch_key());
        assert_eq!(tagged.tenant, Some(42));
        assert_eq!(tagged.deadline, Some(Duration::from_millis(5)));
    }

    #[test]
    fn output_accessors_are_typed() {
        let out = JobOutput::Coeffs(So3Coeffs::zeros(4));
        assert_eq!(out.bandwidth(), 4);
        assert!(out.coeffs().is_some());
        assert!(out.grid().is_none());
        assert!(out.clone().into_grid().is_none());
        assert!(out.into_coeffs().is_some());
        let out = JobOutput::Grid(So3Grid::zeros(2).unwrap());
        assert!(out.clone().into_grid().is_some());
        assert!(out.into_coeffs().is_none());
    }

    #[test]
    fn input_kind_and_bandwidth() {
        let g: JobInput = So3Grid::zeros(2).unwrap().into();
        assert_eq!(g.kind(), "grid");
        assert_eq!(g.bandwidth(), 2);
        let c: JobInput = So3Coeffs::zeros(4).into();
        assert_eq!(c.kind(), "coefficient");
        assert_eq!(c.bandwidth(), 4);
    }

    #[test]
    fn handle_fulfill_wakes_waiter_with_latency() {
        let state = JobState::new();
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        assert!(!handle.is_done());
        let waiter = std::thread::spawn(move || handle.wait_timed().unwrap());
        state.fulfill(Ok(JobOutput::Coeffs(So3Coeffs::zeros(2))));
        let (out, latency) = waiter.join().unwrap();
        assert_eq!(out.bandwidth(), 2);
        assert!(latency.as_nanos() > 0);
    }

    #[test]
    fn try_wait_polls_without_blocking() {
        let state = JobState::new();
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        let handle = match handle.try_wait() {
            TryWait::Pending(h) => h,
            TryWait::Ready(r) => panic!("unfulfilled job reported ready: {r:?}"),
        };
        state.fulfill(Ok(JobOutput::Coeffs(So3Coeffs::zeros(2))));
        match handle.try_wait() {
            TryWait::Ready(Ok(out)) => assert_eq!(out.bandwidth(), 2),
            other => panic!("expected Ready(Ok), got {other:?}"),
        }
    }

    #[test]
    fn cancel_is_recorded_until_fulfilled() {
        let state = JobState::new();
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        assert!(!state.is_cancelled());
        assert!(handle.cancel());
        assert!(state.is_cancelled());
        state.fulfill(Err(crate::error::Error::Cancelled));
        // Once resolved, further cancel requests are no-ops.
        assert!(!handle.cancel());
    }

    #[test]
    fn abandoned_output_recycles_into_the_pool() {
        let pool = Arc::new(WorkspacePool::new());
        let state = JobState::with_pool(Some(Arc::clone(&pool)));
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        state.fulfill(Ok(JobOutput::Grid(So3Grid::zeros(2).unwrap())));
        drop(handle);
        drop(state); // last reference — Drop recycles the output
        assert_eq!(pool.stats().free_grids, 1);

        // A waited handle consumes the slot: nothing left to recycle.
        let state = JobState::with_pool(Some(Arc::clone(&pool)));
        let handle = JobHandle {
            state: Arc::clone(&state),
        };
        state.fulfill(Ok(JobOutput::Grid(So3Grid::zeros(2).unwrap())));
        drop(state);
        let out = handle.wait().unwrap();
        drop(out); // caller-owned now; dropped without recycle()
        assert_eq!(pool.stats().free_grids, 1);

        // Failed results have no buffer; Drop is a no-op.
        let state = JobState::with_pool(Some(Arc::clone(&pool)));
        state.fulfill(Err(crate::error::Error::Cancelled));
        drop(state);
        assert_eq!(pool.stats().free_grids, 1);
    }
}

//! Per-bandwidth free lists of [`Workspace`]s and transform I/O buffers.
//!
//! Steady-state serving must not allocate per job: the dispatcher checks
//! a workspace out per micro-batch and returns it afterwards, input
//! payloads are recycled into the pool once consumed, and outputs come
//! from the pool too (callers hand them back with
//! [`So3Service::recycle`](super::So3Service::recycle)). Free lists are
//! LIFO, so a steady single-key load keeps hitting the same (cache-warm,
//! pointer-stable) buffers — which is exactly what the no-allocation
//! tests assert.
//!
//! Pooled buffers carry **unspecified contents** (whatever the previous
//! job left); every transform entry point fully overwrites its output,
//! and callers filling an input buffer overwrite it anyway.
//!
//! Free lists are **capped** per (bandwidth, kind): beyond
//! [`MAX_FREE_PER_KEY`] a checked-in buffer is dropped instead of
//! retained. Without the cap, traffic whose inputs are caller-allocated
//! (every `So3Coeffs::random(..)` submitted by value) would grow the
//! pool by one buffer per job forever — recycling must bound memory,
//! not leak it.
//!
//! **Abandoned handles recycle too**: a [`JobHandle`](super::JobHandle)
//! dropped without `wait` returns its completed output to these free
//! lists from the job state's `Drop` (subject to the same cap), so
//! fire-and-forget or cancelled callers no longer leak one output
//! buffer per abandoned job.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::Workspace;
use crate::error::{Error, Result};
use crate::so3::coeffs::So3Coeffs;
use crate::so3::sampling::So3Grid;
use crate::util::lock_unpoisoned as lock;

/// Largest free-list length kept per (bandwidth, kind); see the
/// [module docs](self). Sized well above any realistic in-flight count
/// (dispatcher batches cap at the service's `max_batch`, clients hold
/// one buffer each), so steady reuse never hits it.
pub const MAX_FREE_PER_KEY: usize = 64;

/// Push unless the free list is at [`MAX_FREE_PER_KEY`] (drop instead).
fn push_capped<T>(list: &mut Vec<T>, item: T) {
    if list.len() < MAX_FREE_PER_KEY {
        list.push(item);
    }
}

#[derive(Default)]
struct FreeLists {
    workspaces: HashMap<usize, Vec<Workspace>>,
    grids: HashMap<usize, Vec<So3Grid>>,
    coeffs: HashMap<usize, Vec<So3Coeffs>>,
}

/// Allocation counters and free-list occupancy of a [`WorkspacePool`].
/// The `*_created` counters are the pool's high-watermark: under steady
/// load they stop growing once the pool warmed up.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WorkspacePoolStats {
    /// Workspaces ever constructed (high-watermark, not current).
    pub workspaces_created: usize,
    /// Grid buffers ever constructed.
    pub grids_created: usize,
    /// Coefficient buffers ever constructed.
    pub coeffs_created: usize,
    /// Workspaces currently checked in.
    pub free_workspaces: usize,
    /// Grid buffers currently checked in.
    pub free_grids: usize,
    /// Coefficient buffers currently checked in.
    pub free_coeffs: usize,
}

/// See the [module docs](self).
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<FreeLists>,
    workspaces_created: AtomicUsize,
    grids_created: AtomicUsize,
    coeffs_created: AtomicUsize,
}

impl WorkspacePool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// A workspace for bandwidth `b`: pooled if one is free, freshly
    /// allocated otherwise.
    pub fn checkout_workspace(&self, b: usize) -> Result<Workspace> {
        if let Some(ws) = lock(&self.free).workspaces.get_mut(&b).and_then(Vec::pop) {
            return Ok(ws);
        }
        let ws = Workspace::new(b)?;
        // ordering: Relaxed — standalone high-watermark statistic.
        self.workspaces_created.fetch_add(1, Ordering::Relaxed);
        Ok(ws)
    }

    /// Return a workspace to its bandwidth's free list.
    pub fn checkin_workspace(&self, ws: Workspace) {
        let mut free = lock(&self.free);
        push_capped(free.workspaces.entry(ws.bandwidth()).or_default(), ws);
    }

    /// A grid buffer for bandwidth `b` (contents unspecified).
    pub fn checkout_grid(&self, b: usize) -> Result<So3Grid> {
        if let Some(g) = lock(&self.free).grids.get_mut(&b).and_then(Vec::pop) {
            return Ok(g);
        }
        let g = So3Grid::zeros(b)?;
        // ordering: Relaxed — standalone high-watermark statistic.
        self.grids_created.fetch_add(1, Ordering::Relaxed);
        Ok(g)
    }

    /// Return a grid buffer for reuse.
    pub fn checkin_grid(&self, g: So3Grid) {
        let mut free = lock(&self.free);
        push_capped(free.grids.entry(g.bandwidth()).or_default(), g);
    }

    /// A coefficient buffer for bandwidth `b` (contents unspecified).
    pub fn checkout_coeffs(&self, b: usize) -> Result<So3Coeffs> {
        if b == 0 {
            return Err(Error::InvalidBandwidth(0));
        }
        if let Some(c) = lock(&self.free).coeffs.get_mut(&b).and_then(Vec::pop) {
            return Ok(c);
        }
        let c = So3Coeffs::zeros(b);
        // ordering: Relaxed — standalone high-watermark statistic.
        self.coeffs_created.fetch_add(1, Ordering::Relaxed);
        Ok(c)
    }

    /// Return a coefficient buffer for reuse.
    pub fn checkin_coeffs(&self, c: So3Coeffs) {
        let mut free = lock(&self.free);
        push_capped(free.coeffs.entry(c.bandwidth()).or_default(), c);
    }

    /// Construction and free-list counters.
    pub fn stats(&self) -> WorkspacePoolStats {
        let free = lock(&self.free);
        WorkspacePoolStats {
            // ordering: Relaxed — statistics snapshot; each counter is
            // an independent tally, not a consistent cut.
            workspaces_created: self.workspaces_created.load(Ordering::Relaxed),
            grids_created: self.grids_created.load(Ordering::Relaxed),
            coeffs_created: self.coeffs_created.load(Ordering::Relaxed),
            free_workspaces: free.workspaces.values().map(Vec::len).sum(),
            free_grids: free.grids.values().map(Vec::len).sum(),
            free_coeffs: free.coeffs.values().map(Vec::len).sum(),
        }
    }
}

impl fmt::Debug for WorkspacePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkspacePool")
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_checkin_is_lifo_and_pointer_stable() {
        let pool = WorkspacePool::new();
        let ws = pool.checkout_workspace(4).unwrap();
        let ptr = ws.work_ptr();
        pool.checkin_workspace(ws);
        // The same allocation comes back (LIFO pop).
        let again = pool.checkout_workspace(4).unwrap();
        assert_eq!(again.work_ptr(), ptr);
        assert_eq!(pool.stats().workspaces_created, 1);
        pool.checkin_workspace(again);

        let g = pool.checkout_grid(4).unwrap();
        let gptr = g.as_slice().as_ptr();
        pool.checkin_grid(g);
        assert_eq!(pool.checkout_grid(4).unwrap().as_slice().as_ptr(), gptr);
        assert_eq!(pool.stats().grids_created, 1);

        let c = pool.checkout_coeffs(4).unwrap();
        let cptr = c.as_slice().as_ptr();
        pool.checkin_coeffs(c);
        assert_eq!(pool.checkout_coeffs(4).unwrap().as_slice().as_ptr(), cptr);
        assert_eq!(pool.stats().coeffs_created, 1);
    }

    #[test]
    fn bandwidths_are_isolated() {
        let pool = WorkspacePool::new();
        let w4 = pool.checkout_workspace(4).unwrap();
        pool.checkin_workspace(w4);
        // A b=8 request must not receive the pooled b=4 workspace.
        let w8 = pool.checkout_workspace(8).unwrap();
        assert_eq!(w8.bandwidth(), 8);
        assert_eq!(pool.stats().workspaces_created, 2);
        let s = pool.stats();
        assert_eq!(s.free_workspaces, 1);
        pool.checkin_workspace(w8);
        assert_eq!(pool.stats().free_workspaces, 2);
    }

    #[test]
    fn created_counts_stop_growing_under_reuse() {
        let pool = WorkspacePool::new();
        for _ in 0..10 {
            let ws = pool.checkout_workspace(2).unwrap();
            let g = pool.checkout_grid(2).unwrap();
            let c = pool.checkout_coeffs(2).unwrap();
            pool.checkin_coeffs(c);
            pool.checkin_grid(g);
            pool.checkin_workspace(ws);
        }
        let s = pool.stats();
        assert_eq!(
            (s.workspaces_created, s.grids_created, s.coeffs_created),
            (1, 1, 1)
        );
    }

    #[test]
    fn free_lists_are_capped_not_unbounded() {
        let pool = WorkspacePool::new();
        // Caller-allocated buffers checked in beyond the cap are dropped.
        for i in 0..(MAX_FREE_PER_KEY + 40) {
            pool.checkin_coeffs(So3Coeffs::random(2, i as u64));
            pool.checkin_grid(So3Grid::zeros(2).unwrap());
        }
        let s = pool.stats();
        assert_eq!(s.free_coeffs, MAX_FREE_PER_KEY);
        assert_eq!(s.free_grids, MAX_FREE_PER_KEY);
        // The cap is per bandwidth: a second key gets its own list.
        pool.checkin_grid(So3Grid::zeros(4).unwrap());
        assert_eq!(pool.stats().free_grids, MAX_FREE_PER_KEY + 1);
    }

    #[test]
    fn zero_bandwidth_is_typed_error() {
        let pool = WorkspacePool::new();
        assert!(pool.checkout_workspace(0).is_err());
        assert!(pool.checkout_grid(0).is_err());
        assert!(pool.checkout_coeffs(0).is_err());
    }
}

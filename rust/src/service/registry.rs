//! The plan registry: a lazily-built, concurrently-shared cache of
//! [`So3Plan`]s keyed by `(bandwidth, PlanOptions)`.
//!
//! Plans are the expensive part of serving (Wigner tables, partition
//! plans, FFT twiddles); the registry builds each key **once**, hands
//! out `Arc` clones to every caller, and — when configured with a byte
//! budget — evicts least-recently-used plans using the same
//! [`So3Plan::table_bytes`] accounting `WignerStorage::auto` uses.
//! Eviction only drops the registry's reference: in-flight callers
//! holding an `Arc` keep executing on the evicted plan, and a later
//! request for the key simply rebuilds it.
//!
//! **Failed builds are cached too**: a key whose build errors is served
//! the typed
//! [`Error::PlanBuildFailed`](crate::error::Error::PlanBuildFailed)
//! without rebuilding until an exponential backoff elapses
//! ([`PlanRegistry::set_build_backoff`]) — a persistently bad key (or a
//! table file that keeps failing to load) costs one build per backoff
//! window instead of one per miss. A successful build clears the entry.

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::coordinator::{ExecutorConfig, MemoryBudget, PartitionStrategy};
use crate::dwt::tables::WignerStorage;
use crate::dwt::{DwtAlgorithm, Precision};
use crate::error::{Error, Result};
use crate::faults;
use crate::fft::FftEngine;
use crate::pool::{PoolSpec, Schedule, WorkerPool};
use crate::simd::SimdPolicy;
use crate::transform::So3Plan;
use crate::util::{lock_unpoisoned, read_unpoisoned as read, write_unpoisoned as write};
use crate::wisdom::{PlanRigor, WisdomStore};

/// The plan-shaping configuration axes — everything of
/// [`ExecutorConfig`] except the execution substrate (`threads`,
/// `pool`), which the owning [`So3Service`](super::So3Service) supplies.
/// Hashable/comparable, so it forms registry and batch keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanOptions {
    /// Loop schedule for the DWT region (paper default: `dynamic`).
    pub schedule: Schedule,
    /// Order-domain partitioning strategy.
    pub strategy: PartitionStrategy,
    /// DWT dataflow (default: the β-parity-folded engine).
    pub algorithm: DwtAlgorithm,
    /// Wigner row storage.
    pub storage: WignerStorage,
    /// DWT accumulation precision.
    pub precision: Precision,
    /// FFT-stage engine.
    pub fft_engine: FftEngine,
    /// Conjugate-even forward FFT stage (real samples only).
    pub real_input: bool,
    /// SIMD kernel dispatch policy (resolved per plan at build time).
    pub simd: SimdPolicy,
    /// Memory budget, resolved at plan build into table
    /// materialization / streaming choices. Part of the key: jobs with
    /// different budgets never share a cached plan.
    pub memory: MemoryBudget,
}

impl Default for PlanOptions {
    fn default() -> Self {
        Self::from_exec(&ExecutorConfig::default())
    }
}

impl PlanOptions {
    /// The plan-shaping axes of an executor config (drops `threads` and
    /// `pool`, which the service owns).
    pub fn from_exec(config: &ExecutorConfig) -> Self {
        Self {
            schedule: config.schedule,
            strategy: config.strategy,
            algorithm: config.algorithm,
            storage: config.storage,
            precision: config.precision,
            fft_engine: config.fft_engine,
            real_input: config.real_input,
            simd: config.simd,
            memory: config.memory,
        }
    }

    /// Expand back into a full executor config on the given substrate.
    pub fn to_exec(self, threads: usize, pool: PoolSpec) -> ExecutorConfig {
        ExecutorConfig {
            threads,
            schedule: self.schedule,
            strategy: self.strategy,
            algorithm: self.algorithm,
            storage: self.storage,
            precision: self.precision,
            fft_engine: self.fft_engine,
            real_input: self.real_input,
            simd: self.simd,
            memory: self.memory,
            pool,
        }
    }
}

/// Registry key: one cached plan per `(bandwidth, options)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Transform bandwidth B.
    pub bandwidth: usize,
    /// Plan options baked into the cached plan.
    pub options: PlanOptions,
}

struct Entry {
    plan: Arc<So3Plan>,
    /// `table_bytes()` at build time (plans are immutable).
    bytes: usize,
    /// LRU clock tick of the last `get` (atomic so hits only need the
    /// read lock).
    last_used: AtomicU64,
}

/// Counters of one registry (monotonic; read via
/// [`PlanRegistry::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RegistryStats {
    /// Plans currently cached.
    pub plans: usize,
    /// Sum of `table_bytes()` over the cached plans.
    pub table_bytes: usize,
    /// Lookups served from cache.
    pub hits: u64,
    /// Lookups that triggered (or waited on) a build.
    pub misses: u64,
    /// Plans evicted by the LRU capacity policy.
    pub evictions: u64,
    /// Builds that returned an error (monotonic).
    pub build_failures: u64,
    /// Keys currently carrying a cached build failure.
    pub failed_keys: usize,
}

/// Cached outcome of a failed build (see the [module docs](self)).
struct BuildFailure {
    msg: String,
    attempts: u32,
    /// Next instant at which a rebuild is allowed.
    retry_at: Instant,
}

/// See the [module docs](self).
pub struct PlanRegistry {
    /// Region width for every cached plan.
    threads: usize,
    /// The shared worker pool plans execute on (`None` ⇒ sequential).
    pool: Option<Arc<WorkerPool>>,
    /// Table-byte budget; `None` = unbounded.
    budget: Option<usize>,
    allow_any_bandwidth: bool,
    /// Planning rigor for every build. Under `Measure`, the existing
    /// single-flight machinery doubles as measurement deduplication: N
    /// concurrent cold misses on one key run ONE search pass.
    rigor: PlanRigor,
    /// Wisdom store for `Measure` builds (`None` = the global store).
    wisdom: Option<Arc<WisdomStore>>,
    plans: RwLock<HashMap<PlanKey, Entry>>,
    /// Keys with a build in flight — single-flight deduplication so N
    /// concurrent cold requests for one key run ONE table build, not N
    /// (which would also spike memory N× past any budget).
    building: Mutex<HashSet<PlanKey>>,
    building_cv: Condvar,
    /// Cached build failures, served until their backoff elapses.
    /// Lock order: `building` → `failures` (never reversed).
    failures: Mutex<HashMap<PlanKey, BuildFailure>>,
    /// Backoff for failed builds: `base << (attempts-1)`, capped.
    backoff_base_ms: AtomicU64,
    backoff_cap_ms: AtomicU64,
    build_failures: AtomicU64,
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanRegistry {
    pub(crate) fn new(
        threads: usize,
        pool: Option<Arc<WorkerPool>>,
        budget: Option<usize>,
        allow_any_bandwidth: bool,
        rigor: PlanRigor,
        wisdom: Option<Arc<WisdomStore>>,
    ) -> Self {
        Self {
            threads,
            pool,
            budget,
            allow_any_bandwidth,
            rigor,
            wisdom,
            plans: RwLock::new(HashMap::new()),
            building: Mutex::new(HashSet::new()),
            building_cv: Condvar::new(),
            failures: Mutex::new(HashMap::new()),
            backoff_base_ms: AtomicU64::new(100),
            backoff_cap_ms: AtomicU64::new(5_000),
            build_failures: AtomicU64::new(0),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The cached plan for `key`, built on first request. Every caller
    /// of an equal key receives the **same** `Arc` (until eviction);
    /// concurrent cold requests for one key share a single build.
    pub fn get(&self, key: PlanKey) -> Result<Arc<So3Plan>> {
        // ordering: Relaxed — the LRU clock only needs uniqueness and
        // rough monotonicity per caller; ticks are compared, never used
        // to publish data.
        let tick = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        // Fast path: hits touch only the read lock.
        if let Some(plan) = self.lookup(key, tick) {
            return Ok(plan);
        }
        crate::sched_point!("registry.get.miss");
        // Single-flight claim: leave the loop only as the builder of
        // `key`. Everyone else parks on the condvar until the in-flight
        // build resolves, then re-checks the cache. The re-check happens
        // UNDER the building lock (lock order: building → plans-read,
        // never reversed), closing the race where a finishing builder
        // inserts between our miss and our claim — without it a late
        // claimer would rebuild and replace the cached Arc.
        loop {
            let mut building = lock_unpoisoned(&self.building);
            if let Some(plan) = self.lookup(key, tick) {
                return Ok(plan);
            }
            // A recent failed build is served typed (no rebuild) until
            // its backoff elapses — this is also what single-flight
            // waiters woken by a failing builder observe.
            if let Some(err) = self.cached_failure(key) {
                return Err(err);
            }
            if building.insert(key) {
                break;
            }
            // A failed build leaves no cache entry: the woken waiter
            // re-loops, claims the marker, and retries (surfacing the
            // same typed error if it persists).
            let _guard = self
                .building_cv
                .wait(building)
                .unwrap_or_else(|p| p.into_inner());
        }
        crate::sched_point!("registry.build.claim");
        // Build outside every lock: table construction is the expensive
        // part, and a slow build must not block hits on other keys. The
        // marker comes off (and waiters wake) on EVERY exit, including a
        // builder panic — a leaked marker would park waiters forever —
        // and only AFTER a successful build is cached, so woken waiters
        // hit instead of re-building.
        let release_marker = || {
            let mut building = lock_unpoisoned(&self.building);
            building.remove(&key);
            drop(building);
            self.building_cv.notify_all();
        };
        let built = catch_unwind(AssertUnwindSafe(|| self.build(key)));
        let outcome = match built {
            Ok(Ok(plan)) => {
                let plan = Arc::new(plan);
                let mut map = write(&self.plans);
                debug_assert!(
                    !map.contains_key(&key),
                    "single-flight guarantees one builder"
                );
                // ordering: Relaxed — statistic counter; the inserted
                // entry is published by the plans write lock.
                self.misses.fetch_add(1, Ordering::Relaxed);
                map.insert(
                    key,
                    Entry {
                        plan: Arc::clone(&plan),
                        bytes: plan.table_bytes(),
                        last_used: AtomicU64::new(tick),
                    },
                );
                if let Some(budget) = self.budget {
                    Self::evict_lru(&mut map, budget, key, &self.evictions);
                }
                drop(map);
                self.clear_failure(key);
                crate::sched_point!("registry.build.publish");
                Ok(plan)
            }
            Ok(Err(e)) => {
                // The builder itself surfaces the original error; later
                // misses within the backoff window get the cached
                // `PlanBuildFailed` wrapper.
                self.record_failure(key, &e);
                Err(e)
            }
            Err(payload) => {
                release_marker();
                resume_unwind(payload)
            }
        };
        release_marker();
        outcome
    }

    /// Configure the failed-build backoff: the first failure of a key
    /// blocks rebuilds for `base`, doubling per subsequent failure up to
    /// `cap`. Defaults: 100ms base, 5s cap. `Duration::ZERO` base
    /// disables the caching (every miss retries the build).
    pub fn set_build_backoff(&self, base: Duration, cap: Duration) {
        let to_ms = |d: Duration| d.as_millis().min(u64::MAX as u128) as u64;
        // ordering: Relaxed — tuning knobs read at the next failure; a
        // racing reader using the previous value is acceptable.
        self.backoff_base_ms.store(to_ms(base), Ordering::Relaxed);
        self.backoff_cap_ms.store(to_ms(cap), Ordering::Relaxed);
    }

    /// The typed error for a key still inside its failure backoff;
    /// `None` allows a (re)build.
    fn cached_failure(&self, key: PlanKey) -> Option<Error> {
        let failures = lock_unpoisoned(&self.failures);
        let f = failures.get(&key)?;
        let now = Instant::now();
        if now >= f.retry_at {
            return None;
        }
        Some(Error::PlanBuildFailed {
            msg: f.msg.clone(),
            attempts: f.attempts,
            retry_in: f.retry_at - now,
        })
    }

    fn record_failure(&self, key: PlanKey, e: &Error) {
        // ordering: Relaxed — statistic counter + knob reads (see
        // `set_build_backoff`); the failure record itself is published
        // under the failures mutex below.
        self.build_failures.fetch_add(1, Ordering::Relaxed);
        let base = self.backoff_base_ms.load(Ordering::Relaxed);
        let cap = self.backoff_cap_ms.load(Ordering::Relaxed);
        let mut failures = lock_unpoisoned(&self.failures);
        let f = failures.entry(key).or_insert_with(|| BuildFailure {
            msg: String::new(),
            attempts: 0,
            retry_at: Instant::now(),
        });
        f.attempts += 1;
        f.msg = e.to_string();
        let shift = (f.attempts - 1).min(20);
        let backoff = Duration::from_millis(base.saturating_mul(1u64 << shift).min(cap));
        f.retry_at = Instant::now().checked_add(backoff).unwrap_or_else(Instant::now);
    }

    fn clear_failure(&self, key: PlanKey) {
        lock_unpoisoned(&self.failures).remove(&key);
    }

    /// Cache lookup, bumping the LRU tick and hit counter on success.
    fn lookup(&self, key: PlanKey, tick: u64) -> Option<Arc<So3Plan>> {
        let map = read(&self.plans);
        let e = map.get(&key)?;
        // ordering: Release — pairs with the Acquire load in
        // `evict_lru`: an evictor that takes the plans *write* lock
        // already happens-after this read-locked touch, but the
        // release/acquire pair makes the tick publication explicit
        // rather than leaning on the RwLock upgrade for it.
        e.last_used.store(tick, Ordering::Release);
        // ordering: Relaxed — statistic counter.
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(&e.plan))
    }

    fn build(&self, key: PlanKey) -> Result<So3Plan> {
        // Fault site: an injected error here exercises the failure
        // cache; an injected panic exercises the single-flight marker
        // release and the dispatcher's catch_unwind.
        if let Some(action) = faults::fire(faults::PLAN_BUILD) {
            action.apply(faults::PLAN_BUILD)?;
        }
        let pool_spec = match &self.pool {
            Some(p) => PoolSpec::Shared(Arc::clone(p)),
            None => PoolSpec::Owned,
        };
        let mut builder = So3Plan::builder(key.bandwidth)
            .config(key.options.to_exec(self.threads, pool_spec))
            .rigor(self.rigor);
        if let Some(store) = &self.wisdom {
            builder = builder.wisdom_store(Arc::clone(store));
        }
        if self.allow_any_bandwidth {
            builder = builder.allow_any_bandwidth();
        }
        builder.build()
    }

    /// Drop least-recently-used entries (never `keep`, never the last
    /// one) until the summed `table_bytes()` fits the budget.
    fn evict_lru(
        map: &mut HashMap<PlanKey, Entry>,
        budget: usize,
        keep: PlanKey,
        evictions: &AtomicU64,
    ) {
        loop {
            let total: usize = map.values().map(|e| e.bytes).sum();
            if total <= budget || map.len() <= 1 {
                return;
            }
            let victim = map
                .iter()
                .filter(|(k, _)| **k != keep)
                // ordering: Acquire — pairs with the Release store in
                // `lookup` so the evictor ranks entries by the freshest
                // published touch ticks.
                .min_by_key(|(_, e)| e.last_used.load(Ordering::Acquire))
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    map.remove(&k);
                    // ordering: Relaxed — statistic counter.
                    evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => return,
            }
        }
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        read(&self.plans).len()
    }

    /// Whether no plan is currently cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache counters and current footprint.
    pub fn stats(&self) -> RegistryStats {
        let map = read(&self.plans);
        RegistryStats {
            plans: map.len(),
            table_bytes: map.values().map(|e| e.bytes).sum(),
            // ordering: Relaxed — statistics snapshot; counters are
            // independent tallies, not a consistent cut (hits may lead
            // misses by an in-flight lookup).
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            build_failures: self.build_failures.load(Ordering::Relaxed),
            failed_keys: lock_unpoisoned(&self.failures).len(),
        }
    }
}

impl std::fmt::Debug for PlanRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanRegistry")
            .field("threads", &self.threads)
            .field("budget", &self.budget)
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    fn key(b: usize) -> PlanKey {
        PlanKey {
            bandwidth: b,
            options: PlanOptions::default(),
        }
    }

    #[test]
    fn equal_keys_share_one_arc_distinct_keys_do_not() {
        let reg = PlanRegistry::new(1, None, None, false, PlanRigor::Estimate, None);
        let a = reg.get(key(4)).unwrap();
        let b = reg.get(key(4)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let opts = PlanOptions {
            storage: WignerStorage::OnTheFly,
            ..PlanOptions::default()
        };
        let c = reg
            .get(PlanKey {
                bandwidth: 4,
                options: opts,
            })
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        let s = reg.stats();
        assert_eq!(s.plans, 2);
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn options_roundtrip_executor_config() {
        let exec = ExecutorConfig {
            threads: 7,
            real_input: true,
            storage: WignerStorage::OnTheFly,
            ..Default::default()
        };
        let opts = PlanOptions::from_exec(&exec);
        assert!(opts.real_input);
        let back = opts.to_exec(3, PoolSpec::Owned);
        assert_eq!(back.threads, 3); // substrate comes from the service
        assert_eq!(back.storage, WignerStorage::OnTheFly);
        assert!(back.real_input);
        assert_eq!(back.simd, SimdPolicy::Auto);
        // Default options mirror the default executor config.
        assert_eq!(
            PlanOptions::default(),
            PlanOptions::from_exec(&ExecutorConfig::default())
        );
    }

    #[test]
    fn byte_budget_evicts_lru_and_rebuilds_on_demand() {
        // Budget sized to exactly one b=4 plan's tables: inserting a
        // second table-carrying plan must evict the older one.
        let b4_bytes = So3Plan::new(4).unwrap().table_bytes();
        assert!(b4_bytes > 0, "b=4 precomputed tables must be non-empty");
        let reg = PlanRegistry::new(1, None, Some(b4_bytes), false, PlanRigor::Estimate, None);
        let first = reg.get(key(4)).unwrap();
        assert_eq!(reg.stats().evictions, 0);
        let _second = reg.get(key(8)).unwrap();
        let s = reg.stats();
        assert_eq!(s.evictions, 1, "older key must be evicted");
        assert_eq!(s.plans, 1, "only the newest plan stays cached");
        // The evicted Arc stays usable by its holders.
        assert_eq!(first.bandwidth(), 4);
        // Re-requesting the evicted key rebuilds (a fresh Arc).
        let rebuilt = reg.get(key(4)).unwrap();
        assert!(!Arc::ptr_eq(&first, &rebuilt));
        assert_eq!(reg.stats().misses, 3);
    }

    #[test]
    fn budget_never_evicts_the_requested_key() {
        // A budget below even one plan keeps the newest entry anyway
        // (evicting the plan just handed out would thrash).
        let reg = PlanRegistry::new(1, None, Some(0), false, PlanRigor::Estimate, None);
        let a = reg.get(key(4)).unwrap();
        assert_eq!(reg.len(), 1);
        let b = reg.get(key(4)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn strict_bandwidth_validation_is_forwarded() {
        let reg = PlanRegistry::new(1, None, None, false, PlanRigor::Estimate, None);
        assert!(matches!(
            reg.get(key(6)),
            Err(Error::NonPowerOfTwoBandwidth(6))
        ));
        // Failed builds are not cached.
        assert!(reg.is_empty());
        let lenient = PlanRegistry::new(1, None, None, true, PlanRigor::Estimate, None);
        assert_eq!(lenient.get(key(6)).unwrap().bandwidth(), 6);
    }

    #[test]
    fn concurrent_cold_requests_share_one_build() {
        let reg = PlanRegistry::new(1, None, None, false, PlanRigor::Estimate, None);
        let plans: Vec<Arc<So3Plan>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| scope.spawn(|| reg.get(key(8)).unwrap()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p));
        }
        let s = reg.stats();
        assert_eq!(s.misses, 1, "single-flight: exactly one build");
        assert_eq!(s.hits, 3);
        assert_eq!(s.plans, 1);
    }

    #[test]
    fn shared_pool_is_reused_by_cached_plans() {
        let pool = Arc::new(WorkerPool::new(2).unwrap());
        let reg = PlanRegistry::new(2, Some(Arc::clone(&pool)), None, false, PlanRigor::Estimate, None);
        let plan = reg.get(key(4)).unwrap();
        assert!(Arc::ptr_eq(plan.pool().unwrap(), &pool));
    }

    #[test]
    fn failed_builds_are_cached_with_backoff() {
        // Strict registry + key(6): the build fails deterministically
        // (non-power-of-two) without needing an injected fault.
        let reg = PlanRegistry::new(1, None, None, false, PlanRigor::Estimate, None);
        reg.set_build_backoff(Duration::from_secs(5), Duration::from_secs(5));
        assert!(matches!(
            reg.get(key(6)),
            Err(Error::NonPowerOfTwoBandwidth(6))
        ));
        // Within the backoff window the cached failure is served typed,
        // with no rebuild attempt.
        match reg.get(key(6)) {
            Err(Error::PlanBuildFailed {
                msg,
                attempts,
                retry_in,
            }) => {
                assert_eq!(attempts, 1);
                assert!(msg.contains("power of two"));
                assert!(retry_in <= Duration::from_secs(5));
            }
            other => panic!("expected PlanBuildFailed, got {:?}", other.map(|_| ())),
        }
        let s = reg.stats();
        assert_eq!(s.build_failures, 1, "the cached miss ran no build");
        assert_eq!(s.failed_keys, 1);
        assert!(reg.is_empty());

        // Zero backoff disables the failure cache: every miss retries
        // the build and surfaces the original error.
        let eager = PlanRegistry::new(1, None, None, false, PlanRigor::Estimate, None);
        eager.set_build_backoff(Duration::ZERO, Duration::ZERO);
        for _ in 0..2 {
            assert!(matches!(
                eager.get(key(6)),
                Err(Error::NonPowerOfTwoBandwidth(6))
            ));
        }
        assert_eq!(eager.stats().build_failures, 2);
    }
}

//! Bounded admission control for [`So3Service`](super::So3Service).
//!
//! Every `submit` passes through [`Admission::try_admit`] **before** the
//! job is queued; a rejection is a typed
//! [`Error::Overloaded`](crate::error::Error::Overloaded) returned to the
//! caller in microseconds instead of unbounded queueing latency. Three
//! independent limits, all optional (absent = unlimited):
//!
//! - **queue depth** (`max_queue`): number of admitted-but-undispatched
//!   jobs;
//! - **in-flight bytes** (`max_inflight_bytes`): summed
//!   [`job_cost_bytes`] of every admitted job that has not yet been
//!   resolved — queued *and* executing. One oversized job is still
//!   admitted when the service is otherwise idle, so a cap smaller than a
//!   single job degrades to serial admission rather than a permanent
//!   reject;
//! - **tenant quota** (`tenant_quota`): per-tenant in-flight job cap,
//!   keyed by [`JobSpec::tenant`](super::JobSpec::tenant); untenanted
//!   jobs are exempt.
//!
//! The `retry_after_hint` carried by the rejection is `queued × EWMA
//! per-job wall time`, clamped to `[1ms, 5s]` — an estimate of when the
//! current backlog will have drained.

use std::collections::HashMap;
use std::mem::size_of;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, OverloadCause, Result};
use crate::fft::Complex64;
use crate::util::lock_unpoisoned;

/// Memory attributed to one job for the in-flight-bytes cap: its sample
/// grid (`(2b)^3` complex values) plus its coefficient vector
/// (`b(4b^2-1)/3` complex values). Both directions hold one of each
/// (input + output), so the cost is direction-independent.
pub(crate) fn job_cost_bytes(b: usize) -> usize {
    let grid = (2 * b) * (2 * b) * (2 * b);
    let coeffs = b * (4 * b * b - 1) / 3;
    (grid + coeffs) * size_of::<Complex64>()
}

/// Shared admission state (one per service; all methods are lock-light
/// and called from `submit` / the dispatcher).
pub(crate) struct Admission {
    max_queue: Option<usize>,
    max_inflight_bytes: Option<usize>,
    tenant_quota: Option<usize>,
    /// Summed [`job_cost_bytes`] of admitted, unresolved jobs.
    inflight_bytes: AtomicUsize,
    /// In-flight job count per tenant (entries removed at zero). Only
    /// maintained when a quota is configured.
    tenants: Mutex<HashMap<u32, usize>>,
    /// EWMA of per-job wall time in ns (0 = no observation yet).
    ewma_job_ns: AtomicU64,
}

impl Admission {
    pub(crate) fn new(
        max_queue: Option<usize>,
        max_inflight_bytes: Option<usize>,
        tenant_quota: Option<usize>,
    ) -> Self {
        Self {
            max_queue,
            max_inflight_bytes,
            tenant_quota,
            inflight_bytes: AtomicUsize::new(0),
            tenants: Mutex::new(HashMap::new()),
            ewma_job_ns: AtomicU64::new(0),
        }
    }

    /// Admit or reject a job. `queued` is the current queue depth (the
    /// caller holds the queue lock, so the value is exact). On `Ok` the
    /// job's cost and tenant slot are charged; the caller MUST later
    /// [`release`](Self::release) exactly once, when the job resolves.
    pub(crate) fn try_admit(&self, queued: usize, cost: usize, tenant: Option<u32>) -> Result<()> {
        crate::sched_point!("admission.check");
        if let Some(cap) = self.max_queue {
            if queued >= cap {
                return Err(self.overloaded(OverloadCause::QueueDepth, queued));
            }
        }
        if let Some(cap) = self.max_inflight_bytes {
            // ordering: Acquire — pairs with the AcqRel RMWs below so a
            // submitter observes the latest charge set. The cap check
            // itself is serialized by the queue lock the caller holds;
            // Acquire keeps the read from sinking below it.
            let cur = self.inflight_bytes.load(Ordering::Acquire);
            // Idle exception: never wedge on a single job larger than
            // the cap — only reject when other work is already charged.
            if cur > 0 && cur.saturating_add(cost) > cap {
                return Err(self.overloaded(OverloadCause::InflightBytes, queued));
            }
        }
        if let Some(quota) = self.tenant_quota {
            if let Some(t) = tenant {
                let mut tenants = lock_unpoisoned(&self.tenants);
                let slot = tenants.entry(t).or_insert(0);
                if *slot >= quota {
                    return Err(self.overloaded(OverloadCause::TenantQuota, queued));
                }
                *slot += 1;
            }
        }
        // ordering: AcqRel — charge must be visible to the next
        // admission check (Release) and see prior releases (Acquire);
        // pairs with `release` and the Acquire load above.
        self.inflight_bytes.fetch_add(cost, Ordering::AcqRel);
        crate::sched_point!("admission.charge");
        Ok(())
    }

    /// Return a resolved job's charges (exactly once per admitted job).
    pub(crate) fn release(&self, cost: usize, tenant: Option<u32>) {
        // ordering: AcqRel — pairs with `try_admit`'s charge so the
        // freed capacity is visible to the next admission check.
        self.inflight_bytes.fetch_sub(cost, Ordering::AcqRel);
        crate::sched_point!("admission.release");
        if self.tenant_quota.is_some() {
            if let Some(t) = tenant {
                let mut tenants = lock_unpoisoned(&self.tenants);
                if let Some(slot) = tenants.get_mut(&t) {
                    *slot = slot.saturating_sub(1);
                    if *slot == 0 {
                        tenants.remove(&t);
                    }
                }
            }
        }
    }

    /// Feed one completed job's wall time into the EWMA (α = 1/8).
    pub(crate) fn observe_job(&self, per_job: Duration) {
        let ns = per_job.as_nanos().min(u64::MAX as u128) as u64;
        // ordering: Relaxed — lossy EWMA estimate; a racing update may
        // drop one observation, which the hint consumers tolerate.
        let prev = self.ewma_job_ns.load(Ordering::Relaxed);
        let next = if prev == 0 { ns } else { prev - prev / 8 + ns / 8 };
        self.ewma_job_ns.store(next.max(1), Ordering::Relaxed);
    }

    /// Estimated backlog drain time: `queued × EWMA`, clamped to
    /// `[1ms, 5s]`; a fixed 10ms before any observation exists.
    pub(crate) fn retry_hint(&self, queued: usize) -> Duration {
        // ordering: Relaxed — best-effort estimate read (see observe_job).
        let ewma = self.ewma_job_ns.load(Ordering::Relaxed);
        if ewma == 0 {
            return Duration::from_millis(10);
        }
        let total = ewma.saturating_mul(queued.max(1) as u64);
        Duration::from_nanos(total).clamp(Duration::from_millis(1), Duration::from_secs(5))
    }

    /// Current charged in-flight bytes (for the metrics snapshot).
    pub(crate) fn inflight_bytes(&self) -> usize {
        // ordering: Acquire — metrics snapshot sees the latest AcqRel
        // charge/release (pairs with try_admit/release).
        self.inflight_bytes.load(Ordering::Acquire)
    }

    fn overloaded(&self, cause: OverloadCause, queued: usize) -> Error {
        Error::Overloaded {
            cause,
            retry_after_hint: self.retry_hint(queued),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_cost_matches_grid_plus_coeffs() {
        // b=4: grid 8^3 = 512, coeffs 4*63/3 = 84.
        assert_eq!(job_cost_bytes(4), (512 + 84) * size_of::<Complex64>());
        assert_eq!(job_cost_bytes(1), (8 + 1) * size_of::<Complex64>());
    }

    #[test]
    fn queue_depth_cap_rejects_at_capacity() {
        let a = Admission::new(Some(2), None, None);
        assert!(a.try_admit(0, 10, None).is_ok());
        assert!(a.try_admit(1, 10, None).is_ok());
        match a.try_admit(2, 10, None) {
            Err(Error::Overloaded { cause, .. }) => {
                assert_eq!(cause, OverloadCause::QueueDepth);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn inflight_bytes_cap_has_an_idle_exception() {
        let a = Admission::new(None, Some(100), None);
        // A job bigger than the cap is admitted while idle...
        assert!(a.try_admit(0, 500, None).is_ok());
        assert_eq!(a.inflight_bytes(), 500);
        // ...but blocks everything else until it resolves.
        match a.try_admit(1, 1, None) {
            Err(Error::Overloaded { cause, .. }) => {
                assert_eq!(cause, OverloadCause::InflightBytes);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        a.release(500, None);
        assert_eq!(a.inflight_bytes(), 0);
        assert!(a.try_admit(0, 1, None).is_ok());
    }

    #[test]
    fn tenant_quota_is_per_tenant_and_released() {
        let a = Admission::new(None, None, Some(1));
        assert!(a.try_admit(0, 1, Some(7)).is_ok());
        match a.try_admit(1, 1, Some(7)) {
            Err(Error::Overloaded { cause, .. }) => {
                assert_eq!(cause, OverloadCause::TenantQuota);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // Other tenants and untenanted jobs are unaffected.
        assert!(a.try_admit(1, 1, Some(8)).is_ok());
        assert!(a.try_admit(2, 1, None).is_ok());
        a.release(1, Some(7));
        assert!(a.try_admit(2, 1, Some(7)).is_ok());
    }

    #[test]
    fn retry_hint_tracks_backlog_and_clamps() {
        let a = Admission::new(Some(1), None, None);
        assert_eq!(a.retry_hint(4), Duration::from_millis(10));
        a.observe_job(Duration::from_millis(2));
        let hint = a.retry_hint(4);
        assert!(hint >= Duration::from_millis(2) && hint <= Duration::from_millis(16));
        a.observe_job(Duration::from_secs(3600));
        assert!(a.retry_hint(100) <= Duration::from_secs(5));
        let b = Admission::new(None, None, None);
        b.observe_job(Duration::from_nanos(1));
        assert!(b.retry_hint(1) >= Duration::from_millis(1));
    }
}

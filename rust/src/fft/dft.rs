//! Direct O(n²) discrete Fourier transform — the correctness oracle.
//!
//! Every fast path in this module tree is tested against this function;
//! it is intentionally the most literal possible transcription of the
//! DFT definition.

use super::{Complex64, Sign};

/// Direct DFT: `out[k] = Σ_j in[j] · e^{sign·2πi jk/n}` (unnormalized).
pub fn dft(input: &[Complex64], sign: Sign) -> Vec<Complex64> {
    let n = input.len();
    let mut out = vec![Complex64::zero(); n];
    if n == 0 {
        return out;
    }
    let base = sign.factor() * std::f64::consts::TAU / n as f64;
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = Complex64::zero();
        for (j, &x) in input.iter().enumerate() {
            // Reduce j*k mod n before the trig call to keep the angle small
            // (accuracy at large n).
            let jk = (j * k) % n;
            acc = acc.mul_add(x, Complex64::cis(base * jk as f64));
        }
        *o = acc;
    }
    out
}

/// Direct 2-D DFT on a row-major `rows × cols` matrix (oracle for fft2).
pub fn dft2(input: &[Complex64], rows: usize, cols: usize, sign: Sign) -> Vec<Complex64> {
    assert_eq!(input.len(), rows * cols);
    let mut out = vec![Complex64::zero(); rows * cols];
    let br = sign.factor() * std::f64::consts::TAU / rows as f64;
    let bc = sign.factor() * std::f64::consts::TAU / cols as f64;
    for u in 0..rows {
        for v in 0..cols {
            let mut acc = Complex64::zero();
            for r in 0..rows {
                for c in 0..cols {
                    let phase = br * ((r * u) % rows) as f64 + bc * ((c * v) % cols) as f64;
                    acc = acc.mul_add(input[r * cols + c], Complex64::cis(phase));
                }
            }
            out[u * cols + v] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dft_of_impulse_is_flat() {
        let mut x = vec![Complex64::zero(); 8];
        x[0] = Complex64::one();
        for y in dft(&x, Sign::Negative) {
            assert!((y - Complex64::one()).abs() < 1e-12);
        }
    }

    #[test]
    fn dft_of_constant_is_impulse() {
        let x = vec![Complex64::one(); 8];
        let y = dft(&x, Sign::Negative);
        assert!((y[0] - Complex64::new(8.0, 0.0)).abs() < 1e-12);
        for v in &y[1..] {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn forward_then_backward_scales_by_n() {
        let x: Vec<Complex64> = (0..12)
            .map(|i| Complex64::new(i as f64, (i * i) as f64 * 0.1))
            .collect();
        let y = dft(&x, Sign::Negative);
        let z = dft(&y, Sign::Positive);
        for (a, b) in x.iter().zip(z.iter()) {
            assert!((a.scale(12.0) - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn dft_single_tone() {
        // x_j = e^{2πi·3j/16}  →  positive-sign DFT peaks at k = n-3,
        // negative-sign DFT peaks at k = 3.
        let n = 16;
        let x: Vec<Complex64> = (0..n)
            .map(|j| Complex64::cis(std::f64::consts::TAU * 3.0 * j as f64 / n as f64))
            .collect();
        let y = dft(&x, Sign::Negative);
        for (k, v) in y.iter().enumerate() {
            let expect = if k == 3 { n as f64 } else { 0.0 };
            assert!(
                (v.abs() - expect).abs() < 1e-9,
                "bin {k}: {} vs {expect}",
                v.abs()
            );
        }
    }

    #[test]
    fn dft2_matches_row_col_composition() {
        let rows = 4;
        let cols = 6;
        let x: Vec<Complex64> = (0..rows * cols)
            .map(|i| Complex64::new((i % 5) as f64 - 2.0, (i % 3) as f64))
            .collect();
        // Row-column decomposition using the 1-D oracle.
        let mut tmp = x.clone();
        for r in 0..rows {
            let row = dft(&tmp[r * cols..(r + 1) * cols], Sign::Negative);
            tmp[r * cols..(r + 1) * cols].copy_from_slice(&row);
        }
        let mut cols_out = tmp.clone();
        for c in 0..cols {
            let col: Vec<Complex64> = (0..rows).map(|r| tmp[r * cols + c]).collect();
            let colf = dft(&col, Sign::Negative);
            for r in 0..rows {
                cols_out[r * cols + c] = colf[r];
            }
        }
        let direct = dft2(&x, rows, cols, Sign::Negative);
        for (a, b) in cols_out.iter().zip(direct.iter()) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }
}

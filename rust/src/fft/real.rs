//! Real-input (conjugate-even) fast transforms.
//!
//! Real SO(3) samples waste half of a complex FFT: the spectrum of a real
//! signal is Hermitian (`X[n-k] = conj(X[k])`), so half the butterfly
//! work and half the memory traffic recompute values already known. Two
//! exploits live here:
//!
//! * [`RealFftPlan`] — a 1-D real-input transform of even size `n` built
//!   on a half-size complex plan: pack even/odd samples as one complex
//!   signal of length `n/2`, transform, and untangle. Forward
//!   (`real → full complex spectrum`) and inverse (`conjugate-even
//!   spectrum → real`) directions, both unnormalized like the rest of
//!   the substrate.
//! * [`RealFft2`] — the 2-D β-slice transform for real slices, used by
//!   the executor's opt-in `real_input` analysis mode. The row pass packs
//!   *pairs of adjacent real rows* into one complex FFT each (half the
//!   row transforms); the column pass only transforms columns
//!   `0..=n/2` (the rest follow from Hermitian symmetry of the real
//!   slice: `S[v][n-u] = conj(S[(n-v) mod n][u])`) and is filled in by a
//!   copy-only mirror sweep. Net: the FFT stage does ~half the butterfly
//!   work of the complex path.
//!
//! Both untangling identities are sign-agnostic, so [`Sign`] keeps its
//! usual meaning. Outputs agree with the complex kernels to rounding
//! error (`tests/fft_parity.rs` pins this at ≤ 1e-12 for the paper's
//! grid sizes).

use std::sync::Arc;

use super::fft2::{ColumnPass, Fft2};
use super::plan::FftPlan;
use super::{Complex64, Sign};

/// A prepared 1-D real-input transform of fixed even size `n`.
#[derive(Debug, Clone)]
pub struct RealFftPlan {
    n: usize,
    /// Complex plan of size `n/2` for the packed even/odd signal.
    half: Arc<FftPlan>,
    /// `ω^k = e^{-2πi k/n}` for k = 0..n/2 (negative-sign convention;
    /// conjugated on the fly for the positive sign).
    twiddles_neg: Vec<Complex64>,
}

impl RealFftPlan {
    /// Build a plan. `n` must be even and ≥ 2 (the SO(3) grid edge `2B`
    /// always is); odd sizes have no half-length packing and callers
    /// should use the complex [`FftPlan`] directly.
    pub fn new(n: usize) -> Self {
        assert!(n >= 2 && n % 2 == 0, "real FFT requires even n >= 2");
        let base = -std::f64::consts::TAU / n as f64;
        Self {
            n,
            half: Arc::new(FftPlan::new(n / 2)),
            twiddles_neg: (0..n / 2)
                .map(|k| Complex64::cis(base * k as f64))
                .collect(),
        }
    }

    /// Transform size n.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the transform size is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Scratch length required by [`Self::forward`] / [`Self::inverse`].
    #[inline]
    pub fn scratch_len(&self) -> usize {
        self.n / 2
    }

    /// Unnormalized DFT of a real signal:
    /// `out[k] = Σ_j input[j] e^{sign·2πi jk/n}` for k = 0..n.
    /// The full (Hermitian) spectrum is materialized so downstream
    /// consumers are layout-compatible with the complex path.
    pub fn forward(
        &self,
        input: &[f64],
        out: &mut [Complex64],
        scratch: &mut [Complex64],
        sign: Sign,
    ) {
        let n = self.n;
        let half = n / 2;
        assert_eq!(input.len(), n, "real forward: input length");
        assert_eq!(out.len(), n, "real forward: output length");
        assert!(scratch.len() >= half, "real forward: scratch length");
        let z = &mut scratch[..half];
        for (k, zk) in z.iter_mut().enumerate() {
            *zk = Complex64::new(input[2 * k], input[2 * k + 1]);
        }
        self.half.process(z, sign);
        // Untangle E (even-sample DFT) and O (odd-sample DFT) from the
        // packed transform, then combine: X[k] = E[k] + ω^k O[k],
        // X[k+n/2] = E[k] - ω^k O[k].
        for k in 0..half {
            let zk = z[k];
            let zc = z[(half - k) % half].conj();
            let e = (zk + zc).scale(0.5);
            let o = (zk - zc).scale(0.5).mul_neg_i();
            let w = if matches!(sign, Sign::Positive) {
                self.twiddles_neg[k].conj()
            } else {
                self.twiddles_neg[k]
            };
            let t = w * o;
            out[k] = e + t;
            out[k + half] = e - t;
        }
    }

    /// Unnormalized DFT of a conjugate-even spectrum back to real samples:
    /// `out[j] = Re(Σ_k spec[k] e^{sign·2πi jk/n})`. When `spec` is
    /// exactly conjugate-even this equals the complex transform; any
    /// non-Hermitian component (necessarily imaginary in the output) is
    /// discarded.
    pub fn inverse(
        &self,
        spec: &[Complex64],
        out: &mut [f64],
        scratch: &mut [Complex64],
        sign: Sign,
    ) {
        let n = self.n;
        let half = n / 2;
        assert_eq!(spec.len(), n, "real inverse: spectrum length");
        assert_eq!(out.len(), n, "real inverse: output length");
        assert!(scratch.len() >= half, "real inverse: scratch length");
        // Fold the spectrum onto the even/odd interpolants:
        // E'[k] = X[k] + X[k+n/2] (→ even samples),
        // O'[k] = (X[k] - X[k+n/2]) ω^k (→ odd samples), ω = e^{sign·2πi/n},
        // then one packed half-size transform recovers both at once.
        let z = &mut scratch[..half];
        for (k, zk) in z.iter_mut().enumerate() {
            let a = spec[k];
            let b = spec[k + half];
            let w = if matches!(sign, Sign::Positive) {
                self.twiddles_neg[k].conj()
            } else {
                self.twiddles_neg[k]
            };
            let e = a + b;
            let o = (a - b) * w;
            *zk = e + o.mul_i();
        }
        self.half.process(z, sign);
        for k in 0..half {
            out[2 * k] = z[k].re;
            out[2 * k + 1] = z[k].im;
        }
    }
}

/// 2-D transform of one real β-slice (row-major `n × n`, stored as
/// [`Complex64`] with zero imaginary parts — the executor's staging
/// layout). Produces the identical full complex spectrum as
/// [`Fft2::process`] at ~half the butterfly work. Wraps an [`Fft2`] so
/// the plan (twiddles) and the column-pass machinery are shared, not
/// duplicated.
#[derive(Debug, Clone)]
pub struct RealFft2 {
    fft2: Fft2,
}

impl RealFft2 {
    /// Real 2-D wrapper over a complex row plan of size `n`.
    pub fn new(n: usize, plan: Arc<FftPlan>) -> Self {
        Self::from_fft2(&Fft2::new(n, plan))
    }

    /// Build the real companion of an existing [`Fft2`], sharing its plan
    /// (twiddle tables) and column-pass mode.
    pub fn from_fft2(fft2: &Fft2) -> Self {
        assert!(
            fft2.len() >= 2 && fft2.len() % 2 == 0,
            "real 2-D FFT requires even n >= 2"
        );
        Self { fft2: fft2.clone() }
    }

    /// Edge length n.
    #[inline]
    pub fn len(&self) -> usize {
        self.fft2.len()
    }

    /// Whether the edge length is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.fft2.is_empty()
    }

    /// Which column-pass strategy this transform uses.
    #[inline]
    pub fn column_pass(&self) -> ColumnPass {
        self.fft2.column_pass()
    }

    /// Scratch length required by [`Self::forward`]: `n` for the packed
    /// row pass, plus the gather/scatter column buffers when the plan has
    /// no strided panel kernel.
    #[inline]
    pub fn scratch_len(&self) -> usize {
        self.fft2.scratch_len().max(self.fft2.len())
    }

    /// In-place unnormalized 2-D transform of a *real* row-major `n × n`
    /// slice (imaginary parts are ignored and assumed zero — the executor
    /// validates this before dispatch). The output is the full complex
    /// spectrum, bit-compatible in layout with [`Fft2::process`].
    pub fn forward(&self, slice: &mut [Complex64], scratch: &mut [Complex64], sign: Sign) {
        let n = self.fft2.len();
        let plan = self.fft2.plan();
        assert_eq!(slice.len(), n * n, "slice must be n*n");
        assert!(
            scratch.len() >= self.scratch_len(),
            "scratch must be scratch_len()"
        );
        // Row pass: two real rows per complex FFT. With z = a + ib,
        // A[j] = (Z[j] + conj(Z[n-j]))/2 and B[j] = -i(Z[j] - conj(Z[n-j]))/2
        // recover both row spectra from one transform (sign-agnostic).
        // Only columns 0..=n/2 are untangled: the column pass reads
        // nothing beyond them, and the mirror sweep rebuilds the rest of
        // the final spectrum from Hermitian symmetry.
        let pack = &mut scratch[..n];
        for rows in slice.chunks_exact_mut(2 * n) {
            let (row_a, row_b) = rows.split_at_mut(n);
            for j in 0..n {
                pack[j] = Complex64::new(row_a[j].re, row_b[j].re);
            }
            plan.process(pack, sign);
            for j in 0..=n / 2 {
                let zj = pack[j];
                let zc = pack[(n - j) % n].conj();
                row_a[j] = (zj + zc).scale(0.5);
                row_b[j] = (zj - zc).scale(0.5).mul_neg_i();
            }
        }
        // Column pass over u = 0..=n/2 only; the mirror sweep below fills
        // the rest from Hermitian symmetry.
        let last = n / 2; // inclusive
        self.fft2.column_pass_range(slice, last + 1, scratch, sign);
        // Mirror: S[v][n-u] = conj(S[(n-v) mod n][u]) — pure copies, no
        // butterflies. The dst row and src row may alias (v = 0 or
        // v = n/2) but reads come from columns <= n/2 and writes go to
        // columns > n/2, so the index ranges are disjoint.
        for v in 0..n {
            let dst = v * n;
            let src = ((n - v) % n) * n;
            for u in last + 1..n {
                let val = slice[src + (n - u)].conj();
                slice[dst + u] = val;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::{dft, dft2};
    use crate::prng::Xoshiro256;

    fn random_real(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n).map(|_| rng.next_signed()).collect()
    }

    #[test]
    fn forward_matches_oracle_even_sizes() {
        for &n in &[2usize, 4, 6, 8, 10, 16, 32, 96, 256] {
            let plan = RealFftPlan::new(n);
            let x = random_real(n, 5 + n as u64);
            let xc: Vec<Complex64> = x.iter().map(|&r| Complex64::new(r, 0.0)).collect();
            for sign in [Sign::Negative, Sign::Positive] {
                let want = dft(&xc, sign);
                let mut got = vec![Complex64::zero(); n];
                let mut scratch = vec![Complex64::zero(); plan.scratch_len()];
                plan.forward(&x, &mut got, &mut scratch, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!((*a - *b).abs() < 1e-9 * n as f64, "n={n} sign={sign:?}");
                }
            }
        }
    }

    #[test]
    fn inverse_roundtrip_scales_by_n() {
        for &n in &[4usize, 12, 64, 128] {
            let plan = RealFftPlan::new(n);
            let x = random_real(n, 23);
            let xc: Vec<Complex64> = x.iter().map(|&r| Complex64::new(r, 0.0)).collect();
            let spec = dft(&xc, Sign::Negative);
            let mut back = vec![0.0f64; n];
            let mut scratch = vec![Complex64::zero(); plan.scratch_len()];
            plan.inverse(&spec, &mut back, &mut scratch, Sign::Positive);
            for (a, b) in x.iter().zip(back.iter()) {
                assert!((a * n as f64 - b).abs() < 1e-9 * n as f64);
            }
        }
    }

    #[test]
    fn fft2_matches_complex_oracle_on_real_slices() {
        for &n in &[2usize, 4, 8, 16] {
            let rfft2 = RealFft2::new(n, Arc::new(FftPlan::new(n)));
            let x = random_real(n * n, 7 + n as u64);
            let xc: Vec<Complex64> = x.iter().map(|&r| Complex64::new(r, 0.0)).collect();
            for sign in [Sign::Negative, Sign::Positive] {
                let want = dft2(&xc, n, n, sign);
                let mut got = xc.clone();
                let mut scratch = vec![Complex64::zero(); rfft2.scratch_len()];
                rfft2.forward(&mut got, &mut scratch, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!(
                        (*a - *b).abs() < 1e-8 * (n * n) as f64,
                        "n={n} sign={sign:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn fft2_gather_mode_matches_panel_mode() {
        let n = 8;
        let plan = Arc::new(FftPlan::new(n));
        let panel = RealFft2::new(n, plan.clone());
        assert_eq!(panel.column_pass(), ColumnPass::Panel);
        let gather = RealFft2::from_fft2(&Fft2::with_column_pass(
            n,
            plan,
            ColumnPass::GatherScatter,
        ));
        assert_eq!(gather.column_pass(), ColumnPass::GatherScatter);
        let x = random_real(n * n, 99);
        let xc: Vec<Complex64> = x.iter().map(|&r| Complex64::new(r, 0.0)).collect();
        let mut a = xc.clone();
        let mut b = xc;
        let mut sa = vec![Complex64::zero(); panel.scratch_len()];
        let mut sb = vec![Complex64::zero(); gather.scratch_len()];
        panel.forward(&mut a, &mut sa, Sign::Positive);
        gather.forward(&mut b, &mut sb, Sign::Positive);
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((*u - *v).abs() < 1e-12 * n as f64);
        }
    }

    #[test]
    #[should_panic(expected = "even")]
    fn rejects_odd_sizes() {
        let _ = RealFftPlan::new(9);
    }
}

//! Bluestein (chirp-z) FFT for arbitrary sizes.
//!
//! The FSOFT grid size is `2B`; for the paper's bandwidths this is a power
//! of two, but the library accepts any `B ≥ 1`, so non-power-of-two sizes
//! are routed here. The n-point DFT is re-expressed as a circular
//! convolution of length `M = next_pow2(2n-1)` evaluated with the
//! radix-4 (split-radix-family) kernel.

use super::split_radix::Radix4Plan;
use super::{Complex64, Sign};

/// Precomputed state for an arbitrary-size Bluestein transform.
#[derive(Debug, Clone)]
pub struct BluesteinPlan {
    n: usize,
    m: usize,
    inner: Radix4Plan,
    /// Chirp a_j = e^{-iπ j² / n} (negative-sign convention).
    chirp_neg: Vec<Complex64>,
    /// FFT of the zero-padded conjugate chirp (negative-sign convention),
    /// i.e. the convolution kernel spectrum.
    kernel_neg: Vec<Complex64>,
}

impl BluesteinPlan {
    /// Plan an arbitrary-size transform of length `n`.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let inner = Radix4Plan::new(m);
        // j² mod 2n keeps the chirp angle bounded for accuracy.
        let base = -std::f64::consts::PI / n as f64;
        let chirp_neg: Vec<Complex64> = (0..n)
            .map(|j| {
                let sq = (j * j) % (2 * n);
                Complex64::cis(base * sq as f64)
            })
            .collect();
        // Kernel b_j = conj(chirp_j) laid out circularly: b[0..n] and the
        // mirrored tail b[m-j] for j = 1..n.
        let mut kernel = vec![Complex64::zero(); m];
        for j in 0..n {
            let v = chirp_neg[j].conj();
            kernel[j] = v;
            if j > 0 {
                kernel[m - j] = v;
            }
        }
        inner.process(&mut kernel, Sign::Negative);
        Self {
            n,
            m,
            inner,
            chirp_neg,
            kernel_neg: kernel,
        }
    }

    /// Transform size n.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the transform size is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Out-of-place-capable transform; `data` is transformed in place.
    pub fn process(&self, data: &mut [Complex64], sign: Sign) {
        assert_eq!(data.len(), self.n);
        let n = self.n;
        let m = self.m;
        if n == 1 {
            return;
        }
        // For the positive sign: DFT_+(x) = conj(DFT_-(conj(x))).
        if matches!(sign, Sign::Positive) {
            for v in data.iter_mut() {
                *v = v.conj();
            }
            self.process(data, Sign::Negative);
            for v in data.iter_mut() {
                *v = v.conj();
            }
            return;
        }
        // y_j = x_j · a_j, zero-padded to m.
        let mut work = vec![Complex64::zero(); m];
        for j in 0..n {
            work[j] = data[j] * self.chirp_neg[j];
        }
        self.inner.process(&mut work, Sign::Negative);
        for (w, k) in work.iter_mut().zip(self.kernel_neg.iter()) {
            *w = *w * *k;
        }
        self.inner.process(&mut work, Sign::Positive);
        let scale = 1.0 / m as f64;
        for k in 0..n {
            data[k] = work[k].scale(scale) * self.chirp_neg[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft;
    use crate::prng::Xoshiro256;

    fn random_signal(n: usize, seed: u64) -> Vec<Complex64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect()
    }

    #[test]
    fn matches_oracle_awkward_sizes() {
        for &n in &[1usize, 2, 3, 5, 6, 7, 9, 12, 15, 17, 31, 33, 50, 97, 120] {
            let plan = BluesteinPlan::new(n);
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_signal(n, n as u64);
                let want = dft(&x, sign);
                let mut got = x.clone();
                plan.process(&mut got, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!(
                        (*a - *b).abs() < 1e-8 * (1.0 + n as f64),
                        "n={n} sign={sign:?}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn agrees_with_radix2_on_pow2() {
        use crate::fft::radix2::Radix2Plan;
        let n = 64;
        let bs = BluesteinPlan::new(n);
        let r2 = Radix2Plan::new(n);
        let x = random_signal(n, 5);
        let mut a = x.clone();
        let mut b = x;
        bs.process(&mut a, Sign::Negative);
        r2.process(&mut b, Sign::Negative);
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((*u - *v).abs() < 1e-9);
        }
    }

    #[test]
    fn roundtrip_scales_by_n() {
        let n = 45;
        let plan = BluesteinPlan::new(n);
        let x = random_signal(n, 9);
        let mut y = x.clone();
        plan.process(&mut y, Sign::Negative);
        plan.process(&mut y, Sign::Positive);
        for (a, b) in x.iter().zip(y.iter()) {
            assert!((a.scale(n as f64) - *b).abs() < 1e-8 * n as f64);
        }
    }
}

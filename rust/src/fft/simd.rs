//! Arch-specific butterfly stages for the radix-4 FFT.
//!
//! These are whole-stage twins of [`super::split_radix::Radix4Plan`]'s
//! scalar `stages` / `stages_panel` (same decomposition: optional
//! radix-2 head, then radix-4 DIT passes over `[E0, E2, E1, E3]`
//! sub-blocks). They are separate top-level `#[target_feature]`
//! functions — not per-butterfly helpers — so the feature boundary is
//! crossed once per transform, not once per butterfly.
//!
//! Sign handling: twiddles are stored for the negative transform; the
//! positive (conjugate) transform is obtained by flipping the sign of
//! the twiddle imaginary parts and of the ±i rotation. On AVX2 both
//! flips are a single XOR mask computed once per call (`conj` is a
//! plain runtime bool — the branches it guards are loop-invariant).
//!
//! Accuracy: the AVX2 path fuses the complex multiplies with
//! `fmaddsub` (the scalar path rounds the products first), so results
//! differ from scalar by ≤ a few ulp per butterfly — well inside the
//! 1e-12 parity budget pinned by `tests/simd_parity.rs`.

// `unsafe_op_in_unsafe_fn` straddle: on the 1.75 MSRV every intrinsic
// call is an unsafe op, so the bodies below carry explicit `unsafe {}`
// blocks; on newer toolchains (target_feature 1.1) intrinsic calls
// inside a matching `#[target_feature]` fn are safe and those same
// blocks would trip `unused_unsafe` under `-D warnings`. Allow the
// lint so both toolchains stay warning-clean.
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)]
pub(crate) mod avx2 {
    use crate::fft::complex::Complex64;
    use std::arch::x86_64::*;

    /// Complex multiply of the two packed complexes in `z` by the
    /// twiddle whose real parts are duplicated in `wr` and (pre-signed)
    /// imaginary parts in `wi`: even lane `wr·re − wi·im`, odd lane
    /// `wr·im + wi·re`. Conjugation is folded into the sign of `wi`.
    ///
    /// # Safety
    /// Requires AVX2+FMA.
    #[inline(always)]
    unsafe fn cmul(z: __m256d, wr: __m256d, wi: __m256d) -> __m256d {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let swap = _mm256_permute_pd(z, 0b0101);
            _mm256_fmaddsub_pd(wr, z, _mm256_mul_pd(wi, swap))
        }
    }

    /// Load the twiddle pair `(tw[i], tw[i + 3])` (the packed table is
    /// stride-3 triples) into `(re-dup, im-dup ⊕ conj_mask)` form.
    ///
    /// # Safety
    /// Requires AVX2; `tw` must be readable at `i` and `i + 3`.
    #[inline(always)]
    unsafe fn twiddle_pair(tw: &[Complex64], i: usize, conj_mask: __m256d) -> (__m256d, __m256d) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let lo = _mm_loadu_pd(tw.as_ptr().add(i) as *const f64);
            let hi = _mm_loadu_pd(tw.as_ptr().add(i + 3) as *const f64);
            let w = _mm256_set_m128d(hi, lo);
            let wr = _mm256_movedup_pd(w);
            let wi = _mm256_xor_pd(_mm256_permute_pd(w, 0b1111), conj_mask);
            (wr, wi)
        }
    }

    /// # Safety
    /// Requires AVX2 (vector constant materialization only).
    #[inline(always)]
    unsafe fn masks(conj: bool) -> (__m256d, __m256d) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            // conj_mask flips the twiddle imaginary sign; rot_mask turns
            // the pair-swapped odd difference into ·(−i) (negative sign)
            // or ·(+i) (conjugate/positive sign).
            if conj {
                (_mm256_set1_pd(-0.0), _mm256_setr_pd(-0.0, 0.0, -0.0, 0.0))
            } else {
                (_mm256_setzero_pd(), _mm256_setr_pd(0.0, -0.0, 0.0, -0.0))
            }
        }
    }

    /// Radix-4 butterfly stages over a contiguous, already
    /// bit-reversed signal — the vector twin of `Radix4Plan::stages`.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA support and that `twiddles_neg`
    /// is the packed stage table built by `Radix4Plan::new` for
    /// `n = data.len()` (a power of two).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn stages(data: &mut [Complex64], twiddles_neg: &[Complex64], conj: bool) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let n = data.len();
            let ptr = data.as_mut_ptr() as *mut f64;
            let (conj_mask, rot_mask) = masks(conj);
            let mut h = 1usize;
            if n.trailing_zeros() % 2 == 1 {
                // Radix-2 head (twiddle-free): one 2-complex vector per pair.
                let mut g = 0;
                while g < n {
                    let v = _mm256_loadu_pd(ptr.add(2 * g)); // [a, b]
                    let sw = _mm256_permute2f128_pd(v, v, 0x01); // [b, a]
                    let sum = _mm256_add_pd(v, sw); // [a+b, b+a]
                    let diff = _mm256_sub_pd(v, sw); // [a−b, b−a]
                    _mm256_storeu_pd(ptr.add(2 * g), _mm256_blend_pd(sum, diff, 0b1100));
                    g += 2;
                }
                h = 2;
            }
            let mut toff = 0usize;
            // lint: hot-loop-begin
            while h < n {
                let step = 4 * h;
                let tw = &twiddles_neg[toff..toff + 3 * h];
                if h == 1 {
                    // Quarter-size 1: unit twiddles, blocks of 4 complexes
                    // [E0, E2, E1, E3]. Two vectors per block.
                    let mut g = 0;
                    while g < n {
                        let v0 = _mm256_loadu_pd(ptr.add(2 * g)); // [a, c]
                        let v1 = _mm256_loadu_pd(ptr.add(2 * g + 4)); // [b, d]
                        let sw0 = _mm256_permute2f128_pd(v0, v0, 0x01);
                        let sw1 = _mm256_permute2f128_pd(v1, v1, 0x01);
                        let t01 = _mm256_blend_pd(
                            _mm256_add_pd(v0, sw0),
                            _mm256_sub_pd(v0, sw0),
                            0b1100,
                        ); // [t0, t1]
                        let t23 = _mm256_blend_pd(
                            _mm256_add_pd(v1, sw1),
                            _mm256_sub_pd(v1, sw1),
                            0b1100,
                        ); // [t2, t3]
                        let rot = _mm256_xor_pd(_mm256_permute_pd(t23, 0b0101), rot_mask);
                        let mixed = _mm256_blend_pd(t23, rot, 0b1100); // [t2, rot]
                        _mm256_storeu_pd(ptr.add(2 * g), _mm256_add_pd(t01, mixed));
                        _mm256_storeu_pd(ptr.add(2 * g + 4), _mm256_sub_pd(t01, mixed));
                        g += 4;
                    }
                } else {
                    // h is even from here on: two butterflies per vector.
                    let mut g = 0;
                    while g < n {
                        let off0 = 2 * g;
                        let off2 = off0 + 2 * h;
                        let off1 = off0 + 4 * h;
                        let off3 = off0 + 6 * h;
                        let mut k = 0;
                        while k < h {
                            let (w1r, w1i) = twiddle_pair(tw, 3 * k, conj_mask);
                            let (w2r, w2i) = twiddle_pair(tw, 3 * k + 1, conj_mask);
                            let (w3r, w3i) = twiddle_pair(tw, 3 * k + 2, conj_mask);
                            let a = _mm256_loadu_pd(ptr.add(off0 + 2 * k));
                            let c = cmul(_mm256_loadu_pd(ptr.add(off2 + 2 * k)), w2r, w2i);
                            let b = cmul(_mm256_loadu_pd(ptr.add(off1 + 2 * k)), w1r, w1i);
                            let d = cmul(_mm256_loadu_pd(ptr.add(off3 + 2 * k)), w3r, w3i);
                            let t0 = _mm256_add_pd(a, c);
                            let t1 = _mm256_sub_pd(a, c);
                            let t2 = _mm256_add_pd(b, d);
                            let t3 = _mm256_sub_pd(b, d);
                            let rot = _mm256_xor_pd(_mm256_permute_pd(t3, 0b0101), rot_mask);
                            _mm256_storeu_pd(ptr.add(off0 + 2 * k), _mm256_add_pd(t0, t2));
                            _mm256_storeu_pd(ptr.add(off2 + 2 * k), _mm256_add_pd(t1, rot));
                            _mm256_storeu_pd(ptr.add(off1 + 2 * k), _mm256_sub_pd(t0, t2));
                            _mm256_storeu_pd(ptr.add(off3 + 2 * k), _mm256_sub_pd(t1, rot));
                            k += 2;
                        }
                        g += step;
                    }
                }
                toff += 3 * h;
                h = step;
            }
            // lint: hot-loop-end
        }
    }

    /// Four-column panel butterfly stages — the vector twin of
    /// `Radix4Plan::stages_panel` for `cols == 4`: each strided row of
    /// the panel is 4 consecutive complexes = two 2-complex vectors,
    /// and the twiddle is broadcast across the row.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA support, `cols == 4` panel layout
    /// (`data[r * stride + c]`, `data.len() >= (n−1)·stride + 4`), and
    /// that `twiddles_neg` is the packed table for size `n`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn stages_panel4(
        data: &mut [Complex64],
        n: usize,
        stride: usize,
        twiddles_neg: &[Complex64],
        conj: bool,
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let ptr = data.as_mut_ptr() as *mut f64;
            let (conj_mask, rot_mask) = masks(conj);
            let mut h = 1usize;
            if n.trailing_zeros() % 2 == 1 {
                let mut g = 0;
                while g < n {
                    let r0 = 2 * g * stride;
                    let r1 = r0 + 2 * stride;
                    for half in 0..2 {
                        let o = 4 * half;
                        let a = _mm256_loadu_pd(ptr.add(r0 + o));
                        let b = _mm256_loadu_pd(ptr.add(r1 + o));
                        _mm256_storeu_pd(ptr.add(r0 + o), _mm256_add_pd(a, b));
                        _mm256_storeu_pd(ptr.add(r1 + o), _mm256_sub_pd(a, b));
                    }
                    g += 2;
                }
                h = 2;
            }
            let mut toff = 0usize;
            while h < n {
                let step = 4 * h;
                let tw = &twiddles_neg[toff..toff + 3 * h];
                let mut g = 0;
                while g < n {
                    for k in 0..h {
                        let w1 = tw[3 * k];
                        let w2 = tw[3 * k + 1];
                        let w3 = tw[3 * k + 2];
                        let w1r = _mm256_set1_pd(w1.re);
                        let w1i = _mm256_xor_pd(_mm256_set1_pd(w1.im), conj_mask);
                        let w2r = _mm256_set1_pd(w2.re);
                        let w2i = _mm256_xor_pd(_mm256_set1_pd(w2.im), conj_mask);
                        let w3r = _mm256_set1_pd(w3.re);
                        let w3i = _mm256_xor_pd(_mm256_set1_pd(w3.im), conj_mask);
                        let i0 = 2 * (g + k) * stride;
                        let i2 = 2 * (g + h + k) * stride;
                        let i1 = 2 * (g + 2 * h + k) * stride;
                        let i3 = 2 * (g + 3 * h + k) * stride;
                        for half in 0..2 {
                            let o = 4 * half;
                            let a = _mm256_loadu_pd(ptr.add(i0 + o));
                            let c = cmul(_mm256_loadu_pd(ptr.add(i2 + o)), w2r, w2i);
                            let b = cmul(_mm256_loadu_pd(ptr.add(i1 + o)), w1r, w1i);
                            let d = cmul(_mm256_loadu_pd(ptr.add(i3 + o)), w3r, w3i);
                            let t0 = _mm256_add_pd(a, c);
                            let t1 = _mm256_sub_pd(a, c);
                            let t2 = _mm256_add_pd(b, d);
                            let t3 = _mm256_sub_pd(b, d);
                            let rot = _mm256_xor_pd(_mm256_permute_pd(t3, 0b0101), rot_mask);
                            _mm256_storeu_pd(ptr.add(i0 + o), _mm256_add_pd(t0, t2));
                            _mm256_storeu_pd(ptr.add(i2 + o), _mm256_add_pd(t1, rot));
                            _mm256_storeu_pd(ptr.add(i1 + o), _mm256_sub_pd(t0, t2));
                            _mm256_storeu_pd(ptr.add(i3 + o), _mm256_sub_pd(t1, rot));
                        }
                    }
                    g += step;
                }
                toff += 3 * h;
                h = step;
            }
        }
    }
}

// `unsafe_op_in_unsafe_fn` straddle: on the 1.75 MSRV every intrinsic
// call is an unsafe op, so the bodies below carry explicit `unsafe {}`
// blocks; on newer toolchains (target_feature 1.1) intrinsic calls
// inside a matching `#[target_feature]` fn are safe and those same
// blocks would trip `unused_unsafe` under `-D warnings`. Allow the
// lint so both toolchains stay warning-clean.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
pub(crate) mod neon {
    use crate::fft::complex::Complex64;
    use std::arch::aarch64::*;

    /// Complex multiply by a twiddle whose real part is duplicated in
    /// `wr` and whose (pre-signed) imaginary parts are `wi = [−im, im]`
    /// (negative sign) or `[im, −im]` (conjugate).
    ///
    /// # Safety
    /// NEON is baseline on aarch64.
    #[inline(always)]
    unsafe fn cmul(z: float64x2_t, wr: float64x2_t, wi: float64x2_t) -> float64x2_t {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let swap = vextq_f64::<1>(z, z);
            vfmaq_f64(vmulq_f64(wr, z), wi, swap)
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    #[inline(always)]
    unsafe fn twiddle(w: Complex64, conj: bool) -> (float64x2_t, float64x2_t) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let s = if conj { 1.0 } else { -1.0 };
            let wi = [s * w.im, -s * w.im];
            (vdupq_n_f64(w.re), vld1q_f64(wi.as_ptr()))
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64.
    #[inline(always)]
    unsafe fn rotate(t3: float64x2_t, conj: bool) -> float64x2_t {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            if conj {
                // ·(+i): [−im, re]
                vextq_f64::<1>(vnegq_f64(t3), t3)
            } else {
                // ·(−i): [im, −re]
                vextq_f64::<1>(t3, vnegq_f64(t3))
            }
        }
    }

    /// Radix-4 butterfly stages over a contiguous, already
    /// bit-reversed signal — NEON twin of `Radix4Plan::stages`.
    ///
    /// # Safety
    /// `twiddles_neg` must be the packed stage table for
    /// `n = data.len()` (a power of two).
    #[target_feature(enable = "neon")]
    pub unsafe fn stages(data: &mut [Complex64], twiddles_neg: &[Complex64], conj: bool) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let n = data.len();
            let ptr = data.as_mut_ptr() as *mut f64;
            let mut h = 1usize;
            if n.trailing_zeros() % 2 == 1 {
                let mut g = 0;
                while g < n {
                    let a = vld1q_f64(ptr.add(2 * g));
                    let b = vld1q_f64(ptr.add(2 * g + 2));
                    vst1q_f64(ptr.add(2 * g), vaddq_f64(a, b));
                    vst1q_f64(ptr.add(2 * g + 2), vsubq_f64(a, b));
                    g += 2;
                }
                h = 2;
            }
            let mut toff = 0usize;
            while h < n {
                let step = 4 * h;
                let tw = &twiddles_neg[toff..toff + 3 * h];
                let mut g = 0;
                while g < n {
                    let base = 2 * g;
                    for k in 0..h {
                        let (w1r, w1i) = twiddle(tw[3 * k], conj);
                        let (w2r, w2i) = twiddle(tw[3 * k + 1], conj);
                        let (w3r, w3i) = twiddle(tw[3 * k + 2], conj);
                        let i0 = base + 2 * k;
                        let i2 = base + 2 * (h + k);
                        let i1 = base + 2 * (2 * h + k);
                        let i3 = base + 2 * (3 * h + k);
                        let a = vld1q_f64(ptr.add(i0));
                        let c = cmul(vld1q_f64(ptr.add(i2)), w2r, w2i);
                        let b = cmul(vld1q_f64(ptr.add(i1)), w1r, w1i);
                        let d = cmul(vld1q_f64(ptr.add(i3)), w3r, w3i);
                        let t0 = vaddq_f64(a, c);
                        let t1 = vsubq_f64(a, c);
                        let t2 = vaddq_f64(b, d);
                        let t3 = vsubq_f64(b, d);
                        let rot = rotate(t3, conj);
                        vst1q_f64(ptr.add(i0), vaddq_f64(t0, t2));
                        vst1q_f64(ptr.add(i2), vaddq_f64(t1, rot));
                        vst1q_f64(ptr.add(i1), vsubq_f64(t0, t2));
                        vst1q_f64(ptr.add(i3), vsubq_f64(t1, rot));
                    }
                    g += step;
                }
                toff += 3 * h;
                h = step;
            }
        }
    }

    /// Strided-panel butterfly stages — NEON twin of
    /// `Radix4Plan::stages_panel` for any `cols`.
    ///
    /// # Safety
    /// Panel layout contract of `Radix4Plan::process_panel`
    /// (`data.len() >= (n−1)·stride + cols`, `1 <= cols <= stride`);
    /// `twiddles_neg` must be the packed table for size `n`.
    #[target_feature(enable = "neon")]
    pub unsafe fn stages_panel(
        data: &mut [Complex64],
        n: usize,
        stride: usize,
        cols: usize,
        twiddles_neg: &[Complex64],
        conj: bool,
    ) {
        // SAFETY: caller upholds this fn's `# Safety` contract
        // (ISA support and slice bounds); all unsafe ops below are
        // the intrinsics/raw loads that contract licenses.
        unsafe {
            let ptr = data.as_mut_ptr() as *mut f64;
            let mut h = 1usize;
            if n.trailing_zeros() % 2 == 1 {
                let mut g = 0;
                while g < n {
                    let r0 = 2 * g * stride;
                    let r1 = r0 + 2 * stride;
                    for c in 0..cols {
                        let a = vld1q_f64(ptr.add(r0 + 2 * c));
                        let b = vld1q_f64(ptr.add(r1 + 2 * c));
                        vst1q_f64(ptr.add(r0 + 2 * c), vaddq_f64(a, b));
                        vst1q_f64(ptr.add(r1 + 2 * c), vsubq_f64(a, b));
                    }
                    g += 2;
                }
                h = 2;
            }
            let mut toff = 0usize;
            while h < n {
                let step = 4 * h;
                let tw = &twiddles_neg[toff..toff + 3 * h];
                let mut g = 0;
                while g < n {
                    for k in 0..h {
                        let (w1r, w1i) = twiddle(tw[3 * k], conj);
                        let (w2r, w2i) = twiddle(tw[3 * k + 1], conj);
                        let (w3r, w3i) = twiddle(tw[3 * k + 2], conj);
                        let i0 = 2 * (g + k) * stride;
                        let i2 = 2 * (g + h + k) * stride;
                        let i1 = 2 * (g + 2 * h + k) * stride;
                        let i3 = 2 * (g + 3 * h + k) * stride;
                        for c in 0..cols {
                            let o = 2 * c;
                            let a = vld1q_f64(ptr.add(i0 + o));
                            let cc = cmul(vld1q_f64(ptr.add(i2 + o)), w2r, w2i);
                            let b = cmul(vld1q_f64(ptr.add(i1 + o)), w1r, w1i);
                            let d = cmul(vld1q_f64(ptr.add(i3 + o)), w3r, w3i);
                            let t0 = vaddq_f64(a, cc);
                            let t1 = vsubq_f64(a, cc);
                            let t2 = vaddq_f64(b, d);
                            let t3 = vsubq_f64(b, d);
                            let rot = rotate(t3, conj);
                            vst1q_f64(ptr.add(i0 + o), vaddq_f64(t0, t2));
                            vst1q_f64(ptr.add(i2 + o), vaddq_f64(t1, rot));
                            vst1q_f64(ptr.add(i1 + o), vsubq_f64(t0, t2));
                            vst1q_f64(ptr.add(i3 + o), vsubq_f64(t1, rot));
                        }
                    }
                    g += step;
                }
                toff += 3 * h;
                h = step;
            }
        }
    }
}

//! 2-D FFT over one β-slice of the SO(3) grid.
//!
//! The FSOFT's first stage computes, for every β-slice j,
//! `S(m, m'; j) = Σ_{i,k} f(α_i, β_j, γ_k) e^{+i(m α_i + m' γ_k)}`,
//! which is an unnormalized positive-sign 2-D DFT of the slice (the
//! frequencies m, m' ∈ {1-B, …, B-1} live in the DFT bins mod 2B).
//! The iFSOFT's last stage is the negative-sign counterpart.
//!
//! Rows (α-axis) are transformed in place; the column (γ-axis... actually
//! α) pass gathers a column into a stride-1 scratch buffer, transforms it,
//! and scatters back — measurably faster than strided butterflies for the
//! sizes involved (2B ≤ 1024).

use super::plan::FftPlan;
use super::{Complex64, Sign};

/// 2-D transform workspace for an `n × n` slice (row-major `[i][k]`).
#[derive(Debug, Clone)]
pub struct Fft2 {
    n: usize,
    plan: std::sync::Arc<FftPlan>,
}

impl Fft2 {
    pub fn new(n: usize, plan: std::sync::Arc<FftPlan>) -> Self {
        assert_eq!(plan.len(), n, "plan size must match slice edge");
        Self { n, plan }
    }

    /// Build with a private plan (tests / one-off use).
    pub fn with_size(n: usize) -> Self {
        Self::new(n, std::sync::Arc::new(FftPlan::new(n)))
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Scratch length required by [`Self::process`].
    #[inline]
    pub fn scratch_len(&self) -> usize {
        4 * self.n
    }

    /// In-place unnormalized 2-D transform of a row-major `n × n` slice.
    /// `scratch` must have length `4n` (see [`Self::scratch_len`]).
    pub fn process(&self, slice: &mut [Complex64], scratch: &mut [Complex64], sign: Sign) {
        let n = self.n;
        assert_eq!(slice.len(), n * n, "slice must be n*n");
        assert!(scratch.len() >= 4 * n, "scratch must be 4n");
        // Row pass (unit stride).
        for row in slice.chunks_exact_mut(n) {
            self.plan.process(row, sign);
        }
        // Column pass: gather FOUR adjacent columns per sweep — they share
        // cache lines (4 × 16-byte complex = one 64-byte line), so each
        // line of the slice is touched once per sweep instead of four
        // times (§Perf in EXPERIMENTS.md).
        let mut c = 0;
        while c < n {
            let cols = (n - c).min(4);
            for r in 0..n {
                let base = r * n + c;
                for k in 0..cols {
                    scratch[k * n + r] = slice[base + k];
                }
            }
            for k in 0..cols {
                self.plan.process(&mut scratch[k * n..(k + 1) * n], sign);
            }
            for r in 0..n {
                let base = r * n + c;
                for k in 0..cols {
                    slice[base + k] = scratch[k * n + r];
                }
            }
            c += cols;
        }
    }

    /// Convenience wrapper that allocates its own scratch.
    pub fn process_alloc(&self, slice: &mut [Complex64], sign: Sign) {
        let mut scratch = vec![Complex64::zero(); self.scratch_len()];
        self.process(slice, &mut scratch, sign);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft2;
    use crate::prng::Xoshiro256;

    fn random_slice(n: usize, seed: u64) -> Vec<Complex64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n * n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect()
    }

    #[test]
    fn matches_2d_oracle() {
        for &n in &[2usize, 4, 8, 16, 6] {
            let fft2 = Fft2::with_size(n);
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_slice(n, 11 + n as u64);
                let want = dft2(&x, n, n, sign);
                let mut got = x.clone();
                fft2.process_alloc(&mut got, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!((*a - *b).abs() < 1e-8 * n as f64, "n={n} sign={sign:?}");
                }
            }
        }
    }

    #[test]
    fn roundtrip_scales_by_n_squared() {
        let n = 16;
        let fft2 = Fft2::with_size(n);
        let x = random_slice(n, 21);
        let mut y = x.clone();
        fft2.process_alloc(&mut y, Sign::Positive);
        fft2.process_alloc(&mut y, Sign::Negative);
        let scale = (n * n) as f64;
        for (a, b) in x.iter().zip(y.iter()) {
            assert!((a.scale(scale) - *b).abs() < 1e-8 * scale);
        }
    }

    #[test]
    fn separable_tone_lands_in_single_bin() {
        // f(i,k) = e^{+2πi(2i + 5k)/n} under the positive-sign transform
        // concentrates all energy in bin (n-2, n-5)... with positive sign
        // S(u,v) = Σ e^{+2πi(ui+vk)/n} f → peak where u+2 ≡ 0, v+5 ≡ 0.
        let n = 8usize;
        let fft2 = Fft2::with_size(n);
        let tau = std::f64::consts::TAU;
        let mut x = vec![Complex64::zero(); n * n];
        for i in 0..n {
            for k in 0..n {
                x[i * n + k] = Complex64::cis(tau * (2 * i + 5 * k) as f64 / n as f64);
            }
        }
        fft2.process_alloc(&mut x, Sign::Positive);
        for u in 0..n {
            for v in 0..n {
                let mag = x[u * n + v].abs();
                if u == n - 2 && v == n - 5 {
                    assert!((mag - (n * n) as f64).abs() < 1e-8);
                } else {
                    assert!(mag < 1e-8, "leak at ({u},{v}): {mag}");
                }
            }
        }
    }
}

//! 2-D FFT over one β-slice of the SO(3) grid.
//!
//! The FSOFT's first stage computes, for every β-slice j,
//! `S(m, m'; j) = Σ_{i,k} f(α_i, β_j, γ_k) e^{+i(m α_i + m' γ_k)}`,
//! which is an unnormalized positive-sign 2-D DFT of the slice (the
//! frequencies m, m' ∈ {1-B, …, B-1} live in the DFT bins mod 2B).
//! The iFSOFT's last stage is the negative-sign counterpart.
//!
//! Rows (γ-axis, unit stride) are transformed in place. For the column
//! (α-axis) pass there are two strategies:
//!
//! * [`ColumnPass::Panel`] (default for the split-radix kernel) — the
//!   butterflies run *directly* over panels of four adjacent strided
//!   columns via `process_panel`. Four 16-byte complex values are one
//!   64-byte cache line, and an `n`-row panel (≤ 64 KiB for the paper's
//!   sizes) stays cache-resident across all butterfly stages, so every
//!   line of the slice is touched once per transform — no scratch, no
//!   copies.
//! * [`ColumnPass::GatherScatter`] (Bluestein fallback + the measurable
//!   baseline) — gather four columns into stride-1 scratch, transform,
//!   scatter back. Each line of the slice is touched three times per
//!   sweep (gather read, scratch working set, scatter write).

use super::plan::FftPlan;
use super::{Complex64, Sign};

/// Column-pass strategy of a [`Fft2`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnPass {
    /// Copy-free strided panel butterflies (requires the split-radix
    /// kernel).
    Panel,
    /// Gather → stride-1 FFT → scatter through scratch (any kernel).
    GatherScatter,
}

/// 2-D transform workspace for an `n × n` slice (row-major `[i][k]`).
#[derive(Debug, Clone)]
pub struct Fft2 {
    n: usize,
    plan: std::sync::Arc<FftPlan>,
    columns: ColumnPass,
}

impl Fft2 {
    /// Build with the best column pass the plan supports (panel for
    /// split-radix, gather/scatter otherwise).
    pub fn new(n: usize, plan: std::sync::Arc<FftPlan>) -> Self {
        let columns = if plan.supports_panel() {
            ColumnPass::Panel
        } else {
            ColumnPass::GatherScatter
        };
        Self::with_column_pass(n, plan, columns)
    }

    /// Build with an explicit column pass. Panics if `Panel` is requested
    /// for a plan without strided butterflies (radix-2, Bluestein).
    pub fn with_column_pass(
        n: usize,
        plan: std::sync::Arc<FftPlan>,
        columns: ColumnPass,
    ) -> Self {
        assert_eq!(plan.len(), n, "plan size must match slice edge");
        assert!(
            columns == ColumnPass::GatherScatter || plan.supports_panel(),
            "panel column pass requires a radix kernel"
        );
        Self { n, plan, columns }
    }

    /// Build with a private plan (tests / one-off use).
    pub fn with_size(n: usize) -> Self {
        Self::new(n, std::sync::Arc::new(FftPlan::new(n)))
    }

    /// Edge length n of the n×n transform.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the edge length is zero.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The shared 1-D plan (twiddle tables).
    #[inline]
    pub fn plan(&self) -> &std::sync::Arc<FftPlan> {
        &self.plan
    }

    /// Which column-pass strategy this transform uses.
    #[inline]
    pub fn column_pass(&self) -> ColumnPass {
        self.columns
    }

    /// Scratch length required by [`Self::process`]: zero for the
    /// copy-free panel pass, `4n` gather buffers otherwise. Callers must
    /// size scratch from here rather than hard-coding `4n` — the two
    /// modes genuinely differ.
    #[inline]
    pub fn scratch_len(&self) -> usize {
        match self.columns {
            ColumnPass::Panel => 0,
            ColumnPass::GatherScatter => 4 * self.n,
        }
    }

    /// In-place unnormalized 2-D transform of a row-major `n × n` slice.
    /// `scratch` must have at least [`Self::scratch_len`] elements (it is
    /// untouched — and may be empty — in panel mode).
    pub fn process(&self, slice: &mut [Complex64], scratch: &mut [Complex64], sign: Sign) {
        let n = self.n;
        assert_eq!(slice.len(), n * n, "slice must be n*n");
        assert!(
            scratch.len() >= self.scratch_len(),
            "scratch must be scratch_len()"
        );
        // Row pass (unit stride).
        for row in slice.chunks_exact_mut(n) {
            self.plan.process(row, sign);
        }
        self.column_pass_range(slice, n, scratch, sign);
    }

    /// Column pass over columns `0..ncols` of a row-major `n × n` slice
    /// — the full complex transform uses `ncols = n`, the real-input
    /// path ([`super::real::RealFft2`]) only `n/2 + 1` (the rest follow
    /// from Hermitian symmetry).
    pub(crate) fn column_pass_range(
        &self,
        slice: &mut [Complex64],
        ncols: usize,
        scratch: &mut [Complex64],
        sign: Sign,
    ) {
        let n = self.n;
        debug_assert!(ncols <= n);
        match self.columns {
            ColumnPass::Panel => {
                // Butterflies straight over 4-column strided panels (one
                // cache line per row), all stages while the panel is
                // cache-resident.
                let mut c = 0;
                while c < ncols {
                    let cols = (ncols - c).min(4);
                    self.plan.process_panel(&mut slice[c..], n, cols, sign);
                    c += cols;
                }
            }
            ColumnPass::GatherScatter => {
                // Gather FOUR adjacent columns per sweep — they share
                // cache lines (4 × 16-byte complex = one 64-byte line),
                // so each line of the slice is touched once per sweep
                // instead of four times (§Perf in EXPERIMENTS.md).
                let mut c = 0;
                while c < ncols {
                    let cols = (ncols - c).min(4);
                    for r in 0..n {
                        let base = r * n + c;
                        for k in 0..cols {
                            scratch[k * n + r] = slice[base + k];
                        }
                    }
                    for k in 0..cols {
                        self.plan.process(&mut scratch[k * n..(k + 1) * n], sign);
                    }
                    for r in 0..n {
                        let base = r * n + c;
                        for k in 0..cols {
                            slice[base + k] = scratch[k * n + r];
                        }
                    }
                    c += cols;
                }
            }
        }
    }

    /// Convenience wrapper that allocates its own scratch.
    #[deprecated(
        since = "0.3.0",
        note = "allocates per call; use `process` with a reused \
                `scratch_len()`-sized buffer (or the executor's workspace)"
    )]
    /// Deprecated allocating wrapper around [`Self::process`].
    pub fn process_alloc(&self, slice: &mut [Complex64], sign: Sign) {
        let mut scratch = vec![Complex64::zero(); self.scratch_len()];
        self.process(slice, &mut scratch, sign);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft::dft2;
    use crate::fft::plan::FftAlgo;
    use crate::prng::Xoshiro256;

    fn random_slice(n: usize, seed: u64) -> Vec<Complex64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n * n)
            .map(|_| Complex64::new(rng.next_signed(), rng.next_signed()))
            .collect()
    }

    fn process_fresh(fft2: &Fft2, slice: &mut [Complex64], sign: Sign) {
        let mut scratch = vec![Complex64::zero(); fft2.scratch_len()];
        fft2.process(slice, &mut scratch, sign);
    }

    #[test]
    fn matches_2d_oracle() {
        for &n in &[2usize, 4, 8, 16, 6] {
            let fft2 = Fft2::with_size(n);
            assert_eq!(
                fft2.column_pass(),
                if n.is_power_of_two() {
                    ColumnPass::Panel
                } else {
                    ColumnPass::GatherScatter
                }
            );
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_slice(n, 11 + n as u64);
                let want = dft2(&x, n, n, sign);
                let mut got = x.clone();
                process_fresh(&fft2, &mut got, sign);
                for (a, b) in want.iter().zip(got.iter()) {
                    assert!((*a - *b).abs() < 1e-8 * n as f64, "n={n} sign={sign:?}");
                }
            }
        }
    }

    #[test]
    fn panel_and_gather_agree() {
        for &n in &[2usize, 4, 8, 32] {
            let plan = std::sync::Arc::new(FftPlan::new(n));
            let panel = Fft2::with_column_pass(n, plan.clone(), ColumnPass::Panel);
            let gather = Fft2::with_column_pass(n, plan, ColumnPass::GatherScatter);
            assert_eq!(panel.scratch_len(), 0);
            assert_eq!(gather.scratch_len(), 4 * n);
            for sign in [Sign::Negative, Sign::Positive] {
                let x = random_slice(n, 31 + n as u64);
                let mut a = x.clone();
                let mut b = x;
                process_fresh(&panel, &mut a, sign);
                process_fresh(&gather, &mut b, sign);
                for (u, v) in a.iter().zip(b.iter()) {
                    assert!((*u - *v).abs() < 1e-12 * n as f64, "n={n} sign={sign:?}");
                }
            }
        }
    }

    #[test]
    fn radix2_baseline_engine_matches_oracle() {
        let n = 8;
        let fft2 = Fft2::with_column_pass(
            n,
            std::sync::Arc::new(FftPlan::with_algo(n, FftAlgo::Radix2)),
            ColumnPass::GatherScatter,
        );
        let x = random_slice(n, 3);
        let want = dft2(&x, n, n, Sign::Positive);
        let mut got = x;
        process_fresh(&fft2, &mut got, Sign::Positive);
        for (a, b) in want.iter().zip(got.iter()) {
            assert!((*a - *b).abs() < 1e-8 * n as f64);
        }
    }

    #[test]
    fn roundtrip_scales_by_n_squared() {
        let n = 16;
        let fft2 = Fft2::with_size(n);
        let x = random_slice(n, 21);
        let mut y = x.clone();
        process_fresh(&fft2, &mut y, Sign::Positive);
        process_fresh(&fft2, &mut y, Sign::Negative);
        let scale = (n * n) as f64;
        for (a, b) in x.iter().zip(y.iter()) {
            assert!((a.scale(scale) - *b).abs() < 1e-8 * scale);
        }
    }

    #[test]
    fn separable_tone_lands_in_single_bin() {
        // f(i,k) = e^{+2πi(2i + 5k)/n} under the positive-sign transform
        // concentrates all energy in bin (n-2, n-5)... with positive sign
        // S(u,v) = Σ e^{+2πi(ui+vk)/n} f → peak where u+2 ≡ 0, v+5 ≡ 0.
        let n = 8usize;
        let fft2 = Fft2::with_size(n);
        let tau = std::f64::consts::TAU;
        let mut x = vec![Complex64::zero(); n * n];
        for i in 0..n {
            for k in 0..n {
                x[i * n + k] = Complex64::cis(tau * (2 * i + 5 * k) as f64 / n as f64);
            }
        }
        process_fresh(&fft2, &mut x, Sign::Positive);
        for u in 0..n {
            for v in 0..n {
                let mag = x[u * n + v].abs();
                if u == n - 2 && v == n - 5 {
                    assert!((mag - (n * n) as f64).abs() < 1e-8);
                } else {
                    assert!(mag < 1e-8, "leak at ({u},{v}): {mag}");
                }
            }
        }
    }

    #[test]
    #[allow(deprecated)]
    fn process_alloc_still_works() {
        let n = 8;
        let fft2 = Fft2::with_size(n);
        let x = random_slice(n, 77);
        let mut a = x.clone();
        let mut b = x;
        fft2.process_alloc(&mut a, Sign::Negative);
        process_fresh(&fft2, &mut b, Sign::Negative);
        for (u, v) in a.iter().zip(b.iter()) {
            assert_eq!(u.re, v.re);
            assert_eq!(u.im, v.im);
        }
    }
}
